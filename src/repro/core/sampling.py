"""Precision sampling (paper Eq. 11) and the retraining-phase embedding layer.

After the search phase, each group's final bit-width is the *highest* candidate
whose probability exceeds 1/(2m) — not the argmax: a high width with modest
probability still contributed a significant high-precision component to the
mixture, so the group "needs" it (§3.4).

The retrain layer quantizes each row at its sampled width with plain LSQ+/STE;
it is the mixture layer with a one-hot p, so it shares the fused kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantizer
from repro.core.mpe import MPEConfig, MPESearchEmbedding


def sample_group_bits(params, cfg: MPEConfig) -> jnp.ndarray:
    """Eq. (11): per-group sampled width index, shape (g,) int32."""
    p = MPESearchEmbedding.probabilities(params, cfg)        # (g, m)
    m = len(cfg.bits)
    thresh = 1.0 / (2 * m)
    eligible = p > thresh                                     # at least argmax qualifies
    idx = jnp.arange(m, dtype=jnp.int32)
    # highest eligible index (bits sorted ascending in cfg)
    return jnp.max(jnp.where(eligible, idx, -1), axis=-1).astype(jnp.int32)


def feature_bits(group_bits_idx: jnp.ndarray, group_of_feature: jnp.ndarray) -> jnp.ndarray:
    """Expand per-group width index to per-feature, shape (n,) int32."""
    return jnp.take(group_bits_idx, group_of_feature, axis=0)


def average_bits(bits_idx: jnp.ndarray, cfg: MPEConfig) -> float:
    b = np.asarray(cfg.bits, np.float32)[np.asarray(bits_idx)]
    return float(b.mean())


def storage_ratio(bits_idx_per_feature: jnp.ndarray, cfg: MPEConfig) -> float:
    """Bits stored / 32-bit full precision (paper's 'Ratio' column)."""
    b = np.asarray(cfg.bits, np.float32)[np.asarray(bits_idx_per_feature)]
    return float(b.mean() / 32.0)


class MPERetrainEmbedding:
    """Fixed-width QAT layer for the retraining phase (§3.4).

    params: emb (reset to the search phase's *initial* values), alpha, beta
    (warm-started from the searched values). buffers: per-feature width index.
    """

    @staticmethod
    def init(init_emb, searched_alpha, searched_beta, bits_idx_per_feature):
        params = {"emb": init_emb, "alpha": searched_alpha, "beta": searched_beta}
        buffers = {"bits_idx": bits_idx_per_feature.astype(jnp.int32)}
        return params, buffers

    @staticmethod
    def lookup(params, buffers, ids: jnp.ndarray, cfg: MPEConfig) -> jnp.ndarray:
        rows = jnp.take(params["emb"], ids, axis=0)
        widx = jnp.take(buffers["bits_idx"], ids, axis=0)         # (*ids,)
        onehot = jax.nn.one_hot(widx, len(cfg.bits), dtype=rows.dtype)
        return quantizer.mixed_expectation(rows, onehot, params["alpha"],
                                           params["beta"], cfg.bits)

    @staticmethod
    def reg_loss(params, buffers, cfg: MPEConfig) -> jnp.ndarray:
        del params, buffers, cfg
        return jnp.zeros(())
