"""MPE search-phase embedding layer (paper §3.2–§3.3).

Holds the full-precision table, per-group bit-width logits γ, per-width step
sizes α and per-dimension offsets β. Lookup returns the expectation over
candidate quantizers (Eq. 9); ``reg_loss`` is the frequency-weighted expected
bit-width (Eq. 10, second term, without λ).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantizer
from repro.nn import init as initializers


class MPEConfig(NamedTuple):
    bits: tuple = (0, 1, 2, 3, 4, 5, 6)  # paper §5.1.5
    group_size: int = 128                # paper §5.1.5
    tau: float = 3e-3                    # paper §5.1.5
    lam: float = 1e-5                    # swept in {1e-6 .. 3e-4} (paper)
    embed_std: float = initializers.EMBED_STD


def make_groups(freqs: np.ndarray, group_size: int):
    """Frequency-aware grouping (§3.2).

    Sort features by frequency (desc), split into groups of ``group_size``.
    Returns (group_of_feature (n,) int32, freq_sum_per_group (g,) float32).
    """
    freqs = np.asarray(freqs, np.float64)
    n = freqs.shape[0]
    order = np.argsort(-freqs, kind="stable")
    g = -(-n // group_size)
    group_of_rank = np.arange(n) // group_size
    group_of_feature = np.empty((n,), np.int32)
    group_of_feature[order] = group_of_rank.astype(np.int32)
    sums = np.zeros((g,), np.float64)
    np.add.at(sums, group_of_feature, freqs)
    return jnp.asarray(group_of_feature), jnp.asarray(np.maximum(sums, 1.0), dtype=jnp.float32)


class MPESearchEmbedding:
    """Functional module. ``buffers`` are non-trained constants."""

    @staticmethod
    def init(key, n: int, d: int, freqs, cfg: MPEConfig):
        m = len(cfg.bits)
        group_of_feature, freq_sum = make_groups(np.asarray(freqs), cfg.group_size)
        g = int(freq_sum.shape[0])
        emb = initializers.normal(key, (n, d), std=cfg.embed_std)
        params = {
            "emb": emb,
            # all-zero init => uniform distribution over candidate widths (§3.3)
            "gamma": jnp.zeros((g, m), jnp.float32),
            "alpha": jnp.asarray([quantizer.init_alpha(cfg.embed_std, b) for b in cfg.bits],
                                 jnp.float32),
            "beta": jnp.zeros((d,), jnp.float32),
        }
        buffers = {"group_of_feature": group_of_feature, "freq_sum": freq_sum}
        return params, buffers

    @staticmethod
    def probabilities(params, cfg: MPEConfig) -> jnp.ndarray:
        """(g, m) softmax(γ/τ) — Eq. (8)."""
        return jax.nn.softmax(params["gamma"] / cfg.tau, axis=-1)

    @staticmethod
    def lookup(params, buffers, ids: jnp.ndarray, cfg: MPEConfig) -> jnp.ndarray:
        """ids: int32 of any shape -> (*ids.shape, d) mixed-precision embeddings."""
        rows = jnp.take(params["emb"], ids, axis=0)
        # §Perf: keep gathered rows batch-sharded — without the pin, GSPMD
        # may replicate the (B, F, d) gather output to every device
        # (EXPERIMENTS.md §Perf wide-deep it1). No-op outside a mesh.
        from repro.dist.sharding import shard_batch_dim
        rows = shard_batch_dim(rows)
        p = MPESearchEmbedding.probabilities(params, cfg)        # (g, m)
        gid = jnp.take(buffers["group_of_feature"], ids, axis=0)
        probs = jnp.take(p, gid, axis=0)                          # (*ids, m)
        probs = shard_batch_dim(probs)
        return quantizer.mixed_expectation(rows, probs, params["alpha"],
                                           params["beta"], cfg.bits)

    @staticmethod
    def reg_loss(params, buffers, cfg: MPEConfig) -> jnp.ndarray:
        """Eq. (10): Σ_j (1/s_j) Σ_i b_i p_i^j  (caller multiplies by λ)."""
        p = MPESearchEmbedding.probabilities(params, cfg)         # (g, m)
        bits = jnp.asarray(cfg.bits, jnp.float32)
        per_group = p @ bits                                      # (g,)
        return jnp.sum(per_group / buffers["freq_sum"])

    @staticmethod
    def expected_bits(params, buffers, cfg: MPEConfig) -> jnp.ndarray:
        """Average expected bit-width over features (monitoring/compression)."""
        p = MPESearchEmbedding.probabilities(params, cfg)
        bits = jnp.asarray(cfg.bits, jnp.float32)
        per_group = p @ bits                                      # (g,)
        return jnp.mean(jnp.take(per_group, buffers["group_of_feature"]))
