"""Registry adapters exposing MPE phases through the common compressor API."""
from __future__ import annotations

import numpy as np

from repro.core.api import BaseCompressor, register
from repro.core.mpe import MPEConfig, MPESearchEmbedding
from repro.core.sampling import (MPERetrainEmbedding, feature_bits,
                                 sample_group_bits, storage_ratio as _ratio)


def as_mpe_config(cfg) -> MPEConfig:
    if isinstance(cfg, MPEConfig):
        return cfg
    if cfg is None:
        return MPEConfig()
    return MPEConfig(**{k: v for k, v in cfg.items() if k in MPEConfig._fields})


@register("mpe_search")
class MPESearch(BaseCompressor):
    @staticmethod
    def init(key, n, d, freqs, cfg):
        return MPESearchEmbedding.init(key, n, d, freqs, as_mpe_config(cfg))

    @staticmethod
    def lookup(params, buffers, ids, cfg, *, train=False, step=None):
        del train, step
        return MPESearchEmbedding.lookup(params, buffers, ids, as_mpe_config(cfg))

    @staticmethod
    def reg_loss(params, buffers, cfg):
        return MPESearchEmbedding.reg_loss(params, buffers, as_mpe_config(cfg))

    @staticmethod
    def storage_ratio(params, buffers, cfg):
        c = as_mpe_config(cfg)
        gb = sample_group_bits(params, c)
        fb = feature_bits(gb, buffers["group_of_feature"])
        return _ratio(fb, c)


@register("packed")
class Packed(BaseCompressor):
    """Serving-time compressor: the bit-packed table of §4.

    params = the packed table pytree from ``build_packed_table``; cfg must
    carry the static meta {"bits": tuple, "d": int}. ``init`` builds a random
    packed table (tests / dry-run only — production builds via the pipeline).
    """

    @staticmethod
    def init(key, n, d, freqs, cfg):
        import jax
        from repro.core.inference import build_packed_table
        from repro.core.mpe import MPEConfig, MPESearchEmbedding
        from repro.core.sampling import feature_bits, sample_group_bits
        c = as_mpe_config(cfg)
        params, buffers = MPESearchEmbedding.init(key, n, d, freqs, c)
        gamma = 0.01 * jax.random.normal(key, params["gamma"].shape)
        gb = sample_group_bits({**params, "gamma": gamma}, c)
        fb = feature_bits(gb, buffers["group_of_feature"])
        table, meta = build_packed_table(params["emb"], fb, params["alpha"],
                                         params["beta"], c)
        return table, {"meta": meta}

    @staticmethod
    def lookup(params, buffers, ids, cfg, *, train=False, step=None):
        del train, step
        from repro.core.inference import packed_lookup
        meta = (buffers or {}).get("meta") or {"bits": tuple(cfg["bits"]),
                                               "d": cfg["d"]}
        return packed_lookup(params, meta, ids)

    @staticmethod
    def storage_ratio(params, buffers, cfg):
        """True packed bytes (pad-free) from the width histogram."""
        import numpy as np
        from repro.core.packing import words_per_row
        meta = (buffers or {}).get("meta") or {"bits": tuple(cfg["bits"]),
                                               "d": cfg["d"], "n": cfg["n"]}
        widx = np.asarray(params["width_idx"])
        n, d = meta["n"], meta["d"]
        packed = sum(int((widx == i).sum()) * words_per_row(d, b) * 4
                     for i, b in enumerate(meta["bits"]) if b > 0)
        return packed / (n * d * 4.0)


@register("mpe_retrain")
class MPERetrain(BaseCompressor):
    """init() expects cfg to carry the search artifacts (see pipeline.py)."""

    @staticmethod
    def init(key, n, d, freqs, cfg):
        del key, n, d, freqs
        return MPERetrainEmbedding.init(cfg["init_emb"], cfg["alpha"],
                                        cfg["beta"], cfg["bits_idx"])

    @staticmethod
    def lookup(params, buffers, ids, cfg, *, train=False, step=None):
        del train, step
        return MPERetrainEmbedding.lookup(params, buffers, ids, as_mpe_config(cfg))

    @staticmethod
    def storage_ratio(params, buffers, cfg):
        c = as_mpe_config(cfg)
        return _ratio(np.asarray(buffers["bits_idx"]), c)
