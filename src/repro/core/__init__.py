"""The paper's contribution: Mixed-Precision Embeddings (MPE).

Public surface:
  - quantizer: LSQ+ fake quant with the paper's STE gradients (Eqs. 2, 4-6)
  - MPESearchEmbedding / MPEConfig: search phase (Eqs. 8-10)
  - sample_group_bits / MPERetrainEmbedding: sampling (Eq. 11) + retraining
  - build_packed_table / packed_lookup: bit-packed inference tables (§4)
  - baselines: QR-Trick, ALPT, LSQ+, PEP, OptFS (Table 3)
  - get_compressor: registry keyed by method name
"""
from repro.core.api import get_compressor, REGISTRY
from repro.core.mpe import MPEConfig, MPESearchEmbedding, make_groups
from repro.core.quantizer import lsq_quantize, mixed_expectation, int_bounds
from repro.core.sampling import (MPERetrainEmbedding, feature_bits,
                                 sample_group_bits, average_bits)
from repro.core.inference import (build_packed_table, packed_lookup,
                                  packed_specs, packed_storage_bytes)
import repro.core.baselines  # noqa: F401  (registry side-effects)
import repro.core.compressors  # noqa: F401

__all__ = [
    "get_compressor", "REGISTRY", "MPEConfig", "MPESearchEmbedding",
    "make_groups", "lsq_quantize", "mixed_expectation", "int_bounds",
    "MPERetrainEmbedding", "feature_bits", "sample_group_bits", "average_bits",
    "build_packed_table", "packed_lookup", "packed_specs", "packed_storage_bytes",
]
