"""LSQ+ uniform affine quantizer with the paper's closed-form STE gradients.

Implements paper Eq. (2) forward and Eqs. (4)(5)(6) backward exactly:

    v    = (theta - beta) / alpha
    vbar = clamp(round(v), N_b, P_b),  N_b = -2^(b-1), P_b = 2^(b-1) - 1
    Q    = alpha * vbar + beta

    dQ/dtheta = 1[N_b < v < P_b]                                   (Eq. 4)
    dQ/dalpha = N_b        if v <= N_b                             (Eq. 5)
                round(v)-v if N_b < v < P_b
                P_b        if v >= P_b
    dQ/dbeta  = 1[v <= N_b or v >= P_b]                            (Eq. 6)

``b`` is a static Python int (bit-widths are architecture constants); ``alpha``
is a scalar shared per bit-width and ``beta`` a per-dimension vector, matching
§3.3 ("a single step size for each bit-width and a single offset for each
embedding dimension").

b == 0 means the zero-embedding / feature-dropped case (§3.1) and is handled
by callers (contributes a zero vector with zero gradients).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def int_bounds(b: int) -> tuple[int, int]:
    """Signed-integer bounds [N_b, P_b] for a b-bit code."""
    if b < 1:
        raise ValueError(f"bit-width must be >= 1, got {b}")
    return -(2 ** (b - 1)), 2 ** (b - 1) - 1


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def lsq_quantize(theta: jnp.ndarray, alpha: jnp.ndarray, beta: jnp.ndarray,
                 b: int) -> jnp.ndarray:
    """Fake-quantize ``theta`` at ``b`` bits. alpha: scalar, beta: (d,) or scalar."""
    n_b, p_b = int_bounds(b)
    v = (theta - beta) / alpha
    vbar = jnp.clip(jnp.round(v), n_b, p_b)
    return alpha * vbar + beta


def _fwd(theta, alpha, beta, b):
    n_b, p_b = int_bounds(b)
    v = (theta - beta) / alpha
    vbar = jnp.clip(jnp.round(v), n_b, p_b)
    alpha_shape = jnp.shape(alpha)
    beta_shape = jnp.shape(beta)
    return alpha * vbar + beta, (v, vbar, alpha_shape, beta_shape)


def _reduce_to_shape(g, shape):
    """Sum-reduce cotangent ``g`` down to broadcast source ``shape``."""
    if g.shape == tuple(shape):
        return g
    extra = g.ndim - len(shape)
    g = jnp.sum(g, axis=tuple(range(extra))) if extra else g
    keep = tuple(i for i, s in enumerate(shape) if s == 1 and g.shape[i] != 1)
    if keep:
        g = jnp.sum(g, axis=keep, keepdims=True)
    return g.reshape(shape)


def _bwd(b, res, g):
    n_b, p_b = int_bounds(b)
    v, vbar, alpha_shape, beta_shape = res
    inside = (v > n_b) & (v < p_b)
    # Eq. 4
    d_theta = jnp.where(inside, g, 0.0)
    # Eq. 5 — alpha is shared across all quantized parameters: reduce-sum.
    dq_dalpha = jnp.where(v <= n_b, float(n_b),
                          jnp.where(v >= p_b, float(p_b), vbar - v))
    d_alpha = _reduce_to_shape(g * dq_dalpha, alpha_shape)
    # Eq. 6 — beta is shared per embedding dimension: reduce over leading axes.
    d_beta = _reduce_to_shape(g * jnp.where(inside, 0.0, 1.0), beta_shape)
    return d_theta, d_alpha, d_beta


lsq_quantize.defvjp(_fwd, _bwd)


def quantize_codes(theta: jnp.ndarray, alpha: jnp.ndarray, beta: jnp.ndarray,
                   b: int) -> jnp.ndarray:
    """Integer codes (no dequant) — used when exporting packed tables."""
    n_b, p_b = int_bounds(b)
    v = (theta - beta) / alpha
    return jnp.clip(jnp.round(v), n_b, p_b).astype(jnp.int32)


def dequantize_codes(codes: jnp.ndarray, alpha: jnp.ndarray, beta: jnp.ndarray) -> jnp.ndarray:
    return alpha * codes.astype(jnp.float32) + beta


# ---------------------------------------------------------------------------
# symmetric int8 helpers (KV caches, expert weights)
#
# These are the *sanctioned* narrow→wide conversion sites: the staticcheck
# precision-flow pass (repro.analysis.precision) attributes every
# convert-out-of-a-narrow-int to its source module and only this module and
# core/packing.py may widen quantized codes. Routing a dequant through here
# is what marks it audited — the expressions are kept to the exact op order
# of the call sites they replaced, so lowering stays bit-identical.
# ---------------------------------------------------------------------------

def dequantize_symmetric(q: jnp.ndarray, scale: jnp.ndarray,
                         dtype=jnp.float32) -> jnp.ndarray:
    """Symmetric (zero-offset) dequant: ``q * scale`` in ``dtype``.

    Both factors are cast *before* the multiply (``q.astype(dtype) *
    scale.astype(dtype)``) — the order the int8 KV-cache attention reads and
    the MoE expert matmuls always used; changing it would move the rounding
    point and break the bit-exactness tests."""
    return q.astype(dtype) * scale.astype(dtype)


def quantize_symmetric(vals: jnp.ndarray, scale: jnp.ndarray,
                       dtype=jnp.int8) -> jnp.ndarray:
    """Symmetric quant onto the int8 grid: round(vals/scale) clipped to
    ±127. ``vals`` should already be fp32 (callers hold the absmax
    calibration; this is only the grid projection)."""
    return jnp.clip(jnp.round(vals / scale), -127, 127).astype(dtype)


def requantize_int8(codes: jnp.ndarray, ratio: jnp.ndarray) -> jnp.ndarray:
    """Re-project stored int8 codes onto a coarser grid: ``round(codes *
    ratio)`` clipped to ±127, with ``ratio = old_scale / new_scale`` ≤ 1.
    The running-absmax KV cache uses this when a scale grows
    (``LM._requant_cache``)."""
    return jnp.clip(jnp.round(codes.astype(jnp.float32) * ratio),
                    -127, 127).astype(jnp.int8)


def init_alpha(std: float, b: int) -> float:
    """LSQ-style step-size init: alpha ≈ 2·E|θ| / sqrt(P_b) with θ~N(0,std)."""
    if b < 1:
        return 1.0  # unused placeholder for the b=0 slot
    _, p_b = int_bounds(b)
    mean_abs = std * 0.7978845608  # E|N(0,std)| = std * sqrt(2/pi)
    return float(2.0 * mean_abs / max(p_b, 1) ** 0.5)


def mixed_expectation(rows: jnp.ndarray, probs: jnp.ndarray, alpha: jnp.ndarray,
                      beta: jnp.ndarray, bits: tuple) -> jnp.ndarray:
    """Paper Eq. (9): ē = Σ_i p_i · Q(e, α_i, β, b_i).

    rows: (..., d) gathered embeddings; probs: (..., m) per-row probabilities
    over candidate widths; alpha: (m,); beta: (d,); bits: static tuple.

    This is the pure-jnp reference; ``repro.kernels.mpe_qat`` fuses the m
    passes into one VMEM-resident Pallas kernel.
    """
    out = jnp.zeros_like(rows)
    for i, b in enumerate(bits):
        if b == 0:
            continue  # zero vector contribution (feature-selection case)
        q = lsq_quantize(rows, alpha[i], beta, int(b))
        out = out + probs[..., i:i + 1] * q
    return out
