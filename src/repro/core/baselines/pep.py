"""PEP — Plug-in Embedding Pruning with learnable thresholds [arXiv:2101.07577].

ẽ = sign(e) ⊙ relu(|e| − σ(s)) with learnable threshold logits s (one per
embedding dimension, PEP's 'dimension-wise' variant). Parameters whose
magnitude falls below the threshold are exactly zero after training; the
storage ratio is the nonzero fraction (sparse-format index overhead is
reported separately by the latency benchmark, mirroring paper §5.5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.api import BaseCompressor, register
from repro.nn import init as initializers

THRESH_LOGIT_INIT = -15.0  # PEP paper: start with a vanishing threshold


@register("pep")
class PEP(BaseCompressor):
    @staticmethod
    def init(key, n, d, freqs, cfg):
        del freqs
        std = (cfg or {}).get("embed_std", initializers.EMBED_STD)
        return {
            "emb": initializers.normal(key, (n, d), std=std),
            "thresh_logit": jnp.full((d,), THRESH_LOGIT_INIT, jnp.float32),
        }, {}

    @staticmethod
    def _prune(rows, thresh_logit):
        t = jax.nn.sigmoid(thresh_logit)
        return jnp.sign(rows) * jax.nn.relu(jnp.abs(rows) - t)

    @staticmethod
    def lookup(params, buffers, ids, cfg, *, train=False, step=None):
        del buffers, cfg, train, step
        rows = jnp.take(params["emb"], ids, axis=0)
        return PEP._prune(rows, params["thresh_logit"])

    @staticmethod
    def storage_ratio(params, buffers, cfg):
        import numpy as np
        t = np.asarray(jax.nn.sigmoid(params["thresh_logit"]))
        emb = np.asarray(params["emb"])
        nnz = (np.abs(emb) > t).mean()
        return float(nnz)
