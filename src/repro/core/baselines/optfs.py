"""OptFS — Optimizing Feature Set via learnable gates [arXiv:2301.10909, WWW'23].

A per-feature gate g ∈ [0,1] multiplies the embedding; learning-by-continuation
sharpens σ(w·τ_anneal) toward a step function over training. Features with
g < 0.5 at the end are dropped (zero rows — the b=0 case of MPE, §3.1). An L1
regularizer pushes gates closed; the storage ratio is the kept-row fraction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.api import BaseCompressor, register
from repro.nn import init as initializers

ANNEAL_START = 1.0
ANNEAL_END = 100.0


@register("optfs")
class OptFS(BaseCompressor):
    @staticmethod
    def init(key, n, d, freqs, cfg):
        del freqs
        std = (cfg or {}).get("embed_std", initializers.EMBED_STD)
        return {
            "emb": initializers.normal(key, (n, d), std=std),
            "gate_logit": jnp.full((n,), 1.0, jnp.float32),  # start ~open (σ≈0.73)
        }, {}

    @staticmethod
    def _anneal(step, total_steps):
        if step is None:
            return ANNEAL_END
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return ANNEAL_START * (ANNEAL_END / ANNEAL_START) ** t

    @staticmethod
    def lookup(params, buffers, ids, cfg, *, train=False, step=None):
        del buffers
        cfg = cfg or {}
        rows = jnp.take(params["emb"], ids, axis=0)
        logit = jnp.take(params["gate_logit"], ids, axis=0)
        if train:
            tau = OptFS._anneal(step, cfg.get("total_steps", 1000))
            gate = jax.nn.sigmoid(logit * tau)
        else:
            gate = (logit > 0.0).astype(rows.dtype)
        return rows * gate[..., None]

    @staticmethod
    def reg_loss(params, buffers, cfg):
        del buffers, cfg
        return jnp.mean(jax.nn.sigmoid(params["gate_logit"]))

    @staticmethod
    def storage_ratio(params, buffers, cfg):
        import numpy as np
        return float((np.asarray(params["gate_logit"]) > 0).mean())
