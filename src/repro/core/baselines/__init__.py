from repro.core.baselines.plain import PlainEmbedding
from repro.core.baselines.lsq_uniform import LSQUniform
from repro.core.baselines.alpt import ALPT
from repro.core.baselines.qr_trick import QRTrick
from repro.core.baselines.pep import PEP
from repro.core.baselines.optfs import OptFS

__all__ = ["PlainEmbedding", "LSQUniform", "ALPT", "QRTrick", "PEP", "OptFS"]
