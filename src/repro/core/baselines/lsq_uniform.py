"""Uniform-precision QAT with LSQ+ (the 'LSQ+' row of Table 3).

One bit-width for the whole table (paper finds b=6 is the lossless floor).
This is exactly MPE with a degenerate one-candidate distribution, which is the
limitation MPE fixes (§1.2).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import quantizer
from repro.core.api import BaseCompressor, register
from repro.nn import init as initializers


@register("lsq")
class LSQUniform(BaseCompressor):
    @staticmethod
    def init(key, n, d, freqs, cfg):
        del freqs
        cfg = cfg or {}
        std = cfg.get("embed_std", initializers.EMBED_STD)
        b = cfg.get("bits", 6)
        return {
            "emb": initializers.normal(key, (n, d), std=std),
            "alpha": jnp.asarray(quantizer.init_alpha(std, b), jnp.float32),
            "beta": jnp.zeros((d,), jnp.float32),
        }, {}

    @staticmethod
    def lookup(params, buffers, ids, cfg, *, train=False, step=None):
        del buffers, train, step
        b = (cfg or {}).get("bits", 6)
        rows = jnp.take(params["emb"], ids, axis=0)
        return quantizer.lsq_quantize(rows, params["alpha"], params["beta"], int(b))

    @staticmethod
    def storage_ratio(params, buffers, cfg):
        return (cfg or {}).get("bits", 6) / 32.0
