"""QR-Trick — quotient-remainder compositional embeddings [arXiv:1909.02107].

e(id) = E_q[id // k]  ∘  E_r[id % k], with ∘ ∈ {mult, add}. Storage is
(⌈n/k⌉ + k)·d instead of n·d. The MPE paper evaluates it at its minimum 2×
compression (k=2, ratio ≈ 0.5) where it already loses accuracy (Table 3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.api import BaseCompressor, register
from repro.nn import init as initializers


@register("qr")
class QRTrick(BaseCompressor):
    @staticmethod
    def init(key, n, d, freqs, cfg):
        del freqs
        cfg = cfg or {}
        std = cfg.get("embed_std", initializers.EMBED_STD)
        k = cfg.get("k", 2)
        kq, kr = jax.random.split(key)
        n_q = -(-n // k)
        params = {
            "quot": initializers.normal(kq, (n_q, d), std=std),
            # mult combine: remainder table around 1 so init ≈ quotient table
            "rem": 1.0 + initializers.normal(kr, (k, d), std=std),
        }
        return params, {}

    @staticmethod
    def lookup(params, buffers, ids, cfg, *, train=False, step=None):
        del buffers, train, step
        k = (cfg or {}).get("k", 2)
        combine = (cfg or {}).get("combine", "mult")
        q = jnp.take(params["quot"], ids // k, axis=0)
        r = jnp.take(params["rem"], ids % k, axis=0)
        return q * r if combine == "mult" else q + r

    @staticmethod
    def storage_ratio(params, buffers, cfg):
        n_q = params["quot"].shape[0]
        k = params["rem"].shape[0]
        # vs. the uncompressed n×d table this replaced
        return float(n_q + k) / float(n_q * (cfg or {}).get("k", 2))
