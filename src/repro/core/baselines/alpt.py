"""ALPT — Adaptive Low-Precision Training [arXiv:2212.05735, AAAI'23].

Unlike QAT (full-precision master weights), ALPT keeps the embedding table in
a b-bit representable state *throughout training*: after every optimizer step
the table is projected back onto the quantization grid with stochastic
rounding, with a learnable step size α adapted via LSQ-style gradients. The
paper reports b=8 as ALPT's lossless floor (Table 3) because no full-precision
master copy exists.

Functional-JAX adaptation: the param leaf is float but always grid-valued
(== dequantized codes); ``post_update`` performs the stochastic-rounding
projection, so checkpoint/serving can store pure int codes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quantizer
from repro.core.api import BaseCompressor, register
from repro.nn import init as initializers


@register("alpt")
class ALPT(BaseCompressor):
    @staticmethod
    def init(key, n, d, freqs, cfg):
        del freqs
        cfg = cfg or {}
        std = cfg.get("embed_std", initializers.EMBED_STD)
        b = cfg.get("bits", 8)
        alpha0 = quantizer.init_alpha(std, b)
        emb = initializers.normal(key, (n, d), std=std)
        # start on-grid
        params = {
            "emb": emb,
            "alpha": jnp.asarray(alpha0, jnp.float32),
        }
        params["emb"] = ALPT._project(params["emb"], params["alpha"], b,
                                      jax.random.fold_in(key, 1))
        return params, {}

    @staticmethod
    def _project(emb, alpha, b, key):
        """Stochastic rounding of emb/alpha onto the signed b-bit grid."""
        n_b, p_b = quantizer.int_bounds(b)
        v = emb / alpha
        low = jnp.floor(v)
        frac = v - low
        up = jax.random.uniform(key, emb.shape) < frac
        codes = jnp.clip(low + up.astype(low.dtype), n_b, p_b)
        return alpha * codes

    @staticmethod
    def lookup(params, buffers, ids, cfg, *, train=False, step=None):
        del buffers, step
        b = (cfg or {}).get("bits", 8)
        rows = jnp.take(params["emb"], ids, axis=0)
        if train:
            # LSQ-style fake quant so α receives its adaptation gradient.
            return quantizer.lsq_quantize(rows, params["alpha"],
                                          jnp.zeros((), jnp.float32), int(b))
        return rows  # already grid-valued

    @staticmethod
    def post_update(params, buffers, cfg, key):
        del buffers
        b = (cfg or {}).get("bits", 8)
        params = dict(params)
        params["emb"] = ALPT._project(params["emb"], params["alpha"], int(b), key)
        return params

    @staticmethod
    def storage_ratio(params, buffers, cfg):
        return (cfg or {}).get("bits", 8) / 32.0
