"""Full-precision backbone embedding (the 'Backbone' row of Table 3)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.api import BaseCompressor, register
from repro.nn import init as initializers


@register("plain")
class PlainEmbedding(BaseCompressor):
    @staticmethod
    def init(key, n, d, freqs, cfg):
        del freqs
        std = (cfg or {}).get("embed_std", initializers.EMBED_STD)
        return {"emb": initializers.normal(key, (n, d), std=std)}, {}

    @staticmethod
    def lookup(params, buffers, ids, cfg, *, train=False, step=None):
        del buffers, cfg, train, step
        return jnp.take(params["emb"], ids, axis=0)

    @staticmethod
    def storage_ratio(params, buffers, cfg):
        return 1.0
