"""Common interface for embedding compressors (MPE + all Table-3 baselines).

Every compressor is a class of static methods:

    init(key, n, d, freqs, cfg)          -> (params, buffers)
    lookup(params, buffers, ids, cfg, *, train=False, step=None) -> (*ids, d)
    reg_loss(params, buffers, cfg)       -> scalar (caller scales by its λ)
    storage_ratio(params, buffers, cfg)  -> float, post-training bytes ratio
    post_update(params, buffers, cfg, key) -> params   (optional projection hook)

``buffers`` are non-trained constants (group maps, frequency stats, code
assignments); ``cfg`` is a plain dict or NamedTuple of static hyperparameters.
"""
from __future__ import annotations

REGISTRY: dict[str, type] = {}


def register(name: str):
    def deco(cls):
        cls.name = name
        REGISTRY[name] = cls
        return cls
    return deco


def get_compressor(name: str):
    if name not in REGISTRY:
        # import side-effect registration
        import repro.core.baselines  # noqa: F401
        import repro.core.compressors  # noqa: F401
    return REGISTRY[name]


class BaseCompressor:
    """Default no-op hooks shared by all compressors."""
    name = "base"

    @staticmethod
    def reg_loss(params, buffers, cfg):
        import jax.numpy as jnp
        return jnp.zeros(())

    @staticmethod
    def post_update(params, buffers, cfg, key):
        return params
