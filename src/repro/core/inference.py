"""Packed mixed-precision inference table (paper §4, TPU-adapted).

Storage layout: one bit-packed subtable per non-zero candidate width. Rows are
permuted so every subtable is dense; two small index vectors map a global
feature id to (width bucket, local row). Sub-8-bit codes are packed into
uint32 words (see ``repro.core.packing``); a lookup gathers the packed words,
unpacks with static shifts, and dequantizes ``α_b · code + β``.

The pure-jnp lookup below computes all width buckets and selects — static
shapes, shards cleanly under pjit (subtables row-sharded over the model axis).
``repro.kernels.mpe_lookup`` is the fused Pallas version of the per-bucket
gather+unpack+dequant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.mpe import MPEConfig
from repro.core.quantizer import (dequantize_codes, int_bounds,
                                  quantize_codes)


def _pad_rows(n: int, multiple: int) -> int:
    return max(multiple, -(-n // multiple) * multiple)


def _auto_pad_multiple(n: int, n_widths: int, cap: int = 512) -> int:
    """Largest power-of-two ≤ ``cap`` whose worst-case total padding
    (``multiple`` rows per non-empty subtable) stays under n/8 rows.

    512 at production scale — every mesh axis combination divides it, so row
    shards stay even — but a small table (tests, offline export) would drown
    in 512-row padding, so the multiple scales down (≥ 8, the sublane width).
    """
    m = 8
    while m < cap and m * 2 * n_widths * 8 <= n:
        m *= 2
    return m


def build_packed_table(emb, bits_idx_per_feature, alpha, beta, cfg: MPEConfig,
                       row_pad_multiple: int | None = None,
                       row_capacities: dict | None = None):
    """Quantize + pack a trained table.

    Returns a dict pytree ``table`` plus a static metadata dict.
    ``row_pad_multiple`` defaults to a size-aware power of two (see
    ``_auto_pad_multiple``); pass 512 explicitly to force production mesh
    alignment on a small table.

    ``row_capacities`` (``{"b<width>": rows, ...}``) pins each subtable to an
    *exact* padded row count instead of the multiple-derived one — the
    serving-time repack path (``repro.serve.repack``) uses this to re-pack a
    new precision assignment into the byte layout a compiled executable
    already expects, so the swap never recompiles. Raises ``ValueError`` when
    a width bucket holds more real rows than its pinned capacity.
    """
    emb = np.asarray(emb)
    bits_idx = np.asarray(bits_idx_per_feature)
    if row_pad_multiple is None:
        n_widths = sum(1 for b in cfg.bits if b != 0)
        row_pad_multiple = _auto_pad_multiple(emb.shape[0], n_widths)
    alpha_np = np.asarray(alpha)
    beta_np = np.asarray(beta)
    n, d = emb.shape

    subtables = {}
    local_idx = np.zeros((n,), np.int32)
    for i, b in enumerate(cfg.bits):
        sel = np.nonzero(bits_idx == i)[0]
        local_idx[sel] = np.arange(sel.shape[0], dtype=np.int32)
        if b == 0:
            continue
        rows = emb[sel] if sel.size else np.zeros((0, d), emb.dtype)
        codes = np.asarray(quantize_codes(jnp.asarray(rows), alpha_np[i], beta_np, int(b)))
        if row_capacities is not None:
            padded = int(row_capacities[f"b{b}"])
            if codes.shape[0] > padded:
                raise ValueError(
                    f"width bucket b{b} holds {codes.shape[0]} rows, over its "
                    f"pinned capacity {padded} — a capacity-conforming repack "
                    f"must assign within the compiled subtable shapes")
        else:
            padded = _pad_rows(codes.shape[0], row_pad_multiple)
        n_b, _ = int_bounds(b)
        codes_p = np.full((padded, d), n_b, np.int32)
        codes_p[:codes.shape[0]] = codes
        subtables[f"b{b}"] = jnp.asarray(np.asarray(packing.pack_codes(jnp.asarray(codes_p), b)))

    table = {
        "subtables": subtables,
        "local_idx": jnp.asarray(local_idx),
        "width_idx": jnp.asarray(bits_idx.astype(np.int32)),
        "alpha": jnp.asarray(alpha_np),
        "beta": jnp.asarray(beta_np),
    }
    meta = {"bits": cfg.bits, "d": d, "n": n}
    return table, meta


def packed_lookup(table, meta, ids: jnp.ndarray) -> jnp.ndarray:
    """ids: any int shape -> (*ids.shape, d) fp32 dequantized embeddings."""
    bits = meta["bits"]
    d = meta["d"]
    flat = ids.reshape(-1)
    widx = jnp.take(table["width_idx"], flat, axis=0)           # (B,)
    lidx = jnp.take(table["local_idx"], flat, axis=0)           # (B,)
    out = jnp.zeros((flat.shape[0], d), jnp.float32)
    for i, b in enumerate(bits):
        if b == 0:
            continue  # zero-width features contribute the zero vector
        sub = table["subtables"][f"b{b}"]
        words = jnp.take(sub, jnp.clip(lidx, 0, sub.shape[0] - 1), axis=0)
        codes = packing.unpack_codes(words, b, d)               # (B, d)
        deq = dequantize_codes(codes, table["alpha"][i], table["beta"])
        out = jnp.where((widx == i)[:, None], deq, out)
    return out.reshape(*ids.shape, d)


def packed_lookup_fn(meta):
    """``packed_lookup`` with the static metadata bound: ``(table, ids) ->
    embeddings``. The closure is jit-stable (meta never appears as a traced
    argument), so the serving engine can compile one lookup-only executable
    per cell shape for the Figure-5 lookup-vs-compute latency split."""
    return lambda table, ids: packed_lookup(table, meta, ids)


def packed_storage_bytes(table) -> int:
    """Bytes of the packed subtables (index vectors reported separately)."""
    return sum(int(v.size) * 4 for v in jax.tree.leaves(table["subtables"]))


def packed_specs(n: int, d: int, cfg: MPEConfig, width_histogram,
                 row_pad_multiple: int = 512):
    """ShapeDtypeStruct stand-ins for a packed table — used by the dry-run.

    ``width_histogram``: fraction of rows per candidate width (sums to 1).
    """
    subtables = {}
    for i, b in enumerate(cfg.bits):
        if b == 0:
            continue
        rows = _pad_rows(int(n * width_histogram[i]), row_pad_multiple)
        subtables[f"b{b}"] = jax.ShapeDtypeStruct(
            (rows, packing.words_per_row(d, b)), jnp.uint32)
    return {
        "subtables": subtables,
        "local_idx": jax.ShapeDtypeStruct((n,), jnp.int32),
        "width_idx": jax.ShapeDtypeStruct((n,), jnp.int32),
        "alpha": jax.ShapeDtypeStruct((len(cfg.bits),), jnp.float32),
        "beta": jax.ShapeDtypeStruct((d,), jnp.float32),
    }
