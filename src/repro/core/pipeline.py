"""End-to-end MPE pipeline: search → sample → retrain → packed export (§3.4).

Model-agnostic: every model in the zoo stores its compressor state under
``params["embedding"]`` / ``buffers["embedding"]``, so phase transitions are
key swaps. The pipeline implements the paper's three retraining variants
(Table 4):

  - "none": quantize the searched embeddings at the sampled widths directly;
  - "lth":  Lottery-Ticket reset — *all* params back to their initial values;
  - "mpe":  the paper's scheme — embeddings reset to the search-phase init,
            step sizes α, offsets β and the interaction network W warm-started
            from the search phase.

The model is supplied as a builder: build(key, compressor, comp_cfg) ->
{"params", "buffers", "state", "loss_fn", "eval_fn"} where loss_fn follows the
Trainer signature.
"""
from __future__ import annotations

from typing import Callable

import jax
import numpy as np

from repro.core.inference import build_packed_table, packed_storage_bytes
from repro.core.mpe import MPEConfig
from repro.core.sampling import (MPERetrainEmbedding, average_bits,
                                 feature_bits, sample_group_bits,
                                 storage_ratio)
from repro.train.loop import Trainer


def jnp_array(x):
    import jax.numpy as jnp
    return jnp.asarray(x)


def run_mpe_pipeline(build: Callable, data_fn: Callable, *, key,
                     mpe_cfg: MPEConfig, optimizer, search_steps: int,
                     retrain_steps: int, retrain_mode: str = "mpe",
                     eval_fn: Callable | None = None, log_fn=print,
                     ckpt_dir: str | None = None, prefetch: bool = False,
                     mesh=None) -> dict:
    comp_cfg = mpe_cfg._asdict()

    # ---------------- phase 1: precision search ----------------
    bundle = build(key, "mpe_search", comp_cfg)
    params0 = jax.tree.map(lambda x: x, bundle["params"])  # shallow copy of refs
    init_snapshot = jax.tree.map(np.asarray, params0)      # host copy of init
    trainer = Trainer(bundle["loss_fn"], bundle["params"], bundle["buffers"],
                      bundle["state"], optimizer, mesh=mesh,
                      ckpt_dir=None if ckpt_dir is None else f"{ckpt_dir}/search")
    trainer.restore()
    log_fn(f"[mpe] search phase: {search_steps} steps")
    trainer.run(data_fn, search_steps, log_fn=log_fn, prefetch=prefetch)
    # host snapshots: the trainers donate their carries, so later phases must
    # not alias live device arrays from this one.
    search_params = jax.tree.map(np.asarray, trainer.params)
    search_state = jax.tree.map(np.asarray, trainer.state)

    # ---------------- phase 2: precision sampling (Eq. 11) ----------------
    group_bits = sample_group_bits(search_params["embedding"], mpe_cfg)
    gof = bundle["buffers"]["embedding"]["group_of_feature"]
    fbits = feature_bits(group_bits, gof)
    avg_b = average_bits(fbits, mpe_cfg)
    ratio = storage_ratio(fbits, mpe_cfg)
    log_fn(f"[mpe] sampled avg bits={avg_b:.3f} ratio={ratio:.4f}")

    # ---------------- phase 3: retraining ----------------
    searched_alpha = search_params["embedding"]["alpha"]
    searched_beta = search_params["embedding"]["beta"]
    if retrain_mode == "none":
        emb_src = search_params["embedding"]["emb"]
        base = search_params
        steps = 0
    elif retrain_mode == "lth":
        base = jax.tree.map(jax.numpy.asarray, init_snapshot)
        emb_src = base["embedding"]["emb"]
        searched_alpha = base["embedding"]["alpha"]
        searched_beta = base["embedding"]["beta"]
        steps = retrain_steps
    elif retrain_mode == "mpe":
        base = search_params                         # warm-start W (paper §3.4)
        emb_src = jax.numpy.asarray(init_snapshot["embedding"]["emb"])
        steps = retrain_steps
    else:
        raise ValueError(retrain_mode)

    emb_params, emb_buffers = MPERetrainEmbedding.init(
        emb_src, searched_alpha, searched_beta, fbits)
    retrain_params = {k: v for k, v in base.items() if k != "embedding"}
    retrain_params["embedding"] = emb_params
    retrain_buffers = {k: v for k, v in bundle["buffers"].items() if k != "embedding"}
    retrain_buffers["embedding"] = emb_buffers

    rb = build(key, "mpe_retrain", {**comp_cfg, "init_emb": emb_src,
                                    "alpha": searched_alpha, "beta": searched_beta,
                                    "bits_idx": fbits})
    # rebuild only for the loss_fn closure; swap in our params/state
    retrain_params = jax.tree.map(jnp_array, retrain_params)
    trainer2 = Trainer(rb["loss_fn"], retrain_params, retrain_buffers,
                       jax.tree.map(jnp_array, search_state), optimizer,
                       mesh=mesh,
                       ckpt_dir=None if ckpt_dir is None else f"{ckpt_dir}/retrain")
    if steps:
        trainer2.restore()
        log_fn(f"[mpe] retrain phase ({retrain_mode}): {steps} steps")
        trainer2.run(data_fn, steps, log_fn=log_fn, prefetch=prefetch)
    final_params = trainer2.params

    # ---------------- phase 4: packed export ----------------
    table, meta = build_packed_table(final_params["embedding"]["emb"], fbits,
                                     final_params["embedding"]["alpha"],
                                     final_params["embedding"]["beta"], mpe_cfg)
    result = {
        "search_params": search_params,
        "final_params": final_params,
        "buffers": retrain_buffers,
        "state": trainer2.state,
        "group_bits": np.asarray(group_bits),
        "feature_bits_idx": np.asarray(fbits),
        "avg_bits": avg_b,
        "storage_ratio": ratio,
        "packed_table": table,
        "packed_meta": meta,
        "packed_bytes": packed_storage_bytes(table),
    }
    if eval_fn is not None:
        result["eval"] = eval_fn(final_params, retrain_buffers, trainer2.state)
        log_fn(f"[mpe] eval: {result['eval']}")
    return result
