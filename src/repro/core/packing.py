"""Bit-level packing of sub-8-bit integer codes into uint32 words.

The paper (§4) concatenates each embedding vector at the bit level and stores
it as Int-16 words (PyTorch has no sub-8-bit dtypes). On TPU the natural lane
width is 32 bits, so we pack into uint32 words instead: a row of ``d`` codes at
``b`` bits occupies ceil(d*b/32) words. Codes are stored as unsigned offsets
``u = code - N_b`` in [0, 2^b).

Both pack and unpack are fully vectorized (no Python loop over rows) and
jit-able; codes may straddle word boundaries (b ∈ {3,5,6,7} with 32 % b != 0).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.quantizer import int_bounds


def words_per_row(d: int, b: int) -> int:
    return -(-d * b // 32)  # ceil


def row_bytes(d: int, b: int) -> int:
    """Stored bytes of one packed row of ``d`` codes at ``b`` bits.

    This is the unit of host→device traffic for a cold-tier row fill
    (``repro.cache.tiers``): a miss moves the *packed* words, not the
    dequantized fp32 vector, so the transfer inherits the compression ratio.
    """
    return words_per_row(d, b) * 4


def pack_codes(codes: jnp.ndarray, b: int) -> jnp.ndarray:
    """codes: (n, d) signed ints in [N_b, P_b] -> (n, W) uint32."""
    n, d = codes.shape
    n_b, _ = int_bounds(b)
    w = words_per_row(d, b)
    u = (codes - n_b).astype(jnp.uint32)            # (n, d) in [0, 2^b)
    bitpos = jnp.arange(d) * b
    w0 = bitpos // 32                               # (d,)
    off = (bitpos % 32).astype(jnp.uint32)
    lo = u << off                                   # uint32: overflow bits drop
    straddles = (bitpos % 32) + b > 32
    shift_hi = jnp.clip(32 - (bitpos % 32), 0, 31).astype(jnp.uint32)
    hi = jnp.where(straddles, u >> shift_hi, jnp.uint32(0))
    words = jnp.zeros((n, w), jnp.uint32)
    words = words.at[:, w0].add(lo)                 # disjoint bits: add == or
    w1 = jnp.clip(w0 + 1, 0, w - 1)
    words = words.at[:, w1].add(hi)
    return words


def unpack_codes(words: jnp.ndarray, b: int, d: int) -> jnp.ndarray:
    """(n, W) uint32 -> (n, d) signed int32 codes."""
    n_b, _ = int_bounds(b)
    w = words.shape[-1]
    bitpos = jnp.arange(d) * b
    w0 = bitpos // 32
    off = (bitpos % 32).astype(jnp.uint32)
    lo = words[..., w0] >> off
    straddles = (bitpos % 32) + b > 32
    shift_hi = jnp.clip(32 - (bitpos % 32), 0, 31).astype(jnp.uint32)
    w1 = jnp.clip(w0 + 1, 0, w - 1)
    hi = jnp.where(straddles, words[..., w1] << shift_hi, jnp.uint32(0))
    mask = jnp.uint32((1 << b) - 1)
    u = (lo | hi) & mask
    return u.astype(jnp.int32) + n_b
