"""Injectable clocks for the request lifecycle.

Every timestamp in the serving stack flows from one callable: the engine's
``clock`` (default ``time.perf_counter``). The scheduler measures assembly
and compute with it, ``submit`` stamps arrivals with it, and the open-loop
replay threads an explicit virtual ``now`` through ``Scheduler.step``
*alongside* it. Injecting ``ManualClock`` makes every one of those numbers
deterministic — wall-clock never leaks into a virtual-timeline assertion —
which is what lets the max-wait-window and shedding tests pin exact
dispatch/shed times (see ``tests/test_queue.py``).
"""
from __future__ import annotations


class TickClock:
    """A clock that advances by a fixed ``dt`` on every read.

    With a ``TickClock`` injected into ``Engine(clock=...)``, every measured
    duration in the lifecycle (assembly, compute, queue wait) becomes a fixed
    number of ticks, so an open-loop replay — whose cursor advances by
    *measured* work — follows one exact trajectory regardless of host speed:
    the same arrivals coalesce into the same chunks, the same requests shed,
    the same ids hit or miss the tiered cache. That determinism is what lets
    the CI bench gate (``scripts/bench_compare.py --gate``) treat hit-rate /
    bytes-moved / shed / occupancy numbers as exact, never-flaky metrics
    while wall-clock latencies stay advisory."""

    def __init__(self, dt: float = 1e-4, start: float = 0.0):
        if dt <= 0:
            raise ValueError(f"dt must be > 0, got {dt}")
        self._t = float(start)
        self._dt = float(dt)

    def __call__(self) -> float:
        self._t += self._dt
        return self._t


class ManualClock:
    """A clock that only moves when told to.

    Call it like ``time.perf_counter`` (returns the current virtual time in
    seconds); ``advance``/``set`` move it. With a ``ManualClock`` injected
    into ``Engine(clock=...)``, measured assembly/compute durations are
    exactly the amount the test advanced between calls — zero by default —
    so per-request breakdowns and shed timestamps are exact."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def __call__(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        """Move the clock forward by ``dt`` seconds and return the new time."""
        if dt < 0:
            raise ValueError(f"clock cannot move backwards (dt={dt})")
        self._t += float(dt)
        return self._t

    def set(self, t: float) -> float:
        """Jump the clock to absolute time ``t`` (monotonic: no rewinds)."""
        if t < self._t:
            raise ValueError(f"clock cannot move backwards ({t} < {self._t})")
        self._t = float(t)
        return self._t
