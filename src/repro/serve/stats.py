"""Per-cell serving latency accounting.

Follows the paper's Figure-5 protocol: end-to-end request latency is split
into *table lookup* (packed gather + unpack + dequant) and *computation*
(interaction network / towers / decode). The engine measures the lookup slice
with a dedicated lookup-only executable per cell (same padded shape, same
table shardings), so the split survives recompiles and shape changes.
"""
from __future__ import annotations

import numpy as np


class LatencyStats:
    """Append-only per-cell latency records with percentile summaries."""

    def __init__(self):
        self._total_ms: dict[str, list] = {}
        self._lookup_ms: dict[str, list] = {}

    def record(self, cell: str, total_ms: float, lookup_ms: float | None = None):
        self._total_ms.setdefault(cell, []).append(float(total_ms))
        if lookup_ms is not None:
            self._lookup_ms.setdefault(cell, []).append(float(lookup_ms))

    def cells(self):
        return sorted(self._total_ms)

    def percentiles(self, cell: str, *, skip_warmup: int = 0) -> dict:
        """p50/p99/mean of total latency plus the lookup/compute split.

        ``skip_warmup`` drops the first N records (the compile-adjacent
        requests) before aggregating; falls back to all records when fewer
        than N+1 exist."""
        lat = np.asarray(self._total_ms[cell])
        if lat.shape[0] > skip_warmup:
            lat = lat[skip_warmup:]
        out = {
            "count": int(len(self._total_ms[cell])),
            "p50_ms": float(np.percentile(lat, 50)),
            "p99_ms": float(np.percentile(lat, 99)),
            "mean_ms": float(lat.mean()),
        }
        lk = self._lookup_ms.get(cell)
        if lk:
            lk = np.asarray(lk)
            if lk.shape[0] > skip_warmup:
                lk = lk[skip_warmup:]
            lookup_p50 = float(np.percentile(lk, 50))
            out["lookup_p50_ms"] = lookup_p50
            out["compute_p50_ms"] = max(out["p50_ms"] - lookup_p50, 0.0)
        return out

    def summary(self, *, skip_warmup: int = 0) -> dict:
        return {c: self.percentiles(c, skip_warmup=skip_warmup)
                for c in self.cells()}

    def format_table(self, *, skip_warmup: int = 0) -> str:
        lines = []
        for cell, s in self.summary(skip_warmup=skip_warmup).items():
            line = (f"{cell:<28} n={s['count']:<5} "
                    f"p50={s['p50_ms']:.2f}ms p99={s['p99_ms']:.2f}ms")
            if "lookup_p50_ms" in s:
                line += (f" lookup={s['lookup_p50_ms']:.2f}ms "
                         f"compute={s['compute_p50_ms']:.2f}ms")
            lines.append(line)
        return "\n".join(lines)
