"""Per-cell and per-request serving latency accounting.

Two views of the same traffic:

  - ``LatencyStats`` (per **cell** dispatch) follows the paper's Figure-5
    protocol: end-to-end dispatch latency split into *table lookup* (packed
    gather + unpack + dequant, timed via a lookup-only companion executable
    at the same padded shape) and *computation*. It also accumulates per-cell
    **occupancy** — valid rows over padded capacity — so the coalescing win
    of the scheduler is measurable per dispatch.
  - ``RequestStats`` (per **request**) extends the split upstream of the
    cell: *queue wait* (arrival → first dispatch), *batch assembly* (span
    gather + pad + host→device transfer) and *compute* (cell dispatch to
    ready), plus the end-to-end latency on the caller's clock.
"""
from __future__ import annotations

import numpy as np


def _pcts(values, *, skip_warmup: int = 0) -> dict:
    arr = np.asarray(values, np.float64)
    if arr.shape[0] > skip_warmup:
        arr = arr[skip_warmup:]
    return {"count": int(len(values)),
            "p50_ms": float(np.percentile(arr, 50)),
            "p99_ms": float(np.percentile(arr, 99)),
            "mean_ms": float(arr.mean())}


class LatencyStats:
    """Append-only per-cell latency records with percentile summaries."""

    def __init__(self):
        self._total_ms: dict[str, list] = {}
        self._lookup_ms: dict[str, list] = {}
        self._occupancy: dict[str, list] = {}   # [valid_rows, padded_rows]

    def record(self, cell: str, total_ms: float, lookup_ms: float | None = None,
               *, valid_rows: int | None = None,
               capacity_rows: int | None = None):
        self._total_ms.setdefault(cell, []).append(float(total_ms))
        if lookup_ms is not None:
            self._lookup_ms.setdefault(cell, []).append(float(lookup_ms))
        if valid_rows is not None and capacity_rows is not None:
            acc = self._occupancy.setdefault(cell, [0, 0])
            acc[0] += int(valid_rows)
            acc[1] += int(capacity_rows)

    def occupancy(self) -> dict:
        """Per-cell {valid_rows, padded_rows, occupancy} over every recorded
        dispatch — the fraction of compiled rows that carried real work."""
        return {cell: {"valid_rows": v, "padded_rows": p,
                       "occupancy": (v / p) if p else 0.0}
                for cell, (v, p) in sorted(self._occupancy.items())}

    def cells(self):
        return sorted(self._total_ms)

    def percentiles(self, cell: str, *, skip_warmup: int = 0) -> dict:
        """p50/p99/mean of total latency plus the lookup/compute split.

        ``skip_warmup`` drops the first N records (the compile-adjacent
        requests) before aggregating; falls back to all records when fewer
        than N+1 exist."""
        lat = np.asarray(self._total_ms[cell])
        if lat.shape[0] > skip_warmup:
            lat = lat[skip_warmup:]
        out = {
            "count": int(len(self._total_ms[cell])),
            "p50_ms": float(np.percentile(lat, 50)),
            "p99_ms": float(np.percentile(lat, 99)),
            "mean_ms": float(lat.mean()),
        }
        lk = self._lookup_ms.get(cell)
        if lk:
            lk = np.asarray(lk)
            if lk.shape[0] > skip_warmup:
                lk = lk[skip_warmup:]
            lookup_p50 = float(np.percentile(lk, 50))
            out["lookup_p50_ms"] = lookup_p50
            out["compute_p50_ms"] = max(out["p50_ms"] - lookup_p50, 0.0)
        occ = self._occupancy.get(cell)
        if occ is not None and occ[1]:
            out["occupancy"] = occ[0] / occ[1]
        return out

    def summary(self, *, skip_warmup: int = 0) -> dict:
        return {c: self.percentiles(c, skip_warmup=skip_warmup)
                for c in self.cells()}

    def format_table(self, *, skip_warmup: int = 0) -> str:
        lines = []
        for cell, s in self.summary(skip_warmup=skip_warmup).items():
            line = (f"{cell:<28} n={s['count']:<5} "
                    f"p50={s['p50_ms']:.2f}ms p99={s['p99_ms']:.2f}ms")
            if "lookup_p50_ms" in s:
                line += (f" lookup={s['lookup_p50_ms']:.2f}ms "
                         f"compute={s['compute_p50_ms']:.2f}ms")
            if "occupancy" in s:
                line += f" occ={s['occupancy']:.2f}"
            lines.append(line)
        return "\n".join(lines)


class RequestStats:
    """Per-request three-way latency breakdown, grouped by request kind.

    One record per completed request: *queue wait* (arrival → first chunk
    dispatch), *batch assembly* (span gather + pad + ``device_put``, summed
    over the request's chunks), *compute* (cell dispatch-to-ready, summed)
    and the end-to-end latency on the caller's clock. Shed requests are
    counted, not timed (they never reach a cell)."""

    def __init__(self):
        self._records: dict[str, dict[str, list]] = {}
        self.shed = 0

    def record(self, kind: str, *, queue_ms: float, assembly_ms: float,
               compute_ms: float, latency_ms: float):
        rec = self._records.setdefault(
            kind, {"queue_ms": [], "assembly_ms": [], "compute_ms": [],
                   "latency_ms": []})
        rec["queue_ms"].append(float(queue_ms))
        rec["assembly_ms"].append(float(assembly_ms))
        rec["compute_ms"].append(float(compute_ms))
        rec["latency_ms"].append(float(latency_ms))

    def record_shed(self, kind: str):
        del kind
        self.shed += 1

    def kinds(self):
        return sorted(self._records)

    def summary(self, *, skip_warmup: int = 0) -> dict:
        """{kind: {latency: pcts, queue_ms: pcts, assembly_ms: pcts,
        compute_ms: pcts}} — the three-way split + end-to-end."""
        out = {}
        for kind, rec in sorted(self._records.items()):
            out[kind] = {
                "count": len(rec["latency_ms"]),
                "latency": _pcts(rec["latency_ms"], skip_warmup=skip_warmup),
                "queue": _pcts(rec["queue_ms"], skip_warmup=skip_warmup),
                "assembly": _pcts(rec["assembly_ms"], skip_warmup=skip_warmup),
                "compute": _pcts(rec["compute_ms"], skip_warmup=skip_warmup),
            }
        return out

    def format_table(self, *, skip_warmup: int = 0) -> str:
        lines = []
        for kind, s in self.summary(skip_warmup=skip_warmup).items():
            lines.append(
                f"{kind:<12} n={s['count']:<5} "
                f"e2e p50={s['latency']['p50_ms']:.2f}ms "
                f"p99={s['latency']['p99_ms']:.2f}ms | "
                f"queue={s['queue']['p50_ms']:.2f}ms "
                f"assembly={s['assembly']['p50_ms']:.2f}ms "
                f"compute={s['compute']['p50_ms']:.2f}ms")
        if self.shed:
            lines.append(f"shed={self.shed}")
        return "\n".join(lines)
