"""Per-cell and per-request serving latency accounting.

Two views of the same traffic:

  - ``LatencyStats`` (per **cell** dispatch) follows the paper's Figure-5
    protocol: end-to-end dispatch latency split into *table lookup* (packed
    gather + unpack + dequant, timed via a lookup-only companion executable
    at the same padded shape) and *computation*. It also accumulates per-cell
    **occupancy** — valid rows over padded capacity — so the coalescing win
    of the scheduler is measurable per dispatch.
  - ``RequestStats`` (per **request**) extends the split upstream of the
    cell: *queue wait* (arrival → first dispatch), *batch assembly* (span
    gather + pad + host→device transfer) and *compute* (cell dispatch to
    ready), plus the end-to-end latency on the caller's clock.
"""
from __future__ import annotations

import numpy as np


def _pcts(values, *, skip_warmup: int = 0) -> dict:
    arr = np.asarray(values, np.float64)
    if arr.shape[0] > skip_warmup:
        arr = arr[skip_warmup:]
    return {"count": int(len(values)),
            "p50_ms": float(np.percentile(arr, 50)),
            "p99_ms": float(np.percentile(arr, 99)),
            "mean_ms": float(arr.mean())}


class LatencyStats:
    """Append-only per-cell latency records with percentile summaries."""

    def __init__(self):
        self._total_ms: dict[str, list] = {}
        self._lookup_ms: dict[str, list] = {}
        self._occupancy: dict[str, list] = {}   # [valid_rows, padded_rows]

    def record(self, cell: str, total_ms: float, lookup_ms: float | None = None,
               *, valid_rows: int | None = None,
               capacity_rows: int | None = None):
        self._total_ms.setdefault(cell, []).append(float(total_ms))
        if lookup_ms is not None:
            self._lookup_ms.setdefault(cell, []).append(float(lookup_ms))
        if valid_rows is not None and capacity_rows is not None:
            acc = self._occupancy.setdefault(cell, [0, 0])
            acc[0] += int(valid_rows)
            acc[1] += int(capacity_rows)

    def occupancy(self) -> dict:
        """Per-cell {valid_rows, padded_rows, occupancy} over every recorded
        dispatch — the fraction of compiled rows that carried real work."""
        return {cell: {"valid_rows": v, "padded_rows": p,
                       "occupancy": (v / p) if p else 0.0}
                for cell, (v, p) in sorted(self._occupancy.items())}

    def cells(self):
        return sorted(self._total_ms)

    def percentiles(self, cell: str, *, skip_warmup: int = 0) -> dict:
        """p50/p99/mean of total latency plus the lookup/compute split.

        ``skip_warmup`` drops the first N records (the compile-adjacent
        requests) before aggregating; falls back to all records when fewer
        than N+1 exist."""
        lat = np.asarray(self._total_ms[cell])
        if lat.shape[0] > skip_warmup:
            lat = lat[skip_warmup:]
        out = {
            "count": int(len(self._total_ms[cell])),
            "p50_ms": float(np.percentile(lat, 50)),
            "p99_ms": float(np.percentile(lat, 99)),
            "mean_ms": float(lat.mean()),
        }
        lk = self._lookup_ms.get(cell)
        if lk:
            lk = np.asarray(lk)
            if lk.shape[0] > skip_warmup:
                lk = lk[skip_warmup:]
            lookup_p50 = float(np.percentile(lk, 50))
            out["lookup_p50_ms"] = lookup_p50
            out["compute_p50_ms"] = max(out["p50_ms"] - lookup_p50, 0.0)
        occ = self._occupancy.get(cell)
        if occ is not None and occ[1]:
            out["occupancy"] = occ[0] / occ[1]
        return out

    def summary(self, *, skip_warmup: int = 0) -> dict:
        return {c: self.percentiles(c, skip_warmup=skip_warmup)
                for c in self.cells()}

    def format_table(self, *, skip_warmup: int = 0) -> str:
        lines = []
        for cell, s in self.summary(skip_warmup=skip_warmup).items():
            line = (f"{cell:<28} n={s['count']:<5} "
                    f"p50={s['p50_ms']:.2f}ms p99={s['p99_ms']:.2f}ms")
            if "lookup_p50_ms" in s:
                line += (f" lookup={s['lookup_p50_ms']:.2f}ms "
                         f"compute={s['compute_p50_ms']:.2f}ms")
            if "occupancy" in s:
                line += f" occ={s['occupancy']:.2f}"
            lines.append(line)
        return "\n".join(lines)


class RequestStats:
    """Per-request three-way latency breakdown, grouped by request kind —
    and, since the multi-tenant scheduler, splittable by *lane*
    (``kind:p<priority>``) and by *tenant*.

    One record per completed request: *queue wait* (arrival → first chunk
    dispatch), *batch assembly* (span gather + pad + ``device_put``, summed
    over the request's chunks), *compute* (cell dispatch-to-ready, summed)
    and the end-to-end latency on the caller's clock. Shed and failed
    requests are counted (split by kind/tenant), not timed (they never
    deliver a result)."""

    def __init__(self):
        # key: (kind, tenant, priority) -> field lists
        self._records: dict[tuple, dict[str, list]] = {}
        self.shed = 0
        self.failed = 0
        self._shed_by: dict[tuple, int] = {}     # (kind, tenant) -> n
        self._failed_by: dict[tuple, int] = {}

    def record(self, kind: str, *, queue_ms: float, assembly_ms: float,
               compute_ms: float, latency_ms: float,
               tenant: str = "default", priority: int = 0):
        rec = self._records.setdefault(
            (kind, tenant, int(priority)),
            {"queue_ms": [], "assembly_ms": [], "compute_ms": [],
             "latency_ms": []})
        rec["queue_ms"].append(float(queue_ms))
        rec["assembly_ms"].append(float(assembly_ms))
        rec["compute_ms"].append(float(compute_ms))
        rec["latency_ms"].append(float(latency_ms))

    def record_shed(self, kind: str, tenant: str = "default"):
        self.shed += 1
        key = (kind, tenant)
        self._shed_by[key] = self._shed_by.get(key, 0) + 1

    def record_failed(self, kind: str, tenant: str = "default"):
        self.failed += 1
        key = (kind, tenant)
        self._failed_by[key] = self._failed_by.get(key, 0) + 1

    def kinds(self):
        return sorted({kind for kind, _, _ in self._records})

    def lane_counts(self) -> dict[str, int]:
        """Completed requests per lane (``kind:p<priority>``) — the goodput
        view ``engine.counters()`` surfaces."""
        out: dict[str, int] = {}
        for (kind, _, priority), rec in self._records.items():
            lane = f"{kind}:p{priority}"
            out[lane] = out.get(lane, 0) + len(rec["latency_ms"])
        return dict(sorted(out.items()))

    def tenant_counts(self) -> dict[str, int]:
        """Completed requests per tenant."""
        out: dict[str, int] = {}
        for (_, tenant, _), rec in self._records.items():
            out[tenant] = out.get(tenant, 0) + len(rec["latency_ms"])
        return dict(sorted(out.items()))

    @staticmethod
    def _merge(recs: list[dict]) -> dict[str, list]:
        out = {"queue_ms": [], "assembly_ms": [], "compute_ms": [],
               "latency_ms": []}
        for rec in recs:
            for field, values in rec.items():
                out[field].extend(values)
        return out

    def _group(self, label_fn) -> dict[str, dict[str, list]]:
        groups: dict[str, list] = {}
        for key, rec in self._records.items():
            groups.setdefault(label_fn(*key), []).append(rec)
        return {label: self._merge(recs)
                for label, recs in sorted(groups.items())}

    def _summarize(self, grouped: dict, shed_key_fn, *,
                   skip_warmup: int = 0) -> dict:
        out = {}
        for label, rec in grouped.items():
            out[label] = {
                "count": len(rec["latency_ms"]),
                "latency": _pcts(rec["latency_ms"], skip_warmup=skip_warmup),
                "queue": _pcts(rec["queue_ms"], skip_warmup=skip_warmup),
                "assembly": _pcts(rec["assembly_ms"], skip_warmup=skip_warmup),
                "compute": _pcts(rec["compute_ms"], skip_warmup=skip_warmup),
            }
            shed, failed = shed_key_fn(label)
            if shed:
                out[label]["shed"] = shed
            if failed:
                out[label]["failed"] = failed
        return out

    def summary(self, *, skip_warmup: int = 0) -> dict:
        """{kind: {latency: pcts, queue_ms: pcts, assembly_ms: pcts,
        compute_ms: pcts}} — the three-way split + end-to-end."""
        def by_kind(label):
            return (sum(n for (k, _), n in self._shed_by.items()
                        if k == label),
                    sum(n for (k, _), n in self._failed_by.items()
                        if k == label))
        return self._summarize(self._group(lambda k, t, p: k), by_kind,
                               skip_warmup=skip_warmup)

    def lane_summary(self, *, skip_warmup: int = 0) -> dict:
        """The same breakdown keyed by lane — ``kind:p<priority>`` — so a
        high-priority lane's p99 is separable from the background lane's."""
        return self._summarize(
            self._group(lambda k, t, p: f"{k}:p{p}"),
            lambda label: (0, 0), skip_warmup=skip_warmup)

    def tenant_summary(self, *, skip_warmup: int = 0) -> dict:
        """The same breakdown keyed by tenant, with per-tenant shed/failed
        counts merged in — the per-tenant goodput/SLO view."""
        def by_tenant(label):
            return (sum(n for (_, t), n in self._shed_by.items()
                        if t == label),
                    sum(n for (_, t), n in self._failed_by.items()
                        if t == label))
        return self._summarize(self._group(lambda k, t, p: t), by_tenant,
                               skip_warmup=skip_warmup)

    def format_table(self, *, skip_warmup: int = 0, by: str = "kind") -> str:
        summaries = {"kind": self.summary, "lane": self.lane_summary,
                     "tenant": self.tenant_summary}[by]
        lines = []
        for label, s in summaries(skip_warmup=skip_warmup).items():
            lines.append(
                f"{label:<12} n={s['count']:<5} "
                f"e2e p50={s['latency']['p50_ms']:.2f}ms "
                f"p99={s['latency']['p99_ms']:.2f}ms | "
                f"queue={s['queue']['p50_ms']:.2f}ms "
                f"assembly={s['assembly']['p50_ms']:.2f}ms "
                f"compute={s['compute']['p50_ms']:.2f}ms")
        if self.shed:
            lines.append(f"shed={self.shed}")
        if self.failed:
            lines.append(f"failed={self.failed}")
        return "\n".join(lines)
