"""Packed-table serving subsystem (paper §4 deployment path).

Three layers, composable bottom-up:

  ``cache``    — CellCache: compile-once memoization of serving executables
                 keyed by (arch, shape, mesh signature), with explicit in/out
                 shardings from ``repro.dist``.
  ``batcher``  — RequestBatcher: buckets arbitrary request sizes onto the
                 registered cell shapes (pad-to-shape + validity mask) and
                 unpads results.
  ``engine``   — Engine: ``score`` / ``retrieve`` / ``decode`` front-end with
                 per-cell latency percentiles in the Figure-5
                 lookup-vs-compute split.

``repro.serve.cells`` holds the serve-cell builders, shared with the dry-run
harness in ``repro.launch.cells``. Tiered (hot/cold) serving builds on
``repro.cache``: ``Engine.register_tiered_model`` + ``Engine.score_tiered``
gather hot rows device-locally and overlap cold-row fills with compute.
"""
from repro.serve.batcher import Chunk, RequestBatcher
from repro.serve.cache import CellCache, CellKey, CompiledCell, mesh_signature
from repro.serve.cells import (ServeCellDef, lm_decode_cell, packed_lookup_cell,
                               packed_score_cell, packed_score_step,
                               tiered_score_cell, two_tower_retrieval_cell)
from repro.serve.engine import Engine
from repro.serve.stats import LatencyStats

__all__ = [
    "CellCache", "CellKey", "CompiledCell", "mesh_signature",
    "Chunk", "RequestBatcher", "LatencyStats",
    "ServeCellDef", "packed_score_cell", "packed_score_step",
    "packed_lookup_cell", "tiered_score_cell", "two_tower_retrieval_cell",
    "lm_decode_cell", "Engine",
]
