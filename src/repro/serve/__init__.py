"""Packed-table serving subsystem (paper §4 deployment path).

The request-lifecycle stack, composable bottom-up:

  ``cache``     — CellCache: compile-once memoization of serving executables
                  keyed by (arch, shape, mesh signature), with explicit
                  in/out shardings from ``repro.dist``.
  ``batcher``   — RequestBatcher: buckets arbitrary request sizes onto the
                  registered cell shapes (pad-to-shape + validity mask);
                  ``pack`` coalesces many requests into shared chunks whose
                  ``Span``s scatter outputs back per requester.
  ``queue``     — AdmissionQueue: the bounded multi-lane arrival edge —
                  priority lanes with EDF dispatch order, per-tenant quotas
                  (``TenantQuota``), load-adaptive + deadline shedding, and
                  per-kind/per-tenant counters.
  ``scheduler`` — Scheduler: drains the queue into coalesced cell dispatches
                  (with an optional max-wait coalescing window) and isolates
                  dispatch faults to the requests riding the failed chunk;
                  ``DecodeSession`` runs continuous-batching LM decode over a
                  slot-pooled persistent KV cache.
  ``clock``     — ManualClock / TickClock: injectable time sources
                  (``Engine(clock=...)``) for wall-clock-independent
                  lifecycle tests and fully deterministic open-loop replay
                  (the CI bench gate's contract).
  ``engine``    — Engine: ``submit``/``poll``/``drain`` lifecycle with
                  ``score`` / ``retrieve`` / ``decode`` preserved as thin
                  synchronous wrappers; per-cell latency percentiles in the
                  Figure-5 lookup-vs-compute split + per-request queue-wait /
                  assembly / compute breakdown.

``repro.serve.cells`` holds the serve-cell builders, shared with the dry-run
harness in ``repro.launch.cells``. Tiered (hot/cold) serving builds on
``repro.cache``: ``Engine.register_tiered_model`` + ``Engine.score_tiered``
gather hot rows device-locally and overlap cold-row fills with compute.

``repro.serve.repack`` adds serving-time precision adaptation on top:
``RepackPlanner`` turns a bytes budget or tier-pressure signal into a new
per-group precision assignment, ``TableSwapper`` re-packs it into the live
subtable layout and swaps it through ``Engine.request_swap`` — zero
recompiles, applied atomically between ``sched_step`` rounds.
``PressureAdapter`` closes the loop: windowed live hit/miss deltas drive
``plan_pressure``/``plan_promote`` on the engine's policy cadence
(``Engine.attach_adapter``), alongside the traffic-adaptive tier policy
(``Engine.attach_tier_policy`` + ``repro.cache.policy``) and the
training-update path ``Engine.writeback_embeddings``.
"""
from repro.serve.batcher import Chunk, RequestBatcher, Span
from repro.serve.cache import CellCache, CellKey, CompiledCell, mesh_signature
from repro.serve.cells import (ServeCellDef, baseline_score_cell,
                               lm_decode_cell, lm_decode_slotted_cell,
                               packed_lookup_cell, packed_score_cell,
                               packed_score_step, tiered_score_cell,
                               two_tower_retrieval_cell)
from repro.serve.clock import ManualClock, TickClock
from repro.serve.engine import Engine
from repro.serve.queue import (AdmissionQueue, Request, RequestFailedError,
                               TenantQuota)
from repro.serve.repack import (PressureAdapter, RepackPlan, RepackPlanner,
                                TableSwapper, headroom_capacities,
                                subtable_capacities)
from repro.serve.scheduler import DecodeSession, Scheduler
from repro.serve.stats import LatencyStats, RequestStats

__all__ = [
    "CellCache", "CellKey", "CompiledCell", "mesh_signature",
    "Chunk", "Span", "RequestBatcher", "LatencyStats", "RequestStats",
    "AdmissionQueue", "Request", "TenantQuota", "RequestFailedError",
    "ManualClock", "TickClock", "Scheduler", "DecodeSession",
    "ServeCellDef", "baseline_score_cell", "packed_score_cell",
    "packed_score_step",
    "packed_lookup_cell", "tiered_score_cell", "two_tower_retrieval_cell",
    "lm_decode_cell", "lm_decode_slotted_cell", "Engine",
    "RepackPlan", "RepackPlanner", "TableSwapper", "PressureAdapter",
    "headroom_capacities", "subtable_capacities",
]
