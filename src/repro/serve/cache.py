"""Compile-once cell cache for serving executables.

A serving process handles many requests against few (arch, shape) pairs; the
cache makes the compile cost a registration-time event. Keys are
``(arch, shape, mesh signature)`` — the same cell on a different mesh is a
different executable — and values are ahead-of-time compiled ``jax.jit``
executables with explicit in/out ``NamedSharding``s from ``repro.dist``, so a
repeat request hits a warm executable instead of re-tracing.

Compile/hit counters are first-class: the zero-recompile property of the
serving path is asserted in ``tests/test_serve.py`` against ``compiles``.
"""
from __future__ import annotations

import time
from typing import Any, Callable, NamedTuple

import jax

from repro.dist.mesh import use_mesh
from repro.dist.sharding import tree_named_shardings


def mesh_signature(mesh) -> str:
    """Stable identity of a mesh: shape, axis names, device platform."""
    shape = "x".join(str(s) for s in mesh.devices.shape)
    axes = ",".join(mesh.axis_names)
    platform = mesh.devices.flat[0].platform
    return f"{shape}:{axes}:{platform}"


class CellKey(NamedTuple):
    """Identity of one compiled serving executable: the same (arch, shape)
    on a different mesh — or with different static config baked into the
    shape string's fingerprint — is a different executable."""
    arch: str        # model/architecture identity, e.g. "dlrm"
    shape: str       # shape name + capacity + static-config digest,
                     # e.g. "serve_p99@512#3f9ab2c41d07" (see
                     # ServeCellDef.fingerprint — config baked into the step
                     # closure must key its own executable)
    mesh_sig: str


class CompiledCell(NamedTuple):
    """A warm AOT-compiled serving executable plus the explicit in/out
    ``NamedSharding``s it was compiled with (callers ``device_put`` request
    inputs to ``in_shardings`` before dispatch) and its compile cost."""
    key: CellKey
    compiled: Any          # jax.stages.Compiled — call as compiled(*args)
    in_shardings: tuple    # NamedSharding pytrees, one per positional arg
    out_shardings: Any
    compile_s: float
    meta: dict


class CellCache:
    """Compile-once memo of serving executables, keyed by ``CellKey``.

    ``get_or_compile`` AOT-compiles on first use and returns the warm
    ``CompiledCell`` afterwards; ``compiles``/``hits`` counters back the
    zero-recompile assertion of the serving path."""

    def __init__(self, mesh):
        self.mesh = mesh
        self._cells: dict[CellKey, CompiledCell] = {}
        self.compiles = 0
        self.hits = 0

    def key(self, arch: str, shape: str) -> CellKey:
        return CellKey(arch, shape, mesh_signature(self.mesh))

    def __contains__(self, key: CellKey) -> bool:
        return key in self._cells

    def __len__(self) -> int:
        return len(self._cells)

    def lookup(self, key: CellKey) -> CompiledCell | None:
        return self._cells.get(key)

    def get_or_compile(self, key: CellKey, build_fn: Callable) -> CompiledCell:
        """Return the cached executable for ``key``, compiling on first use.

        ``build_fn() -> (step_fn, input_specs, in_pspecs, out_pspecs, meta)``
        is only invoked on a miss. ``input_specs`` may mix concrete arrays
        (bound params — their avals are used) and ShapeDtypeStructs (request
        stand-ins); ``in_pspecs``/``out_pspecs`` are PartitionSpec pytrees
        resolved against the cache's mesh.
        """
        if key in self._cells:
            self.hits += 1
            return self._cells[key]

        step_fn, input_specs, in_pspecs, out_pspecs, meta = build_fn()
        in_shardings = tuple(tree_named_shardings(self.mesh, ps)
                             for ps in in_pspecs)
        out_shardings = tree_named_shardings(self.mesh, out_pspecs)
        t0 = time.perf_counter()
        with use_mesh(self.mesh):
            jitted = jax.jit(step_fn, in_shardings=in_shardings,
                             out_shardings=out_shardings)
            compiled = jitted.lower(*input_specs).compile()
        cell = CompiledCell(key=key, compiled=compiled,
                            in_shardings=in_shardings,
                            out_shardings=out_shardings,
                            compile_s=time.perf_counter() - t0,
                            meta=dict(meta))
        self._cells[key] = cell
        self.compiles += 1
        return cell

    def counters(self) -> dict:
        return {"compiles": self.compiles, "hits": self.hits,
                "cells": len(self._cells)}
