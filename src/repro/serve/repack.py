"""Serving-time precision adaptation: plan → re-pack → swap, zero recompiles.

The paper fixes each feature group's bit-width when the table is packed
(§3.3/§4); production memory pressure and popularity shifts argue for
re-assigning precision *while serving*. The substrate makes that a pure data
swap: widths are part of the cell registry (every per-width subtable is a
separate leaf of the bound packed table), so as long as a new assignment is
packed into the **same subtable shapes**, the compiled executable is
untouched — the engine re-``device_put``s the new leaves through the very
``in_shardings`` the cell was compiled with (the subtables re-shard under the
same ``packed_table_pspecs``) and no recompile can occur.

Three pieces:

  - ``RepackPlanner`` — policy. Given the current per-group assignment and
    either a bytes budget (``plan_budget``) or the tier hit/miss counters of
    a ``repro.cache.TieredTableStore`` (``plan_pressure``), emit a new
    per-group width assignment that respects the per-width row *capacities*
    of the live table (the padded subtable row counts the executables were
    compiled against).
  - ``TableSwapper`` — mechanism. Holds the full-precision master embedding
    (+ the trained α/β) and re-packs any assignment into the pinned
    capacities via ``core.inference.build_packed_table(row_capacities=...)``,
    then queues the swap on the engine.
  - ``Engine.request_swap`` / ``Engine._apply_swaps`` (engine wiring) — the
    atomic swap point: queued swaps apply only **between** ``sched_step``s,
    and each dispatch reads an immutable ``bound`` tuple snapshot, so an
    in-flight coalesced batch can never observe a torn table.

Invariants (asserted in ``tests/test_repack.py``):

  - a repack to a *new* assignment completes with zero ``CellCache``
    recompiles (``engine.compile_count`` is flat across the swap);
  - a repack to the *identical* assignment is bit-exact (same bytes in, same
    executable, same bytes out);
  - under a multi-device mesh the swapped subtables re-shard through the
    compiled ``in_shardings`` and scores match the single-device reference.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.core.inference import _pad_rows, build_packed_table
from repro.core.packing import row_bytes


def subtable_capacities(table) -> dict:
    """Per-width padded row counts of a packed table: ``{"b<width>": rows}``.

    These are the shapes the serving executables were compiled against — the
    hard constraint every repack plan must fit inside."""
    return {k: int(v.shape[0]) for k, v in table["subtables"].items()}


def headroom_capacities(meta, *, fraction: float = 0.5,
                        multiple: int = 8) -> dict:
    """Capacity template reserving repack headroom: each non-zero width
    bucket is sized to hold ``ceil(fraction * n)`` features (rounded up to
    ``multiple`` rows, so row shards stay aligned to whole packed rows).

    Build the serving table with
    ``build_packed_table(..., row_capacities=headroom_capacities(meta))`` and
    any later assignment that puts at most that fraction of the features into
    one bucket swaps in without recompiling. The cost is padding bytes at
    rest — the production trade for a fixed executable fleet."""
    n = int(meta["n"])
    rows = _pad_rows(int(np.ceil(fraction * n)), multiple)
    return {f"b{b}": rows for b in meta["bits"] if b != 0}


class RepackPlan(NamedTuple):
    """One planner decision: the new per-group/per-feature assignment plus
    the byte math that justified it (``tests/test_repack.py`` asserts
    ``bytes_packed`` ≤ the requested budget and capacity feasibility)."""
    group_bits_idx: np.ndarray    # (G,) int32 — new per-group width index
    feature_bits_idx: np.ndarray  # (n,) int32 — expanded per feature
    bytes_packed: int             # projected pad-free packed payload bytes
    bytes_before: int             # payload bytes under the input assignment
    n_features_moved: int         # features whose width changed


class RepackPlanner:
    """Capacity-constrained precision (re-)assignment policy.

    ``meta`` is the packed table's static metadata (``bits``/``d``/``n``),
    ``group_of_feature`` the (n,) feature→group map the pipeline trained with
    (``core.mpe.make_groups``), ``capacities`` the per-width row capacities
    of the live table (``subtable_capacities``), and ``frequencies`` an
    optional per-feature access-count vector — groups are demoted coldest
    first (summed frequency), promoted hottest first; without it, group index
    order is used (``make_groups`` orders groups hottest-first already).

    The planner is *policy only*: it never touches device state. Feasibility
    means every width bucket's feature count stays within its capacity
    (width 0 stores nothing and is always feasible), so whatever the planner
    emits, ``TableSwapper.repack`` can pack without changing a shape.
    """

    def __init__(self, meta, group_of_feature, capacities: dict, *,
                 frequencies=None):
        self.bits = tuple(meta["bits"])
        self.d = int(meta["d"])
        self.n = int(meta["n"])
        self.gof = np.asarray(group_of_feature, np.int32)
        self.n_groups = int(self.gof.max()) + 1 if self.gof.size else 0
        self.capacities = {k: int(v) for k, v in capacities.items()}
        self.group_size = np.bincount(self.gof, minlength=self.n_groups)
        if frequencies is not None:
            freqs = np.asarray(frequencies, np.float64)
            gfreq = np.zeros((self.n_groups,), np.float64)
            np.add.at(gfreq, self.gof, freqs)
            self.group_priority = gfreq
        else:
            # make_groups assigns hottest features to the lowest group ids
            self.group_priority = -np.arange(self.n_groups, dtype=np.float64)

    # -- byte/capacity math -------------------------------------------------

    def _row_bytes(self) -> np.ndarray:
        return np.array([row_bytes(self.d, b) if b else 0 for b in self.bits],
                        np.int64)

    def bytes_packed(self, group_bits_idx) -> int:
        """Pad-free packed payload bytes under an assignment."""
        fb = np.asarray(group_bits_idx, np.int32)[self.gof]
        return int(self._row_bytes()[fb].sum())

    def bucket_counts(self, group_bits_idx) -> np.ndarray:
        """(m,) feature count per width bucket under an assignment."""
        fb = np.asarray(group_bits_idx, np.int32)[self.gof]
        return np.bincount(fb, minlength=len(self.bits))

    def capacity_ok(self, group_bits_idx) -> bool:
        """True when every non-zero bucket fits its pinned row capacity."""
        counts = self.bucket_counts(group_bits_idx)
        return all(counts[i] <= self.capacities.get(f"b{b}", 0)
                   for i, b in enumerate(self.bits) if b != 0)

    def _fits(self, counts, i: int, size: int) -> bool:
        b = self.bits[i]
        if b == 0:
            return True
        return counts[i] + size <= self.capacities.get(f"b{b}", 0)

    # -- planning -----------------------------------------------------------

    def plan_budget(self, group_bits_idx, bytes_budget: int) -> RepackPlan:
        """Demote groups (coldest first, one width notch at a time, each to
        the widest narrower bucket with free capacity) until the packed
        payload fits ``bytes_budget``. Deterministic greedy; a budget below
        the all-zero-width floor simply bottoms out at width 0."""
        assign = np.asarray(group_bits_idx, np.int32).copy()
        before = self.bytes_packed(assign)
        rb = self._row_bytes()
        counts = self.bucket_counts(assign)
        total = before
        order = np.argsort(self.group_priority, kind="stable")  # coldest first
        changed = True
        while total > bytes_budget and changed:
            changed = False
            for g in order:
                if total <= bytes_budget:
                    break
                i = int(assign[g])
                if i == 0:
                    continue
                size = int(self.group_size[g])
                j = next((j for j in range(i - 1, -1, -1)
                          if self._fits(counts, j, size)), None)
                if j is None:
                    continue
                assign[g] = j
                counts[i] -= size
                counts[j] += size
                total -= size * int(rb[i] - rb[j])
                changed = True
        return self._finish(group_bits_idx, assign)

    def plan_pressure(self, group_bits_idx, counters: dict, *,
                      max_shrink: float = 0.5) -> RepackPlan:
        """Turn a ``TieredTableStore.counters()`` record into a byte budget:
        the cold-lookup share of traffic scales a shrink factor (up to
        ``max_shrink``), so a store thrashing its cold tier narrows the tail
        until the bytes a miss moves get proportionally cheaper. A 100% hit
        rate plans the identity assignment."""
        total = counters.get("hot_lookups", 0) + counters.get("cold_lookups", 0)
        miss = counters.get("cold_lookups", 0) / total if total else 0.0
        before = self.bytes_packed(group_bits_idx)
        budget = int(before * (1.0 - max_shrink * miss))
        return self.plan_budget(group_bits_idx, budget)

    def plan_promote(self, group_bits_idx, *, bytes_budget: int) -> RepackPlan:
        """Spend spare budget the other way: promote the hottest groups one
        notch at a time (to the narrowest wider bucket with capacity) while
        the payload stays within ``bytes_budget``."""
        assign = np.asarray(group_bits_idx, np.int32).copy()
        rb = self._row_bytes()
        counts = self.bucket_counts(assign)
        total = self.bytes_packed(assign)
        m = len(self.bits)
        order = np.argsort(-self.group_priority, kind="stable")  # hottest first
        changed = True
        while changed:
            changed = False
            for g in order:
                i = int(assign[g])
                if i >= m - 1:
                    continue
                size = int(self.group_size[g])
                j = next((j for j in range(i + 1, m)
                          if self._fits(counts, j, size)), None)
                if j is None:
                    continue
                delta = size * int(rb[j] - rb[i])
                if total + delta > bytes_budget:
                    continue
                assign[g] = j
                counts[i] -= size
                counts[j] += size
                total += delta
                changed = True
        return self._finish(group_bits_idx, assign)

    def _finish(self, old_assign, assign: np.ndarray) -> RepackPlan:
        old_fb = np.asarray(old_assign, np.int32)[self.gof]
        fb = assign[self.gof]
        return RepackPlan(
            group_bits_idx=assign,
            feature_bits_idx=fb.astype(np.int32),
            bytes_packed=self.bytes_packed(assign),
            bytes_before=int(self._row_bytes()[old_fb].sum()),
            n_features_moved=int((fb != old_fb).sum()),
        )


class TableSwapper:
    """Re-packs the master embedding under a planner assignment and queues
    the atomic swap on a live engine.

    ``emb``/``alpha``/``beta`` are the retrained full-precision artifacts the
    original table was packed from (``run_mpe_pipeline``'s
    ``final_params["embedding"]``) — the master copy a production parameter
    server would hold; ``cfg`` the same ``MPEConfig``; ``capacities`` the
    pinned per-width row counts (defaults to the engine's live table shapes
    at first ``repack``). Swaps re-quantize from the master, so repacking to
    the identical assignment reproduces the original table bit for bit."""

    def __init__(self, engine, emb, alpha, beta, cfg, *,
                 capacities: dict | None = None, arch: str | None = None):
        self.engine = engine
        self.emb = np.asarray(emb)
        self.alpha = np.asarray(alpha)
        self.beta = np.asarray(beta)
        self.cfg = cfg
        self.arch = arch
        self.capacities = (dict(capacities) if capacities is not None
                           else None)
        self.n_swaps = 0

    def _resolve_capacities(self) -> dict:
        if self.capacities is None:
            table = self.engine.live_packed_table(arch=self.arch)
            self.capacities = subtable_capacities(table)
        return self.capacities

    def build(self, feature_bits_idx):
        """Pack ``feature_bits_idx`` into the pinned capacities →
        ``(table, meta)``, without touching the engine. Raises when the
        assignment doesn't fit (the planner should never emit one)."""
        return build_packed_table(self.emb, np.asarray(feature_bits_idx),
                                  self.alpha, self.beta, self.cfg,
                                  row_capacities=self._resolve_capacities())

    def repack(self, plan) -> dict:
        """Re-pack ``plan`` (a ``RepackPlan`` or a bare per-feature width
        index array) and queue the swap; it lands atomically at the engine's
        next ``sched_step`` boundary. Returns a summary dict
        (``bytes_packed``, ``n_features_moved``, ``swaps``)."""
        fb = (plan.feature_bits_idx if isinstance(plan, RepackPlan)
              else np.asarray(plan, np.int32))
        table, meta = self.build(fb)
        self.engine.request_swap(table, meta, arch=self.arch)
        self.n_swaps += 1
        summary = {"swaps": self.n_swaps,
                   "n_features": int(fb.size),
                   "compiles": self.engine.compile_count}
        if isinstance(plan, RepackPlan):
            summary.update(bytes_packed=plan.bytes_packed,
                           bytes_before=plan.bytes_before,
                           n_features_moved=plan.n_features_moved)
        return summary


class PressureAdapter:
    """Drive ``RepackPlanner.plan_pressure`` from *live* serving counters —
    the control loop the one-shot repack path left open: precision now
    follows traffic drift automatically.

    Attach with ``Engine.attach_adapter``; ``step(engine)`` runs once per
    ``sched_step`` (after the tier policy's moves). Every ``every`` rounds
    the adapter takes a **windowed** hit/miss delta across the engine's
    tiered stores — windowing, not cumulative counters, so old traffic
    can't mask fresh drift — and plans against it:

      - miss share above ``promote_below`` → ``plan_pressure`` narrows the
        tail (cold thrash makes each miss's bytes cheaper);
      - miss share at/below ``promote_below`` → ``plan_promote`` spends the
        recovered headroom widening the hottest groups back toward the
        baseline byte payload.

    A plan moving fewer than ``min_moved`` features is dropped (repacks are
    not free: the swap re-quantizes from the master embedding). Queued swaps
    land at the *next* round's atomic swap point, zero recompiles — the
    capacities were pinned when the serving table was built."""

    def __init__(self, planner: RepackPlanner, swapper: TableSwapper,
                 group_bits_idx, *, every: int = 32, max_shrink: float = 0.5,
                 promote_below: float = 0.02, min_moved: int = 1):
        self.planner = planner
        self.swapper = swapper
        self.assignment = np.asarray(group_bits_idx, np.int32).copy()
        self.base_bytes = planner.bytes_packed(self.assignment)
        self.every = int(every)
        self.max_shrink = float(max_shrink)
        self.promote_below = float(promote_below)
        self.min_moved = int(min_moved)
        self._rounds = 0
        self._seen = (0, 0)     # cumulative (hot, cold) at last window edge
        self.repacks = 0

    def step(self, engine) -> dict | None:
        """One cadence tick; returns the repack summary when a swap was
        queued this round, else None."""
        self._rounds += 1
        if self._rounds % self.every:
            return None
        hot = cold = 0
        for store in engine._tier_stores():
            c = store.counters()
            hot += c["hot_lookups"]
            cold += c["cold_lookups"]
        window = {"hot_lookups": hot - self._seen[0],
                  "cold_lookups": cold - self._seen[1]}
        self._seen = (hot, cold)
        total = window["hot_lookups"] + window["cold_lookups"]
        if total == 0:
            return None
        miss = window["cold_lookups"] / total
        if miss <= self.promote_below:
            plan = self.planner.plan_promote(self.assignment,
                                             bytes_budget=self.base_bytes)
        else:
            plan = self.planner.plan_pressure(self.assignment, window,
                                              max_shrink=self.max_shrink)
        if plan.n_features_moved < self.min_moved:
            return None
        summary = self.swapper.repack(plan)
        self.assignment = plan.group_bits_idx
        self.repacks += 1
        return summary
