"""Coalescing scheduler + continuous-batching decode.

The dispatch edge of the request lifecycle: the scheduler drains the
``AdmissionQueue`` and turns *many* callers' requests into *few* cell-shaped
dispatches on the compiled-cell substrate (``CellCache`` executables — never
recompiled, never reshaped):

  - **score / tiered lanes** — pending requests come out of the queue in
    priority/EDF order (the queue owns lane ordering and per-tenant quotas)
    and are coalesced by ``RequestBatcher.pack`` into the registered cell
    shapes: one padded cell invocation carries row spans from many requests,
    and the outputs scatter back per requester (``Chunk.spans``). Concurrent
    small requests stop burning whole cells on padding — occupancy, not
    recompiles, absorbs the traffic mix.
  - **max-wait coalescing window** — with ``coalesce_window_ms > 0`` a lane
    *holds* a light load (fewer pending rows than the smallest registered
    bucket) for up to the window, trading p99 for occupancy; the window
    expires against the same clock that stamps arrivals, so held requests
    dispatch at a deterministic time on a virtual timeline. ``0`` (the
    default) dispatches immediately — exactly the pre-window behaviour.
  - **decode lane** — a ``DecodeSession`` per registered
    ``lm_decode_slotted_cell`` runs *continuous batching*: the compiled batch
    dim is a pool of KV-cache slots with a free-list; a request joins by
    taking a free slot at length 0 and replaying its prompt token-by-token
    through the running batch (other slots keep decoding their own
    sequences), and a finished sequence's slot is recycled for the next
    waiting request without recompiling or restarting the batch.
  - **fault isolation** — a dispatch that raises fails only the requests
    riding that chunk (status ``FAILED``; ``poll`` re-raises with the
    original error) and, on the decode lane, recycles the failed jobs' KV
    slots; every other pending request keeps flowing and the engine stays
    drainable.

Time is driven by the caller: ``step(now=None)`` uses the engine's clock
(live serving), while an explicit ``now`` advances a virtual timeline by
measured work (deterministic open-loop replay — ``launch/serve.py --qps``).
Either way, per-request queue-wait / batch-assembly / compute land in
``RequestStats`` tagged with the request's tenant and priority lane.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.serve.batcher import RequestBatcher
from repro.serve.queue import DISPATCHED, DONE, FAILED

# lanes the scheduler coalesces through RequestBatcher.pack (decode is the
# continuous-batching lane and paces itself)
SCORED_KINDS = ("score", "tiered")


class DecodeJob:
    """One generation request inside a ``DecodeSession``: replay the prompt,
    then greedy-decode ``max_new`` tokens."""
    __slots__ = ("req", "prompt", "fed", "out", "max_new")

    def __init__(self, req, prompt: np.ndarray, max_new: int):
        self.req = req
        self.prompt = np.asarray(prompt, np.int32).reshape(-1)
        self.fed = 0          # tokens fed into the cell so far
        self.out: list[int] = []
        self.max_new = int(max_new)

    def next_token(self) -> int:
        """The next input token: prompt replay first, then feed back the
        previously generated token."""
        if self.fed < len(self.prompt):
            return int(self.prompt[self.fed])
        return self.out[self.fed - len(self.prompt)]

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new


class DecodeSession:
    """A persistent decode batch: one compiled slotted cell, one device-
    resident KV cache whose batch dim is a slot pool, and the free-list that
    recycles slots between steps."""

    def __init__(self, reg):
        self.reg = reg
        self.cap = reg.celldef.batch
        self.max_len = reg.celldef.meta["max_len"]
        n_bound = len(reg.bound)
        self._tok_sh = reg.cell.in_shardings[n_bound]
        self._lens_sh = reg.cell.in_shardings[n_bound + 1]
        self._cache_sh = reg.cell.in_shardings[n_bound + 2]
        self.caches = jax.device_put(reg.celldef.make_request_state(),
                                     self._cache_sh)
        self.lens = np.zeros((self.cap,), np.int32)
        self.free = list(range(self.cap - 1, -1, -1))
        self.active: dict[int, DecodeJob] = {}
        self.waiting: list[DecodeJob] = []
        self.steps = 0

    def admit(self, job: DecodeJob):
        if len(job.prompt) + job.max_new > self.max_len:
            raise ValueError(
                f"sequence of {len(job.prompt)}+{job.max_new} tokens exceeds "
                f"the cell's max_len={self.max_len}")
        self.waiting.append(job)

    @property
    def busy(self) -> bool:
        return bool(self.active or self.waiting)

    def join_waiting(self, now: float):
        """Move waiting jobs into free cache slots (joining the running
        batch is the job's dispatch moment)."""
        while self.waiting and self.free:
            slot = self.free.pop()
            job = self.waiting.pop(0)
            self.lens[slot] = 0
            self.active[slot] = job
            job.req.status = DISPATCHED
            job.req.dispatch_t = now
            job.req.queue_ms = (now - job.req.arrival_t) * 1e3

    def step_tokens(self) -> np.ndarray:
        tokens = np.zeros((self.cap, 1), np.int32)
        for slot, job in self.active.items():
            tokens[slot, 0] = job.next_token()
        return tokens

    def advance(self, logits: np.ndarray, step_ms: float, assembly_ms: float,
                now: float, rstats, queue) -> list[DecodeJob]:
        """Account one decode step: feed counters advance, prompt-done slots
        emit a greedy token, finished jobs release their slot. Returns the
        jobs completed this step."""
        completed = []
        share = step_ms / max(len(self.active), 1)
        asm_share = assembly_ms / max(len(self.active), 1)
        for slot, job in list(self.active.items()):
            job.fed += 1
            self.lens[slot] += 1
            job.req.compute_ms += share
            job.req.assembly_ms += asm_share
            if job.fed >= len(job.prompt):
                job.out.append(int(np.argmax(logits[slot])))
            if job.done:
                req = job.req
                req.result = np.asarray(job.out, np.int32)
                req.status = DONE
                req.complete_t = now
                req.payload = None
                queue.release(req)
                rstats.record("decode", queue_ms=req.queue_ms or 0.0,
                              assembly_ms=req.assembly_ms,
                              compute_ms=req.compute_ms,
                              latency_ms=req.latency_ms,
                              tenant=req.tenant, priority=req.priority)
                del self.active[slot]
                self.free.append(slot)   # recycled, never recompiled
                completed.append(job)
        self.steps += 1
        return completed

    def fail_active(self, err: Exception, now: float, rstats, queue):
        """A decode dispatch raised: fail every active job, recycle their KV
        slots (the free-list grows back to capacity for those slots — stale
        cache contents are harmless because a joining job resets its slot's
        length to 0), and leave waiting jobs queued for the next round."""
        msg = f"{type(err).__name__}: {err}"
        for slot, job in list(self.active.items()):
            req = job.req
            req.status = FAILED
            req.error = msg
            req.complete_t = now
            req.payload = None
            queue.release(req)
            rstats.record_failed("decode", tenant=req.tenant)
            del self.active[slot]
            self.free.append(slot)


class Scheduler:
    """Drains the admission queue into coalesced cell dispatches.

    One ``step`` handles each lane once: score and tiered requests are
    coalesced onto their cell-shape registries (in the queue's priority/EDF
    order, subject to tenant quotas and the max-wait window); every decode
    session with active slots advances one token. ``step`` returns the
    advanced ``now`` cursor so an open-loop driver can thread a virtual
    timeline through it — when a round dispatches nothing because every lane
    is holding for its coalescing window, the returned cursor jumps to the
    earliest window expiry so virtual drains terminate.
    """

    def __init__(self, engine, *, coalesce_window_ms: float = 0.0):
        if coalesce_window_ms < 0:
            raise ValueError(
                f"coalesce_window_ms must be >= 0, got {coalesce_window_ms}")
        self.engine = engine
        self.coalesce_window_ms = float(coalesce_window_ms)
        self.sessions: dict[str, DecodeSession] = {}   # arch -> session
        self._progress = False     # did this step dispatch anything?

    def add_session(self, arch: str, reg) -> DecodeSession:
        session = DecodeSession(reg)
        self.sessions[arch] = session
        return session

    @property
    def busy(self) -> bool:
        return bool(len(self.engine.queue)
                    or any(s.busy for s in self.sessions.values()))

    # -- clock helpers ------------------------------------------------------

    def _advance(self, cursor: float, elapsed_s: float, wall: bool) -> float:
        return self.engine._clock() if wall else cursor + elapsed_s

    def _next_window_expiry(self) -> float | None:
        """Earliest max-wait-window expiry across lanes with pending work."""
        if self.coalesce_window_ms <= 0:
            return None
        window_s = self.coalesce_window_ms / 1e3
        oldest = [self.engine.queue.oldest_arrival(kind)
                  for kind in SCORED_KINDS]
        expiries = [t + window_s for t in oldest if t is not None]
        return min(expiries) if expiries else None

    # -- one scheduling round ----------------------------------------------

    def step(self, *, now: float | None = None) -> float:
        wall = now is None
        cursor = self.engine._clock() if wall else float(now)
        self._progress = False
        cursor = self._dispatch_scored("score", cursor, wall)
        cursor = self._dispatch_scored("tiered", cursor, wall)
        cursor = self._dispatch_decode(cursor, wall)
        if not wall and not self._progress:
            # every lane held for its coalescing window: jump the virtual
            # cursor to the earliest expiry so drain() terminates
            expiry = self._next_window_expiry()
            if expiry is not None and expiry > cursor:
                cursor = expiry
        return cursor

    def _shed_expired(self, expired):
        for req in expired:
            self.engine.rstats.record_shed(req.kind, tenant=req.tenant)

    # -- score / tiered lanes ----------------------------------------------

    def _take(self, kind: str, cursor: float):
        """Drain one scored lane, applying the max-wait coalescing window:
        below the smallest bucket's row count the lane holds (everything
        stays queued) until the oldest pending request ages past the
        window."""
        engine = self.engine
        if self.coalesce_window_ms > 0:
            batcher = (engine._score_batcher if kind == "score"
                       else engine._tiered_batcher)
            min_rows = min(batcher.shapes.values()) if batcher.shapes else 0
            return engine.queue.take(kind, now=cursor, min_rows=min_rows,
                                     max_wait_s=self.coalesce_window_ms / 1e3)
        return engine.queue.take(kind, now=cursor)

    def _fail_chunk(self, ready, chunk, err: Exception, cursor: float,
                    kind: str):
        """Fault isolation: a dispatch raised — fail exactly the requests
        with rows in this chunk (later chunks skip their spans), release
        their quota, and keep the round going."""
        msg = f"{type(err).__name__}: {err}"
        for span in chunk.spans:
            req = ready[span.req]
            if req.status == FAILED:
                continue
            req.status = FAILED
            req.error = msg
            req.complete_t = cursor
            self.engine.queue.release(req)
            self.engine.rstats.record_failed(kind, tenant=req.tenant)

    def _dispatch_scored(self, kind: str, cursor: float, wall: bool) -> float:
        engine = self.engine
        table = engine._score if kind == "score" else engine._tiered
        ready, expired = self._take(kind, cursor)
        self._shed_expired(expired)
        if not ready:
            return cursor
        self._progress = True

        for req in ready:
            req.result = np.empty((req.n_rows,), np.float32)
        batcher = (engine._score_batcher if kind == "score"
                   else engine._tiered_batcher)
        chunks = batcher.pack([r.n_rows for r in ready])

        if kind == "tiered":
            return self._dispatch_tiered(ready, chunks, cursor, wall)

        for chunk in chunks:
            reg = table[chunk.bucket]
            try:
                t0 = engine._clock()
                rows = RequestBatcher.gather([r.payload for r in ready], chunk)
                padded, _mask = RequestBatcher.pad(rows, chunk.rows)
                # numpy straight into device_put: jnp.asarray first would
                # cost a second host->device transfer per dispatch
                x = jax.device_put(padded,
                                   reg.cell.in_shardings[len(reg.bound)])
                assembly_ms = (engine._clock() - t0) * 1e3
                self._mark_dispatch(ready, chunk, cursor)
                y, total_ms = engine._timed_call(reg, x)
            except Exception as err:   # fault injection: fail only this chunk
                self._fail_chunk(ready, chunk, err, cursor, kind)
                continue
            lookup_ms = None
            if reg.lookup is not None:
                try:
                    _, lookup_ms = engine._timed_call(reg.lookup, x)
                except Exception:   # stats companion only — the chunk's
                    lookup_ms = None    # results already computed fine
            engine.stats.record(reg.celldef.name, total_ms, lookup_ms,
                                valid_rows=chunk.n_valid,
                                capacity_rows=chunk.rows)
            cursor = self._advance(cursor, (assembly_ms + total_ms) / 1e3,
                                   wall)
            self._scatter(ready, chunk, np.asarray(y), assembly_ms, total_ms,
                          cursor, kind)
        return cursor

    def _dispatch_tiered(self, ready, chunks, cursor: float,
                         wall: bool) -> float:
        """Tiered chunks stage each chunk's cold fill one chunk ahead of the
        in-flight compute (mirrors the pre-lifecycle ``score_tiered``).
        ``overlap=False`` on every coalesced request stages synchronously —
        the reference timing."""
        engine = self.engine
        overlap = all((r.meta or {}).get("overlap", True) for r in ready)
        payloads = [r.payload for r in ready]

        def stage(chunk):
            t0 = engine._clock()
            tc = engine._tiered[chunk.bucket]
            rows = RequestBatcher.gather(payloads, chunk)
            padded, mask = RequestBatcher.pad(rows, chunk.rows)
            fill = tc.store.prefetch_cold(padded + tc.offsets[None, :],
                                          valid=mask)
            x = jax.device_put(padded,
                               tc.reg.cell.in_shardings[len(tc.reg.bound)])
            return tc, x, fill, (engine._clock() - t0) * 1e3

        def safe_stage(chunk):
            try:
                return stage(chunk)
            except Exception as err:   # staged one ahead: defer to its chunk
                return err

        staged = safe_stage(chunks[0]) if overlap else None
        for k, chunk in enumerate(chunks):
            try:
                if overlap:
                    if isinstance(staged, Exception):
                        raise staged
                    tc, x, fill, assembly_ms = staged
                else:
                    tc, x, fill, assembly_ms = stage(chunk)
                self._mark_dispatch(ready, chunk, cursor)
                t0 = engine._clock()
                cold = tc.store.cold_part(fill).reshape(
                    x.shape[0], x.shape[1], -1)
                cold = jax.device_put(
                    cold, tc.reg.cell.in_shardings[len(tc.reg.bound) + 1])
                y = tc.reg.cell.compiled(*tc.reg.bound, x, cold)
                if overlap and k + 1 < len(chunks):
                    staged = safe_stage(chunks[k + 1])   # under y's compute
                # deliberate timing barrier: chunk latency feeds engine.stats
                jax.block_until_ready(y)  # staticcheck: ignore[RL403]
                total_ms = (engine._clock() - t0) * 1e3
            except Exception as err:   # fault injection: fail only this chunk
                self._fail_chunk(ready, chunk, err, cursor, "tiered")
                if overlap and k + 1 < len(chunks):
                    staged = safe_stage(chunks[k + 1])
                continue
            engine.stats.record(tc.reg.celldef.name, total_ms,
                                valid_rows=chunk.n_valid,
                                capacity_rows=chunk.rows)
            cursor = self._advance(cursor, (assembly_ms + total_ms) / 1e3,
                                   wall)
            self._scatter(ready, chunk, np.asarray(y), assembly_ms, total_ms,
                          cursor, "tiered")
        return cursor

    @staticmethod
    def _mark_dispatch(ready, chunk, cursor: float):
        for span in chunk.spans:
            req = ready[span.req]
            if req.dispatch_t is None:
                req.status = DISPATCHED
                req.dispatch_t = cursor
                req.queue_ms = (cursor - req.arrival_t) * 1e3

    def _scatter(self, ready, chunk, y: np.ndarray, assembly_ms: float,
                 compute_ms: float, cursor: float, kind: str):
        """Write a chunk's outputs back per requester and complete requests
        whose rows all arrived; assembly/compute attribute to requests in
        proportion to their rows in the chunk."""
        live = [s for s in chunk.spans if ready[s.req].status != FAILED]
        RequestBatcher.scatter(
            y, chunk._replace(spans=tuple(live)), [r.result for r in ready])
        for span in live:
            req = ready[span.req]
            frac = span.n / chunk.n_valid
            req.assembly_ms += assembly_ms * frac
            req.compute_ms += compute_ms * frac
            req.rows_done += span.n
            if req.rows_done == req.n_rows:
                req.status = DONE
                req.complete_t = cursor
                req.payload = None      # drop the ids; only the result stays
                self.engine.queue.release(req)
                self.engine.rstats.record(
                    kind, queue_ms=req.queue_ms, assembly_ms=req.assembly_ms,
                    compute_ms=req.compute_ms, latency_ms=req.latency_ms,
                    tenant=req.tenant, priority=req.priority)

    # -- decode lane (continuous batching) ----------------------------------

    def _dispatch_decode(self, cursor: float, wall: bool) -> float:
        engine = self.engine
        ready, expired = engine.queue.take("decode", now=cursor)
        self._shed_expired(expired)
        for req in ready:
            prompt, max_new, arch = req.payload
            session = self._pick_session(arch)
            session.admit(DecodeJob(req, prompt, max_new))
        for session in self.sessions.values():
            self._shed_expired_waiting(session, cursor)
            session.join_waiting(cursor)
            if not session.active:
                continue
            self._progress = True
            try:
                t0 = engine._clock()
                # fresh numpy buffers straight into device_put (one transfer
                # each); lens is copied because the session mutates it in
                # place
                tokens = jax.device_put(session.step_tokens(),
                                        session._tok_sh)
                lens = jax.device_put(session.lens.copy(), session._lens_sh)
                assembly_s = engine._clock() - t0
                (logits, new_caches), total_ms = engine._timed_call(
                    session.reg, tokens, lens, session.caches)
            except Exception as err:   # fail active jobs, recycle their slots
                session.fail_active(err, cursor, engine.rstats, engine.queue)
                session.join_waiting(cursor)
                continue
            session.caches = new_caches
            engine.stats.record(session.reg.celldef.name, total_ms,
                                valid_rows=len(session.active),
                                capacity_rows=session.cap)
            cursor = self._advance(cursor, assembly_s + total_ms / 1e3, wall)
            session.advance(np.asarray(logits), total_ms, assembly_s * 1e3,
                            cursor, engine.rstats, engine.queue)
            session.join_waiting(cursor)   # freed slots recycle immediately
        return cursor

    def _shed_expired_waiting(self, session: DecodeSession, now: float):
        """Deadlines hold while a job waits for a slot, not just while it
        sits in the admission queue: a waiting job past its deadline is shed
        before it can take a freed slot."""
        keep = []
        for job in session.waiting:
            req = job.req
            if req.deadline_t is not None and now > req.deadline_t:
                self.engine.queue.note_shed(req, now=now)
                self.engine.rstats.record_shed("decode", tenant=req.tenant)
            else:
                keep.append(job)
        session.waiting = keep

    def _pick_session(self, arch: str | None) -> DecodeSession:
        if not self.sessions:
            raise ValueError("no continuous-batching decode cell registered "
                             "(register an lm_decode_slotted_cell)")
        if arch is not None:
            return self.sessions[arch]
        if len(self.sessions) > 1:
            raise ValueError(
                f"multiple decode sessions ({sorted(self.sessions)}); "
                f"pass arch=")
        return next(iter(self.sessions.values()))
