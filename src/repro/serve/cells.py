"""Serve-cell builders: (model, config, bound state) → compilable cell defs.

These are the serving counterparts of ``repro.launch.cells`` — but where the
dry-run builds production-scale ShapeDtypeStruct stand-ins, these bind *real*
trained arrays (a packed table, tower MLPs, KV caches) and parameterize the
batch shape, so the same builder serves a 4-field test table on one CPU
device and the Criteo-scale table on the production mesh. The dry-run serve
cells reuse ``packed_score_step`` so the lowered computation is identical in
both harnesses.

A ``ServeCellDef`` separates *bound* inputs (params/state/buffers — device_put
once at registration) from *request* inputs (ids/tokens/caches — fresh every
call); ``repro.serve.cache.CellCache`` compiles the pair into one executable
with explicit shardings.
"""
from __future__ import annotations

import hashlib
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.cache.tiers import tiered_hot_lookup_fn
from repro.core.inference import packed_lookup_fn
from repro.dist.sharding import (lm_kv_cache_pspecs, lm_logits_pspecs,
                                 lm_param_pspecs, packed_serve_pspecs,
                                 replicate_like, tiered_hot_pspecs)


class ServeCellDef(NamedTuple):
    """One compilable serving cell: a step function plus everything the
    ``CellCache`` needs to AOT-compile it — *bound* inputs (params/state,
    device_put once at registration) with their pspecs, *request* input
    ShapeDtypeStructs with theirs, output pspecs, and the identity fields
    (``arch``/``shape``/``kind``/``batch``) that key the compile cache."""
    arch: str              # architecture identity (cache-key component)
    shape: str             # shape name, e.g. "serve_p99"
    kind: str              # score | lookup | retrieve | decode
    batch: int             # leading-dim capacity of the compiled executable
    step_fn: Callable      # step_fn(*bound, *request) -> outputs
    bound: tuple           # pytrees fixed at registration (params, state, ...)
    bound_pspecs: tuple
    request_specs: tuple   # ShapeDtypeStructs for the per-request inputs
    request_pspecs: tuple
    out_pspecs: Any
    meta: dict
    static: Any = None     # config baked into step_fn closures (cfg, top_k…)
    make_request_state: Callable | None = None  # e.g. fresh KV caches

    @property
    def name(self) -> str:
        return f"{self.arch}/{self.shape}"

    @property
    def fingerprint_blob(self) -> str:
        """The raw repr the fingerprint digests — exposed so the
        recompile-hazard pass (``repro.analysis.recompile``) can inspect it
        for unstable content (``0x...`` object addresses from a default
        ``__repr__``, which would fork the compile cache every process
        restart) instead of reasoning about an opaque hash."""
        return repr((self.kind, self.batch, sorted(self.meta.items(), key=str),
                     self.static))

    @property
    def fingerprint(self) -> str:
        """Digest of everything baked into the compiled executable beyond the
        input avals — the step closure's static config (``static``), kind and
        meta. Part of the cache key: two same-named registrations with
        different baked-in config must not share an executable."""
        return hashlib.sha1(self.fingerprint_blob.encode()).hexdigest()[:12]

    def abstract_signature(self) -> tuple:
        """Traced-abstract-value signature of every input the executable sees:
        ``((shape, dtype, weak_type), ...)`` over the flattened bound +
        request pytrees, in call order.

        This is exactly what distinguishes executables *beyond* the cache
        key — two cells whose keys collide but whose signatures differ would
        silently fork (or worse, warm-hit a wrong executable). The
        recompile-hazard pass diffs keys against these signatures; weak-typed
        leaves (Python scalars closed into ``bound``) are flagged because
        their weak dtype re-traces against strongly-typed request arrays."""
        sig = []
        for leaf in jax.tree.leaves((self.bound, self.request_specs)):
            aval = jax.api_util.shaped_abstractify(leaf)
            sig.append((tuple(aval.shape), str(aval.dtype),
                        bool(getattr(aval, "weak_type", False))))
        return tuple(sig)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def packed_score_step(model, cfg, *, top_k: int | None = None,
                      shard_lookup: bool = False, rows_axes=("model",),
                      lookup_comms: str = "psum",
                      bucket_capacity: int | None = None):
    """The packed-table scoring computation shared by the live engine and the
    dry-run serve cells: eval-mode forward over a packed embedding config,
    optionally topped with a candidate ``top_k``.

    ``shard_lookup`` routes the embedding gather through
    ``repro.dist.shard.sharded_packed_lookup`` — the fused lookup runs
    *inside* the partitioner as a ``shard_map`` over the mesh active at
    trace time (the ``CellCache`` compiles under the engine's mesh), with
    subtables row-sharded over ``rows_axes``. ``lookup_comms`` picks the
    merge collective — ``"psum"`` (dequantized partials) or ``"a2a"`` (the
    capacity-bucketed all-to-all of the packed words, ``bucket_capacity``
    ids per bucket) — both bit-exact, so scores match the unsharded cell
    either way. The post-lookup interaction net (``model.interact``) is
    identical to the monolithic path. Degrades to the plain forward when
    compiled without a multi-device mesh."""
    if not shard_lookup:
        def serve_step(params, state, buffers, ids):
            logits, _, _ = model.apply(params, buffers, state, {"ids": ids},
                                       cfg, train=False)
            if top_k is not None:
                return tuple(jax.lax.top_k(logits, top_k))
            return logits
        return serve_step

    from repro.dist.shard import sharded_packed_lookup
    meta = {k: cfg.comp_cfg[k] for k in ("bits", "d", "n")}

    def serve_step(params, state, buffers, ids):
        gids = ids + buffers["offsets"][None, :]
        emb = sharded_packed_lookup(params["embedding"], meta, gids,
                                    rows_axes=rows_axes,
                                    lookup_comms=lookup_comms,
                                    bucket_capacity=bucket_capacity)
        logits, _ = model.interact(params, state, emb, gids, cfg, train=False)
        if top_k is not None:
            return tuple(jax.lax.top_k(logits, top_k))
        return logits
    return serve_step


def packed_score_cell(model, cfg, params, state, buffers, *, batch: int,
                      arch: str, shape: str, dp=("data",),
                      rows_axes=("model",), shard_lookup: bool = False,
                      lookup_comms: str = "psum",
                      bucket_capacity: int | None = None) -> ServeCellDef:
    """Batched CTR scoring from a packed table: ``ids (B, F) -> logits (B,)``.

    ``cfg`` must carry ``compressor="packed"`` with the table's comp_cfg;
    ``params["embedding"]`` is the packed table pytree. ``shard_lookup``
    compiles the ``shard_map`` lookup path and ``lookup_comms``/
    ``bucket_capacity`` pick its merge collective (see
    ``packed_score_step``); both enter the cell fingerprint, so a psum cell
    and an a2a cell never share an executable."""
    n_fields = len(cfg.fields)
    return ServeCellDef(
        arch=arch, shape=shape, kind="score", batch=batch,
        step_fn=packed_score_step(model, cfg, shard_lookup=shard_lookup,
                                  rows_axes=rows_axes,
                                  lookup_comms=lookup_comms,
                                  bucket_capacity=bucket_capacity),
        bound=(params, state, buffers),
        bound_pspecs=(packed_serve_pspecs(params, rows_axes=rows_axes),
                      replicate_like(state), replicate_like(buffers)),
        request_specs=(_sds((batch, n_fields), jnp.int32),),
        request_pspecs=(P(dp, None),),
        out_pspecs=P(dp),
        meta={"kind": "score", "batch": batch, "n_fields": n_fields,
              "shard_lookup": shard_lookup, "lookup_comms": lookup_comms,
              "bucket_capacity": bucket_capacity},
        static=cfg,
    )


def baseline_score_cell(model, cfg, params, state, buffers, *, batch: int,
                        arch: str, shape: str, dp=("data",)) -> ServeCellDef:
    """Batched CTR scoring for a *baseline* compressor (plain, qr, pep,
    optfs, alpt, lsq — anything registered in ``core.compressors``):
    ``ids (B, F) -> logits (B,)``.

    The same eval-mode forward as ``packed_score_cell``, but the dense
    baseline ``params`` replicate instead of packed-table row-sharding —
    baseline tables aren't width-bucketed, so ``packed_serve_pspecs`` doesn't
    apply. This is how ``benchmarks/compression_bench.py`` gets
    apples-to-apples serve p50/p99 for every ``repro.core.baselines`` method
    against the packed MPE path."""
    n_fields = len(cfg.fields)
    return ServeCellDef(
        arch=arch, shape=shape, kind="score", batch=batch,
        step_fn=packed_score_step(model, cfg),
        bound=(params, state, buffers),
        bound_pspecs=(replicate_like(params), replicate_like(state),
                      replicate_like(buffers)),
        request_specs=(_sds((batch, n_fields), jnp.int32),),
        request_pspecs=(P(dp, None),),
        out_pspecs=P(dp),
        meta={"kind": "score", "batch": batch, "n_fields": n_fields,
              "shard_lookup": False},
        static=cfg,
    )


def packed_lookup_cell(table, meta, offsets, *, batch: int, n_fields: int,
                       arch: str, shape: str, dp=("data",),
                       rows_axes=("model",)) -> ServeCellDef:
    """Lookup-only companion cell: the packed gather+unpack+dequant slice of a
    score cell, compiled at the same padded shape. The engine times it per
    request to report the Figure-5 lookup-vs-compute split."""
    from repro.dist.sharding import packed_table_pspecs
    lookup = packed_lookup_fn(meta)

    def lookup_step(tbl, offs, ids):
        return lookup(tbl, ids + offs[None, :])

    return ServeCellDef(
        arch=arch, shape=f"{shape}.lookup", kind="lookup", batch=batch,
        step_fn=lookup_step,
        bound=(table, offsets),
        bound_pspecs=(packed_table_pspecs(table, rows_axes=rows_axes),
                      P(None)),
        request_specs=(_sds((batch, n_fields), jnp.int32),),
        request_pspecs=(P(dp, None),),
        out_pspecs=P(dp, None, None),
        meta={"kind": "lookup", "batch": batch, "n_fields": n_fields},
        static=(meta["bits"], meta["d"], meta["n"]),
    )


def tiered_score_cell(model, cfg, params, state, buffers, hot, meta, *,
                      batch: int, arch: str, shape: str, dp=("data",),
                      rows_axes=("model",), row_keys=("wide", "fm_linear"),
                      shard_lookup: bool = False,
                      lookup_comms: str = "psum",
                      bucket_capacity: int | None = None) -> ServeCellDef:
    """Batched CTR scoring from a **tiered** table: ``(ids (B, F), cold_fill
    (B, F, d)) -> logits (B,)``.

    Hot rows are gathered device-locally inside the cell from the bound hot
    tier (row-sharded like the monolithic table, ``tiered_hot_pspecs``);
    the cold rows arrive as a per-request dense fill staged by the engine's
    prefetch (``TieredTableStore.prefetch_cold`` → ``cold_part``), so their
    host→device transfer overlaps the previous chunk's compute. The merge is
    a ``jnp.where`` on the tier mask and the interaction net is the model's
    own ``interact`` — the scores match the monolithic score cell.

    ``params`` is the serving param tree *without* the ``"embedding"`` entry
    (the tiered store owns the table); ``hot`` is ``TieredTableStore.hot``.
    ``shard_lookup`` routes the hot-tier gather through
    ``repro.dist.shard.sharded_tiered_hot_lookup`` (``shard_map`` over the
    mesh active at compile time, hot subtables row-sharded per
    ``tiered_hot_pspecs``), with ``lookup_comms``/``bucket_capacity``
    selecting the psum or capacity-bucketed a2a merge — scores still match
    the monolithic cell either way.
    """
    n_fields = len(cfg.fields)
    d = int(meta["d"])
    bits = tuple(meta["bits"])
    if shard_lookup:
        from repro.dist.shard import sharded_tiered_hot_lookup

        def hot_lookup(hot_tree, gids):
            return sharded_tiered_hot_lookup(hot_tree, bits, d, gids,
                                             rows_axes=rows_axes,
                                             lookup_comms=lookup_comms,
                                             bucket_capacity=bucket_capacity)
    else:
        hot_lookup = tiered_hot_lookup_fn(bits, d)

    def tiered_step(p, st, bufs, hot_tree, ids, cold_fill):
        gids = ids + bufs["offsets"][None, :]
        hot_emb = hot_lookup(hot_tree, gids)                    # 0 at cold
        is_hot = jnp.take(hot_tree["is_hot"], gids, axis=0)
        emb = jnp.where(is_hot[..., None], hot_emb, cold_fill)
        logits, _ = model.interact(p, st, emb, gids, cfg, train=False)
        return logits

    param_pspecs = {k: replicate_like(v) for k, v in params.items()}
    for k in row_keys:
        if k in params:
            param_pspecs[k] = P(rows_axes)

    return ServeCellDef(
        arch=arch, shape=shape, kind="tiered_score", batch=batch,
        step_fn=tiered_step,
        bound=(params, state, buffers, hot),
        bound_pspecs=(param_pspecs, replicate_like(state),
                      replicate_like(buffers),
                      tiered_hot_pspecs(hot, rows_axes=rows_axes)),
        request_specs=(_sds((batch, n_fields), jnp.int32),
                       _sds((batch, n_fields, d), jnp.float32)),
        request_pspecs=(P(dp, None), P(dp, None, None)),
        out_pspecs=P(dp),
        meta={"kind": "tiered_score", "batch": batch, "n_fields": n_fields,
              "shard_lookup": shard_lookup, "lookup_comms": lookup_comms,
              "bucket_capacity": bucket_capacity},
        static=(cfg, bits, d),
    )


def two_tower_retrieval_cell(model, cfg, params, state, buffers, *,
                             n_cands: int, top_k: int = 100, arch: str,
                             shape: str = "retrieval_cand",
                             rows_axes=("model",)) -> ServeCellDef:
    """One user against a padded candidate corpus → masked top-k.

    Padded candidates score ``-inf`` through the validity mask, so they can
    never enter the top-k of a real request."""
    fu, fi = len(cfg.user_fields), len(cfg.item_fields)

    def retrieve_step(p, st, bufs, user_ids, cand_ids, cand_mask):
        u, _ = model.user_tower(p, bufs, st, user_ids, cfg)
        v, _ = model.item_tower(p, bufs, st, cand_ids, cfg)
        scores = (v @ u[0]) / cfg.temperature
        scores = jnp.where(cand_mask, scores, -jnp.inf)
        return tuple(jax.lax.top_k(scores, top_k))

    return ServeCellDef(
        arch=arch, shape=shape, kind="retrieve", batch=n_cands,
        step_fn=retrieve_step,
        bound=(params, state, buffers),
        bound_pspecs=(packed_serve_pspecs(params, rows_axes=rows_axes),
                      replicate_like(state), replicate_like(buffers)),
        request_specs=(_sds((1, fu), jnp.int32), _sds((n_cands, fi), jnp.int32),
                       _sds((n_cands,), jnp.bool_)),
        request_pspecs=(P(None, None), P(rows_axes, None), P(rows_axes)),
        out_pspecs=(P(None), P(None)),
        meta={"kind": "retrieve", "n_cands": n_cands, "top_k": top_k},
        static=cfg,
    )


def lm_decode_slotted_cell(cfg, params, buffers, *, batch: int, max_len: int,
                           kv_int8: bool = True, arch: str,
                           shape: str = "decode_cb",
                           dp=("data",)) -> ServeCellDef:
    """Continuous-batching decode: per-slot cache lengths.

    The compiled batch dim is a pool of ``batch`` KV-cache *slots*; each slot
    holds one request's sequence at its own length. Request inputs are
    ``(tokens (B, 1), lens (B,) int32, caches)`` where ``lens`` is the
    scheduler-owned per-slot valid length (a recycled slot rejoins at 0,
    which re-seeds its int8 scale on first write) and ``caches`` omits the
    shared ``"len"`` entry of the classic decode cell. Requests join/leave
    the running batch between steps without recompiling — the scheduler's
    ``DecodeSession`` owns the slot free-list."""
    from repro.models.lm import LM

    def decode_step(p, tokens, lens, caches):
        return LM.decode_step_slotted(p, buffers, tokens, lens, caches, cfg)

    kv_dtype = jnp.int8 if kv_int8 else jnp.bfloat16

    def make_caches():
        caches = LM.make_kv_caches(cfg, batch, max_len, kv_dtype)
        caches.pop("len")
        return caches

    caches_sds = jax.eval_shape(make_caches)
    cache_ps = {k: v for k, v in
                lm_kv_cache_pspecs(quantized=kv_int8).items() if k != "len"}
    tok_ps = P(dp, None) if batch > 1 else P(None, None)
    lens_ps = P(dp) if batch > 1 else P(None)
    params_pspecs = lm_param_pspecs(params, cfg)

    return ServeCellDef(
        arch=arch, shape=shape, kind="decode_slotted", batch=batch,
        step_fn=decode_step,
        bound=(params,),
        bound_pspecs=(params_pspecs,),
        request_specs=(_sds((batch, 1), jnp.int32), _sds((batch,), jnp.int32),
                       caches_sds),
        request_pspecs=(tok_ps, lens_ps, cache_ps),
        out_pspecs=(lm_logits_pspecs(batch, dp=dp), cache_ps),
        meta={"kind": "decode_slotted", "batch": batch, "max_len": max_len,
              "kv_int8": kv_int8},
        static=cfg,
        make_request_state=make_caches,
    )


def lm_decode_cell(cfg, params, buffers, *, batch: int, max_len: int,
                   kv_int8: bool = True, arch: str, shape: str = "decode",
                   dp=("data",)) -> ServeCellDef:
    """One-token decode against a persistent KV cache.

    The int8 cache with running-absmax scale calibration (``LM._requant_cache``)
    is the default — the paper-aligned halving of the decode-dominant KV
    traffic; pass ``kv_int8=False`` for the bf16 reference cache."""
    from repro.models.lm import LM

    def decode_step(p, tokens, caches):
        return LM.decode_step(p, buffers, tokens, caches, cfg)

    kv_dtype = jnp.int8 if kv_int8 else jnp.bfloat16
    # the model owns cache layout + scale seeding; the SDS template and the
    # engine's fresh caches both derive from make_kv_caches
    caches_sds = jax.eval_shape(
        lambda: LM.make_kv_caches(cfg, batch, max_len, kv_dtype))
    cache_ps = lm_kv_cache_pspecs(quantized=kv_int8)
    tok_ps = P(dp, None) if batch > 1 else P(None, None)
    params_pspecs = lm_param_pspecs(params, cfg)

    return ServeCellDef(
        arch=arch, shape=shape, kind="decode", batch=batch,
        step_fn=decode_step,
        bound=(params,),
        bound_pspecs=(params_pspecs,),
        request_specs=(_sds((batch, 1), jnp.int32), caches_sds),
        request_pspecs=(tok_ps, cache_ps),
        out_pspecs=(lm_logits_pspecs(batch, dp=dp), cache_ps),
        meta={"kind": "decode", "batch": batch, "max_len": max_len,
              "kv_int8": kv_int8},
        static=cfg,
        make_request_state=lambda: LM.make_kv_caches(cfg, batch, max_len,
                                                     kv_dtype),
    )
