"""Admission queue: the arrival edge of the request lifecycle.

Serving decouples *arrival* from *dispatch*: callers ``submit`` requests into
a bounded queue and the scheduler (``repro.serve.scheduler``) drains it into
coalesced cell-shaped batches. The queue owns the admission policy:

  - **backpressure** — the queue is bounded (``capacity`` requests); a full
    queue *sheds* new arrivals (reject-on-full, counted in ``shed_full``)
    instead of growing without bound — the open-loop overload behaviour the
    Figure-5-style latency split needs to stay measurable;
  - **priority lanes + EDF** — each request carries a ``priority`` (0 is the
    most urgent lane) and ``take`` drains lanes in priority order with
    earliest-deadline-first dispatch *inside* each lane (ties broken by
    ticket, so a single-tenant no-deadline stream dispatches in exactly the
    PR-5 FIFO order — bit-identical results);
  - **per-tenant quotas** — ``quotas[tenant] = TenantQuota(max_queued,
    max_inflight_rows)`` bounds a tenant's queue share at admission
    (``shed_quota``) and its dispatched-but-incomplete rows at drain
    (over-quota requests *defer* — stay queued — rather than shed);
  - **load-adaptive shedding** — above ``shed_watermark`` occupancy only the
    priority-0 lane is admitted (``shed_load``): background traffic is the
    first to go when the queue backs up, long before reject-on-full;
  - **deadlines** — a request may carry a deadline; requests still queued
    past it are shed at drain time (``shed_deadline``) rather than burning
    cell capacity on answers nobody is waiting for;
  - **timestamps** — arrival, dispatch and completion times are recorded per
    request, so queue-wait is separable from batch-assembly and compute in
    the latency breakdown (``repro.serve.stats.RequestStats``).

All shed/admit counters are kept both as totals (back-compat) and split per
request kind and per tenant (``counters()["per_kind"]`` /
``["per_tenant"]``), so an overloaded lane is distinguishable from an
overloaded queue.

Timestamps are driven by the caller-provided ``now`` (the engine passes its
injectable clock — ``time.perf_counter`` by default; the open-loop replay in
``launch/serve.py`` passes a virtual timeline), so the same queue serves
live traffic and deterministic offline replay.
"""
from __future__ import annotations

import math
from collections import deque
from typing import NamedTuple

# request lifecycle states
QUEUED = "queued"
DISPATCHED = "dispatched"   # at least one chunk dispatched, results pending
DONE = "done"
SHED = "shed"
FAILED = "failed"           # a dispatch raised; the error rode back instead


class RequestFailedError(RuntimeError):
    """Polling a ticket whose dispatch raised mid-``sched_step``. The
    message carries the original exception's type and text."""


class TenantQuota(NamedTuple):
    """Per-tenant admission/dispatch budget.

    ``max_queued`` caps the tenant's *queue share* (pending requests; the
    arrival edge — exceeding it sheds with ``shed_quota``).
    ``max_inflight_rows`` caps the tenant's dispatched-but-incomplete rows
    (the drain edge — over-quota requests stay queued until in-flight work
    completes). Either may be None (unbounded)."""
    max_queued: int | None = None
    max_inflight_rows: int | None = None


_COUNTER_KEYS = ("admitted", "shed_full", "shed_deadline", "shed_quota",
                 "shed_load")


class Request:
    """One submitted request and its lifecycle record."""
    __slots__ = ("ticket", "kind", "payload", "meta", "n_rows", "arrival_t",
                 "deadline_t", "dispatch_t", "complete_t", "status", "result",
                 "rows_done", "queue_ms", "assembly_ms", "compute_ms",
                 "tenant", "priority", "error")

    def __init__(self, ticket: int, kind: str, payload, n_rows: int,
                 arrival_t: float, deadline_t: float | None, meta=None,
                 tenant: str = "default", priority: int = 0):
        self.ticket = ticket
        self.kind = kind
        self.payload = payload
        self.meta = meta
        self.n_rows = int(n_rows)
        self.arrival_t = float(arrival_t)
        self.deadline_t = deadline_t
        self.dispatch_t = None
        self.complete_t = None
        self.status = QUEUED
        self.result = None
        self.rows_done = 0
        self.queue_ms = None
        self.assembly_ms = 0.0
        self.compute_ms = 0.0
        self.tenant = tenant
        self.priority = int(priority)
        self.error = None

    @property
    def latency_ms(self) -> float | None:
        if self.complete_t is None:
            return None
        return (self.complete_t - self.arrival_t) * 1e3

    @property
    def lane(self) -> str:
        """The scheduling lane: request kind + priority level."""
        return f"{self.kind}:p{self.priority}"


class AdmissionQueue:
    """Bounded multi-lane queue of admitted requests with shed counters.

    The queue never dispatches anything itself — the scheduler calls
    ``take`` to drain one kind's pending requests (shedding the expired ones
    on the way out, in priority/EDF order, subject to per-tenant in-flight
    quotas). All counters are cumulative over the queue's life.
    """

    def __init__(self, capacity: int = 1024, *,
                 quotas: dict[str, TenantQuota] | None = None,
                 shed_watermark: float = 1.0):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        if not 0.0 < shed_watermark <= 1.0:
            raise ValueError(
                f"shed_watermark must be in (0, 1], got {shed_watermark}")
        self.capacity = int(capacity)
        self.quotas = dict(quotas or {})
        self.shed_watermark = float(shed_watermark)
        self._pending: deque[Request] = deque()
        self._next_ticket = 0
        self._per_kind: dict[str, dict[str, int]] = {}
        self._per_tenant: dict[str, dict[str, int]] = {}
        self._queued_by_tenant: dict[str, int] = {}
        self._inflight_rows: dict[str, int] = {}

    def __len__(self) -> int:
        return len(self._pending)

    # -- counter plumbing ----------------------------------------------------

    def _bump(self, counter: str, kind: str, tenant: str):
        for table, key in ((self._per_kind, kind), (self._per_tenant, tenant)):
            rec = table.setdefault(key, dict.fromkeys(_COUNTER_KEYS, 0))
            rec[counter] += 1

    def _total(self, counter: str) -> int:
        return sum(rec[counter] for rec in self._per_kind.values())

    @property
    def admitted(self) -> int:
        return self._total("admitted")

    @property
    def shed_full(self) -> int:
        return self._total("shed_full")

    @property
    def shed_deadline(self) -> int:
        return self._total("shed_deadline")

    @property
    def shed_quota(self) -> int:
        return self._total("shed_quota")

    @property
    def shed_load(self) -> int:
        return self._total("shed_load")

    # -- admission -----------------------------------------------------------

    def submit(self, kind: str, payload, n_rows: int, *, now: float,
               deadline_ms: float | None = None, meta=None,
               tenant: str = "default", priority: int = 0) -> Request | None:
        """Admit a request, or shed it (returns None) when an admission rule
        rejects it: queue full (``shed_full``), queue above the watermark
        and ``priority > 0`` (``shed_load``), or the tenant's queue share
        exhausted (``shed_quota``).

        ``now`` is the arrival timestamp on the caller's clock; a relative
        ``deadline_ms`` becomes an absolute deadline on the same clock."""
        if priority < 0:
            raise ValueError(f"priority must be >= 0, got {priority}")
        quota = self.quotas.get(tenant)
        if (quota is not None and quota.max_inflight_rows is not None
                and int(n_rows) > quota.max_inflight_rows):
            # could never dispatch: deferring it would wedge the scheduler
            raise ValueError(
                f"request of {n_rows} rows exceeds tenant {tenant!r} "
                f"max_inflight_rows={quota.max_inflight_rows}")
        if len(self._pending) >= self.capacity:
            self._bump("shed_full", kind, tenant)
            return None
        if (priority > 0 and self.shed_watermark < 1.0
                and len(self._pending) >= self.shed_watermark * self.capacity):
            self._bump("shed_load", kind, tenant)
            return None
        if (quota is not None and quota.max_queued is not None
                and self._queued_by_tenant.get(tenant, 0)
                >= quota.max_queued):
            self._bump("shed_quota", kind, tenant)
            return None
        deadline_t = None if deadline_ms is None else now + deadline_ms / 1e3
        req = Request(self._next_ticket, kind, payload, n_rows, now,
                      deadline_t, meta=meta, tenant=tenant, priority=priority)
        self._next_ticket += 1
        self._pending.append(req)
        self._bump("admitted", kind, tenant)
        self._queued_by_tenant[tenant] = \
            self._queued_by_tenant.get(tenant, 0) + 1
        return req

    # -- drain ---------------------------------------------------------------

    @staticmethod
    def _edf_key(req: Request):
        # priority lanes first; EDF inside a lane; ticket (arrival order)
        # breaks ties — so no-priority no-deadline traffic drains pure FIFO
        deadline = math.inf if req.deadline_t is None else req.deadline_t
        return (req.priority, deadline, req.ticket)

    def take(self, kind: str, *, now: float, min_rows: int | None = None,
             max_wait_s: float | None = None) -> tuple[list, list]:
        """Drain the pending requests of ``kind`` -> (ready, expired).

        ``ready`` comes out in dispatch order: priority lane 0 first,
        earliest deadline first within a lane, ticket order on ties.
        Requests whose deadline passed while they queued are shed (status
        ``SHED``, counted) instead of dispatched; other kinds stay queued
        untouched, as do requests a tenant in-flight quota defers.

        ``min_rows``/``max_wait_s`` implement the scheduler's **max-wait
        coalescing window**: when the ready rows sum below ``min_rows`` and
        the oldest pending request of this kind is younger than
        ``max_wait_s``, everything stays queued and ``ready`` is empty — the
        lane keeps coalescing until the bucket fills or the window expires
        (expired requests are still shed while holding)."""
        candidates, keep = [], deque()
        while self._pending:
            req = self._pending.popleft()
            if req.kind != kind:
                keep.append(req)
                continue
            candidates.append(req)
        expired, live = [], []
        for req in candidates:
            if req.deadline_t is not None and now > req.deadline_t:
                req.status = SHED
                req.complete_t = now
                self._bump("shed_deadline", req.kind, req.tenant)
                self._queued_by_tenant[req.tenant] -= 1
                expired.append(req)
            else:
                live.append(req)
        live.sort(key=self._edf_key)

        if (max_wait_s is not None and live
                and sum(r.n_rows for r in live) < (min_rows or 0)
                and now - min(r.arrival_t for r in live) < max_wait_s):
            # hold: not enough rows to fill the smallest bucket and the
            # oldest request hasn't waited out the coalescing window yet
            keep.extend(sorted(live, key=lambda r: r.ticket))
            self._pending = keep
            return [], expired

        ready, taken_rows = [], {}
        deferred = []
        for req in live:
            quota = self.quotas.get(req.tenant)
            if quota is not None and quota.max_inflight_rows is not None:
                inflight = (self._inflight_rows.get(req.tenant, 0)
                            + taken_rows.get(req.tenant, 0))
                if inflight + req.n_rows > quota.max_inflight_rows:
                    deferred.append(req)
                    continue
            taken_rows[req.tenant] = \
                taken_rows.get(req.tenant, 0) + req.n_rows
            ready.append(req)
        for req in ready:
            self._inflight_rows[req.tenant] = \
                self._inflight_rows.get(req.tenant, 0) + req.n_rows
            self._queued_by_tenant[req.tenant] -= 1
        keep.extend(sorted(deferred, key=lambda r: r.ticket))
        self._pending = keep
        return ready, expired

    def release(self, req: Request):
        """Return a taken request's rows to its tenant's in-flight budget —
        called once when the request completes, fails or is shed after
        dispatch (decode jobs shed while waiting for a KV slot)."""
        left = self._inflight_rows.get(req.tenant, 0) - req.n_rows
        self._inflight_rows[req.tenant] = max(left, 0)

    def note_shed(self, req: Request, *, now: float):
        """Shed a request that was already taken (e.g. a decode job whose
        deadline passed while it waited for a KV slot): counts it under
        ``shed_deadline`` for its kind/tenant and releases its quota."""
        req.status = SHED
        req.complete_t = now
        req.payload = None
        self._bump("shed_deadline", req.kind, req.tenant)
        self.release(req)

    # -- introspection -------------------------------------------------------

    def pending_rows(self, kind: str) -> int:
        return sum(r.n_rows for r in self._pending if r.kind == kind)

    def oldest_arrival(self, kind: str) -> float | None:
        arrivals = [r.arrival_t for r in self._pending if r.kind == kind]
        return min(arrivals) if arrivals else None

    def counters(self) -> dict:
        """Totals (back-compat) plus the per-kind / per-tenant split of
        every admission counter and the live in-flight row budget."""
        return {"capacity": self.capacity, "depth": len(self._pending),
                "admitted": self.admitted, "shed_full": self.shed_full,
                "shed_deadline": self.shed_deadline,
                "shed_quota": self.shed_quota, "shed_load": self.shed_load,
                "per_kind": {k: dict(v)
                             for k, v in sorted(self._per_kind.items())},
                "per_tenant": {t: dict(v)
                               for t, v in sorted(self._per_tenant.items())},
                "inflight_rows": {t: n for t, n
                                  in sorted(self._inflight_rows.items())
                                  if n}}
