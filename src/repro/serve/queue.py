"""Admission queue: the arrival edge of the request lifecycle.

Serving decouples *arrival* from *dispatch*: callers ``submit`` requests into
a bounded queue and the scheduler (``repro.serve.scheduler``) drains it into
coalesced cell-shaped batches. The queue owns the admission policy:

  - **backpressure** — the queue is bounded (``capacity`` requests); a full
    queue *sheds* new arrivals (reject-on-full, counted in ``shed_full``)
    instead of growing without bound — the open-loop overload behaviour the
    Figure-5-style latency split needs to stay measurable;
  - **deadlines** — a request may carry a deadline; requests still queued
    past it are shed at drain time (``shed_deadline``) rather than burning
    cell capacity on answers nobody is waiting for;
  - **timestamps** — arrival, dispatch and completion times are recorded per
    request, so queue-wait is separable from batch-assembly and compute in
    the latency breakdown (``repro.serve.stats.RequestStats``).

Timestamps are driven by the caller-provided ``now`` (the engine passes
``time.perf_counter()``; the open-loop replay in ``launch/serve.py`` passes a
virtual timeline), so the same queue serves live traffic and deterministic
offline replay.
"""
from __future__ import annotations

from collections import deque

# request lifecycle states
QUEUED = "queued"
DISPATCHED = "dispatched"   # at least one chunk dispatched, results pending
DONE = "done"
SHED = "shed"


class Request:
    """One submitted request and its lifecycle record."""
    __slots__ = ("ticket", "kind", "payload", "meta", "n_rows", "arrival_t",
                 "deadline_t", "dispatch_t", "complete_t", "status", "result",
                 "rows_done", "queue_ms", "assembly_ms", "compute_ms")

    def __init__(self, ticket: int, kind: str, payload, n_rows: int,
                 arrival_t: float, deadline_t: float | None, meta=None):
        self.ticket = ticket
        self.kind = kind
        self.payload = payload
        self.meta = meta
        self.n_rows = int(n_rows)
        self.arrival_t = float(arrival_t)
        self.deadline_t = deadline_t
        self.dispatch_t = None
        self.complete_t = None
        self.status = QUEUED
        self.result = None
        self.rows_done = 0
        self.queue_ms = None
        self.assembly_ms = 0.0
        self.compute_ms = 0.0

    @property
    def latency_ms(self) -> float | None:
        if self.complete_t is None:
            return None
        return (self.complete_t - self.arrival_t) * 1e3


class AdmissionQueue:
    """Bounded FIFO of admitted requests with shed counters.

    The queue never dispatches anything itself — the scheduler calls
    ``take`` to drain one kind's pending requests (shedding the expired ones
    on the way out). All counters are cumulative over the queue's life.
    """

    def __init__(self, capacity: int = 1024):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self._pending: deque[Request] = deque()
        self._next_ticket = 0
        self.admitted = 0
        self.shed_full = 0
        self.shed_deadline = 0

    def __len__(self) -> int:
        return len(self._pending)

    def submit(self, kind: str, payload, n_rows: int, *, now: float,
               deadline_ms: float | None = None, meta=None) -> Request | None:
        """Admit a request, or shed it (returns None) when the queue is full.

        ``now`` is the arrival timestamp on the caller's clock; a relative
        ``deadline_ms`` becomes an absolute deadline on the same clock."""
        if len(self._pending) >= self.capacity:
            self.shed_full += 1
            return None
        deadline_t = None if deadline_ms is None else now + deadline_ms / 1e3
        req = Request(self._next_ticket, kind, payload, n_rows, now,
                      deadline_t, meta=meta)
        self._next_ticket += 1
        self._pending.append(req)
        self.admitted += 1
        return req

    def take(self, kind: str, *, now: float) -> tuple[list, list]:
        """Drain the pending requests of ``kind`` in FIFO order ->
        (ready, expired). Requests whose deadline passed while they queued
        are shed (status ``SHED``, counted) instead of dispatched; other
        kinds stay queued untouched."""
        ready, expired, keep = [], [], deque()
        while self._pending:
            req = self._pending.popleft()
            if req.kind != kind:
                keep.append(req)
                continue
            if req.deadline_t is not None and now > req.deadline_t:
                req.status = SHED
                req.complete_t = now
                self.shed_deadline += 1
                expired.append(req)
                continue
            ready.append(req)
        self._pending = keep
        return ready, expired

    def counters(self) -> dict:
        return {"capacity": self.capacity, "depth": len(self._pending),
                "admitted": self.admitted, "shed_full": self.shed_full,
                "shed_deadline": self.shed_deadline}
