"""The serving engine: a submit/poll request lifecycle over compiled cells.

Request flow for a scored request:

  submit(ids) ──▶ AdmissionQueue (bounded; deadlines; shed-on-full)
      ──▶ Scheduler.step: coalesce pending requests across callers onto the
          registered cell shapes (one padded cell invocation serves many
          requests; outputs scatter back per requester via Chunk.spans)
      ──▶ poll(ticket) → probs (n,)

``score`` / ``score_tiered`` / ``decode`` are preserved as thin synchronous
wrappers (submit + drain + poll), so single-caller code and every pre-
lifecycle test keep working bit-identically — a lone request packs onto
exactly the chunks the old per-request planner chose. LM generation rides
the scheduler's **continuous-batching** decode lane (``submit_decode``):
sequences join/leave a persistent slot-pooled KV cache between steps.

Every executable is compiled exactly once per (arch, shape, mesh) by the
``CellCache``; bound state (packed table, MLPs, towers) is device_put with
its serving shardings at registration and reused across requests. Per-cell
wall-clock is recorded with a lookup-only companion executable timed
alongside to report the paper's Figure-5 lookup-vs-compute latency split,
plus per-dispatch occupancy; per-request queue-wait / batch-assembly /
compute land in ``RequestStats``. Timings cover executable dispatch-to-ready
(host→device transfer of the request ids is excluded, matching the Figure-5
protocol).
"""
from __future__ import annotations

import time
from typing import NamedTuple

import jax
import numpy as np

from repro.dist.mesh import host_mesh
from repro.serve.batcher import RequestBatcher
from repro.serve.cache import CellCache, CompiledCell
from repro.serve.cells import (ServeCellDef, packed_lookup_cell,
                               packed_score_cell, tiered_score_cell)
from repro.serve.queue import (DONE, FAILED, SHED, AdmissionQueue,
                               RequestFailedError, TenantQuota)
from repro.serve.scheduler import Scheduler
from repro.serve.stats import LatencyStats, RequestStats


class RegisteredCell(NamedTuple):
    """A cell after registration: its definition, the warm compiled
    executable, the bound inputs committed to their shardings, and the
    optional Figure-5 lookup-split companion cell."""
    celldef: ServeCellDef
    cell: CompiledCell        # the warm executable
    bound: tuple              # bound inputs, committed to their shardings
    lookup: "RegisteredCell | None"   # Figure-5 split companion


class TieredCell(NamedTuple):
    """A tiered score cell plus the ``TieredTableStore`` that feeds it and
    the per-field id offsets used to globalize request ids for the cold
    prefetch (the cell itself re-globalizes on device)."""
    reg: RegisteredCell
    store: object             # repro.cache.TieredTableStore
    offsets: np.ndarray       # (F,) int32


def _sigmoid(x: np.ndarray) -> np.ndarray:
    return 1.0 / (1.0 + np.exp(-x))


class Engine:
    """Front-end over the cell cache + request batcher.

    One engine holds one mesh (default: the host mesh — 1×1 on a stock CPU,
    where every sharding constraint is a no-op) and one ``CellCache``; cells
    from several models can coexist, keyed by their ``arch`` identity.
    """

    def __init__(self, mesh=None, cache: CellCache | None = None,
                 queue_capacity: int = 1024, *,
                 quotas: dict[str, TenantQuota] | None = None,
                 shed_watermark: float = 1.0,
                 coalesce_window_ms: float = 0.0,
                 clock=None):
        self.mesh = mesh if mesh is not None else host_mesh()
        self.cache = cache if cache is not None else CellCache(self.mesh)
        # every timestamp in the lifecycle flows from this one callable —
        # inject repro.serve.clock.ManualClock for deterministic tests
        self._clock = clock if clock is not None else time.perf_counter
        self.stats = LatencyStats()
        self.rstats = RequestStats()
        self.queue = AdmissionQueue(queue_capacity, quotas=quotas,
                                    shed_watermark=shed_watermark)
        self.scheduler = Scheduler(self,
                                   coalesce_window_ms=coalesce_window_ms)
        self._requests: dict[int, object] = {}          # ticket -> Request
        self._score: dict[str, RegisteredCell] = {}     # bucket name -> cell
        self._score_batcher = RequestBatcher()
        self._retrieve: dict[str, RegisteredCell] = {}  # arch -> cell
        self._decode: dict[str, RegisteredCell] = {}    # arch -> cell
        self._tiered: dict[str, TieredCell] = {}        # bucket name -> cell
        self._tiered_batcher = RequestBatcher()
        self._pending_swaps: list[tuple] = []           # (arch, table, meta)
        self.swaps_applied = 0
        # traffic-adaptive tiering (repro.cache.policy): one policy drives
        # every registered tiered store; adapters (repro.serve.repack.
        # PressureAdapter) ride the same cadence hook
        self._tier_policy = None
        self._policy_every = 8
        self._policy_rounds = 0
        self._adapters: list = []
        self._hot_seen: dict[str, int] = {}     # shape -> store.hot_version
        self.tier_moves = {"plans": 0, "promotions": 0, "demotions": 0,
                           "bytes": 0}

    # -- registration -------------------------------------------------------

    def _compile(self, celldef: ServeCellDef) -> RegisteredCell:
        # the fingerprint covers config baked into the step closure (model
        # cfg, top_k, …): same-named registrations with different static
        # config must compile their own executable, not warm-hit a wrong one
        key = self.cache.key(
            celldef.arch,
            f"{celldef.shape}@{celldef.batch}#{celldef.fingerprint}")

        def build():
            input_specs = celldef.bound + celldef.request_specs
            in_pspecs = celldef.bound_pspecs + celldef.request_pspecs
            return (celldef.step_fn, input_specs, in_pspecs,
                    celldef.out_pspecs, celldef.meta)

        cell = self.cache.get_or_compile(key, build)
        n_bound = len(celldef.bound)
        bound = tuple(jax.device_put(b, s) for b, s in
                      zip(celldef.bound, cell.in_shardings[:n_bound]))
        return RegisteredCell(celldef, cell, bound, None)

    def register(self, celldef: ServeCellDef,
                 lookup_cell: ServeCellDef | None = None) -> RegisteredCell:
        """Compile (or warm-hit) a cell and route it by kind. Score cells also
        register their capacity as a batcher bucket under their shape name."""
        reg = self._compile(celldef)
        if lookup_cell is not None:
            reg = reg._replace(lookup=self._compile(lookup_cell))
        if celldef.kind == "score":
            self._score[celldef.shape] = reg
            self._score_batcher.register(celldef.shape, celldef.batch)
        elif celldef.kind == "retrieve":
            self._retrieve[celldef.arch] = reg
        elif celldef.kind == "decode":
            self._decode[celldef.arch] = reg
        elif celldef.kind == "decode_slotted":
            self.scheduler.add_session(celldef.arch, reg)
        else:
            raise ValueError(f"unroutable cell kind {celldef.kind!r}")
        return reg

    def register_packed_model(self, arch, model, cfg, params, state, buffers,
                              *, shapes: dict[str, int],
                              lookup_split: bool = True, dp=("data",),
                              rows_axes=("model",),
                              shard_lookup: bool = False,
                              lookup_comms: str = "psum",
                              bucket_capacity: int | None = None):
        """Register one score cell per (shape name → row capacity) for a flat
        CTR model serving from a packed table, each with its lookup-split
        companion when ``lookup_split``. ``shard_lookup`` compiles the
        ``shard_map`` lookup path against the engine's mesh (the fused
        gather runs inside the partitioner — a no-op on a 1-device mesh);
        ``lookup_comms``/``bucket_capacity`` select its merge collective
        (psum, or the capacity-bucketed all-to-all) and enter the cell
        fingerprint."""
        meta = {k: cfg.comp_cfg[k] for k in ("bits", "d", "n")}
        n_fields = len(cfg.fields)
        for shape, rows in shapes.items():
            cd = packed_score_cell(model, cfg, params, state, buffers,
                                   batch=rows, arch=arch, shape=shape,
                                   dp=dp, rows_axes=rows_axes,
                                   shard_lookup=shard_lookup,
                                   lookup_comms=lookup_comms,
                                   bucket_capacity=bucket_capacity)
            lc = None
            if lookup_split:
                lc = packed_lookup_cell(params["embedding"], meta,
                                        buffers["offsets"], batch=rows,
                                        n_fields=n_fields, arch=arch,
                                        shape=shape, dp=dp,
                                        rows_axes=rows_axes)
            self.register(cd, lookup_cell=lc)

    def register_tiered_model(self, arch, model, cfg, params, state, buffers,
                              store, *, shapes: dict[str, int], dp=("data",),
                              rows_axes=("model",),
                              shard_lookup: bool = False,
                              lookup_comms: str = "psum",
                              bucket_capacity: int | None = None):
        """Register one **tiered** score cell per (shape name → row capacity)
        serving from a ``repro.cache.TieredTableStore``: the store's hot tier
        binds into the executable (device-local gather), cold rows ride each
        request as prefetch-staged fills (see ``score_tiered``).

        ``params`` may carry an ``"embedding"`` entry (the monolithic packed
        table) — it is dropped; the store owns the table now."""
        p = {k: v for k, v in params.items() if k != "embedding"}
        offsets = np.asarray(buffers["offsets"], np.int32)
        for shape, rows in shapes.items():
            cd = tiered_score_cell(model, cfg, p, state, buffers, store.hot,
                                   store.meta, batch=rows, arch=arch,
                                   shape=shape, dp=dp, rows_axes=rows_axes,
                                   shard_lookup=shard_lookup,
                                   lookup_comms=lookup_comms,
                                   bucket_capacity=bucket_capacity)
            reg = self._compile(cd)
            self._tiered[shape] = TieredCell(reg, store, offsets)
            self._tiered_batcher.register(shape, rows)
            self._hot_seen[shape] = store.hot_version

    # -- serving-time precision adaptation (repro.serve.repack) -------------

    def request_swap(self, table, meta, *, arch: str | None = None):
        """Queue an atomic packed-table swap (serving-time precision
        adaptation, ``repro.serve.repack``).

        The swap applies at the **next ``sched_step`` boundary**, never
        mid-round: every dispatch reads an immutable ``bound`` tuple
        snapshot, and the scheduler's per-round cell lookups all happen after
        the swap point, so an in-flight coalesced batch can never observe a
        torn table. The new ``table`` must match the live table's leaf
        shapes/dtypes exactly (a capacity-conforming repack —
        ``TableSwapper`` guarantees this), so re-binding goes through the
        compiled ``in_shardings`` and **zero recompiles** occur."""
        self._pending_swaps.append((arch, table, dict(meta)))

    def live_packed_table(self, *, arch: str | None = None):
        """The packed-table pytree currently bound into the score cells of
        ``arch`` (host-side view from the cell definition) — the shape
        template a repack must conform to."""
        for reg in self._score.values():
            if arch is None or reg.celldef.arch == arch:
                return reg.celldef.bound[0]["embedding"]
        raise ValueError(f"no packed score cell registered for arch={arch!r}")

    def _apply_swaps(self):
        while self._pending_swaps:
            arch, table, meta = self._pending_swaps.pop(0)
            self._swap_now(arch, table, meta)

    def _swap_now(self, arch, table, meta):
        swapped = False
        for shape, reg in self._score.items():
            if arch is not None and reg.celldef.arch != arch:
                continue
            self._score[shape] = self._rebind_score(reg, table)
            swapped = True
        for shape, tc in self._tiered.items():
            if arch is not None and tc.reg.celldef.arch != arch:
                continue
            self._tiered[shape] = self._rebind_tiered(tc, table, meta)
            swapped = True
        if not swapped:
            raise ValueError(
                f"table swap targets no registered cell (arch={arch!r})")
        self.swaps_applied += 1

    @staticmethod
    def _check_swap_layout(old, new, what: str):
        """A swap must be invisible to the executable: identical pytree
        structure, shapes and dtypes — otherwise the compiled input avals no
        longer match and the call would have to recompile."""
        def sig(tree):
            return jax.tree.map(lambda x: (tuple(x.shape), str(x.dtype)),
                                tree)
        if sig(old) != sig(new):
            raise ValueError(
                f"table swap would change the compiled {what} layout — "
                f"repack with row_capacities pinned to the live table "
                f"(repro.serve.repack.subtable_capacities)")

    def _rebind_score(self, reg: RegisteredCell, table) -> RegisteredCell:
        celldef = reg.celldef
        params = dict(celldef.bound[0])
        self._check_swap_layout(params["embedding"], table, "packed-table")
        params["embedding"] = table
        celldef = celldef._replace(bound=(params,) + celldef.bound[1:])
        # same executable, same shardings: the new leaves re-shard under the
        # exact NamedShardings the cell compiled with (packed_table_pspecs)
        bound0 = jax.device_put(params, reg.cell.in_shardings[0])
        reg = reg._replace(celldef=celldef, bound=(bound0,) + reg.bound[1:])
        if reg.lookup is not None:
            lr = reg.lookup
            lcd = lr.celldef._replace(bound=(table,) + lr.celldef.bound[1:])
            lb0 = jax.device_put(table, lr.cell.in_shardings[0])
            reg = reg._replace(lookup=lr._replace(
                celldef=lcd, bound=(lb0,) + lr.bound[1:]))
        return reg

    def _rebind_tiered(self, tc: TieredCell, table, meta) -> TieredCell:
        tc.store.refresh(table, meta)
        reg = tc.reg
        hot_i = len(reg.bound) - 1          # (params, state, buffers, hot)
        self._check_swap_layout(reg.celldef.bound[hot_i], tc.store.hot,
                                "hot-tier")
        hot = jax.device_put(tc.store.hot, reg.cell.in_shardings[hot_i])
        celldef = reg.celldef._replace(
            bound=reg.celldef.bound[:hot_i] + (tc.store.hot,))
        reg = reg._replace(celldef=celldef,
                           bound=reg.bound[:hot_i] + (hot,))
        return TieredCell(reg, tc.store, tc.offsets)

    # -- traffic-adaptive tiering (repro.cache.policy) ----------------------

    def attach_tier_policy(self, policy, *, every: int = 8):
        """Wire an admission/eviction policy (``cache.DecayAdmissionPolicy``
        or ``cache.StaticTierPolicy``) into the serving loop: every
        registered tiered store feeds its lookup stream to the policy, and
        every ``every``-th ``sched_step`` the policy plans a bounded batch
        of promotions/demotions that the stores apply incrementally — no
        re-pack, no recompile (the moves are shape-preserving and the
        updated hot tier rebinds through the compiled ``in_shardings``).
        Returns the policy for chaining."""
        stores = self._tier_stores()
        if not stores:
            raise ValueError(
                "attach_tier_policy requires a registered tiered model "
                "(register_tiered_model)")
        for store in stores:
            store.attach_policy(policy)
        self._tier_policy = policy
        self._policy_every = int(every)
        return policy

    def attach_adapter(self, adapter):
        """Register a drift adapter (``repro.serve.repack.PressureAdapter``)
        on the policy cadence hook: ``adapter.step(engine)`` runs once per
        ``sched_step``, after tier moves apply — the adapter decides its own
        cadence and may queue atomic table swaps (``request_swap``), which
        land at the *next* round's swap point."""
        self._adapters.append(adapter)
        return adapter

    def _tier_stores(self) -> list:
        """The distinct ``TieredTableStore``s behind the tiered cells (one
        store usually backs several shape buckets)."""
        stores, seen = [], set()
        for tc in self._tiered.values():
            if id(tc.store) not in seen:
                seen.add(id(tc.store))
                stores.append(tc.store)
        return stores

    def _policy_step(self):
        if self._tier_policy is None and not self._adapters:
            return
        self._policy_rounds += 1
        if (self._tier_policy is not None
                and self._policy_rounds % self._policy_every == 0):
            for store in self._tier_stores():
                plan = self._tier_policy.plan(store)
                self.tier_moves["plans"] += 1
                if plan.n_moves:
                    s = store.apply_moves(plan.promote, plan.demote)
                    self.tier_moves["promotions"] += s["promotions"]
                    self.tier_moves["demotions"] += s["demotions"]
                    self.tier_moves["bytes"] += s["bytes"]
        for adapter in self._adapters:
            adapter.step(self)
        self._sync_tiered()

    def _sync_tiered(self):
        """Rebind every tiered cell whose store mutated its hot tier
        (promotions, writebacks) since the last sync — the incremental
        analogue of ``_rebind_tiered``, same shapes, zero recompiles."""
        for shape, tc in list(self._tiered.items()):
            if self._hot_seen.get(shape) != tc.store.hot_version:
                self._tiered[shape] = self._rebind_hot(tc)
                self._hot_seen[shape] = tc.store.hot_version

    def _rebind_hot(self, tc: TieredCell) -> TieredCell:
        """Re-``device_put`` the store's current hot tier through the
        compiled shardings — ``_rebind_tiered`` minus the refresh (the store
        already mutated itself shape-preservingly)."""
        reg = tc.reg
        hot_i = len(reg.bound) - 1          # (params, state, buffers, hot)
        self._check_swap_layout(reg.celldef.bound[hot_i], tc.store.hot,
                                "hot-tier")
        hot = jax.device_put(tc.store.hot, reg.cell.in_shardings[hot_i])
        celldef = reg.celldef._replace(
            bound=reg.celldef.bound[:hot_i] + (tc.store.hot,))
        reg = reg._replace(celldef=celldef,
                           bound=reg.bound[:hot_i] + (hot,))
        return TieredCell(reg, tc.store, tc.offsets)

    def writeback_embeddings(self, ids, vectors) -> dict:
        """Flow training-time embedding updates (global feature ids →
        full-precision vectors) into every registered tiered store:
        re-quantized under each feature's current width, mirror written
        first (no update can be lost to a concurrent demotion — see
        ``TieredTableStore.writeback``), hot copies patched and rebound
        without a recompile. Call between scheduling rounds."""
        out = {"written": 0, "bytes": 0}
        for store in self._tier_stores():
            s = store.writeback(ids, vectors)
            out["written"] += s["written"]
            out["bytes"] += s["bytes"]
        self._sync_tiered()
        return out

    # -- request lifecycle: submit / poll / drain ---------------------------

    def _timed_call(self, reg: RegisteredCell, *request):
        t0 = self._clock()
        out = reg.cell.compiled(*reg.bound, *request)
        # deliberate timing barrier: wall-clock per call is the product here
        jax.block_until_ready(out)  # staticcheck: ignore[RL403]
        return out, (self._clock() - t0) * 1e3

    def submit(self, ids, *, kind: str = "score",
               deadline_ms: float | None = None, now: float | None = None,
               overlap: bool = True, tenant: str = "default",
               priority: int = 0) -> int | None:
        """Admit an (n, F) scoring request into the queue -> ticket, or None
        when the admission policy sheds it (queue full, load watermark, or
        tenant queue-share quota; all counted per kind and tenant).

        ``kind`` routes the request to a lane: ``"score"`` (packed cells) or
        ``"tiered"`` (hot/cold store cells, where ``overlap`` controls the
        one-chunk-ahead cold-fill staging) — decode requests go through
        ``submit_decode``. ``tenant``/``priority`` place the request in the
        multi-tenant scheduling lanes (priority 0 is most urgent; dispatch is
        EDF within a lane). ``now`` overrides the arrival timestamp for
        open-loop replay; ``deadline_ms`` is relative to it — requests still
        queued past their deadline are shed at drain."""
        if kind not in ("score", "tiered"):
            raise ValueError(
                f"unroutable request kind {kind!r} (use 'score' or 'tiered'; "
                f"LM generation goes through submit_decode)")
        ids = np.asarray(ids, np.int32)
        req = self.queue.submit(
            kind, ids, ids.shape[0],
            now=self._clock() if now is None else now,
            deadline_ms=deadline_ms,
            meta={"overlap": overlap} if kind == "tiered" else None,
            tenant=tenant, priority=priority)
        if req is None:
            self.rstats.record_shed(kind, tenant=tenant)
            return None
        self._requests[req.ticket] = req
        return req.ticket

    def submit_decode(self, prompt, max_new: int, *, arch: str | None = None,
                      deadline_ms: float | None = None,
                      now: float | None = None, tenant: str = "default",
                      priority: int = 0) -> int | None:
        """Admit an LM generation request (prompt replay + ``max_new`` greedy
        tokens) into the continuous-batching decode lane -> ticket, or None
        when shed. Requires a registered ``lm_decode_slotted_cell``; the
        sequence joins the running decode batch when a KV-cache slot frees
        up, without recompiling or restarting the batch."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        session = self.scheduler._pick_session(arch)
        if prompt.shape[0] + int(max_new) > session.max_len:
            raise ValueError(
                f"sequence of {prompt.shape[0]}+{int(max_new)} tokens exceeds "
                f"the cell's max_len={session.max_len}")
        req = self.queue.submit(
            "decode", (prompt, int(max_new), arch), 1,
            now=self._clock() if now is None else now,
            deadline_ms=deadline_ms, tenant=tenant, priority=priority)
        if req is None:
            self.rstats.record_shed("decode", tenant=tenant)
            return None
        self._requests[req.ticket] = req
        return req.ticket

    def poll(self, ticket: int):
        """The completed result for ``ticket`` — scored requests return the
        (n,) logits, decode requests the generated tokens — or None while the
        request is still queued/in flight. Raises ``RuntimeError`` on a shed
        ticket and ``RequestFailedError`` on a ticket whose dispatch raised.

        A finished ticket (done, shed or failed) is consumed by its poll
        (its record is dropped so a long-running process doesn't accumulate
        per-request state); polling it again raises KeyError."""
        req = self._requests[ticket]
        if req.status == SHED:
            del self._requests[ticket]
            raise RuntimeError(
                f"request {ticket} was shed (deadline passed while queued)")
        if req.status == FAILED:
            del self._requests[ticket]
            raise RequestFailedError(
                f"request {ticket} failed in dispatch: {req.error}")
        if req.status != DONE:
            return None
        del self._requests[ticket]
        return req.result

    def try_poll(self, ticket: int) -> dict:
        """Non-raising poll for harness code (the socket server): always
        returns ``{"status": ...}`` — ``pending`` (ticket still in flight),
        ``done`` (+ ``result``), ``shed``, ``failed`` (+ ``error``), or
        ``unknown`` (never issued, or already consumed). Terminal tickets
        are consumed exactly like ``poll``."""
        req = self._requests.get(ticket)
        if req is None:
            return {"status": "unknown"}
        if req.status == SHED:
            del self._requests[ticket]
            return {"status": "shed"}
        if req.status == FAILED:
            del self._requests[ticket]
            return {"status": "failed", "error": req.error}
        if req.status != DONE:
            return {"status": "pending"}
        del self._requests[ticket]
        return {"status": "done", "result": req.result}

    def sched_step(self, *, now: float | None = None) -> float:
        """Run one scheduling round (coalesce + dispatch each lane once; one
        decode token per active session). ``now=None`` uses the wall clock;
        an explicit ``now`` threads a virtual open-loop timeline through the
        dispatch timestamps and returns the advanced cursor.

        Queued table swaps (``request_swap``) apply here, *before* the round
        dispatches — the atomic swap point of the serving-time precision
        adaptation path: every chunk of a round reads the same table. The
        tier policy and drift adapters run right after the swap point
        (``_policy_step``), so tier moves are likewise never observed
        mid-round."""
        self._apply_swaps()
        self._policy_step()
        return self.scheduler.step(now=now)

    def drain(self, *, now: float | None = None) -> float:
        """Scheduling rounds until the queue is empty and every decode
        session is idle. Returns the final clock cursor."""
        cursor = now
        while self.scheduler.busy:
            cursor = self.sched_step(now=cursor)
        return cursor if cursor is not None else self._clock()

    # -- synchronous wrappers (submit + drain + poll) -----------------------

    def score(self, ids, *, return_logits: bool = False) -> np.ndarray:
        """Score an (n, F) id batch; any n — the scheduler packs it onto the
        registered cell shapes. Returns probabilities (or raw logits).

        Thin synchronous wrapper over the lifecycle: a lone request packs
        onto exactly the chunks the per-request planner would choose, so
        results are bit-identical to pre-lifecycle engines."""
        ticket = self.submit(ids)
        if ticket is None:
            raise RuntimeError("request shed: admission queue full")
        self.drain()
        out = self.poll(ticket)
        return out if return_logits else _sigmoid(out)

    def score_tiered(self, ids, *, overlap: bool = True,
                     return_logits: bool = False) -> np.ndarray:
        """Score an (n, F) id batch through the tiered hot/cold store.

        Hot rows are gathered device-locally inside the compiled cell; each
        chunk's cold-row fill (packed words, host-gathered) is
        ``device_put`` **one chunk ahead** while the previous chunk's cell is
        still computing, so the cold transfer hides under compute.
        ``overlap=False`` stages each fill synchronously right before its
        dispatch — the reference timing in ``BENCH_prefetch.json``. Results
        are identical either way (the pipeline only moves bytes earlier)."""
        ticket = self.submit(ids, kind="tiered", overlap=overlap)
        if ticket is None:
            raise RuntimeError("request shed: admission queue full")
        self.drain()
        out = self.poll(ticket)
        return out if return_logits else _sigmoid(out)

    def tier_counters(self) -> dict:
        """Per-bucket ``TieredTableStore.counters()`` (stores may be shared
        across buckets, in which case the numbers repeat)."""
        return {name: tc.store.counters()
                for name, tc in sorted(self._tiered.items())}

    def retrieve(self, user_ids, cand_ids, *, arch: str | None = None):
        """Top-k retrieval of one user against an arbitrary-size candidate
        corpus. Oversized corpora are chunked onto the compiled candidate
        capacity and the per-chunk top-ks merged; padded candidates are
        masked to -inf inside the cell. Returns (scores, indices) sorted."""
        reg = self._pick(self._retrieve, arch, "retrieval")
        cap = reg.celldef.batch
        top_k = reg.celldef.meta["top_k"]
        user = jax.device_put(np.asarray(user_ids, np.int32),
                              reg.cell.in_shardings[len(reg.bound)])
        cand_ids = np.asarray(cand_ids, np.int32)
        all_scores, all_idx = [], []
        for start in range(0, cand_ids.shape[0], cap):
            part = cand_ids[start:start + cap]
            padded, mask = RequestBatcher.pad(part, cap)
            c = jax.device_put(padded,
                               reg.cell.in_shardings[len(reg.bound) + 1])
            m = jax.device_put(mask,
                               reg.cell.in_shardings[len(reg.bound) + 2])
            (scores, idx), total_ms = self._timed_call(reg, user, c, m)
            self.stats.record(reg.celldef.name, total_ms)
            keep = min(top_k, part.shape[0])
            all_scores.append(np.asarray(scores)[:keep])
            all_idx.append(np.asarray(idx)[:keep] + start)
        scores = np.concatenate(all_scores)
        idx = np.concatenate(all_idx)
        order = np.argsort(-scores)[:top_k]
        return scores[order], idx[order]

    def decode(self, tokens, caches=None, *, arch: str | None = None):
        """One decode step for a (b, 1) token batch, b ≤ the cell's capacity.
        ``caches=None`` starts fresh KV caches (int8 + running-absmax scales
        when the cell was registered with ``kv_int8``, the default). Returns
        (logits (b, V), new_caches) — feed ``new_caches`` back in."""
        reg = self._pick(self._decode, arch, "decode")
        cap = reg.celldef.batch
        tokens = np.asarray(tokens, np.int32)
        b = tokens.shape[0]
        padded, _ = RequestBatcher.pad(tokens, cap)
        toks = jax.device_put(padded,
                              reg.cell.in_shardings[len(reg.bound)])
        if caches is None:
            caches = self.fresh_caches(arch=reg.celldef.arch)
        (logits, new_caches), total_ms = self._timed_call(reg, toks, caches)
        self.stats.record(reg.celldef.name, total_ms)
        return np.asarray(logits)[:b], new_caches

    def fresh_caches(self, *, arch: str | None = None):
        """Fresh KV caches for a decode cell — built by the model's own cache
        constructor (bound at cell build time, so layout and scale seeding
        stay the model's single source of truth), committed to the compiled
        cache shardings."""
        reg = self._pick(self._decode, arch, "decode")
        caches = reg.celldef.make_request_state()
        return jax.device_put(caches,
                              reg.cell.in_shardings[len(reg.bound) + 1])

    @staticmethod
    def _pick(table: dict, arch: str | None, what: str) -> RegisteredCell:
        if not table:
            raise ValueError(f"no {what} cell registered")
        if arch is not None:
            return table[arch]
        if len(table) > 1:
            raise ValueError(
                f"multiple {what} cells registered ({sorted(table)}); "
                f"pass arch=")
        return next(iter(table.values()))

    # -- introspection ------------------------------------------------------

    def registered_cells(self) -> dict:
        """Every registered cell across the four lanes, keyed by its
        ``CellKey``: {key: RegisteredCell}. The static-analysis runner
        (``repro.analysis``) walks this to get each cell's definition *and*
        its warm compiled executable (HLO text, cost analysis) without
        re-deriving registration wiring — tiered cells unwrap to their
        ``RegisteredCell``; lookup-split companions are included under their
        own keys."""
        out = {}

        def add(reg):
            if reg is None:
                return
            out[reg.cell.key] = reg
            add(reg.lookup)

        for reg in self._score.values():
            add(reg)
        for tc in self._tiered.values():
            add(tc.reg)
        for reg in self._retrieve.values():
            add(reg)
        for reg in self._decode.values():
            add(reg)
        for session in self.scheduler.sessions.values():
            add(session.reg)
        return out

    @property
    def compile_count(self) -> int:
        return self.cache.compiles

    @property
    def registered_shapes(self) -> dict:
        """The score-path cell-shape registry: shape name → row capacity."""
        return self._score_batcher.shapes

    def counters(self) -> dict:
        """Cell-cache counters plus per-cell occupancy (valid rows / padded
        rows over every dispatch — the coalescing win), the admission
        queue's depth/shed counters (per kind and per tenant), and goodput —
        completed-request counts — split by lane and by tenant."""
        out = dict(self.cache.counters())
        out["occupancy"] = self.stats.occupancy()
        out["queue"] = self.queue.counters()
        out["goodput"] = {"by_lane": self.rstats.lane_counts(),
                          "by_tenant": self.rstats.tenant_counts()}
        out["tier_moves"] = dict(self.tier_moves)
        return out

    def summary(self, *, skip_warmup: int = 0) -> dict:
        """Per-cell latency percentiles (Figure-5 lookup/compute split) with
        per-cell ``occupancy`` merged in where dispatches recorded it."""
        return self.stats.summary(skip_warmup=skip_warmup)

    def request_summary(self, *, skip_warmup: int = 0,
                        by: str = "kind") -> dict:
        """Per-request breakdown: end-to-end latency plus the three-way
        queue-wait / batch-assembly / compute split. ``by`` groups the
        records: ``"kind"`` (back-compat shape), ``"lane"``
        (``kind:p<priority>``) or ``"tenant"`` (with per-tenant shed/failed
        counts)."""
        summaries = {"kind": self.rstats.summary,
                     "lane": self.rstats.lane_summary,
                     "tenant": self.rstats.tenant_summary}
        return summaries[by](skip_warmup=skip_warmup)
