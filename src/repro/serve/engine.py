"""The serving engine: warm compiled cells behind score/retrieve/decode.

Request flow for ``score``:

  ids (n, F) ──plan──▶ chunks on registered shapes ──pad──▶ compiled cell
  ──unpad──▶ probs (n,)

Every executable is compiled exactly once per (arch, shape, mesh) by the
``CellCache``; bound state (packed table, MLPs, towers) is device_put with
its serving shardings at registration and reused across requests. Per-request
wall-clock is recorded per cell, with a lookup-only companion executable
timed alongside to report the paper's Figure-5 lookup-vs-compute latency
split. Timings cover executable dispatch-to-ready (host→device transfer of
the request ids is excluded, matching the Figure-5 protocol).
"""
from __future__ import annotations

import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.mesh import host_mesh
from repro.serve.batcher import RequestBatcher
from repro.serve.cache import CellCache, CompiledCell
from repro.serve.cells import (ServeCellDef, packed_lookup_cell,
                               packed_score_cell, tiered_score_cell)
from repro.serve.stats import LatencyStats


class RegisteredCell(NamedTuple):
    """A cell after registration: its definition, the warm compiled
    executable, the bound inputs committed to their shardings, and the
    optional Figure-5 lookup-split companion cell."""
    celldef: ServeCellDef
    cell: CompiledCell        # the warm executable
    bound: tuple              # bound inputs, committed to their shardings
    lookup: "RegisteredCell | None"   # Figure-5 split companion


class TieredCell(NamedTuple):
    """A tiered score cell plus the ``TieredTableStore`` that feeds it and
    the per-field id offsets used to globalize request ids for the cold
    prefetch (the cell itself re-globalizes on device)."""
    reg: RegisteredCell
    store: object             # repro.cache.TieredTableStore
    offsets: np.ndarray       # (F,) int32


def _sigmoid(x: np.ndarray) -> np.ndarray:
    return 1.0 / (1.0 + np.exp(-x))


class Engine:
    """Front-end over the cell cache + request batcher.

    One engine holds one mesh (default: the host mesh — 1×1 on a stock CPU,
    where every sharding constraint is a no-op) and one ``CellCache``; cells
    from several models can coexist, keyed by their ``arch`` identity.
    """

    def __init__(self, mesh=None, cache: CellCache | None = None):
        self.mesh = mesh if mesh is not None else host_mesh()
        self.cache = cache if cache is not None else CellCache(self.mesh)
        self.stats = LatencyStats()
        self._score: dict[str, RegisteredCell] = {}     # bucket name -> cell
        self._score_batcher = RequestBatcher()
        self._retrieve: dict[str, RegisteredCell] = {}  # arch -> cell
        self._decode: dict[str, RegisteredCell] = {}    # arch -> cell
        self._tiered: dict[str, TieredCell] = {}        # bucket name -> cell
        self._tiered_batcher = RequestBatcher()

    # -- registration -------------------------------------------------------

    def _compile(self, celldef: ServeCellDef) -> RegisteredCell:
        # the fingerprint covers config baked into the step closure (model
        # cfg, top_k, …): same-named registrations with different static
        # config must compile their own executable, not warm-hit a wrong one
        key = self.cache.key(
            celldef.arch,
            f"{celldef.shape}@{celldef.batch}#{celldef.fingerprint}")

        def build():
            input_specs = celldef.bound + celldef.request_specs
            in_pspecs = celldef.bound_pspecs + celldef.request_pspecs
            return (celldef.step_fn, input_specs, in_pspecs,
                    celldef.out_pspecs, celldef.meta)

        cell = self.cache.get_or_compile(key, build)
        n_bound = len(celldef.bound)
        bound = tuple(jax.device_put(b, s) for b, s in
                      zip(celldef.bound, cell.in_shardings[:n_bound]))
        return RegisteredCell(celldef, cell, bound, None)

    def register(self, celldef: ServeCellDef,
                 lookup_cell: ServeCellDef | None = None) -> RegisteredCell:
        """Compile (or warm-hit) a cell and route it by kind. Score cells also
        register their capacity as a batcher bucket under their shape name."""
        reg = self._compile(celldef)
        if lookup_cell is not None:
            reg = reg._replace(lookup=self._compile(lookup_cell))
        if celldef.kind == "score":
            self._score[celldef.shape] = reg
            self._score_batcher.register(celldef.shape, celldef.batch)
        elif celldef.kind == "retrieve":
            self._retrieve[celldef.arch] = reg
        elif celldef.kind == "decode":
            self._decode[celldef.arch] = reg
        else:
            raise ValueError(f"unroutable cell kind {celldef.kind!r}")
        return reg

    def register_packed_model(self, arch, model, cfg, params, state, buffers,
                              *, shapes: dict[str, int],
                              lookup_split: bool = True, dp=("data",),
                              rows_axes=("model",),
                              shard_lookup: bool = False):
        """Register one score cell per (shape name → row capacity) for a flat
        CTR model serving from a packed table, each with its lookup-split
        companion when ``lookup_split``. ``shard_lookup`` compiles the
        ``shard_map`` lookup path against the engine's mesh (the fused
        gather runs inside the partitioner — a no-op on a 1-device mesh)."""
        meta = {k: cfg.comp_cfg[k] for k in ("bits", "d", "n")}
        n_fields = len(cfg.fields)
        for shape, rows in shapes.items():
            cd = packed_score_cell(model, cfg, params, state, buffers,
                                   batch=rows, arch=arch, shape=shape,
                                   dp=dp, rows_axes=rows_axes,
                                   shard_lookup=shard_lookup)
            lc = None
            if lookup_split:
                lc = packed_lookup_cell(params["embedding"], meta,
                                        buffers["offsets"], batch=rows,
                                        n_fields=n_fields, arch=arch,
                                        shape=shape, dp=dp,
                                        rows_axes=rows_axes)
            self.register(cd, lookup_cell=lc)

    def register_tiered_model(self, arch, model, cfg, params, state, buffers,
                              store, *, shapes: dict[str, int], dp=("data",),
                              rows_axes=("model",),
                              shard_lookup: bool = False):
        """Register one **tiered** score cell per (shape name → row capacity)
        serving from a ``repro.cache.TieredTableStore``: the store's hot tier
        binds into the executable (device-local gather), cold rows ride each
        request as prefetch-staged fills (see ``score_tiered``).

        ``params`` may carry an ``"embedding"`` entry (the monolithic packed
        table) — it is dropped; the store owns the table now."""
        p = {k: v for k, v in params.items() if k != "embedding"}
        offsets = np.asarray(buffers["offsets"], np.int32)
        for shape, rows in shapes.items():
            cd = tiered_score_cell(model, cfg, p, state, buffers, store.hot,
                                   store.meta, batch=rows, arch=arch,
                                   shape=shape, dp=dp, rows_axes=rows_axes,
                                   shard_lookup=shard_lookup)
            reg = self._compile(cd)
            self._tiered[shape] = TieredCell(reg, store, offsets)
            self._tiered_batcher.register(shape, rows)

    # -- request paths ------------------------------------------------------

    def _timed_call(self, reg: RegisteredCell, *request):
        t0 = time.perf_counter()
        out = reg.cell.compiled(*reg.bound, *request)
        jax.block_until_ready(out)
        return out, (time.perf_counter() - t0) * 1e3

    def score(self, ids, *, return_logits: bool = False) -> np.ndarray:
        """Score an (n, F) id batch; any n — the batcher pads/chunks onto the
        registered cell shapes. Returns probabilities (or raw logits)."""
        ids = np.asarray(ids, np.int32)
        out = np.empty((ids.shape[0],), np.float32)
        for chunk, padded, _mask in self._score_batcher.split(ids):
            reg = self._score[chunk.bucket]
            x = jax.device_put(jnp.asarray(padded),
                               reg.cell.in_shardings[len(reg.bound)])
            y, total_ms = self._timed_call(reg, x)
            lookup_ms = None
            if reg.lookup is not None:
                _, lookup_ms = self._timed_call(reg.lookup, x)
            self.stats.record(reg.celldef.name, total_ms, lookup_ms)
            out[chunk.start:chunk.start + chunk.n_valid] = \
                np.asarray(y)[:chunk.n_valid]
        return out if return_logits else _sigmoid(out)

    def score_tiered(self, ids, *, overlap: bool = True,
                     return_logits: bool = False) -> np.ndarray:
        """Score an (n, F) id batch through the tiered hot/cold store.

        Hot rows are gathered device-locally inside the compiled cell; each
        chunk's cold-row fill (packed words, host-gathered) is
        ``device_put`` **one chunk ahead** while the previous chunk's cell is
        still computing, so the cold transfer hides under compute.
        ``overlap=False`` stages each fill synchronously right before its
        dispatch — the reference timing in ``BENCH_prefetch.json``. Results
        are identical either way (the pipeline only moves bytes earlier)."""
        ids = np.asarray(ids, np.int32)
        out = np.empty((ids.shape[0],), np.float32)
        chunks = list(self._tiered_batcher.split(ids))

        def stage(k):
            chunk, padded, mask = chunks[k]
            tc = self._tiered[chunk.bucket]
            # mask out batcher padding: pad rows fetch no cold bytes and
            # stay out of the hit/byte counters (their outputs are dropped
            # at unpad, so a zero fill is as good as a real one)
            fill = tc.store.prefetch_cold(padded + tc.offsets[None, :],
                                          valid=mask)
            x = jax.device_put(jnp.asarray(padded),
                               tc.reg.cell.in_shardings[len(tc.reg.bound)])
            return tc, x, fill

        staged = stage(0) if overlap else None
        for k, (chunk, _padded, _mask) in enumerate(chunks):
            tc, x, fill = staged if overlap else stage(k)
            t0 = time.perf_counter()
            cold = tc.store.cold_part(fill).reshape(
                x.shape[0], x.shape[1], -1)                    # (B, F, d)
            cold = jax.device_put(
                cold, tc.reg.cell.in_shardings[len(tc.reg.bound) + 1])
            y = tc.reg.cell.compiled(*tc.reg.bound, x, cold)   # async dispatch
            if overlap and k + 1 < len(chunks):
                staged = stage(k + 1)   # host gather + H2D under y's compute
            jax.block_until_ready(y)
            self.stats.record(tc.reg.celldef.name,
                              (time.perf_counter() - t0) * 1e3)
            out[chunk.start:chunk.start + chunk.n_valid] = \
                np.asarray(y)[:chunk.n_valid]
        return out if return_logits else _sigmoid(out)

    def tier_counters(self) -> dict:
        """Per-bucket ``TieredTableStore.counters()`` (stores may be shared
        across buckets, in which case the numbers repeat)."""
        return {name: tc.store.counters()
                for name, tc in sorted(self._tiered.items())}

    def retrieve(self, user_ids, cand_ids, *, arch: str | None = None):
        """Top-k retrieval of one user against an arbitrary-size candidate
        corpus. Oversized corpora are chunked onto the compiled candidate
        capacity and the per-chunk top-ks merged; padded candidates are
        masked to -inf inside the cell. Returns (scores, indices) sorted."""
        reg = self._pick(self._retrieve, arch, "retrieval")
        cap = reg.celldef.batch
        top_k = reg.celldef.meta["top_k"]
        user = jax.device_put(jnp.asarray(np.asarray(user_ids, np.int32)),
                              reg.cell.in_shardings[len(reg.bound)])
        cand_ids = np.asarray(cand_ids, np.int32)
        all_scores, all_idx = [], []
        for start in range(0, cand_ids.shape[0], cap):
            part = cand_ids[start:start + cap]
            padded, mask = RequestBatcher.pad(part, cap)
            c = jax.device_put(jnp.asarray(padded),
                               reg.cell.in_shardings[len(reg.bound) + 1])
            m = jax.device_put(jnp.asarray(mask),
                               reg.cell.in_shardings[len(reg.bound) + 2])
            (scores, idx), total_ms = self._timed_call(reg, user, c, m)
            self.stats.record(reg.celldef.name, total_ms)
            keep = min(top_k, part.shape[0])
            all_scores.append(np.asarray(scores)[:keep])
            all_idx.append(np.asarray(idx)[:keep] + start)
        scores = np.concatenate(all_scores)
        idx = np.concatenate(all_idx)
        order = np.argsort(-scores)[:top_k]
        return scores[order], idx[order]

    def decode(self, tokens, caches=None, *, arch: str | None = None):
        """One decode step for a (b, 1) token batch, b ≤ the cell's capacity.
        ``caches=None`` starts fresh KV caches (int8 + running-absmax scales
        when the cell was registered with ``kv_int8``, the default). Returns
        (logits (b, V), new_caches) — feed ``new_caches`` back in."""
        reg = self._pick(self._decode, arch, "decode")
        cap = reg.celldef.batch
        tokens = np.asarray(tokens, np.int32)
        b = tokens.shape[0]
        padded, _ = RequestBatcher.pad(tokens, cap)
        toks = jax.device_put(jnp.asarray(padded),
                              reg.cell.in_shardings[len(reg.bound)])
        if caches is None:
            caches = self.fresh_caches(arch=reg.celldef.arch)
        (logits, new_caches), total_ms = self._timed_call(reg, toks, caches)
        self.stats.record(reg.celldef.name, total_ms)
        return np.asarray(logits)[:b], new_caches

    def fresh_caches(self, *, arch: str | None = None):
        """Fresh KV caches for a decode cell — built by the model's own cache
        constructor (bound at cell build time, so layout and scale seeding
        stay the model's single source of truth), committed to the compiled
        cache shardings."""
        reg = self._pick(self._decode, arch, "decode")
        caches = reg.celldef.make_request_state()
        return jax.device_put(caches,
                              reg.cell.in_shardings[len(reg.bound) + 1])

    @staticmethod
    def _pick(table: dict, arch: str | None, what: str) -> RegisteredCell:
        if not table:
            raise ValueError(f"no {what} cell registered")
        if arch is not None:
            return table[arch]
        if len(table) > 1:
            raise ValueError(
                f"multiple {what} cells registered ({sorted(table)}); "
                f"pass arch=")
        return next(iter(table.values()))

    # -- introspection ------------------------------------------------------

    @property
    def compile_count(self) -> int:
        return self.cache.compiles

    @property
    def registered_shapes(self) -> dict:
        """The score-path cell-shape registry: shape name → row capacity."""
        return self._score_batcher.shapes

    def counters(self) -> dict:
        return self.cache.counters()

    def summary(self, *, skip_warmup: int = 0) -> dict:
        return self.stats.summary(skip_warmup=skip_warmup)
