"""Request batching onto registered cell shapes.

Serving executables are compiled at a small set of fixed batch shapes (the
cell-shape registry: e.g. ``serve_p99`` = 512 rows, ``serve_bulk`` = 262144).
An incoming request of arbitrary size is *planned* onto those shapes:

  - a request that fits rides the smallest bucket that holds it (a 300-row
    request pads to the 512-row ``serve_p99`` cell);
  - an oversized request (a 100k bulk job against a 4k bulk cell) is chunked
    into full largest-bucket chunks plus a remainder on the smallest bucket
    that holds it.

Padding appends rows of id 0 (always a valid row — lookups stay in-bounds)
and carries a validity mask; ``unpad`` drops the padded tail. Padded rows are
wasted compute, never wrong answers: serving runs the models in eval mode,
where every row is computed independently (BatchNorm reads running stats).

``pack`` is the coalescing variant (the scheduler's planner): many pending
requests are packed as one concatenated super-request onto the same buckets,
and each ``Chunk`` carries per-request ``Span``s so one padded cell
invocation serves many callers and outputs scatter back per requester.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class Span(NamedTuple):
    """One requester's slice of a coalesced chunk: rows
    ``[src_start, src_start + n)`` of request ``req`` land at rows
    ``[dst_start, dst_start + n)`` of the padded chunk (and its outputs
    scatter back the same way)."""
    req: int         # requester index (position in the packed sequence)
    src_start: int   # offset within the request
    dst_start: int   # offset within the chunk
    n: int           # rows carried


class Chunk(NamedTuple):
    """One slice of a planned request: which registered bucket serves rows
    ``[start, start + n_valid)`` of the original request, padded up to the
    bucket's compiled capacity ``rows``.

    ``spans`` is set by the coalescing planner (``pack``): the per-request
    row spans sharing this chunk, so one padded cell invocation serves many
    requesters and ``unpad`` scatters results back per requester. A
    single-request plan leaves it None."""
    bucket: str      # registered shape name
    rows: int        # bucket capacity (the compiled leading dim)
    start: int       # offset of this chunk in the request (packed order)
    n_valid: int     # real rows carried (<= rows)
    spans: tuple = None   # per-request Spans (coalesced plans only)


class RequestBatcher:
    """Shape registry + planning + pad/unpad."""

    def __init__(self, shapes: dict[str, int] | None = None):
        self._shapes: dict[str, int] = {}
        for name, rows in (shapes or {}).items():
            self.register(name, rows)

    def register(self, name: str, rows: int):
        if rows <= 0:
            raise ValueError(f"bucket {name!r}: rows must be positive")
        self._shapes[name] = int(rows)

    @property
    def shapes(self) -> dict[str, int]:
        return dict(self._shapes)

    def _sorted(self):
        return sorted(self._shapes.items(), key=lambda kv: (kv[1], kv[0]))

    def smallest_fitting(self, n: int) -> tuple[str, int] | None:
        for name, rows in self._sorted():
            if rows >= n:
                return name, rows
        return None

    def plan(self, n: int) -> list[Chunk]:
        """Cover an ``n``-row request with registered buckets."""
        if not self._shapes:
            raise ValueError("no cell shapes registered")
        if n <= 0:
            raise ValueError(f"empty request (n={n})")
        max_name, max_rows = max(self._sorted(), key=lambda kv: kv[1])
        chunks, start = [], 0
        while n - start > max_rows:
            chunks.append(Chunk(max_name, max_rows, start, max_rows))
            start += max_rows
        rem = n - start
        name, rows = self.smallest_fitting(rem)
        chunks.append(Chunk(name, rows, start, rem))
        return chunks

    def pack(self, sizes) -> list[Chunk]:
        """Coalesce many requests into cell-shaped chunks.

        ``sizes`` is the pending requests' row counts in dispatch (FIFO)
        order. The packed plan covers their *concatenation* with registered
        buckets — identical bucket choices to ``plan(sum(sizes))``, so a
        single request packs exactly like it plans — and each chunk carries
        the ``Span``s mapping its rows back to (request, offset). Every
        request's rows appear exactly once, in order, across the spans.
        """
        sizes = [int(n) for n in sizes]
        for i, n in enumerate(sizes):
            if n <= 0:
                raise ValueError(f"empty request at position {i} (n={n})")
        chunks = self.plan(sum(sizes))
        # walk the requests across the chunk boundaries
        out, req, consumed = [], 0, 0
        for chunk in chunks:
            spans, filled = [], 0
            while filled < chunk.n_valid:
                take = min(sizes[req] - consumed, chunk.n_valid - filled)
                spans.append(Span(req, consumed, filled, take))
                filled += take
                consumed += take
                if consumed == sizes[req]:
                    req, consumed = req + 1, 0
            out.append(chunk._replace(spans=tuple(spans)))
        return out

    @staticmethod
    def gather(arrs, chunk: Chunk) -> np.ndarray:
        """Assemble a coalesced chunk's valid rows from the per-request
        arrays (``arrs[span.req]``), in span order."""
        parts = [np.asarray(arrs[s.req])[s.src_start:s.src_start + s.n]
                 for s in chunk.spans]
        return parts[0] if len(parts) == 1 else np.concatenate(parts)

    @staticmethod
    def scatter(out, chunk: Chunk, sinks):
        """Scatter a cell output's valid rows back per requester:
        ``sinks[span.req][span.src_start : +span.n] = out[span.dst_start : +span.n]``."""
        for s in chunk.spans:
            sinks[s.req][s.src_start:s.src_start + s.n] = \
                np.asarray(out)[s.dst_start:s.dst_start + s.n]

    @staticmethod
    def pad(arr: np.ndarray, rows: int) -> tuple[np.ndarray, np.ndarray]:
        """Pad axis 0 to ``rows`` with zeros; returns (padded, validity mask)."""
        arr = np.asarray(arr)
        n = arr.shape[0]
        if n > rows:
            raise ValueError(f"chunk of {n} rows exceeds bucket of {rows}")
        mask = np.zeros((rows,), bool)
        mask[:n] = True
        if n == rows:
            return arr, mask
        pad_width = [(0, rows - n)] + [(0, 0)] * (arr.ndim - 1)
        return np.pad(arr, pad_width), mask

    @staticmethod
    def unpad(out, n_valid: int):
        """Drop the padded tail of a cell output (leading axis)."""
        return out[:n_valid]

    def split(self, arr: np.ndarray):
        """Plan + pad a whole request: yields (chunk, padded, mask)."""
        arr = np.asarray(arr)
        for chunk in self.plan(arr.shape[0]):
            padded, mask = self.pad(
                arr[chunk.start:chunk.start + chunk.n_valid], chunk.rows)
            yield chunk, padded, mask
