"""Request batching onto registered cell shapes.

Serving executables are compiled at a small set of fixed batch shapes (the
cell-shape registry: e.g. ``serve_p99`` = 512 rows, ``serve_bulk`` = 262144).
An incoming request of arbitrary size is *planned* onto those shapes:

  - a request that fits rides the smallest bucket that holds it (a 300-row
    request pads to the 512-row ``serve_p99`` cell);
  - an oversized request (a 100k bulk job against a 4k bulk cell) is chunked
    into full largest-bucket chunks plus a remainder on the smallest bucket
    that holds it.

Padding appends rows of id 0 (always a valid row — lookups stay in-bounds)
and carries a validity mask; ``unpad`` drops the padded tail. Padded rows are
wasted compute, never wrong answers: serving runs the models in eval mode,
where every row is computed independently (BatchNorm reads running stats).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class Chunk(NamedTuple):
    """One slice of a planned request: which registered bucket serves rows
    ``[start, start + n_valid)`` of the original request, padded up to the
    bucket's compiled capacity ``rows``."""
    bucket: str      # registered shape name
    rows: int        # bucket capacity (the compiled leading dim)
    start: int       # offset of this chunk in the request
    n_valid: int     # real rows carried (<= rows)


class RequestBatcher:
    """Shape registry + planning + pad/unpad."""

    def __init__(self, shapes: dict[str, int] | None = None):
        self._shapes: dict[str, int] = {}
        for name, rows in (shapes or {}).items():
            self.register(name, rows)

    def register(self, name: str, rows: int):
        if rows <= 0:
            raise ValueError(f"bucket {name!r}: rows must be positive")
        self._shapes[name] = int(rows)

    @property
    def shapes(self) -> dict[str, int]:
        return dict(self._shapes)

    def _sorted(self):
        return sorted(self._shapes.items(), key=lambda kv: (kv[1], kv[0]))

    def smallest_fitting(self, n: int) -> tuple[str, int] | None:
        for name, rows in self._sorted():
            if rows >= n:
                return name, rows
        return None

    def plan(self, n: int) -> list[Chunk]:
        """Cover an ``n``-row request with registered buckets."""
        if not self._shapes:
            raise ValueError("no cell shapes registered")
        if n <= 0:
            raise ValueError(f"empty request (n={n})")
        max_name, max_rows = max(self._sorted(), key=lambda kv: kv[1])
        chunks, start = [], 0
        while n - start > max_rows:
            chunks.append(Chunk(max_name, max_rows, start, max_rows))
            start += max_rows
        rem = n - start
        name, rows = self.smallest_fitting(rem)
        chunks.append(Chunk(name, rows, start, rem))
        return chunks

    @staticmethod
    def pad(arr: np.ndarray, rows: int) -> tuple[np.ndarray, np.ndarray]:
        """Pad axis 0 to ``rows`` with zeros; returns (padded, validity mask)."""
        arr = np.asarray(arr)
        n = arr.shape[0]
        if n > rows:
            raise ValueError(f"chunk of {n} rows exceeds bucket of {rows}")
        mask = np.zeros((rows,), bool)
        mask[:n] = True
        if n == rows:
            return arr, mask
        pad_width = [(0, rows - n)] + [(0, 0)] * (arr.ndim - 1)
        return np.pad(arr, pad_width), mask

    @staticmethod
    def unpad(out, n_valid: int):
        """Drop the padded tail of a cell output (leading axis)."""
        return out[:n_valid]

    def split(self, arr: np.ndarray):
        """Plan + pad a whole request: yields (chunk, padded, mask)."""
        arr = np.asarray(arr)
        for chunk in self.plan(arr.shape[0]):
            padded, mask = self.pad(
                arr[chunk.start:chunk.start + chunk.n_valid], chunk.rows)
            yield chunk, padded, mask
