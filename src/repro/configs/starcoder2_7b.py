"""starcoder2-7b [arXiv:2402.19173; hf] — dense, GQA kv=4, RoPE.

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152. 36 heads don't divide
the 16-wide model axis, so attention runs sequence-sharded
(cfg.seq_shard_attn; DESIGN.md §5).
"""
from repro.configs.base import ArchSpec, LM_SHAPES, register_arch
from repro.models.lm import LMConfig


def make_config(reduced: bool = False) -> LMConfig:
    if reduced:
        return LMConfig(name="starcoder2-7b-smoke", n_layers=2, d_model=96,
                        n_heads=6, n_kv_heads=2, head_dim=16, d_ff=192,
                        vocab=512, seq_shard_attn=False)
    return LMConfig(
        name="starcoder2-7b", n_layers=32, d_model=4608, n_heads=36,
        n_kv_heads=4, head_dim=128, d_ff=18432, vocab=49152,
        dtype="bfloat16", attn_chunk_q=512, attn_chunk_kv=1024,
        ce_chunk=512, seq_shard_attn=True,
    )


ARCH = register_arch(ArchSpec(
    arch_id="starcoder2-7b", family="lm", make_config=make_config,
    shapes=LM_SHAPES, citation="arXiv:2402.19173; hf",
    notes="36 q-heads % 16 != 0 -> sequence-sharded attention",
))
