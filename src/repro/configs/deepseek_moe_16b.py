"""deepseek-moe-16b [arXiv:2401.06066; hf] — fine-grained MoE.

28L d_model=2048 16H (kv=16) per-expert d_ff=1408 vocab=102400,
2 shared + 64 routed experts, top-6. Expert-parallel on the mesh
(64 experts / 16 chips = 4 per chip).
"""
from repro.configs.base import ArchSpec, LM_SHAPES, register_arch
from repro.models.lm import LMConfig
from repro.nn.moe import MoEConfig


def make_config(reduced: bool = False) -> LMConfig:
    if reduced:
        return LMConfig(name="deepseek-moe-16b-smoke", n_layers=2, d_model=64,
                        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=512,
                        moe=MoEConfig(n_experts=8, top_k=2, d_model=64, d_ff=32,
                                      n_shared=2))
    return LMConfig(
        name="deepseek-moe-16b", n_layers=28, d_model=2048, n_heads=16,
        n_kv_heads=16, head_dim=128, d_ff=1408, vocab=102400,
        moe=MoEConfig(n_experts=64, top_k=6, d_model=2048, d_ff=1408,
                      n_shared=2, capacity_factor=1.25),
        dtype="bfloat16", attn_chunk_q=512, attn_chunk_kv=1024, ce_chunk=512,
    )


ARCH = register_arch(ArchSpec(
    arch_id="deepseek-moe-16b", family="lm", make_config=make_config,
    shapes=LM_SHAPES, citation="arXiv:2401.06066; hf",
    notes="2 shared + 64 routed top-6 fine-grained; EP over model axis",
))
