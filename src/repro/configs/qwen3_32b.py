"""qwen3-32b [hf:Qwen/Qwen3-8B family config; hf] — dense, GQA kv=8, qk_norm.

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936.
"""
from repro.configs.base import ArchSpec, LM_SHAPES, register_arch
from repro.models.lm import LMConfig


def make_config(reduced: bool = False) -> LMConfig:
    if reduced:
        return LMConfig(name="qwen3-32b-smoke", n_layers=2, d_model=64,
                        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                        vocab=512, qk_norm=True)
    return LMConfig(
        name="qwen3-32b", n_layers=64, d_model=5120, n_heads=64,
        n_kv_heads=8, head_dim=128, d_ff=25600, vocab=151936, qk_norm=True,
        dtype="bfloat16", attn_chunk_q=512, attn_chunk_kv=1024, ce_chunk=256,
    )


ARCH = register_arch(ArchSpec(
    arch_id="qwen3-32b", family="lm", make_config=make_config,
    shapes=LM_SHAPES, citation="hf:Qwen/Qwen3-8B; hf",
    notes="qk_norm per-head RMSNorm",
))
