"""Architecture registry: one module per assigned arch (``--arch <id>``).

Each module defines ``ARCH`` (an ArchSpec). ``get_arch(arch_id)`` resolves it;
``ALL_ARCHS`` lists every id. Exact configs come from public literature — the
citation is recorded on each spec.
"""
from repro.configs.base import ArchSpec, get_arch, ALL_ARCHS, register_arch

# import side effects populate the registry
from repro.configs import (starcoder2_7b, qwen3_32b, internlm2_1_8b,  # noqa: F401
                           deepseek_moe_16b, grok_1_314b, gin_tu,
                           two_tower_retrieval, bst, sasrec, wide_deep,
                           dlrm_criteo)

__all__ = ["ArchSpec", "get_arch", "ALL_ARCHS", "register_arch"]
