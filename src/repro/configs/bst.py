"""bst [arXiv:1905.06874; paper] — Behavior Sequence Transformer (Alibaba).

embed_dim=32, seq_len=20, 1 transformer block, 8 heads, MLP 1024-512-256.
Item vocab 16,777,216 + 4 context fields × 65,536.
"""
from repro.configs.base import ArchSpec, RECSYS_SHAPES, register_arch
from repro.embeddings.table import FieldSpec
from repro.models.bst import BSTConfig

ITEM_VOCAB = 16_777_216
CTX_VOCAB = 65_536


def make_config(reduced: bool = False) -> BSTConfig:
    if reduced:
        return BSTConfig(item_vocab=2_000,
                         ctx_fields=(FieldSpec("c0", 100),),
                         d_embed=16, seq_len=8, mlp_hidden=(32, 16),
                         compressor="mpe_search")
    return BSTConfig(
        item_vocab=ITEM_VOCAB,
        ctx_fields=tuple(FieldSpec(f"c{i}", CTX_VOCAB) for i in range(4)),
        d_embed=32, seq_len=20, n_blocks=1, n_heads=8,
        mlp_hidden=(1024, 512, 256), compressor="mpe_search",
    )


ARCH = register_arch(ArchSpec(
    arch_id="bst", family="recsys", make_config=make_config,
    shapes=RECSYS_SHAPES, citation="arXiv:1905.06874; paper",
))
