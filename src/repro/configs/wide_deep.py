"""wide-deep [arXiv:1606.07792; paper] — 40 sparse fields, d=32, MLP 1024-512-256.

Tables: 40 fields × 1,048,576 rows = 41.9M rows (Zipf-popular). Training runs
the MPE search phase (the paper's system); serving uses the bit-packed
mixed-precision table (§4).
"""
from repro.configs.base import ArchSpec, RECSYS_SHAPES, register_arch
from repro.embeddings.table import FieldSpec
from repro.models.wide_deep import WideDeepConfig

N_FIELDS = 40
FIELD_VOCAB = 1_048_576


def fields(reduced: bool = False):
    v = 1_000 if reduced else FIELD_VOCAB
    n = 6 if reduced else N_FIELDS
    return tuple(FieldSpec(f"f{i}", v) for i in range(n))


def make_config(reduced: bool = False) -> WideDeepConfig:
    return WideDeepConfig(
        fields=fields(reduced),
        d_embed=32,
        mlp_hidden=(64, 32) if reduced else (1024, 512, 256),
        compressor="mpe_search",
    )


ARCH = register_arch(ArchSpec(
    arch_id="wide-deep", family="recsys", make_config=make_config,
    shapes=RECSYS_SHAPES, citation="arXiv:1606.07792; paper",
))
