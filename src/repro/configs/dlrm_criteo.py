"""The paper's own benchmark configuration: DLRM backbones at Criteo scale.

39 fields / 33,762,577 features (paper Table 2), d=16, MLP 1024-512-256,
candidate widths {0..6}, group size 128 — §5.1.5 exactly. The backbone is
selectable (dnn | dcn | deepfm | ipnn); the dry-run cell uses dnn.
"""
from repro.configs.base import ArchSpec, register_arch
from repro.embeddings.table import FieldSpec
from repro.models.dlrm import DLRMConfig

# Criteo has 26 categorical + 13 discretized-numeric fields = 39; vocab sizes
# are heavy-tailed — approximated with a few large id fields + many small ones.
_CRITEO_VOCABS = ([8_388_608, 8_388_608, 4_194_304, 4_194_304, 2_097_152,
                   2_097_152, 1_048_576, 1_048_576] + [262_144] * 8 +
                  [65_536] * 10 + [1_024] * 13)
assert len(_CRITEO_VOCABS) == 39
assert abs(sum(_CRITEO_VOCABS) - 33_762_577) / 33_762_577 < 0.05  # ±5% of Table 2


def make_config(reduced: bool = False, backbone: str = "dnn") -> DLRMConfig:
    if reduced:
        fields = tuple(FieldSpec(f"f{i}", 1_000) for i in range(8))
        return DLRMConfig(fields=fields, d_embed=16, mlp_hidden=(32, 16),
                          backbone=backbone, compressor="mpe_search")
    fields = tuple(FieldSpec(f"f{i}", v) for i, v in enumerate(_CRITEO_VOCABS))
    return DLRMConfig(fields=fields, d_embed=16, mlp_hidden=(1024, 512, 256),
                      backbone=backbone, compressor="mpe_search")


ARCH = register_arch(ArchSpec(
    arch_id="dlrm-criteo", family="recsys", make_config=make_config,
    shapes=("train_batch", "serve_p99", "serve_bulk", "retrieval_cand"),
    citation="paper §5.1 (Criteo statistics, Table 2)",
    notes="the paper's own evaluation config; extra beyond the assigned 10",
))
