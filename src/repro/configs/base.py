"""ArchSpec: the contract between configs/, launch/dryrun.py and tests.

  make_config(reduced)   -> model config NamedTuple (full or smoke-test size)
  shapes                 -> tuple of shape-cell names (the assigned set)

Cell construction (input specs, step functions, shardings) lives in
``repro.launch.cells`` keyed by ``family``.
"""
from __future__ import annotations

from typing import Callable, NamedTuple


class ArchSpec(NamedTuple):
    arch_id: str
    family: str                    # lm | gnn | recsys
    make_config: Callable          # (reduced: bool) -> model config
    shapes: tuple
    citation: str = ""
    notes: str = ""


_REGISTRY: dict[str, ArchSpec] = {}


def register_arch(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.arch_id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    return _REGISTRY[arch_id]


def ALL_ARCHS():
    return sorted(_REGISTRY)


LM_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
GNN_SHAPES = ("full_graph_sm", "minibatch_lg", "ogb_products", "molecule")
RECSYS_SHAPES = ("train_batch", "serve_p99", "serve_bulk", "retrieval_cand")
