"""grok-1-314b [hf:xai-org/grok-1; unverified] — 8-expert MoE, top-2.

64L d_model=6144 48H (GQA kv=8) per-expert d_ff=32768 vocab=131072.
8 experts don't divide the 16-wide model axis: tensor-parallel *within*
experts over d_ff instead (DESIGN.md §5). bf16 params + bf16 Adam moments —
the quantized-optimizer variant that fits 314B × Adam on 256 × 16 GB chips.
"""
from repro.configs.base import ArchSpec, LM_SHAPES, register_arch
from repro.models.lm import LMConfig
from repro.nn.moe import MoEConfig


def make_config(reduced: bool = False) -> LMConfig:
    if reduced:
        return LMConfig(name="grok-1-smoke", n_layers=2, d_model=64,
                        n_heads=6, n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
                        moe=MoEConfig(n_experts=4, top_k=2, d_model=64, d_ff=64))
    return LMConfig(
        name="grok-1-314b", n_layers=64, d_model=6144, n_heads=48,
        n_kv_heads=8, head_dim=128, d_ff=32768, vocab=131072,
        moe=MoEConfig(n_experts=8, top_k=2, d_model=6144, d_ff=32768,
                      capacity_factor=1.25),
        dtype="bfloat16", attn_chunk_q=256, attn_chunk_kv=1024, ce_chunk=256,
    )


ARCH = register_arch(ArchSpec(
    arch_id="grok-1-314b", family="lm", make_config=make_config,
    shapes=LM_SHAPES, citation="hf:xai-org/grok-1; unverified",
    notes="8 experts % 16 != 0 -> TP within experts over d_ff",
))
