"""internlm2-1.8b [arXiv:2403.17297; hf] — dense, GQA kv=8.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
"""
from repro.configs.base import ArchSpec, LM_SHAPES, register_arch
from repro.models.lm import LMConfig


def make_config(reduced: bool = False) -> LMConfig:
    if reduced:
        return LMConfig(name="internlm2-1.8b-smoke", n_layers=2, d_model=64,
                        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                        vocab=512)
    return LMConfig(
        name="internlm2-1.8b", n_layers=24, d_model=2048, n_heads=16,
        n_kv_heads=8, head_dim=128, d_ff=8192, vocab=92544,
        dtype="bfloat16", attn_chunk_q=512, attn_chunk_kv=1024, ce_chunk=512,
    )


ARCH = register_arch(ArchSpec(
    arch_id="internlm2-1.8b", family="lm", make_config=make_config,
    shapes=LM_SHAPES, citation="arXiv:2403.17297; hf",
))
