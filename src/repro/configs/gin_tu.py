"""gin-tu [arXiv:1810.00826; paper] — GIN, 5 layers, d=64, sum agg, learnable ε.

Shape cells carry their own graph geometry (base.GNN_SHAPES):
  full_graph_sm: cora   (2,708 / 10,556, d_feat 1,433, 7 classes)
  minibatch_lg:  reddit (232,965 / 114,615,892, d_feat 602, 41 cls, fanout 15-10)
  ogb_products:         (2,449,029 / 61,859,140, d_feat 100, 47 cls)
  molecule:      128 graphs × (30 / 64), atom vocab 119, graph-level binary

MPE applies only to the molecule cell's categorical atom embedding
(DESIGN.md §4); the dense-feature cells run without the technique.
"""
from typing import NamedTuple

from repro.configs.base import ArchSpec, GNN_SHAPES, register_arch
from repro.models.gnn import GINConfig


class GraphCell(NamedTuple):
    n_nodes: int
    n_edges: int
    d_feat: int
    n_classes: int
    input_mode: str = "dense"
    readout: str = "node"
    batch_nodes: int = 0           # minibatch cells
    fanout: tuple = ()
    n_graphs: int = 0              # molecule cells
    atom_vocab: int = 0


GRAPH_CELLS = {
    "full_graph_sm": GraphCell(2_708, 10_556, 1_433, 7),
    "minibatch_lg": GraphCell(232_965, 114_615_892, 602, 41,
                              batch_nodes=1_024, fanout=(15, 10)),
    "ogb_products": GraphCell(2_449_029, 61_859_140, 100, 47),
    "molecule": GraphCell(30, 64, 0, 2, input_mode="categorical",
                          readout="graph", n_graphs=128, atom_vocab=119),
}


def make_config(reduced: bool = False, shape: str = "full_graph_sm") -> GINConfig:
    cell = GRAPH_CELLS[shape]
    if reduced:
        return GINConfig(n_layers=2, d_hidden=16,
                         d_in=min(cell.d_feat, 32) or 16,
                         n_classes=cell.n_classes,
                         input_mode=cell.input_mode, readout=cell.readout,
                         atom_vocab=cell.atom_vocab or 119)
    return GINConfig(n_layers=5, d_hidden=64, d_in=cell.d_feat or 64,
                     n_classes=cell.n_classes, input_mode=cell.input_mode,
                     readout=cell.readout, atom_vocab=cell.atom_vocab or 119,
                     compressor=("mpe_search" if cell.input_mode == "categorical"
                                 else "plain"))


ARCH = register_arch(ArchSpec(
    arch_id="gin-tu", family="gnn", make_config=make_config,
    shapes=GNN_SHAPES, citation="arXiv:1810.00826; paper",
    notes="MPE applies to the molecule cell's atom-type table only",
))
