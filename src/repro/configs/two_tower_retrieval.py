"""two-tower-retrieval [RecSys'19 (YouTube); unverified] — dot-product
retrieval, tower MLP 1024-512-256 (output = 256-d dot space).

Tables: 4 user fields × 8,388,608 + 4 item fields × 2,097,152 = 41.9M rows,
id-embedding d=64. In-batch sampled softmax with logQ correction.
"""
from repro.configs.base import ArchSpec, RECSYS_SHAPES, register_arch
from repro.embeddings.table import FieldSpec
from repro.models.two_tower import TwoTowerConfig

USER_VOCAB = 8_388_608
ITEM_VOCAB = 2_097_152


def make_config(reduced: bool = False) -> TwoTowerConfig:
    if reduced:
        uf = tuple(FieldSpec(f"u{i}", 1_000) for i in range(2))
        itf = tuple(FieldSpec(f"i{i}", 500) for i in range(2))
        return TwoTowerConfig(user_fields=uf, item_fields=itf, d_embed=16,
                              tower_hidden=(32, 16), compressor="mpe_search")
    uf = tuple(FieldSpec(f"u{i}", USER_VOCAB) for i in range(4))
    itf = tuple(FieldSpec(f"i{i}", ITEM_VOCAB) for i in range(4))
    return TwoTowerConfig(user_fields=uf, item_fields=itf, d_embed=64,
                          tower_hidden=(1024, 512, 256),
                          compressor="mpe_search")


ARCH = register_arch(ArchSpec(
    arch_id="two-tower-retrieval", family="recsys", make_config=make_config,
    shapes=RECSYS_SHAPES, citation="RecSys'19 (YouTube); unverified",
))
