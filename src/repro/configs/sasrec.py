"""sasrec [arXiv:1808.09781; paper] — d=50, 2 blocks, 1 head, seq 50.

Item vocab 8,388,608 (shared input/output table).
"""
from repro.configs.base import ArchSpec, RECSYS_SHAPES, register_arch
from repro.models.sasrec import SASRecConfig

ITEM_VOCAB = 8_388_608


def make_config(reduced: bool = False) -> SASRecConfig:
    if reduced:
        return SASRecConfig(item_vocab=2_000, d_embed=16, seq_len=10,
                            compressor="mpe_search")
    return SASRecConfig(item_vocab=ITEM_VOCAB, d_embed=50, seq_len=50,
                        n_blocks=2, n_heads=1, compressor="mpe_search")


ARCH = register_arch(ArchSpec(
    arch_id="sasrec", family="recsys", make_config=make_config,
    shapes=RECSYS_SHAPES, citation="arXiv:1808.09781; paper",
))
