"""Builder factories binding models + compressors for the MPE pipeline,
benchmarks, tests and examples.

A builder is ``build(key, compressor, comp_cfg) -> bundle`` with
bundle = {"params", "buffers", "state", "loss_fn", "eval_fn"}; loss_fn follows
the Trainer signature (params, buffers, state, batch, *, step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.dlrm import DLRM, DLRMConfig
from repro.models.wide_deep import WideDeep, WideDeepConfig
from repro.train.metrics import auc, logloss


def _ctr_eval(apply_fn, eval_batches):
    def eval_fn(params, buffers, state):
        scores, labels = [], []
        for b in eval_batches:
            batch = {k: jnp.asarray(v) for k, v in b.items()}
            logits, _, _ = apply_fn(params, buffers, state, batch)
            scores.append(np.asarray(jax.nn.sigmoid(logits)))
            labels.append(np.asarray(batch["label"]))
        s = np.concatenate(scores); l = np.concatenate(labels)
        return {"auc": float(auc(jnp.asarray(l), jnp.asarray(s))),
                "logloss": float(logloss(jnp.asarray(l, jnp.float32),
                                         jnp.asarray(s)))}
    return eval_fn


def dlrm_builder(base: DLRMConfig, freqs, *, lam: float = 0.0,
                 eval_batches=None):
    """Returns build(key, compressor, comp_cfg)."""
    def build(key, compressor: str, comp_cfg):
        cfg = base._replace(compressor=compressor, comp_cfg=comp_cfg)
        params, buffers, state = DLRM.init(key, cfg, freqs=freqs)

        def loss_fn(p, bu, st, batch, *, step=None):
            return DLRM.loss_fn(p, bu, st, batch, cfg, lam=lam, train=True,
                                step=step)

        def apply_eval(p, bu, st, batch):
            return DLRM.apply(p, bu, st, batch, cfg, train=False)

        return {"params": params, "buffers": buffers, "state": state,
                "loss_fn": loss_fn, "cfg": cfg,
                "eval_fn": (None if eval_batches is None
                            else _ctr_eval(apply_eval, eval_batches))}
    return build


def wide_deep_builder(base: WideDeepConfig, freqs, *, lam: float = 0.0,
                      eval_batches=None):
    def build(key, compressor: str, comp_cfg):
        cfg = base._replace(compressor=compressor, comp_cfg=comp_cfg)
        params, buffers, state = WideDeep.init(key, cfg, freqs=freqs)

        def loss_fn(p, bu, st, batch, *, step=None):
            return WideDeep.loss_fn(p, bu, st, batch, cfg, lam=lam, train=True,
                                    step=step)

        def apply_eval(p, bu, st, batch):
            return WideDeep.apply(p, bu, st, batch, cfg, train=False)

        return {"params": params, "buffers": buffers, "state": state,
                "loss_fn": loss_fn, "cfg": cfg,
                "eval_fn": (None if eval_batches is None
                            else _ctr_eval(apply_eval, eval_batches))}
    return build
