"""Decoder-only LM covering every assigned transformer arch.

One config class expresses: starcoder2-7b (GQA kv=4, RoPE), qwen3-32b
(GQA kv=8, qk_norm), internlm2-1.8b (GQA kv=8), deepseek-moe-16b (2 shared +
64 routed top-6 fine-grained MoE), grok-1-314b (8 experts top-2).

Layers are scanned (stacked params) so HLO size is O(1) in depth — essential
for the 64-layer archs' multi-pod dry-run — with optional per-layer remat.
The token embedding is a pluggable compressor table: MPE applies to the
Zipf-distributed vocab exactly as to CTR features (DESIGN.md §4); the LM head
and transformer weights stay uncompressed (paper quantizes only embeddings).

Decode: stacked KV caches {"k","v": (L, B, T_max, n_kv, hd), "len": ()};
``apply`` with ``kv_caches`` runs one (or few) tokens against the cache.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import get_compressor
from repro.core.quantizer import (dequantize_symmetric, quantize_symmetric,
                                  requantize_int8)
from repro.nn import init as initializers
from repro.nn.attention import MHA, gqa_attention
from repro.nn.moe import MoE, MoEConfig
from repro.nn.norms import RMSNorm
from repro.nn.rope import apply_rope


class LMConfig(NamedTuple):
    name: str = "lm"
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 2
    head_dim: int = 64
    d_ff: int = 512
    vocab: int = 1024
    qk_norm: bool = False
    rope_theta: float = 10000.0
    moe: MoEConfig | None = None      # None => dense SwiGLU FFN
    dtype: str = "float32"            # param/activation dtype ("bfloat16" at scale)
    remat: bool = True
    compressor: str = "plain"
    comp_cfg: dict | None = None
    embed_std: float = 0.02
    # memory-bounded paths (nn/chunked.py) — required for the 32k/4k cells
    attn_chunk_q: int = 0             # 0 => unchunked attention
    attn_chunk_kv: int = 1024
    ce_chunk: int = 0                 # 0 => unchunked cross-entropy
    # sequence-shard attention activations (starcoder2: 36 heads ∤ 16 chips)
    seq_shard_attn: bool = False
    # §Perf: pin layer activations to the batch axes so GSPMD gathers weights,
    # never the (tokens × d_model) activations (see dist.sharding.shard_batch_dim)
    shard_activations: bool = False
    # §Perf: expand K/V to query heads inside chunked attention so the head
    # dim shards over "model" (see nn.chunked.chunked_gqa_attention)
    attn_expand_kv: bool = False
    # §Perf: bf16 attention blocks (fp32 softmax stats + accumulation)
    attn_block_bf16: bool = False


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def _layer_init(key, cfg: LMConfig):
    dt = _dt(cfg)
    ks = jax.random.split(key, 3)
    p = {
        "attn": MHA.init(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                         cfg.head_dim, qk_norm=cfg.qk_norm, dtype=dt),
        "ln_attn": RMSNorm.init(None, cfg.d_model, dt),
        "ln_ffn": RMSNorm.init(None, cfg.d_model, dt),
    }
    if cfg.moe is not None:
        p["moe"] = MoE.init(ks[1], cfg.moe, dtype=dt)
    else:
        k1, k2, k3 = jax.random.split(ks[1], 3)
        p["ffn"] = {
            "w_gate": initializers.he_normal(k1, (cfg.d_model, cfg.d_ff), dt),
            "w_up": initializers.he_normal(k2, (cfg.d_model, cfg.d_ff), dt),
            "w_down": initializers.he_normal(k3, (cfg.d_ff, cfg.d_model), dt),
        }
    return p


class LM:
    @staticmethod
    def init(key, cfg: LMConfig, freqs=None):
        dt = _dt(cfg)
        ks = jax.random.split(key, 4)
        comp = get_compressor(cfg.compressor)
        if freqs is None:
            freqs = np.ones((cfg.vocab,), np.float64)
        ccfg = dict(cfg.comp_cfg or {})
        ccfg.setdefault("embed_std", cfg.embed_std)
        emb_params, emb_buffers = comp.init(ks[0], cfg.vocab, cfg.d_model,
                                            freqs, ccfg)
        # stacked per-layer params: every leaf gets a leading (L,) axis
        layer_keys = jax.random.split(ks[1], cfg.n_layers)
        layers = jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys)
        params = {
            "embedding": emb_params,
            "layers": layers,
            "ln_f": RMSNorm.init(None, cfg.d_model, dt),
            "lm_head": initializers.normal(ks[2], (cfg.d_model, cfg.vocab),
                                           std=0.02, dtype=dt),
        }
        buffers = {"embedding": emb_buffers}
        return params, buffers

    @staticmethod
    def _layer_apply(cfg: LMConfig, x, layer_params, *, positions,
                     cache_k=None, cache_v=None, cache_len=None,
                     cache_k_scale=None, cache_v_scale=None):
        """x: (B,S,d). Returns (x_out, aux_loss, new_cache_k, new_cache_v,
        new_k_scale, new_v_scale) — the scales are None unless the caches
        are int8-quantized."""
        p = layer_params
        if cfg.shard_activations:
            from repro.dist.sharding import shard_batch_dim
            x = shard_batch_dim(x)
            p = LM._gather_fsdp_weights(p, cfg)
        h = RMSNorm.apply(p["ln_attn"], x)
        b, s, _ = h.shape
        nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        from repro.nn.linear import Dense
        q = Dense.apply(p["attn"]["wq"], h).reshape(b, s, nh, hd)
        k = Dense.apply(p["attn"]["wk"], h).reshape(b, s, nkv, hd)
        v = Dense.apply(p["attn"]["wv"], h).reshape(b, s, nkv, hd)
        if cfg.qk_norm:
            q = RMSNorm.apply(p["attn"]["q_norm"], q)
            k = RMSNorm.apply(p["attn"]["k_norm"], k)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        if cfg.seq_shard_attn and s > 1:
            # context parallelism for head counts the mesh can't divide:
            # shard S over "model"; the chunked softmax handles the rest.
            from jax.sharding import PartitionSpec as P
            from repro.dist.sharding import current_dp_axes, maybe_shard
            dp = current_dp_axes()
            if dp is not None:
                q = maybe_shard(q, P(dp, "model", None, None))
                k = maybe_shard(k, P(dp, "model", None, None))
                v = maybe_shard(v, P(dp, "model", None, None))

        new_ck = new_cv = new_ks = new_vs = None
        if cache_k is not None:
            if cache_k.dtype == jnp.int8:
                # §Perf, paper-aligned: int8 KV cache (per-(batch,head) scales,
                # dequant fused into the attention reads) — halves the
                # decode-dominant KV traffic vs bf16. Scales are calibrated
                # from the observed K/V absmax (a static scale saturates any
                # value beyond 127·scale and flips decode argmaxes).
                new_ck, new_ks = LM._requant_cache(cache_k, cache_k_scale, k,
                                                   cache_len)
                new_cv, new_vs = LM._requant_cache(cache_v, cache_v_scale, v,
                                                   cache_len)
                k_att = dequantize_symmetric(new_ck, new_ks, _dt(cfg))
                v_att = dequantize_symmetric(new_cv, new_vs, _dt(cfg))
            else:
                new_ck = LM._cache_write(cache_k, k, cache_len)
                new_cv = LM._cache_write(cache_v, v, cache_len)
                k_att, v_att = new_ck, new_cv
            attn = gqa_attention(q, k_att, v_att, n_heads=nh, n_kv_heads=nkv,
                                 causal=True, q_offset=cache_len,
                                 kv_valid_len=cache_len + s)
        elif cfg.attn_chunk_q and s > cfg.attn_chunk_q:
            from repro.nn.chunked import chunked_gqa_attention
            attn = chunked_gqa_attention(q, k, v, n_kv_heads=nkv, causal=True,
                                         q_chunk=cfg.attn_chunk_q,
                                         kv_chunk=cfg.attn_chunk_kv,
                                         expand_kv=cfg.attn_expand_kv,
                                         block_dtype=(jnp.bfloat16
                                                      if cfg.attn_block_bf16
                                                      else None))
        else:
            attn = gqa_attention(q, k, v, n_heads=nh, n_kv_heads=nkv, causal=True)
        x = x + Dense.apply(p["attn"]["wo"], attn.reshape(b, s, nh * hd))

        h = RMSNorm.apply(p["ln_ffn"], x)
        aux = jnp.zeros((), jnp.float32)
        if cfg.moe is not None:
            ff, aux = MoE.apply(p["moe"], h, cfg.moe)
        else:
            w = p["ffn"]
            ff = (jax.nn.silu(h @ w["w_gate"]) * (h @ w["w_up"])) @ w["w_down"]
        return x + ff, aux, new_ck, new_cv, new_ks, new_vs

    @staticmethod
    def _cache_write(cache, update, start):
        """Write ``update`` (B, s, H, hd) into ``cache`` (B, T, H, hd) at
        sequence offset ``start`` — a scalar (one shared length: the classic
        decode batch) or a per-row ``(B,)`` vector (continuous batching:
        every slot advances independently, lowered as a vmapped
        dynamic-update-slice so each row still writes only its own slot)."""
        start = jnp.asarray(start)
        update = update.astype(cache.dtype)
        if start.ndim == 0:
            return jax.lax.dynamic_update_slice_in_dim(cache, update, start,
                                                       axis=1)
        return jax.vmap(
            lambda c, u, s: jax.lax.dynamic_update_slice_in_dim(c, u, s,
                                                                axis=0)
        )(cache, update, start)

    @staticmethod
    def _requant_cache(cache, scale, new_vals, cache_len):
        """Write ``new_vals`` into an int8 cache with running-absmax scales.

        The per-(batch, head) scale is calibrated so the observed absmax maps
        to code 127: on the first write (empty cache) it is set outright; on
        later writes it only grows (monotone max), and the already-stored
        codes are re-quantized onto the coarser grid so one scale stays valid
        for the whole cache. cache: (B, T, H, hd) int8; scale: (B, 1, H, 1).
        ``cache_len`` may be per-row ``(B,)`` (continuous batching) — a slot
        rejoining at length 0 then re-seeds its own scale outright while the
        other rows keep their running maxima.
        """
        vals32 = new_vals.astype(jnp.float32)
        obs = jnp.maximum(
            jnp.max(jnp.abs(vals32), axis=(1, 3), keepdims=True) / 127.0, 1e-8)
        first = jnp.asarray(cache_len) == 0
        if first.ndim:
            first = first.reshape((-1, 1, 1, 1))
        new_scale = jnp.where(first, obs, jnp.maximum(scale, obs))

        def _rewrite(c):  # scale grew: shrink stored codes onto the new grid
            return requantize_int8(c, scale / new_scale)

        # The full-cache rewrite is the rare path — scales only grow, mostly
        # during the first writes. The common decode step must stay
        # read-cache + write-one-slot, or the rewrite traffic would eat the
        # bandwidth halving the int8 cache exists for. (A shrink below the
        # seed scale happens only on an all-zero cache: nothing to rewrite.)
        cache = jax.lax.cond(jnp.any(new_scale > scale), _rewrite,
                             lambda c: c, cache)
        q = quantize_symmetric(vals32, new_scale)
        return LM._cache_write(cache, q, cache_len), new_scale

    @staticmethod
    def _gather_fsdp_weights(p, cfg: LMConfig):
        """§Perf: constrain layer weights to 'model'-only sharding inside the
        scan body. The params live FSDP-sharded (d_model/d_ff over "data") in
        HBM; this forces GSPMD to all-gather each layer's weights once per
        layer — instead of its default of replicating the (tokens × d_model)
        activations, which costs ~16× the bytes (EXPERIMENTS.md §Perf)."""
        from jax.sharding import PartitionSpec as P
        from repro.dist.sharding import current_dp_axes, maybe_shard
        if current_dp_axes() is None:
            return p
        p = jax.tree.map(lambda x: x, p)  # shallow structural copy
        attn = dict(p["attn"])
        for k in ("wq", "wk", "wv"):
            attn[k] = {"kernel": maybe_shard(p["attn"][k]["kernel"],
                                             P(None, "model"))}
        attn["wo"] = {"kernel": maybe_shard(p["attn"]["wo"]["kernel"],
                                            P("model", None))}
        for k in ("q_norm", "k_norm"):
            if k in p["attn"]:
                attn[k] = p["attn"][k]
        p["attn"] = attn
        if "ffn" in p:
            p["ffn"] = {
                "w_gate": maybe_shard(p["ffn"]["w_gate"], P(None, "model")),
                "w_up": maybe_shard(p["ffn"]["w_up"], P(None, "model")),
                "w_down": maybe_shard(p["ffn"]["w_down"], P("model", None)),
            }
        if "moe" in p:
            moe = dict(p["moe"])
            ep = cfg.moe.n_experts % 16 == 0
            ex = p["moe"]["experts"]
            if ep:  # experts stay sharded over model; gather the fsdp dim
                moe["experts"] = {
                    "w_gate": maybe_shard(ex["w_gate"], P("model", None, None)),
                    "w_up": maybe_shard(ex["w_up"], P("model", None, None)),
                    "w_down": maybe_shard(ex["w_down"], P("model", None, None)),
                }
            else:   # TP within experts over d_ff
                moe["experts"] = {
                    "w_gate": maybe_shard(ex["w_gate"], P(None, None, "model")),
                    "w_up": maybe_shard(ex["w_up"], P(None, None, "model")),
                    "w_down": maybe_shard(ex["w_down"], P(None, "model", None)),
                }
            if "shared" in p["moe"]:
                sh = p["moe"]["shared"]
                moe["shared"] = {
                    "w_gate": maybe_shard(sh["w_gate"], P(None, "model")),
                    "w_up": maybe_shard(sh["w_up"], P(None, "model")),
                    "w_down": maybe_shard(sh["w_down"], P("model", None)),
                }
            p["moe"] = moe
        return p

    @staticmethod
    def apply(params, buffers, tokens, cfg: LMConfig, *, positions=None,
              kv_caches=None, train: bool = False, step=None):
        """tokens: (B, S) -> (logits (B,S,V), aux_loss, new_kv_caches)."""
        comp = get_compressor(cfg.compressor)
        ccfg = dict(cfg.comp_cfg or {})
        ccfg.setdefault("embed_std", cfg.embed_std)
        x = comp.lookup(params["embedding"], buffers["embedding"], tokens,
                        ccfg, train=train, step=step).astype(_dt(cfg))
        if positions is None:
            offset = kv_caches["len"] if kv_caches is not None else 0
            # scalar offset -> (1, S) as before; per-slot (B,) -> (B, S)
            positions = (jnp.reshape(jnp.asarray(offset), (-1, 1))
                         + jnp.arange(tokens.shape[1])[None, :])

        cache_len = kv_caches["len"] if kv_caches is not None else None

        quant_kv = kv_caches is not None and "k_scale" in kv_caches

        def body(carry, xs):
            h, aux = carry
            if kv_caches is not None:
                if quant_kv:
                    lp, ck, cv, ks, vs = xs
                else:
                    lp, ck, cv = xs
                    ks = vs = None
                h, a, nck, ncv, nks, nvs = LM._layer_apply(
                    cfg, h, lp, positions=positions, cache_k=ck, cache_v=cv,
                    cache_len=cache_len, cache_k_scale=ks, cache_v_scale=vs)
                if quant_kv:
                    return (h, aux + a), (nck, ncv, nks, nvs)
                return (h, aux + a), (nck, ncv)
            lp = xs
            h, a, *_ = LM._layer_apply(cfg, h, lp, positions=positions)
            return (h, aux + a), None

        body_fn = jax.checkpoint(body) if (cfg.remat and kv_caches is None) else body
        if kv_caches is None:
            xs = params["layers"]
        elif quant_kv:
            xs = (params["layers"], kv_caches["k"], kv_caches["v"],
                  kv_caches["k_scale"], kv_caches["v_scale"])
        else:
            xs = (params["layers"], kv_caches["k"], kv_caches["v"])
        (x, aux), caches_out = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)), xs)

        x = RMSNorm.apply(params["ln_f"], x)
        logits = x @ params["lm_head"]
        new_caches = None
        if kv_caches is not None:
            new_caches = {"k": caches_out[0], "v": caches_out[1],
                          "len": kv_caches["len"] + tokens.shape[1]}
            if quant_kv:
                new_caches["k_scale"] = caches_out[2]
                new_caches["v_scale"] = caches_out[3]
        return logits, aux, new_caches

    @staticmethod
    def hidden_states(params, buffers, tokens, cfg: LMConfig, *, train=False,
                      step=None):
        """Final-layer hidden states (before the LM head) — big-vocab CE path."""
        comp = get_compressor(cfg.compressor)
        ccfg = dict(cfg.comp_cfg or {})
        ccfg.setdefault("embed_std", cfg.embed_std)
        x = comp.lookup(params["embedding"], buffers["embedding"], tokens,
                        ccfg, train=train, step=step).astype(_dt(cfg))
        positions = jnp.arange(tokens.shape[1])[None, :]

        def body(carry, lp):
            h, aux = carry
            h, a, *_ = LM._layer_apply(cfg, h, lp, positions=positions)
            return (h, aux + a), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                   params["layers"])
        return RMSNorm.apply(params["ln_f"], x), aux

    @staticmethod
    def loss_fn(params, buffers, batch, cfg: LMConfig, *, aux_weight: float = 0.01,
                train: bool = True, step=None):
        """batch: {"tokens": (B,S), "labels": (B,S)} next-token CE."""
        if cfg.ce_chunk:
            from repro.nn.chunked import chunked_softmax_xent
            x, aux = LM.hidden_states(params, buffers, batch["tokens"], cfg,
                                      train=train, step=step)
            ce = chunked_softmax_xent(x, params["lm_head"], batch["labels"],
                                      chunk=cfg.ce_chunk)
            return ce + aux_weight * aux, ce
        logits, aux, _ = LM.apply(params, buffers, batch["tokens"], cfg,
                                  train=train, step=step)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ce = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)
        return jnp.mean(ce) + aux_weight * aux, ce

    @staticmethod
    def make_kv_caches(cfg: LMConfig, batch: int, max_len: int,
                       dtype=jnp.bfloat16, prefill_len: int = 0,
                       kv_scale_init: float = 0.05):
        shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        caches = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                  "len": jnp.asarray(prefill_len, jnp.int32)}
        if dtype == jnp.int8:
            # §Perf, paper-aligned: int8 KV with per-(layer,batch,head) scales.
            # kv_scale_init only seeds caches created with prefill_len > 0;
            # the first write into an empty cache calibrates the scale from
            # the observed K/V absmax (see LM._requant_cache).
            sshape = (cfg.n_layers, batch, 1, cfg.n_kv_heads, 1)
            caches["k_scale"] = jnp.full(sshape, kv_scale_init, jnp.float32)
            caches["v_scale"] = jnp.full(sshape, kv_scale_init, jnp.float32)
        return caches

    @staticmethod
    def decode_step(params, buffers, tokens, kv_caches, cfg: LMConfig):
        """One-token serving step. tokens: (B, 1)."""
        logits, _, new_caches = LM.apply(params, buffers, tokens, cfg,
                                         kv_caches=kv_caches)
        return logits[:, -1], new_caches

    @staticmethod
    def decode_step_slotted(params, buffers, tokens, lens, kv_caches,
                            cfg: LMConfig):
        """One continuous-batching decode step: per-slot cache lengths.

        ``tokens``: (B, 1); ``lens``: (B,) int32 — each cache slot's valid
        length, owned by the scheduler (a freed slot rejoins at 0, which
        re-seeds its int8 scale on the first write); ``kv_caches``:
        {"k","v"[,"k_scale","v_scale"]} **without** the shared "len" entry.
        Returns (logits (B, V), new_caches without "len")."""
        caches = dict(kv_caches, len=lens)
        logits, _, new_caches = LM.apply(params, buffers, tokens, cfg,
                                         kv_caches=caches)
        new_caches.pop("len")
        return logits[:, -1], new_caches

    @staticmethod
    def prefill(params, buffers, tokens, cfg: LMConfig, max_len: int,
                cache_dtype=jnp.bfloat16):
        """Prompt pass that fills fresh caches. tokens: (B, S)."""
        caches = LM.make_kv_caches(cfg, tokens.shape[0], max_len, cache_dtype)
        logits, _, caches = LM.apply(params, buffers, tokens, cfg, kv_caches=caches)
        return logits[:, -1], caches
