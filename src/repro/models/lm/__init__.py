from repro.models.lm.transformer import LM, LMConfig

__all__ = ["LM", "LMConfig"]
