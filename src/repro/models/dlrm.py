"""The paper's four DLRM backbones: DNN, DCN, DeepFM, IPNN (§5.1.2).

All share: a global embedding table over all feature fields (compressed by a
pluggable compressor — MPE or any baseline), a 1024-512-256 MLP with
BatchNorm (§5.1.5), and a sigmoid CTR head. They differ only in the
interaction branch.

batch = {"ids": (B, F) int32 per-field local ids, "label": (B,)}.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import get_compressor
from repro.embeddings.table import field_offsets, total_vocab
from repro.models.interactions import CrossNetwork, fm_second_order, inner_products
from repro.nn import init as initializers
from repro.nn.mlp import MLP


class DLRMConfig(NamedTuple):
    fields: tuple                      # tuple[FieldSpec]
    d_embed: int = 16                  # paper §5.1.5
    mlp_hidden: tuple = (1024, 512, 256)
    backbone: str = "dnn"              # dnn | dcn | deepfm | ipnn
    n_cross_layers: int = 3
    compressor: str = "plain"
    comp_cfg: dict | None = None
    use_batchnorm: bool = True


class DLRM:
    @staticmethod
    def init(key, cfg: DLRMConfig, freqs=None):
        n = total_vocab(cfg.fields)
        f = len(cfg.fields)
        d_in = f * cfg.d_embed
        keys = jax.random.split(key, 5)
        comp = get_compressor(cfg.compressor)
        if freqs is None:
            freqs = np.ones((n,), np.float64)
        emb_params, emb_buffers = comp.init(keys[0], n, cfg.d_embed, freqs, cfg.comp_cfg)

        if cfg.backbone == "ipnn":
            mlp_in = d_in + f * (f - 1) // 2
        else:
            mlp_in = d_in
        params = {
            "embedding": emb_params,
            "mlp": MLP.init(keys[1], mlp_in, cfg.mlp_hidden, d_out=1,
                            use_batchnorm=cfg.use_batchnorm),
        }
        if cfg.backbone == "dcn":
            params["cross"] = CrossNetwork.init(keys[2], d_in, cfg.n_cross_layers)
            params["cross_head"] = initializers.normal(keys[3], (d_in,), std=0.01)
        if cfg.backbone == "deepfm":
            # first-order per-feature weights (the FM linear term)
            params["fm_linear"] = jnp.zeros((n,), jnp.float32)
            params["fm_bias"] = jnp.zeros((), jnp.float32)

        buffers = {
            "embedding": emb_buffers,
            "offsets": jnp.asarray(field_offsets(cfg.fields)),
        }
        state = {"mlp": MLP.init_state(cfg.mlp_hidden, use_batchnorm=cfg.use_batchnorm)}
        return params, buffers, state

    @staticmethod
    def interact(params, state, emb, gids, cfg: DLRMConfig, *,
                 train: bool = False):
        """The post-lookup half of ``apply``: interaction branch + MLP head
        over pre-gathered embeddings ``emb (B, F, d)``. Split out so serving
        paths that gather embeddings elsewhere (the tiered hot/cold store in
        ``repro.cache``) reuse the exact compute graph. ``gids`` are the
        globalized ids (only the DeepFM first-order term reads them).
        Returns (logits (B,), new_state)."""
        b, f, d = emb.shape
        flat = emb.reshape(b, f * d)

        if cfg.backbone == "ipnn":
            mlp_in = jnp.concatenate([flat, inner_products(emb)], axis=-1)
        else:
            mlp_in = flat
        deep, new_mlp_state = MLP.apply(params["mlp"], state["mlp"], mlp_in, train=train)
        logit = deep[:, 0]

        if cfg.backbone == "dcn":
            cross = CrossNetwork.apply(params["cross"], flat)
            logit = logit + cross @ params["cross_head"]
        elif cfg.backbone == "deepfm":
            first = jnp.sum(jnp.take(params["fm_linear"], gids, axis=0), axis=1)
            logit = logit + first + fm_second_order(emb) + params["fm_bias"]
        return logit, {"mlp": new_mlp_state}

    @staticmethod
    def apply(params, buffers, state, batch, cfg: DLRMConfig, *,
              train: bool = False, step=None):
        """Returns (logits (B,), new_state, reg_loss)."""
        comp = get_compressor(cfg.compressor)
        gids = batch["ids"] + buffers["offsets"][None, :]
        emb = comp.lookup(params["embedding"], buffers["embedding"], gids,
                          cfg.comp_cfg, train=train, step=step)  # (B, F, d)
        logit, new_state = DLRM.interact(params, state, emb, gids, cfg,
                                         train=train)
        reg = comp.reg_loss(params["embedding"], buffers["embedding"], cfg.comp_cfg)
        return logit, new_state, reg

    @staticmethod
    def loss_fn(params, buffers, state, batch, cfg: DLRMConfig, *,
                lam: float = 0.0, train: bool = True, step=None):
        logits, new_state, reg = DLRM.apply(params, buffers, state, batch, cfg,
                                            train=train, step=step)
        labels = batch["label"].astype(jnp.float32)
        ce = jnp.mean(
            jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits))))
        return ce + lam * reg, (new_state, ce)
