"""BST — Behavior Sequence Transformer [arXiv:1905.06874] — assigned config:
embed_dim=32, seq_len=20, n_blocks=1, n_heads=8, MLP 1024-512-256.

The user's behavior sequence plus the target item pass through a transformer
block (learned positions, post-LN as in the paper); the flattened outputs are
concatenated with context-field embeddings and fed to the MLP CTR head. One
global table covers items + context fields so MPE compresses everything.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import get_compressor
from repro.embeddings.table import field_offsets, total_vocab
from repro.nn import init as initializers
from repro.nn.attention import MHA
from repro.nn.linear import Dense
from repro.nn.mlp import MLP
from repro.nn.norms import LayerNorm


class BSTConfig(NamedTuple):
    item_vocab: int
    ctx_fields: tuple = ()
    d_embed: int = 32
    seq_len: int = 20
    n_blocks: int = 1
    n_heads: int = 8
    transformer_ff: int = 128
    mlp_hidden: tuple = (1024, 512, 256)
    compressor: str = "plain"
    comp_cfg: dict | None = None
    use_batchnorm: bool = True


def _block_init(key, d, n_heads, d_ff):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "attn": MHA.init(k1, d, n_heads, head_dim=max(d // n_heads, 4)),
        "ln1": LayerNorm.init(None, d),
        "ff1": Dense.init(k2, d, d_ff),
        "ff2": Dense.init(k3, d_ff, d),
        "ln2": LayerNorm.init(None, d),
    }


def _block_apply(p, x, n_heads, d):
    hd = max(d // n_heads, 4)
    a, _ = MHA.apply(p["attn"], x, n_heads=n_heads, n_kv_heads=n_heads,
                     head_dim=hd, causal=False, rope_theta=None)
    x = LayerNorm.apply(p["ln1"], x + a)                       # post-LN (BST paper)
    h = Dense.apply(p["ff2"], jax.nn.relu(Dense.apply(p["ff1"], x)))
    return LayerNorm.apply(p["ln2"], x + h)


class BST:
    @staticmethod
    def init(key, cfg: BSTConfig, freqs=None):
        from repro.embeddings.table import FieldSpec
        fields = (FieldSpec("item", cfg.item_vocab), *cfg.ctx_fields)
        n = total_vocab(fields)
        keys = jax.random.split(key, 4 + cfg.n_blocks)
        comp = get_compressor(cfg.compressor)
        if freqs is None:
            freqs = np.ones((n,), np.float64)
        emb_params, emb_buffers = comp.init(keys[0], n, cfg.d_embed, freqs, cfg.comp_cfg)
        f_ctx = len(cfg.ctx_fields)
        mlp_in = (cfg.seq_len + 1) * cfg.d_embed + f_ctx * cfg.d_embed
        params = {
            "embedding": emb_params,
            "pos": initializers.normal(keys[1], (cfg.seq_len + 1, cfg.d_embed), std=0.02),
            "blocks": [_block_init(keys[3 + i], cfg.d_embed, cfg.n_heads,
                                   cfg.transformer_ff) for i in range(cfg.n_blocks)],
            "mlp": MLP.init(keys[2], mlp_in, cfg.mlp_hidden, d_out=1,
                            use_batchnorm=cfg.use_batchnorm),
        }
        offsets = field_offsets(fields)
        buffers = {"embedding": emb_buffers,
                   "item_offset": jnp.asarray(offsets[0]),
                   "ctx_offsets": jnp.asarray(offsets[1:])}
        state = {"mlp": MLP.init_state(cfg.mlp_hidden, use_batchnorm=cfg.use_batchnorm)}
        return params, buffers, state

    @staticmethod
    def apply(params, buffers, state, batch, cfg: BSTConfig, *,
              train: bool = False, step=None):
        """batch: seq_ids (B,S), target_id (B,), ctx_ids (B,Fc), label (B,)."""
        comp = get_compressor(cfg.compressor)
        seq = jnp.concatenate([batch["seq_ids"], batch["target_id"][:, None]], axis=1)
        gids = seq + buffers["item_offset"]
        x = comp.lookup(params["embedding"], buffers["embedding"], gids,
                        cfg.comp_cfg, train=train, step=step)   # (B, S+1, d)
        x = x + params["pos"][None]
        for blk in params["blocks"]:
            x = _block_apply(blk, x, cfg.n_heads, cfg.d_embed)
        feats = [x.reshape(x.shape[0], -1)]
        if len(cfg.ctx_fields):
            cgids = batch["ctx_ids"] + buffers["ctx_offsets"][None, :]
            ctx = comp.lookup(params["embedding"], buffers["embedding"], cgids,
                              cfg.comp_cfg, train=train, step=step)
            feats.append(ctx.reshape(ctx.shape[0], -1))
        deep, new_mlp = MLP.apply(params["mlp"], state["mlp"],
                                  jnp.concatenate(feats, axis=-1), train=train)
        reg = comp.reg_loss(params["embedding"], buffers["embedding"], cfg.comp_cfg)
        return deep[:, 0], {"mlp": new_mlp}, reg

    @staticmethod
    def loss_fn(params, buffers, state, batch, cfg: BSTConfig, *,
                lam: float = 0.0, train: bool = True, step=None):
        logits, new_state, reg = BST.apply(params, buffers, state, batch, cfg,
                                           train=train, step=step)
        y = batch["label"].astype(jnp.float32)
        ce = jnp.mean(jnp.maximum(logits, 0) - logits * y
                      + jnp.log1p(jnp.exp(-jnp.abs(logits))))
        return ce + lam * reg, (new_state, ce)
