from repro.models.dlrm import DLRM, DLRMConfig

__all__ = ["DLRM", "DLRMConfig"]
