"""SASRec [arXiv:1808.09781] — assigned config: d=50, 2 blocks, 1 head, S=50.

Causal self-attention over the item sequence with a shared input/output item
table; training uses the paper's per-position binary CE with one sampled
negative. ``score_candidates`` does full-corpus scoring for retrieval cells
(batched matmul against the — possibly dequantized — item table).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import get_compressor
from repro.nn import init as initializers
from repro.nn.attention import MHA
from repro.nn.linear import Dense
from repro.nn.norms import LayerNorm


class SASRecConfig(NamedTuple):
    item_vocab: int = 1_000_000
    d_embed: int = 50
    seq_len: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    dropout: float = 0.0  # dropout omitted (BN-free small model; noted in DESIGN)
    compressor: str = "plain"
    comp_cfg: dict | None = None


def _block_init(key, d, n_heads):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": LayerNorm.init(None, d),
        "attn": MHA.init(k1, d, n_heads, head_dim=max(d // n_heads, 4)),
        "ln2": LayerNorm.init(None, d),
        "ff1": Dense.init(k2, d, d),
        "ff2": Dense.init(k3, d, d),
    }


def _block_apply(p, x, n_heads, d):
    hd = max(d // n_heads, 4)
    h = LayerNorm.apply(p["ln1"], x)
    a, _ = MHA.apply(p["attn"], h, n_heads=n_heads, n_kv_heads=n_heads,
                     head_dim=hd, causal=True, rope_theta=None)
    x = x + a
    h = LayerNorm.apply(p["ln2"], x)
    return x + Dense.apply(p["ff2"], jax.nn.relu(Dense.apply(p["ff1"], h)))


class SASRec:
    @staticmethod
    def init(key, cfg: SASRecConfig, freqs=None):
        keys = jax.random.split(key, 2 + cfg.n_blocks)
        comp = get_compressor(cfg.compressor)
        if freqs is None:
            freqs = np.ones((cfg.item_vocab,), np.float64)
        emb_params, emb_buffers = comp.init(keys[0], cfg.item_vocab, cfg.d_embed,
                                            freqs, cfg.comp_cfg)
        params = {
            "embedding": emb_params,
            "pos": initializers.normal(keys[1], (cfg.seq_len, cfg.d_embed), std=0.02),
            "blocks": [_block_init(keys[2 + i], cfg.d_embed, cfg.n_heads)
                       for i in range(cfg.n_blocks)],
            "ln_f": LayerNorm.init(None, cfg.d_embed),
        }
        buffers = {"embedding": emb_buffers}
        state = {}
        return params, buffers, state

    @staticmethod
    def encode(params, buffers, seq_ids, cfg: SASRecConfig, *,
               train: bool = False, step=None):
        """seq_ids: (B, S) -> hidden states (B, S, d)."""
        comp = get_compressor(cfg.compressor)
        x = comp.lookup(params["embedding"], buffers["embedding"], seq_ids,
                        cfg.comp_cfg, train=train, step=step)
        x = x + params["pos"][None]
        for blk in params["blocks"]:
            x = _block_apply(blk, x, cfg.n_heads, cfg.d_embed)
        return LayerNorm.apply(params["ln_f"], x)

    @staticmethod
    def loss_fn(params, buffers, state, batch, cfg: SASRecConfig, *,
                lam: float = 0.0, train: bool = True, step=None):
        """batch: seq_ids, pos_ids, neg_ids (B,S), mask (B,S) valid positions."""
        comp = get_compressor(cfg.compressor)
        h = SASRec.encode(params, buffers, batch["seq_ids"], cfg,
                          train=train, step=step)               # (B, S, d)
        pos = comp.lookup(params["embedding"], buffers["embedding"],
                          batch["pos_ids"], cfg.comp_cfg, train=train, step=step)
        neg = comp.lookup(params["embedding"], buffers["embedding"],
                          batch["neg_ids"], cfg.comp_cfg, train=train, step=step)
        pos_logit = jnp.sum(h * pos, axis=-1)
        neg_logit = jnp.sum(h * neg, axis=-1)
        mask = batch["mask"].astype(jnp.float32)
        ce = (jnp.log1p(jnp.exp(-pos_logit)) + jnp.log1p(jnp.exp(neg_logit)))
        ce = jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        reg = comp.reg_loss(params["embedding"], buffers["embedding"], cfg.comp_cfg)
        return ce + lam * reg, (state, ce)

    @staticmethod
    def score_candidates(params, buffers, seq_ids, cand_ids, cfg: SASRecConfig,
                         *, top_k: int = 100):
        """seq_ids: (B,S); cand_ids: (C,) -> top-k over the candidate corpus."""
        comp = get_compressor(cfg.compressor)
        h = SASRec.encode(params, buffers, seq_ids, cfg, train=False)[:, -1]  # (B,d)
        cand = comp.lookup(params["embedding"], buffers["embedding"], cand_ids,
                           cfg.comp_cfg, train=False)            # (C, d)
        scores = h @ cand.T                                      # (B, C)
        return tuple(jax.lax.top_k(scores, top_k))
