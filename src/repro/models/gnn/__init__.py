from repro.models.gnn.gin import GIN, GINConfig

__all__ = ["GIN", "GINConfig"]
