"""GIN [arXiv:1810.00826] — assigned config: 5 layers, d=64, sum aggregator,
learnable ε.

Message passing is jax.ops.segment_sum over an edge list (JAX has no sparse
SpMM beyond BCOO; the scatter formulation IS the system — DESIGN.md §5):

    h'_i = MLP_l((1 + ε_l)·h_i + Σ_{j→i} h_j)

Supports three input regimes: dense node features (cora/ogbn-products),
categorical atom types through a pluggable compressor table (molecule cells —
the MPE-applicable case), and sampled subgraphs from the neighbor sampler
(minibatch_lg). Graph-level tasks sum-pool node states per graph id.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import get_compressor
from repro.nn import init as initializers
from repro.nn.linear import Dense


class GINConfig(NamedTuple):
    n_layers: int = 5
    d_hidden: int = 64
    d_in: int = 64                    # dense-feature width (ignored if categorical)
    n_classes: int = 2
    input_mode: str = "dense"         # dense | categorical
    atom_vocab: int = 128             # categorical mode
    readout: str = "node"             # node | graph
    compressor: str = "plain"
    comp_cfg: dict | None = None


def _gin_mlp_init(key, d_in, d_out):
    k1, k2 = jax.random.split(key)
    return {"l1": Dense.init(k1, d_in, d_out, kernel_init=initializers.he_normal),
            "l2": Dense.init(k2, d_out, d_out, kernel_init=initializers.he_normal)}


def _gin_mlp_apply(p, x):
    return Dense.apply(p["l2"], jax.nn.relu(Dense.apply(p["l1"], x)))


class GIN:
    @staticmethod
    def init(key, cfg: GINConfig, freqs=None):
        keys = jax.random.split(key, cfg.n_layers + 3)
        d0 = cfg.d_in if cfg.input_mode == "dense" else cfg.d_hidden
        layers = []
        for i in range(cfg.n_layers):
            d_in = d0 if i == 0 else cfg.d_hidden
            layers.append({
                "eps": jnp.zeros((), jnp.float32),   # learnable ε, init 0
                "mlp": _gin_mlp_init(keys[i], d_in, cfg.d_hidden),
            })
        params = {"layers": layers,
                  "head": Dense.init(keys[-1], cfg.d_hidden, cfg.n_classes)}
        buffers = {}
        if cfg.input_mode == "categorical":
            comp = get_compressor(cfg.compressor)
            if freqs is None:
                freqs = np.ones((cfg.atom_vocab,), np.float64)
            ep, eb = comp.init(keys[-2], cfg.atom_vocab, cfg.d_hidden, freqs,
                               cfg.comp_cfg)
            params["embedding"] = ep
            buffers["embedding"] = eb
        return params, buffers

    @staticmethod
    def apply(params, buffers, graph, cfg: GINConfig, *, train: bool = False,
              step=None):
        """graph: {x | atom_ids, edge_src, edge_dst, n_nodes(static),
        edge_mask?, graph_ids?, n_graphs?} -> logits."""
        if cfg.input_mode == "categorical":
            comp = get_compressor(cfg.compressor)
            h = comp.lookup(params["embedding"], buffers["embedding"],
                            graph["atom_ids"], cfg.comp_cfg, train=train, step=step)
        else:
            h = graph["x"]
        src, dst = graph["edge_src"], graph["edge_dst"]
        n = h.shape[0]
        emask = graph.get("edge_mask")
        reg = jnp.zeros(())
        if cfg.input_mode == "categorical":
            comp = get_compressor(cfg.compressor)
            reg = comp.reg_loss(params["embedding"], buffers.get("embedding", {}),
                                cfg.comp_cfg)
        for layer in params["layers"]:
            msg = jnp.take(h, src, axis=0)                       # (E, d)
            if emask is not None:
                msg = msg * emask[:, None].astype(msg.dtype)
            agg = jax.ops.segment_sum(msg, dst, num_segments=n)  # scatter-sum
            h = _gin_mlp_apply(layer["mlp"], (1.0 + layer["eps"]) * h + agg)
        if cfg.readout == "graph":
            pooled = jax.ops.segment_sum(h, graph["graph_ids"],
                                         num_segments=graph["n_graphs"])
            return Dense.apply(params["head"], pooled), reg
        return Dense.apply(params["head"], h), reg

    @staticmethod
    def loss_fn(params, buffers, graph, cfg: GINConfig, *, lam: float = 0.0,
                train: bool = True, step=None):
        """graph additionally carries {"labels", "label_mask"?} on nodes/graphs."""
        logits, reg = GIN.apply(params, buffers, graph, cfg, train=train, step=step)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ce = -jnp.take_along_axis(logp, graph["labels"][:, None], axis=-1)[:, 0]
        if "label_mask" in graph:
            m = graph["label_mask"].astype(jnp.float32)
            ce = jnp.sum(ce * m) / jnp.maximum(jnp.sum(m), 1.0)
        else:
            ce = jnp.mean(ce)
        return ce + lam * reg, ce
