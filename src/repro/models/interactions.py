"""Feature-interaction operators for the DLRM backbones (paper §5.1.2).

DNN = MLP only; DCN adds a cross network [arXiv:1708.05123]; DeepFM adds a
factorization machine [Rendle ICDM'10]; IPNN adds an inner-product layer
[arXiv:1611.00144].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import init as initializers


def fm_second_order(emb: jnp.ndarray) -> jnp.ndarray:
    """emb: (B, F, d) -> (B,) FM 2nd-order term: ½Σ_d[(Σ_f v)² − Σ_f v²]."""
    sum_sq = jnp.square(jnp.sum(emb, axis=1))
    sq_sum = jnp.sum(jnp.square(emb), axis=1)
    return 0.5 * jnp.sum(sum_sq - sq_sum, axis=-1)


def inner_products(emb: jnp.ndarray) -> jnp.ndarray:
    """emb: (B, F, d) -> (B, F(F-1)/2) pairwise inner products (IPNN)."""
    f = emb.shape[1]
    gram = jnp.einsum("bfd,bgd->bfg", emb, emb)
    iu, ju = jnp.triu_indices(f, k=1)
    return gram[:, iu, ju]


class CrossNetwork:
    """DCN-v1 cross layers: x_{l+1} = x0 ⊙ (x_l·w_l) + b_l + x_l."""

    @staticmethod
    def init(key, dim: int, n_layers: int = 3, dtype=jnp.float32):
        keys = jax.random.split(key, n_layers)
        return {
            "w": [initializers.normal(keys[i], (dim,), std=0.01, dtype=dtype)
                  for i in range(n_layers)],
            "b": [jnp.zeros((dim,), dtype) for _ in range(n_layers)],
        }

    @staticmethod
    def apply(params, x0: jnp.ndarray) -> jnp.ndarray:
        x = x0
        for w, b in zip(params["w"], params["b"]):
            x = x0 * (x @ w)[:, None] + b + x
        return x
