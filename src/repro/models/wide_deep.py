"""Wide & Deep [arXiv:1606.07792] — assigned config: n_sparse=40, d=32,
MLP 1024-512-256, interaction=concat.

Wide part: per-feature scalar weights (a d=1 embedding) over the raw sparse
ids. Deep part: concat field embeddings -> MLP. The d=32 table is compressed
by the pluggable compressor (MPE's home regime).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import get_compressor
from repro.embeddings.table import field_offsets, total_vocab
from repro.nn.mlp import MLP


class WideDeepConfig(NamedTuple):
    fields: tuple
    d_embed: int = 32
    mlp_hidden: tuple = (1024, 512, 256)
    compressor: str = "plain"
    comp_cfg: dict | None = None
    use_batchnorm: bool = True


class WideDeep:
    @staticmethod
    def init(key, cfg: WideDeepConfig, freqs=None):
        n = total_vocab(cfg.fields)
        f = len(cfg.fields)
        keys = jax.random.split(key, 3)
        comp = get_compressor(cfg.compressor)
        if freqs is None:
            freqs = np.ones((n,), np.float64)
        emb_params, emb_buffers = comp.init(keys[0], n, cfg.d_embed, freqs, cfg.comp_cfg)
        params = {
            "embedding": emb_params,
            "wide": jnp.zeros((n,), jnp.float32),
            "wide_bias": jnp.zeros((), jnp.float32),
            "mlp": MLP.init(keys[1], f * cfg.d_embed, cfg.mlp_hidden, d_out=1,
                            use_batchnorm=cfg.use_batchnorm),
        }
        buffers = {"embedding": emb_buffers,
                   "offsets": jnp.asarray(field_offsets(cfg.fields))}
        state = {"mlp": MLP.init_state(cfg.mlp_hidden, use_batchnorm=cfg.use_batchnorm)}
        return params, buffers, state

    @staticmethod
    def apply(params, buffers, state, batch, cfg: WideDeepConfig, *,
              train: bool = False, step=None):
        comp = get_compressor(cfg.compressor)
        gids = batch["ids"] + buffers["offsets"][None, :]
        emb = comp.lookup(params["embedding"], buffers["embedding"], gids,
                          cfg.comp_cfg, train=train, step=step)       # (B, F, d)
        b, f, d = emb.shape
        deep, new_mlp = MLP.apply(params["mlp"], state["mlp"],
                                  emb.reshape(b, f * d), train=train)
        wide = jnp.sum(jnp.take(params["wide"], gids, axis=0), axis=1)
        logit = deep[:, 0] + wide + params["wide_bias"]
        reg = comp.reg_loss(params["embedding"], buffers["embedding"], cfg.comp_cfg)
        return logit, {"mlp": new_mlp}, reg

    @staticmethod
    def loss_fn(params, buffers, state, batch, cfg: WideDeepConfig, *,
                lam: float = 0.0, train: bool = True, step=None):
        logits, new_state, reg = WideDeep.apply(params, buffers, state, batch,
                                                cfg, train=train, step=step)
        y = batch["label"].astype(jnp.float32)
        ce = jnp.mean(jnp.maximum(logits, 0) - logits * y
                      + jnp.log1p(jnp.exp(-jnp.abs(logits))))
        return ce + lam * reg, (new_state, ce)
