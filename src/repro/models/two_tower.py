"""Two-tower retrieval [Yi et al., RecSys'19] — assigned config:
embed_dim=256 (dot space = tower output), tower MLP 1024-512-256.

One global id-embedding table spans user + item fields (so MPE's global
frequency grouping applies across both); each tower concatenates its field
embeddings and maps them through its MLP. Training uses in-batch sampled
softmax with logQ correction; ``retrieval_score`` scores one query against a
candidate corpus with a single batched matmul (no loop).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import get_compressor
from repro.embeddings.table import field_offsets, total_vocab
from repro.nn.mlp import MLP


class TwoTowerConfig(NamedTuple):
    user_fields: tuple
    item_fields: tuple
    d_embed: int = 64                   # id-table dim (tower input granularity)
    tower_hidden: tuple = (1024, 512, 256)  # last = dot-space dim 256
    compressor: str = "plain"
    comp_cfg: dict | None = None
    temperature: float = 0.05
    use_batchnorm: bool = True


class TwoTower:
    @staticmethod
    def init(key, cfg: TwoTowerConfig, freqs=None):
        fields = (*cfg.user_fields, *cfg.item_fields)
        n = total_vocab(fields)
        keys = jax.random.split(key, 3)
        comp = get_compressor(cfg.compressor)
        if freqs is None:
            freqs = np.ones((n,), np.float64)
        emb_params, emb_buffers = comp.init(keys[0], n, cfg.d_embed, freqs, cfg.comp_cfg)
        fu, fi = len(cfg.user_fields), len(cfg.item_fields)
        params = {
            "embedding": emb_params,
            "user_mlp": MLP.init(keys[1], fu * cfg.d_embed, cfg.tower_hidden,
                                 use_batchnorm=cfg.use_batchnorm),
            "item_mlp": MLP.init(keys[2], fi * cfg.d_embed, cfg.tower_hidden,
                                 use_batchnorm=cfg.use_batchnorm),
        }
        offsets = field_offsets(fields)
        buffers = {
            "embedding": emb_buffers,
            "user_offsets": jnp.asarray(offsets[:fu]),
            "item_offsets": jnp.asarray(offsets[fu:]),
        }
        state = {
            "user_mlp": MLP.init_state(cfg.tower_hidden, use_batchnorm=cfg.use_batchnorm),
            "item_mlp": MLP.init_state(cfg.tower_hidden, use_batchnorm=cfg.use_batchnorm),
        }
        return params, buffers, state

    @staticmethod
    def _tower(which, params, buffers, state, ids, cfg, *, train, step):
        comp = get_compressor(cfg.compressor)
        gids = ids + buffers[f"{which}_offsets"][None, :]
        emb = comp.lookup(params["embedding"], buffers["embedding"], gids,
                          cfg.comp_cfg, train=train, step=step)
        b = emb.shape[0]
        out, new_state = MLP.apply(params[f"{which}_mlp"], state[f"{which}_mlp"],
                                   emb.reshape(b, -1), train=train)
        # L2-normalized dot space (standard for sampled-softmax retrieval)
        out = out / jnp.maximum(jnp.linalg.norm(out, axis=-1, keepdims=True), 1e-6)
        return out, new_state

    @staticmethod
    def user_tower(params, buffers, state, user_ids, cfg, *, train=False, step=None):
        return TwoTower._tower("user", params, buffers, state, user_ids, cfg,
                               train=train, step=step)

    @staticmethod
    def item_tower(params, buffers, state, item_ids, cfg, *, train=False, step=None):
        return TwoTower._tower("item", params, buffers, state, item_ids, cfg,
                               train=train, step=step)

    @staticmethod
    def loss_fn(params, buffers, state, batch, cfg: TwoTowerConfig, *,
                lam: float = 0.0, train: bool = True, step=None):
        """In-batch sampled softmax with logQ correction.

        batch: user_ids (B,Fu), item_ids (B,Fi), item_logq (B,) log sampling prob.
        """
        u, su = TwoTower.user_tower(params, buffers, state, batch["user_ids"],
                                    cfg, train=train, step=step)
        v, si = TwoTower.item_tower(params, buffers, state, batch["item_ids"],
                                    cfg, train=train, step=step)
        logits = (u @ v.T) / cfg.temperature                 # (B, B)
        if "item_logq" in batch:
            logits = logits - batch["item_logq"][None, :]    # logQ correction
        labels = jnp.arange(logits.shape[0])
        ce = jnp.mean(-jax.nn.log_softmax(logits, axis=-1)[labels, labels])
        comp = get_compressor(cfg.compressor)
        reg = comp.reg_loss(params["embedding"], buffers["embedding"], cfg.comp_cfg)
        return ce + lam * reg, ({"user_mlp": su, "item_mlp": si}, ce)

    @staticmethod
    def retrieval_score(params, buffers, state, user_ids, cand_item_ids, cfg,
                        *, top_k: int = 100, step=None):
        """user_ids: (1, Fu); cand_item_ids: (C, Fi) -> (scores, indices) top-k."""
        u, _ = TwoTower.user_tower(params, buffers, state, user_ids, cfg, train=False)
        v, _ = TwoTower.item_tower(params, buffers, state, cand_item_ids, cfg, train=False)
        scores = (v @ u[0]) / cfg.temperature                # (C,)
        return tuple(jax.lax.top_k(scores, top_k))
