"""Field bookkeeping for multi-field categorical inputs.

The paper (and CTR practice) keeps one global embedding table across all
feature fields; a sample's per-field local ids are globalized by adding the
field's vocabulary offset. This keeps MPE's frequency grouping global — a rare
user-id can land in the same precision group as a rare ad-id.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax.numpy as jnp
import numpy as np


class FieldSpec(NamedTuple):
    name: str
    vocab: int
    multiplicity: int = 1  # >1 for multi-hot fields (bag-reduced)


def field_offsets(fields: Sequence[FieldSpec]) -> np.ndarray:
    sizes = np.asarray([f.vocab for f in fields], np.int64)
    return np.concatenate([[0], np.cumsum(sizes)[:-1]]).astype(np.int32)


def total_vocab(fields: Sequence[FieldSpec]) -> int:
    return int(sum(f.vocab for f in fields))


def globalize_ids(local_ids: jnp.ndarray, offsets) -> jnp.ndarray:
    """local_ids: (B, F) per-field ids -> (B, F) global table rows."""
    return local_ids + jnp.asarray(offsets)[None, :]
