from repro.embeddings.table import FieldSpec, field_offsets, globalize_ids
from repro.embeddings.bag import embedding_bag, segment_mean
from repro.embeddings.frequency import (zipf_frequencies, count_frequencies,
                                        hot_feature_mask)

__all__ = ["FieldSpec", "field_offsets", "globalize_ids", "embedding_bag",
           "segment_mean", "zipf_frequencies", "count_frequencies",
           "hot_feature_mask"]
