"""Feature-frequency statistics — the prior MPE's grouping relies on (§3.2).

In production the counter runs over the training log; here we provide both a
host-side exact counter and the Zipf profile used by the synthetic datasets
(CTR feature popularity is famously Zipfian; Criteo's published histograms
fit a ≈ 1.05–1.2 exponent).
"""
from __future__ import annotations

import numpy as np


def zipf_frequencies(n: int, exponent: float = 1.1, seed: int | None = None) -> np.ndarray:
    """Expected access counts for a Zipf(exponent) vocabulary of size n."""
    ranks = np.arange(1, n + 1, dtype=np.float64)
    f = ranks ** (-exponent)
    if seed is not None:
        rng = np.random.default_rng(seed)
        f = f[rng.permutation(n)]  # decouple id order from rank order
    return f / f.sum()


def count_frequencies(id_batches, n: int) -> np.ndarray:
    """Exact counts over an iterable of integer-array batches."""
    counts = np.zeros((n,), np.int64)
    for batch in id_batches:
        ids = np.asarray(batch).reshape(-1)
        np.add.at(counts, ids, 1)
    return counts
