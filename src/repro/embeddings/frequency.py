"""Feature-frequency statistics — the prior MPE's grouping relies on (§3.2).

In production the counter runs over the training log; here we provide both a
host-side exact counter and the Zipf profile used by the synthetic datasets
(CTR feature popularity is famously Zipfian; Criteo's published histograms
fit a ≈ 1.05–1.2 exponent).
"""
from __future__ import annotations

import numpy as np


def zipf_frequencies(n: int, exponent: float = 1.1, seed: int | None = None) -> np.ndarray:
    """Expected access counts for a Zipf(exponent) vocabulary of size n."""
    ranks = np.arange(1, n + 1, dtype=np.float64)
    f = ranks ** (-exponent)
    if seed is not None:
        rng = np.random.default_rng(seed)
        f = f[rng.permutation(n)]  # decouple id order from rank order
    return f / f.sum()


def hot_feature_mask(frequencies, hot_fraction: float) -> np.ndarray:
    """Boolean mask of the top-``hot_fraction`` features by access frequency.

    The MPE grouping sorts features by frequency to assign precision (§3.2);
    the same ordering drives the hot/cold cache split of ``repro.cache``:
    the ``ceil(hot_fraction * n)`` most frequent features are pinned in the
    device-resident hot tier, the long tail stays in host memory. Ties are
    broken by feature id (stable), so the split is deterministic.

    ``hot_fraction`` 0 pins nothing, 1 pins everything.
    """
    f = np.asarray(frequencies, np.float64).reshape(-1)
    if not 0.0 <= hot_fraction <= 1.0:
        raise ValueError(f"hot_fraction must be in [0, 1], got {hot_fraction}")
    n_hot = int(np.ceil(hot_fraction * f.shape[0]))
    mask = np.zeros(f.shape, bool)
    if n_hot:
        # stable sort on (-freq, id): deterministic under ties
        order = np.lexsort((np.arange(f.shape[0]), -f))
        mask[order[:n_hot]] = True
    return mask


def count_frequencies(id_batches, n: int) -> np.ndarray:
    """Exact counts over an iterable of integer-array batches."""
    counts = np.zeros((n,), np.int64)
    for batch in id_batches:
        ids = np.asarray(batch).reshape(-1)
        np.add.at(counts, ids, 1)
    return counts
