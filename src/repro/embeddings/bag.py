"""EmbeddingBag — JAX has no native nn.EmbeddingBag; this IS the system.

Multi-hot bags are represented padded: ids (B, L) with a validity mask
(B, L). ``embedding_bag`` gathers rows and segment-reduces per bag. For
mixed-precision tables the gather is replaced by the compressor's lookup —
the reduce stays identical, so the bag composes with every compression method.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray, mask: jnp.ndarray | None = None,
                  *, combine: str = "sum") -> jnp.ndarray:
    """table: (n, d); ids: (B, L); mask: (B, L) bool -> (B, d)."""
    rows = jnp.take(table, ids, axis=0)                    # (B, L, d)
    return reduce_bag(rows, mask, combine=combine)


def reduce_bag(rows: jnp.ndarray, mask: jnp.ndarray | None, *, combine: str = "sum"):
    """rows: (B, L, d) already-gathered (possibly dequantized) embeddings."""
    if mask is not None:
        rows = rows * mask[..., None].astype(rows.dtype)
    if combine == "sum":
        return jnp.sum(rows, axis=-2)
    if combine == "mean":
        denom = (jnp.sum(mask, axis=-1, keepdims=True).astype(rows.dtype)
                 if mask is not None else rows.shape[-2])
        return jnp.sum(rows, axis=-2) / jnp.maximum(denom, 1.0)
    if combine == "max":
        neg = jnp.finfo(rows.dtype).min
        if mask is not None:
            rows = jnp.where(mask[..., None], rows, neg)
        return jnp.max(rows, axis=-2)
    raise ValueError(f"unknown combine {combine}")


def ragged_embedding_bag(table: jnp.ndarray, flat_ids: jnp.ndarray,
                         segment_ids: jnp.ndarray, num_bags: int,
                         *, combine: str = "sum") -> jnp.ndarray:
    """True ragged form: flat_ids (N,), segment_ids (N,) -> (num_bags, d).

    Used by the GNN message-passing path and by the data loader when bags are
    CSR-encoded; segment_sum is the TPU-native scatter-reduce.
    """
    rows = jnp.take(table, flat_ids, axis=0)               # (N, d)
    if combine == "sum":
        return jax.ops.segment_sum(rows, segment_ids, num_segments=num_bags)
    if combine == "mean":
        s = jax.ops.segment_sum(rows, segment_ids, num_segments=num_bags)
        c = jax.ops.segment_sum(jnp.ones((rows.shape[0], 1), rows.dtype),
                                segment_ids, num_segments=num_bags)
        return s / jnp.maximum(c, 1.0)
    if combine == "max":
        return jax.ops.segment_max(rows, segment_ids, num_segments=num_bags)
    raise ValueError(f"unknown combine {combine}")


def segment_mean(data: jnp.ndarray, segment_ids: jnp.ndarray, num_segments: int):
    s = jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)
    c = jax.ops.segment_sum(jnp.ones_like(data[..., :1]), segment_ids,
                            num_segments=num_segments)
    return s / jnp.maximum(c, 1.0)
