"""Context-managed current-mesh registry.

The model code never takes a mesh argument: layers ask ``repro.dist.sharding``
for the active mesh at trace time and pin activations with
``with_sharding_constraint`` only when one is installed. ``use_mesh`` is the
single entry point — it pushes onto a process-local stack *and* enters jax's
own mesh context so bare-``PartitionSpec`` constraints resolve too.

Importing this module must never touch jax device state (the smoke tests run
on 1 CPU device; only launch/dryrun.py forces 512 virtual devices).
"""
from __future__ import annotations

import contextlib

import numpy as np

import jax
from jax.sharding import Mesh

_MESH_STACK: list[Mesh] = []


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Install ``mesh`` as the current mesh for the dynamic extent.

    Nestable; the innermost mesh wins. Also enters the jax mesh context so
    library code using bare PartitionSpecs under pjit keeps working.
    """
    _MESH_STACK.append(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        _MESH_STACK.pop()


def current_mesh() -> Mesh | None:
    """The innermost ``use_mesh`` mesh, else jax's own ambient mesh, else None."""
    if _MESH_STACK:
        return _MESH_STACK[-1]
    try:  # a plain `with mesh:` entered outside repro.dist still counts
        from jax._src.mesh import thread_resources
        env_mesh = thread_resources.env.physical_mesh
        if env_mesh is not None and not env_mesh.empty:
            return env_mesh
    except Exception:  # noqa: BLE001 — internal API; absence means "no mesh"
        pass
    return None


def make_device_mesh(shape: tuple[int, ...], axis_names: tuple[str, ...]) -> Mesh:
    """Mesh over the available devices (prod 16×16 / 2×16×16, tests 1×N CPU)."""
    try:
        return jax.make_mesh(shape, axis_names)
    except AttributeError:  # older jax: build the device grid by hand
        from jax.experimental import mesh_utils
        return Mesh(mesh_utils.create_device_mesh(shape), axis_names)


def parse_mesh_flag(flag: str | None) -> Mesh | None:
    """``--mesh dp,mp`` CLI flag → a ("data", "model") host mesh, or None.

    ``"2,2"`` builds a 2×2 mesh over the visible devices (fails loudly when
    fewer than dp·mp are visible — virtualize CPU devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``); ``"auto"``
    spreads every visible device on the data axis; None/"" disables.
    """
    if not flag:
        return None
    if flag == "auto":
        return host_mesh()
    try:
        n_data, n_model = (int(x) for x in flag.split(","))
    except ValueError as e:
        raise SystemExit(f"--mesh expects 'dp,mp' or 'auto', got {flag!r}") from e
    n_dev = len(jax.devices())
    if n_data * n_model > n_dev:
        raise SystemExit(
            f"--mesh {flag}: needs {n_data * n_model} devices, "
            f"{n_dev} visible (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_data * n_model})")
    return host_mesh(n_data=n_data, n_model=n_model)


def host_mesh(n_data: int | None = None, n_model: int = 1) -> Mesh:
    """("data", "model") mesh over host devices — the test-time mesh.

    Defaults to all visible devices on the data axis. Under
    ``--xla_force_host_platform_device_count=4`` this yields a real 4-way
    mesh; on a stock single-device CPU it is a 1×1 mesh, on which every
    constraint in ``repro.dist.sharding`` is a no-op.
    """
    devs = jax.devices()
    if n_data is None:
        n_data = len(devs) // n_model
    grid = np.asarray(devs[: n_data * n_model]).reshape(n_data, n_model)
    return Mesh(grid, ("data", "model"))
