"""Context-managed current-mesh registry.

The model code never takes a mesh argument: layers ask ``repro.dist.sharding``
for the active mesh at trace time and pin activations with
``with_sharding_constraint`` only when one is installed. ``use_mesh`` is the
single entry point — it pushes onto a process-local stack *and* enters jax's
own mesh context so bare-``PartitionSpec`` constraints resolve too.

Importing this module must never touch jax device state (the smoke tests run
on 1 CPU device; only launch/dryrun.py forces 512 virtual devices).
"""
from __future__ import annotations

import contextlib

import numpy as np

import jax
from jax.sharding import Mesh

_MESH_STACK: list[Mesh] = []
_DIST_INITIALIZED = False


def init_distributed(coordinator: str | None = None,
                     num_processes: int | None = None,
                     process_id: int | None = None,
                     local_device_ids=None) -> bool:
    """Bring up the multi-host runtime (``jax.distributed.initialize``).

    Call once per process, before the first device query. With no
    ``coordinator`` and ``num_processes`` in (None, 0, 1) this is a
    documented no-op — the single-host default of the launch CLIs — so
    tests and one-box serving never touch the distributed client.
    Idempotent: a second call after a successful init returns True without
    re-initializing. Returns True when a multi-process runtime is up.

    The launch CLIs reach this through ``--coordinator``/``--num-hosts``/
    ``--host-id``; afterwards ``jax.devices()`` spans every host and
    ``host_mesh(..., n_pod=...)`` lays the "pod" axis on host boundaries
    (see ``host_boundary_groups``), which is what lets the MPE packed
    subtables row-shard *across* hosts under
    ``host_packed_table_pspecs``."""
    global _DIST_INITIALIZED
    if coordinator is None and num_processes in (None, 0, 1):
        return _DIST_INITIALIZED
    if _DIST_INITIALIZED:
        return True
    kwargs = {}
    if coordinator is not None:
        kwargs["coordinator_address"] = coordinator
    if num_processes is not None:
        kwargs["num_processes"] = num_processes
    if process_id is not None:
        kwargs["process_id"] = process_id
    if local_device_ids is not None:
        kwargs["local_device_ids"] = local_device_ids
    jax.distributed.initialize(**kwargs)
    _DIST_INITIALIZED = True
    return True


def host_boundary_groups() -> list[list]:
    """Visible devices grouped by owning process (host), process-major.

    Group ``g`` holds the devices whose ``process_index`` is the g-th
    smallest — the host boundary a leading ("pod", ...) mesh axis must
    align with so the inner ("data", "model") axes stay host-local:
    row-shard groups and a2a peer rings then cross the network only along
    "pod". Single-process returns one group with every device."""
    groups: dict[int, list] = {}
    for dev in jax.devices():
        groups.setdefault(dev.process_index, []).append(dev)
    return [groups[p] for p in sorted(groups)]


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Install ``mesh`` as the current mesh for the dynamic extent.

    Nestable; the innermost mesh wins. Also enters the jax mesh context so
    library code using bare PartitionSpecs under pjit keeps working.
    """
    _MESH_STACK.append(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        _MESH_STACK.pop()


def current_mesh() -> Mesh | None:
    """The innermost ``use_mesh`` mesh, else jax's own ambient mesh, else None."""
    if _MESH_STACK:
        return _MESH_STACK[-1]
    try:  # a plain `with mesh:` entered outside repro.dist still counts
        from jax._src.mesh import thread_resources
        env_mesh = thread_resources.env.physical_mesh
        if env_mesh is not None and not env_mesh.empty:
            return env_mesh
    except Exception:  # noqa: BLE001 — internal API; absence means "no mesh"
        pass
    return None


def make_device_mesh(shape: tuple[int, ...], axis_names: tuple[str, ...]) -> Mesh:
    """Mesh over the available devices (prod 16×16 / 2×16×16, tests 1×N CPU)."""
    try:
        return jax.make_mesh(shape, axis_names)
    except AttributeError:  # older jax: build the device grid by hand
        from jax.experimental import mesh_utils
        return Mesh(mesh_utils.create_device_mesh(shape), axis_names)


def parse_mesh_flag(flag: str | None) -> Mesh | None:
    """``--mesh`` CLI flag → a host mesh, or None.

    ``"dp,mp"`` (e.g. ``"2,2"``) builds a ("data", "model") mesh;
    ``"pod,dp,mp"`` (e.g. ``"1,2,2"``) a ("pod", "data", "model") multi-pod
    mesh — the shard wrappers are axis-generic, so everything that runs on
    the two-axis mesh runs on the three-axis one (batch spreads over every
    non-"model" axis). Fails loudly when fewer than the product of the axis
    sizes are visible — virtualize CPU devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``. ``"auto"``
    spreads every visible device on the data axis; None/"" disables.
    """
    if not flag:
        return None
    if flag == "auto":
        return host_mesh()
    try:
        sizes = tuple(int(x) for x in flag.split(","))
        if len(sizes) not in (2, 3):
            raise ValueError(flag)
    except ValueError as e:
        raise SystemExit(
            f"--mesh expects 'dp,mp', 'pod,dp,mp' or 'auto', got {flag!r}"
        ) from e
    n_need = 1
    for s in sizes:
        n_need *= s
    n_dev = len(jax.devices())
    if n_need > n_dev:
        raise SystemExit(
            f"--mesh {flag}: needs {n_need} devices, "
            f"{n_dev} visible (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_need})")
    if len(sizes) == 2:
        return host_mesh(n_data=sizes[0], n_model=sizes[1])
    return host_mesh(n_data=sizes[1], n_model=sizes[2], n_pod=sizes[0])


def host_mesh(n_data: int | None = None, n_model: int = 1,
              n_pod: int | None = None) -> Mesh:
    """("data", "model") mesh over host devices — the test-time mesh — or,
    with ``n_pod``, the multi-pod ("pod", "data", "model") layout.

    Defaults to all visible devices on the data axis. Under
    ``--xla_force_host_platform_device_count=4`` this yields a real 4-way
    mesh; on a stock single-device CPU it is a 1×1 mesh, on which every
    constraint in ``repro.dist.sharding`` is a no-op.
    """
    devs = jax.devices()
    if n_data is None:
        n_data = len(devs) // ((n_pod or 1) * n_model)
    if n_pod is None:
        grid = np.asarray(devs[: n_data * n_model]).reshape(n_data, n_model)
        return Mesh(grid, ("data", "model"))
    if len({d.process_index for d in devs}) > 1:
        # multi-host: order host-major so "pod" boundaries are host
        # boundaries and the inner axes stay host-local
        devs = [d for group in host_boundary_groups() for d in group]
    grid = np.asarray(devs[: n_pod * n_data * n_model]).reshape(
        n_pod, n_data, n_model)
    return Mesh(grid, ("pod", "data", "model"))
