"""Partition-spec contract for every workload family on the production mesh.

Mesh axes: ``("data", "model")`` single-pod (16×16 TPU v5e), with a leading
``"pod"`` axis (2×16×16) multi-pod. Three pspec families:

  LM       — params FSDP-style (last dim over "model", second-to-last over
             "data"); token batches over the data axes; KV caches with the
             sequence dim over "model" (batch over data when batch > 1).
  recsys   — (n, d) embedding tables row-sharded over ``rows_axes`` (vocab
             rows are the dominant bytes); γ/α/β side params and the MLP
             stay replicated.
  MPE pack — one bit-packed uint32 subtable per candidate width, each
             row-sharded over ``rows_axes``. Rows are padded to multiples of
             512 (``core.inference._pad_rows``), so row shards stay aligned
             to the packed-row groups of ``core/packing.py`` — the uint32
             words of one embedding row never split across devices (codes
             straddle word boundaries; a row is only decodable whole).

In-model helpers (``maybe_shard``, ``shard_batch_dim``, ``current_dp_axes``)
read the registry in ``repro.dist.mesh`` at trace time and degrade to no-ops
when no mesh (or a single-device mesh) is active, so the same model code runs
unmodified in 1-device tests and 512-chip dry-runs.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.mesh import current_mesh

# Production axis sizes (launch/mesh.py): only dims divisible by these are
# assigned a mesh axis — everything else stays replicated, which keeps every
# pspec valid on any submesh (1×1 test mesh included).
PROD_AXIS_SIZE = {"pod": 2, "data": 16, "model": 16}

# ---------------------------------------------------------------------------
# machine-readable spec contract (read by repro.analysis.shardspec)
# ---------------------------------------------------------------------------

#: Every mesh axis any pspec family may name. A spec entry naming an axis
#: outside this set can never resolve on a production mesh (SC201).
MESH_AXES = frozenset(PROD_AXIS_SIZE)

#: The axis *groups* a single pspec dim may combine, normalized to tuples in
#: mesh order. ``("pod", "data")`` is the multi-pod batch dim;
#: ``("data", "model")`` / ("pod","data","model") are the every-axis row
#: splits of ``sharded_mixed_expectation``; ``("pod", "model")`` is the
#: cross-host table-row split of ``host_packed_table_pspecs`` (pod-major:
#: host boundaries outermost, so a shard's neighbours along "model" stay
#: host-local); singletons are the common case. A dim entry outside this
#: family is out of contract (SC202) — e.g. ``("model", "data")`` (wrong
#: order ⇒ wrong row-major shard index) or an ad-hoc axis pairing no
#: wrapper produces.
AXIS_GROUPS = frozenset({
    ("pod",), ("data",), ("model",),
    ("pod", "data"), ("pod", "model"), ("data", "model"),
    ("pod", "data", "model"),
})

#: name → builder for every pspec family below; ``repro.analysis`` resolves
#: cell/wrapper specs against this registry (a spec is in contract when each
#: of its dim entries normalizes into AXIS_GROUPS — the families themselves
#: only ever emit such entries).
SPEC_FAMILIES = {}


def _family(fn):
    SPEC_FAMILIES[fn.__name__] = fn
    return fn


def normalize_entry(entry) -> tuple[str, ...] | None:
    """One PartitionSpec dim entry → tuple-of-axes (None stays None).

    ``P("data")`` and ``P(("data",))`` are the same placement; the analysis
    passes compare normalized entries against ``AXIS_GROUPS``."""
    if entry is None:
        return None
    if isinstance(entry, str):
        return (entry,)
    return tuple(entry)


def spec_in_contract(spec) -> bool:
    """True when every dim entry of ``spec`` is a registered axis group."""
    for entry in tuple(spec):
        norm = normalize_entry(entry)
        if norm is not None and norm not in AXIS_GROUPS:
            return False
    return True


def dp_axes(multi_pod: bool = False) -> tuple[str, ...]:
    """The data-parallel (batch) axes of the production mesh."""
    return ("pod", "data") if multi_pod else ("data",)


def current_dp_axes() -> tuple[str, ...] | None:
    """Batch axes of the active mesh, or None when sharding is a no-op."""
    mesh = current_mesh()
    if mesh is None or mesh.size <= 1:
        return None
    dp = tuple(n for n in mesh.axis_names if n != "model")
    return dp or None


# ---------------------------------------------------------------------------
# constraint helpers (trace-time no-ops without a multi-device mesh)
# ---------------------------------------------------------------------------

def _axes_size(mesh, entry) -> int:
    names = (entry,) if isinstance(entry, str) else tuple(entry)
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size


def _fit_spec(shape, spec, mesh):
    """Drop pspec entries whose axes are unknown to ``mesh`` or don't divide
    the dim — a constraint we can't honor cleanly becomes "replicated"."""
    fitted = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * len(shape)):
        if entry is None:
            fitted.append(None)
            continue
        names = (entry,) if isinstance(entry, str) else tuple(entry)
        if not all(n in mesh.shape for n in names):
            fitted.append(None)
            continue
        fitted.append(entry if dim % _axes_size(mesh, entry) == 0 else None)
    return P(*fitted)


def maybe_shard(x, spec: P):
    """``with_sharding_constraint`` against the active mesh; identity when no
    multi-device mesh is installed or the spec doesn't fit ``x``."""
    mesh = current_mesh()
    if mesh is None or mesh.size <= 1:
        return x
    fitted = _fit_spec(x.shape, spec, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, fitted))


def shard_batch_dim(x, axis: int = 0):
    """Pin ``x``'s batch dim to the data axes (other dims replicated)."""
    dp = current_dp_axes()
    if dp is None:
        return x
    entries = [None] * x.ndim
    entries[axis] = dp
    return maybe_shard(x, P(*entries))


# ---------------------------------------------------------------------------
# tree utilities
# ---------------------------------------------------------------------------

def _is_pspec(x) -> bool:
    return isinstance(x, P)


def tree_named_shardings(mesh, pspec_tree):
    """Map a pytree of PartitionSpecs to NamedShardings on ``mesh``."""
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps), pspec_tree,
                        is_leaf=_is_pspec)


def replicate_like(tree):
    """Rank-matched fully-replicated pspecs for every leaf of ``tree``."""
    return jax.tree.map(lambda x: P(*([None] * x.ndim)), tree)


def cell_shardings(mesh, cell):
    """(in_shardings, out_shardings) NamedShardings for a launch cell."""
    ins = tuple(tree_named_shardings(mesh, ps) for ps in cell.in_pspecs)
    outs = tree_named_shardings(mesh, cell.out_pspecs)
    return ins, outs


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def _fsdp_leaf_spec(leaf) -> P:
    """FSDP-style storage spec: last dim over "model", second-to-last over
    "data" — assigned only when the production axis size divides the dim.
    1-D leaves (norm scales, biases) and scalars stay replicated."""
    nd = leaf.ndim
    if nd < 2:
        return P(*([None] * nd))
    entries = [None] * nd
    if leaf.shape[-1] % PROD_AXIS_SIZE["model"] == 0:
        entries[-1] = "model"
    if leaf.shape[-2] % PROD_AXIS_SIZE["data"] == 0:
        entries[-2] = "data"
    return P(*entries)


@_family
def lm_param_pspecs(params_sds, cfg=None):
    """Pspecs matching the LM param tree (stacked-layer leaves included).

    Weights live FSDP-sharded in HBM; ``LM._gather_fsdp_weights`` re-pins
    them to "model"-only layouts inside the scan body at apply time, so this
    only fixes the at-rest placement. ``cfg`` is accepted for call-site
    stability (expert layout already falls out of the generic rule).
    """
    del cfg
    return jax.tree.map(_fsdp_leaf_spec, params_sds)


@_family
def lm_logits_pspecs(batch: int, *, vocab_sharded: bool = False, dp=None,
                     multi_pod: bool = False) -> P:
    """Logits ``(B, V)`` of a prefill/decode step.

    Batched steps shard the batch over the data axes (``dp`` overrides the
    production ``dp_axes`` for cells compiled against a custom data tuple)
    with the vocab dim optionally over "model" (prefill keeps it sharded —
    the ``lm_head`` matmul output layout); a ``batch == 1`` step has nothing
    to split on the data axes, so the vocab dim takes "model" instead. The
    serve/launch decode cells previously hand-rolled this split at four call
    sites — staticcheck SC202 now pins them here."""
    if batch > 1:
        axes = tuple(dp) if dp is not None else dp_axes(multi_pod)
        return P(axes, "model" if vocab_sharded else None)
    return P(None, "model")


@_family
def lm_batch_pspecs(multi_pod: bool = False):
    """{"tokens", "labels"}: (B, S) int32, batch over the data axes."""
    dp = dp_axes(multi_pod)
    return {"tokens": P(dp, None), "labels": P(dp, None)}


@_family
def lm_cache_pspecs(*, long_context: bool = False, multi_pod: bool = False):
    """Stacked KV caches {"k","v": (L, B, T, n_kv, hd), "len": ()}.

    The cache-length dim T shards over "model" (always mesh-divisible at the
    assigned shapes; kv-head counts are not). Batch shards over the data axes
    except in the long-context cell (B=1 — nothing to split)."""
    batch_ax = None if long_context else dp_axes(multi_pod)
    kv = P(None, batch_ax, "model", None, None)
    return {"k": kv, "v": kv, "len": P()}


@_family
def lm_kv_cache_pspecs(*, quantized: bool = False, long_context: bool = False,
                       multi_pod: bool = False):
    """``lm_cache_pspecs`` plus the int8 per-(layer, batch, head) scale
    entries {"k_scale","v_scale": (L, B, 1, n_kv, 1)} when ``quantized``.

    Scales shard with the cache batch axis only — the T and head dims are
    size-1/ungathered, so everything else replicates."""
    ps = lm_cache_pspecs(long_context=long_context, multi_pod=multi_pod)
    if quantized:
        scale_ps = P(None, ps["k"][1], None, None, None)
        ps = dict(ps, k_scale=scale_ps, v_scale=scale_ps)
    return ps


# ---------------------------------------------------------------------------
# recsys embedding tables (search/train phase)
# ---------------------------------------------------------------------------

@_family
def recsys_table_pspecs(rows_axes, emb_sds=None):
    """MPE search-phase embedding params: the (n, d) table row-shards over
    ``rows_axes``; γ is (n/group_size, m) — not generally mesh-divisible and
    7 floats per group — so it and α/β replicate.

    With ``emb_sds`` (a param dict from any compressor), unknown leaves get
    rank-matched replicated specs so the tree structures always align."""
    base = {"emb": P(rows_axes, None), "gamma": P(None, None),
            "alpha": P(None), "beta": P(None)}
    if emb_sds is None:
        return base
    return {k: base[k] if k in base else P(*([None] * v.ndim))
            for k, v in emb_sds.items()}


# ---------------------------------------------------------------------------
# MPE packed serving tables
# ---------------------------------------------------------------------------

@_family
def packed_table_pspecs(table_sds, *, rows_axes=("model",)):
    """Pspecs for a packed inference table (core/inference.py layout).

    Each per-width subtable (rows, words_per_row) row-shards over
    ``rows_axes``; production-scale subtables pad their rows to multiples of
    512 (``core.inference._auto_pad_multiple``), which every production axis
    combination divides, so shard boundaries always land on whole packed
    rows. Small tables pad to a smaller power of two and simply replicate
    (``maybe_shard`` drops non-dividing axes). The word dim is never split
    (a row's codes straddle word boundaries). The id→(bucket, local row)
    index vectors are gathered by every device and replicate, as do the
    dequant params α/β."""
    return {
        "subtables": {k: P(rows_axes, None) for k in table_sds["subtables"]},
        "local_idx": P(None),
        "width_idx": P(None),
        "alpha": P(None),
        "beta": P(None),
    }


@_family
def host_packed_table_pspecs(table_sds, *, rows_axes=("pod", "model")):
    """Multi-host layout for a packed inference table: subtable rows shard
    over ``("pod", "model")`` — the vocab split that fits on no single host.

    The "pod" axis sits on host boundaries (``mesh.host_boundary_groups`` /
    ``host_mesh(n_pod=...)``), so the row-major shard index of
    ``rows_shard_index`` walks hosts outermost: one host owns a contiguous
    row range and its "model"-axis neighbours are host-local, which keeps
    the capacity-bucketed all-to-all's dense peer traffic on-host and only
    the pod hop cross-host. Everything else matches
    ``packed_table_pspecs``: the word dim never splits, the id→(bucket,
    row) vectors and α/β replicate (every host resolves every id)."""
    return packed_table_pspecs(table_sds, rows_axes=tuple(rows_axes))


@_family
def tiered_hot_pspecs(hot_sds, *, rows_axes=("model",)):
    """Pspecs for the **hot tier** of a ``repro.cache.TieredTableStore``.

    The hot tier is the device-resident half of the hot/cold split and the
    only half that ever sees the mesh — the cold tier lives in host memory
    and reaches devices per request as already-placed ``device_put`` fills.
    Hot subtables row-shard over ``rows_axes`` exactly like the monolithic
    ``packed_table_pspecs`` layout (rows padded to the same multiples, so
    shard boundaries land on whole packed rows); the id→(tier, local row)
    routing vectors and the dequant params replicate, as every device
    resolves every id."""
    return {
        "subtables": {k: P(rows_axes, None) for k in hot_sds["subtables"]},
        "tier_local": P(None),
        "is_hot": P(None),
        "width_idx": P(None),
        "alpha": P(None),
        "beta": P(None),
    }


@_family
def packed_serve_pspecs(params, *, rows_axes=("model",),
                        row_keys=("wide", "fm_linear")):
    """Full param-tree pspecs for a model serving from a packed table.

    ``params["embedding"]`` gets the packed-table layout above; per-feature
    1-D vectors named in ``row_keys`` (wide & deep's linear term, DeepFM's
    first-order weights) row-shard with the vocab; everything else — MLP,
    cross layers, towers — replicates. Used by both the dry-run serve cells
    (``launch/cells.py``) and the live engine (``repro.serve``)."""
    pspecs = {k: replicate_like(v) for k, v in params.items()
              if k != "embedding"}
    pspecs["embedding"] = packed_table_pspecs(params["embedding"],
                                              rows_axes=rows_axes)
    for k in row_keys:
        if k in params:
            pspecs[k] = P(rows_axes)
    return pspecs
