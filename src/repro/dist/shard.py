"""shard_map layer: run the fused kernels and cells *inside* the partitioner.

``pjit`` slices a computation after the fact; ``shard_map`` places it — each
device runs the body on its local block and every cross-device byte is an
explicit collective. This module is the bridge between the Pallas kernels
(written against local arrays) and the ``("data", "model")`` mesh contract of
``repro.dist.sharding``: every wrapper derives its in/out specs from the
pspec families (``packed_table_pspecs``, ``tiered_hot_pspecs``,
``recsys_table_pspecs``) and degrades to the single-device path when no
multi-device mesh is active, so the same call site serves 1-CPU tests and a
real mesh.

Placement per wrapper:

  ``sharded_packed_lookup``    subtables row-sharded over ``rows_axes``
                               ("model"), ids batch-sharded over the data
                               axes; device-local gather+unpack+dequant with
                               an ownership mask, then ONE ``psum`` over the
                               row axes merges the buckets. Each id owns
                               exactly one (bucket, row), so the psum adds
                               one non-zero term to zeros — bit-exact against
                               the jitted single-device reference. (A
                               capacity-bucketed all-to-all id shuffle would
                               move ~32/b× fewer bytes but drops ids on
                               overflow; the masked psum is capacity-free.)
  ``sharded_tiered_hot_lookup``  same layout for the hot tier of a
                               ``repro.cache.TieredTableStore`` (zeros at
                               cold positions, merged by the caller).
  ``sharded_embedding_bag``    table rows over ``rows_axes``, bags over the
                               data axes; per-device partial bag sums +
                               psum. NOT bit-exact for >1 row shard (the
                               psum reassociates the bag sum) — documented
                               tolerance ~1e-6 relative.
  ``sharded_flash_attention``  batch over the data axes, heads over
                               "model"; no collectives, bit-exact.
  ``sharded_mixed_expectation`` rows over every mesh axis (row-parallel
                               QAT); no collectives, bit-exact.
  ``sharded_value_and_grad``   the train step's grad: batch data-parallel
                               over the mesh, embedding-table leaves stored
                               row-sharded over ``rows_axes`` (specs from
                               ``recsys_table_pspecs``) and all-gathered in
                               the body; autodiff transposes the gather into
                               a psum-scatter, so table grads arrive
                               row-shard-local while replicated MLP/side
                               params get a ``pmean`` over the batch axes.

Tables whose rows don't divide the row-axis size are padded up to the next
multiple (``pad_rows_to_shard``) — pad rows carry zero words and are never
owned by a real id, so they change no result (the pad-to-shard path).

Call the wrappers from traced code (under ``jax.jit`` — the serve cells and
the train step always are): eagerly-executed ``shard_map`` on jax 0.4.37
reassembles replicated outputs incorrectly for some mesh shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import packing
from repro.core.quantizer import dequantize_codes
from repro.dist.mesh import current_mesh
from repro.dist.sharding import replicate_like

__all__ = [
    "active_mesh", "pad_rows_to_shard", "rows_shard_index",
    "sharded_packed_lookup", "sharded_tiered_hot_lookup",
    "sharded_embedding_bag", "sharded_flash_attention",
    "sharded_mixed_expectation", "sharded_value_and_grad",
]


# ---------------------------------------------------------------------------
# mesh plumbing
# ---------------------------------------------------------------------------

def active_mesh(mesh=None):
    """``mesh`` or the registry's current mesh — None when sharding is a
    no-op (no mesh, or a 1-device mesh)."""
    mesh = mesh if mesh is not None else current_mesh()
    if mesh is None or mesh.size <= 1:
        return None
    return mesh


def _present_axes(mesh, axes) -> tuple[str, ...]:
    return tuple(a for a in axes if a in mesh.shape)


def _axes_size(mesh, axes) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def _dp_axes_of(mesh, rows_axes) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a not in rows_axes)


def _batch_entry(mesh, dim: int, axes) -> tuple[str, ...] | None:
    """The pspec entry for a batch dim: ``axes`` when they divide it, else
    replicated (mirrors ``sharding._fit_spec``)."""
    if axes and dim % _axes_size(mesh, axes) == 0:
        return tuple(axes)
    return None


def pad_rows_to_shard(x, n_shards: int):
    """Pad dim 0 up to a multiple of ``n_shards`` with zeros (the
    pad-to-shard path for tables whose rows don't divide the row axes).
    Zero packed words decode to the most-negative code, but pad rows are
    never *owned* by a real id, so no result can read them.

    Implemented with ``jnp.pad``, NOT ``jnp.concatenate``: on jax 0.4.37 the
    SPMD partitioner mis-lowers an uneven concatenate that feeds a
    ``shard_map`` row-sharded operand (wrong rows reach the shards on a 2×2
    mesh — see tests/test_shard.py::test_packed_lookup_pad_to_shard_edge,
    which fails with the concatenate formulation)."""
    pad = (-x.shape[0]) % n_shards
    if pad == 0:
        return x
    return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))


def rows_shard_index(mesh, rows_axes):
    """Linear shard index of this device along ``rows_axes`` (row-major over
    the axes tuple, matching ``PartitionSpec((a, b), ...)`` layout). Call
    inside a ``shard_map`` body."""
    idx = jnp.int32(0)
    for a in rows_axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


# ---------------------------------------------------------------------------
# packed-table lookup (repro.kernels.mpe_lookup / core.inference)
# ---------------------------------------------------------------------------

def _bucket_dequant(sub, loc, alpha_i, beta, *, b, d, use_kernel, interpret):
    """Device-local gather+unpack+dequant of one width bucket — the fused
    Pallas kernel or its jnp formulation, on local rows only."""
    if use_kernel:
        from repro.kernels.mpe_lookup.kernel import packed_lookup_pallas
        return packed_lookup_pallas(loc, sub, alpha_i, beta, b=b, d=d,
                                    interpret=interpret)
    words = jnp.take(sub, loc, axis=0)
    codes = packing.unpack_codes(words, b, d)
    return dequantize_codes(codes, alpha_i, beta)


def sharded_packed_lookup(table, meta, ids, *, rows_axes=("model",),
                          mesh=None, use_kernel: bool = False,
                          interpret: bool = True):
    """``core.inference.packed_lookup`` under ``shard_map``: subtables
    row-sharded over ``rows_axes`` (layout: ``packed_table_pspecs``), ids
    batch-sharded over the remaining axes, one ``psum`` over the row axes.

    Degrades to the single-device lookup when no multi-device mesh is active
    (or none of ``rows_axes`` is on it). ``use_kernel`` runs the fused
    Pallas kernel per bucket inside the body. Bit-exact against the jitted
    single-device reference (see module docstring)."""
    from repro.core.inference import packed_lookup

    mesh = active_mesh(mesh)
    if mesh is None:
        if use_kernel:
            from repro.kernels.mpe_lookup.ops import packed_lookup_kernel
            return packed_lookup_kernel(table, meta, ids, interpret=interpret)
        return packed_lookup(table, meta, ids)
    rows_ax = _present_axes(mesh, rows_axes)
    mp = _axes_size(mesh, rows_ax)

    bits, d = meta["bits"], meta["d"]
    dp = _dp_axes_of(mesh, rows_ax)
    flat = ids.reshape(-1)
    batch_ax = _batch_entry(mesh, flat.shape[0], dp)

    tbl = dict(table, subtables={k: pad_rows_to_shard(v, mp)
                                 for k, v in table["subtables"].items()})

    def body(subs, local_idx, width_idx, alpha, beta, fl):
        widx = jnp.take(width_idx, fl, axis=0)
        lidx = jnp.take(local_idx, fl, axis=0)
        base = rows_shard_index(mesh, rows_ax)
        out = jnp.zeros((fl.shape[0], d), jnp.float32)
        for i, b in enumerate(bits):
            if b == 0:
                continue  # zero-width features contribute the zero vector
            sub = subs[f"b{b}"]
            rows_loc = sub.shape[0]
            loc = lidx - base * rows_loc
            own = (loc >= 0) & (loc < rows_loc)
            deq = _bucket_dequant(sub, jnp.clip(loc, 0, rows_loc - 1),
                                  alpha[i], beta, b=b, d=d,
                                  use_kernel=use_kernel, interpret=interpret)
            out = jnp.where((own & (widx == i))[:, None], deq, out)
        # one non-zero owner per id: the psum adds zeros — exact
        return jax.lax.psum(out, rows_ax) if rows_ax else out

    in_specs = ({k: P(rows_ax or None, None) for k in tbl["subtables"]},
                P(None), P(None), P(None), P(None), P(batch_ax))
    out = shard_map(body, mesh, in_specs=in_specs,
                    out_specs=P(batch_ax, None), check_rep=False)(
        tbl["subtables"], tbl["local_idx"], tbl["width_idx"],
        tbl["alpha"], tbl["beta"], flat)
    return out.reshape(*ids.shape, d)


def sharded_tiered_hot_lookup(hot, bits, d: int, ids, *,
                              rows_axes=("model",), mesh=None):
    """``repro.cache.tiers.tiered_hot_lookup`` under ``shard_map``: hot
    subtables row-sharded per ``tiered_hot_pspecs``, zeros at cold positions
    (the caller merges the cold fill). Bit-exact like the packed lookup —
    the ownership mask additionally requires the hot bit."""
    from repro.cache.tiers import tiered_hot_lookup

    mesh = active_mesh(mesh)
    if mesh is None:
        return tiered_hot_lookup(hot, bits, d, ids)
    rows_ax = _present_axes(mesh, rows_axes)
    mp = _axes_size(mesh, rows_ax)

    dp = _dp_axes_of(mesh, rows_ax)
    flat = ids.reshape(-1)
    batch_ax = _batch_entry(mesh, flat.shape[0], dp)
    hot_p = dict(hot, subtables={k: pad_rows_to_shard(v, mp)
                                 for k, v in hot["subtables"].items()})

    def body(subs, tier_local, is_hot, width_idx, alpha, beta, fl):
        widx = jnp.take(width_idx, fl, axis=0)
        lidx = jnp.take(tier_local, fl, axis=0)
        hot_bit = jnp.take(is_hot, fl, axis=0)
        base = rows_shard_index(mesh, rows_ax)
        out = jnp.zeros((fl.shape[0], d), jnp.float32)
        for i, b in enumerate(bits):
            if b == 0:
                continue
            sub = subs[f"b{b}"]
            rows_loc = sub.shape[0]
            loc = lidx - base * rows_loc
            own = (loc >= 0) & (loc < rows_loc) & hot_bit
            words = jnp.take(sub, jnp.clip(loc, 0, rows_loc - 1), axis=0)
            codes = packing.unpack_codes(words, b, d)
            deq = dequantize_codes(codes, alpha[i], beta)
            out = jnp.where((own & (widx == i))[:, None], deq, out)
        return jax.lax.psum(out, rows_ax) if rows_ax else out

    in_specs = ({k: P(rows_ax or None, None) for k in hot_p["subtables"]},
                P(None), P(None), P(None), P(None), P(None), P(batch_ax))
    out = shard_map(body, mesh, in_specs=in_specs,
                    out_specs=P(batch_ax, None), check_rep=False)(
        hot_p["subtables"], hot_p["tier_local"], hot_p["is_hot"],
        hot_p["width_idx"], hot_p["alpha"], hot_p["beta"], flat)
    return out.reshape(*ids.shape, d)


# ---------------------------------------------------------------------------
# embedding bag (repro.kernels.embedding_bag)
# ---------------------------------------------------------------------------

def sharded_embedding_bag(table, ids, mask, *, rows_axes=("model",),
                          mesh=None, use_kernel: bool = True,
                          interpret: bool = True):
    """Multi-hot embedding bag under ``shard_map``: the (N, d) table
    row-sharded over ``rows_axes`` (layout: ``recsys_table_pspecs``), bags
    batch-sharded over the data axes; each device sums its owned slots with
    the fused kernel, one ``psum`` merges the partial bags.

    NOT bit-exact for >1 row shard: a bag whose slots land on different
    shards has its sum reassociated by the psum (~1e-6 relative on fp32).
    Exact when ``rows_axes`` resolve to a single shard (pure batch
    sharding)."""
    from repro.kernels.embedding_bag.kernel import embedding_bag_pallas
    from repro.kernels.embedding_bag.ref import embedding_bag_ref

    mesh = active_mesh(mesh)
    rows_ax = _present_axes(mesh, rows_axes) if mesh is not None else ()
    mp = _axes_size(mesh, rows_ax) if mesh is not None else 1
    local = (embedding_bag_pallas if use_kernel else embedding_bag_ref)
    kw = {"interpret": interpret} if use_kernel else {}
    if mesh is None:
        return local(table, ids, mask, **kw)

    dp = _dp_axes_of(mesh, rows_ax)
    batch_ax = _batch_entry(mesh, ids.shape[0], dp)
    tab = pad_rows_to_shard(table, mp) if mp > 1 else table

    def body(tab_loc, ids_b, mask_b):
        rows_loc = tab_loc.shape[0]
        base = rows_shard_index(mesh, rows_ax) * rows_loc
        own = (ids_b >= base) & (ids_b < base + rows_loc)
        loc = jnp.clip(ids_b - base, 0, rows_loc - 1)
        part = local(tab_loc, loc, mask_b & own, **kw)
        return jax.lax.psum(part, rows_ax) if mp > 1 else part

    in_specs = (P(rows_ax if mp > 1 else None, None),
                P(batch_ax, None), P(batch_ax, None))
    return shard_map(body, mesh, in_specs=in_specs,
                     out_specs=P(batch_ax, None), check_rep=False)(
        tab, ids.astype(jnp.int32), mask.astype(bool))


# ---------------------------------------------------------------------------
# flash attention (repro.kernels.flash_attention)
# ---------------------------------------------------------------------------

def sharded_flash_attention(q, k, v, *, n_kv_heads: int | None = None,
                            causal: bool = True, bq: int = 128, bk: int = 128,
                            head_axes=("model",), mesh=None,
                            interpret: bool = True):
    """Flash attention under ``shard_map``: batch over the data axes, query
    heads over ``head_axes`` — every (batch, head) pair computes wholly on
    one device, so there are no collectives and the result is bit-exact
    against the single-device kernel. GQA KV expansion happens *before* the
    shard_map so the head sharding stays aligned."""
    from repro.kernels.flash_attention.ops import flash_attention_kernel

    del n_kv_heads  # derived from the shapes, as in the flat wrapper
    mesh = active_mesh(mesh)
    if mesh is None:
        return flash_attention_kernel(q, k, v, causal=causal, bq=bq, bk=bk,
                                      interpret=interpret)

    hq, hkv = q.shape[2], k.shape[2]
    if hkv != hq:  # GQA: expand KV to query heads before placing
        k = jnp.repeat(k, hq // hkv, axis=2)
        v = jnp.repeat(v, hq // hkv, axis=2)

    head_ax = _present_axes(mesh, head_axes)
    dp = _dp_axes_of(mesh, head_ax)
    batch_ax = _batch_entry(mesh, q.shape[0], dp)
    head_entry = _batch_entry(mesh, hq, head_ax)

    def body(qb, kb, vb):
        return flash_attention_kernel(qb, kb, vb, causal=causal, bq=bq, bk=bk,
                                      interpret=interpret)

    spec = P(batch_ax, None, head_entry, None)
    return shard_map(body, mesh, in_specs=(spec, spec, spec),
                     out_specs=spec, check_rep=False)(q, k, v)


# ---------------------------------------------------------------------------
# QAT mixed expectation (repro.kernels.mpe_qat)
# ---------------------------------------------------------------------------

def sharded_mixed_expectation(rows, probs, alpha, beta, bits, *, mesh=None,
                              interpret: bool = True):
    """Eq. (9) expectation-over-widths under ``shard_map``: rows split over
    *every* mesh axis (the op is row-parallel — the natural placement for
    the gathered rows of a batch-sharded train step); α/β replicated. No
    collectives, bit-exact. Rows pad up to the device count and unpad after
    (the pad-to-shard path)."""
    from repro.kernels.mpe_qat.ops import mixed_expectation_kernel

    mesh = active_mesh(mesh)
    if mesh is None:
        return mixed_expectation_kernel(rows, probs, alpha, beta, bits,
                                        interpret)

    axes = tuple(mesh.axis_names)
    n = rows.shape[0]
    rows_p = pad_rows_to_shard(rows, mesh.size)
    probs_p = pad_rows_to_shard(probs, mesh.size)

    def body(r, p, a, b_):
        return mixed_expectation_kernel(r, p, a, b_, bits, interpret)

    out = shard_map(
        body, mesh,
        in_specs=(P(axes, None), P(axes, None), P(None), P(None)),
        out_specs=P(axes, None), check_rep=False)(rows_p, probs_p, alpha, beta)
    return out[:n]


# ---------------------------------------------------------------------------
# train step: DP batch + row-sharded tables
# ---------------------------------------------------------------------------

def _table_pspecs(params, mesh, rows_axes):
    """Param pspecs for the train step: ``recsys_table_pspecs`` for the
    ``"embedding"`` entry (row axes only where the rows divide), everything
    else replicated."""
    from repro.dist.sharding import recsys_table_pspecs

    pspecs = replicate_like(params)
    emb = params.get("embedding") if isinstance(params, dict) else None
    if not isinstance(emb, dict):
        return pspecs
    wanted = recsys_table_pspecs(tuple(rows_axes), emb)
    fitted = {}
    for k, v in emb.items():
        spec = wanted[k]
        entry = spec[0] if len(spec) else None
        if entry and v.ndim >= 1 and v.shape[0] % _axes_size(mesh, rows_axes) == 0:
            fitted[k] = spec
        else:
            fitted[k] = P(*([None] * v.ndim))
    pspecs = dict(pspecs)
    pspecs["embedding"] = fitted
    return pspecs


def _is_row_sharded(spec) -> bool:
    return len(spec) > 0 and spec[0] is not None


def sharded_value_and_grad(loss_fn, mesh, *, rows_axes=("model",)):
    """A drop-in for ``jax.value_and_grad(loss_fn, has_aux=True)`` that runs
    the loss+grad *inside* ``shard_map`` on ``mesh``.

    Layout: the batch is data-parallel over every mesh axis that divides it
    (falling back to the non-row axes, then to replicated); dense embedding
    leaves (``params["embedding"]``, per ``recsys_table_pspecs``) are stored
    row-sharded over ``rows_axes`` and all-gathered in the body, so autodiff
    transposes the gather into a psum-scatter — table grads arrive
    row-shard-local ("row-shard-local updates") while every replicated leaf
    gets a ``pmean`` over the mesh ("gradient reduction for replicated MLP
    params"). Loss and float aux leaves are ``pmean``-ed to replication;
    integer/bool aux leaves must already be batch-independent.

    Parity: mean-of-shard-means reassociates the batch reduction, so losses
    and grads match the single-device step to fp32 tolerance (~1e-6), not
    bit-exactly.

    Returns ``vag(params, buffers, state, batch, *, step)`` →
    ``((loss, aux), grads)``.
    """
    rows_ax = _present_axes(mesh, rows_axes)
    mp = _axes_size(mesh, rows_ax)
    other_axes = _dp_axes_of(mesh, rows_ax)
    axes_all = tuple(mesh.axis_names)

    def vag(params, buffers, state, batch, *, step):
        leaves = jax.tree.leaves(batch)
        bsz = leaves[0].shape[0] if leaves else 0
        if bsz and bsz % mesh.size == 0:
            batch_ax = axes_all
        elif bsz and other_axes and bsz % _axes_size(mesh, other_axes) == 0:
            batch_ax = other_axes
        else:
            batch_ax = ()
        batch_specs = jax.tree.map(
            lambda x: P(batch_ax or None, *([None] * (x.ndim - 1))), batch)
        pspecs = _table_pspecs(params, mesh, rows_ax) if mp > 1 \
            else replicate_like(params)

        def gather_tables(p_sh):
            return jax.tree_util.tree_map_with_path(
                lambda path, x: _gather_leaf(pspecs, path, x), p_sh)

        def _gather_leaf(specs, path, x):
            spec = _leaf_spec(specs, path)
            if _is_row_sharded(spec):
                return jax.lax.all_gather(x, spec[0], axis=0, tiled=True)
            return x

        def inner(p_sh, bu, st, ba, stp):
            def local(p_sh):
                return loss_fn(gather_tables(p_sh), bu, st, ba, step=stp)

            (loss, aux), grads = jax.value_and_grad(
                local, has_aux=True)(p_sh)
            loss = jax.lax.pmean(loss, axes_all)
            aux = jax.tree.map(
                lambda x: jax.lax.pmean(x, axes_all)
                if jnp.issubdtype(jnp.asarray(x).dtype, jnp.inexact) else x,
                aux)
            grads = jax.tree_util.tree_map_with_path(
                lambda path, g: _reduce_grad(path, g), grads)
            return (loss, aux), grads

        def _reduce_grad(path, g):
            spec = _leaf_spec(pspecs, path)
            if _is_row_sharded(spec):
                # the all_gather transpose already psum-scattered over the
                # row axes; average the rest and undo the row-axis sum/dup
                g = jax.lax.pmean(g, other_axes) if other_axes else g
                return g / mp
            return jax.lax.pmean(g, axes_all)

        aux_sds = jax.eval_shape(
            lambda p, bu, st, ba: loss_fn(p, bu, st, ba, step=step)[1],
            params, buffers, state, batch)
        aux_specs = jax.tree.map(lambda s: P(*([None] * len(s.shape))),
                                 aux_sds)
        out_specs = ((P(), aux_specs), pspecs)
        f = shard_map(inner, mesh,
                      in_specs=(pspecs, replicate_like(buffers),
                                replicate_like(state), batch_specs, P()),
                      out_specs=out_specs, check_rep=False)
        return f(params, buffers, state, batch, jnp.asarray(step))

    return vag


def _leaf_spec(specs, path):
    """The PartitionSpec at ``path`` of a spec tree mirroring the params."""
    node = specs
    for entry in path:
        if isinstance(node, P):
            break
        key = getattr(entry, "key", getattr(entry, "idx", None))
        node = node[key]
    return node
