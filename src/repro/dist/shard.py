"""shard_map layer: run the fused kernels and cells *inside* the partitioner.

``pjit`` slices a computation after the fact; ``shard_map`` places it — each
device runs the body on its local block and every cross-device byte is an
explicit collective. This module is the bridge between the Pallas kernels
(written against local arrays) and the ``("data", "model")`` mesh contract of
``repro.dist.sharding``: every wrapper derives its in/out specs from the
pspec families (``packed_table_pspecs``, ``tiered_hot_pspecs``,
``recsys_table_pspecs``) and degrades to the single-device path when no
multi-device mesh is active, so the same call site serves 1-CPU tests and a
real mesh.

Placement per wrapper:

  ``sharded_packed_lookup``    subtables row-sharded over ``rows_axes``
                               ("model"), ids batch-sharded over the data
                               axes. Two comms paths, selected by
                               ``lookup_comms``: ``"psum"`` (default) does a
                               device-local gather+unpack+dequant with an
                               ownership mask, then ONE ``psum`` over the
                               row axes merges the buckets — each id owns
                               exactly one (bucket, row), so the psum adds
                               one non-zero term to zeros, bit-exact against
                               the jitted single-device reference. ``"a2a"``
                               ships only the *packed uint32 words*: a
                               capacity-bucketed ``all_to_all`` id shuffle
                               (``plan_buckets``) routes each id to its
                               owner shard, the owner gathers the packed
                               row, a second ``all_to_all`` returns the
                               words and the *requesting* shard dequantizes
                               — ~32/b× fewer bytes than psum-ing the
                               dequantized (batch, d) f32 activation when
                               the row axes are wide. Ids that overflow a
                               bucket deterministically spill to a masked
                               integer psum of the same packed words, so
                               the a2a path is bit-exact at ANY capacity
                               (nothing is dropped; see ``plan_buckets``).
  ``sharded_tiered_hot_lookup``  same layout (and the same two comms paths)
                               for the hot tier of a
                               ``repro.cache.TieredTableStore`` (zeros at
                               cold positions, merged by the caller).
  ``sharded_embedding_bag``    table rows over ``rows_axes``, bags over the
                               data axes; per-device partial bag sums +
                               psum. Differentiable: a ``custom_vjp`` runs
                               the backward as a per-device ``segment_sum``
                               of the owned slot cotangents into the local
                               row block (psum-merged over the batch axes
                               when the bags are split). NOT bit-exact for
                               >1 row shard (the psum reassociates the bag
                               sum) — documented tolerance ~1e-6 relative,
                               pinned by tests/test_shard_a2a.py.
  ``sharded_flash_attention``  batch over the data axes, heads over
                               "model"; no collectives, bit-exact.
                               Differentiable: a ``custom_vjp`` runs the
                               fused fwd-stats/bwd Pallas kernels in their
                               own shard_maps with the (o, lse) residuals
                               stored sharded.
  ``sharded_mixed_expectation`` rows over every mesh axis (row-parallel
                               QAT); no collectives, bit-exact.
  ``sharded_value_and_grad``   the train step's grad: batch data-parallel
                               over the mesh, embedding-table leaves stored
                               row-sharded over ``rows_axes`` (specs from
                               ``recsys_table_pspecs``) and all-gathered in
                               the body; autodiff transposes the gather into
                               a psum-scatter, so table grads arrive
                               row-shard-local while replicated MLP/side
                               params get a ``pmean`` over the batch axes.

Tables whose rows don't divide the row-axis size are padded up to the next
multiple (``pad_rows_to_shard``) — pad rows carry zero words and are never
owned by a real id, so they change no result (the pad-to-shard path).

Call the wrappers from traced code (under ``jax.jit`` — the serve cells and
the train step always are): eagerly-executed ``shard_map`` on jax 0.4.37
reassembles replicated outputs incorrectly for some mesh shapes.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import packing
from repro.core.quantizer import dequantize_codes
from repro.dist.mesh import current_mesh
from repro.dist.sharding import replicate_like

__all__ = [
    "active_mesh", "pad_rows_to_shard", "rows_shard_index",
    "LOOKUP_COMMS", "BucketPlan", "plan_buckets", "spill_capacity",
    "lookup_route_stats",
    "sharded_packed_lookup", "sharded_tiered_hot_lookup",
    "sharded_embedding_bag", "sharded_flash_attention",
    "sharded_mixed_expectation", "sharded_value_and_grad",
]


# ---------------------------------------------------------------------------
# mesh plumbing
# ---------------------------------------------------------------------------

def active_mesh(mesh=None):
    """``mesh`` or the registry's current mesh — None when sharding is a
    no-op (no mesh, or a 1-device mesh)."""
    mesh = mesh if mesh is not None else current_mesh()
    if mesh is None or mesh.size <= 1:
        return None
    return mesh


def _present_axes(mesh, axes) -> tuple[str, ...]:
    return tuple(a for a in axes if a in mesh.shape)


def _axes_size(mesh, axes) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def _dp_axes_of(mesh, rows_axes) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a not in rows_axes)


def _batch_entry(mesh, dim: int, axes) -> tuple[str, ...] | None:
    """The pspec entry for a batch dim: ``axes`` when they divide it, else
    replicated (mirrors ``sharding._fit_spec``)."""
    if axes and dim % _axes_size(mesh, axes) == 0:
        return tuple(axes)
    return None


def pad_rows_to_shard(x, n_shards: int):
    """Pad dim 0 up to a multiple of ``n_shards`` with zeros (the
    pad-to-shard path for tables whose rows don't divide the row axes).
    Zero packed words decode to the most-negative code, but pad rows are
    never *owned* by a real id, so no result can read them.

    Implemented with ``jnp.pad``, NOT ``jnp.concatenate``: on jax 0.4.37 the
    SPMD partitioner mis-lowers an uneven concatenate that feeds a
    ``shard_map`` row-sharded operand (wrong rows reach the shards on a 2×2
    mesh — see tests/test_shard.py::test_packed_lookup_pad_to_shard_edge,
    which fails with the concatenate formulation)."""
    pad = (-x.shape[0]) % n_shards
    if pad == 0:
        return x
    return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))


def rows_shard_index(mesh, rows_axes):
    """Linear shard index of this device along ``rows_axes`` (row-major over
    the axes tuple, matching ``PartitionSpec((a, b), ...)`` layout). Call
    inside a ``shard_map`` body."""
    idx = jnp.int32(0)
    for a in rows_axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


# ---------------------------------------------------------------------------
# capacity-bucketed all-to-all routing plan
# ---------------------------------------------------------------------------

#: Comms paths for the sharded lookups: "psum" merges dequantized partials
#: with one float psum; "a2a" ships the packed words through two all_to_alls
#: (+ an integer spill psum) and dequantizes on the requesting shard.
LOOKUP_COMMS = ("psum", "a2a")


class BucketPlan(NamedTuple):
    """Static-shape routing plan for the capacity-bucketed all-to-all.

    ``slot``/``in_bucket``/``spilled`` share ``owner``'s shape, with the
    second-to-last axis enumerating the ids of one batch slice: ``slot`` is
    the flat position in the (n_shards × capacity) send buffer
    (``owner * capacity + rank`` within the (slice, owner) bucket);
    ``in_bucket`` marks ids that fit under the capacity; ``spilled`` marks
    valid ids that overflowed — the lookup merges those through the integer
    psum spill path instead of dropping them. ``counts`` replaces the id
    axis with an ``n_shards`` axis: the total per-bucket demand (occupancy
    is ``min(counts, capacity)``). The plan is a pure function of
    ``(owner, valid)``, so every device derives the identical plan from
    replicated inputs — that determinism is what lets the spill psum write
    each overflow row from exactly one owner."""
    slot: jnp.ndarray
    in_bucket: jnp.ndarray
    spilled: jnp.ndarray
    counts: jnp.ndarray


def plan_buckets(owner, valid, *, n_shards: int, capacity: int) -> BucketPlan:
    """Plan per-destination-shard buckets under a static ``capacity``.

    ``owner[..., j]`` is the shard that holds id j's row; ``valid`` masks
    the ids that participate (batch padding and zero-width/cold ids don't).
    Rank within a bucket is the id's order of appearance in its slice, so
    the plan — and therefore which ids spill — is deterministic."""
    owner = jnp.asarray(owner, jnp.int32)
    valid = jnp.asarray(valid, bool)
    oc = jnp.clip(owner, 0, n_shards - 1)
    onehot = (oc[..., None] == jnp.arange(n_shards, dtype=jnp.int32)) \
        & valid[..., None]
    cum = jnp.cumsum(onehot.astype(jnp.int32), axis=-2)
    rank = jnp.take_along_axis(cum, oc[..., None], axis=-1)[..., 0] - 1
    in_bucket = valid & (rank < capacity)
    return BucketPlan(slot=(oc * capacity + rank).astype(jnp.int32),
                      in_bucket=in_bucket,
                      spilled=valid & ~in_bucket,
                      counts=onehot.sum(axis=-2).astype(jnp.int32))


def spill_capacity(slice_len: int, capacity: int, n_shards: int) -> int:
    """Static row count of the overflow spill buffer.

    One slice of ``slice_len`` ids spills at most ``slice_len - capacity``:
    summing ``max(0, count_o - capacity)`` over the owners with overflow
    gives ``sum(count_o) - |overflowing| * capacity <= slice_len -
    capacity``. ``n_shards`` slices therefore always fit."""
    return n_shards * max(0, slice_len - capacity)


def _cap_slice(batch: int, n_shards: int, capacity) -> tuple[int, int]:
    """(slice_len, clamped capacity): each of the ``n_shards`` batch slices
    holds ``ceil(batch / n_shards)`` ids; a capacity of None (or anything
    >= slice_len) makes the plan statically spill-free."""
    slice_len = -(-batch // n_shards)
    if capacity is None:
        return slice_len, slice_len
    return slice_len, max(1, min(int(capacity), slice_len))


def _route_words(subs, widths, widx, lidx, shard, n_words, mask=None):
    """Packed words of the locally-owned rows among ``(widx, lidx)``,
    zero-padded to ``n_words`` columns → (words, owned). Positions this
    shard doesn't own (or ``mask`` excludes) stay zero."""
    n = widx.shape[0]
    words = jnp.zeros((n, n_words), jnp.uint32)
    owned = jnp.zeros((n,), bool)
    for i, b in widths:
        sub = subs[f"b{b}"]
        rows_loc = sub.shape[0]
        loc = lidx - shard * rows_loc
        own = (widx == i) & (loc >= 0) & (loc < rows_loc)
        if mask is not None:
            own = own & mask
        w = jnp.take(sub, jnp.clip(loc, 0, rows_loc - 1), axis=0)
        w = jnp.pad(w, ((0, 0), (0, n_words - w.shape[1])))
        words = jnp.where(own[:, None], w, words)
        owned = owned | own
    return words, owned


def _a2a_lookup(subs, local_idx, width_idx, alpha, beta, fl, *, mesh, rows_ax,
                bits, d, capacity, use_kernel, interpret, ok_vec=None):
    """Body of the capacity-bucketed all-to-all lookup (inside shard_map).

    The ids are replicated along ``rows_ax`` (they enter sharded over the
    batch axes only), so shard s takes ownership of batch slice s and every
    device computes the identical replicated ``plan_buckets`` plan. Steps:

      1. all_to_all the bucketed ids (static shape (n_shards, capacity));
      2. the owner gathers the packed uint32 words of its rows;
      3. all_to_all the words back; the requester collects its slice and an
         ``all_gather`` rebuilds the full (batch, words) array;
      4. overflowed ids merge through ONE masked integer psum of a static
         ``spill_capacity``-row buffer — exact (each row has one writer);
      5. the requesting shard unpacks + dequantizes through the sanctioned
         ``core.quantizer.dequantize_codes`` path (or the fused kernel).

    Identical words → identical static-shift unpack → identical dequant, so
    the result is bit-exact vs the psum path at ANY capacity. ``ok_vec`` is
    an optional replicated per-id validity vector (the tiered hot bit):
    unselected ids are not routed and output zeros, matching the psum
    path's ownership mask."""
    mp = _axes_size(mesh, rows_ax)
    batch = fl.shape[0]
    slice_len, cap = _cap_slice(batch, mp, capacity)
    bp = mp * slice_len
    n_spill = spill_capacity(slice_len, cap, mp)
    widths = [(i, b) for i, b in enumerate(bits) if b != 0]
    n_words = max(packing.words_per_row(d, b) for _, b in widths)

    fl_p = jnp.pad(fl, (0, bp - batch))
    widx = jnp.take(width_idx, fl_p, axis=0)
    lidx = jnp.take(local_idx, fl_p, axis=0)
    nz = jnp.asarray([b != 0 for b in bits])
    route = (jnp.arange(bp) < batch) & jnp.take(nz, widx, axis=0)
    if ok_vec is not None:
        route = route & jnp.take(ok_vec, fl_p, axis=0)
    rows_loc_vec = jnp.asarray(
        [subs[f"b{b}"].shape[0] if b else 1 for b in bits], jnp.int32)
    owner = jnp.clip(lidx // jnp.take(rows_loc_vec, widx, axis=0), 0, mp - 1)
    plan = plan_buckets(owner.reshape(mp, slice_len),
                        route.reshape(mp, slice_len),
                        n_shards=mp, capacity=cap)

    me = rows_shard_index(mesh, rows_ax)
    ids_me = jax.lax.dynamic_slice_in_dim(fl_p, me * slice_len, slice_len)
    slot_me = jnp.take(plan.slot, me, axis=0)
    inb_me = jnp.take(plan.in_bucket, me, axis=0)

    # (1) ship the bucketed ids; pad slots carry id 0 and are never read
    send = jnp.zeros((mp * cap,), fl_p.dtype).at[
        jnp.where(inb_me, slot_me, mp * cap)].set(ids_me, mode="drop")
    recv = jax.lax.all_to_all(send.reshape(mp, cap), rows_ax, 0, 0)

    # (2) owner-local gather of the packed words
    r_flat = recv.reshape(-1)
    words, _ = _route_words(subs, widths, jnp.take(width_idx, r_flat, axis=0),
                            jnp.take(local_idx, r_flat, axis=0), me, n_words)

    # (3) words travel back; collect my slice, share all slices
    ret = jax.lax.all_to_all(words.reshape(mp, cap, n_words), rows_ax, 0, 0)
    ret = ret.reshape(mp * cap, n_words)
    w_me = jnp.where(
        inb_me[:, None],
        jnp.take(ret, jnp.clip(slot_me, 0, mp * cap - 1), axis=0),
        jnp.zeros((), jnp.uint32))
    full = jax.lax.all_gather(w_me, rows_ax, axis=0, tiled=True)

    # (4) deterministic overflow spill: masked integer psum, exact
    if n_spill > 0:
        sp = plan.spilled.reshape(bp)
        sp_rank = jnp.cumsum(sp.astype(jnp.int32)) - 1
        contrib, owned = _route_words(subs, widths, widx, lidx, me, n_words,
                                      mask=sp)
        buf = jnp.zeros((n_spill, n_words), jnp.uint32).at[
            jnp.where(owned, sp_rank, n_spill)].set(contrib, mode="drop")
        buf = jax.lax.psum(buf, rows_ax)
        full = jnp.where(
            sp[:, None],
            jnp.take(buf, jnp.clip(sp_rank, 0, n_spill - 1), axis=0), full)

    # (5) dequant on the requesting shard (PF102-sanctioned path)
    out = jnp.zeros((bp, d), jnp.float32)
    for i, b in widths:
        wb = packing.words_per_row(d, b)
        deq = _bucket_dequant(full[:, :wb], jnp.arange(bp), alpha[i], beta,
                              b=b, d=d, use_kernel=use_kernel,
                              interpret=interpret)
        out = jnp.where((route & (widx == i))[:, None], deq, out)
    return out[:batch]


def lookup_route_stats(table, meta, ids, *, n_shards: int,
                       bucket_capacity: int | None = None) -> dict:
    """Deterministic routing counters for the a2a path of one lookup.

    Mirrors the in-body plan exactly — same batch padding, owner derivation
    (over ``pad_rows_to_shard``-ed subtables) and capacity clamp — so the
    numbers are reproducible bench-gate metrics, not samples."""
    bits, d = meta["bits"], meta["d"]
    flat = jnp.asarray(ids).reshape(-1)
    batch = flat.shape[0]
    slice_len, cap = _cap_slice(batch, n_shards, bucket_capacity)
    bp = n_shards * slice_len
    rows_loc = []
    for b in bits:
        if b == 0:
            rows_loc.append(1)
            continue
        rows = table["subtables"][f"b{b}"].shape[0]
        rows_loc.append((rows + (-rows) % n_shards) // n_shards)
    fl_p = jnp.pad(flat, (0, bp - batch))
    widx = jnp.take(table["width_idx"], fl_p, axis=0)
    lidx = jnp.take(table["local_idx"], fl_p, axis=0)
    nz = jnp.asarray([b != 0 for b in bits])
    route = (jnp.arange(bp) < batch) & jnp.take(nz, widx, axis=0)
    owner = jnp.clip(
        lidx // jnp.take(jnp.asarray(rows_loc, jnp.int32), widx, axis=0),
        0, n_shards - 1)
    plan = plan_buckets(owner.reshape(n_shards, slice_len),
                        route.reshape(n_shards, slice_len),
                        n_shards=n_shards, capacity=cap)
    n_slots = n_shards * n_shards * cap
    return {
        "slice_len": slice_len,
        "capacity": cap,
        "spill_cap": spill_capacity(slice_len, cap, n_shards),
        "routed": int(route.sum()),
        "bucketed": int(plan.in_bucket.sum()),
        "spilled": int(plan.spilled.sum()),
        "bucket_demand_max": int(plan.counts.max()),
        "occupancy_pct": round(100.0 * int(plan.in_bucket.sum()) / n_slots,
                               4),
    }


# ---------------------------------------------------------------------------
# packed-table lookup (repro.kernels.mpe_lookup / core.inference)
# ---------------------------------------------------------------------------

def _bucket_dequant(sub, loc, alpha_i, beta, *, b, d, use_kernel, interpret):
    """Device-local gather+unpack+dequant of one width bucket — the fused
    Pallas kernel or its jnp formulation, on local rows only."""
    if use_kernel:
        from repro.kernels.mpe_lookup.kernel import packed_lookup_pallas
        return packed_lookup_pallas(loc, sub, alpha_i, beta, b=b, d=d,
                                    interpret=interpret)
    words = jnp.take(sub, loc, axis=0)
    codes = packing.unpack_codes(words, b, d)
    return dequantize_codes(codes, alpha_i, beta)


def sharded_packed_lookup(table, meta, ids, *, rows_axes=("model",),
                          mesh=None, use_kernel: bool = False,
                          interpret: bool = True,
                          lookup_comms: str = "psum",
                          bucket_capacity: int | None = None):
    """``core.inference.packed_lookup`` under ``shard_map``: subtables
    row-sharded over ``rows_axes`` (layout: ``packed_table_pspecs``), ids
    batch-sharded over the remaining axes. ``lookup_comms`` picks the merge:
    ``"psum"`` (one float psum over the row axes) or ``"a2a"`` (the
    capacity-bucketed all-to-all of ``_a2a_lookup`` — ``bucket_capacity``
    ids per (slice, shard) bucket, overflow spilling to an integer psum).
    Both are bit-exact vs the single-device reference; a2a falls back to
    psum when the row axes resolve to a single shard.

    Degrades to the single-device lookup when no multi-device mesh is active
    (or none of ``rows_axes`` is on it). ``use_kernel`` runs the fused
    Pallas kernel per bucket inside the body."""
    from repro.core.inference import packed_lookup

    if lookup_comms not in LOOKUP_COMMS:
        raise ValueError(f"lookup_comms must be one of {LOOKUP_COMMS}, "
                         f"got {lookup_comms!r}")
    mesh = active_mesh(mesh)
    if mesh is None:
        if use_kernel:
            from repro.kernels.mpe_lookup.ops import packed_lookup_kernel
            return packed_lookup_kernel(table, meta, ids, interpret=interpret)
        return packed_lookup(table, meta, ids)
    rows_ax = _present_axes(mesh, rows_axes)
    mp = _axes_size(mesh, rows_ax)

    bits, d = meta["bits"], meta["d"]
    use_a2a = lookup_comms == "a2a" and mp > 1 and any(bits)
    dp = _dp_axes_of(mesh, rows_ax)
    flat = ids.reshape(-1)
    batch_ax = _batch_entry(mesh, flat.shape[0], dp)

    tbl = dict(table, subtables={k: pad_rows_to_shard(v, mp)
                                 for k, v in table["subtables"].items()})

    def body(subs, local_idx, width_idx, alpha, beta, fl):
        if use_a2a:
            return _a2a_lookup(subs, local_idx, width_idx, alpha, beta, fl,
                               mesh=mesh, rows_ax=rows_ax, bits=bits, d=d,
                               capacity=bucket_capacity,
                               use_kernel=use_kernel, interpret=interpret)
        widx = jnp.take(width_idx, fl, axis=0)
        lidx = jnp.take(local_idx, fl, axis=0)
        base = rows_shard_index(mesh, rows_ax)
        out = jnp.zeros((fl.shape[0], d), jnp.float32)
        for i, b in enumerate(bits):
            if b == 0:
                continue  # zero-width features contribute the zero vector
            sub = subs[f"b{b}"]
            rows_loc = sub.shape[0]
            loc = lidx - base * rows_loc
            own = (loc >= 0) & (loc < rows_loc)
            deq = _bucket_dequant(sub, jnp.clip(loc, 0, rows_loc - 1),
                                  alpha[i], beta, b=b, d=d,
                                  use_kernel=use_kernel, interpret=interpret)
            out = jnp.where((own & (widx == i))[:, None], deq, out)
        # one non-zero owner per id: the psum adds zeros — exact
        return jax.lax.psum(out, rows_ax) if rows_ax else out

    in_specs = ({k: P(rows_ax or None, None) for k in tbl["subtables"]},
                P(None), P(None), P(None), P(None), P(batch_ax))
    out = shard_map(body, mesh, in_specs=in_specs,
                    out_specs=P(batch_ax, None), check_rep=False)(
        tbl["subtables"], tbl["local_idx"], tbl["width_idx"],
        tbl["alpha"], tbl["beta"], flat)
    return out.reshape(*ids.shape, d)


def sharded_tiered_hot_lookup(hot, bits, d: int, ids, *,
                              rows_axes=("model",), mesh=None,
                              lookup_comms: str = "psum",
                              bucket_capacity: int | None = None):
    """``repro.cache.tiers.tiered_hot_lookup`` under ``shard_map``: hot
    subtables row-sharded per ``tiered_hot_pspecs``, zeros at cold positions
    (the caller merges the cold fill). Bit-exact like the packed lookup —
    the ownership mask additionally requires the hot bit. ``lookup_comms``
    / ``bucket_capacity`` select the same two merge paths as
    ``sharded_packed_lookup`` (under a2a, only hot ids are routed)."""
    from repro.cache.tiers import tiered_hot_lookup

    if lookup_comms not in LOOKUP_COMMS:
        raise ValueError(f"lookup_comms must be one of {LOOKUP_COMMS}, "
                         f"got {lookup_comms!r}")
    mesh = active_mesh(mesh)
    if mesh is None:
        return tiered_hot_lookup(hot, bits, d, ids)
    rows_ax = _present_axes(mesh, rows_axes)
    mp = _axes_size(mesh, rows_ax)
    use_a2a = lookup_comms == "a2a" and mp > 1 and any(bits)

    dp = _dp_axes_of(mesh, rows_ax)
    flat = ids.reshape(-1)
    batch_ax = _batch_entry(mesh, flat.shape[0], dp)
    hot_p = dict(hot, subtables={k: pad_rows_to_shard(v, mp)
                                 for k, v in hot["subtables"].items()})

    def body(subs, tier_local, is_hot, width_idx, alpha, beta, fl):
        if use_a2a:
            return _a2a_lookup(subs, tier_local, width_idx, alpha, beta, fl,
                               mesh=mesh, rows_ax=rows_ax, bits=bits, d=d,
                               capacity=bucket_capacity, use_kernel=False,
                               interpret=True, ok_vec=is_hot)
        widx = jnp.take(width_idx, fl, axis=0)
        lidx = jnp.take(tier_local, fl, axis=0)
        hot_bit = jnp.take(is_hot, fl, axis=0)
        base = rows_shard_index(mesh, rows_ax)
        out = jnp.zeros((fl.shape[0], d), jnp.float32)
        for i, b in enumerate(bits):
            if b == 0:
                continue
            sub = subs[f"b{b}"]
            rows_loc = sub.shape[0]
            loc = lidx - base * rows_loc
            own = (loc >= 0) & (loc < rows_loc) & hot_bit
            words = jnp.take(sub, jnp.clip(loc, 0, rows_loc - 1), axis=0)
            codes = packing.unpack_codes(words, b, d)
            deq = dequantize_codes(codes, alpha[i], beta)
            out = jnp.where((own & (widx == i))[:, None], deq, out)
        return jax.lax.psum(out, rows_ax) if rows_ax else out

    in_specs = ({k: P(rows_ax or None, None) for k in hot_p["subtables"]},
                P(None), P(None), P(None), P(None), P(None), P(batch_ax))
    out = shard_map(body, mesh, in_specs=in_specs,
                    out_specs=P(batch_ax, None), check_rep=False)(
        hot_p["subtables"], hot_p["tier_local"], hot_p["is_hot"],
        hot_p["width_idx"], hot_p["alpha"], hot_p["beta"], flat)
    return out.reshape(*ids.shape, d)


# ---------------------------------------------------------------------------
# embedding bag (repro.kernels.embedding_bag)
# ---------------------------------------------------------------------------

def sharded_embedding_bag(table, ids, mask, *, rows_axes=("model",),
                          mesh=None, use_kernel: bool = True,
                          interpret: bool = True):
    """Multi-hot embedding bag under ``shard_map``: the (N, d) table
    row-sharded over ``rows_axes`` (layout: ``recsys_table_pspecs``), bags
    batch-sharded over the data axes; each device sums its owned slots with
    the fused kernel, one ``psum`` merges the partial bags.

    Differentiable w.r.t. the table: a ``custom_vjp`` runs the backward in
    its own shard_map — per-device ``segment_sum`` of the owned slot
    cotangents into the local row block (the transpose of the ownership
    mask), psum-merged over the batch axes only when the bags are actually
    split — so ``sharded_value_and_grad`` and training loss functions no
    longer fall back to the jnp bag. Table grads land row-shard-local.

    NOT bit-exact for >1 row shard: a bag whose slots land on different
    shards has its sum reassociated by the psum (~1e-6 relative on fp32,
    pinned by tests/test_shard_a2a.py::test_embedding_bag_psum_tolerance).
    Exact when ``rows_axes`` resolve to a single shard (pure batch
    sharding)."""
    from repro.kernels.embedding_bag.kernel import embedding_bag_pallas
    from repro.kernels.embedding_bag.ops import embedding_bag_kernel
    from repro.kernels.embedding_bag.ref import embedding_bag_ref

    mesh = active_mesh(mesh)
    rows_ax = _present_axes(mesh, rows_axes) if mesh is not None else ()
    mp = _axes_size(mesh, rows_ax) if mesh is not None else 1
    if mesh is None:
        if use_kernel:  # the custom_vjp wrapper: same kernel, differentiable
            return embedding_bag_kernel(table, ids, mask, interpret)
        return embedding_bag_ref(table, ids, mask)
    local = (embedding_bag_pallas if use_kernel else embedding_bag_ref)
    kw = {"interpret": interpret} if use_kernel else {}

    dp = _dp_axes_of(mesh, rows_ax)
    batch_ax = _batch_entry(mesh, ids.shape[0], dp)
    bsplit = batch_ax is not None and _axes_size(mesh, batch_ax) > 1
    tab = pad_rows_to_shard(table, mp) if mp > 1 else table
    rows_entry = rows_ax if mp > 1 else None
    d_model = table.shape[1]

    def fwd_body(tab_loc, ids_b, mask_b):
        rows_loc = tab_loc.shape[0]
        base = rows_shard_index(mesh, rows_ax) * rows_loc if mp > 1 else 0
        own = (ids_b >= base) & (ids_b < base + rows_loc)
        loc = jnp.clip(ids_b - base, 0, rows_loc - 1)
        part = local(tab_loc, loc, mask_b & own, **kw)
        return jax.lax.psum(part, rows_ax) if mp > 1 else part

    run_fwd = shard_map(
        fwd_body, mesh,
        in_specs=(P(rows_entry, None), P(batch_ax, None), P(batch_ax, None)),
        out_specs=P(batch_ax, None), check_rep=False)

    def bwd_body(g_loc, ids_b, mask_b):
        rows_loc = tab.shape[0] // mp
        base = rows_shard_index(mesh, rows_ax) * rows_loc if mp > 1 else 0
        own = mask_b & (ids_b >= base) & (ids_b < base + rows_loc)
        loc = jnp.clip(ids_b - base, 0, rows_loc - 1)
        contrib = jnp.where(
            own[..., None],
            jnp.broadcast_to(g_loc[:, None, :], (*ids_b.shape, d_model)),
            0.0)
        d_loc = jax.ops.segment_sum(contrib.reshape(-1, d_model),
                                    loc.reshape(-1), num_segments=rows_loc)
        if bsplit:  # replicated bags would double-count under a psum
            d_loc = jax.lax.psum(d_loc, batch_ax)
        return d_loc.astype(g_loc.dtype)

    run_bwd = shard_map(
        bwd_body, mesh,
        in_specs=(P(batch_ax, None), P(batch_ax, None), P(batch_ax, None)),
        out_specs=P(rows_entry, None), check_rep=False)

    @jax.custom_vjp
    def bag(tab_p, ids_b, mask_b):
        return run_fwd(tab_p, ids_b, mask_b)

    def bag_fwd(tab_p, ids_b, mask_b):
        return run_fwd(tab_p, ids_b, mask_b), (ids_b, mask_b)

    def bag_bwd(res, g):
        return run_bwd(g, *res), None, None

    bag.defvjp(bag_fwd, bag_bwd)
    # the jnp.pad to the padded table is differentiated *outside* the
    # custom_vjp, so grads slice back to the caller's row count
    return bag(tab, ids.astype(jnp.int32), mask.astype(bool))


# ---------------------------------------------------------------------------
# flash attention (repro.kernels.flash_attention)
# ---------------------------------------------------------------------------

def sharded_flash_attention(q, k, v, *, n_kv_heads: int | None = None,
                            causal: bool = True, bq: int = 128, bk: int = 128,
                            head_axes=("model",), mesh=None,
                            interpret: bool = True):
    """Flash attention under ``shard_map``: batch over the data axes, query
    heads over ``head_axes`` — every (batch, head) pair computes wholly on
    one device, so there are no collectives and the result is bit-exact
    against the single-device kernel. GQA KV expansion happens *before* the
    shard_map so the head sharding stays aligned.

    Differentiable: a ``custom_vjp`` places the fused fwd-stats and
    backward Pallas kernels in their own shard_maps, with the (o, lse)
    residuals stored under the same batch/head sharding as the activations
    — training through the sharded wrapper runs the flash backward kernel
    per device instead of falling back to the jnp attention, and the grads
    are bit-exact vs the single-device kernel's (still collective-free)."""
    from repro.kernels.flash_attention.kernel import (
        flash_attention_bwd, flash_attention_fwd_stats)
    from repro.kernels.flash_attention.ops import flash_attention_kernel

    del n_kv_heads  # derived from the shapes, as in the flat wrapper
    mesh = active_mesh(mesh)
    if mesh is None:
        return flash_attention_kernel(q, k, v, causal=causal, bq=bq, bk=bk,
                                      interpret=interpret)

    hq, hkv = q.shape[2], k.shape[2]
    if hkv != hq:  # GQA: expand KV to query heads before placing
        k = jnp.repeat(k, hq // hkv, axis=2)
        v = jnp.repeat(v, hq // hkv, axis=2)

    head_ax = _present_axes(mesh, head_axes)
    dp = _dp_axes_of(mesh, head_ax)
    batch_ax = _batch_entry(mesh, q.shape[0], dp)
    head_entry = _batch_entry(mesh, hq, head_ax)
    spec = P(batch_ax, None, head_entry, None)
    lse_spec = P(batch_ax, head_entry, None)
    bq_, bk_ = min(bq, q.shape[1]), min(bk, q.shape[1])

    def _flat(x):  # (b, s, h, hd) -> the kernels' (b*h, s, hd)
        b, s, h, hd = x.shape
        return jnp.moveaxis(x, 2, 1).reshape(b * h, s, hd)

    def _unflat(xf, b, s, h):
        return jnp.moveaxis(xf.reshape(b, h, s, -1), 1, 2)

    def fwd_body(qb, kb, vb):
        return flash_attention_kernel(qb, kb, vb, causal=causal, bq=bq,
                                      bk=bk, interpret=interpret)

    def stats_body(qb, kb, vb):
        b, s, h, _ = qb.shape
        o, lse = flash_attention_fwd_stats(
            _flat(qb), _flat(kb), _flat(vb), causal=causal, bq=bq_, bk=bk_,
            interpret=interpret)
        return _unflat(o, b, s, h), lse.reshape(b, h, s)

    def bwd_body(qb, kb, vb, ob, lseb, dob):
        b, s, h, _ = qb.shape
        dq, dk, dv = flash_attention_bwd(
            _flat(qb), _flat(kb), _flat(vb), _flat(ob),
            lseb.reshape(b * h, s), _flat(dob), causal=causal, bq=bq_,
            bk=bk_, interpret=interpret)
        return (_unflat(dq, b, s, h), _unflat(dk, b, s, h),
                _unflat(dv, b, s, h))

    run_fwd = shard_map(fwd_body, mesh, in_specs=(spec,) * 3,
                        out_specs=spec, check_rep=False)
    run_stats = shard_map(stats_body, mesh, in_specs=(spec,) * 3,
                          out_specs=(spec, lse_spec), check_rep=False)
    run_bwd = shard_map(bwd_body, mesh,
                        in_specs=(spec, spec, spec, spec, lse_spec, spec),
                        out_specs=(spec, spec, spec), check_rep=False)

    @jax.custom_vjp
    def fa(qx, kx, vx):
        return run_fwd(qx, kx, vx)

    def fa_fwd(qx, kx, vx):
        o, lse = run_stats(qx, kx, vx)
        return o, (qx, kx, vx, o, lse)

    def fa_bwd(res, do):
        return run_bwd(*res, do)

    fa.defvjp(fa_fwd, fa_bwd)
    return fa(q, k, v)


# ---------------------------------------------------------------------------
# QAT mixed expectation (repro.kernels.mpe_qat)
# ---------------------------------------------------------------------------

def sharded_mixed_expectation(rows, probs, alpha, beta, bits, *, mesh=None,
                              interpret: bool = True):
    """Eq. (9) expectation-over-widths under ``shard_map``: rows split over
    *every* mesh axis (the op is row-parallel — the natural placement for
    the gathered rows of a batch-sharded train step); α/β replicated. No
    collectives, bit-exact. Rows pad up to the device count and unpad after
    (the pad-to-shard path)."""
    from repro.kernels.mpe_qat.ops import mixed_expectation_kernel

    mesh = active_mesh(mesh)
    if mesh is None:
        return mixed_expectation_kernel(rows, probs, alpha, beta, bits,
                                        interpret)

    axes = tuple(mesh.axis_names)
    n = rows.shape[0]
    rows_p = pad_rows_to_shard(rows, mesh.size)
    probs_p = pad_rows_to_shard(probs, mesh.size)

    def body(r, p, a, b_):
        return mixed_expectation_kernel(r, p, a, b_, bits, interpret)

    out = shard_map(
        body, mesh,
        in_specs=(P(axes, None), P(axes, None), P(None), P(None)),
        out_specs=P(axes, None), check_rep=False)(rows_p, probs_p, alpha, beta)
    return out[:n]


# ---------------------------------------------------------------------------
# train step: DP batch + row-sharded tables
# ---------------------------------------------------------------------------

def _table_pspecs(params, mesh, rows_axes):
    """Param pspecs for the train step: ``recsys_table_pspecs`` for the
    ``"embedding"`` entry (row axes only where the rows divide), everything
    else replicated."""
    from repro.dist.sharding import recsys_table_pspecs

    pspecs = replicate_like(params)
    emb = params.get("embedding") if isinstance(params, dict) else None
    if not isinstance(emb, dict):
        return pspecs
    wanted = recsys_table_pspecs(tuple(rows_axes), emb)
    fitted = {}
    for k, v in emb.items():
        spec = wanted[k]
        entry = spec[0] if len(spec) else None
        if entry and v.ndim >= 1 and v.shape[0] % _axes_size(mesh, rows_axes) == 0:
            fitted[k] = spec
        else:
            fitted[k] = P(*([None] * v.ndim))
    pspecs = dict(pspecs)
    pspecs["embedding"] = fitted
    return pspecs


def _is_row_sharded(spec) -> bool:
    return len(spec) > 0 and spec[0] is not None


def sharded_value_and_grad(loss_fn, mesh, *, rows_axes=("model",)):
    """A drop-in for ``jax.value_and_grad(loss_fn, has_aux=True)`` that runs
    the loss+grad *inside* ``shard_map`` on ``mesh``.

    Layout: the batch is data-parallel over every mesh axis that divides it
    (falling back to the non-row axes, then to replicated); dense embedding
    leaves (``params["embedding"]``, per ``recsys_table_pspecs``) are stored
    row-sharded over ``rows_axes`` and all-gathered in the body, so autodiff
    transposes the gather into a psum-scatter — table grads arrive
    row-shard-local ("row-shard-local updates") while every replicated leaf
    gets a ``pmean`` over the mesh ("gradient reduction for replicated MLP
    params"). Loss and float aux leaves are ``pmean``-ed to replication;
    integer/bool aux leaves must already be batch-independent.

    Parity: mean-of-shard-means reassociates the batch reduction, so losses
    and grads match the single-device step to fp32 tolerance (~1e-6), not
    bit-exactly.

    Returns ``vag(params, buffers, state, batch, *, step)`` →
    ``((loss, aux), grads)``.
    """
    rows_ax = _present_axes(mesh, rows_axes)
    mp = _axes_size(mesh, rows_ax)
    other_axes = _dp_axes_of(mesh, rows_ax)
    axes_all = tuple(mesh.axis_names)

    def vag(params, buffers, state, batch, *, step):
        leaves = jax.tree.leaves(batch)
        bsz = leaves[0].shape[0] if leaves else 0
        if bsz and bsz % mesh.size == 0:
            batch_ax = axes_all
        elif bsz and other_axes and bsz % _axes_size(mesh, other_axes) == 0:
            batch_ax = other_axes
        else:
            batch_ax = ()
        batch_specs = jax.tree.map(
            lambda x: P(batch_ax or None, *([None] * (x.ndim - 1))), batch)
        pspecs = _table_pspecs(params, mesh, rows_ax) if mp > 1 \
            else replicate_like(params)

        def gather_tables(p_sh):
            return jax.tree_util.tree_map_with_path(
                lambda path, x: _gather_leaf(pspecs, path, x), p_sh)

        def _gather_leaf(specs, path, x):
            spec = _leaf_spec(specs, path)
            if _is_row_sharded(spec):
                return jax.lax.all_gather(x, spec[0], axis=0, tiled=True)
            return x

        def inner(p_sh, bu, st, ba, stp):
            def local(p_sh):
                return loss_fn(gather_tables(p_sh), bu, st, ba, step=stp)

            (loss, aux), grads = jax.value_and_grad(
                local, has_aux=True)(p_sh)
            loss = jax.lax.pmean(loss, axes_all)
            aux = jax.tree.map(
                lambda x: jax.lax.pmean(x, axes_all)
                if jnp.issubdtype(jnp.asarray(x).dtype, jnp.inexact) else x,
                aux)
            grads = jax.tree_util.tree_map_with_path(
                lambda path, g: _reduce_grad(path, g), grads)
            return (loss, aux), grads

        def _reduce_grad(path, g):
            spec = _leaf_spec(pspecs, path)
            if _is_row_sharded(spec):
                # the all_gather transpose already psum-scattered over the
                # row axes; average the rest and undo the row-axis sum/dup
                g = jax.lax.pmean(g, other_axes) if other_axes else g
                return g / mp
            return jax.lax.pmean(g, axes_all)

        aux_sds = jax.eval_shape(
            lambda p, bu, st, ba: loss_fn(p, bu, st, ba, step=step)[1],
            params, buffers, state, batch)
        aux_specs = jax.tree.map(lambda s: P(*([None] * len(s.shape))),
                                 aux_sds)
        out_specs = ((P(), aux_specs), pspecs)
        f = shard_map(inner, mesh,
                      in_specs=(pspecs, replicate_like(buffers),
                                replicate_like(state), batch_specs, P()),
                      out_specs=out_specs, check_rep=False)
        return f(params, buffers, state, batch, jnp.asarray(step))

    return vag


def _leaf_spec(specs, path):
    """The PartitionSpec at ``path`` of a spec tree mirroring the params."""
    node = specs
    for entry in path:
        if isinstance(node, P):
            break
        key = getattr(entry, "key", getattr(entry, "idx", None))
        node = node[key]
    return node
