"""Distribution layer: mesh registry + partition-spec vocabulary.

``repro.dist.mesh`` owns the context-managed current-mesh registry;
``repro.dist.sharding`` defines the partition-spec contract for every
workload family in-tree (LM params/caches, recsys embedding tables, MPE
packed serving tables) plus the in-model constraint helpers
(``maybe_shard``/``shard_batch_dim``) that degrade to no-ops on one device;
``repro.dist.shard`` places the fused Pallas kernels and the serve/train
cells *inside* the partitioner with ``shard_map`` wrappers whose in/out
specs derive from the same pspec contract.
"""
from repro.dist.mesh import (current_mesh, host_mesh, make_device_mesh,
                             parse_mesh_flag, use_mesh)
from repro.dist.shard import (sharded_embedding_bag, sharded_flash_attention,
                              sharded_mixed_expectation,
                              sharded_packed_lookup,
                              sharded_tiered_hot_lookup,
                              sharded_value_and_grad)
from repro.dist.sharding import (cell_shardings, current_dp_axes, dp_axes,
                                 lm_batch_pspecs, lm_cache_pspecs,
                                 lm_kv_cache_pspecs, lm_param_pspecs,
                                 maybe_shard, packed_serve_pspecs,
                                 packed_table_pspecs, recsys_table_pspecs,
                                 replicate_like, shard_batch_dim,
                                 tiered_hot_pspecs, tree_named_shardings)

__all__ = [
    "use_mesh", "current_mesh", "make_device_mesh", "host_mesh",
    "parse_mesh_flag",
    "dp_axes", "current_dp_axes", "maybe_shard", "shard_batch_dim",
    "tree_named_shardings", "replicate_like", "cell_shardings",
    "lm_batch_pspecs", "lm_cache_pspecs", "lm_kv_cache_pspecs",
    "lm_param_pspecs", "recsys_table_pspecs", "packed_table_pspecs",
    "packed_serve_pspecs", "tiered_hot_pspecs",
    "sharded_packed_lookup", "sharded_tiered_hot_lookup",
    "sharded_embedding_bag", "sharded_flash_attention",
    "sharded_mixed_expectation", "sharded_value_and_grad",
]
