"""Serving launcher: drive the packed-table engine with a live traffic mix.

Thin CLI over ``repro.serve.Engine`` (the paper's §4 deployment path):
train-or-load a packed mixed-precision table, register the serve cell shapes
(``serve_p99`` for latency traffic, ``serve_bulk`` for offline jobs), then
stream request batches through ``engine.score``. Requests of any size ride
the registered shapes via pad-to-shape batching — ``--batch 300`` really
issues 300-row requests (padded onto the 512-row p99 cell), it no longer
silently falls back to the training batch size.

``--qps`` switches to **open-loop** mode: request arrivals follow seeded
exponential inter-arrival times at the offered rate (the way offline replay
of production traffic drives a server — arrivals don't wait for service), and
concurrent requests coalesce through the admission queue + scheduler onto
shared padded cells. The report then adds the per-request queue-wait /
batch-assembly / compute breakdown, shed counts and per-cell occupancy.

Per-cell p50/p99 latency is reported in the Figure-5 lookup-vs-compute split,
plus the cell-cache counters (a warm process performs zero recompiles).

``--repack-budget`` demonstrates **serving-time precision adaptation**
(``repro.serve.repack``): halfway through the request stream the planner
emits a new per-group assignment at that fraction of the current packed
payload bytes and the swapper re-packs + swaps it into the live cells — the
run asserts the swap compiled nothing. Pair with ``--repack-headroom`` to
pack the serving table with spare per-width row capacity so demoted groups
can land in intermediate widths instead of bottoming out at width 0.

``--cache-policy decay`` turns the tiered store's hit/miss stream into a
**traffic-adaptive hot set** (``repro.cache.policy``): exponential-decay
admission scores plan bounded promotion/demotion batches every
``--policy-every`` scheduling rounds, applied incrementally — no re-pack, no
recompile. ``--drift``/``--shift-at`` make the request stream non-stationary
(``DriftingCTR``), and ``--writeback N`` interleaves training-update
writebacks with live traffic.

    python -m repro.launch.serve --steps 20 --batch 300
    python -m repro.launch.serve --steps 50 --batch 300 --bulk 20000 --json out.json
    python -m repro.launch.serve --qps 20 --steps 100 --batch 60 --deadline-ms 2000
    python -m repro.launch.serve --steps 20 --repack-budget 0.6 --repack-headroom 0.5
    python -m repro.launch.serve --qps 40 --steps 200 --batch 60 --hot-frac 0.2 \
        --cache-policy decay --decay-halflife 64 --shift-at 60 --writeback 16
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.core.mpe import MPEConfig
from repro.core.pipeline import run_mpe_pipeline
from repro.data.synthetic import CTRSpec, SyntheticCTR
from repro.dist.mesh import init_distributed, parse_mesh_flag
from repro.embeddings.table import FieldSpec
from repro.models.dlrm import DLRMConfig
from repro.serve import Engine
from repro.train.optimizer import adam
from repro.zoo import dlrm_builder

DEFAULT_VOCABS = (2000, 1000, 1500, 800)


def train_packed_dlrm(*, field_vocabs=DEFAULT_VOCABS, train_steps: int = 120,
                      train_batch: int = 1024, d_embed: int = 16,
                      mlp_hidden=(64, 32), lam: float = 3e-5, seed: int = 0):
    """Quick MPE pipeline → (serve cfg, params, state, buffers, dataset
    spec, pipeline result). The packed table + retrained interaction net are
    exactly what the engine binds at cell registration."""
    spec = CTRSpec(field_vocabs=tuple(field_vocabs), batch_size=train_batch,
                   seed=seed)
    ds = SyntheticCTR(spec)
    fields = tuple(FieldSpec(f"f{i}", v) for i, v in enumerate(spec.field_vocabs))
    base = DLRMConfig(fields=fields, d_embed=d_embed, mlp_hidden=tuple(mlp_hidden),
                      backbone="dnn")
    build = dlrm_builder(base, ds.expected_frequencies(), lam=lam)
    res = run_mpe_pipeline(build, lambda s: ds.batch(s),
                           key=jax.random.PRNGKey(seed), mpe_cfg=MPEConfig(lam=lam),
                           optimizer=adam(1e-3), search_steps=train_steps,
                           retrain_steps=train_steps, log_fn=lambda *a: None)

    cfg = base._replace(compressor="packed",
                        comp_cfg={"bits": res["packed_meta"]["bits"],
                                  "d": res["packed_meta"]["d"],
                                  "n": res["packed_meta"]["n"]})
    params = {k: v for k, v in res["final_params"].items() if k != "embedding"}
    params["embedding"] = res["packed_table"]
    buffers = dict(res["buffers"], embedding={})
    return cfg, params, res["state"], buffers, spec, res


def build_engine(cfg, params, state, buffers, *, p99_rows: int = 512,
                 bulk_rows: int = 4096, lookup_split: bool = True,
                 store=None, mesh=None, shard_lookup: bool | None = None,
                 lookup_comms: str = "psum",
                 bucket_capacity: int | None = None,
                 queue_capacity: int = 1024, quotas=None,
                 shed_watermark: float = 1.0,
                 coalesce_window_ms: float = 0.0, clock=None) -> Engine:
    """An engine with the standard cell-shape registry for one DLRM table.

    With a ``repro.cache.TieredTableStore`` in ``store``, the same shapes are
    additionally registered as tiered cells (``tiered_p99``/``tiered_bulk``)
    served through ``engine.score_tiered``. A multi-device ``mesh`` compiles
    every cell against it; ``shard_lookup`` (default: on exactly when the
    mesh has >1 device) routes the packed/hot gathers through the
    ``shard_map`` wrappers of ``repro.dist.shard``; ``lookup_comms="a2a"``
    switches those wrappers to the capacity-bucketed all-to-all id shuffle
    (``bucket_capacity`` bounds ids per destination shard, overflow spills
    to the psum merge — bit-exact at any capacity). ``quotas`` /
    ``shed_watermark`` / ``coalesce_window_ms`` / ``clock`` pass through to
    the engine's multi-tenant admission and scheduling policy."""
    from repro.models.dlrm import DLRM
    engine = Engine(mesh=mesh, queue_capacity=queue_capacity, quotas=quotas,
                    shed_watermark=shed_watermark,
                    coalesce_window_ms=coalesce_window_ms, clock=clock)
    if shard_lookup is None:
        shard_lookup = engine.mesh.size > 1
    engine.register_packed_model(
        "dlrm", DLRM, cfg, params, state, buffers,
        shapes={"serve_p99": p99_rows, "serve_bulk": bulk_rows},
        lookup_split=lookup_split, shard_lookup=shard_lookup,
        lookup_comms=lookup_comms, bucket_capacity=bucket_capacity)
    if store is not None:
        engine.register_tiered_model(
            "dlrm", DLRM, cfg, params, state, buffers, store,
            shapes={"tiered_p99": p99_rows, "tiered_bulk": bulk_rows},
            shard_lookup=shard_lookup,
            lookup_comms=lookup_comms, bucket_capacity=bucket_capacity)
    return engine


def repack_tools(engine, res, frequencies, *, lam: float = 3e-5):
    """A ``(RepackPlanner, TableSwapper)`` pair bound to a live engine.

    ``res`` is the ``run_mpe_pipeline`` result dict (the swapper re-packs
    from its retrained full-precision master embedding); ``frequencies``
    orders the planner's demote/promote priorities and recovers the
    feature→group map the pipeline trained with (serving buffers don't carry
    it). Capacities default to the engine's live subtable shapes."""
    from repro.core.mpe import make_groups
    from repro.serve.repack import (RepackPlanner, TableSwapper,
                                    subtable_capacities)
    mpe_cfg = MPEConfig(lam=lam)
    gof, _ = make_groups(frequencies, mpe_cfg.group_size)
    planner = RepackPlanner(res["packed_meta"], gof,
                            subtable_capacities(engine.live_packed_table()),
                            frequencies=frequencies)
    emb = res["final_params"]["embedding"]
    swapper = TableSwapper(engine, emb["emb"], emb["alpha"], emb["beta"],
                           mpe_cfg)
    return planner, swapper


def run_open_loop(engine, make_ids, n_requests: int, qps: float, *,
                  seed: int = 0, deadline_ms: float | None = None,
                  kind: str = "score", on_submit=None) -> dict:
    """Open-loop replay: offered traffic at ``qps`` on a virtual timeline.

    Arrivals are seeded exponential inter-arrival times (Poisson traffic at
    the offered rate); they **don't wait for service** — when the offered
    rate exceeds capacity the queue grows until the admission policy sheds.
    The scheduler threads the virtual clock through dispatch (queue-wait is
    virtual-time from arrival to first dispatch) while assembly/compute are
    measured wall-clock, so one CPU run still produces an honest breakdown.
    Inject ``serve.TickClock`` into the engine to make the whole trajectory
    — coalescing, sheds, tier hits — deterministic for the CI bench gate.

    ``on_submit(i, ids)`` (optional) runs right before request ``i`` is
    admitted — the hook the launcher uses to interleave training-update
    writebacks (``Engine.writeback_embeddings``) with live traffic.

    Returns {tickets, makespan_s, offered_qps, goodput_qps, completed,
    shed} — per-request latency percentiles live in
    ``engine.request_summary()``.
    """
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / qps, size=n_requests))
    tickets, shed = [], 0
    now, i = 0.0, 0
    while i < n_requests or engine.scheduler.busy:
        if not engine.scheduler.busy and i < n_requests and arrivals[i] > now:
            now = float(arrivals[i])        # idle server: jump to the arrival
        while i < n_requests and arrivals[i] <= now:
            ids = make_ids(i)
            if on_submit is not None:
                on_submit(i, ids)
            t = engine.submit(ids, kind=kind, now=float(arrivals[i]),
                              deadline_ms=deadline_ms)
            if t is None:
                shed += 1
            tickets.append(t)
            i += 1
        now = engine.sched_step(now=now)
        if (not engine.scheduler._progress and i < n_requests
                and float(arrivals[i]) < now):
            # the round held for its coalescing window and jumped the cursor
            # past the next arrival — cap the jump so that arrival gets to
            # join the held batch before the window decision is remade
            now = float(arrivals[i])
    from repro.serve.queue import DONE, FAILED, SHED
    completed = sum(1 for t in tickets
                    if t is not None and engine._requests[t].status == DONE)
    shed += sum(1 for t in tickets
                if t is not None and engine._requests[t].status == SHED)
    failed = sum(1 for t in tickets
                 if t is not None and engine._requests[t].status == FAILED)
    makespan = max(now, float(arrivals[-1])) if n_requests else now
    return {"tickets": tickets, "makespan_s": makespan,
            "offered_qps": qps,
            "goodput_qps": completed / makespan if makespan > 0 else 0.0,
            "completed": completed, "shed": shed, "failed": failed}


def run_open_loop_mix(engine, make_ids, streams, *, seed: int = 0,
                      kind: str = "score") -> dict:
    """Multi-tenant open-loop replay: merge several Poisson request streams
    onto one virtual timeline.

    Each stream is a dict: ``{"tenant": str, "qps": float, "n_requests":
    int, "priority": int = 0, "deadline_ms": float | None = None,
    "batch": int | None = None}``. Arrivals across streams interleave in
    timestamp order and every request is submitted with its stream's
    tenant/priority/deadline — the two-tenant skewed-priority sweep
    ``queue_bench`` reports is exactly this with one latency-sensitive and
    one bulk stream. ``make_ids(i, batch)`` makes the i-th request's id
    batch (``batch=None`` means the stream's default size).

    Returns {makespan_s, per_stream: {tenant: {offered_qps, completed,
    shed, failed, goodput_qps}}}; per-lane/per-tenant percentiles live in
    ``engine.request_summary(by=...)``.
    """
    rng = np.random.default_rng(seed)
    events = []     # (arrival_t, global_idx, stream)
    gi = 0
    for s in streams:
        arr = np.cumsum(rng.exponential(1.0 / s["qps"],
                                        size=s["n_requests"]))
        for t in arr:
            events.append((float(t), gi, s))
            gi += 1
    events.sort(key=lambda e: (e[0], e[1]))
    tickets = {id(s): [] for s in streams}
    submitted_shed = {id(s): 0 for s in streams}
    now, i = 0.0, 0
    while i < len(events) or engine.scheduler.busy:
        if not engine.scheduler.busy and i < len(events) \
                and events[i][0] > now:
            now = events[i][0]
        while i < len(events) and events[i][0] <= now:
            t_arr, idx, s = events[i]
            t = engine.submit(make_ids(idx, s.get("batch")), kind=kind,
                              now=t_arr, deadline_ms=s.get("deadline_ms"),
                              tenant=s.get("tenant", "default"),
                              priority=s.get("priority", 0))
            if t is None:
                submitted_shed[id(s)] += 1
            tickets[id(s)].append(t)
            i += 1
        now = engine.sched_step(now=now)
        if (not engine.scheduler._progress and i < len(events)
                and events[i][0] < now):
            now = events[i][0]
    from repro.serve.queue import DONE, FAILED, SHED
    makespan = max(now, events[-1][0]) if events else now
    per_stream = {}
    for s in streams:
        stats = {DONE: 0, SHED: submitted_shed[id(s)], FAILED: 0}
        for t in tickets[id(s)]:
            if t is None:
                continue
            st = engine._requests[t].status
            if st in stats:
                stats[st] += 1
        per_stream[s.get("tenant", "default")] = {
            "offered_qps": s["qps"], "completed": stats[DONE],
            "shed": stats[SHED], "failed": stats[FAILED],
            "goodput_qps": (stats[DONE] / makespan if makespan > 0 else 0.0)}
    return {"makespan_s": makespan, "per_stream": per_stream}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--batch", type=int, default=512,
                    help="rows per scoring request (any size; the batcher "
                         "pads/chunks onto the registered cell shapes)")
    ap.add_argument("--steps", type=int, default=50,
                    help="number of scoring requests to issue")
    ap.add_argument("--train-steps", type=int, default=120)
    ap.add_argument("--p99-rows", type=int, default=512,
                    help="serve_p99 cell capacity")
    ap.add_argument("--bulk-rows", type=int, default=4096,
                    help="serve_bulk cell capacity")
    ap.add_argument("--bulk", type=int, default=0,
                    help="also issue one bulk job of this many rows")
    ap.add_argument("--qps", type=float, default=None,
                    help="open-loop mode: offer --steps requests of --batch "
                         "rows at this rate with seeded exponential "
                         "inter-arrival times (offline replay of production "
                         "traffic); concurrent requests coalesce through the "
                         "admission queue onto shared padded cells")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="open-loop per-request deadline: requests still "
                         "queued past it are shed instead of dispatched")
    ap.add_argument("--queue-capacity", type=int, default=1024,
                    help="admission-queue bound (reject-on-full shedding)")
    ap.add_argument("--coalesce-window-ms", type=float, default=0.0,
                    help="max-wait coalescing window: hold a lane's light "
                         "load up to this long for a fuller bucket (0 "
                         "dispatches immediately)")
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for the open-loop inter-arrival times")
    ap.add_argument("--hot-frac", type=float, default=None,
                    help="also serve through a hot/cold TieredTableStore "
                         "pinning this fraction of features device-resident "
                         "(repro.cache; requests go through score_tiered "
                         "with cold fills prefetched one chunk ahead)")
    ap.add_argument("--cache-policy", choices=("static", "decay"),
                    default=None,
                    help="tier policy over the TieredTableStore (requires "
                         "--hot-frac; open-loop requests then ride the "
                         "tiered lane): 'decay' adapts the hot set with "
                         "exponential-decay admission scores "
                         "(repro.cache.policy), 'static' keeps the "
                         "training-frequency split but runs the identical "
                         "observation/plan machinery as the baseline")
    ap.add_argument("--decay-halflife", type=float, default=256.0,
                    help="decay-policy score half-life, in observation "
                         "ticks (one tick per dispatched chunk)")
    ap.add_argument("--policy-every", type=int, default=8,
                    help="plan/apply tier moves every this many scheduling "
                         "rounds")
    ap.add_argument("--writeback", type=int, default=0,
                    help="every N open-loop requests, write the request's "
                         "features' master embeddings back through "
                         "Engine.writeback_embeddings (train→serve update "
                         "flow; 0 disables)")
    ap.add_argument("--drift", type=float, default=0.0,
                    help="non-stationary traffic: rotate each field's "
                         "popularity ranks by this many ids per request "
                         "step (DriftingCTR)")
    ap.add_argument("--shift-at", type=int, default=None,
                    help="hard popularity shift: from this request step on, "
                         "rotate each field's hot set by --shift-frac of "
                         "its vocabulary")
    ap.add_argument("--shift-frac", type=float, default=0.3,
                    help="fraction of each field's vocabulary the "
                         "--shift-at popularity shift moves")
    ap.add_argument("--repack-budget", type=float, default=None,
                    help="serving-time precision adaptation: halfway through "
                         "the request stream, plan a new per-group "
                         "assignment at this fraction of the current packed "
                         "payload bytes and swap it into the live cells "
                         "(repro.serve.repack; zero recompiles, asserted)")
    ap.add_argument("--repack-headroom", type=float, default=None,
                    help="pack the serving table with every non-zero width "
                         "bucket sized to hold this fraction of the features "
                         "(headroom_capacities), so repacks can move groups "
                         "between intermediate widths")
    ap.add_argument("--mesh", default=None,
                    help="'dp,mp', 'pod,dp,mp' or 'auto': compile the serve "
                         "cells against a (data, model) — or multi-pod "
                         "(pod, data, model) — device mesh: requests "
                         "batch-shard over the non-model axes, packed "
                         "subtables row-shard over model and the fused "
                         "lookup runs under shard_map (repro.dist.shard). "
                         "Virtualize CPU devices with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    ap.add_argument("--lookup-comms", choices=("psum", "a2a"), default="psum",
                    help="model-axis comms for the sharded packed lookup: "
                         "'psum' merges full dequantized partials (default), "
                         "'a2a' all-to-alls the ids and ships back only the "
                         "packed quantized words each shard owns "
                         "(capacity-bucketed; bit-exact either way)")
    ap.add_argument("--bucket-capacity", type=int, default=None,
                    help="a2a ids per destination shard per batch slice "
                         "(default: the full slice, i.e. no overflow); "
                         "overflow ids spill deterministically to the psum "
                         "merge")
    ap.add_argument("--coordinator", default=None,
                    help="multi-host: coordinator address host:port for "
                         "jax.distributed.initialize (single-host runs "
                         "leave this unset)")
    ap.add_argument("--num-hosts", type=int, default=None,
                    help="multi-host: total process count")
    ap.add_argument("--host-id", type=int, default=None,
                    help="multi-host: this process's index in [0, num-hosts)")
    ap.add_argument("--json", default=None,
                    help="write the latency/compile summary to this path")
    args = ap.parse_args(argv)
    init_distributed(coordinator=args.coordinator,
                     num_processes=args.num_hosts, process_id=args.host_id)
    mesh = parse_mesh_flag(args.mesh)
    if mesh is not None:
        print(f"[serve] mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    cfg, params, state, buffers, spec, res = train_packed_dlrm(
        train_steps=args.train_steps)
    print(f"[serve] packed table: ratio={res['storage_ratio']:.4f} "
          f"bytes={res['packed_bytes']}")

    if args.repack_headroom is not None:
        from repro.core.inference import build_packed_table
        from repro.serve.repack import headroom_capacities
        emb = res["final_params"]["embedding"]
        caps = headroom_capacities(res["packed_meta"],
                                   fraction=args.repack_headroom)
        table, meta = build_packed_table(
            emb["emb"], res["feature_bits_idx"], emb["alpha"], emb["beta"],
            MPEConfig(lam=3e-5), row_capacities=caps)
        params["embedding"] = table
        res = dict(res, packed_table=table, packed_meta=meta)
        print(f"[serve] headroom capacities: {caps}")

    store = None
    if args.cache_policy is not None and args.hot_frac is None:
        ap.error("--cache-policy requires --hot-frac (a tiered store)")
    if args.hot_frac is not None:
        from repro.cache import TieredTableStore
        freqs = SyntheticCTR(spec).expected_frequencies()
        store = TieredTableStore(res["packed_table"], res["packed_meta"],
                                 freqs, args.hot_frac)
        s = store.storage()
        print(f"[serve] tiered store: hot_frac={args.hot_frac} "
              f"hot={s['hot_bytes']}B (device) cold={s['cold_bytes']}B (host)")

    engine = build_engine(cfg, params, state, buffers,
                          p99_rows=args.p99_rows, bulk_rows=args.bulk_rows,
                          store=store, mesh=mesh,
                          lookup_comms=args.lookup_comms,
                          bucket_capacity=args.bucket_capacity,
                          queue_capacity=args.queue_capacity,
                          coalesce_window_ms=args.coalesce_window_ms)
    print(f"[serve] registered cells: "
          f"{dict(sorted(engine.registered_shapes.items()))} "
          f"(compiles={engine.compile_count})")

    if args.cache_policy is not None:
        from repro.cache import DecayAdmissionPolicy, StaticTierPolicy
        if args.cache_policy == "decay":
            policy = DecayAdmissionPolicy(store.meta["n"],
                                          halflife=args.decay_halflife)
        else:
            policy = StaticTierPolicy()
        engine.attach_tier_policy(policy, every=args.policy_every)
        print(f"[serve] cache policy: {args.cache_policy} "
              f"(halflife={args.decay_halflife}, every={args.policy_every})")

    # request stream at the *requested* batch size — decoupled from training
    if args.drift or args.shift_at is not None:
        from repro.data.synthetic import DriftingCTR
        req_ds = DriftingCTR(spec._replace(batch_size=args.batch),
                             drift_rate=args.drift, shift_at=args.shift_at,
                             shift_frac=args.shift_frac, step0=10_000)
        print(f"[serve] drifting traffic: rate={args.drift} "
              f"shift_at={args.shift_at} shift_frac={args.shift_frac}")
    else:
        req_ds = SyntheticCTR(spec._replace(batch_size=args.batch))

    on_submit = None
    if args.writeback:
        master = np.asarray(res["final_params"]["embedding"]["emb"])
        offs = np.asarray(buffers["offsets"], np.int64)

        def on_submit(i, ids):
            if i == 0 or i % args.writeback:
                return
            gids = np.unique(np.asarray(ids, np.int64) + offs[None, :])
            engine.writeback_embeddings(gids, master[gids])

    repack_info = None

    def _queue_repack():
        """Plan at the budget and queue the swap — it lands atomically at
        the engine's next ``sched_step`` boundary, mid-stream."""
        nonlocal repack_info
        freqs = SyntheticCTR(spec).expected_frequencies()
        planner, swapper = repack_tools(engine, res, freqs)
        gbits = np.asarray(res["group_bits"])
        plan = planner.plan_budget(
            gbits, int(args.repack_budget * planner.bytes_packed(gbits)))
        swapper.repack(plan)
        repack_info = (engine.compile_count, plan)

    req_kind = "tiered" if args.cache_policy is not None else "score"
    open_loop = None
    if args.qps:
        warm_ids = req_ds.batch(9_999)["ids"]
        engine.score(warm_ids)                     # warm the cells
        if req_kind == "tiered":
            engine.score_tiered(warm_ids)
        if args.repack_budget is not None:
            _queue_repack()   # applies at the open loop's first round
        open_loop = run_open_loop(
            engine, lambda i: req_ds.batch(10_000 + i)["ids"], args.steps,
            args.qps, seed=args.seed, deadline_ms=args.deadline_ms,
            kind=req_kind, on_submit=on_submit)
    else:
        for step in range(args.steps):
            if args.repack_budget is not None and step == args.steps // 2:
                _queue_repack()
            ids = req_ds.batch(10_000 + step)["ids"]
            if on_submit is not None:
                on_submit(step, ids)
            engine.score(ids)
            if store is not None:
                engine.score_tiered(ids)
    if repack_info is not None:
        c0, plan = repack_info
        if engine.compile_count != c0:
            raise RuntimeError("serving-time repack recompiled a cell — the "
                               "zero-recompile invariant is broken")
        print(f"[serve] repack: bytes {plan.bytes_before} -> "
              f"{plan.bytes_packed} ({plan.n_features_moved} features "
              f"moved), swaps={engine.swaps_applied}, recompiles=0")
    if args.bulk:
        bulk_ds = SyntheticCTR(spec._replace(batch_size=args.bulk))
        bulk_ids = bulk_ds.batch(99_999)["ids"]
        engine.score(bulk_ids)
        if store is not None:
            engine.score_tiered(bulk_ids)

    skip = min(3, max(args.steps - 1, 0))  # drop compile-adjacent warmup
    print(f"[serve] batch={args.batch} steps={args.steps}"
          + (f" bulk={args.bulk}" if args.bulk else "")
          + (f" qps={args.qps}" if args.qps else ""))
    print(engine.stats.format_table(skip_warmup=skip))
    if open_loop is not None:
        print(f"[serve] open loop: offered={open_loop['offered_qps']:.1f}qps "
              f"goodput={open_loop['goodput_qps']:.1f}qps "
              f"completed={open_loop['completed']} shed={open_loop['shed']}")
        print(engine.rstats.format_table(skip_warmup=skip))
    counters = engine.counters()
    print(f"[serve] cell cache: compiles={counters['compiles']} "
          f"hits={counters['hits']} (warm process ⇒ zero recompiles)")
    occ = counters["occupancy"]
    if occ:
        print("[serve] occupancy: " + " ".join(
            f"{cell}={v['occupancy']:.2f}" for cell, v in occ.items()))
    if store is not None:
        c = store.counters()
        print(f"[serve] tiers: hit_rate={c['hit_rate']:.3f} "
              f"cold_bytes_moved={c['bytes_moved']}")
        if args.cache_policy is not None:
            m = engine.tier_moves
            print(f"[serve] tier policy: plans={m['plans']} "
                  f"promotions={m['promotions']} demotions={m['demotions']} "
                  f"moved_bytes={m['bytes']}")
        if args.writeback:
            print(f"[serve] writeback: writes={c['writebacks']} "
                  f"bytes={c['writeback_bytes']}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"batch": args.batch, "steps": args.steps,
                       "cells": engine.summary(skip_warmup=skip),
                       "requests": engine.request_summary(skip_warmup=skip),
                       "open_loop": ({k: v for k, v in open_loop.items()
                                      if k != "tickets"}
                                     if open_loop is not None else None),
                       "cache": counters,
                       "tiers": (store.counters() if store is not None
                                 else None),
                       "storage_ratio": res["storage_ratio"],
                       "packed_bytes": res["packed_bytes"]}, f, indent=2)
        print(f"[serve] wrote {args.json}")
    return engine


if __name__ == "__main__":
    main()
