"""Serving launcher: load a packed mixed-precision table and score requests.

Demonstrates the paper's §4 deployment: embeddings live bit-packed in memory;
lookups dequantize on the fly. Batched scoring loop with latency stats
(mirrors the paper's Figure-5 protocol: lookup vs compute split).

    python -m repro.launch.serve --steps 50 --batch 512
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mpe import MPEConfig
from repro.core.pipeline import run_mpe_pipeline
from repro.data.synthetic import CTRSpec, SyntheticCTR
from repro.embeddings.table import FieldSpec
from repro.models.dlrm import DLRM, DLRMConfig
from repro.train.optimizer import adam
from repro.zoo import dlrm_builder


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--train-steps", type=int, default=120)
    args = ap.parse_args()

    # quick pipeline to obtain a packed table + trained interaction net
    spec = CTRSpec(field_vocabs=(2000, 1000, 1500, 800), batch_size=1024)
    ds = SyntheticCTR(spec)
    fields = tuple(FieldSpec(f"f{i}", v) for i, v in enumerate(spec.field_vocabs))
    base = DLRMConfig(fields=fields, d_embed=16, mlp_hidden=(64, 32),
                      backbone="dnn")
    build = dlrm_builder(base, ds.expected_frequencies(), lam=3e-5)
    res = run_mpe_pipeline(build, lambda s: ds.batch(s),
                           key=jax.random.PRNGKey(0), mpe_cfg=MPEConfig(lam=3e-5),
                           optimizer=adam(1e-3), search_steps=args.train_steps,
                           retrain_steps=args.train_steps)
    print(f"[serve] packed table: ratio={res['storage_ratio']:.4f} "
          f"bytes={res['packed_bytes']}")

    cfg = base._replace(compressor="packed",
                        comp_cfg={"bits": res["packed_meta"]["bits"],
                                  "d": res["packed_meta"]["d"],
                                  "n": res["packed_meta"]["n"]})
    params = {k: v for k, v in res["final_params"].items() if k != "embedding"}
    params["embedding"] = res["packed_table"]
    buffers = dict(res["buffers"], embedding={})
    state = res["state"]

    @jax.jit
    def serve_step(p, batch_ids):
        logits, _, _ = DLRM.apply(p, buffers, state, {"ids": batch_ids}, cfg,
                                  train=False)
        return jax.nn.sigmoid(logits)

    lat = []
    for step in range(args.steps):
        ids = jnp.asarray(ds.batch(10_000 + step)["ids"])
        t0 = time.perf_counter()
        probs = serve_step(params, ids)
        probs.block_until_ready()
        lat.append(time.perf_counter() - t0)
    lat_ms = np.asarray(lat[3:]) * 1e3  # skip warmup
    print(f"[serve] batch={args.batch} p50={np.percentile(lat_ms, 50):.2f}ms "
          f"p99={np.percentile(lat_ms, 99):.2f}ms")


if __name__ == "__main__":
    main()
