"""Production training launcher.

Single-host CPU runs execute really; on a TPU pod slice the same script runs
under the production mesh (sharding specs from launch/cells.py). The MPE
pipeline (search → sample → retrain → export) is the default recsys flow.

Examples:
    python -m repro.launch.train --arch wide-deep --steps 500 --reduced
    python -m repro.launch.train --arch dlrm-criteo --backbone dcn \
        --compressor mpe --steps 300 --reduced --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_arch
from repro.core.mpe import MPEConfig
from repro.core.pipeline import run_mpe_pipeline
from repro.data.synthetic import CTRSpec, SyntheticCTR
from repro.dist.mesh import parse_mesh_flag
from repro.models.dlrm import DLRMConfig
from repro.train.loop import Trainer
from repro.train.optimizer import adam
from repro.zoo import dlrm_builder, wide_deep_builder


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dlrm-criteo")
    ap.add_argument("--backbone", default="dnn")
    ap.add_argument("--compressor", default="mpe",
                    help="mpe | plain | lsq | alpt | qr | pep | optfs")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--retrain-steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=2048)
    ap.add_argument("--lam", type=float, default=3e-5)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--prefetch", action="store_true",
                    help="stage batches on device one step ahead of compute "
                         "(repro.cache.PrefetchPipeline); loss-identical to "
                         "the synchronous loop")
    ap.add_argument("--mesh", default=None,
                    help="'dp,mp', 'pod,dp,mp' or 'auto': run the train step "
                         "under shard_map on a (data, model) — or multi-pod "
                         "(pod, data, model) — device mesh: batch "
                         "data-parallel over the non-model axes, "
                         "embedding-table rows sharded over the model axis "
                         "with row-shard-local grad updates "
                         "(repro.dist.shard). Virtualize CPU devices with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    mesh = parse_mesh_flag(args.mesh)
    if mesh is not None:
        print(f"[train] mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    spec = get_arch(args.arch)
    if spec.family != "recsys":
        raise SystemExit("train.py drives the recsys flow; "
                         "use examples/ for lm/gnn end-to-end runs")

    if args.arch == "wide-deep":
        cfg = spec.make_config(args.reduced)
        fields = cfg.fields
        builder_fn = wide_deep_builder
    else:
        cfg = spec.make_config(args.reduced, backbone=args.backbone) \
            if args.arch == "dlrm-criteo" else spec.make_config(args.reduced)
        fields = cfg.fields
        builder_fn = dlrm_builder
        if not isinstance(cfg, DLRMConfig):
            raise SystemExit(f"{args.arch}: use examples/ for this arch")

    ds = SyntheticCTR(CTRSpec(field_vocabs=tuple(f.vocab for f in fields),
                              batch_size=args.batch, seed=args.seed))
    eval_batches = ds.eval_set(4)
    build = builder_fn(cfg, ds.expected_frequencies(), lam=args.lam,
                       eval_batches=eval_batches)

    if args.compressor == "mpe":
        res = run_mpe_pipeline(
            build, lambda s: ds.batch(s), key=jax.random.PRNGKey(args.seed),
            mpe_cfg=MPEConfig(lam=args.lam), optimizer=adam(args.lr),
            search_steps=args.steps,
            retrain_steps=args.retrain_steps or args.steps,
            eval_fn=build(jax.random.PRNGKey(args.seed), "plain", {})["eval_fn"],
            ckpt_dir=args.ckpt_dir, prefetch=args.prefetch, mesh=mesh)
        print(f"[train] MPE ratio={res['storage_ratio']:.4f} "
              f"avg_bits={res['avg_bits']:.2f} eval={res['eval']}")
        return

    comp_cfg = {"bits": 6} if args.compressor == "lsq" else \
               {"bits": 8} if args.compressor == "alpt" else \
               {"total_steps": args.steps} if args.compressor == "optfs" else {}
    bundle = build(jax.random.PRNGKey(args.seed), args.compressor, comp_cfg)
    from repro.core import get_compressor
    comp = get_compressor(args.compressor)
    post = None
    if args.compressor == "alpt":
        key_holder = {"k": jax.random.PRNGKey(args.seed + 1)}

        def post(params):
            key_holder["k"], sub = jax.random.split(key_holder["k"])
            emb = comp.post_update(params["embedding"], {}, comp_cfg, sub)
            return dict(params, embedding=emb)

    trainer = Trainer(bundle["loss_fn"], bundle["params"], bundle["buffers"],
                      bundle["state"], adam(args.lr), ckpt_dir=args.ckpt_dir,
                      post_update=post, mesh=mesh)
    trainer.restore()
    trainer.run(lambda s: ds.batch(s), args.steps, prefetch=args.prefetch)
    ev = bundle["eval_fn"](trainer.params, bundle["buffers"], trainer.state)
    r = comp.storage_ratio(trainer.params["embedding"],
                           bundle["buffers"]["embedding"], comp_cfg)
    print(f"[train] {args.compressor} ratio={r:.4f} eval={ev}")


if __name__ == "__main__":
    main()
