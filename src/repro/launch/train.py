"""Production training launcher.

Single-host CPU runs execute really; on a TPU pod slice the same script runs
under the production mesh (sharding specs from launch/cells.py). The MPE
pipeline (search → sample → retrain → export) is the default recsys flow.

Examples:
    python -m repro.launch.train --arch wide-deep --steps 500 --reduced
    python -m repro.launch.train --arch dlrm-criteo --backbone dcn \
        --compressor mpe --steps 300 --reduced --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_arch
from repro.core.mpe import MPEConfig
from repro.core.pipeline import run_mpe_pipeline
from repro.data.synthetic import CTRSpec, SyntheticCTR
from repro.dist.mesh import init_distributed, parse_mesh_flag
from repro.models.dlrm import DLRMConfig
from repro.train.loop import Trainer
from repro.train.optimizer import adam
from repro.zoo import dlrm_builder, wide_deep_builder


def _check_packed_lookup(res, fields, mesh, *, lookup_comms, bucket_capacity,
                         seed):
    """Post-train packed-lookup parity check under the training mesh.

    Runs the row-sharded lookup on the just-packed table through the
    selected comms path and asserts it is bit-exact against the
    single-device ``core.inference.packed_lookup`` reference, printing the
    deterministic a2a routing counters — the quickest way to see, on a real
    mesh, how the chosen ``--bucket-capacity`` routes this table's traffic.
    """
    import numpy as np

    from repro.core.inference import packed_lookup
    from repro.dist.shard import lookup_route_stats, sharded_packed_lookup

    table, meta = res["packed_table"], res["packed_meta"]
    rng = np.random.default_rng(seed)
    ids = jax.numpy.asarray(rng.integers(0, meta["n"], size=(512,)),
                            dtype=jax.numpy.int32)
    want = np.asarray(packed_lookup(table, meta, ids))
    got = np.asarray(sharded_packed_lookup(
        table, meta, ids, mesh=mesh, lookup_comms=lookup_comms,
        bucket_capacity=bucket_capacity))
    exact = bool(np.array_equal(want, got))
    line = f"[train] lookup check ({lookup_comms}): bit_exact={exact}"
    if lookup_comms == "a2a":
        stats = lookup_route_stats(table, meta, ids,
                                   n_shards=mesh.shape["model"],
                                   bucket_capacity=bucket_capacity)
        line += (f" capacity={stats['capacity']} routed={stats['routed']} "
                 f"bucketed={stats['bucketed']} spilled={stats['spilled']}")
    print(line)
    if not exact:
        raise SystemExit("[train] sharded packed lookup diverged from the "
                         "single-device reference")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dlrm-criteo")
    ap.add_argument("--backbone", default="dnn")
    ap.add_argument("--compressor", default="mpe",
                    help="mpe | plain | lsq | alpt | qr | pep | optfs")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--retrain-steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=2048)
    ap.add_argument("--lam", type=float, default=3e-5)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--prefetch", action="store_true",
                    help="stage batches on device one step ahead of compute "
                         "(repro.cache.PrefetchPipeline); loss-identical to "
                         "the synchronous loop")
    ap.add_argument("--mesh", default=None,
                    help="'dp,mp', 'pod,dp,mp' or 'auto': run the train step "
                         "under shard_map on a (data, model) — or multi-pod "
                         "(pod, data, model) — device mesh: batch "
                         "data-parallel over the non-model axes, "
                         "embedding-table rows sharded over the model axis "
                         "with row-shard-local grad updates "
                         "(repro.dist.shard). Virtualize CPU devices with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    ap.add_argument("--lookup-comms", choices=("psum", "a2a"), default="psum",
                    help="model-axis comms path for the post-train packed "
                         "lookup check under --mesh: 'psum' merges "
                         "dequantized partials, 'a2a' shuffles ids and "
                         "ships back packed words (repro.dist.shard; "
                         "bit-exact either way, route stats printed)")
    ap.add_argument("--bucket-capacity", type=int, default=None,
                    help="a2a ids per destination shard per batch slice "
                         "(default: full slice); overflow spills to psum")
    ap.add_argument("--coordinator", default=None,
                    help="multi-host: coordinator host:port for "
                         "jax.distributed.initialize")
    ap.add_argument("--num-hosts", type=int, default=None,
                    help="multi-host: total process count")
    ap.add_argument("--host-id", type=int, default=None,
                    help="multi-host: this process's index in [0, num-hosts)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    init_distributed(coordinator=args.coordinator,
                     num_processes=args.num_hosts, process_id=args.host_id)
    mesh = parse_mesh_flag(args.mesh)
    if mesh is not None:
        print(f"[train] mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    spec = get_arch(args.arch)
    if spec.family != "recsys":
        raise SystemExit("train.py drives the recsys flow; "
                         "use examples/ for lm/gnn end-to-end runs")

    if args.arch == "wide-deep":
        cfg = spec.make_config(args.reduced)
        fields = cfg.fields
        builder_fn = wide_deep_builder
    else:
        cfg = spec.make_config(args.reduced, backbone=args.backbone) \
            if args.arch == "dlrm-criteo" else spec.make_config(args.reduced)
        fields = cfg.fields
        builder_fn = dlrm_builder
        if not isinstance(cfg, DLRMConfig):
            raise SystemExit(f"{args.arch}: use examples/ for this arch")

    ds = SyntheticCTR(CTRSpec(field_vocabs=tuple(f.vocab for f in fields),
                              batch_size=args.batch, seed=args.seed))
    eval_batches = ds.eval_set(4)
    build = builder_fn(cfg, ds.expected_frequencies(), lam=args.lam,
                       eval_batches=eval_batches)

    if args.compressor == "mpe":
        res = run_mpe_pipeline(
            build, lambda s: ds.batch(s), key=jax.random.PRNGKey(args.seed),
            mpe_cfg=MPEConfig(lam=args.lam), optimizer=adam(args.lr),
            search_steps=args.steps,
            retrain_steps=args.retrain_steps or args.steps,
            eval_fn=build(jax.random.PRNGKey(args.seed), "plain", {})["eval_fn"],
            ckpt_dir=args.ckpt_dir, prefetch=args.prefetch, mesh=mesh)
        print(f"[train] MPE ratio={res['storage_ratio']:.4f} "
              f"avg_bits={res['avg_bits']:.2f} eval={res['eval']}")
        if mesh is not None and mesh.shape.get("model", 1) > 1:
            _check_packed_lookup(res, fields, mesh,
                                 lookup_comms=args.lookup_comms,
                                 bucket_capacity=args.bucket_capacity,
                                 seed=args.seed)
        return

    comp_cfg = {"bits": 6} if args.compressor == "lsq" else \
               {"bits": 8} if args.compressor == "alpt" else \
               {"total_steps": args.steps} if args.compressor == "optfs" else {}
    bundle = build(jax.random.PRNGKey(args.seed), args.compressor, comp_cfg)
    from repro.core import get_compressor
    comp = get_compressor(args.compressor)
    post = None
    if args.compressor == "alpt":
        key_holder = {"k": jax.random.PRNGKey(args.seed + 1)}

        def post(params):
            key_holder["k"], sub = jax.random.split(key_holder["k"])
            emb = comp.post_update(params["embedding"], {}, comp_cfg, sub)
            return dict(params, embedding=emb)

    trainer = Trainer(bundle["loss_fn"], bundle["params"], bundle["buffers"],
                      bundle["state"], adam(args.lr), ckpt_dir=args.ckpt_dir,
                      post_update=post, mesh=mesh)
    trainer.restore()
    trainer.run(lambda s: ds.batch(s), args.steps, prefetch=args.prefetch)
    ev = bundle["eval_fn"](trainer.params, bundle["buffers"], trainer.state)
    r = comp.storage_ratio(trainer.params["embedding"],
                           bundle["buffers"]["embedding"], comp_cfg)
    print(f"[train] {args.compressor} ratio={r:.4f} eval={ev}")


if __name__ == "__main__":
    main()
