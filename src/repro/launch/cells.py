"""Dry-run cell construction: (arch × shape × mesh) → lowerable step.

A Cell bundles the step function, ShapeDtypeStruct input stand-ins (never
allocated), and in/out shardings for the production mesh. Training cells
lower the *full* train step (loss + grad + Adam update); serve cells lower
the model's serving computation — decode steps for ``decode_*``/``long_*``
(one token against a KV cache), packed-table scoring for recsys serving.

Shape cells follow the assignment exactly:
  LM:     train_4k (256×4096) · prefill_32k (32×32768) · decode_32k
          (128 @ 32768 KV) · long_500k (1 @ 524288 KV)
  GNN:    full_graph_sm · minibatch_lg (fanout 15-10 sampler shapes) ·
          ogb_products · molecule
  recsys: train_batch (65536) · serve_p99 (512) · serve_bulk (262144) ·
          retrieval_cand (1 × 1,048,576)
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.configs.gin_tu import GRAPH_CELLS
from repro.core.inference import packed_specs
from repro.core.mpe import MPEConfig
from repro.data.graphs import NeighborSampler
from repro.dist.sharding import (dp_axes, lm_batch_pspecs, lm_kv_cache_pspecs,
                                 lm_logits_pspecs, lm_param_pspecs,
                                 packed_serve_pspecs, recsys_table_pspecs,
                                 replicate_like)
from repro.models.bst import BST
from repro.models.dlrm import DLRM
from repro.models.gnn import GIN
from repro.models.lm import LM
from repro.models.sasrec import SASRec
from repro.models.two_tower import TwoTower
from repro.models.wide_deep import WideDeep
from repro.serve.cells import packed_score_step
from repro.train.optimizer import adam, apply_updates

PACKED_HIST = (0.0, 0.30, 0.20, 0.20, 0.10, 0.10, 0.10)  # widths 0..6 (b>0 rows)
MPE_BITS = (0, 1, 2, 3, 4, 5, 6)


class Cell(NamedTuple):
    name: str
    step_fn: Callable
    input_specs: tuple
    in_pspecs: tuple
    out_pspecs: Any
    meta: dict


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

LM_SHAPE_DEFS = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1, long=True),
}


def apply_overrides(cfg, overrides):
    """NamedTuple config overrides ('moe.x' targets the nested MoEConfig)."""
    if not overrides:
        return cfg
    direct = {k: v for k, v in overrides.items()
              if "." not in k and k in cfg._fields}
    cfg = cfg._replace(**direct)
    moe_over = {k.split(".", 1)[1]: v for k, v in overrides.items()
                if k.startswith("moe.")}
    if moe_over and getattr(cfg, "moe", None) is not None:
        cfg = cfg._replace(moe=cfg.moe._replace(**moe_over))
    return cfg


def build_lm_cell(arch_id: str, shape: str, multi_pod: bool,
                  overrides=None) -> Cell:
    spec = get_arch(arch_id)
    cfg = apply_overrides(spec.make_config(False), overrides)
    sd = LM_SHAPE_DEFS[shape]
    dp = dp_axes(multi_pod)
    buffers = {"embedding": {}}  # plain vocab table: no buffer state

    params_sds = jax.eval_shape(
        lambda k: LM.init(k, cfg)[0], sds((2,), jnp.uint32))
    p_pspecs = lm_param_pspecs(params_sds, cfg)

    if sd["kind"] == "train":
        opt = adam(1e-3)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        opt_pspecs = {"step": P(), "mu": p_pspecs, "nu": p_pspecs}
        batch_sds = {"tokens": sds((sd["batch"], sd["seq"]), jnp.int32),
                     "labels": sds((sd["batch"], sd["seq"]), jnp.int32)}
        b_pspecs = lm_batch_pspecs(multi_pod)

        def train_step(params, opt_state, batch):
            (loss, _), grads = jax.value_and_grad(
                lambda p: LM.loss_fn(p, buffers, batch, cfg), has_aux=True
            )(params)
            updates, new_opt = opt.update(grads, opt_state, params)
            return apply_updates(params, updates), new_opt, loss

        return Cell(
            name=f"{arch_id}/{shape}", step_fn=train_step,
            input_specs=(params_sds, opt_sds, batch_sds),
            in_pspecs=(p_pspecs, opt_pspecs, b_pspecs),
            out_pspecs=(p_pspecs, opt_pspecs, P()),
            meta={"kind": "train", "tokens": sd["batch"] * sd["seq"],
                  "family": "lm"},
        )

    cache_shape = (cfg.n_layers, sd["batch"], sd["seq"], cfg.n_kv_heads,
                   cfg.head_dim)
    kv_dtype = jnp.int8 if (overrides or {}).get("kv_int8") else jnp.bfloat16
    cache_ps = lm_kv_cache_pspecs(quantized=kv_dtype == jnp.int8,
                                  long_context=sd.get("long", False),
                                  multi_pod=multi_pod)
    caches_sds = {"k": sds(cache_shape, kv_dtype),
                  "v": sds(cache_shape, kv_dtype),
                  "len": sds((), jnp.int32)}
    if kv_dtype == jnp.int8:
        sshape = (cfg.n_layers, sd["batch"], 1, cfg.n_kv_heads, 1)
        caches_sds["k_scale"] = sds(sshape, jnp.float32)
        caches_sds["v_scale"] = sds(sshape, jnp.float32)

    if sd["kind"] == "prefill":
        tokens_sds = sds((sd["batch"], sd["seq"]), jnp.int32)

        def prefill_step(params, tokens):
            return LM.prefill(params, buffers, tokens, cfg, max_len=sd["seq"])

        return Cell(
            name=f"{arch_id}/{shape}", step_fn=prefill_step,
            input_specs=(params_sds, tokens_sds),
            in_pspecs=(p_pspecs, P(dp, None)),
            out_pspecs=(lm_logits_pspecs(sd["batch"], vocab_sharded=True,
                                         dp=dp), cache_ps),
            meta={"kind": "prefill", "tokens": sd["batch"] * sd["seq"],
                  "family": "lm"},
        )

    # decode: one new token against the KV cache
    tok_batch_ps = P(dp, None) if sd["batch"] > 1 else P(None, None)
    tokens_sds = sds((sd["batch"], 1), jnp.int32)

    def decode_step(params, tokens, caches):
        return LM.decode_step(params, buffers, tokens, caches, cfg)

    return Cell(
        name=f"{arch_id}/{shape}", step_fn=decode_step,
        input_specs=(params_sds, tokens_sds, caches_sds),
        in_pspecs=(p_pspecs, tok_batch_ps, cache_ps),
        out_pspecs=(lm_logits_pspecs(sd["batch"], dp=dp), cache_ps),
        meta={"kind": "decode", "tokens": sd["batch"], "family": "lm",
              "kv_len": sd["seq"]},
    )


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------

def build_gnn_cell(arch_id: str, shape: str, multi_pod: bool) -> Cell:
    spec = get_arch(arch_id)
    cfg = spec.make_config(False, shape=shape)
    cell = GRAPH_CELLS[shape]
    dp = dp_axes(multi_pod)
    edge_ax = (*dp, "model")
    opt = adam(1e-3)

    if shape == "minibatch_lg":
        n_nodes, n_edges = NeighborSampler.output_sizes(cell.batch_nodes,
                                                        cell.fanout)
    elif shape == "molecule":
        n_nodes = cell.n_graphs * cell.n_nodes
        n_edges = cell.n_graphs * cell.n_edges
    else:
        n_nodes, n_edges = cell.n_nodes, cell.n_edges
    # pad the edge list to the full mesh size (512 covers both meshes) so the
    # edge shards are even; padded edges carry edge_mask = False
    n_edges = -(-n_edges // 512) * 512

    graph_sds = {
        "edge_src": sds((n_edges,), jnp.int32),
        "edge_dst": sds((n_edges,), jnp.int32),
        "edge_mask": sds((n_edges,), jnp.bool_),
        "labels": sds((cell.n_graphs if cfg.readout == "graph" else n_nodes,),
                      jnp.int32),
    }
    graph_ps = {"edge_src": P(edge_ax), "edge_dst": P(edge_ax),
                "edge_mask": P(edge_ax), "labels": P(None)}
    if cfg.input_mode == "categorical":
        graph_sds["atom_ids"] = sds((n_nodes,), jnp.int32)
        graph_sds["graph_ids"] = sds((n_nodes,), jnp.int32)
        graph_ps["atom_ids"] = P(None)
        graph_ps["graph_ids"] = P(None)
        n_graphs = cell.n_graphs
    else:
        graph_sds["x"] = sds((n_nodes, cell.d_feat), jnp.float32)
        graph_ps["x"] = P(None, None)
        n_graphs = 0
    if shape == "minibatch_lg":
        graph_sds["label_mask"] = sds((n_nodes,), jnp.float32)
        graph_ps["label_mask"] = P(None)

    init_fn = lambda k: GIN.init(k, cfg)
    params_sds, buffers_sds = jax.eval_shape(init_fn, sds((2,), jnp.uint32))
    p_pspecs = replicate_like(params_sds)
    bufs_pspecs = replicate_like(buffers_sds)
    opt_sds = jax.eval_shape(opt.init, params_sds)
    opt_pspecs = {"step": P(), "mu": p_pspecs, "nu": p_pspecs}

    def train_step(params, opt_state, buffers, graph):
        if n_graphs:
            graph = dict(graph, n_graphs=n_graphs)
        (loss, _), grads = jax.value_and_grad(
            lambda p: GIN.loss_fn(p, buffers, graph, cfg, lam=1e-5),
            has_aux=True)(params)
        updates, new_opt = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), new_opt, loss

    return Cell(
        name=f"{arch_id}/{shape}", step_fn=train_step,
        input_specs=(params_sds, opt_sds, buffers_sds, graph_sds),
        in_pspecs=(p_pspecs, opt_pspecs, bufs_pspecs, graph_ps),
        out_pspecs=(p_pspecs, opt_pspecs, P()),
        meta={"kind": "train", "family": "gnn", "n_edges": n_edges,
              "n_nodes": n_nodes},
    )


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

RECSYS_BATCH = {"train_batch": 65536, "serve_p99": 512, "serve_bulk": 262144,
                "retrieval_cand": 1}
N_CANDIDATES = 1_048_576
SERVE_CANDS = 1000  # candidate set for sasrec online scoring


def _mpe_comp_cfg():
    return MPEConfig()._asdict()


def _packed_cfg(n, d):
    return {"bits": MPE_BITS, "d": d, "n": n}


def _mpe_buffer_specs(n: int, group_size: int = 128):
    g = -(-n // group_size)
    return {"group_of_feature": sds((n,), jnp.int32),
            "freq_sum": sds((g,), jnp.float32)}


def _mpe_param_specs(n: int, d: int, m: int = 7, group_size: int = 128):
    g = -(-n // group_size)
    return {"emb": sds((n, d), jnp.float32), "gamma": sds((g, m), jnp.float32),
            "alpha": sds((m,), jnp.float32), "beta": sds((d,), jnp.float32)}


def _mpe_emb_pspecs(rows_axes):
    return recsys_table_pspecs(rows_axes)


def _packed_param_specs(n, d):
    return packed_specs(n, d, MPEConfig(), PACKED_HIST)


def build_recsys_cell(arch_id: str, shape: str, multi_pod: bool,
                      overrides=None) -> Cell:
    spec = get_arch(arch_id)
    dp = dp_axes(multi_pod)
    rows_axes = (*dp, "model")
    batch = RECSYS_BATCH[shape]
    train = shape == "train_batch"
    builder = {
        "wide-deep": _wide_deep_cell, "dlrm-criteo": _dlrm_cell,
        "two-tower-retrieval": _two_tower_cell, "bst": _bst_cell,
        "sasrec": _sasrec_cell,
    }[arch_id]
    global _RECSYS_OVERRIDES
    _RECSYS_OVERRIDES = overrides or {}
    if _RECSYS_OVERRIDES.get("table_model_only"):
        rows_axes = ("model",)
    return builder(spec, shape, batch, train, dp, rows_axes, multi_pod)


_RECSYS_OVERRIDES: dict = {}


def _train_cell(name, model_loss, params_sds, buffers_sds, state_sds,
                p_pspecs, bufs_pspecs, st_pspecs, batch_sds, batch_ps, meta):
    import jax.numpy as _jnp
    moment_dtype = (_jnp.bfloat16 if _RECSYS_OVERRIDES.get("bf16_moments")
                    else None)
    opt = adam(1e-3, moment_dtype=moment_dtype)
    opt_sds = jax.eval_shape(opt.init, params_sds)
    opt_pspecs = {"step": P(), "mu": p_pspecs, "nu": p_pspecs}

    def train_step(params, opt_state, state, buffers, batch):
        (loss, (new_state, _)), grads = jax.value_and_grad(
            lambda p: model_loss(p, buffers, state, batch), has_aux=True)(params)
        updates, new_opt = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), new_opt, new_state, loss

    return Cell(
        name=name, step_fn=train_step,
        input_specs=(params_sds, opt_sds, state_sds, buffers_sds, batch_sds),
        in_pspecs=(p_pspecs, opt_pspecs, st_pspecs, bufs_pspecs, batch_ps),
        out_pspecs=(p_pspecs, opt_pspecs, st_pspecs, P()),
        meta=meta,
    )


def _serve_cell(name, serve_fn, inputs_sds, inputs_ps, out_ps, meta):
    return Cell(name=name, step_fn=serve_fn, input_specs=inputs_sds,
                in_pspecs=inputs_ps, out_pspecs=out_ps, meta=meta)


# -- wide-deep / dlrm (flat multi-field CTR) --------------------------------

def _flat_ctr_cell(spec, shape, batch, train, dp, rows_axes, multi_pod, *,
                   model, n_fields_attr="fields"):
    if train:
        cfg = spec.make_config(False)
        fields = cfg.fields
        n = int(sum(f.vocab for f in fields))
        d = cfg.d_embed
        init_fn = lambda k: model.init(k, cfg)
        params_sds, buffers_sds, state_sds = jax.eval_shape(
            init_fn, sds((2,), jnp.uint32))
        p_pspecs = jax.tree.map(lambda x: P(*([None] * x.ndim)), params_sds)
        p_pspecs["embedding"] = _mpe_emb_pspecs(rows_axes)
        if "wide" in params_sds:
            p_pspecs["wide"] = P(rows_axes)
        if "fm_linear" in params_sds:
            p_pspecs["fm_linear"] = P(rows_axes)
        bufs_pspecs = jax.tree.map(lambda x: P(*([None] * x.ndim)), buffers_sds)
        bufs_pspecs["embedding"] = {"group_of_feature": P(rows_axes),
                                    "freq_sum": P(None)}
        st_pspecs = replicate_like(state_sds)
        batch_sds = {"ids": sds((batch, len(fields)), jnp.int32),
                     "label": sds((batch,), jnp.int32)}
        batch_ps = {"ids": P(dp, None), "label": P(dp)}

        def loss(p, bu, st, b):
            return model.loss_fn(p, bu, st, b, cfg, lam=1e-5, train=True,
                                 step=None)

        return _train_cell(f"{spec.arch_id}/{shape}", loss, params_sds,
                           buffers_sds, state_sds, p_pspecs, bufs_pspecs,
                           st_pspecs, batch_sds, batch_ps,
                           {"kind": "train", "family": "recsys", "rows": n,
                            "batch": batch})

    # serving on the packed table
    cfg = spec.make_config(False)._replace(compressor="packed")
    fields = cfg.fields
    n = int(sum(f.vocab for f in fields))
    d = cfg.d_embed
    cfg = cfg._replace(comp_cfg=_packed_cfg(n, d))
    n_eff = N_CANDIDATES if shape == "retrieval_cand" else batch

    plain_cfg = spec.make_config(False)  # structure donor for non-emb params
    params_sds, buffers_sds, state_sds = jax.eval_shape(
        lambda k: model.init(k, plain_cfg._replace(compressor="plain")),
        sds((2,), jnp.uint32))
    params_sds = dict(params_sds)
    params_sds["embedding"] = _packed_param_specs(n, d)
    p_pspecs = packed_serve_pspecs(params_sds, rows_axes=rows_axes)
    buffers_sds = dict(buffers_sds, embedding={})
    bufs_pspecs = jax.tree.map(lambda x: P(*([None] * x.ndim)), buffers_sds)
    st_pspecs = replicate_like(state_sds)
    ids_sds = sds((n_eff, len(fields)), jnp.int32)
    ids_ps = P(rows_axes if shape == "retrieval_cand" else dp, None)

    serve_step = packed_score_step(
        model, cfg, top_k=100 if shape == "retrieval_cand" else None)

    return _serve_cell(
        f"{spec.arch_id}/{shape}", serve_step,
        (params_sds, state_sds, buffers_sds, ids_sds),
        (p_pspecs, st_pspecs, bufs_pspecs, ids_ps),
        (P(None), P(None)) if shape == "retrieval_cand" else P(dp),
        {"kind": "serve", "family": "recsys", "rows": n, "batch": n_eff},
    )


def _wide_deep_cell(spec, shape, batch, train, dp, rows_axes, multi_pod):
    return _flat_ctr_cell(spec, shape, batch, train, dp, rows_axes, multi_pod,
                          model=WideDeep)


def _dlrm_cell(spec, shape, batch, train, dp, rows_axes, multi_pod):
    return _flat_ctr_cell(spec, shape, batch, train, dp, rows_axes, multi_pod,
                          model=DLRM)


# -- two-tower ---------------------------------------------------------------

def _two_tower_cell(spec, shape, batch, train, dp, rows_axes, multi_pod):
    cfg = spec.make_config(False)
    fields = (*cfg.user_fields, *cfg.item_fields)
    n = int(sum(f.vocab for f in fields))
    d = cfg.d_embed
    fu, fi = len(cfg.user_fields), len(cfg.item_fields)

    if train:
        params_sds, buffers_sds, state_sds = jax.eval_shape(
            lambda k: TwoTower.init(k, cfg), sds((2,), jnp.uint32))
        p_pspecs = jax.tree.map(lambda x: P(*([None] * x.ndim)), params_sds)
        p_pspecs["embedding"] = _mpe_emb_pspecs(rows_axes)
        bufs_pspecs = jax.tree.map(lambda x: P(*([None] * x.ndim)), buffers_sds)
        bufs_pspecs["embedding"] = {"group_of_feature": P(rows_axes),
                                    "freq_sum": P(None)}
        st_pspecs = replicate_like(state_sds)
        batch_sds = {"user_ids": sds((batch, fu), jnp.int32),
                     "item_ids": sds((batch, fi), jnp.int32),
                     "item_logq": sds((batch,), jnp.float32)}
        batch_ps = {"user_ids": P(dp, None), "item_ids": P(dp, None),
                    "item_logq": P(dp)}

        def loss(p, bu, st, b):
            return TwoTower.loss_fn(p, bu, st, b, cfg, lam=1e-5, train=True)

        return _train_cell(f"{spec.arch_id}/{shape}", loss, params_sds,
                           buffers_sds, state_sds, p_pspecs, bufs_pspecs,
                           st_pspecs, batch_sds, batch_ps,
                           {"kind": "train", "family": "recsys", "rows": n,
                            "batch": batch})

    scfg = cfg._replace(compressor="packed", comp_cfg=_packed_cfg(n, d))
    params_sds, buffers_sds, state_sds = jax.eval_shape(
        lambda k: TwoTower.init(k, cfg), sds((2,), jnp.uint32))
    params_sds = dict(params_sds, embedding=_packed_param_specs(n, d))
    p_pspecs = packed_serve_pspecs(params_sds, rows_axes=rows_axes)
    buffers_sds = dict(buffers_sds, embedding={})
    bufs_pspecs = jax.tree.map(lambda x: P(*([None] * x.ndim)), buffers_sds)
    st_pspecs = replicate_like(state_sds)

    if shape == "retrieval_cand":
        u_sds = sds((1, fu), jnp.int32)
        c_sds = sds((N_CANDIDATES, fi), jnp.int32)

        def serve_step(params, state, buffers, user_ids, cand_ids):
            return TwoTower.retrieval_score(params, buffers, state, user_ids,
                                            cand_ids, scfg, top_k=100)

        return _serve_cell(
            f"{spec.arch_id}/{shape}", serve_step,
            (params_sds, state_sds, buffers_sds, u_sds, c_sds),
            (p_pspecs, st_pspecs, bufs_pspecs, P(None, None),
             P(rows_axes, None)),
            (P(None), P(None)),
            {"kind": "serve", "family": "recsys", "rows": n,
             "batch": N_CANDIDATES})

    u_sds = sds((batch, fu), jnp.int32)
    i_sds = sds((batch, fi), jnp.int32)

    def serve_step(params, state, buffers, user_ids, item_ids):
        u, _ = TwoTower.user_tower(params, buffers, state, user_ids, scfg)
        v, _ = TwoTower.item_tower(params, buffers, state, item_ids, scfg)
        return jnp.sum(u * v, axis=-1)

    return _serve_cell(
        f"{spec.arch_id}/{shape}", serve_step,
        (params_sds, state_sds, buffers_sds, u_sds, i_sds),
        (p_pspecs, st_pspecs, bufs_pspecs, P(dp, None), P(dp, None)),
        P(dp),
        {"kind": "serve", "family": "recsys", "rows": n, "batch": batch})


# -- bst ----------------------------------------------------------------------

def _bst_cell(spec, shape, batch, train, dp, rows_axes, multi_pod):
    cfg = spec.make_config(False)
    n = cfg.item_vocab + sum(f.vocab for f in cfg.ctx_fields)
    d = cfg.d_embed
    fc = len(cfg.ctx_fields)
    s = cfg.seq_len

    if train:
        params_sds, buffers_sds, state_sds = jax.eval_shape(
            lambda k: BST.init(k, cfg), sds((2,), jnp.uint32))
        p_pspecs = jax.tree.map(lambda x: P(*([None] * x.ndim)), params_sds)
        p_pspecs["embedding"] = _mpe_emb_pspecs(rows_axes)
        bufs_pspecs = jax.tree.map(lambda x: P(*([None] * x.ndim)), buffers_sds)
        bufs_pspecs["embedding"] = {"group_of_feature": P(rows_axes),
                                    "freq_sum": P(None)}
        st_pspecs = replicate_like(state_sds)
        batch_sds = {"seq_ids": sds((batch, s), jnp.int32),
                     "target_id": sds((batch,), jnp.int32),
                     "ctx_ids": sds((batch, fc), jnp.int32),
                     "label": sds((batch,), jnp.int32)}
        batch_ps = {"seq_ids": P(dp, None), "target_id": P(dp),
                    "ctx_ids": P(dp, None), "label": P(dp)}

        def loss(p, bu, st, b):
            return BST.loss_fn(p, bu, st, b, cfg, lam=1e-5, train=True)

        return _train_cell(f"{spec.arch_id}/{shape}", loss, params_sds,
                           buffers_sds, state_sds, p_pspecs, bufs_pspecs,
                           st_pspecs, batch_sds, batch_ps,
                           {"kind": "train", "family": "recsys", "rows": n,
                            "batch": batch})

    scfg = cfg._replace(compressor="packed", comp_cfg=_packed_cfg(n, d))
    params_sds, buffers_sds, state_sds = jax.eval_shape(
        lambda k: BST.init(k, cfg), sds((2,), jnp.uint32))
    params_sds = dict(params_sds, embedding=_packed_param_specs(n, d))
    p_pspecs = packed_serve_pspecs(params_sds, rows_axes=rows_axes)
    buffers_sds = dict(buffers_sds, embedding={})
    bufs_pspecs = jax.tree.map(lambda x: P(*([None] * x.ndim)), buffers_sds)
    st_pspecs = replicate_like(state_sds)

    n_eff = N_CANDIDATES if shape == "retrieval_cand" else batch
    row_ax = rows_axes if shape == "retrieval_cand" else dp
    batch_sds = {"seq_ids": sds((n_eff, s), jnp.int32),
                 "target_id": sds((n_eff,), jnp.int32),
                 "ctx_ids": sds((n_eff, fc), jnp.int32),
                 "label": sds((n_eff,), jnp.int32)}
    batch_ps = {"seq_ids": P(row_ax, None), "target_id": P(row_ax),
                "ctx_ids": P(row_ax, None), "label": P(row_ax)}

    def serve_step(params, state, buffers, batch_in):
        logits, _, _ = BST.apply(params, buffers, state, batch_in, scfg,
                                 train=False)
        if shape == "retrieval_cand":
            return tuple(jax.lax.top_k(logits, 100))
        return logits

    return _serve_cell(
        f"{spec.arch_id}/{shape}", serve_step,
        (params_sds, state_sds, buffers_sds, batch_sds),
        (p_pspecs, st_pspecs, bufs_pspecs, batch_ps),
        (P(None), P(None)) if shape == "retrieval_cand" else P(row_ax),
        {"kind": "serve", "family": "recsys", "rows": n, "batch": n_eff})


# -- sasrec -------------------------------------------------------------------

def _sasrec_cell(spec, shape, batch, train, dp, rows_axes, multi_pod):
    cfg = spec.make_config(False)
    n, d, s = cfg.item_vocab, cfg.d_embed, cfg.seq_len

    if train:
        params_sds, buffers_sds, _ = jax.eval_shape(
            lambda k: SASRec.init(k, cfg), sds((2,), jnp.uint32))
        p_pspecs = jax.tree.map(lambda x: P(*([None] * x.ndim)), params_sds)
        p_pspecs["embedding"] = _mpe_emb_pspecs(rows_axes)
        bufs_pspecs = {"embedding": {"group_of_feature": P(rows_axes),
                                     "freq_sum": P(None)}}
        batch_sds = {k: sds((batch, s), jnp.int32)
                     for k in ("seq_ids", "pos_ids", "neg_ids")}
        batch_sds["mask"] = sds((batch, s), jnp.float32)
        batch_ps = {k: P(dp, None)
                    for k in ("seq_ids", "pos_ids", "neg_ids", "mask")}

        def loss(p, bu, st, b):
            return SASRec.loss_fn(p, bu, st, b, cfg, lam=1e-5, train=True)

        return _train_cell(f"{spec.arch_id}/{shape}", loss, params_sds,
                           buffers_sds, {}, p_pspecs, bufs_pspecs, {},
                           batch_sds, batch_ps,
                           {"kind": "train", "family": "recsys", "rows": n,
                            "batch": batch})

    scfg = cfg._replace(compressor="packed", comp_cfg=_packed_cfg(n, d))
    params_sds, _, _ = jax.eval_shape(lambda k: SASRec.init(k, cfg),
                                      sds((2,), jnp.uint32))
    params_sds = dict(params_sds, embedding=_packed_param_specs(n, d))
    p_pspecs = packed_serve_pspecs(params_sds, rows_axes=rows_axes)
    buffers_sds = {"embedding": {}}
    bufs_pspecs = {"embedding": {}}

    if shape == "retrieval_cand":
        seq_sds = sds((1, s), jnp.int32)
        cand_sds = sds((N_CANDIDATES,), jnp.int32)

        def serve_step(params, buffers, seq_ids, cand_ids):
            return SASRec.score_candidates(params, buffers, seq_ids, cand_ids,
                                           scfg, top_k=100)

        return _serve_cell(
            f"{spec.arch_id}/{shape}", serve_step,
            (params_sds, buffers_sds, seq_sds, cand_sds),
            (p_pspecs, bufs_pspecs, P(None, None), P(rows_axes)),
            (P(None, None), P(None, None)),
            {"kind": "serve", "family": "recsys", "rows": n,
             "batch": N_CANDIDATES})

    seq_sds = sds((batch, s), jnp.int32)
    cand_sds = sds((SERVE_CANDS,), jnp.int32)

    def serve_step(params, buffers, seq_ids, cand_ids):
        return SASRec.score_candidates(params, buffers, seq_ids, cand_ids,
                                       scfg, top_k=100)

    return _serve_cell(
        f"{spec.arch_id}/{shape}", serve_step,
        (params_sds, buffers_sds, seq_sds, cand_sds),
        (p_pspecs, bufs_pspecs, P(dp, None), P(None)),
        (P(dp, None), P(dp, None)),
        {"kind": "serve", "family": "recsys", "rows": n, "batch": batch})


# ---------------------------------------------------------------------------

def build_cell(arch_id: str, shape: str, multi_pod: bool = False,
               overrides=None) -> Cell:
    spec = get_arch(arch_id)
    if spec.family == "lm":
        return build_lm_cell(arch_id, shape, multi_pod, overrides)
    if spec.family == "gnn":
        return build_gnn_cell(arch_id, shape, multi_pod)
    return build_recsys_cell(arch_id, shape, multi_pod, overrides)
