"""Black-box serving harness: the engine behind a real process boundary.

A small TCP server wrapping ``Engine.submit``/``poll`` so the request
lifecycle is exercised end-to-end — serialization, framing, concurrent
clients, and the multi-tenant admission policy — with latency measured over
the wire instead of in-process. One frame is 4 bytes of big-endian length
followed by that many bytes of UTF-8 JSON (the length-prefixed framing of
TGI-style integration harnesses); one connection carries any number of
request/response frame pairs.

Operations (the ``op`` field of a request frame):

  ``ping``             → ``{"ok": true}`` — the readiness probe.
  ``submit``           ``{ids, kind?, deadline_ms?, tenant?, priority?}``
                       → ``{"ticket": int | null}`` (null = shed at
                       admission).
  ``poll``             ``{ticket}`` → ``{"status": "pending" | "done" |
                       "shed" | "failed" | "unknown", result?, error?}`` —
                       terminal polls consume the ticket.
  ``counters``         → ``engine.counters()`` (cache, occupancy, queue,
                       per-lane/per-tenant goodput).
  ``request_summary``  ``{by?}`` → ``engine.request_summary(by=...)``.
  ``shutdown``         → ``{"ok": true}``, then the server exits.

A background *pump* thread runs ``engine.sched_step`` whenever the
scheduler has work, so submits from one client coalesce with submits from
every other client onto shared padded cells — exactly the multi-client
traffic the scheduler exists for. All engine access (submit/poll/step)
serializes through one lock; the socket layer is the concurrent part.

The CLI trains a small packed DLRM (same recipe as ``repro.launch.serve``),
registers the serve cells, warms them, then prints ``READY host:port`` on
stdout — the launcher fixture in ``tests/server_fixture.py`` waits for that
line, then probes ``ping``.

    python -m repro.launch.server --port 0 --train-steps 25
"""
from __future__ import annotations

import argparse
import json
import socket
import struct
import threading
import time

import numpy as np

_HEADER = struct.Struct(">I")
MAX_FRAME_BYTES = 64 << 20     # refuse absurd frames instead of OOMing


def send_frame(sock: socket.socket, obj) -> None:
    """Write one length-prefixed JSON frame."""
    data = json.dumps(obj).encode("utf-8")
    if len(data) > MAX_FRAME_BYTES:
        raise ValueError(f"frame of {len(data)} bytes exceeds "
                         f"MAX_FRAME_BYTES={MAX_FRAME_BYTES}")
    sock.sendall(_HEADER.pack(len(data)) + data)


def recv_frame(sock: socket.socket):
    """Read one frame -> decoded object, or None on clean EOF (the peer
    closed between frames). EOF mid-frame raises ConnectionError."""
    header = _recv_exact(sock, _HEADER.size)
    if header is None:
        return None
    (length,) = _HEADER.unpack(header)
    if length > MAX_FRAME_BYTES:
        raise ValueError(f"peer announced a {length}-byte frame (max "
                         f"{MAX_FRAME_BYTES})")
    payload = _recv_exact(sock, length)
    if payload is None:
        raise ConnectionError("connection closed mid-frame")
    return json.loads(payload.decode("utf-8"))


def _recv_exact(sock: socket.socket, n: int) -> bytes | None:
    buf = bytearray()
    while len(buf) < n:
        part = sock.recv(n - len(buf))
        if not part:
            return None if not buf else _raise_eof()
        buf.extend(part)
    return bytes(buf)


def _raise_eof():
    raise ConnectionError("connection closed mid-frame")


class EngineServer:
    """Serve one engine over TCP with length-prefixed JSON framing.

    ``port=0`` binds an ephemeral port (read it back from ``.port``). Every
    client connection gets a handler thread; one pump thread drives
    ``sched_step`` while the scheduler is busy, so concurrent clients'
    requests coalesce onto shared cells."""

    def __init__(self, engine, host: str = "127.0.0.1", port: int = 0):
        self.engine = engine
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._listener = socket.create_server((host, port))
        self._listener.settimeout(0.2)   # so the accept loop sees _stop
        self.host, self.port = self._listener.getsockname()[:2]
        self._threads: list[threading.Thread] = []

    # -- lifecycle ----------------------------------------------------------

    def start(self):
        """Run the accept loop and the scheduler pump in daemon threads."""
        for target, name in ((self._accept_loop, "accept"),
                             (self._pump, "pump")):
            t = threading.Thread(target=target, name=f"engine-server-{name}",
                                 daemon=True)
            t.start()
            self._threads.append(t)
        return self

    def serve_forever(self):
        self.start()
        while not self._stop.is_set():
            self._stop.wait(0.2)

    def shutdown(self):
        self._stop.set()
        try:
            self._listener.close()
        except OSError:
            pass

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.shutdown()

    # -- threads ------------------------------------------------------------

    def _accept_loop(self):
        while not self._stop.is_set():
            try:
                conn, _addr = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                return      # listener closed during shutdown
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 name="engine-server-conn", daemon=True)
            t.start()
            self._threads.append(t)

    def _pump(self):
        """Drive the scheduler whenever it has work. Idle polling stays
        cheap (a short wait), and every step holds the engine lock so
        submits/polls from handler threads interleave safely between
        rounds."""
        while not self._stop.is_set():
            with self._lock:
                busy = self.engine.scheduler.busy
                if busy:
                    self.engine.sched_step()
            if not busy:
                self._stop.wait(0.002)

    def _serve_conn(self, conn: socket.socket):
        with conn:
            while not self._stop.is_set():
                try:
                    msg = recv_frame(conn)
                except (ConnectionError, ValueError, json.JSONDecodeError):
                    return
                if msg is None:
                    return
                try:
                    reply = self._handle(msg)
                except Exception as err:   # protocol errors ride back as JSON
                    reply = {"error": f"{type(err).__name__}: {err}"}
                try:
                    send_frame(conn, reply)
                except OSError:
                    return

    # -- request handling ---------------------------------------------------

    def _handle(self, msg: dict) -> dict:
        op = msg.get("op")
        if op == "ping":
            return {"ok": True}
        if op == "submit":
            ids = np.asarray(msg["ids"], np.int32)
            with self._lock:
                ticket = self.engine.submit(
                    ids, kind=msg.get("kind", "score"),
                    deadline_ms=msg.get("deadline_ms"),
                    tenant=msg.get("tenant", "default"),
                    priority=int(msg.get("priority", 0)))
            return {"ticket": ticket}
        if op == "poll":
            with self._lock:
                out = self.engine.try_poll(int(msg["ticket"]))
            if out["status"] == "done":
                out = dict(out, result=np.asarray(out["result"]).tolist())
            return out
        if op == "counters":
            with self._lock:
                return self.engine.counters()
        if op == "request_summary":
            with self._lock:
                return self.engine.request_summary(by=msg.get("by", "kind"))
        if op == "shutdown":
            self._stop.set()
            return {"ok": True}
        return {"error": f"unknown op {op!r}"}


class EngineClient:
    """Blocking client for ``EngineServer``'s framed-JSON protocol.

    One instance = one connection; safe from one thread at a time (tests
    spawn one client per concurrent worker). ``score`` is the end-to-end
    convenience: submit, poll until terminal, return the result array —
    raising on shed/failed, so over-the-wire latency includes framing and
    serialization on both legs."""

    def __init__(self, host: str, port: int, *, timeout: float = 30.0):
        self._sock = socket.create_connection((host, port), timeout=timeout)

    def close(self):
        self._sock.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def call(self, op: str, **fields) -> dict:
        send_frame(self._sock, {"op": op, **fields})
        reply = recv_frame(self._sock)
        if reply is None:
            raise ConnectionError("server closed the connection")
        return reply

    def ping(self) -> bool:
        return self.call("ping").get("ok", False)

    def submit(self, ids, *, kind: str = "score",
               deadline_ms: float | None = None, tenant: str = "default",
               priority: int = 0) -> int | None:
        reply = self.call("submit", ids=np.asarray(ids).tolist(), kind=kind,
                          deadline_ms=deadline_ms, tenant=tenant,
                          priority=priority)
        if "error" in reply:
            raise RuntimeError(reply["error"])
        return reply["ticket"]

    def poll(self, ticket: int) -> dict:
        return self.call("poll", ticket=ticket)

    def score(self, ids, *, poll_interval_s: float = 0.005,
              timeout_s: float = 60.0, **submit_kw) -> np.ndarray:
        ticket = self.submit(ids, **submit_kw)
        if ticket is None:
            raise RuntimeError("request shed at admission")
        deadline = time.monotonic() + timeout_s
        while True:
            out = self.poll(ticket)
            status = out.get("status")
            if status == "done":
                return np.asarray(out["result"], np.float32)
            if status == "shed":
                raise RuntimeError(f"request {ticket} shed")
            if status == "failed":
                raise RuntimeError(
                    f"request {ticket} failed: {out.get('error')}")
            if status not in ("pending",):
                raise RuntimeError(f"request {ticket}: {out}")
            if time.monotonic() > deadline:
                raise TimeoutError(f"request {ticket} still pending after "
                                   f"{timeout_s}s")
            time.sleep(poll_interval_s)

    def counters(self) -> dict:
        return self.call("counters")

    def request_summary(self, *, by: str = "kind") -> dict:
        return self.call("request_summary", by=by)

    def shutdown(self):
        self.call("shutdown")


def main(argv=None):
    from repro.launch.serve import build_engine, train_packed_dlrm
    from repro.serve import TenantQuota

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 binds an ephemeral port (printed on READY)")
    ap.add_argument("--train-steps", type=int, default=25)
    ap.add_argument("--p99-rows", type=int, default=64)
    ap.add_argument("--bulk-rows", type=int, default=256)
    ap.add_argument("--queue-capacity", type=int, default=1024)
    ap.add_argument("--coalesce-window-ms", type=float, default=0.0)
    ap.add_argument("--shed-watermark", type=float, default=1.0)
    ap.add_argument("--quota", action="append", default=[],
                    help="tenant quota as name=max_queued[:max_inflight_rows]"
                         " (repeatable)")
    args = ap.parse_args(argv)

    quotas = {}
    for spec in args.quota:
        name, _, bound = spec.partition("=")
        queued, _, rows = bound.partition(":")
        quotas[name] = TenantQuota(
            max_queued=int(queued) if queued else None,
            max_inflight_rows=int(rows) if rows else None)

    print(f"[server] training packed DLRM ({args.train_steps} steps)",
          flush=True)
    cfg, params, state, buffers, spec, _res = train_packed_dlrm(
        field_vocabs=(600, 400, 500), train_steps=args.train_steps,
        train_batch=256, seed=3)
    engine = build_engine(cfg, params, state, buffers,
                          p99_rows=args.p99_rows, bulk_rows=args.bulk_rows,
                          queue_capacity=args.queue_capacity,
                          quotas=quotas or None,
                          shed_watermark=args.shed_watermark,
                          coalesce_window_ms=args.coalesce_window_ms)
    # warm every score cell so the first client request isn't a compile
    n_fields = len(cfg.fields)
    for rows in sorted(set(engine.registered_shapes.values())):
        engine.score(np.zeros((rows, n_fields), np.int32))
    server = EngineServer(engine, host=args.host, port=args.port)
    print(f"READY {server.host}:{server.port}", flush=True)
    server.serve_forever()


if __name__ == "__main__":
    main()
