"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (smoke tests see 1 device; only dryrun.py forces 512).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod (TPU v5e); 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


# v5e hardware constants for the roofline terms (EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s per chip
HBM_BW = 819e9                  # B/s per chip
ICI_BW = 50e9                   # B/s per link
