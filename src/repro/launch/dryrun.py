"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: a successful
SPMD compile for the 16×16 single-pod mesh and the 2×16×16 multi-pod mesh
means every sharding constraint, collective, and memory budget is consistent.
Emits per-cell JSON artifacts (memory analysis, FLOPs/bytes, per-collective
byte counts parsed from the post-SPMD HLO) consumed by benchmarks/roofline.py.

Usage:
    python -m repro.launch.dryrun --arch wide-deep --shape train_batch
    python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import sys
import time
import traceback

import jax

from repro.launch.mesh import make_production_mesh
from repro.launch.cells import build_cell
from repro.launch.hlo_analysis import analyze, normalize_cost
from repro.dist.mesh import use_mesh
from repro.dist.sharding import cell_shardings
from repro.configs import get_arch, ALL_ARCHS


def run_cell(arch_id: str, shape: str, *, multi_pod: bool = False,
             verbose: bool = True, save_dir: str | None = None,
             overrides: dict | None = None, tag: str = "",
             cond_mode: str = "sum") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = build_cell(arch_id, shape, multi_pod, overrides)
    in_shardings, out_shardings = cell_shardings(mesh, cell)

    with use_mesh(mesh):
        jitted = jax.jit(cell.step_fn, in_shardings=in_shardings,
                         out_shardings=out_shardings)
        lowered = jitted.lower(*cell.input_specs)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = normalize_cost(compiled.cost_analysis())
    hlo = compiled.as_text()
    # per-device, while-trip-count weighted; cond_mode picks the lax.cond
    # branch accounting ("min" reports the common write-one-slot branch of
    # the kv_int8 decode step instead of the conservative both-branch sum)
    loop_aware = analyze(hlo, cond_mode=cond_mode)

    n_chips = mesh.devices.size
    result = {
        "cell": cell.name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_chips": int(n_chips),
        "compile_s": round(time.time() - t0, 1),
        # raw XLA numbers (loop bodies counted once — see hlo_analysis.py)
        "xla_flops_unweighted": float(cost.get("flops", 0.0)) if cost else None,
        "xla_bytes_unweighted": (float(cost.get("bytes accessed", 0.0))
                                 if cost else None),
        # loop-aware per-device numbers (the roofline inputs)
        "cond_mode": cond_mode,
        "flops_per_device": loop_aware["flops_per_device"],
        "hbm_bytes_per_device": loop_aware["hbm_bytes_per_device"],
        "collectives_per_device": loop_aware["collectives_per_device"],
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                            None),
        },
        "meta": cell.meta,
    }
    if verbose:
        coll = loop_aware["collectives_per_device"]
        print(f"[dryrun] {cell.name} mesh={result['mesh']} "
              f"compile={result['compile_s']}s "
              f"flops/dev={result['flops_per_device']:.3e} "
              f"hbm/dev={result['hbm_bytes_per_device']:.3e} "
              f"coll/dev={coll['total_bytes']:.3e}")
        print("  memory:", result["memory"])
    if save_dir:
        os.makedirs(save_dir, exist_ok=True)
        fname = f"{arch_id}_{shape}_{result['mesh']}".replace("/", "_")
        if tag:
            fname += f"_{tag}"
            result["variant"] = tag
            result["overrides"] = overrides
        with open(os.path.join(save_dir, f"dryrun_{fname}.json"), "w") as f:
            json.dump(result, f, indent=2)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/artifacts")
    ap.add_argument("--overrides", default=None,
                    help="comma-separated k=v config overrides for §Perf "
                         "variants, e.g. 'shard_activations=true,"
                         "attn_expand_kv=true,moe.shard_dispatch=true'")
    ap.add_argument("--tag", default="", help="artifact suffix for variants")
    ap.add_argument("--cond-bytes", default="sum",
                    choices=["sum", "max", "min"], dest="cond_bytes",
                    help="lax.cond branch accounting in the static byte "
                         "counts: 'sum' charges both branches (conservative "
                         "upper bound), 'max'/'min' only the heaviest/"
                         "lightest — 'min' reports the common write-one-slot "
                         "branch of the kv_int8 decode cells")
    args = ap.parse_args()

    overrides = None
    if args.overrides:
        overrides = {}
        for kv in args.overrides.split(","):
            k, v = kv.split("=", 1)
            overrides[k.strip()] = {"true": True, "false": False}.get(
                v.strip().lower(), v.strip())

    cells = []
    if args.all:
        for arch_id in ALL_ARCHS():
            for shape in get_arch(arch_id).shapes:
                cells.append((arch_id, shape))
    else:
        spec = get_arch(args.arch)
        shapes = [args.shape] if args.shape else list(spec.shapes)
        cells = [(args.arch, s) for s in shapes]

    failures = []
    for arch_id, shape in cells:
        try:
            run_cell(arch_id, shape, multi_pod=args.multi_pod,
                     save_dir=args.out, overrides=overrides, tag=args.tag,
                     cond_mode=args.cond_bytes)
        except Exception as e:  # noqa: BLE001 — report every failing cell
            failures.append((arch_id, shape, repr(e)))
            print(f"[dryrun] FAIL {arch_id}/{shape}: {e}")
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)}/{len(cells)} cells FAILED")
        sys.exit(1)
    print(f"\nall {len(cells)} cells compiled OK "
          f"({'multi-pod 2x16x16' if args.multi_pod else 'single-pod 16x16'})")


if __name__ == "__main__":
    main()
