"""Loop-aware static analysis of post-SPMD HLO for the roofline terms.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, but our
models scan over layers (and the chunked attention/CE scan over blocks), so
raw numbers under-count FLOPs/bytes/collective traffic by the trip counts.
This module parses the compiled HLO text into computations, extracts each
while's trip count from its condition's compare constant, propagates
multiplicative weights down the call graph, and accumulates:

  - flops:       2 · |out| · contracted_size for every dot (weighted);
  - hbm_bytes:   operand+output bytes of every non-fusion-internal op
                 (fusion internals are VMEM-resident; the fusion call site's
                 operands/outputs are the real HBM traffic);
  - collectives: per-op-kind byte totals (output shard bytes, weighted).

All shapes in post-SPMD HLO are per-partition, so every total is per-device —
exactly what the per-chip roofline terms need.

``lax.cond`` lowers to an HLO ``conditional`` whose branch computations run
*alternatively* at runtime, which a static analysis can't resolve —
``cond_mode`` selects the accounting: ``"sum"`` (default) charges every
branch (the conservative static upper bound), ``"max"`` only the heaviest
branch, ``"min"`` only the lightest. The int8 KV-cache decode step gates its
rare full-cache requant rewrite behind a cond, so ``cond_mode="min"``
reports the common write-one-slot decode step (``--cond-bytes min`` on
``repro.launch.dryrun``).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2,
                "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16, "token": 0,
                "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
               "while", "conditional", "after-all", "partition-id",
               "replica-id", "iota", "copy-done", "all-gather-done",
               "all-reduce-done", "collective-permute-done", "rng-bit-generator"}

def normalize_cost(cost):
    """``compiled.cost_analysis()`` → one dict or None.

    jax 0.4.x returns a *list* of per-computation dicts on some
    backend/version combinations (and an empty list for modules XLA declines
    to cost, seen on sharded shard_map modules); newer jax returns the dict
    directly. Every consumer (dryrun, shard_bench) goes through here so the
    normalization lives in one place."""
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else None
    return cost


_HDR = re.compile(r"^(ENTRY )?%?([A-Za-z_][\w\.\-]*) \(")
_SHAPE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OP = re.compile(r"^\s*(?:ROOT )?%?([\w\.\-]+) = (.*?) ([\w\-]+)\((.*)$")


def _shape_dims(m) -> tuple:
    dtype, dims = m.groups()
    return dtype, [int(d) for d in dims.split(",") if d]


def _shape_bytes_from_str(s: str) -> int:
    total = 0
    for m in _SHAPE.finditer(s):
        dtype, dims = _shape_dims(m)
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


def split_computations(hlo: str):
    """-> (entry_name, {name: [op lines]})."""
    comps, entry = {}, None
    cur, cur_lines = None, []
    for line in hlo.splitlines():
        if cur is None:
            m = _HDR.match(line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(2)
                if m.group(1):
                    entry = cur
                cur_lines = []
        else:
            if line.startswith("}"):
                comps[cur] = cur_lines
                cur = None
            else:
                cur_lines.append(line)
    return entry, comps


def _trip_count(cond_lines) -> int:
    """Largest s32 constant in the loop condition ≈ the trip count."""
    best = 1
    for line in cond_lines:
        for m in re.finditer(r"s32\[\] constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


_REF = re.compile(r"%([\w\.\-]+)")


def _operand_names(args: str):
    """%refs inside the op's parens (attrs after ')' reference computations,
    which never appear in the defs table, so they filter out naturally)."""
    depth, end = 1, len(args)
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return _REF.findall(args[:end])


def _dot_flops(line: str, defs: dict) -> float:
    """2 · prod(output dims) · contracted size; operand shapes via defs."""
    m = _OP.match(line)
    if not m:
        return 0.0
    out_m = _SHAPE.search(m.group(2))
    if not out_m:
        return 0.0
    _, out_dims = _shape_dims(out_m)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    lc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    ops = _operand_names(m.group(4))
    lhs_shape = defs.get(ops[0]) if ops else None
    if lc is None or lhs_shape is None:
        return 2.0 * out_elems  # fallback: elementwise-scale estimate
    lhs_dims = lhs_shape[1]
    contracted = 1
    for i in (int(x) for x in lc.group(1).split(",") if x):
        if i < len(lhs_dims):
            contracted *= lhs_dims[i]
    return 2.0 * out_elems * contracted


COND_MODES = ("sum", "max", "min")


def _branch_targets(line: str) -> tuple:
    """Branch computations of an HLO ``conditional`` op (both syntaxes)."""
    m = re.search(r"branch_computations=\{([^}]*)\}", line)
    if m:
        return tuple(_REF.findall(m.group(1)))
    m = re.search(r"true_computation=%?([\w\.\-]+), "
                  r"false_computation=%?([\w\.\-]+)", line)
    if m:
        return (m.group(1), m.group(2))
    return ()


def _collect_facts(comps: dict) -> dict:
    """Per-computation static facts (two passes: defs table, then ops)."""
    facts = {}
    for name, lines in comps.items():
        defs = {}
        for line in lines:
            m = _OP.match(line)
            if m:
                out_m = _SHAPE.search(m.group(2))
                if out_m:
                    defs[m.group(1)] = _shape_dims(out_m)
            else:  # parameters: "%p = f32[..]{..} parameter(0)" matches _OP;
                pass  # others (e.g. constants without parens) are irrelevant
        whiles, calls, conds, dots = [], [], [], 0.0
        bytes_ops = 0
        coll = defaultdict(lambda: [0, 0])  # kind -> [bytes, count]
        for line in lines:
            m = _OP.match(line)
            if not m:
                continue
            _, out_part, opcode, args = m.groups()
            if opcode == "while":
                w = re.search(r"condition=%?([\w\.\-]+), body=%?([\w\.\-]+)",
                              line)
                if w:
                    whiles.append((w.group(1), w.group(2)))
            if opcode == "conditional":
                branches = _branch_targets(line)
                if branches:
                    conds.append(branches)
            cm = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", line)
            if cm and opcode not in ("while", "conditional"):
                calls.append(cm.group(1))
            if opcode == "dot":
                dots += _dot_flops(line, defs)
            base_op = opcode.replace("-start", "")
            if base_op in COLLECTIVES and not opcode.endswith("-done"):
                b = _shape_bytes_from_str(out_part)
                coll[base_op][0] += b
                coll[base_op][1] += 1
            if opcode not in _SKIP_BYTES and not opcode.endswith("-done"):
                out_b = _shape_bytes_from_str(out_part)
                operand_bytes = 0
                passthrough = False
                for ref in _operand_names(args):
                    sh = defs.get(ref)
                    if sh:
                        n = 1
                        for d in sh[1]:
                            n *= d
                        b = n * _DTYPE_BYTES.get(sh[0], 4)
                        # in-place accumulation pattern (scan stashes, DUS):
                        # an operand identical in size to the output aliases
                        # it; real traffic is the *update*, not the buffer.
                        if not passthrough and b == out_b and b > (1 << 20):
                            passthrough = True
                            continue
                        operand_bytes += b
                if passthrough:
                    out_b = 0  # aliased in-place write; updates counted above
                bytes_ops += out_b + operand_bytes
        facts[name] = {"whiles": whiles, "calls": calls, "conds": conds,
                       "dot_flops": dots, "bytes": bytes_ops,
                       "coll": dict(coll), "is_fusion_body": False}

    # mark fusion bodies (reached via calls= from fusion ops) — their ops are
    # VMEM-internal; bytes counted at the call site instead.
    for lines in comps.values():
        for line in lines:
            m = _OP.match(line)
            if m and m.group(3) == "fusion":
                cm = re.search(r"calls=%?([\w\.\-]+)", line)
                if cm and cm.group(1) in facts:
                    facts[cm.group(1)]["is_fusion_body"] = True
    return facts


def _subtree_bytes(name, facts, comps, cond_mode, memo, stack) -> float:
    """Ranking metric for branch selection: the recursive byte cost of one
    computation's subtree (fusion bodies contribute at their call sites)."""
    if name not in facts or name in stack:
        return 0.0
    if name in memo:
        return memo[name]
    stack.add(name)
    f = facts[name]
    total = 0.0 if f["is_fusion_body"] else float(f["bytes"])
    for cond, body in f["whiles"]:
        trips = _trip_count(comps.get(cond, []))
        total += (trips + 1) * _subtree_bytes(cond, facts, comps, cond_mode,
                                              memo, stack)
        total += trips * _subtree_bytes(body, facts, comps, cond_mode, memo,
                                        stack)
    for callee in f["calls"]:
        total += _subtree_bytes(callee, facts, comps, cond_mode, memo, stack)
    for branches in f["conds"]:
        sub = [_subtree_bytes(b, facts, comps, cond_mode, memo, stack)
               for b in branches]
        if sub:
            total += (sum(sub) if cond_mode == "sum"
                      else max(sub) if cond_mode == "max" else min(sub))
    stack.discard(name)
    memo[name] = total
    return total


def _propagate_weights(entry, comps, facts, cond_mode: str) -> dict:
    """Multiplicative execution weights down the call graph: while bodies by
    their trip counts, calls at parent weight, ``conditional`` branches per
    ``cond_mode`` ("sum" charges every branch; "max"/"min" only the
    heaviest/lightest by recursive byte cost)."""
    if cond_mode not in COND_MODES:
        raise ValueError(f"cond_mode must be one of {COND_MODES}, "
                         f"got {cond_mode!r}")
    weights = defaultdict(float)
    memo: dict = {}

    def visit(name, w):
        if name not in facts or w <= 0:
            return
        weights[name] += w
        f = facts[name]
        for cond, body in f["whiles"]:
            trips = _trip_count(comps.get(cond, []))
            visit(cond, w * (trips + 1))
            visit(body, w * trips)
        for callee in f["calls"]:
            visit(callee, w)
        for branches in f["conds"]:
            if cond_mode == "sum":
                for b in branches:
                    visit(b, w)
            elif branches:
                costs = [_subtree_bytes(b, facts, comps, cond_mode, memo,
                                        set()) for b in branches]
                picked = (costs.index(max(costs)) if cond_mode == "max"
                          else costs.index(min(costs)))
                visit(branches[picked], w)

    visit(entry, 1.0)
    return weights


def analyze(hlo: str, *, cond_mode: str = "sum") -> dict:
    entry, comps = split_computations(hlo)
    facts = _collect_facts(comps)
    weights = _propagate_weights(entry, comps, facts, cond_mode)

    flops = 0.0
    hbm_bytes = 0.0
    coll_total = defaultdict(lambda: [0.0, 0])
    for name, w in weights.items():
        f = facts[name]
        flops += w * f["dot_flops"]
        if not f["is_fusion_body"]:
            hbm_bytes += w * f["bytes"]
        for kind, (b, c) in f["coll"].items():
            coll_total[kind][0] += w * b
            coll_total[kind][1] += int(w * c)

    coll_out = {k: {"bytes": v[0], "count": v[1]} for k, v in coll_total.items()}
    coll_out["total_bytes"] = sum(v[0] for v in coll_total.values())
    return {
        "flops_per_device": flops,
        "hbm_bytes_per_device": hbm_bytes,
        "collectives_per_device": coll_out,
        "n_computations": len(comps),
        "cond_mode": cond_mode,
    }


def breakdown(hlo: str, top: int = 12, *, cond_mode: str = "sum") -> list:
    """Top computations by weighted bytes/flops — the §Perf profiling view."""
    entry, comps = split_computations(hlo)
    facts = _collect_facts(comps)
    weights = _propagate_weights(entry, comps, facts, cond_mode)

    rows = []
    for name, lines in comps.items():
        w = weights.get(name, 0.0)
        if w <= 0:
            continue
        defs = {}
        for line in lines:
            m = _OP.match(line)
            if m:
                sm = _SHAPE.search(m.group(2))
                if sm:
                    defs[m.group(1)] = _shape_dims(sm)
        dot_fl, byts = 0.0, 0
        for line in lines:
            m = _OP.match(line)
            if not m:
                continue
            if m.group(3) == "dot":
                dot_fl += _dot_flops(line, defs)
            if m.group(3) not in _SKIP_BYTES:
                byts += _shape_bytes_from_str(m.group(2))
        rows.append((name, w, w * dot_fl, w * byts))
    rows.sort(key=lambda r: -r[3])
    return rows[:top]
