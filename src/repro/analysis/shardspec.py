"""Sharding-contract pass (SC2xx): pspec families and the psum invariant.

Two symbolic checks run against a cell *definition* (no devices needed —
they inspect declared PartitionSpecs, not placements):

  SC201  a spec entry names a mesh axis outside ``dist.sharding.MESH_AXES``
         — it can never resolve on a production mesh, so the constraint
         silently degrades to replicated (``_fit_spec`` drops it).
  SC202  a spec dim entry normalizes to an axis group outside
         ``dist.sharding.AXIS_GROUPS`` — an out-of-contract placement
         (wrong axis order changes the row-major shard index; ad-hoc
         pairings match no wrapper layout).

One structural check runs on the traced jaxpr:

  SC204  a ``shard_map`` consumes an operand sharded over an axis that no
         output keeps, but its body never reduces over that axis — the
         PR 4 bucket-merge invariant. Every ownership-masked device-local
         partial (packed lookup, tiered hot lookup, embedding bag, the
         train step's grads) must be followed by its ``psum``/``pmean``
         over exactly the row axes, or each device returns a partial
         result that the partitioner then treats as replicated (our
         wrappers pass ``check_rep=False``, so jax itself won't catch it).
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.analysis.findings import Finding
from repro.analysis.jaxpr_walk import walk
from repro.dist.sharding import AXIS_GROUPS, MESH_AXES, normalize_entry

#: body primitives that reduce (or materialize) over a named mesh axis.
_REDUCING_PRIMS = frozenset({
    "psum", "psum2", "pmean", "pmax", "pmin", "all_gather",
    "reduce_scatter", "all_to_all", "ppermute", "pgather",
})


def _iter_specs(tree):
    """Every PartitionSpec leaf of a (possibly nested) pspec pytree."""
    if isinstance(tree, P):
        yield tree
        return
    for leaf in jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, P)):
        if isinstance(leaf, P):
            yield leaf


def check_spec_tree(tree, where: str, *, role: str) -> list[Finding]:
    """SC201/SC202 over one declared pspec pytree (``role``: which input/
    output slot, for the message)."""
    findings = []
    for spec in _iter_specs(tree):
        for entry in tuple(spec):
            norm = normalize_entry(entry)
            if norm is None:
                continue
            unknown = [a for a in norm if a not in MESH_AXES]
            if unknown:
                findings.append(Finding(
                    "SC201", f"{role} spec {spec} names mesh axis "
                    f"{unknown[0]!r} not in the production mesh contract "
                    f"{sorted(MESH_AXES)}", where))
            elif norm not in AXIS_GROUPS:
                findings.append(Finding(
                    "SC202", f"{role} spec {spec} entry {entry!r} is not a "
                    f"registered axis group (dist.sharding.AXIS_GROUPS) — "
                    f"use a pspec family from dist/sharding.py", where))
    return findings


def check_celldef_specs(celldef) -> list[Finding]:
    """SC201/SC202 over every declared spec of a ``ServeCellDef``."""
    where = celldef.name
    findings = []
    for i, ps in enumerate(celldef.bound_pspecs):
        findings += check_spec_tree(ps, where, role=f"bound[{i}]")
    for i, ps in enumerate(celldef.request_pspecs):
        findings += check_spec_tree(ps, where, role=f"request[{i}]")
    findings += check_spec_tree(celldef.out_pspecs, where, role="out")
    return findings


def _names_axes(names) -> set:
    """Axes referenced by a shard_map in_names/out_names tuple-of-dicts."""
    axes = set()
    for entry in names:
        for axs in entry.values():
            axes.update(axs)
    return axes


def _reduced_axes(jaxpr) -> set:
    """Axes any reducing/collective primitive in ``jaxpr`` (recursively)
    operates over."""
    axes = set()
    for item in walk(jaxpr):
        if item.eqn.primitive.name in _REDUCING_PRIMS:
            for ax in item.eqn.params.get("axes", ()) or ():
                axes.add(ax)
            ax = item.eqn.params.get("axis_name")
            if isinstance(ax, str):
                axes.add(ax)
            elif ax is not None:
                axes.update(ax)
    return axes


def check_shard_map_reductions(closed_jaxpr, where: str) -> list[Finding]:
    """SC204 over every shard_map equation in a traced cell."""
    findings = []
    for item in walk(closed_jaxpr):
        eqn = item.eqn
        if eqn.primitive.name != "shard_map":
            continue
        in_axes = _names_axes(eqn.params.get("in_names", ()))
        out_axes = _names_axes(eqn.params.get("out_names", ()))
        missing = in_axes - out_axes
        if not missing:
            continue
        covered = _reduced_axes(eqn.params["jaxpr"])
        unreduced = sorted(missing - covered)
        if unreduced:
            findings.append(Finding(
                "SC204", f"shard_map consumes operands sharded over "
                f"{unreduced} but no output keeps the axis and the body "
                f"never psums over it — each device returns an unmerged "
                f"partial (the bucket-merge invariant)",
                where, file=item.file, line=item.line))
    return findings
