"""Analysis corpus: the standard serving fleet, built tiny.

The trace-level passes need real cells to walk — a jaxpr checker with no
jaxprs audits nothing. Rather than invent synthetic cells (which would
drift from what production registers), the corpus builds the same fleet
``launch.serve`` ships, at toy sizes: the packed DLRM score cells with
their lookup-split companions, the tiered hot/cold cells over a
``TieredTableStore``, and the LM decode + continuous-batching decode
cells with int8 KV caches. ~10 cells covering every cell kind and every
shard_map wrapper in the repo.

Mesh policy mirrors the test suite: with ≥4 devices (the CI staticcheck
job sets ``--xla_force_host_platform_device_count=4`` before importing
jax) the corpus compiles on a 2×2 ``("data", "model")`` mesh with
``shard_lookup`` on, so the SC204 and BC5xx passes see the real
``shard_map`` lowerings; on a stock single-device CPU it degrades to the
1×1 host mesh (sharding no-ops, still full precision/recompile
coverage).

Registration AOT-compiles every cell (``CellCache``), so each
``RegisteredCell`` arrives with its HLO text for the collective-budget
pass; ``trace_cell`` re-traces the step closure for the jaxpr passes.
"""
from __future__ import annotations

import jax

from repro.dist.mesh import host_mesh, use_mesh

def budget_name(key) -> str:
    """budgets.json key for one cell: ``arch/shape@batch`` — stable across
    mesh-signature and static-config (fingerprint) churn, which move the
    ``CellKey`` but not the layout the budget bounds."""
    return f"{key.arch}/{key.shape.split('#')[0]}"


def corpus_mesh():
    """2×2 ``("data", "model")`` when ≥4 devices are visible, else the
    host mesh (1×1 on a stock CPU)."""
    if len(jax.devices()) >= 4:
        return host_mesh(n_data=2, n_model=2)
    return host_mesh()


def build_corpus(mesh=None, *, seed: int = 4):
    """Build and register the standard cell fleet at toy sizes.

    Returns the ``Engine``; walk ``engine.registered_cells()`` for the
    per-cell definitions + warm executables.
    """
    from repro.cache import TieredTableStore
    from repro.data.synthetic import SyntheticCTR
    from repro.launch.serve import build_engine, train_packed_dlrm
    from repro.models.lm import LM, LMConfig
    from repro.serve.cells import lm_decode_cell, lm_decode_slotted_cell

    mesh = mesh if mesh is not None else corpus_mesh()

    cfg, params, state, buffers, spec, res = train_packed_dlrm(
        field_vocabs=(150, 100, 120), train_steps=6, train_batch=128,
        d_embed=8, mlp_hidden=(16,), seed=seed)
    freqs = SyntheticCTR(spec).expected_frequencies()
    store = TieredTableStore(res["packed_table"], res["packed_meta"],
                             freqs, 0.3)
    engine = build_engine(cfg, params, state, buffers, p99_rows=64,
                          bulk_rows=256, store=store, mesh=mesh)
    if engine.mesh.size > 1:
        # a2a comms variants under their own shape names: BC501 budgets the
        # all-to-all id/word shuffle separately from (and below) the dense
        # psum merge of the plain cells (ISSUE 10 crossover)
        from repro.models.dlrm import DLRM
        engine.register_packed_model(
            "dlrm", DLRM, cfg, params, state, buffers,
            shapes={"serve_p99_a2a": 64}, lookup_split=False,
            shard_lookup=True, lookup_comms="a2a", bucket_capacity=16)
        engine.register_tiered_model(
            "dlrm", DLRM, cfg, params, state, buffers, store,
            shapes={"tiered_p99_a2a": 64}, shard_lookup=True,
            lookup_comms="a2a", bucket_capacity=16)

    lm_cfg = LMConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                      head_dim=16, d_ff=64, vocab=50, remat=False)
    lm_params, lm_buffers = LM.init(jax.random.PRNGKey(0), lm_cfg)
    engine.register(lm_decode_cell(lm_cfg, lm_params, lm_buffers,
                                   batch=4, max_len=8, arch="lm-tiny"))
    engine.register(lm_decode_slotted_cell(lm_cfg, lm_params, lm_buffers,
                                           batch=2, max_len=8,
                                           arch="lm-cb"))
    return engine


#: cell kinds whose traces carry packed/quantized table codes as int32 —
#: PF102 widens its narrow set for these (see repro.analysis.precision).
PACKED_KINDS = frozenset({"score", "lookup", "tiered_score"})


def is_packed(celldef) -> bool:
    return celldef.kind in PACKED_KINDS


def trace_cell(reg, mesh):
    """ClosedJaxpr of a registered cell's step over its compiled avals —
    same closure + arg specs ``CellCache.get_or_compile`` lowered, traced
    under the same mesh so shard_map bodies appear."""
    celldef = reg.celldef
    args = celldef.bound + celldef.request_specs
    # the fresh wrapper defeats make_jaxpr's trace cache (keyed on function
    # identity) — the RC304 double-trace check needs each call to really
    # re-run the Python closure
    step = celldef.step_fn
    with use_mesh(mesh):
        return jax.make_jaxpr(lambda *a: step(*a))(*args)
