"""Precision-flow pass (PF1xx): dtype-lattice checks over traced cells.

The paper's contribution is that precision is a *per-feature-group
property* — packed codes live at their assigned widths until the one
sanctioned dequant. These rules catch the ways that discipline silently
erodes (each was a real runtime bug class in this repo's history: the int8
KV absmax bug, the FMA dequant subtlety):

  PF101  an op produces a float64/complex128 value — double precision is
         never intentional on the TPU path (and doubles every byte the
         roofline model budgets).
  PF102  a narrow quantized dtype is converted to float outside the
         sanctioned dequant modules (``core/packing.py``,
         ``core/quantizer.py``). Narrow = int8/int16/uint8/uint16 always;
         in cells marked *packed* it widens to int32/uint32 too, because
         unpacked codes travel as int32 there (int32 index/label converts
         in unpacked cells stay legal).
  PF103  a uint32 value is converted to float — packed *words* leaking
         into float math decodes garbage regardless of call site; only
         ``unpack_codes`` may consume packed words.
  PF104  integer arithmetic on int8 operands (add/sub/mul/dot staying in
         int8) — overflows at ±127 with wraparound; quantized arithmetic
         must widen (or dequant) first.

Attribution is by the equation's innermost user frame: routing a dequant
through ``core.quantizer.dequantize_codes`` moves the convert's frame into
the sanctioned module, which is exactly what "sanctioned call site" means
mechanically. Frames outside the repo (jax internals) are treated as
sanctioned — library-internal converts (e.g. ``jnp.mean`` accumulators)
are not ours to flag.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.analysis.findings import Finding
from repro.analysis.jaxpr_walk import in_dtypes, out_dtypes, walk

#: modules whose frames may widen quantized codes to float.
SANCTIONED_DEQUANT = ("repro/core/quantizer.py", "repro/core/packing.py")

_NARROW_INTS = ("int8", "uint8", "int16", "uint16")
_PACKED_EXTRA = ("int32", "uint32")
_ARITH_PRIMS = frozenset({"add", "sub", "mul", "dot_general"})


def _is_float(dt) -> bool:
    return jnp.issubdtype(dt, jnp.floating)


def _sanctioned(file: str | None) -> bool:
    if file is None:
        return True           # no user frame: jax-internal, not ours
    if "repro/" not in file.replace("\\", "/"):
        return True           # outside the repo source tree
    return any(file.replace("\\", "/").endswith(s)
               for s in SANCTIONED_DEQUANT)


def check_precision(closed_jaxpr, where: str, *,
                    packed: bool = False) -> list[Finding]:
    """Run PF101–PF104 over one traced cell/kernel jaxpr.

    ``packed`` marks cells serving from packed/quantized tables: their
    int32-carried codes join the narrow set for PF102 (see module doc)."""
    findings = []
    narrow = _NARROW_INTS + (_PACKED_EXTRA if packed else ())
    for item in walk(closed_jaxpr):
        eqn = item.eqn
        name = eqn.primitive.name

        for dt in out_dtypes(eqn):
            if str(dt) in ("float64", "complex128"):
                findings.append(Finding(
                    "PF101", f"op '{name}' produces {dt} — double precision "
                    f"is never intentional on this path",
                    where, file=item.file, line=item.line))
                break

        if name == "convert_element_type":
            src = in_dtypes(eqn)
            dst = eqn.params.get("new_dtype")
            if src and dst is not None and _is_float(dst):
                s = str(src[0])
                if s == "uint32":
                    findings.append(Finding(
                        "PF103", f"uint32 -> {dst} convert: packed words "
                        f"must go through core.packing.unpack_codes, never "
                        f"into float math",
                        where, file=item.file, line=item.line))
                elif s in narrow and not _sanctioned(item.file):
                    findings.append(Finding(
                        "PF102", f"{s} -> {dst} convert outside the "
                        f"sanctioned dequant modules "
                        f"({', '.join(SANCTIONED_DEQUANT)}) — route through "
                        f"core.quantizer",
                        where, file=item.file, line=item.line))

        if name in _ARITH_PRIMS:
            dts = out_dtypes(eqn)
            if dts and str(dts[0]) == "int8":
                findings.append(Finding(
                    "PF104", f"int8 '{name}' — 8-bit arithmetic wraps at "
                    f"±127; widen (or dequantize) before computing",
                    where, file=item.file, line=item.line))
    return findings
