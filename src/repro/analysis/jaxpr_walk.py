"""Recursive jaxpr traversal with source attribution.

``walk(closed_jaxpr)`` yields every equation of the program, descending into
the sub-jaxprs carried in equation params — ``pjit`` bodies, ``scan``/
``while`` bodies, ``cond`` branches, ``custom_vjp``/``custom_jvp`` wrappers
and ``shard_map`` bodies — so a checker sees the whole traced computation,
not just the top level.

``pallas_call`` internals are deliberately **not** descended into: a Pallas
kernel body is written against device-local refs with its own (audited)
dtype discipline, and its jaxpr primitives (``get``/``swap``/masked loads)
don't obey the array-level rules the checkers encode. The call-site
operands/results of the ``pallas_call`` itself still flow through the
enclosing jaxpr and stay checked.

Every yielded item carries the innermost *user* stack frame of the
equation's source info — the line whose Python executed the op. That makes
attribution actionable (point at ``serve/cells.py:198``, not at jnp
internals) and is what lets the precision pass distinguish a dequant routed
through ``core/quantizer.py`` (sanctioned) from the same convert inlined at
a call site (flagged).
"""
from __future__ import annotations

from typing import Iterator, NamedTuple

from jax._src import source_info_util
from jax.extend import core as jex_core

#: eqn param values holding sub-jaxprs are discovered structurally, but
#: these primitives' bodies are skipped outright.
SKIP_PRIMITIVES = frozenset({"pallas_call"})


class WalkItem(NamedTuple):
    eqn: object                # jax JaxprEqn
    path: tuple[str, ...]      # enclosing primitive names, outermost first
    file: str | None           # innermost user frame, when known
    line: int | None


def _user_frame(eqn):
    try:
        frame = source_info_util.user_frame(eqn.source_info)
    except Exception:
        return None, None
    if frame is None:
        return None, None
    return frame.file_name, frame.start_line


def _sub_jaxprs(params: dict):
    """Every Jaxpr/ClosedJaxpr reachable from an eqn's params (one level)."""
    for val in params.values():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            if isinstance(v, jex_core.ClosedJaxpr):
                yield v.jaxpr
            elif isinstance(v, jex_core.Jaxpr):
                yield v


def _walk_jaxpr(jaxpr, path) -> Iterator[WalkItem]:
    for eqn in jaxpr.eqns:
        file, line = _user_frame(eqn)
        yield WalkItem(eqn, path, file, line)
        name = eqn.primitive.name
        if name in SKIP_PRIMITIVES:
            continue
        for sub in _sub_jaxprs(eqn.params):
            yield from _walk_jaxpr(sub, path + (name,))


def walk(closed_jaxpr) -> Iterator[WalkItem]:
    """Yield every equation of ``closed_jaxpr`` (a ClosedJaxpr or Jaxpr),
    sub-jaxprs included, with source attribution."""
    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    yield from _walk_jaxpr(jaxpr, ())


def out_dtypes(eqn):
    """dtypes of the eqn's output avals (skips tokens/abstract units)."""
    out = []
    for v in eqn.outvars:
        aval = getattr(v, "aval", None)
        dt = getattr(aval, "dtype", None)
        if dt is not None:
            out.append(dt)
    return out


def in_dtypes(eqn):
    out = []
    for v in eqn.invars:
        aval = getattr(v, "aval", None)
        dt = getattr(aval, "dtype", None)
        if dt is not None:
            out.append(dt)
    return out
