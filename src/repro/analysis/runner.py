"""Orchestrates the static-analysis passes into one report.

Two independent halves, composable for the CLI (``scripts/staticcheck.py``)
and the tests:

* ``check_engine(engine)`` — the trace-level passes over every cell an
  engine has registered: precision flow (PF1xx), sharding contract
  (SC2xx), recompile hazards (RC3xx), collective budgets (BC5xx). No
  real devices needed beyond what the engine compiled on.
* ``lint_tree(repo_root)`` (re-exported from ``.lint``) — the AST rules
  (RL4xx) over ``src/repro``.

``run(repo_root)`` is the whole gate: build the tiny standard corpus
(``.corpus``), run both halves, return findings sorted by rule code.
Findings carrying a file/line honor ``# staticcheck: ignore[...]``
pragmas at that line (trace-level findings attribute to the *user frame*
of the offending equation, so the pragma goes where the op is written).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.analysis.budgets import (check_budget, load_budgets,
                                    measure_collectives)
from repro.analysis.corpus import (budget_name, build_corpus, is_packed,
                                   trace_cell)
from repro.analysis.findings import Finding, PragmaIndex
from repro.analysis.lint import lint_tree
from repro.analysis.precision import check_precision
from repro.analysis.recompile import (check_fingerprint,
                                      check_key_collisions,
                                      check_trace_determinism)
from repro.analysis.shardspec import (check_celldef_specs,
                                      check_shard_map_reductions)


@dataclass
class Report:
    """One static-analysis run: findings plus the per-cell collective
    measurements (kept so ``--update-budgets`` reuses them instead of
    re-measuring)."""
    findings: list = field(default_factory=list)
    measured: dict = field(default_factory=dict)   # budget name -> bytes
    n_cells: int = 0

    @property
    def codes(self) -> set:
        return {f.code for f in self.findings}

    def render(self) -> str:
        lines = [f.render() for f in
                 sorted(self.findings, key=lambda f: (f.code, f.where))]
        lines.append(f"{len(self.findings)} finding(s) across "
                     f"{self.n_cells} cell(s)")
        return "\n".join(lines)


def check_cell(reg, mesh, *, budgets=None, report: Report | None = None,
               skip_budgets: bool = False) -> Report:
    """Every trace-level pass over one ``RegisteredCell``."""
    report = report if report is not None else Report()
    celldef = reg.celldef
    jaxpr = trace_cell(reg, mesh)

    report.findings += check_precision(jaxpr, celldef.name,
                                       packed=is_packed(celldef))
    report.findings += check_shard_map_reductions(jaxpr, celldef.name)
    report.findings += check_celldef_specs(celldef)
    report.findings += check_fingerprint(celldef)
    report.findings += check_trace_determinism(
        celldef, lambda: trace_cell(reg, mesh))

    if not skip_budgets:
        name = budget_name(reg.cell.key)
        measured = measure_collectives(reg.cell.compiled)
        report.measured[name] = measured
        report.findings += check_budget(name, measured,
                                        budgets if budgets is not None
                                        else {})
    report.n_cells += 1
    return report


def check_engine(engine, *, budgets=None,
                 skip_budgets: bool = False) -> Report:
    """All trace-level passes over every cell ``engine`` registered."""
    report = Report()
    cells = engine.registered_cells()
    for reg in cells.values():
        check_cell(reg, engine.mesh, budgets=budgets, report=report,
                   skip_budgets=skip_budgets)
    report.findings += check_key_collisions(
        [reg.celldef for reg in cells.values()])
    return report


def run(repo_root: str, *, mesh=None, lint: bool = True,
        trace: bool = True, budgets: dict | None = None) -> Report:
    """The whole gate: corpus + trace passes + source lint.

    ``budgets`` defaults to the checked-in ``budgets.json``.
    """
    report = Report()
    if trace:
        engine = build_corpus(mesh)
        report = check_engine(
            engine, budgets=budgets if budgets is not None
            else load_budgets())
    if lint:
        report.findings += lint_tree(repo_root)

    # trace-level findings with a file/line honor source pragmas too
    # (lint findings were already filtered in lint_source; re-checking is
    # idempotent — their relative paths resolve against the cwd, and the
    # trace findings carry absolute user-frame paths)
    pragmas = PragmaIndex()
    report.findings = [f for f in report.findings
                       if not pragmas.suppressed(f)]
    report.findings.sort(key=lambda f: (f.code, f.where, f.line or 0))
    return report


__all__ = ["Report", "check_cell", "check_engine", "lint_tree", "run",
           "Finding"]
