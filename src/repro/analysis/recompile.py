"""Recompile-hazard pass (RC3xx): keep serving zero-recompile.

The serving path's contract is *compile once per (arch, shape, mesh)*:
``CellCache`` keys executables by ``(arch, shape@batch#fingerprint,
mesh_sig)`` and ``tests/test_serve.py`` asserts a warm process performs
zero recompiles. These rules catch the ways a cell definition breaks that
statically, by diffing the cache key's ingredients against the
traced-abstract-value signature (``ServeCellDef.abstract_signature``):

  RC301  a weak-typed input leaf — a Python scalar closed into ``bound``
         (or a weak constant) traces as ``weak_type=True``, which jax
         re-specializes against strongly-typed arrays: the first real
         request re-traces the "warm" executable.
  RC302  the fingerprint blob contains a ``0x…`` object address — some
         ``static`` ingredient falls back to the default ``__repr__``, so
         the same registration fingerprints differently every process
         (warm-start caches can never hit) and two *different* configs can
         collide after an address reuse.
  RC303  two cell definitions produce the same cache key but different
         abstract signatures — the key under-identifies the executable;
         whichever registers second silently warm-hits the wrong one.
  RC304  tracing the cell twice yields different jaxprs — Python-level
         nondeterminism in the step closure (dict-order dependence, RNG,
         time) forks the compile cache between traces.
"""
from __future__ import annotations

import re

from repro.analysis.findings import Finding

_ADDR = re.compile(r"0x[0-9a-fA-F]{6,}")


def check_fingerprint(celldef) -> list[Finding]:
    """RC301/RC302 over one cell definition."""
    findings = []
    blob = celldef.fingerprint_blob
    m = _ADDR.search(blob)
    if m:
        findings.append(Finding(
            "RC302", f"fingerprint blob contains object address {m.group(0)}"
            f" (default __repr__ of a static/meta ingredient) — the "
            f"fingerprint changes every process; give the object a stable "
            f"repr", celldef.name))
    for i, (shape, dtype, weak) in enumerate(celldef.abstract_signature()):
        if weak:
            findings.append(Finding(
                "RC301", f"input leaf #{i} ({dtype}{list(shape)}) is "
                f"weak-typed — a Python scalar closed into the cell; the "
                f"first strongly-typed request re-traces. Wrap it in "
                f"jnp.asarray(..., dtype=...) at build time",
                celldef.name))
    return findings


def _key_of(celldef) -> tuple:
    # mirror Engine._compile / CellCache.key, minus the mesh (same for all
    # cells under one engine, so it can't disambiguate colliding defs)
    return (celldef.arch,
            f"{celldef.shape}@{celldef.batch}#{celldef.fingerprint}")


def check_key_collisions(celldefs) -> list[Finding]:
    """RC303 across a set of cell definitions."""
    findings = []
    seen: dict[tuple, tuple] = {}
    for cd in celldefs:
        key = _key_of(cd)
        sig = cd.abstract_signature()
        prev = seen.setdefault(key, sig)
        if prev != sig:
            findings.append(Finding(
                "RC303", f"cache key {key[1]!r} collides across cell "
                f"definitions with different abstract signatures — the "
                f"second registration warm-hits an executable compiled for "
                f"other avals", cd.name))
    return findings


def check_trace_determinism(celldef, make_jaxpr) -> list[Finding]:
    """RC304: trace twice, compare jaxpr text. ``make_jaxpr()`` builds the
    cell's ClosedJaxpr (the runner owns mesh/context plumbing). It must
    defeat ``jax.make_jaxpr``'s identity-keyed trace cache — wrap the step
    in a fresh closure per call, as ``corpus.trace_cell`` does — or both
    traces return the same cached jaxpr and the check is vacuous."""
    # printed jaxprs embed object addresses (custom_jvp thunks etc.) that
    # legitimately differ between traces — scrub before comparing
    a, b = (_ADDR.sub("0xADDR", str(make_jaxpr())) for _ in range(2))
    if a != b:
        return [Finding(
            "RC304", "tracing the step function twice produced different "
            "jaxprs — nondeterministic Python in the cell closure forks "
            "the compile cache", celldef.name)]
    return []
