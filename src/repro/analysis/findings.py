"""Finding model shared by every checker, plus pragma suppression.

A ``Finding`` is one rule violation rendered ruff-style::

    src/repro/serve/cells.py:297:1: SC202 out_pspec P(None, 'model') is not ...
    cell dlrm/serve_p99@64: PF102 int8 -> float32 convert outside ...

Trace-level findings carry the cell/kernel name in ``where`` and, when the
jaxpr equation has a user frame, the source ``file``/``line`` it executes
from — which is also where an inline suppression pragma applies::

    deq = codes.astype(jnp.float32) * alpha  # staticcheck: ignore[PF102]

The pragma suppresses the named rule(s) for findings attributed to that
line (``ignore`` with no bracket suppresses every rule). Suppression is
per-line, not per-file — a blanket opt-out would defeat the gate.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_PRAGMA = re.compile(r"#\s*staticcheck:\s*ignore(?:\[([A-Z0-9,\s]+)\])?")


@dataclass(frozen=True)
class Finding:
    """One rule violation."""
    code: str                  # e.g. "PF102"
    message: str
    where: str                 # cell/kernel name, or the linted file
    file: str | None = None    # source file the violation executes from
    line: int | None = None    # 1-indexed line in ``file``
    col: int = 1
    extra: dict = field(default_factory=dict, compare=False)

    def render(self) -> str:
        loc = (f"{self.file}:{self.line}:{self.col}" if self.file
               else self.where)
        prefix = f" [{self.where}]" if self.file and self.where != self.file \
            else ""
        return f"{loc}: {self.code} {self.message}{prefix}"


def parse_pragmas(source: str) -> dict[int, set[str] | None]:
    """line number -> suppressed rule codes (None = every rule)."""
    out: dict[int, set[str] | None] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _PRAGMA.search(text)
        if not m:
            continue
        codes = m.group(1)
        out[i] = (None if codes is None
                  else {c.strip() for c in codes.split(",") if c.strip()})
    return out


class PragmaIndex:
    """Lazy per-file pragma tables for suppression lookups."""

    def __init__(self):
        self._cache: dict[str, dict[int, set[str] | None]] = {}

    def _table(self, path: str) -> dict[int, set[str] | None]:
        if path not in self._cache:
            try:
                with open(path) as f:
                    self._cache[path] = parse_pragmas(f.read())
            except OSError:
                self._cache[path] = {}
        return self._cache[path]

    def suppressed(self, finding: Finding) -> bool:
        if finding.file is None or finding.line is None:
            return False
        codes = self._table(finding.file).get(finding.line, ())
        return codes is None or finding.code in codes


def filter_suppressed(findings, pragmas: PragmaIndex | None = None):
    """Drop findings whose source line carries a matching ignore pragma."""
    pragmas = pragmas if pragmas is not None else PragmaIndex()
    return [f for f in findings if not pragmas.suppressed(f)]
