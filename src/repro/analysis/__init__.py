"""repro.analysis — static contract checker for the serving stack.

Four trace-level passes walk the jaxprs and compiled HLO of registered
serving cells (no real devices needed), plus an AST lint over
``src/repro``, all reported ruff-style with stable rule codes:

==========  ============================================================
 PF1xx       precision flow (``.precision``): float64 leaks, dequants
             outside the sanctioned modules, packed words into float
             math, int8 wraparound arithmetic
 SC2xx       sharding contract (``.shardspec``): specs vs the
             ``dist.sharding`` mesh contract; the shard_map
             bucket-merge (psum) invariant
 RC3xx       recompile hazards (``.recompile``): weak types, unstable
             fingerprints, cache-key collisions, trace nondeterminism
 BC5xx       collective budgets (``.budgets``): per-cell cross-device
             bytes vs checked-in ``budgets.json``
 RL4xx       source lint (``.lint``): hand-rolled PartitionSpecs,
             shard_map outside ``dist/``, host syncs in the serve hot
             path, device-path float64 literals, nondeterminism in
             cell-definition modules
==========  ============================================================

Entry points: ``run`` (the whole gate — what
``scripts/staticcheck.py`` and the blocking CI job call), or the
per-pass ``check_*`` functions for targeted use. Inline suppression:
``# staticcheck: ignore[PF102]`` on the offending line.
"""
from repro.analysis.findings import (Finding, PragmaIndex,
                                     filter_suppressed, parse_pragmas)
from repro.analysis.runner import (Report, check_cell, check_engine,
                                   lint_tree, run)

__all__ = [
    "Finding", "PragmaIndex", "Report", "check_cell", "check_engine",
    "filter_suppressed", "lint_tree", "parse_pragmas", "run",
]
