"""Collective-budget pass (BC5xx): per-cell collective bytes stay bounded.

Cross-device bytes are the serving path's scarcest resource — the whole
point of the masked-psum lookup layout is that a score cell moves one
``all-reduce`` of the (batch, d) output and nothing else. A refactor that
accidentally all-gathers a subtable (or lets GSPMD insert resharding
collectives) can be numerically perfect and still blow the latency budget,
so the measured per-cell collective bytes are checked in and gated:

  BC501  a cell's per-device collective bytes (from
         ``launch.hlo_analysis.analyze`` over its compiled HLO — the same
         accounting ``roofline.py --collectives`` reports) exceed its
         checked-in budget.
  BC502  a cell has no budget entry — new cells must check in a budget
         (run ``scripts/staticcheck.py --update-budgets``).

Budgets live in ``src/repro/analysis/budgets.json`` with ~25% headroom
over the measured bytes at budget-update time, absorbing jax/XLA version
drift in lowering while still catching a layout regression (any stray
table gather is orders of magnitude over).
"""
from __future__ import annotations

import json
import os

from repro.analysis.findings import Finding
from repro.launch.hlo_analysis import analyze

BUDGETS_PATH = os.path.join(os.path.dirname(__file__), "budgets.json")

#: headroom multiplier applied by ``--update-budgets``.
HEADROOM = 1.25


def measure_collectives(compiled) -> dict:
    """Per-kind collective bytes of one AOT-compiled executable."""
    return analyze(compiled.as_text())["collectives_per_device"]


def load_budgets(path: str | None = None) -> dict:
    path = path or BUDGETS_PATH
    try:
        with open(path) as f:
            return json.load(f)
    except OSError:
        return {}


def save_budgets(budgets: dict, path: str | None = None) -> None:
    path = path or BUDGETS_PATH
    with open(path, "w") as f:
        json.dump(budgets, f, indent=2, sort_keys=True)
        f.write("\n")


def budget_entry(measured: dict) -> dict:
    """A fresh budget line: measured total bytes with headroom."""
    return {"total_bytes": int(measured["total_bytes"] * HEADROOM)}


def check_budget(name: str, measured: dict,
                 budgets: dict) -> list[Finding]:
    """BC501/BC502 for one cell's measured collectives."""
    entry = budgets.get(name)
    if entry is None:
        return [Finding(
            "BC502", f"no collective budget checked in for this cell — run "
            f"scripts/staticcheck.py --update-budgets and commit "
            f"budgets.json", name)]
    total = float(measured["total_bytes"])
    cap = float(entry["total_bytes"])
    if total > cap:
        kinds = {k: int(v["bytes"]) for k, v in measured.items()
                 if isinstance(v, dict) and v.get("bytes")}
        return [Finding(
            "BC501", f"collective bytes {int(total)} exceed the checked-in "
            f"budget {int(cap)} (per-kind: {kinds}) — a layout change is "
            f"moving extra cross-device bytes", name)]
    return []
