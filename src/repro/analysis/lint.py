"""Source-level lint (RL4xx): repo conventions enforced mechanically.

AST-based (no regexes over code), ruff-style output, scoped to
``src/repro``. These rules encode conventions ARCHITECTURE.md previously
stated as prose:

  RL401  a ``PartitionSpec``/``P`` call with a **string-literal mesh axis**
         outside ``repro/dist/`` — naming an axis inline is declaring
         placement policy, which belongs to the pspec families in
         ``dist/sharding.py``. Two shapes stay legal: axis-less literals
         (``P(None)``, ``P(dp, None)`` — wiring contract-derived tuples
         through) and literals passed *directly* to ``maybe_shard``/
         ``shard_batch_dim`` (those route through ``_fit_spec``, which
         validates axes against the active mesh).
  RL402  ``shard_map`` imported or called outside ``repro/dist/shard.py``
         — every shard_map body must live behind the wrappers whose in/out
         specs come from the contract (and which SC204 can audit).
  RL403  ``jax.device_get`` / ``block_until_ready`` in ``repro/serve/`` —
         host syncs in the hot path serialize the dispatch pipeline. The
         two deliberate timing barriers carry
         ``# staticcheck: ignore[RL403]``.
  RL404  a device-path ``float64`` dtype literal (``jnp.float64`` /
         ``jnp.double``) — doubles are never intentional on the TPU path
         (PF101 is the trace-level twin). Host-side ``np.float64`` stays
         legal: the Zipf/statistics code uses it deliberately.
  RL405  nondeterminism in a cell-definition module (``serve/cells.py``,
         ``launch/cells.py``): ``time.*``/``random.*``/``np.random.*``/
         ``datetime.*`` — a cell closure must trace identically every
         process, or the compile cache forks (RC304 is the trace-level
         twin).
"""
from __future__ import annotations

import ast
import os

from repro.analysis.findings import Finding, parse_pragmas

RULES = ("RL401", "RL402", "RL403", "RL404", "RL405")

_PSPEC_NAMES = {"P", "PartitionSpec"}
_SHARD_WRAPPERS = {"maybe_shard", "shard_batch_dim"}
_CELL_MODULES = ("serve/cells.py", "launch/cells.py")
_NONDET_ROOTS = {"time", "random", "datetime"}


def _norm(path: str) -> str:
    return path.replace(os.sep, "/")


def _in_dist(path: str) -> bool:
    return "/dist/" in _norm(path) or _norm(path).endswith("/dist")


def _in_serve(path: str) -> bool:
    return "repro/serve/" in _norm(path)


def _is_cell_module(path: str) -> bool:
    return any(_norm(path).endswith(m) for m in _CELL_MODULES)


def _call_name(node: ast.Call) -> str | None:
    fn = node.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return None


def _dotted_root(node) -> str | None:
    """Leftmost name of a dotted expression (``np.random.default_rng`` ->
    ``np``; second segment via _dotted_second)."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _has_axis_literal(call: ast.Call) -> bool:
    """Does a P(...) call name a mesh axis as a string literal (directly or
    inside a tuple literal)?"""
    for arg in call.args:
        entries = arg.elts if isinstance(arg, ast.Tuple) else (arg,)
        for e in entries:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                return True
    return False


class _Visitor(ast.NodeVisitor):
    def __init__(self, relpath: str):
        self.relpath = relpath
        self.findings: list[Finding] = []
        self._wrapper_args: set[int] = set()  # ids of maybe_shard arg nodes

    def _flag(self, code: str, node, message: str):
        self.findings.append(Finding(
            code, message, self.relpath, file=self.relpath,
            line=node.lineno, col=node.col_offset + 1))

    # -- RL402: imports ----------------------------------------------------
    def visit_ImportFrom(self, node: ast.ImportFrom):
        names = {a.name for a in node.names}
        if "shard_map" in names and not _in_dist(self.relpath):
            self._flag("RL402", node,
                       "shard_map import outside dist/shard.py — use the "
                       "sharded_* wrappers")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        name = _call_name(node)

        if name in _SHARD_WRAPPERS:
            for arg in node.args:
                if isinstance(arg, ast.Call) and \
                        _call_name(arg) in _PSPEC_NAMES:
                    self._wrapper_args.add(id(arg))

        if name in _PSPEC_NAMES and not _in_dist(self.relpath) \
                and id(node) not in self._wrapper_args \
                and _has_axis_literal(node):
            self._flag("RL401", node,
                       "hand-rolled PartitionSpec with a string-literal "
                       "mesh axis — use a pspec family from "
                       "dist/sharding.py (or pass it directly to "
                       "maybe_shard)")

        if name == "shard_map" and not _in_dist(self.relpath):
            self._flag("RL402", node,
                       "shard_map call outside dist/shard.py — use the "
                       "sharded_* wrappers")

        if name in ("device_get", "block_until_ready") \
                and _in_serve(self.relpath):
            self._flag("RL403", node,
                       f"{name} in the serve hot path — host syncs "
                       f"serialize the dispatch pipeline")

        if _is_cell_module(self.relpath):
            root = _dotted_root(node.func)
            attr = node.func.attr if isinstance(node.func, ast.Attribute) \
                else None
            if root in _NONDET_ROOTS or (root == "np" and attr is not None
                                         and "random" in ast.dump(node.func)):
                self._flag("RL405", node,
                           f"nondeterministic call in a cell-definition "
                           f"module ({root}.{attr or name}) — cell closures "
                           f"must trace identically every process")

        self.generic_visit(node)

    # -- RL404: device-path float64 literals -------------------------------
    def visit_Attribute(self, node: ast.Attribute):
        if node.attr in ("float64", "double") and \
                _dotted_root(node) in ("jnp", "jax"):
            self._flag("RL404", node,
                       f"device-path float64 dtype literal (jnp."
                       f"{node.attr}) — double precision is never "
                       f"intentional on the TPU path")
        self.generic_visit(node)


def lint_source(source: str, relpath: str) -> list[Finding]:
    """Lint one module's source text; pragma suppression applied."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding("RL400", f"syntax error: {e.msg}", relpath,
                        file=relpath, line=e.lineno or 1)]
    visitor = _Visitor(relpath)
    visitor.visit(tree)
    pragmas = parse_pragmas(source)
    out = []
    for f in visitor.findings:
        codes = pragmas.get(f.line, ())
        if codes is None or f.code in codes:
            continue
        out.append(f)
    return out


def lint_file(path: str, root: str | None = None) -> list[Finding]:
    rel = os.path.relpath(path, root) if root else path
    with open(path) as f:
        return lint_source(f.read(), _norm(rel))


def lint_tree(src_root: str) -> list[Finding]:
    """Lint every ``.py`` under ``src_root`` (pass the repo root; scope is
    ``src/repro``)."""
    target = os.path.join(src_root, "src", "repro")
    findings = []
    for dirpath, _, files in os.walk(target):
        for fn in sorted(files):
            if fn.endswith(".py"):
                findings += lint_file(os.path.join(dirpath, fn),
                                      root=src_root)
    return findings
