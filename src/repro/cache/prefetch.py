"""Async prefetch: stage the next step's inputs while this step computes.

jax dispatch is asynchronous — a jitted train step returns device futures
immediately — so the host is free while the accelerator works. The
synchronous loop wastes that window: it only starts materializing batch
``s+1`` (host data generation + host→device copy) after dispatching step
``s`` *and then blocks on the copy before the next dispatch*. The
``PrefetchPipeline`` moves that work one step ahead: when the trainer asks
for batch ``s`` it receives an already-staged device batch and the pipeline
immediately issues the ``jax.device_put`` for batch ``s+1``, double-buffering
the transfer against the in-flight step's MLP compute.

The pipeline changes *when* bytes move, never *which* bytes: the staged batch
is bit-identical to what the synchronous loop would build, so training losses
match step for step (asserted in ``tests/test_cache.py``). With a
``TieredTableStore`` attached it also issues the batch's cold embedding-row
transfer alongside (the serving-style gather overlap), exposing the in-flight
``ColdPrefetch`` fills via ``take_cold``.
"""
from __future__ import annotations

from typing import Callable

import jax
import numpy as np


class PrefetchPipeline:
    """Depth-``depth`` read-ahead wrapper around a ``data_fn(step) -> batch``.

    Drop-in for the Trainer's ``data_fn`` (``trainer.run(..., prefetch=True)``
    builds one): calling ``pipeline(step)`` returns the staged device batch
    for ``step`` and eagerly stages steps ``step+1 .. step+depth``. Staging is
    ``jax.device_put`` per array — issued asynchronously, overlapped with
    whatever compute is already dispatched.

    ``store``/``ids_key``: optionally prefetch the batch's cold embedding
    rows from a ``TieredTableStore`` at the same time; ``offsets`` (per-field
    id offsets) globalizes the ids first, matching the model's lookup.
    """

    def __init__(self, data_fn: Callable, *, depth: int = 1, device=None,
                 store=None, ids_key: str = "ids", offsets=None):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self.data_fn = data_fn
        self.depth = depth
        self.device = device
        self.store = store
        self.ids_key = ids_key
        self.offsets = None if offsets is None else np.asarray(offsets)
        self._staged: dict[int, dict] = {}
        self._cold: dict[int, object] = {}
        self.staged_steps = 0

    def _stage(self, step: int) -> dict:
        raw = self.data_fn(step)
        staged = {k: jax.device_put(np.asarray(v), self.device)
                  for k, v in raw.items()}
        if self.store is not None and self.ids_key in raw:
            ids = np.asarray(raw[self.ids_key])
            if self.offsets is not None:
                ids = ids + self.offsets[None, :]
            self._cold[step] = self.store.prefetch_cold(ids)
        self._staged[step] = staged
        self.staged_steps += 1
        return staged

    def __call__(self, step: int) -> dict:
        batch = self._staged.pop(step, None)
        if batch is None:                      # cold start / restart at `step`
            batch = self._stage(step)
            self._staged.pop(step)
        for ahead in range(step + 1, step + 1 + self.depth):
            if ahead not in self._staged:
                self._stage(ahead)
        # drop stale read-ahead (e.g. after a checkpoint-restore jump); cold
        # fills are evicted independently — the served step's fill survives
        # until the caller's take_cold or the next __call__, never longer
        for s in [s for s in self._staged if s <= step]:
            self._staged.pop(s)
        for s in [s for s in self._cold if s < step]:
            self._cold.pop(s)
        return batch

    def take_cold(self, step: int):
        """The in-flight ``ColdPrefetch`` staged for ``step`` (or None)."""
        return self._cold.pop(step, None)
