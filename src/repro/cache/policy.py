"""Traffic-adaptive tier policy: frequency-decay admission over the hot set.

The hot/cold split of ``TieredTableStore`` is seeded once from training-set
frequency, but production popularity drifts hour to hour — *Mixed-Precision
Embedding Using a Cache* (Yang et al., 2020) makes the serving-time cache
policy the thing that keeps a mixed-precision table viable at scale. This
module closes that loop: it turns the store's live lookup stream into
**exponentially-decayed per-feature scores** (an LRU-ish recency/frequency
blend) and emits bounded batches of promotions/demotions that the store
applies *incrementally* — no full re-pack, no recompile (the hot subtable
shapes never change; moves land in free slots or swap row-for-row).

Score model (lazy decay — O(touched) per observation, O(n) per plan):

    score_f(t) = score_f(t_last) * 0.5^((t - t_last)/halflife) + hits

where ``t`` advances by one tick per ``observe`` call (one dispatched chunk).
A feature's score is therefore a half-life-weighted hit count: traffic from
``halflife`` chunks ago counts half as much as current traffic, so a
popularity shift re-ranks the vocabulary within a few half-lives.

Promotion batching: each ``plan`` emits at most ``max_moves`` moves, filling
free hot slots hottest-cold-feature first, then swapping cold risers against
the coldest hot residents only when the riser's score beats the victim's by
the hysteresis ``margin`` (> 1 damps thrash on near-ties). All ordering is
deterministic (stable sorts, feature-id tie-break).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class TierPlan(NamedTuple):
    """One policy decision: global feature ids to promote into the hot tier
    and to demote out of it, plus the decayed scores that justified the
    moves (debug/telemetry; the store only consumes the id arrays)."""
    promote: np.ndarray        # (p,) int64 global feature ids, hottest first
    demote: np.ndarray         # (q,) int64 global feature ids
    promote_score: np.ndarray  # (p,) float64 decayed scores at plan time
    demote_score: np.ndarray   # (q,) float64

    @property
    def n_moves(self) -> int:
        """Total rows this plan touches (promotions + demotions)."""
        return int(self.promote.size + self.demote.size)


class StaticTierPolicy:
    """The no-op policy: keep the training-frequency split forever.

    Exists so ``--cache-policy static`` and the adaptive policy drive the
    identical code path in benchmarks and tests — same observation hooks,
    same plan cadence, zero moves."""

    def observe(self, ids) -> None:
        """Ignore the traffic (the static split never re-ranks)."""

    def plan(self, store) -> TierPlan:
        """An empty plan: nothing promotes, nothing demotes."""
        empty = np.zeros((0,), np.int64)
        return TierPlan(empty, empty, np.zeros((0,)), np.zeros((0,)))


class DecayAdmissionPolicy:
    """Frequency-decay admission/eviction over a ``TieredTableStore``.

    ``n`` is the store's vocabulary size; ``halflife`` the score half-life in
    observation ticks (one tick per ``observe`` call — one dispatched chunk
    in the serving engine); ``max_moves`` bounds each plan's promotion batch;
    ``margin`` is the swap hysteresis (a cold riser must beat the coldest
    hot resident's score by this factor before they trade places).

    Attach with ``TieredTableStore.attach_policy(policy)`` — the store then
    feeds every valid looked-up id into ``observe`` from ``prefetch_cold``,
    so the scores see exactly the traffic the hit/miss counters see.
    """

    def __init__(self, n: int, *, halflife: float = 256.0,
                 max_moves: int = 64, margin: float = 1.1):
        if halflife <= 0:
            raise ValueError(f"halflife must be > 0, got {halflife}")
        if margin < 1.0:
            raise ValueError(f"margin must be >= 1, got {margin}")
        self.n = int(n)
        self.halflife = float(halflife)
        self.max_moves = int(max_moves)
        self.margin = float(margin)
        self._decay = 0.5 ** (1.0 / self.halflife)   # per-tick factor
        self._score = np.zeros((self.n,), np.float64)
        self._last = np.zeros((self.n,), np.float64)  # tick of last touch
        self._t = 0.0
        self.observations = 0

    # -- observation ---------------------------------------------------------

    def observe(self, ids) -> None:
        """Fold one chunk's looked-up ids into the decayed scores.

        Lazy decay: only the touched features pay the catch-up
        multiplication, so a chunk costs O(unique ids) regardless of
        vocabulary size."""
        ids = np.asarray(ids).reshape(-1)
        self._t += 1.0
        self.observations += 1
        if ids.size == 0:
            return
        u, c = np.unique(ids, return_counts=True)
        self._score[u] = (self._score[u]
                          * self._decay ** (self._t - self._last[u]) + c)
        self._last[u] = self._t

    def scores(self) -> np.ndarray:
        """Every feature's score decayed to the current tick (O(n))."""
        return self._score * self._decay ** (self._t - self._last)

    # -- planning ------------------------------------------------------------

    def plan(self, store) -> TierPlan:
        """Emit at most ``max_moves`` promotions/demotions against ``store``.

        Per width bucket (moves never cross buckets — a row only fits its
        own packed width): free hot slots fill with the highest-scoring cold
        features that have any traffic; then cold risers swap against the
        coldest hot residents while ``riser > resident * margin``. The plan
        is feasible by construction: every promotion either lands in a free
        slot or is paired with a demotion of the same width."""
        scores = self.scores()
        width_idx = store._width_idx_np
        is_hot = store._is_hot_np
        free = store.free_slot_counts()
        budget = self.max_moves
        promote, demote = [], []
        pro_s, dem_s = [], []
        for i, b in enumerate(store.meta["bits"]):
            if b == 0 or budget <= 0:
                continue
            feats = np.nonzero(width_idx == i)[0]
            cold = feats[~is_hot[feats]]
            hot = feats[is_hot[feats]]
            if cold.size == 0:
                continue
            # hottest cold features first; coldest hot residents first —
            # stable under score ties via the feature-id tie-break
            cold = cold[np.lexsort((cold, -scores[cold]))]
            hot = hot[np.lexsort((hot, scores[hot]))]
            k = 0
            n_free = min(int(free.get(f"b{b}", 0)), budget)
            while k < n_free and k < cold.size and scores[cold[k]] > 0.0:
                promote.append(cold[k]); pro_s.append(scores[cold[k]])
                k += 1
            budget -= k
            j = 0
            while (budget >= 2 and k < cold.size and j < hot.size
                   and scores[cold[k]] > scores[hot[j]] * self.margin):
                promote.append(cold[k]); pro_s.append(scores[cold[k]])
                demote.append(hot[j]); dem_s.append(scores[hot[j]])
                k += 1; j += 1; budget -= 2
        return TierPlan(np.asarray(promote, np.int64),
                        np.asarray(demote, np.int64),
                        np.asarray(pro_s, np.float64),
                        np.asarray(dem_s, np.float64))
