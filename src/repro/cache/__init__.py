"""Tiered embedding cache + async prefetch (ROADMAP scaling item).

Two layers:

  ``tiers``    — ``TieredTableStore``: splits an MPE packed table by feature
                 frequency into a device-resident hot tier (row-shards like
                 the monolithic table; see ``dist.sharding.tiered_hot_pspecs``)
                 and a host-memory cold tier whose rows move as packed words
                 on demand. Bit-exact against ``core.inference.packed_lookup``
                 at every hot fraction; per-tier hit/miss/byte counters.
  ``prefetch`` — ``PrefetchPipeline``: double-buffers the next batch's
                 host→device staging (and optionally its cold-row fills)
                 against the current step's compute. Same bytes, one step
                 earlier: losses are step-identical to the synchronous loop.

Consumers: ``train.loop.Trainer(run(..., prefetch=True))``,
``serve.Engine.register_tiered_model``/``score_tiered``, and
``benchmarks/prefetch_bench.py`` (→ ``BENCH_prefetch.json``).
"""
from repro.cache.prefetch import PrefetchPipeline
from repro.cache.tiers import (ColdPrefetch, TieredTableStore,
                               tiered_hot_lookup, tiered_hot_lookup_fn)

__all__ = [
    "TieredTableStore", "ColdPrefetch", "tiered_hot_lookup",
    "tiered_hot_lookup_fn", "PrefetchPipeline",
]
