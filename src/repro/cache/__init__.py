"""Tiered embedding cache + async prefetch (ROADMAP scaling item).

Three layers:

  ``tiers``    — ``TieredTableStore``: splits an MPE packed table by feature
                 frequency into a device-resident hot tier (row-shards like
                 the monolithic table; see ``dist.sharding.tiered_hot_pspecs``)
                 and an inclusive host mirror whose rows move as packed words
                 on demand. Bit-exact against ``core.inference.packed_lookup``
                 at every hot fraction; per-tier hit/miss/byte counters;
                 incremental ``apply_moves`` promotions/demotions and
                 training-update ``writeback`` — both shape-preserving, so
                 compiled tiered cells never recompile.
  ``policy``   — ``DecayAdmissionPolicy``: exponential-decay admission
                 scores over the live lookup stream (attach with
                 ``TieredTableStore.attach_policy``) planning bounded
                 ``TierPlan`` promotion batches; ``StaticTierPolicy`` is the
                 no-op baseline.
  ``prefetch`` — ``PrefetchPipeline``: double-buffers the next batch's
                 host→device staging (and optionally its cold-row fills)
                 against the current step's compute. Same bytes, one step
                 earlier: losses are step-identical to the synchronous loop.

Consumers: ``train.loop.Trainer(run(..., prefetch=True))``,
``serve.Engine.register_tiered_model``/``score_tiered``/
``attach_tier_policy``, and ``benchmarks/prefetch_bench.py``
(→ ``BENCH_prefetch.json``).
"""
from repro.cache.policy import (DecayAdmissionPolicy, StaticTierPolicy,
                                TierPlan)
from repro.cache.prefetch import PrefetchPipeline
from repro.cache.tiers import (ColdPrefetch, TieredTableStore,
                               tiered_hot_lookup, tiered_hot_lookup_fn)

__all__ = [
    "TieredTableStore", "ColdPrefetch", "tiered_hot_lookup",
    "tiered_hot_lookup_fn", "PrefetchPipeline", "DecayAdmissionPolicy",
    "StaticTierPolicy", "TierPlan",
]
