"""Hot/cold tiered storage for MPE packed tables.

MPE's frequency-grouped precision assignment (paper §3.2/§4.1) hands the
serving layer a ready-made cache policy: the high-frequency features that get
wide precision are exactly the rows worth pinning device-resident, while the
long tail can live in host memory and be fetched per request — the split
*Mixed-Precision Embedding Using a Cache* (Yang et al., 2020) validates at
production scale.

``TieredTableStore`` splits each per-width packed subtable of a
``core.inference.build_packed_table`` pytree into

  - a **hot tier**: the top-``hot_fraction`` features by frequency, kept as
    device arrays (HBM on an accelerator). The hot tier is a pytree shaped
    for ``repro.dist.sharding.tiered_hot_pspecs`` — it row-shards over the
    mesh exactly like the monolithic table; the cold tier never does.
  - a **cold tier**: the remaining rows as host ``np.ndarray``s. A lookup
    that touches them gathers the *packed words* on the host and moves only
    those bytes over PCIe (``jax.device_put``), so the transfer inherits the
    table's compression ratio.

Lookups are bit-exact against ``core.inference.packed_lookup`` on the
monolithic table at every hot fraction: both tiers gather the same packed
words, unpack with the same static shifts and dequantize with the same
``α_b · code + β`` expression, and the tier merge is a ``jnp.where`` on the
tier mask (never an add), so no float combine can perturb a row.

Per-tier hit/miss/byte counters are first-class — ``counters()`` backs the
hit-rate-vs-hot-fraction curve in ``benchmarks/prefetch_bench.py`` and the
hand-computed trace asserted in ``tests/test_cache.py``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.inference import _pad_rows, _auto_pad_multiple
from repro.core.quantizer import dequantize_codes, int_bounds
from repro.embeddings.frequency import hot_feature_mask


class ColdPrefetch(NamedTuple):
    """In-flight cold-row fill for one id batch.

    Produced by ``TieredTableStore.prefetch_cold`` — the host gather has
    happened and the ``jax.device_put`` of the packed words has been
    *issued* (asynchronously) but not awaited, so creating one of these a
    step ahead overlaps the host→device copy with the current step's
    compute. Consumed by ``cold_part``/``lookup``.
    """
    n: int                 # flat batch size the fill covers
    parts: tuple           # ((width_index, positions, device_words), ...)
    bytes_moved: int       # packed bytes issued host→device


class TieredTableStore:
    """Frequency-split hot/cold view of one packed inference table.

    ``table``/``meta`` are the pytree + static metadata from
    ``build_packed_table``; ``frequencies`` is any per-feature access-count
    vector (training-log counts or the Zipf profile); ``hot_fraction`` pins
    the top fraction of features device-resident (0 = everything cold,
    1 = everything hot — both degenerate tiers stay valid).

    ``row_pad_multiple`` pads hot-subtable rows the same way the monolithic
    table pads (size-aware power of two, 512 at production scale) so the hot
    tier row-shards cleanly under ``tiered_hot_pspecs``.
    """

    def __init__(self, table, meta, frequencies, hot_fraction: float, *,
                 row_pad_multiple: int | None = None, device=None):
        self.meta = {"bits": tuple(meta["bits"]), "d": int(meta["d"]),
                     "n": int(meta["n"])}
        self.hot_fraction = float(hot_fraction)
        self.device = device
        self._freqs = np.asarray(frequencies)
        bits = self.meta["bits"]

        width_idx = np.asarray(table["width_idx"])
        is_hot = self._hot_mask(width_idx)

        if row_pad_multiple is None:
            n_widths = sum(1 for b in bits if b != 0)
            row_pad_multiple = _auto_pad_multiple(max(int(is_hot.sum()), 1),
                                                  max(n_widths, 1))
        self._row_pad_multiple = int(row_pad_multiple)

        self._rebuild(table, is_hot, capacities=None)
        self.reset_counters()

    def _hot_mask(self, width_idx: np.ndarray) -> np.ndarray:
        """Frequency policy for the hot tier: top-``hot_fraction`` features,
        plus every zero-width feature — those never occupy a subtable row
        (their embedding is the zero vector), so hot residency is free."""
        is_hot = hot_feature_mask(self._freqs, self.hot_fraction)
        for i, b in enumerate(self.meta["bits"]):
            if b == 0:
                is_hot[width_idx == i] = True
        return is_hot

    def _rebuild(self, table, is_hot: np.ndarray,
                 capacities: dict | None) -> None:
        """(Re)split ``table`` into the two tiers. ``capacities`` pins each
        hot subtable to an exact row count (the repack path — compiled hot
        shapes must survive); ``None`` pads to ``row_pad_multiple``."""
        bits, d, n = self.meta["bits"], self.meta["d"], self.meta["n"]
        width_idx = np.asarray(table["width_idx"])
        local_idx = np.asarray(table["local_idx"])
        device = self.device

        tier_local = np.zeros((n,), np.int32)
        hot_subs, cold_subs = {}, {}
        hot_bytes = cold_bytes = 0
        for i, b in enumerate(bits):
            if b == 0:
                continue
            sub = np.asarray(table["subtables"][f"b{b}"])       # (rows_p, W)
            feats = np.nonzero(width_idx == i)[0]
            hot_f = feats[is_hot[feats]]
            cold_f = feats[~is_hot[feats]]
            tier_local[hot_f] = np.arange(hot_f.size, dtype=np.int32)
            tier_local[cold_f] = np.arange(cold_f.size, dtype=np.int32)
            # pad hot rows like build_packed_table pads (all-N_b rows), so
            # row shards stay aligned to whole packed rows
            n_b, _ = int_bounds(b)
            pad_row = np.asarray(
                packing.pack_codes(jnp.full((1, d), n_b, jnp.int32), b))
            if capacities is not None:
                padded = int(capacities[f"b{b}"])
                if hot_f.size > padded:
                    raise ValueError(
                        f"hot tier b{b} holds {hot_f.size} rows, over its "
                        f"compiled capacity {padded}")
            else:
                padded = _pad_rows(hot_f.size, self._row_pad_multiple)
            hot_rows = np.tile(pad_row, (padded, 1))
            hot_rows[:hot_f.size] = sub[local_idx[hot_f]]
            hot_subs[f"b{b}"] = jax.device_put(jnp.asarray(hot_rows), device)
            cold_subs[f"b{b}"] = np.ascontiguousarray(sub[local_idx[cold_f]])
            hot_bytes += hot_f.size * packing.row_bytes(d, b)
            cold_bytes += cold_f.size * packing.row_bytes(d, b)

        # host-side routing vectors (the cold path plans gathers with them)
        self._is_hot_np = is_hot
        self._width_idx_np = width_idx
        self._tier_local_np = tier_local
        self._cold_subs = cold_subs

        # device-resident hot tier: the pytree a serve cell binds (layout
        # contract: repro.dist.sharding.tiered_hot_pspecs)
        self.hot = {
            "subtables": hot_subs,
            "tier_local": jax.device_put(jnp.asarray(tier_local), device),
            "is_hot": jax.device_put(jnp.asarray(is_hot), device),
            "width_idx": jax.device_put(jnp.asarray(width_idx.astype(np.int32)),
                                        device),
            "alpha": jax.device_put(jnp.asarray(table["alpha"]), device),
            "beta": jax.device_put(jnp.asarray(table["beta"]), device),
        }
        self._storage = {"hot_bytes": int(hot_bytes),
                         "cold_bytes": int(cold_bytes)}

    # -- serving-time repack (repro.serve.repack) ---------------------------

    def refresh(self, table, meta, frequencies=None) -> None:
        """Re-seat a re-packed table into this store *without changing any
        hot-tier array shape* — the hook ``Engine._rebind_tiered`` uses to
        keep compiled tiered cells valid across a serving-time repack.

        The hot/cold split is recomputed from the (optionally updated)
        frequencies under the same policy as construction, then clamped to
        the compiled hot-subtable capacities: if a repack widened enough hot
        features to overflow a bucket, the coldest overflow features demote
        to the cold tier (flipping ``is_hot`` values only — the masks keep
        their shapes, so the executable is unchanged). Counters stay
        cumulative; ``storage()`` reflects the new split."""
        meta = {"bits": tuple(meta["bits"]), "d": int(meta["d"]),
                "n": int(meta["n"])}
        if meta != self.meta:
            raise ValueError(
                f"refresh changes the table's static metadata "
                f"({self.meta} -> {meta}) — that is a re-registration, "
                f"not a repack")
        if frequencies is not None:
            self._freqs = np.asarray(frequencies)

        width_idx = np.asarray(table["width_idx"])
        is_hot = self._hot_mask(width_idx)
        caps = {k: int(v.shape[0]) for k, v in self.hot["subtables"].items()}
        for i, b in enumerate(self.meta["bits"]):
            if b == 0:
                continue
            hot_f = np.nonzero(is_hot & (width_idx == i))[0]
            over = hot_f.size - caps[f"b{b}"]
            if over > 0:    # demote the coldest overflow features
                order = hot_f[np.argsort(self._freqs[hot_f], kind="stable")]
                is_hot[order[:over]] = False
        self._rebuild(table, is_hot, capacities=caps)

    # -- counters -----------------------------------------------------------

    def reset_counters(self):
        self._counters = {"hot_lookups": 0, "cold_lookups": 0,
                          "bytes_moved": 0, "prefetches": 0}

    def counters(self) -> dict:
        """Cumulative tier traffic: ``hot_lookups``/``cold_lookups`` count id
        lookups served per tier, ``bytes_moved`` the packed host→device bytes
        of cold fills, ``hit_rate`` their ratio, plus the static per-tier
        storage bytes."""
        c = dict(self._counters, **self._storage)
        total = c["hot_lookups"] + c["cold_lookups"]
        c["hit_rate"] = c["hot_lookups"] / total if total else 1.0
        return c

    # -- cold tier (host side) ----------------------------------------------

    def prefetch_cold(self, ids, valid=None) -> ColdPrefetch:
        """Gather the batch's cold rows on the host and *issue* their async
        device transfer. Call this one step (or one chunk) ahead of the
        compute that consumes it — ``jax.device_put`` returns immediately,
        so the copy overlaps whatever is already dispatched.

        ``valid``: optional boolean mask over ``ids`` (or over its leading
        axis — e.g. the batcher's per-row validity mask) — invalid entries
        are batcher padding: they fetch nothing and stay out of the
        counters, so hit rates and bytes reflect real traffic only.

        Row counts are padded up to powers of two so the downstream eager
        unpack/scatter in ``cold_part`` sees a handful of stable shapes
        (shape-churn would compile a fresh executable per request); padded
        entries carry an out-of-bounds position, which the scatter drops.
        ``bytes_moved`` counts the real rows only."""
        ids = np.asarray(ids)
        flat = ids.reshape(-1)
        if valid is None:
            valid_flat = np.ones(flat.shape, bool)
        else:
            valid = np.asarray(valid, bool)
            if valid.shape != ids.shape:   # per-row mask -> per-id mask
                valid = np.broadcast_to(valid.reshape(valid.shape[0],
                                                      *([1] * (ids.ndim - 1))),
                                        ids.shape)
            valid_flat = valid.reshape(-1)
        widx = self._width_idx_np[flat]
        lidx = self._tier_local_np[flat]
        cold = ~self._is_hot_np[flat] & valid_flat
        parts, nbytes = [], 0
        for i, b in enumerate(self.meta["bits"]):
            if b == 0:
                continue
            sub = self._cold_subs[f"b{b}"]
            sel = np.nonzero(cold & (widx == i))[0]
            if sel.size == 0 or sub.shape[0] == 0:
                continue
            rows = sub[lidx[sel]]                         # (k, W) host gather
            nbytes += rows.nbytes
            padded = 1 << max(int(np.ceil(np.log2(sel.size))), 3)
            pos = np.full((padded,), flat.size, np.int32)  # OOB pads: dropped
            pos[:sel.size] = sel
            rows_p = np.zeros((padded, rows.shape[1]), rows.dtype)
            rows_p[:sel.size] = rows
            parts.append((i, pos,
                          jax.device_put(jnp.asarray(rows_p), self.device)))
        self._counters["prefetches"] += 1
        self._counters["hot_lookups"] += int(valid_flat.sum() - cold.sum())
        self._counters["cold_lookups"] += int(cold.sum())
        self._counters["bytes_moved"] += int(nbytes)
        return ColdPrefetch(n=int(flat.size), parts=tuple(parts),
                            bytes_moved=int(nbytes))

    def cold_part(self, fill: ColdPrefetch) -> jnp.ndarray:
        """Dequantize an in-flight cold fill into a dense ``(n, d)`` fp32
        array (zeros at hot positions) — bit-exact against ``packed_lookup``
        (asserted in tests/test_cache.py).

        The integer work (unpack + scatter, jitted — fusion cannot perturb
        integer ops; the pow-2 padding of ``prefetch_cold`` keeps the shape
        cache tiny) lands the codes in a dense grid; the float dequant then
        runs as whole-array *eager* ops, because compiling the dequant lets
        LLVM contract its mul+add into a single-rounding FMA that differs
        from the reference by 1 ulp."""
        bits, d = self.meta["bits"], self.meta["d"]
        codes_grid = jnp.zeros((fill.n, d), jnp.int32)
        wgrid = jnp.full((fill.n,), -1, jnp.int32)
        for i, pos, words in fill.parts:
            codes_grid, wgrid = _scatter_codes(bits[i], d, codes_grid, wgrid,
                                               jnp.asarray(pos), words, i)
        alpha_vec = jnp.take(self.hot["alpha"], jnp.maximum(wgrid, 0), axis=0)
        deq = dequantize_codes(codes_grid, alpha_vec[:, None],
                               self.hot["beta"])
        return jnp.where((wgrid >= 0)[:, None], deq, 0.0)

    # -- full lookup --------------------------------------------------------

    def lookup(self, ids, fill: ColdPrefetch | None = None) -> jnp.ndarray:
        """ids: any int shape -> (*ids.shape, d) fp32 — bit-exact against
        ``packed_lookup`` on the monolithic table. Pass a ``fill`` from an
        earlier ``prefetch_cold(ids)`` to consume an overlapped transfer;
        otherwise the cold fetch happens synchronously here."""
        ids = jnp.asarray(ids)
        if fill is None:
            fill = self.prefetch_cold(np.asarray(ids))
        flat = ids.reshape(-1)
        hot = tiered_hot_lookup(self.hot, self.meta["bits"], self.meta["d"],
                                flat)
        is_hot = jnp.take(self.hot["is_hot"], flat, axis=0)
        out = jnp.where(is_hot[:, None], hot, self.cold_part(fill))
        return out.reshape(*ids.shape, self.meta["d"])

    def storage(self) -> dict:
        """Static per-tier packed bytes (pad-free)."""
        return dict(self._storage)


@functools.partial(jax.jit, static_argnums=(0, 1))
def _scatter_codes(b: int, d: int, codes_grid, wgrid, pos, words, width_i):
    """Unpack one width's cold rows and scatter the integer codes (and the
    width id) into the dense grids. Out-of-bounds positions (the pow-2
    padding of ``prefetch_cold``) are dropped by jax scatter semantics."""
    codes = packing.unpack_codes(words, b, d)
    return (codes_grid.at[pos].set(codes),
            wgrid.at[pos].set(jnp.int32(width_i)))


def tiered_hot_lookup(hot, bits, d: int, ids: jnp.ndarray) -> jnp.ndarray:
    """Device-local gather from a hot tier: ids (any int shape) ->
    (*ids.shape, d) fp32, **zeros at cold positions**.

    Mirrors ``core.inference.packed_lookup`` bucket by bucket (same unpack
    shifts, same dequant expression) but reads the hot subtables and masks on
    the tier bit as well as the width bucket. Pure jnp + static shapes: safe
    to close over in a jitted serve cell, shards under
    ``tiered_hot_pspecs``.
    """
    flat = ids.reshape(-1)
    widx = jnp.take(hot["width_idx"], flat, axis=0)
    lidx = jnp.take(hot["tier_local"], flat, axis=0)
    is_hot = jnp.take(hot["is_hot"], flat, axis=0)
    out = jnp.zeros((flat.shape[0], d), jnp.float32)
    for i, b in enumerate(bits):
        if b == 0:
            continue
        sub = hot["subtables"][f"b{b}"]
        words = jnp.take(sub, jnp.clip(lidx, 0, sub.shape[0] - 1), axis=0)
        codes = packing.unpack_codes(words, b, d)
        deq = dequantize_codes(codes, hot["alpha"][i], hot["beta"])
        out = jnp.where((is_hot & (widx == i))[:, None], deq, out)
    return out.reshape(*ids.shape, d)


def tiered_hot_lookup_fn(bits, d: int):
    """``tiered_hot_lookup`` with the static metadata bound:
    ``(hot_tree, ids) -> embeddings``. Jit-stable the same way
    ``core.inference.packed_lookup_fn`` is."""
    bits = tuple(bits)
    return lambda hot, ids: tiered_hot_lookup(hot, bits, d, ids)
