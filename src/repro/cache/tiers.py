"""Hot/cold tiered storage for MPE packed tables.

MPE's frequency-grouped precision assignment (paper §3.2/§4.1) hands the
serving layer a ready-made cache policy: the high-frequency features that get
wide precision are exactly the rows worth pinning device-resident, while the
long tail can live in host memory and be fetched per request — the split
*Mixed-Precision Embedding Using a Cache* (Yang et al., 2020) validates at
production scale.

``TieredTableStore`` splits each per-width packed subtable of a
``core.inference.build_packed_table`` pytree into

  - a **hot tier**: the top-``hot_fraction`` features by frequency, kept as
    device arrays (HBM on an accelerator). The hot tier is a pytree shaped
    for ``repro.dist.sharding.tiered_hot_pspecs`` — it row-shards over the
    mesh exactly like the monolithic table; the cold tier never does.
  - a **cold tier**: the remaining rows as host ``np.ndarray``s. A lookup
    that touches them gathers the *packed words* on the host and moves only
    those bytes over PCIe (``jax.device_put``), so the transfer inherits the
    table's compression ratio.

Lookups are bit-exact against ``core.inference.packed_lookup`` on the
monolithic table at every hot fraction: both tiers gather the same packed
words, unpack with the same static shifts and dequantize with the same
``α_b · code + β`` expression, and the tier merge is a ``jnp.where`` on the
tier mask (never an add), so no float combine can perturb a row.

Per-tier hit/miss/byte counters are first-class — ``counters()`` backs the
hit-rate-vs-hot-fraction curve in ``benchmarks/prefetch_bench.py`` and the
hand-computed trace asserted in ``tests/test_cache.py``.

The store is an **inclusive cache**: the host side keeps a full packed
mirror of every row (indexed by ``local_idx``), and the hot tier holds
device copies of the currently-resident subset. That makes the incremental
tier moves of ``cache.policy`` cheap and safe — a demotion only flips the
``is_hot`` bit (the authoritative row never left the mirror), a promotion
copies one mirror row into a free hot slot, and both preserve every array
shape so compiled tiered cells never recompile (``apply_moves``). Training
updates enter through ``writeback``, which re-quantizes under the feature's
current width and writes the mirror *first*, then patches the hot copy if
resident — so a concurrent demotion can never lose an update (writeback
ordering).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.inference import _pad_rows, _auto_pad_multiple
from repro.core.quantizer import dequantize_codes, int_bounds, quantize_codes
from repro.embeddings.frequency import hot_feature_mask


class ColdPrefetch(NamedTuple):
    """In-flight cold-row fill for one id batch.

    Produced by ``TieredTableStore.prefetch_cold`` — the host gather has
    happened and the ``jax.device_put`` of the packed words has been
    *issued* (asynchronously) but not awaited, so creating one of these a
    step ahead overlaps the host→device copy with the current step's
    compute. Consumed by ``cold_part``/``lookup``.
    """
    n: int                 # flat batch size the fill covers
    parts: tuple           # ((width_index, positions, device_words), ...)
    bytes_moved: int       # packed bytes issued host→device


class TieredTableStore:
    """Frequency-split hot/cold view of one packed inference table.

    ``table``/``meta`` are the pytree + static metadata from
    ``build_packed_table``; ``frequencies`` is any per-feature access-count
    vector (training-log counts or the Zipf profile); ``hot_fraction`` pins
    the top fraction of features device-resident (0 = everything cold,
    1 = everything hot — both degenerate tiers stay valid).

    ``row_pad_multiple`` pads hot-subtable rows the same way the monolithic
    table pads (size-aware power of two, 512 at production scale) so the hot
    tier row-shards cleanly under ``tiered_hot_pspecs``.
    """

    def __init__(self, table, meta, frequencies, hot_fraction: float, *,
                 row_pad_multiple: int | None = None, device=None):
        self.meta = {"bits": tuple(meta["bits"]), "d": int(meta["d"]),
                     "n": int(meta["n"])}
        self.hot_fraction = float(hot_fraction)
        self.device = device
        self._freqs = np.asarray(frequencies)
        bits = self.meta["bits"]

        width_idx = np.asarray(table["width_idx"])
        is_hot = self._hot_mask(width_idx)

        if row_pad_multiple is None:
            n_widths = sum(1 for b in bits if b != 0)
            row_pad_multiple = _auto_pad_multiple(max(int(is_hot.sum()), 1),
                                                  max(n_widths, 1))
        self._row_pad_multiple = int(row_pad_multiple)
        self._policy = None
        self.hot_version = 0   # bumped on any hot-tier array replacement

        self._rebuild(table, is_hot, capacities=None)
        self.reset_counters()

    def _hot_mask(self, width_idx: np.ndarray) -> np.ndarray:
        """Frequency policy for the hot tier: top-``hot_fraction`` features,
        plus every zero-width feature — those never occupy a subtable row
        (their embedding is the zero vector), so hot residency is free."""
        is_hot = hot_feature_mask(self._freqs, self.hot_fraction)
        for i, b in enumerate(self.meta["bits"]):
            if b == 0:
                is_hot[width_idx == i] = True
        return is_hot

    def _rebuild(self, table, is_hot: np.ndarray,
                 capacities: dict | None) -> None:
        """(Re)split ``table`` into the two tiers. ``capacities`` pins each
        hot subtable to an exact row count (the repack path — compiled hot
        shapes must survive); ``None`` pads to ``row_pad_multiple``."""
        bits, d, n = self.meta["bits"], self.meta["d"], self.meta["n"]
        width_idx = np.asarray(table["width_idx"])
        local_idx = np.asarray(table["local_idx"])
        device = self.device

        tier_local = np.zeros((n,), np.int32)
        hot_subs, mirror, free_slots = {}, {}, {}
        hot_bytes = cold_bytes = mirror_bytes = 0
        for i, b in enumerate(bits):
            if b == 0:
                continue
            sub = np.asarray(table["subtables"][f"b{b}"])       # (rows_p, W)
            feats = np.nonzero(width_idx == i)[0]
            hot_f = feats[is_hot[feats]]
            cold_f = feats[~is_hot[feats]]
            tier_local[hot_f] = np.arange(hot_f.size, dtype=np.int32)
            tier_local[cold_f] = np.arange(cold_f.size, dtype=np.int32)
            # pad hot rows like build_packed_table pads (all-N_b rows), so
            # row shards stay aligned to whole packed rows
            n_b, _ = int_bounds(b)
            pad_row = np.asarray(
                packing.pack_codes(jnp.full((1, d), n_b, jnp.int32), b))
            if capacities is not None:
                padded = int(capacities[f"b{b}"])
                if hot_f.size > padded:
                    raise ValueError(
                        f"hot tier b{b} holds {hot_f.size} rows, over its "
                        f"compiled capacity {padded}")
            else:
                padded = _pad_rows(hot_f.size, self._row_pad_multiple)
            hot_rows = np.tile(pad_row, (padded, 1))
            hot_rows[:hot_f.size] = sub[local_idx[hot_f]]
            hot_subs[f"b{b}"] = jax.device_put(jnp.asarray(hot_rows), device)
            # inclusive host mirror: every packed row, indexed by local_idx —
            # the authoritative copy that cold fills, promotions and
            # writebacks all read/write
            mirror[f"b{b}"] = np.array(sub)
            # hot pad rows double as free promotion slots; stored descending
            # so pop() hands out the lowest slot first (deterministic)
            free_slots[f"b{b}"] = list(range(padded - 1, hot_f.size - 1, -1))
            hot_bytes += hot_f.size * packing.row_bytes(d, b)
            cold_bytes += cold_f.size * packing.row_bytes(d, b)
            mirror_bytes += mirror[f"b{b}"].nbytes

        # host-side routing vectors (the cold path plans gathers with them)
        self._is_hot_np = is_hot
        self._width_idx_np = width_idx
        self._tier_local_np = tier_local
        self._local_idx_np = local_idx
        self._mirror = mirror
        self._free_slots = free_slots
        self._alpha_np = np.asarray(table["alpha"])
        self._beta_np = np.asarray(table["beta"])

        # device-resident hot tier: the pytree a serve cell binds (layout
        # contract: repro.dist.sharding.tiered_hot_pspecs)
        self.hot = {
            "subtables": hot_subs,
            "tier_local": jax.device_put(jnp.asarray(tier_local), device),
            "is_hot": jax.device_put(jnp.asarray(is_hot), device),
            "width_idx": jax.device_put(jnp.asarray(width_idx.astype(np.int32)),
                                        device),
            "alpha": jax.device_put(jnp.asarray(table["alpha"]), device),
            "beta": jax.device_put(jnp.asarray(table["beta"]), device),
        }
        self._storage = {"hot_bytes": int(hot_bytes),
                         "cold_bytes": int(cold_bytes),
                         "mirror_bytes": int(mirror_bytes)}
        self.hot_version += 1

    # -- serving-time repack (repro.serve.repack) ---------------------------

    def refresh(self, table, meta, frequencies=None) -> None:
        """Re-seat a re-packed table into this store *without changing any
        hot-tier array shape* — the hook ``Engine._rebind_tiered`` uses to
        keep compiled tiered cells valid across a serving-time repack.

        The hot/cold split is recomputed from the (optionally updated)
        frequencies under the same policy as construction, then clamped to
        the compiled hot-subtable capacities: if a repack widened enough hot
        features to overflow a bucket, the coldest overflow features demote
        to the cold tier (flipping ``is_hot`` values only — the masks keep
        their shapes, so the executable is unchanged). Counters stay
        cumulative; ``storage()`` reflects the new split."""
        meta = {"bits": tuple(meta["bits"]), "d": int(meta["d"]),
                "n": int(meta["n"])}
        if meta != self.meta:
            raise ValueError(
                f"refresh changes the table's static metadata "
                f"({self.meta} -> {meta}) — that is a re-registration, "
                f"not a repack")
        if frequencies is not None:
            self._freqs = np.asarray(frequencies)

        width_idx = np.asarray(table["width_idx"])
        if self._policy is not None:
            # an adaptive policy owns the split: carry the live tier bits
            # across the repack instead of re-seating from training
            # frequencies, and rank overflow demotions by live score
            is_hot = self._is_hot_np.copy()
            for i, b in enumerate(self.meta["bits"]):
                if b == 0:
                    is_hot[width_idx == i] = True
            rank = (self._policy.scores()
                    if hasattr(self._policy, "scores") else self._freqs)
        else:
            is_hot = self._hot_mask(width_idx)
            rank = self._freqs
        caps = {k: int(v.shape[0]) for k, v in self.hot["subtables"].items()}
        for i, b in enumerate(self.meta["bits"]):
            if b == 0:
                continue
            hot_f = np.nonzero(is_hot & (width_idx == i))[0]
            over = hot_f.size - caps[f"b{b}"]
            if over > 0:    # demote the coldest overflow features
                order = hot_f[np.argsort(rank[hot_f], kind="stable")]
                is_hot[order[:over]] = False
        self._rebuild(table, is_hot, capacities=caps)

    # -- incremental tier moves (cache.policy) ------------------------------

    def attach_policy(self, policy):
        """Wire a tier policy (``cache.policy``) into the lookup stream:
        every ``prefetch_cold`` feeds its valid ids to ``policy.observe``,
        so the policy scores exactly the traffic the hit/miss counters see.
        Returns the policy for chaining."""
        self._policy = policy
        return policy

    @property
    def policy(self):
        """The attached tier policy, or ``None`` (static split)."""
        return self._policy

    def free_slot_counts(self) -> dict:
        """Free hot-subtable rows per width key (``{"b8": 3, ...}``) — the
        promotion headroom ``cache.policy`` plans against."""
        return {k: len(v) for k, v in self._free_slots.items()}

    def apply_moves(self, promote, demote) -> dict:
        """Apply one ``TierPlan``'s promotions/demotions *incrementally* —
        no re-pack, no shape change, so compiled tiered cells stay valid
        (the engine rebinds the updated arrays; zero recompiles is
        counter-asserted in tests/test_policy.py).

        Demotions flip the tier bit and free the slot — the inclusive
        mirror already holds the row, nothing is copied. Promotions copy
        mirror rows into free slots (one pow-2-padded device scatter per
        width). Plans must be feasible: every promoted feature cold, every
        demoted feature hot, and per-width promotions ≤ free slots after
        demotions (``DecayAdmissionPolicy.plan`` guarantees this)."""
        promote = np.asarray(promote, np.int64).reshape(-1)
        demote = np.asarray(demote, np.int64).reshape(-1)
        if promote.size == 0 and demote.size == 0:
            return {"promotions": 0, "demotions": 0, "bytes": 0}
        bits, d, n = self.meta["bits"], self.meta["d"], self.meta["n"]
        widx = self._width_idx_np
        if promote.size and self._is_hot_np[promote].any():
            raise ValueError("plan promotes features already hot")
        if demote.size and not self._is_hot_np[demote].all():
            raise ValueError("plan demotes features already cold")
        moved = np.concatenate([promote, demote])
        if np.unique(moved).size != moved.size:
            raise ValueError("plan lists a feature twice")
        if any(bits[widx[f]] == 0 for f in moved):
            raise ValueError("zero-width features never occupy a hot row")

        # 1) demote: free the slot, flip the bit — the mirror is authoritative
        for f in demote:
            self._free_slots[f"b{bits[widx[f]]}"].append(
                int(self._tier_local_np[f]))
        self._is_hot_np[demote] = False

        # 2) promote: copy mirror rows into free slots, batched per width
        new_subs = dict(self.hot["subtables"])
        nbytes = 0
        slot_idx, slot_val = [], []
        for i, b in enumerate(bits):
            if b == 0:
                continue
            sel = promote[widx[promote] == i]
            if sel.size == 0:
                continue
            free = self._free_slots[f"b{b}"]
            if sel.size > len(free):
                raise ValueError(
                    f"hot tier b{b} has {len(free)} free slots, plan "
                    f"promotes {sel.size}")
            slots = np.asarray([free.pop() for _ in range(sel.size)],
                               np.int32)
            self._tier_local_np[sel] = slots
            rows = self._mirror[f"b{b}"][self._local_idx_np[sel]]
            nbytes += rows.nbytes
            sub = new_subs[f"b{b}"]
            p2 = 1 << max(int(np.ceil(np.log2(sel.size))), 2)
            slots_p = np.full((p2,), sub.shape[0], np.int32)  # OOB: dropped
            slots_p[:sel.size] = slots
            rows_p = np.zeros((p2, rows.shape[1]), rows.dtype)
            rows_p[:sel.size] = rows
            new_subs[f"b{b}"] = _scatter_rows(sub, jnp.asarray(slots_p),
                                              jnp.asarray(rows_p))
            slot_idx.append(sel)
            slot_val.append(slots)
        self._is_hot_np[promote] = True

        # 3) device routing vectors: one padded scatter each, only the moves
        p2 = 1 << max(int(np.ceil(np.log2(moved.size))), 2)
        idx = np.full((p2,), n, np.int32)                     # OOB: dropped
        idx[:moved.size] = moved
        hotv = np.zeros((p2,), bool)
        hotv[:promote.size] = True
        new_is_hot = _scatter_vec(self.hot["is_hot"], jnp.asarray(idx),
                                  jnp.asarray(hotv))
        new_tl = self.hot["tier_local"]
        if slot_idx:
            up_i, up_v = np.concatenate(slot_idx), np.concatenate(slot_val)
            p2 = 1 << max(int(np.ceil(np.log2(up_i.size))), 2)
            tidx = np.full((p2,), n, np.int32)
            tidx[:up_i.size] = up_i
            tval = np.zeros((p2,), np.int32)
            tval[:up_v.size] = up_v
            new_tl = _scatter_vec(new_tl, jnp.asarray(tidx),
                                  jnp.asarray(tval))
        self.hot = dict(self.hot, subtables=new_subs, is_hot=new_is_hot,
                        tier_local=new_tl)
        self.hot_version += 1

        # storage accounting stays pad-free, keyed on the tier bit
        for i, b in enumerate(bits):
            if b == 0:
                continue
            delta = (int((widx[promote] == i).sum())
                     - int((widx[demote] == i).sum())) * packing.row_bytes(d, b)
            self._storage["hot_bytes"] += delta
            self._storage["cold_bytes"] -= delta
        self._counters["promotions"] += int(promote.size)
        self._counters["demotions"] += int(demote.size)
        self._counters["promote_bytes"] += int(nbytes)
        return {"promotions": int(promote.size),
                "demotions": int(demote.size), "bytes": int(nbytes)}

    # -- training-update writeback ------------------------------------------

    def writeback(self, ids, vectors) -> dict:
        """Flow training-time embedding updates into the store without a
        re-pack: re-quantize each vector under its feature's *current*
        width and overwrite the packed row.

        Ordering contract: the host mirror (the cold store) is written
        **first** — it is the authoritative copy — and the hot subtable is
        patched after, only for currently-resident features. A demotion
        interleaved between the two writes therefore cannot lose the
        update: demotions copy nothing, they re-expose the already-updated
        mirror row. Duplicate ids resolve last-write-wins. Zero-width
        features store no row and are skipped. Hot and cold reads of a
        written feature are bit-exact to each other (same packed words in
        both tiers; round-trip asserted in tests/test_policy.py)."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        vectors = np.asarray(vectors, np.float32).reshape(ids.size,
                                                          self.meta["d"])
        if ids.size:
            # np.unique keeps the first occurrence; scan reversed to keep
            # the last (last-write-wins)
            _, first = np.unique(ids[::-1], return_index=True)
            keep = np.sort(ids.size - 1 - first)
            ids, vectors = ids[keep], vectors[keep]
        bits = self.meta["bits"]
        widx = self._width_idx_np[ids] if ids.size else np.zeros(0, np.int32)
        new_subs = dict(self.hot["subtables"])
        nbytes, written, touched_hot = 0, 0, False
        for i, b in enumerate(bits):
            if b == 0:
                continue
            sel = np.nonzero(widx == i)[0]
            if sel.size == 0:
                continue
            f = ids[sel]
            codes = quantize_codes(jnp.asarray(vectors[sel]),
                                   self._alpha_np[i], self._beta_np, b)
            words = np.asarray(packing.pack_codes(codes, b))
            # cold store FIRST: mirror is authoritative (see docstring)
            self._mirror[f"b{b}"][self._local_idx_np[f]] = words
            nbytes += words.nbytes
            written += int(f.size)
            hot_sel = np.nonzero(self._is_hot_np[f])[0]
            if hot_sel.size:
                slots = self._tier_local_np[f[hot_sel]].astype(np.int32)
                sub = new_subs[f"b{b}"]
                p2 = 1 << max(int(np.ceil(np.log2(hot_sel.size))), 2)
                slots_p = np.full((p2,), sub.shape[0], np.int32)
                slots_p[:hot_sel.size] = slots
                rows_p = np.zeros((p2, words.shape[1]), words.dtype)
                rows_p[:hot_sel.size] = words[hot_sel]
                new_subs[f"b{b}"] = _scatter_rows(sub, jnp.asarray(slots_p),
                                                  jnp.asarray(rows_p))
                nbytes += int(words[hot_sel].nbytes)
                touched_hot = True
        if touched_hot:
            self.hot = dict(self.hot, subtables=new_subs)
            self.hot_version += 1
        self._counters["writebacks"] += written
        self._counters["writeback_bytes"] += int(nbytes)
        return {"written": written, "bytes": int(nbytes)}

    # -- counters -----------------------------------------------------------

    def reset_counters(self):
        self._counters = {"hot_lookups": 0, "cold_lookups": 0,
                          "bytes_moved": 0, "prefetches": 0,
                          "promotions": 0, "demotions": 0,
                          "promote_bytes": 0,
                          "writebacks": 0, "writeback_bytes": 0}

    def counters(self) -> dict:
        """Cumulative tier traffic: ``hot_lookups``/``cold_lookups`` count id
        lookups served per tier, ``bytes_moved`` the packed host→device bytes
        of cold fills, ``hit_rate`` their ratio, plus the static per-tier
        storage bytes. Adaptive-policy activity rides along:
        ``promotions``/``demotions``/``promote_bytes`` from ``apply_moves``
        and ``writebacks``/``writeback_bytes`` from ``writeback``."""
        c = dict(self._counters, **self._storage)
        total = c["hot_lookups"] + c["cold_lookups"]
        c["hit_rate"] = c["hot_lookups"] / total if total else 1.0
        return c

    # -- cold tier (host side) ----------------------------------------------

    def prefetch_cold(self, ids, valid=None) -> ColdPrefetch:
        """Gather the batch's cold rows on the host and *issue* their async
        device transfer. Call this one step (or one chunk) ahead of the
        compute that consumes it — ``jax.device_put`` returns immediately,
        so the copy overlaps whatever is already dispatched.

        ``valid``: optional boolean mask over ``ids`` (or over its leading
        axis — e.g. the batcher's per-row validity mask) — invalid entries
        are batcher padding: they fetch nothing and stay out of the
        counters, so hit rates and bytes reflect real traffic only.

        Row counts are padded up to powers of two so the downstream eager
        unpack/scatter in ``cold_part`` sees a handful of stable shapes
        (shape-churn would compile a fresh executable per request); padded
        entries carry an out-of-bounds position, which the scatter drops.
        ``bytes_moved`` counts the real rows only."""
        ids = np.asarray(ids)
        flat = ids.reshape(-1)
        if valid is None:
            valid_flat = np.ones(flat.shape, bool)
        else:
            valid = np.asarray(valid, bool)
            if valid.shape != ids.shape:   # per-row mask -> per-id mask
                valid = np.broadcast_to(valid.reshape(valid.shape[0],
                                                      *([1] * (ids.ndim - 1))),
                                        ids.shape)
            valid_flat = valid.reshape(-1)
        if self._policy is not None:
            # the policy sees exactly the traffic the counters see
            self._policy.observe(flat[valid_flat])
        widx = self._width_idx_np[flat]
        lidx = self._local_idx_np[flat]
        cold = ~self._is_hot_np[flat] & valid_flat
        parts, nbytes = [], 0
        for i, b in enumerate(self.meta["bits"]):
            if b == 0:
                continue
            sub = self._mirror[f"b{b}"]
            sel = np.nonzero(cold & (widx == i))[0]
            if sel.size == 0 or sub.shape[0] == 0:
                continue
            rows = sub[lidx[sel]]                         # (k, W) host gather
            nbytes += rows.nbytes
            padded = 1 << max(int(np.ceil(np.log2(sel.size))), 3)
            pos = np.full((padded,), flat.size, np.int32)  # OOB pads: dropped
            pos[:sel.size] = sel
            rows_p = np.zeros((padded, rows.shape[1]), rows.dtype)
            rows_p[:sel.size] = rows
            parts.append((i, pos,
                          jax.device_put(jnp.asarray(rows_p), self.device)))
        self._counters["prefetches"] += 1
        self._counters["hot_lookups"] += int(valid_flat.sum() - cold.sum())
        self._counters["cold_lookups"] += int(cold.sum())
        self._counters["bytes_moved"] += int(nbytes)
        return ColdPrefetch(n=int(flat.size), parts=tuple(parts),
                            bytes_moved=int(nbytes))

    def cold_part(self, fill: ColdPrefetch) -> jnp.ndarray:
        """Dequantize an in-flight cold fill into a dense ``(n, d)`` fp32
        array (zeros at hot positions) — bit-exact against ``packed_lookup``
        (asserted in tests/test_cache.py).

        The integer work (unpack + scatter, jitted — fusion cannot perturb
        integer ops; the pow-2 padding of ``prefetch_cold`` keeps the shape
        cache tiny) lands the codes in a dense grid; the float dequant then
        runs as whole-array *eager* ops, because compiling the dequant lets
        LLVM contract its mul+add into a single-rounding FMA that differs
        from the reference by 1 ulp."""
        bits, d = self.meta["bits"], self.meta["d"]
        codes_grid = jnp.zeros((fill.n, d), jnp.int32)
        wgrid = jnp.full((fill.n,), -1, jnp.int32)
        for i, pos, words in fill.parts:
            codes_grid, wgrid = _scatter_codes(bits[i], d, codes_grid, wgrid,
                                               jnp.asarray(pos), words, i)
        alpha_vec = jnp.take(self.hot["alpha"], jnp.maximum(wgrid, 0), axis=0)
        deq = dequantize_codes(codes_grid, alpha_vec[:, None],
                               self.hot["beta"])
        return jnp.where((wgrid >= 0)[:, None], deq, 0.0)

    # -- full lookup --------------------------------------------------------

    def lookup(self, ids, fill: ColdPrefetch | None = None) -> jnp.ndarray:
        """ids: any int shape -> (*ids.shape, d) fp32 — bit-exact against
        ``packed_lookup`` on the monolithic table. Pass a ``fill`` from an
        earlier ``prefetch_cold(ids)`` to consume an overlapped transfer;
        otherwise the cold fetch happens synchronously here."""
        ids = jnp.asarray(ids)
        if fill is None:
            fill = self.prefetch_cold(np.asarray(ids))
        flat = ids.reshape(-1)
        hot = tiered_hot_lookup(self.hot, self.meta["bits"], self.meta["d"],
                                flat)
        is_hot = jnp.take(self.hot["is_hot"], flat, axis=0)
        out = jnp.where(is_hot[:, None], hot, self.cold_part(fill))
        return out.reshape(*ids.shape, self.meta["d"])

    def storage(self) -> dict:
        """Static per-tier packed bytes (pad-free)."""
        return dict(self._storage)


@jax.jit
def _scatter_rows(sub, slots, rows):
    """Land promoted/written packed rows in a hot subtable. ``slots`` is
    pow-2 padded with out-of-bounds indices (dropped by scatter), so the
    jit shape cache stays tiny and the subtable shape never changes."""
    return sub.at[slots].set(rows)


@jax.jit
def _scatter_vec(vec, idx, vals):
    """Patch a routing vector (``is_hot``/``tier_local``) at the moved
    features only — same pow-2 OOB-padding contract as ``_scatter_rows``."""
    return vec.at[idx].set(vals)


@functools.partial(jax.jit, static_argnums=(0, 1))
def _scatter_codes(b: int, d: int, codes_grid, wgrid, pos, words, width_i):
    """Unpack one width's cold rows and scatter the integer codes (and the
    width id) into the dense grids. Out-of-bounds positions (the pow-2
    padding of ``prefetch_cold``) are dropped by jax scatter semantics."""
    codes = packing.unpack_codes(words, b, d)
    return (codes_grid.at[pos].set(codes),
            wgrid.at[pos].set(jnp.int32(width_i)))


def tiered_hot_lookup(hot, bits, d: int, ids: jnp.ndarray) -> jnp.ndarray:
    """Device-local gather from a hot tier: ids (any int shape) ->
    (*ids.shape, d) fp32, **zeros at cold positions**.

    Mirrors ``core.inference.packed_lookup`` bucket by bucket (same unpack
    shifts, same dequant expression) but reads the hot subtables and masks on
    the tier bit as well as the width bucket. Pure jnp + static shapes: safe
    to close over in a jitted serve cell, shards under
    ``tiered_hot_pspecs``.
    """
    flat = ids.reshape(-1)
    widx = jnp.take(hot["width_idx"], flat, axis=0)
    lidx = jnp.take(hot["tier_local"], flat, axis=0)
    is_hot = jnp.take(hot["is_hot"], flat, axis=0)
    out = jnp.zeros((flat.shape[0], d), jnp.float32)
    for i, b in enumerate(bits):
        if b == 0:
            continue
        sub = hot["subtables"][f"b{b}"]
        words = jnp.take(sub, jnp.clip(lidx, 0, sub.shape[0] - 1), axis=0)
        codes = packing.unpack_codes(words, b, d)
        deq = dequantize_codes(codes, hot["alpha"][i], hot["beta"])
        out = jnp.where((is_hot & (widx == i))[:, None], deq, out)
    return out.reshape(*ids.shape, d)


def tiered_hot_lookup_fn(bits, d: int):
    """``tiered_hot_lookup`` with the static metadata bound:
    ``(hot_tree, ids) -> embeddings``. Jit-stable the same way
    ``core.inference.packed_lookup_fn`` is."""
    bits = tuple(bits)
    return lambda hot, ids: tiered_hot_lookup(hot, bits, d, ids)
