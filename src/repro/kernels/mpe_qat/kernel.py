"""Fused expectation-over-bit-widths QAT kernel (paper Eq. 9) + its backward.

The naive formulation runs the LSQ+ quantizer m=7 times over the gathered
rows — 7 HBM round-trips on a memory-bound op. This kernel keeps a
(TILE_B, d) row block resident in VMEM and unrolls the (static) width list in
registers: one HBM read, one write, regardless of m.

Backward fuses all four gradient terms of Eq. (9) — ∂rows (Eq. 4 per width,
p-weighted), ∂probs (= Q_i(e)·g reduced over d), ∂α (Eq. 5 reduced over the
whole tile grid) and ∂β (Eq. 6, likewise) — in a single pass over the same
block, accumulating the shared-parameter grads across grid steps in a
revisited output block.

Tile geometry: TILE_B = 256 rows keeps (rows + g + out + per-width temps)
≈ 256·d·4·4 B ≤ 1 MiB for d ≤ 256 — well inside the ~16 MiB v5e VMEM, and
d is lane-aligned (pad d to 128 upstream for peak VPU utilization; correctness
does not require it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quantizer import int_bounds

TILE_B = 256


def _fwd_kernel(rows_ref, probs_ref, alpha_ref, beta_ref, out_ref, *, bits):
    rows = rows_ref[...]                       # (T, d)
    probs = probs_ref[...]                     # (T, m)
    beta = beta_ref[...]                       # (1, d)
    acc = jnp.zeros_like(rows)
    for i, b in enumerate(bits):
        if b == 0:
            continue
        n_b, p_b = int_bounds(b)
        alpha = alpha_ref[0, i]
        v = (rows - beta) / alpha
        codes = jnp.clip(jnp.round(v), n_b, p_b)
        acc = acc + probs[:, i:i + 1] * (alpha * codes + beta)
    out_ref[...] = acc


def _bwd_kernel(rows_ref, probs_ref, alpha_ref, beta_ref, g_ref,
                drows_ref, dprobs_ref, dalpha_ref, dbeta_ref, *, bits):
    rows = rows_ref[...]
    probs = probs_ref[...]
    beta = beta_ref[...]
    g = g_ref[...]

    first = pl.program_id(0) == 0

    @pl.when(first)
    def _init():
        dalpha_ref[...] = jnp.zeros_like(dalpha_ref)
        dbeta_ref[...] = jnp.zeros_like(dbeta_ref)

    drows = jnp.zeros_like(rows)
    dprobs_cols = []
    dalpha_acc = []
    dbeta_acc = jnp.zeros_like(beta)
    for i, b in enumerate(bits):
        if b == 0:
            dprobs_cols.append(jnp.zeros_like(probs[:, :1]))
            dalpha_acc.append(jnp.zeros((1, 1), jnp.float32))
            continue
        n_b, p_b = int_bounds(b)
        alpha = alpha_ref[0, i]
        p_i = probs[:, i:i + 1]
        v = (rows - beta) / alpha
        codes = jnp.clip(jnp.round(v), n_b, p_b)
        q = alpha * codes + beta
        inside = (v > n_b) & (v < p_b)
        # ∂probs_i = <g, Q_i> per row
        dprobs_cols.append(jnp.sum(g * q, axis=1, keepdims=True))
        # ∂rows += p_i · 1[inside] · g                      (Eq. 4)
        drows = drows + p_i * jnp.where(inside, g, 0.0)
        # ∂α_i = Σ p_i · g · (N_b | codes - v | P_b)        (Eq. 5)
        dq_da = jnp.where(v <= n_b, float(n_b),
                          jnp.where(v >= p_b, float(p_b), codes - v))
        dalpha_acc.append(jnp.sum(p_i * g * dq_da).reshape(1, 1))
        # ∂β += p_i · g · 1[outside]                        (Eq. 6)
        dbeta_acc = dbeta_acc + jnp.sum(p_i * jnp.where(inside, 0.0, g),
                                        axis=0, keepdims=True)
    drows_ref[...] = drows
    dprobs_ref[...] = jnp.concatenate(dprobs_cols, axis=1)
    dalpha_ref[...] += jnp.concatenate(dalpha_acc, axis=1)   # (1, m) revisited
    dbeta_ref[...] += dbeta_acc                              # (1, d) revisited


def _pad(x, tile):
    b = x.shape[0]
    rem = (-b) % tile
    if rem:
        x = jnp.concatenate([x, jnp.zeros((rem, *x.shape[1:]), x.dtype)], axis=0)
    return x


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def mixed_expectation_fwd(rows, probs, alpha, beta, *, bits, interpret=True):
    b0, d = rows.shape
    m = len(bits)
    rows_p, probs_p = _pad(rows, TILE_B), _pad(probs, TILE_B)
    n_tiles = rows_p.shape[0] // TILE_B
    out = pl.pallas_call(
        functools.partial(_fwd_kernel, bits=bits),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((TILE_B, d), lambda i: (i, 0)),
            pl.BlockSpec((TILE_B, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_B, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(rows_p.shape, jnp.float32),
        interpret=interpret,
    )(rows_p, probs_p, alpha.reshape(1, m), beta.reshape(1, d))
    return out[:b0]


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def mixed_expectation_bwd(rows, probs, alpha, beta, g, *, bits, interpret=True):
    b0, d = rows.shape
    m = len(bits)
    rows_p, probs_p, g_p = _pad(rows, TILE_B), _pad(probs, TILE_B), _pad(g, TILE_B)
    n_tiles = rows_p.shape[0] // TILE_B
    drows, dprobs, dalpha, dbeta = pl.pallas_call(
        functools.partial(_bwd_kernel, bits=bits),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((TILE_B, d), lambda i: (i, 0)),
            pl.BlockSpec((TILE_B, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((TILE_B, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_B, d), lambda i: (i, 0)),
            pl.BlockSpec((TILE_B, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),   # revisited: accumulates
            pl.BlockSpec((1, d), lambda i: (0, 0)),   # revisited: accumulates
        ],
        out_shape=[
            jax.ShapeDtypeStruct(rows_p.shape, jnp.float32),
            jax.ShapeDtypeStruct(probs_p.shape, jnp.float32),
            jax.ShapeDtypeStruct((1, m), jnp.float32),
            jax.ShapeDtypeStruct((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(rows_p, probs_p, alpha.reshape(1, m), beta.reshape(1, d), g_p)
    return drows[:b0], dprobs[:b0], dalpha.reshape(m), dbeta.reshape(d)
