"""Pure-jnp oracle for the fused QAT kernel: composition of the paper's
LSQ+ quantizer (with its custom STE vjp) and the Eq. 9 mixture."""
from __future__ import annotations

from repro.core.quantizer import mixed_expectation


def mixed_expectation_ref(rows, probs, alpha, beta, *, bits):
    return mixed_expectation(rows, probs, alpha, beta, bits)
