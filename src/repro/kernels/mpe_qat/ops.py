"""jit'd public wrapper with custom_vjp: forward and backward both run the
fused Pallas kernels, so QAT training takes one HBM round-trip per direction
instead of m=7."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mpe_qat.kernel import (mixed_expectation_bwd,
                                          mixed_expectation_fwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def mixed_expectation_kernel(rows, probs, alpha, beta, bits, interpret=True):
    return mixed_expectation_fwd(rows, probs, alpha, beta, bits=bits,
                                 interpret=interpret)


def _fwd(rows, probs, alpha, beta, bits, interpret):
    out = mixed_expectation_fwd(rows, probs, alpha, beta, bits=bits,
                                interpret=interpret)
    return out, (rows, probs, alpha, beta)


def _bwd(bits, interpret, res, g):
    rows, probs, alpha, beta = res
    drows, dprobs, dalpha, dbeta = mixed_expectation_bwd(
        rows, probs, alpha, beta, g, bits=bits, interpret=interpret)
    return drows, dprobs, dalpha, dbeta


mixed_expectation_kernel.defvjp(_fwd, _bwd)


def mixed_expectation_kernel_sharded(rows, probs, alpha, beta, bits, *,
                                     mesh=None, interpret: bool = True):
    """Forward Eq. (9) under ``shard_map``: rows split over every mesh axis
    (row-parallel, collective-free, bit-exact), padded up to the device
    count and unpadded after. Falls back to the fused kernel when no
    multi-device mesh is active (see ``repro.dist.shard``)."""
    from repro.dist.shard import sharded_mixed_expectation
    return sharded_mixed_expectation(rows, probs, alpha, beta, bits,
                                     mesh=mesh, interpret=interpret)
