from repro.kernels.mpe_qat.ops import mixed_expectation_kernel
from repro.kernels.mpe_qat.ref import mixed_expectation_ref

__all__ = ["mixed_expectation_kernel", "mixed_expectation_ref"]
