"""Fused multi-hot embedding bag: gather + masked segment-sum in one pass.

JAX has no nn.EmbeddingBag; the jnp formulation materializes the (B, L, d)
gathered tensor in HBM before reducing. This kernel never does: the grid is
(B, L) with L innermost, each step DMAs one table row (scalar-prefetched id)
into VMEM and accumulates into the bag's (1, d) output block, which Pallas
keeps resident across the L revisits. HBM traffic drops from
B·L·d·(read+write) + B·d to B·L·d reads + B·d writes — and the row DMA for
(i, j+1) overlaps the accumulate of (i, j) via the automatic pipeline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(idx_ref, row_ref, mask_ref, out_ref):
    del idx_ref
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += row_ref[...] * mask_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag_pallas(table: jnp.ndarray, ids: jnp.ndarray,
                         mask: jnp.ndarray, *, interpret: bool = True):
    """table: (N, d); ids, mask: (B, L) -> (B, d) masked sum per bag."""
    bsz, l = ids.shape
    d = table.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bsz, l),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j, idx_ref: (idx_ref[i * l + j], 0)),
            pl.BlockSpec((1, 1), lambda i, j, idx_ref: (i * l + j, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, j, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _bag_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, d), table.dtype),
        interpret=interpret,
    )(ids.reshape(-1).astype(jnp.int32), table,
      mask.reshape(-1, 1).astype(table.dtype))
