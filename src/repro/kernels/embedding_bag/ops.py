"""jit'd wrapper with custom_vjp: backward is the (sparse) scatter of the bag
cotangent into the touched rows — expressed with segment_sum (itself the
TPU-native scatter) since the kernel's forward never materializes (B, L, d)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.embedding_bag.kernel import embedding_bag_pallas


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def embedding_bag_kernel(table, ids, mask, interpret=True):
    return embedding_bag_pallas(table, ids, mask, interpret=interpret)


def _fwd(table, ids, mask, interpret):
    out = embedding_bag_pallas(table, ids, mask, interpret=interpret)
    return out, (table.shape, ids, mask)


def _bwd(interpret, res, g):
    table_shape, ids, mask = res
    b, l = ids.shape
    # d_table[row] += mask * g[bag] for every (bag, slot) pointing at row
    flat_ids = ids.reshape(-1)
    contrib = (g[:, None, :] * mask[..., None].astype(g.dtype)).reshape(b * l, -1)
    d_table = jax.ops.segment_sum(contrib, flat_ids,
                                  num_segments=table_shape[0])
    return d_table.astype(g.dtype), None, None


embedding_bag_kernel.defvjp(_fwd, _bwd)


def embedding_bag_kernel_sharded(table, ids, mask, *, rows_axes=("model",),
                                 mesh=None, interpret: bool = True):
    """Differentiable bag under ``shard_map``: table rows over ``rows_axes``,
    bags over the data axes, partial sums psum-merged; the backward pass is
    a ``custom_vjp`` that segment-sums each device's owned cotangent rows
    locally (no dense-gradient collective over the row axis). Tolerance
    ~1e-6 vs the single-device kernel when the rows really split (the psum
    reassociates the bag sum — pinned by
    ``tests/test_shard_a2a.py::test_embedding_bag_psum_tolerance``); falls
    back to the kernel when no multi-device mesh is active (see
    ``repro.dist.shard``)."""
    from repro.dist.shard import sharded_embedding_bag
    return sharded_embedding_bag(table, ids, mask, rows_axes=rows_axes,
                                 mesh=mesh, interpret=interpret)
