"""Pure-jnp oracle for the embedding-bag kernel."""
from __future__ import annotations

from repro.embeddings.bag import embedding_bag


def embedding_bag_ref(table, ids, mask):
    return embedding_bag(table, ids, mask, combine="sum")
