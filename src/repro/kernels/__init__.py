"""Pallas TPU kernels for the paper's embedding hot paths.

Each kernel package ships:
  kernel.py — pl.pallas_call with explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (+ custom_vjp where trainable)
  ref.py    — pure-jnp oracle; tests assert_allclose against it

Validated with interpret=True on CPU (the container has no TPU); BlockSpecs
are chosen for v5e VMEM/VREG geometry — see DESIGN.md §6.
"""
from repro.kernels.mpe_lookup.ops import (packed_lookup_kernel,
                                           packed_lookup_kernel_sharded)
from repro.kernels.mpe_qat.ops import (mixed_expectation_kernel,
                                        mixed_expectation_kernel_sharded)
from repro.kernels.embedding_bag.ops import (embedding_bag_kernel,
                                             embedding_bag_kernel_sharded)
from repro.kernels.flash_attention.ops import (flash_attention_kernel,
                                               flash_attention_kernel_sharded)

__all__ = ["packed_lookup_kernel", "mixed_expectation_kernel",
           "embedding_bag_kernel", "flash_attention_kernel",
           "packed_lookup_kernel_sharded", "mixed_expectation_kernel_sharded",
           "embedding_bag_kernel_sharded", "flash_attention_kernel_sharded"]
