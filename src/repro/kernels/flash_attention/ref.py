"""Pure-jnp oracle: exact softmax attention over flattened (BH, S, hd)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True):
    bh, s, hd = q.shape
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * hd ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
