"""Public wrapper: multi-head causal attention through the flash kernels.

Accepts (B, S, H, hd) (GQA handled by pre-expanding KV, as the §Perf-tuned
chunked path does) and flattens to the kernels' (B·H, S, hd) layout. Fully
differentiable: custom_vjp runs the fused backward kernel (blockwise p
recomputation from the stored logsumexp — no score tensors in HBM in either
direction).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (flash_attention_bwd,
                                                  flash_attention_fwd_stats,
                                                  flash_attention_pallas)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_flat(q, k, v, causal, bq, bk, interpret):
    return flash_attention_pallas(q, k, v, causal=causal, bq=bq, bk=bk,
                                  interpret=interpret)


def _flash_flat_fwd(q, k, v, causal, bq, bk, interpret):
    o, lse = flash_attention_fwd_stats(q, k, v, causal=causal, bq=bq, bk=bk,
                                       interpret=interpret)
    return o, (q, k, v, o, lse)


def _flash_flat_bwd(causal, bq, bk, interpret, res, do):
    q, k, v, o, lse = res
    return flash_attention_bwd(q, k, v, o, lse, do, causal=causal, bq=bq,
                               bk=bk, interpret=interpret)


_flash_flat.defvjp(_flash_flat_fwd, _flash_flat_bwd)


def flash_attention_kernel(q, k, v, *, n_kv_heads: int | None = None,
                           causal: bool = True, bq: int = 128, bk: int = 128,
                           interpret: bool = True):
    """q: (B, S, Hq, hd); k,v: (B, S, Hkv, hd) -> (B, S, Hq, hd)."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    if hkv != hq:  # GQA: expand KV to query heads
        k = jnp.repeat(k, hq // hkv, axis=2)
        v = jnp.repeat(v, hq // hkv, axis=2)
    qf = jnp.moveaxis(q, 2, 1).reshape(b * hq, s, hd)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * hq, s, hd)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * hq, s, hd)
    of = _flash_flat(qf, kf, vf, causal, min(bq, s), min(bk, s), interpret)
    return jnp.moveaxis(of.reshape(b, hq, s, hd), 1, 2)


def flash_attention_kernel_sharded(q, k, v, *, n_kv_heads: int | None = None,
                                   causal: bool = True, bq: int = 128,
                                   bk: int = 128, head_axes=("model",),
                                   mesh=None, interpret: bool = True):
    """Flash attention under ``shard_map``: batch over the data axes, heads
    over ``head_axes`` — collective-free and bit-exact vs the single-device
    kernel, forward and backward (a ``custom_vjp`` reruns the kernel with
    logsumexp stats saved and drives the Pallas backward kernel under the
    same specs, so grads match the unsharded ``jax.value_and_grad`` exactly).
    Falls back to ``flash_attention_kernel`` when no multi-device mesh is
    active (see ``repro.dist.shard``)."""
    from repro.dist.shard import sharded_flash_attention
    return sharded_flash_attention(q, k, v, n_kv_heads=n_kv_heads,
                                   causal=causal, bq=bq, bk=bk,
                                   head_axes=head_axes, mesh=mesh,
                                   interpret=interpret)
