"""Flash-style fused causal attention forward (TPU Pallas).

The §Perf log (EXPERIMENTS.md, qwen cell) showed the remaining LM-train memory
term is the chunked-softmax score blocks crossing HBM between XLA fusions.
This kernel keeps them in VMEM: grid = (batch·heads, q_blocks, kv_blocks) with
the kv dimension innermost; the running (max, denom, accumulator) live in VMEM
scratch across the kv sweep and only the final normalized (BQ, hd) output
block is written — one HBM write per q block, zero score-block traffic.

BlockSpec geometry (v5e): q/o blocks (BQ=128, hd) and kv blocks (BK=128, hd)
are MXU-aligned for hd ∈ {64, 128}; VMEM per step ≈
(2·BQ·hd + 2·BK·hd + BQ·BK)·4 B ≤ 0.4 MiB — far under the ~16 MiB budget, so
the automatic double-buffering pipeline overlaps the next KV DMA with compute.

Causality is block-granular: fully-masked blocks contribute nothing (compute
skipped via pl.when), the diagonal block applies the element mask — the
causal-block-skipping optimization the chunked jnp path can't express.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, d_ref, *,
                  bq: int, bk: int, causal: bool, scale: float, n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        d_ref[...] = jnp.zeros_like(d_ref)

    run = ((ki * bk) <= (qi * bq + bq - 1)) if causal else True

    @pl.when(run)
    def _step():
        q = q_ref[0]                      # (BQ, hd)
        k = k_ref[0]                      # (BK, hd)
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        d_ref[...] = d_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(d_ref[...], 1e-30)).astype(o_ref.dtype)


def _flash_fwd_stats_kernel(q_ref, k_ref, v_ref, o_ref, l_ref,
                            acc_ref, m_ref, d_ref, *,
                            bq: int, bk: int, causal: bool, scale: float,
                            n_kv: int):
    """Forward that also emits the logsumexp rows (for the backward)."""
    _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, d_ref,
                  bq=bq, bk=bk, causal=causal, scale=scale, n_kv=n_kv)
    ki = pl.program_id(2)

    @pl.when(ki == n_kv - 1)
    def _emit_lse():
        l_ref[0] = (m_ref[...] +
                    jnp.log(jnp.maximum(d_ref[...], 1e-30)))[:, 0]


def _flash_bwd_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                      dq_ref, dk_ref, dv_ref, *, bq: int, bk: int,
                      causal: bool, scale: float, n_q: int):
    """Backward over the same tiling: grid (BH, kv_blocks, q_blocks).

    Recomputes p from (q, k, lse) blockwise — no stored score tensors.
    q_blocks is the inner sweep, so each dk/dv block stays VMEM-resident and
    accumulates consecutively; dq blocks are revisited once per kv block
    (re-fetched, read-modify-write) and initialized on the first kv block.
    """
    ki = pl.program_id(1)
    qi = pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_ref[0] = jnp.zeros_like(dk_ref[0])
        dv_ref[0] = jnp.zeros_like(dv_ref[0])

    @pl.when(ki == 0)
    def _init_dq():
        dq_ref[0] = jnp.zeros_like(dq_ref[0])

    run = ((ki * bk) <= (qi * bq + bq - 1)) if causal else True

    @pl.when(run)
    def _step():
        q = q_ref[0]
        k = k_ref[0]
        v = v_ref[0]
        do = do_ref[0]
        lse = lse_ref[0][:, None]                 # (BQ, 1)
        delta = delta_ref[0][:, None]             # (BQ, 1) = rowsum(do*o)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        p = jnp.exp(s - lse)                      # exact softmax via stored lse
        dv_ref[0] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                         preferred_element_type=jnp.float32
                                         ).astype(dv_ref.dtype)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta) * scale
        dk_ref[0] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                         preferred_element_type=jnp.float32
                                         ).astype(dk_ref.dtype)
        dq_ref[0] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                         preferred_element_type=jnp.float32
                                         ).astype(dq_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_fwd_stats(q, k, v, *, causal: bool = True, bq: int = 128,
                              bk: int = 128, interpret: bool = True):
    """Forward returning (o, lse) — the residuals the backward needs."""
    bh, s, hd = q.shape
    bq, bk = min(bq, s), min(bk, s)
    n_q, n_kv = s // bq, s // bk
    kern = functools.partial(_flash_fwd_stats_kernel, bq=bq, bk=bk,
                             causal=causal, scale=hd ** -0.5, n_kv=n_kv)
    return pl.pallas_call(
        kern,
        grid=(bh, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
            jax.ShapeDtypeStruct((bh, s), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_bwd(q, k, v, o, lse, do, *, causal: bool = True,
                        bq: int = 128, bk: int = 128, interpret: bool = True):
    """-> (dq, dk, dv). delta = rowsum(do ⊙ o) computed outside (cheap)."""
    bh, s, hd = q.shape
    bq, bk = min(bq, s), min(bk, s)
    n_q, n_kv = s // bq, s // bk
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    kern = functools.partial(_flash_bwd_kernel, bq=bq, bk=bk, causal=causal,
                             scale=hd ** -0.5, n_q=n_q)
    dq, dk, dv = pl.pallas_call(
        kern,
        grid=(bh, n_kv, n_q),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bq, hd), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, j, i: (b, i)),
            pl.BlockSpec((1, bq), lambda b, j, i: (b, i)),
        ],
        out_specs=[
            # dq revisited across the kv sweep (j) — accumulates
            pl.BlockSpec((1, bq, hd), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, hd), jnp.float32),
            jax.ShapeDtypeStruct((bh, s, hd), jnp.float32),
            jax.ShapeDtypeStruct((bh, s, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, bq: int = 128,
                           bk: int = 128, interpret: bool = True):
    """q,k,v: (BH, S, hd) flattened batch·heads -> (BH, S, hd)."""
    bh, s, hd = q.shape
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    n_q, n_kv = s // bq, s // bk
    scale = hd ** -0.5
    kern = functools.partial(_flash_kernel, bq=bq, bk=bk, causal=causal,
                             scale=scale, n_kv=n_kv)
    return pl.pallas_call(
        kern,
        grid=(bh, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),   # running accumulator
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running denominator
        ],
        interpret=interpret,
    )(q, k, v)
