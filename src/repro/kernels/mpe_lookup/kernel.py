"""Fused packed-embedding gather → bit-unpack → dequantize (paper §4).

One pallas_call per width bucket (the bit-width ``b`` is a compile-time
constant — buckets are static after sampling). The row index for each grid
step comes from scalar-prefetched ids, so the packed row's DMA is issued ahead
of compute (Pallas double-buffers the (1, W) row blocks automatically); unpack
is shift/mask arithmetic on 32-bit lanes, dequant an FMA with the per-width
step size and per-dimension offset, all in VMEM.

The unpack avoids in-kernel gathers (TPU lanes dislike them): each of the ≤12
packed words is broadcast against a (1, d) iota of bit offsets and the right
word is chosen with a select — a (W, d) mask-reduce that vectorizes on the
8×128 VPU. Captured constants are avoided (Pallas requirement); everything is
built from broadcasted_iota.

HBM traffic per row is ceil(d·b/32)·4 bytes instead of d·4 — the packed table
is the roofline win (memory-bound lookup: 32/b× fewer bytes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantizer import int_bounds


def _unpack_block(words, *, b: int, d: int, w: int):
    """words: (1, W) uint32 -> (1, d) int32 signed codes. No gathers."""
    bitpos = jax.lax.broadcasted_iota(jnp.int32, (1, d), 1) * b      # (1, d)
    w0 = bitpos // 32                                                # (1, d)
    off = (bitpos % 32).astype(jnp.uint32)
    straddle = (bitpos % 32) + b > 32
    shift_hi = jnp.clip(32 - (bitpos % 32), 0, 31).astype(jnp.uint32)
    w1 = jnp.minimum(w0 + 1, w - 1)

    word_ids = jax.lax.broadcasted_iota(jnp.int32, (w, d), 0)        # (W, d)
    wcol = jnp.broadcast_to(words.reshape(w, 1), (w, d))             # (W, d)
    lo_all = wcol >> jnp.broadcast_to(off, (w, d))
    hi_all = wcol << jnp.broadcast_to(shift_hi, (w, d))
    zero = jnp.zeros((w, d), jnp.uint32)
    lo = jnp.sum(jnp.where(word_ids == jnp.broadcast_to(w0, (w, d)),
                           lo_all, zero), axis=0, keepdims=True)     # (1, d)
    hi = jnp.sum(jnp.where(word_ids == jnp.broadcast_to(w1, (w, d)),
                           hi_all, zero), axis=0, keepdims=True)
    mask = jnp.uint32((1 << b) - 1)
    n_b, _ = int_bounds(b)
    u = jnp.where(straddle, lo | hi, lo) & mask
    return u.astype(jnp.int32) + n_b


def _lookup_kernel(idx_ref, words_ref, alpha_ref, beta_ref, out_ref, *,
                   b: int, d: int, w: int):
    del idx_ref  # consumed by the BlockSpec index_map
    codes = _unpack_block(words_ref[...], b=b, d=d, w=w)
    out_ref[...] = alpha_ref[0, 0] * codes.astype(jnp.float32) + beta_ref[...]


@functools.partial(jax.jit, static_argnames=("b", "d", "interpret"))
def packed_lookup_pallas(ids: jnp.ndarray, words: jnp.ndarray,
                         alpha: jnp.ndarray, beta: jnp.ndarray, *,
                         b: int, d: int, interpret: bool = True) -> jnp.ndarray:
    """ids: (B,) rows into the packed subtable ``words`` (N, W) -> (B, d)."""
    n_rows, w = words.shape
    bsz = ids.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, w), lambda i, idx_ref: (idx_ref[i], 0)),
            pl.BlockSpec((1, 1), lambda i, idx_ref: (0, 0)),
            pl.BlockSpec((1, d), lambda i, idx_ref: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, idx_ref: (i, 0)),
    )
    kern = functools.partial(_lookup_kernel, b=b, d=d, w=w)
    return pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, d), jnp.float32),
        interpret=interpret,
    )(ids.astype(jnp.int32), words, alpha.reshape(1, 1), beta.reshape(1, d))
