"""Pure-jnp oracle for the packed lookup kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import packing
from repro.core.quantizer import dequantize_codes


def packed_lookup_ref(ids: jnp.ndarray, words: jnp.ndarray, alpha, beta, *,
                      b: int, d: int) -> jnp.ndarray:
    rows = jnp.take(words, ids, axis=0)               # (B, W)
    codes = packing.unpack_codes(rows, b, d)          # (B, d)
    return dequantize_codes(codes, alpha, beta)
