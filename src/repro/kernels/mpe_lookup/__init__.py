from repro.kernels.mpe_lookup.ops import packed_lookup_kernel
from repro.kernels.mpe_lookup.ref import packed_lookup_ref

__all__ = ["packed_lookup_kernel", "packed_lookup_ref"]
