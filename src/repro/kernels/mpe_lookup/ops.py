"""Public wrapper: full mixed-precision table lookup through the Pallas path.

Composes the per-width bucket kernels exactly like
``repro.core.inference.packed_lookup`` composes the jnp reference: gather each
bucket's rows with the static-width kernel, then select by the row's width.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.mpe_lookup.kernel import packed_lookup_pallas


def packed_lookup_kernel_sharded(table, meta, ids: jnp.ndarray, *,
                                 rows_axes=("model",), mesh=None,
                                 interpret: bool = True,
                                 lookup_comms: str = "psum",
                                 bucket_capacity: int | None = None
                                 ) -> jnp.ndarray:
    """The fused lookup under ``shard_map``: subtables row-sharded over
    ``rows_axes`` of the active mesh, the per-bucket Pallas kernel gathering
    device-locally, one psum merging buckets — or, with
    ``lookup_comms="a2a"``, the capacity-bucketed all-to-all id shuffle that
    ships packed words instead of dequantized partials (bit-exact either
    way). Falls back to the single-device kernel path when no multi-device
    mesh is active (see ``repro.dist.shard``)."""
    from repro.dist.shard import sharded_packed_lookup
    return sharded_packed_lookup(table, meta, ids, rows_axes=rows_axes,
                                 mesh=mesh, use_kernel=True,
                                 interpret=interpret,
                                 lookup_comms=lookup_comms,
                                 bucket_capacity=bucket_capacity)


def packed_lookup_kernel(table, meta, ids: jnp.ndarray, *,
                         interpret: bool = True) -> jnp.ndarray:
    bits = meta["bits"]
    d = meta["d"]
    flat = ids.reshape(-1)
    widx = jnp.take(table["width_idx"], flat, axis=0)
    lidx = jnp.take(table["local_idx"], flat, axis=0)
    out = jnp.zeros((flat.shape[0], d), jnp.float32)
    for i, b in enumerate(bits):
        if b == 0:
            continue
        sub = table["subtables"][f"b{b}"]
        deq = packed_lookup_pallas(jnp.clip(lidx, 0, sub.shape[0] - 1), sub,
                                   table["alpha"][i], table["beta"],
                                   b=b, d=d, interpret=interpret)
        out = jnp.where((widx == i)[:, None], deq, out)
    return out.reshape(*ids.shape, d)
