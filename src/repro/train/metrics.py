"""Evaluation metrics: AUC (rank-based Mann-Whitney) and logloss.

AUC is computed jit-ably from sorted scores so it can run on-device over large
eval shards; ties are handled with average ranks (matches sklearn on CTR data).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def logloss(labels: jnp.ndarray, probs: jnp.ndarray, eps: float = 1e-7) -> jnp.ndarray:
    p = jnp.clip(probs, eps, 1 - eps)
    return -jnp.mean(labels * jnp.log(p) + (1 - labels) * jnp.log(1 - p))


def auc(labels: jnp.ndarray, scores: jnp.ndarray) -> jnp.ndarray:
    """Mann-Whitney U AUC with average-rank tie handling."""
    labels = labels.astype(jnp.float32).reshape(-1)
    scores = scores.astype(jnp.float32).reshape(-1)
    n = scores.shape[0]
    order = jnp.argsort(scores)
    sorted_scores = scores[order]
    sorted_labels = labels[order]
    ranks = jnp.arange(1, n + 1, dtype=jnp.float32)
    # average ranks for ties: group by unique score via segment mean
    is_new = jnp.concatenate([jnp.ones((1,), bool),
                              sorted_scores[1:] != sorted_scores[:-1]])
    group_id = jnp.cumsum(is_new) - 1
    group_sum = jax.ops.segment_sum(ranks, group_id, num_segments=n)
    group_cnt = jax.ops.segment_sum(jnp.ones_like(ranks), group_id, num_segments=n)
    avg_rank = (group_sum / jnp.maximum(group_cnt, 1.0))[group_id]
    n_pos = jnp.sum(sorted_labels)
    n_neg = n - n_pos
    sum_pos_ranks = jnp.sum(avg_rank * sorted_labels)
    u = sum_pos_ranks - n_pos * (n_pos + 1) / 2.0
    return jnp.where((n_pos == 0) | (n_neg == 0), 0.5, u / jnp.maximum(n_pos * n_neg, 1.0))


def binary_accuracy(labels, probs, threshold: float = 0.5):
    return jnp.mean((probs > threshold).astype(jnp.float32) == labels)
