from repro.train.optimizer import adam, sgd, clip_by_global_norm, chain_weight_decay
from repro.train.metrics import auc, logloss

__all__ = ["adam", "sgd", "clip_by_global_norm", "chain_weight_decay", "auc", "logloss"]
