"""Gradient compression for cross-pod all-reduce (DESIGN.md §5).

At 1000+ nodes the pod-to-pod DCN link is the thin pipe; int8 quantization
with error feedback [1-bit Adam lineage, arXiv:1606.06160 / arXiv:2102.02888]
cuts cross-pod gradient bytes 4× with provably-bounded bias: the residual of
each quantization is carried into the next step, so the compressed series
telescopes to the true gradient sum.

Embedding gradients are additionally row-sparse (only touched rows are
nonzero); ``rowsparse_compress`` ships (row_idx, values) instead of the dense
table — the natural format for MPE-scale tables.

The numerics here are exercised by unit tests and wired into the Trainer via
``grad_transform``; on real multi-pod hardware the same functions run inside a
shard_map over the "pod" axis around the DCN all-reduce.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_compress(g: jnp.ndarray, err: jnp.ndarray):
    """Quantize g+err to int8 with a per-tensor scale. Returns (q, scale, new_err)."""
    target = g + err
    scale = jnp.max(jnp.abs(target)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, target - deq


def int8_decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def make_error_feedback_transform():
    """Stateful grad transform: tree of residuals threaded by the caller.

        ef_state = init_error_feedback(grads_template)
        grads, ef_state = apply_error_feedback(grads, ef_state)
    """
    def init(grads_template):
        return jax.tree.map(jnp.zeros_like, grads_template)

    def apply(grads, ef_state):
        def one(g, e):
            q, s, new_e = int8_compress(g, e)
            return int8_decompress(q, s), new_e
        pairs = jax.tree.map(one, grads, ef_state)
        new_grads = jax.tree.map(lambda p: p[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
        new_state = jax.tree.map(lambda p: p[1], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return new_grads, new_state

    return init, apply


def rowsparse_compress(grad_table: jnp.ndarray, touched_rows: jnp.ndarray):
    """Embedding-table grads: ship only touched rows (idx, values)."""
    vals = jnp.take(grad_table, touched_rows, axis=0)
    return touched_rows, vals


def rowsparse_decompress(n_rows: int, idx: jnp.ndarray, vals: jnp.ndarray):
    out = jnp.zeros((n_rows, vals.shape[-1]), vals.dtype)
    return out.at[idx].add(vals)
