"""Generic fault-tolerant training loop.

Works with every model in the zoo through a uniform loss signature:

    loss_fn(params, buffers, state, batch, *, step) -> (loss, (new_state, metric))

Features (DESIGN.md §5):
  - jitted train step with grad clipping;
  - NaN/inf guard: non-finite grads skip the update (params/opt state kept);
  - checkpoint every N steps (atomic, keep-k, async), restore-on-start;
  - optional compressor post-update hook (ALPT grid projection);
  - optional int8 error-feedback gradient compression (cross-pod simulation);
  - deterministic restart: the data function is keyed by step.
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.train import checkpoint as ckpt
from repro.train.compression import make_error_feedback_transform
from repro.train.optimizer import apply_updates, clip_by_global_norm


class Trainer:
    def __init__(self, loss_fn: Callable, params, buffers, state, optimizer, *,
                 ckpt_dir: str | None = None, ckpt_every: int = 200,
                 ckpt_keep: int = 3, clip_norm: float = 10.0,
                 post_update: Callable | None = None,
                 grad_compression: bool = False, donate: bool = True,
                 mesh=None, table_rows_axes=("model",)):
        self.loss_fn = loss_fn
        self.buffers = buffers
        self.optimizer = optimizer
        self.ckpt_dir, self.ckpt_every, self.ckpt_keep = ckpt_dir, ckpt_every, ckpt_keep
        self.post_update = post_update
        self.step = 0
        opt_state = optimizer.init(params)
        ef_init, ef_apply = make_error_feedback_transform()
        self.grad_compression = grad_compression
        ef_state = ef_init(params) if grad_compression else None
        self.carry = {"params": params, "state": state, "opt": opt_state,
                      "ef": ef_state}

        # loss+grad: plain on one device; on a multi-device mesh the whole
        # thing runs inside shard_map — batch data-parallel over the mesh,
        # embedding-table rows sharded over `table_rows_axes` with
        # row-shard-local grads, replicated params pmean'd (repro.dist.shard)
        self.mesh = mesh
        if mesh is not None and getattr(mesh, "size", 1) > 1:
            from repro.dist.shard import sharded_value_and_grad
            value_and_grad = sharded_value_and_grad(
                self.loss_fn, mesh, rows_axes=table_rows_axes)
        else:
            def value_and_grad(params, buffers, state, batch, *, step):
                return jax.value_and_grad(self.loss_fn, has_aux=True)(
                    params, buffers, state, batch, step=step)

        def train_step(carry, batch, step):
            params, state, opt_state = carry["params"], carry["state"], carry["opt"]
            (loss, (new_state, metric)), grads = value_and_grad(
                params, self.buffers, state, batch, step=step)
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
            ef_state = carry["ef"]
            if self.grad_compression:
                grads, ef_state = ef_apply(grads, ef_state)
            updates, new_opt = self.optimizer.update(grads, opt_state, params)
            new_params = apply_updates(params, updates)
            # NaN guard: skip the whole update on non-finite grads
            ok = jnp.isfinite(gnorm) & jnp.isfinite(loss)
            new_params = jax.tree.map(lambda n, o: jnp.where(ok, n, o),
                                      new_params, params)
            new_opt = jax.tree.map(lambda n, o: jnp.where(ok, n, o),
                                   new_opt, opt_state)
            new_carry = {"params": new_params, "state": new_state,
                         "opt": new_opt, "ef": ef_state}
            return new_carry, {"loss": loss, "metric": metric,
                               "grad_norm": gnorm, "skipped": ~ok}

        self._train_step = jax.jit(train_step, donate_argnums=(0,) if donate else ())

    # -- fault tolerance ----------------------------------------------------
    def restore(self) -> bool:
        if self.ckpt_dir is None:
            return False
        tree, step = ckpt.restore(self.ckpt_dir, {"carry": _restorable(self.carry),
                                                  "step": 0})
        if tree is None:
            return False
        restored = tree["carry"]
        if self.carry.get("ef") is None:
            restored["ef"] = None
        self.carry = restored
        self.step = int(tree["step"])
        return True

    def save(self, blocking: bool = False):
        if self.ckpt_dir is None:
            return
        payload = {"carry": _restorable(self.carry), "step": self.step}
        if blocking:
            ckpt.save(self.ckpt_dir, self.step, payload, keep=self.ckpt_keep)
        else:
            ckpt.save_async(self.ckpt_dir, self.step, payload, keep=self.ckpt_keep)

    # -- main loop ------------------------------------------------------------
    def run(self, data_fn: Callable, n_steps: int, *, log_every: int = 100,
            log_fn=print, prefetch=False) -> dict:
        """Run up to ``n_steps``. ``prefetch`` stages each batch on device one
        step ahead of compute (``repro.cache.PrefetchPipeline`` — pass True
        for a default pipeline or a pre-built one), overlapping the
        host→device copy with the in-flight step's compute. Same bytes, same
        order: losses are step-identical to the synchronous loop."""
        if prefetch:
            from repro.cache.prefetch import PrefetchPipeline
            data_fn = (prefetch if isinstance(prefetch, PrefetchPipeline)
                       else PrefetchPipeline(data_fn))
        t0 = time.time()
        last = {}
        while self.step < n_steps:
            batch = data_fn(self.step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            self.carry, out = self._train_step(self.carry, batch,
                                               jnp.asarray(self.step))
            if self.post_update is not None:
                self.carry["params"] = self.post_update(self.carry["params"])
            self.step += 1
            if log_every and self.step % log_every == 0:
                last = {k: float(v) for k, v in out.items()}
                log_fn(f"step {self.step} loss {last['loss']:.5f} "
                       f"gnorm {last['grad_norm']:.3f} "
                       f"({(time.time()-t0)/self.step*1e3:.1f} ms/step)")
            if self.ckpt_dir and self.step % self.ckpt_every == 0:
                self.save()
        if self.ckpt_dir:
            self.save(blocking=True)
        return last

    @property
    def params(self):
        return self.carry["params"]

    @property
    def state(self):
        return self.carry["state"]


def _restorable(carry):
    """Drop None leaves (npz can't store them)."""
    return {k: v for k, v in carry.items() if v is not None}
