"""Checkpointing: atomic, versioned, keep-k — the fault-tolerance substrate.

Format: one .npz per checkpoint holding every leaf under a dotted path name
(no pickle — robust across refactors), written to a temp file then atomically
renamed so a crash mid-write never corrupts the latest checkpoint. Restore
picks the highest complete step. ``keep`` bounds disk usage.

At multi-pod scale each host writes its local shards; here (single host) the
full tree is written. The async wrapper offloads serialization to a thread so
the train loop never blocks on disk.
"""
from __future__ import annotations

import os
import re
import threading

import jax
import numpy as np

_LEAF_SEP = "|"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_LEAF_SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}[{i}]{_LEAF_SEP}"))
    else:
        out[prefix.rstrip(_LEAF_SEP)] = np.asarray(tree)
    return out


def _unflatten_into(template, flat):
    """Rebuild arrays into the *structure* of ``template``."""
    def rebuild(t, prefix):
        if isinstance(t, dict):
            return {k: rebuild(v, f"{prefix}{k}{_LEAF_SEP}") for k, v in t.items()}
        if isinstance(t, (list, tuple)):
            vals = [rebuild(v, f"{prefix}[{i}]{_LEAF_SEP}") for i, v in enumerate(t)]
            return type(t)(vals)
        key = prefix.rstrip(_LEAF_SEP)
        arr = flat[key]
        return jax.numpy.asarray(arr).astype(t.dtype) if hasattr(t, "dtype") else arr
    return rebuild(template, "")


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    # unique tmp name: concurrent saves of the same step (async + final
    # blocking save) must not collide before the atomic rename
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}_{os.getpid()}_{id(tree)}.npz")
    final = os.path.join(ckpt_dir, f"step_{step:010d}.npz")
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, final)  # atomic
    _gc(ckpt_dir, keep)
    return final


def save_async(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> threading.Thread:
    host_tree = jax.tree.map(np.asarray, tree)  # device->host copy now
    t = threading.Thread(target=save, args=(ckpt_dir, step, host_tree),
                         kwargs={"keep": keep}, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)\.npz", f))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, template, step: int | None = None):
    """Returns (tree, step) or (None, None) when no checkpoint exists."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        return None, None
    path = os.path.join(ckpt_dir, f"step_{step:010d}.npz")
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    return _unflatten_into(template, flat), step


def _gc(ckpt_dir: str, keep: int):
    files = sorted(f for f in os.listdir(ckpt_dir)
                   if re.fullmatch(r"step_\d+\.npz", f))
    for f in files[:-keep]:
        try:
            os.remove(os.path.join(ckpt_dir, f))
        except FileNotFoundError:
            pass  # concurrent GC from an async save already removed it
