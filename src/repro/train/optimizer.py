"""Optimizers (the environment has no optax — built here).

API mirrors optax's GradientTransformation so call-sites read familiarly:

    opt = adam(1e-3, weight_decay=3e-6)
    opt_state = opt.init(params)
    updates, opt_state = opt.update(grads, opt_state, params)
    params = apply_updates(params, updates)

Paper recipe (§5.1.5): Adam, lr=1e-3, weight decay in {0, 3e-6} depending on
the dataset.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class GradientTransformation(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, state)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0,
         moment_dtype=None) -> GradientTransformation:
    """Adam/AdamW. ``lr`` may be a float or a schedule fn(step)->float.

    Decoupled weight decay (AdamW-style); decay is skipped automatically for
    1-D leaves (biases / norm scales) following common practice.

    ``moment_dtype`` (§Perf, paper-aligned): store mu/nu in a reduced dtype
    (bf16). Halves optimizer-state memory and HBM traffic — what makes
    314B-param Adam fit 256×16 GB chips, and cuts the per-step moment
    read/write for 10⁷–10⁹-row embedding tables. Update math stays fp32.
    """
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def _stored(x):
        return x.astype(moment_dtype) if (moment_dtype is not None and
                                          jnp.issubdtype(x.dtype, jnp.floating)) \
            else x

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(lambda p: _stored(jnp.zeros_like(p)), params),
            "nu": jax.tree.map(lambda p: _stored(jnp.zeros_like(p)), params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        mu = jax.tree.map(
            lambda m, g: _stored(b1 * m.astype(jnp.float32)
                                 + (1 - b1) * g.astype(jnp.float32)),
            state["mu"], grads)
        nu = jax.tree.map(
            lambda v, g: _stored(b2 * v.astype(jnp.float32)
                                 + (1 - b2) * jnp.square(g.astype(jnp.float32))),
            state["nu"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def _upd(m, v, p):
            m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
            u = -lr_t * (m32 / bc1) / (jnp.sqrt(v32 / bc2) + eps)
            if weight_decay and p.ndim > 1:
                u = u - lr_t * weight_decay * p
            return u

        updates = jax.tree.map(_upd, mu, nu, params)
        return updates, {"step": step, "mu": mu, "nu": nu}

    return GradientTransformation(init, update)


def sgd(lr, momentum: float = 0.0) -> GradientTransformation:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            state["mom"] = jax.tree.map(jnp.zeros_like, params)
        return state

    def update(grads, state, params):
        del params
        step = state["step"] + 1
        lr_t = lr_fn(step)
        if momentum:
            mom = jax.tree.map(lambda m, g: momentum * m + g, state["mom"], grads)
            updates = jax.tree.map(lambda m: -lr_t * m, mom)
            return updates, {"step": step, "mom": mom}
        updates = jax.tree.map(lambda g: -lr_t * g, grads)
        return updates, {"step": step}

    return GradientTransformation(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def clip_by_global_norm(grads, max_norm: float):
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)]
    gnorm = jnp.sqrt(jnp.sum(jnp.stack(leaves)))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def chain_weight_decay(grads, params, wd: float):
    """L2 (coupled) weight decay added to grads, matrices only."""
    return jax.tree.map(
        lambda g, p: g + wd * p if p.ndim > 1 else g, grads, params)


def warmup_cosine(base_lr: float, warmup: int, total: int, floor: float = 0.0):
    def fn(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = base_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (base_lr - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return fn
