"""Synthetic token streams for the LM archs.

Zipf-distributed unigrams (matching real vocab statistics — the property MPE's
frequency grouping exploits on token embeddings) with a hashed bigram kernel
so next-token prediction has learnable structure beyond unigram frequency.
"""
from __future__ import annotations

import numpy as np


class TokenStream:
    def __init__(self, vocab: int, batch: int, seq_len: int,
                 zipf_exponent: float = 1.05, seed: int = 0):
        self.vocab, self.batch, self.seq_len = vocab, batch, seq_len
        self.seed = seed
        p = np.arange(1, vocab + 1, dtype=np.float64) ** (-zipf_exponent)
        self.cdf = np.cumsum(p / p.sum())

    def expected_frequencies(self) -> np.ndarray:
        return np.diff(self.cdf, prepend=0.0)

    def batch_at(self, step: int, host_id: int = 0, n_hosts: int = 1) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, host_id, n_hosts]))
        toks = np.empty((self.batch, self.seq_len + 1), np.int64)
        toks[:, 0] = np.searchsorted(self.cdf, rng.random(self.batch))
        for t in range(self.seq_len):
            # bigram kernel: with p=0.5 the next token is a hash of the current
            fresh = np.searchsorted(self.cdf, rng.random(self.batch))
            chained = (toks[:, t] * 2654435761 + 12345) % self.vocab
            use_chain = rng.random(self.batch) < 0.5
            toks[:, t + 1] = np.where(use_chain, chained, fresh)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}
