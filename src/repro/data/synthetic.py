"""Synthetic CTR data with a planted logistic ground truth.

Criteo/Avazu/KDD12 are not redistributable inside the container, so the data
layer generates streams matching their *statistics* (DESIGN.md §8):

  - per-field Zipf(exponent) popularity — CTR feature histograms are Zipfian;
  - per-feature latent weights drawn from a hash (no giant tables
    materialized): w(id) ~ N(0, σ·decay(rank)) where rare features carry
    noisier/weaker signal — the property MPE exploits (frequent ⇒ important);
  - a few planted pairwise interactions so DCN/DeepFM/IPNN beat DNN;
  - bias calibrated to the requested positive ratio.

Batches are pure functions of (seed, step, host_id, n_hosts): restarted or
re-scaled jobs re-shard the stream deterministically (elastic data sharding).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class CTRSpec(NamedTuple):
    field_vocabs: tuple            # per-field vocabulary sizes
    batch_size: int = 1024
    zipf_exponent: float = 1.1
    positive_logit_bias: float = -1.1   # ≈25% positive (Criteo-like)
    signal_scale: float = 0.8
    rare_decay: float = 0.25       # signal std multiplier at the rarest rank
    n_pairs: int = 4               # planted field-pair interactions
    seed: int = 0


def _hash_normal(ids: np.ndarray, salt: int) -> np.ndarray:
    """Deterministic per-id standard normal via splitmix64 + Box-Muller."""
    salt_mix = np.uint64((salt * 0xBF58476D1CE4E5B9 + 0x94D049BB133111EB) % (1 << 64))
    x = ids.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15) + salt_mix
    x ^= x >> np.uint64(30); x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27); x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(31)
    u1 = (x >> np.uint64(11)).astype(np.float64) * (1.0 / (1 << 53))
    y = x * np.uint64(0xD6E8FEB86659FD93)
    y ^= y >> np.uint64(32)
    u2 = (y >> np.uint64(11)).astype(np.float64) * (1.0 / (1 << 53))
    return np.sqrt(-2.0 * np.log(np.clip(u1, 1e-12, 1.0))) * np.cos(2 * np.pi * u2)


class SyntheticCTR:
    def __init__(self, spec: CTRSpec):
        self.spec = spec
        self.n_fields = len(spec.field_vocabs)
        self.offsets = np.concatenate(
            [[0], np.cumsum(spec.field_vocabs)[:-1]]).astype(np.int64)
        self.total_vocab = int(sum(spec.field_vocabs))
        rng = np.random.default_rng(spec.seed)
        # planted interactions between random field pairs
        self.pairs = [tuple(rng.choice(self.n_fields, 2, replace=False))
                      for _ in range(spec.n_pairs)]
        # per-field Zipf CDF for popularity-ranked local ids
        self._cdfs = []
        for v in spec.field_vocabs:
            p = np.arange(1, v + 1, dtype=np.float64) ** (-spec.zipf_exponent)
            p /= p.sum()
            self._cdfs.append(np.cumsum(p))

    # -- frequency prior ---------------------------------------------------
    def expected_frequencies(self) -> np.ndarray:
        """Expected per-(global)feature access probability — MPE's prior."""
        out = np.empty((self.total_vocab,), np.float64)
        for f, v in enumerate(self.spec.field_vocabs):
            pdf = np.diff(self._cdfs[f], prepend=0.0)
            out[self.offsets[f]:self.offsets[f] + v] = pdf
        return out

    # -- latent ground truth ------------------------------------------------
    def _weight(self, gids: np.ndarray, local_rank: np.ndarray,
                vocab: np.ndarray, salt: int) -> np.ndarray:
        """Rank-dependent signal: frequent features carry cleaner weight."""
        s = self.spec
        frac = local_rank.astype(np.float64) / np.maximum(vocab - 1, 1)
        scale = s.signal_scale * (1.0 - (1.0 - s.rare_decay) * np.sqrt(frac))
        return _hash_normal(gids, salt) * scale

    def true_logit(self, ids: np.ndarray) -> np.ndarray:
        """ids: (B, F) popularity-ranked local ids -> (B,) ground-truth logit."""
        s = self.spec
        gids = ids.astype(np.int64) + self.offsets[None, :]
        vocab = np.asarray(s.field_vocabs, np.int64)[None, :]
        z = self._weight(gids, ids, vocab, salt=1).sum(axis=1)
        for pi, (a, b) in enumerate(self.pairs):
            z = z + (self._weight(gids[:, a], ids[:, a], vocab[:, a], salt=10 + pi)
                     * self._weight(gids[:, b], ids[:, b], vocab[:, b], salt=20 + pi))
        return z + s.positive_logit_bias

    # -- streaming ----------------------------------------------------------
    def batch(self, step: int, host_id: int = 0, n_hosts: int = 1) -> dict:
        s = self.spec
        rng = np.random.default_rng(
            np.random.SeedSequence([s.seed, step, host_id, n_hosts]))
        ids = np.empty((s.batch_size, self.n_fields), np.int64)
        for f in range(self.n_fields):
            u = rng.random(s.batch_size)
            ids[:, f] = np.searchsorted(self._cdfs[f], u)
        z = self.true_logit(ids)
        label = (rng.random(s.batch_size) < 1.0 / (1.0 + np.exp(-z))).astype(np.int32)
        return {"ids": ids.astype(np.int32), "label": label}

    def eval_set(self, n_batches: int, start_step: int = 1_000_000):
        return [self.batch(start_step + i) for i in range(n_batches)]


class DriftingCTR(SyntheticCTR):
    """Non-stationary power-law-with-drift request stream.

    Ids are drawn from the same per-field Zipf popularity ranks as
    ``SyntheticCTR``, then **rotated** within each field's vocabulary by a
    step-dependent offset:

        id = (zipf_rank_draw + offset_f(step)) mod vocab_f
        offset_f(step) = floor(drift_rate · step)
                         + (floor(shift_frac · vocab_f) if step ≥ shift_at)

    so the marginal distribution stays exactly power-law at every step while
    *which* features are popular drifts continuously (``drift_rate`` ids per
    step) and/or jumps wholesale at ``shift_at`` (a popularity shift moving
    the hot set by ``shift_frac`` of each vocabulary). The training-time
    frequency prior (``expected_frequencies``) describes step 0, so a static
    hot/cold split seeded from it decays as the stream drifts — the workload
    the traffic-adaptive tier policy (``repro.cache.policy``) exists for.

    Batches stay pure functions of (seed, step, host_id, n_hosts): the same
    construction replays the same drift trajectory exactly.
    """

    def __init__(self, spec: CTRSpec, *, drift_rate: float = 0.0,
                 shift_at: int | None = None, shift_frac: float = 0.3,
                 step0: int = 0):
        super().__init__(spec)
        self.drift_rate = float(drift_rate)
        self.shift_at = None if shift_at is None else int(shift_at)
        self.shift_frac = float(shift_frac)
        self.step0 = int(step0)     # drift clock zero (serving streams often
        # start at a large step to stay disjoint from training batches)

    def field_offset(self, field: int, step: int) -> int:
        """The rotation applied to ``field``'s ids at ``step``."""
        v = int(self.spec.field_vocabs[field])
        t = max(step - self.step0, 0)
        off = int(np.floor(self.drift_rate * t))
        if self.shift_at is not None and t >= self.shift_at:
            off += int(self.shift_frac * v)
        return off % v

    def batch(self, step: int, host_id: int = 0, n_hosts: int = 1) -> dict:
        s = self.spec
        rng = np.random.default_rng(
            np.random.SeedSequence([s.seed, step, host_id, n_hosts]))
        ids = np.empty((s.batch_size, self.n_fields), np.int64)
        for f in range(self.n_fields):
            u = rng.random(s.batch_size)
            v = int(s.field_vocabs[f])
            ids[:, f] = (np.searchsorted(self._cdfs[f], u)
                         + self.field_offset(f, step)) % v
        z = self.true_logit(ids)
        label = (rng.random(s.batch_size)
                 < 1.0 / (1.0 + np.exp(-z))).astype(np.int32)
        return {"ids": ids.astype(np.int32), "label": label}
