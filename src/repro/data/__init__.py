from repro.data.synthetic import SyntheticCTR, CTRSpec
from repro.data.graphs import (make_sbm_graph, make_molecule_batch, CSRGraph,
                               NeighborSampler)
from repro.data.tokens import TokenStream
from repro.data.loader import Prefetcher

__all__ = ["SyntheticCTR", "CTRSpec", "make_sbm_graph", "make_molecule_batch",
           "CSRGraph", "NeighborSampler", "TokenStream", "Prefetcher"]
