"""Streaming loader for the real Criteo TSV format (deployment path).

The public Criteo Display Advertising Challenge file is TSV:
    label \\t I1..I13 (ints, may be empty) \\t C1..C26 (32-bit hex, may be empty)

This loader applies the paper's §5.1.1 preprocessing exactly:
  - numeric x -> floor(log²(x)) for x > 2 else 1 (discretized to categorical);
  - missing values -> a per-field sentinel id;
  - features seen once -> OOV (approximated streaming via a min-count filter
    built on a first counting pass, or a user-provided vocab);
  - each of the 39 resulting categorical fields gets its own id space.

Usage:
    vocabs, counts = build_criteo_vocab("train.txt", min_count=2)
    ds = CriteoTSV("train.txt", vocabs, batch_size=10_000)
    for step, batch in enumerate(ds):   # {"ids": (B, 39) int32, "label": (B,)}
        ...

The synthetic generator (data/synthetic.py) remains the in-container default;
this module is exercised by tests on a generated mini-TSV fixture.
"""
from __future__ import annotations

import math
from collections import defaultdict

import numpy as np

N_INT, N_CAT = 13, 26
N_FIELDS = N_INT + N_CAT


def _discretize(raw: str) -> str:
    """Paper §5.1.1: x -> floor(log²(x)) for x>2 else 1; '' -> missing."""
    if raw == "":
        return "<missing>"
    x = int(raw)
    if x <= 2:
        return "1"
    return str(int(math.floor(math.log(x) ** 2)))


def _row_tokens(line: str):
    parts = line.rstrip("\n").split("\t")
    label = int(parts[0])
    toks = []
    for i in range(N_INT):
        raw = parts[1 + i] if 1 + i < len(parts) else ""
        toks.append(_discretize(raw))
    for c in range(N_CAT):
        raw = parts[1 + N_INT + c] if 1 + N_INT + c < len(parts) else ""
        toks.append(raw if raw else "<missing>")
    return label, toks


def build_criteo_vocab(path: str, min_count: int = 2, max_rows: int | None = None):
    """First pass: per-field token counts -> vocab dicts (token -> local id).

    Tokens below ``min_count`` map to the field's OOV id (paper: features
    appearing once are replaced by OOV). id 0 is OOV for every field.
    """
    counts = [defaultdict(int) for _ in range(N_FIELDS)]
    with open(path) as f:
        for n, line in enumerate(f):
            if max_rows is not None and n >= max_rows:
                break
            _, toks = _row_tokens(line)
            for fi, t in enumerate(toks):
                counts[fi][t] += 1
    vocabs = []
    for fi in range(N_FIELDS):
        vocab = {"<oov>": 0}
        for tok, c in sorted(counts[fi].items(), key=lambda kv: -kv[1]):
            if c >= min_count:
                vocab[tok] = len(vocab)
        vocabs.append(vocab)
    return vocabs, counts


def vocab_sizes(vocabs) -> tuple:
    return tuple(len(v) for v in vocabs)


def frequencies_from_counts(vocabs, counts) -> np.ndarray:
    """Global per-feature frequency vector aligned with the offsets layout —
    MPE's grouping prior, from the same counting pass."""
    sizes = vocab_sizes(vocabs)
    out = np.zeros((sum(sizes),), np.float64)
    offset = 0
    for fi, vocab in enumerate(vocabs):
        for tok, lid in vocab.items():
            out[offset + lid] = counts[fi].get(tok, 1)
        # OOV absorbs the filtered tail
        tail = sum(c for t, c in counts[fi].items() if t not in vocab)
        out[offset] = max(tail, 1)
        offset += sizes[fi]
    return out


class CriteoTSV:
    """Second pass: stream batches of globalizable local ids."""

    def __init__(self, path: str, vocabs, batch_size: int = 10_000,
                 loop: bool = False):
        self.path, self.vocabs, self.batch_size = path, vocabs, batch_size
        self.loop = loop

    def __iter__(self):
        while True:
            with open(self.path) as f:
                ids = np.zeros((self.batch_size, N_FIELDS), np.int32)
                labels = np.zeros((self.batch_size,), np.int32)
                fill = 0
                for line in f:
                    label, toks = _row_tokens(line)
                    for fi, t in enumerate(toks):
                        ids[fill, fi] = self.vocabs[fi].get(t, 0)
                    labels[fill] = label
                    fill += 1
                    if fill == self.batch_size:
                        yield {"ids": ids.copy(), "label": labels.copy()}
                        fill = 0
                if fill:  # final partial batch, padded by repetition
                    reps = -(-self.batch_size // fill)
                    yield {"ids": np.tile(ids[:fill], (reps, 1))[:self.batch_size],
                           "label": np.tile(labels[:fill], reps)[:self.batch_size]}
            if not self.loop:
                return
