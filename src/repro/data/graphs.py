"""Graph generators + a real fanout neighbor sampler (host-side, numpy).

``make_sbm_graph`` plants community structure (stochastic block model) so GIN
has learnable signal on the node-classification cells. ``NeighborSampler``
implements GraphSAGE-style layered fanout sampling over CSR adjacency with
static output shapes (padded) — the minibatch_lg requirement.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class CSRGraph(NamedTuple):
    indptr: np.ndarray     # (N+1,)
    indices: np.ndarray    # (E,) neighbor ids
    n_nodes: int


def csr_from_edges(src: np.ndarray, dst: np.ndarray, n_nodes: int) -> CSRGraph:
    order = np.argsort(dst, kind="stable")
    src_sorted = src[order]
    counts = np.bincount(dst, minlength=n_nodes)
    indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return CSRGraph(indptr=indptr, indices=src_sorted.astype(np.int64), n_nodes=n_nodes)


def make_sbm_graph(n_nodes: int, n_edges: int, d_feat: int, n_classes: int,
                   seed: int = 0, homophily: float = 0.8):
    """Stochastic-block-model graph with class-correlated features."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, n_nodes)
    same = rng.random(n_edges) < homophily
    src = rng.integers(0, n_nodes, n_edges)
    # homophilous edges pick a destination with the same label
    by_class = [np.nonzero(labels == c)[0] for c in range(n_classes)]
    dst = rng.integers(0, n_nodes, n_edges)
    for c in range(n_classes):
        sel = same & (labels[src] == c)
        if sel.any() and len(by_class[c]):
            dst[sel] = rng.choice(by_class[c], sel.sum())
    centers = rng.normal(0, 1.0, (n_classes, d_feat)).astype(np.float32)
    x = centers[labels] + rng.normal(0, 2.0, (n_nodes, d_feat)).astype(np.float32)
    return {
        "x": x, "edge_src": src.astype(np.int32), "edge_dst": dst.astype(np.int32),
        "labels": labels.astype(np.int32), "n_nodes": n_nodes,
    }


def make_molecule_batch(batch: int, n_nodes: int, n_edges: int,
                        atom_vocab: int = 119, n_classes: int = 2, seed: int = 0):
    """Batched small graphs (block-diagonal edge list), categorical atoms.

    Planted rule: label = presence of an atom-type above a threshold count —
    learnable, and dependent on the atom embedding (MPE's categorical case).
    """
    rng = np.random.default_rng(seed)
    atoms = rng.integers(0, atom_vocab, (batch, n_nodes)).astype(np.int32)
    src = rng.integers(0, n_nodes, (batch, n_edges))
    dst = rng.integers(0, n_nodes, (batch, n_edges))
    offs = (np.arange(batch) * n_nodes)[:, None]
    labels = ((atoms < atom_vocab // 8).sum(axis=1) > n_nodes // 8).astype(np.int32)
    return {
        "atom_ids": atoms.reshape(-1),
        "edge_src": (src + offs).reshape(-1).astype(np.int32),
        "edge_dst": (dst + offs).reshape(-1).astype(np.int32),
        "graph_ids": np.repeat(np.arange(batch), n_nodes).astype(np.int32),
        "n_graphs": batch,
        "labels": labels,
    }


def pad_graph_edges(graph: dict, multiple: int = 512) -> dict:
    """Pad the edge list (and mask) so edge shards divide the mesh evenly.

    Padded edges point node 0 -> node 0 with edge_mask=False, so message
    passing ignores them exactly.
    """
    e = graph["edge_src"].shape[0]
    target = -(-e // multiple) * multiple
    if target == e and "edge_mask" in graph:
        return graph
    pad = target - e
    out = dict(graph)
    mask = graph.get("edge_mask", np.ones((e,), bool))
    out["edge_src"] = np.concatenate([graph["edge_src"],
                                      np.zeros((pad,), graph["edge_src"].dtype)])
    out["edge_dst"] = np.concatenate([graph["edge_dst"],
                                      np.zeros((pad,), graph["edge_dst"].dtype)])
    out["edge_mask"] = np.concatenate([mask, np.zeros((pad,), bool)])
    return out


class NeighborSampler:
    """Layered uniform fanout sampling with static (padded) output shapes."""

    def __init__(self, graph: CSRGraph, fanouts: tuple, seed: int = 0):
        self.g = graph
        self.fanouts = fanouts
        self.rng = np.random.default_rng(seed)

    def sample(self, seeds: np.ndarray):
        """seeds: (B,) -> dict with padded nodes/edges for all hops.

        Output nodes: [seeds, hop1 samples, hop2 samples, ...] with fixed
        sizes B, B*f1, B*f1*f2, ... (duplicates allowed — GraphSAGE style);
        edges connect each sampled neighbor to its parent.
        """
        g = self.g
        frontier = seeds.astype(np.int64)
        all_nodes = [frontier]
        src_list, dst_list, mask_list = [], [], []
        node_offset = 0
        for f in self.fanouts:
            deg = g.indptr[frontier + 1] - g.indptr[frontier]
            # uniform sample f neighbors per frontier node (with replacement)
            r = self.rng.integers(0, 2**63 - 1, (frontier.shape[0], f))
            idx = np.where(deg[:, None] > 0, r % np.maximum(deg, 1)[:, None], 0)
            nbrs = g.indices[g.indptr[frontier][:, None] + idx]      # (Bf, f)
            valid = np.broadcast_to(deg[:, None] > 0, (frontier.shape[0], f))
            child_offset = node_offset + frontier.shape[0]
            # edge: sampled neighbor (child, message src) -> parent (dst)
            parents = node_offset + np.arange(frontier.shape[0])
            src_list.append((child_offset + np.arange(nbrs.size)).astype(np.int64))
            dst_list.append(np.repeat(parents, f))
            mask_list.append(valid.reshape(-1))
            frontier = nbrs.reshape(-1)
            all_nodes.append(frontier)
            node_offset = child_offset
        nodes = np.concatenate(all_nodes)
        return {
            "node_ids": nodes.astype(np.int64),          # global ids to fetch feats
            "edge_src": np.concatenate(src_list).astype(np.int32),
            "edge_dst": np.concatenate(dst_list).astype(np.int32),
            "edge_mask": np.concatenate(mask_list),
            "n_seeds": int(seeds.shape[0]),
        }

    @staticmethod
    def output_sizes(batch: int, fanouts: tuple):
        """Static node/edge counts for dry-run specs."""
        nodes, edges, b = batch, 0, batch
        for f in fanouts:
            edges += b * f
            b *= f
            nodes += b
        return nodes, edges
