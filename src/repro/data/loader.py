"""Host-side prefetching loader.

Data generation runs on a background thread while the device computes the
previous step — the standard straggler-avoidance pattern for host-bound input
pipelines (the generator itself is deterministic in (seed, step, host), so a
restarted/re-scaled job reproduces the stream — see DESIGN.md §5).
"""
from __future__ import annotations

import queue
import threading


class Prefetcher:
    def __init__(self, batch_fn, start_step: int = 0, depth: int = 2):
        """batch_fn: step -> batch dict (host numpy)."""
        self.batch_fn = batch_fn
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.batch_fn(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
