"""Memory-bounded attention and cross-entropy for long sequences / big vocabs.

``chunked_gqa_attention`` is blockwise (flash-style) attention in pure JAX:
an online-softmax scan over KV chunks nested in a map over Q chunks, so the
materialized score block is (q_chunk × kv_chunk) instead of (S × T). This is
what lets the 32k-prefill and 4k-train cells fit HBM without a fused kernel —
XLA fuses the inner block into a tight loop, and under pjit the scan works
with any KV sharding (softmax statistics combine exactly like
flash-decoding's partial-max/denominator trick).

``chunked_softmax_xent`` scans the sequence axis when computing logits×CE for
151k-vocab LM heads, so the (tokens × vocab) logit tensor never exists in
full; jax.checkpoint on the chunk body keeps the backward at one chunk too.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def chunked_gqa_attention(q, k, v, *, n_kv_heads: int, causal: bool,
                          q_offset=0, kv_valid_len=None,
                          q_chunk: int = 512, kv_chunk: int = 1024,
                          expand_kv: bool = False,
                          block_dtype=None):
    """q: (B,S,Hq,hd); k,v: (B,T,Hkv,hd) -> (B,S,Hq,hd). fp32 softmax.

    expand_kv (§Perf): repeat K/V up to the query-head count so the head dim
    is mesh-divisible and pinned to "model". Without it, the grouped 5-D
    reshape defeats GSPMD's head-sharding propagation and every device
    computes all heads (measured 16× redundant compute+bytes on qwen3 —
    EXPERIMENTS.md §Perf iteration 2). Costs Hq/Hkv× more K/V bytes, which the
    sharding reclaims.
    """
    b, s, hq, hd = q.shape
    t = k.shape[1]
    if expand_kv and hq != n_kv_heads:
        rep = hq // n_kv_heads
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        n_kv_heads = hq
    group = hq // n_kv_heads
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, t)
    nq, nk = s // q_chunk, t // kv_chunk
    assert s % q_chunk == 0 and t % kv_chunk == 0, (s, t, q_chunk, kv_chunk)

    scale = hd ** -0.5
    # §Perf: blocks may stay bf16 (block_dtype) — the matmuls accumulate in
    # fp32 via preferred_element_type and softmax statistics remain fp32, so
    # only the stored block tensors (the HBM traffic) shrink 2×.
    bd = block_dtype or jnp.float32
    qr = q.reshape(b, nq, q_chunk, n_kv_heads, group, hd).astype(bd)
    kr = k.reshape(b, nk, kv_chunk, n_kv_heads, hd).astype(bd)
    vr = v.reshape(b, nk, kv_chunk, n_kv_heads, hd).astype(bd)
    if expand_kv:
        from jax.sharding import PartitionSpec as P
        from repro.dist.sharding import current_dp_axes, maybe_shard
        dp = current_dp_axes()
        if dp is not None:
            qr = maybe_shard(qr, P(dp, None, None, "model", None, None))
            kr = maybe_shard(kr, P(dp, None, None, "model", None))
            vr = maybe_shard(vr, P(dp, None, None, "model", None))

    def q_block(qi, qb):
        """qb: (b, q_chunk, kv, g, hd) -> attention output for this q block."""
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inputs):
            acc, m, denom = carry
            ki, kb, vb = inputs
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            logits = jnp.einsum("bqkgh,bckh->bkgqc", qb, kb,
                                preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            if kv_valid_len is not None:
                mask &= (k_pos < kv_valid_len)[None, :]
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            new_m = jnp.maximum(m, jnp.max(logits, axis=-1))
            alpha = jnp.exp(m - new_m)
            p = jnp.exp(logits - new_m[..., None])
            denom = denom * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqc,bckh->bkgqh", p.astype(bd), vb,
                preferred_element_type=jnp.float32)
            return (acc, new_m, denom), None

        acc0 = jnp.zeros((b, n_kv_heads, group, q_chunk, hd), jnp.float32)
        m0 = jnp.full((b, n_kv_heads, group, q_chunk), NEG_INF, jnp.float32)
        d0 = jnp.zeros((b, n_kv_heads, group, q_chunk), jnp.float32)
        (acc, _, denom), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (acc0, m0, d0),
            (jnp.arange(nk), jnp.moveaxis(kr, 1, 0), jnp.moveaxis(vr, 1, 0)))
        out = acc / jnp.maximum(denom[..., None], 1e-30)
        return jnp.transpose(out, (0, 3, 1, 2, 4))        # (b, qc, kv, g, hd)

    outs = jax.lax.map(lambda args: q_block(*args),
                       (jnp.arange(nq), jnp.moveaxis(qr, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, hq, hd)
    return out.astype(q.dtype)


def chunked_softmax_xent(x, lm_head, labels, *, chunk: int = 512):
    """x: (B,S,d) final hidden; lm_head: (d,V); labels: (B,S) -> mean CE.

    Scans S in chunks; the (B, chunk, V) logits block is the only vocab-sized
    intermediate, re-materialized in backward via checkpoint.
    """
    b, s, d = x.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    xr = jnp.moveaxis(x.reshape(b, nc, chunk, d), 1, 0)
    lr = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)

    @jax.checkpoint
    def step(tot, inputs):
        xc, lc = inputs
        logits = xc @ lm_head
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ce = -jnp.take_along_axis(logp, lc[..., None], axis=-1)
        return tot + jnp.sum(ce), None

    tot, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (xr, lr))
    return tot / (b * s)
