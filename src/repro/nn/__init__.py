"""Pure-JAX neural-network substrate (the environment has no flax/optax).

Every layer is a namespace of two functions:

    init(key, ...)   -> params   (a nested dict pytree)
    apply(params, x) -> y

Params are plain dict pytrees so they compose with pjit shardings, our
optimizer, and checkpointing without any framework machinery.
"""
from repro.nn import init as initializers
from repro.nn.linear import Dense
from repro.nn.mlp import MLP
from repro.nn.norms import LayerNorm, RMSNorm, BatchNorm
from repro.nn.module import param_count, param_bytes, tree_cast, flatten_with_names

__all__ = [
    "initializers", "Dense", "MLP", "LayerNorm", "RMSNorm", "BatchNorm",
    "param_count", "param_bytes", "tree_cast", "flatten_with_names",
]
