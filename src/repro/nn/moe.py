"""Mixture-of-Experts FFN with token-choice top-k routing.

Gather-based capacity dispatch (no (T,E,C) one-hot tensor): tokens pick top-k
experts; a (T,E) cumsum assigns each (token, choice) a slot in its expert's
capacity buffer; dispatch/combine are scatter/gather with int32 index arrays.
This shards two ways on the production mesh:

  - expert-parallel (deepseek-moe: 64 experts / 16 chips) — experts over
    "model", dispatch lowers to all-to-all;
  - tensor-parallel within experts (grok-1: 8 experts ∤ 16) — expert d_ff over
    "model", experts replicated.

Supports shared experts (DeepSeekMoE's 2 shared + 64 routed fine-grained
design [arXiv:2401.06066]).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.quantizer import dequantize_symmetric
from repro.nn import init as initializers


class MoEConfig(NamedTuple):
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int              # per-expert hidden
    n_shared: int = 0      # always-on shared experts
    capacity_factor: float = 1.25
    # §Perf: shard the (E, C, d) dispatch buffers' capacity dim over the
    # data axes (routing is token-local; the a2a then crosses only "model")
    shard_dispatch: bool = False
    # §Perf, paper-aligned: store expert weights int8 (per-expert scales),
    # dequantized on use — shrinks the dominant serve-time weight traffic
    # (HBM + cross-shard gathers) 2× vs bf16 / 4× vs fp32. This is MPE's own
    # quantize-the-parameters insight applied to the MoE weights.
    expert_weight_int8: bool = False


def _ffn_init(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {  # SwiGLU (LLaMA/grok/deepseek convention)
        "w_gate": initializers.he_normal(k1, (d_model, d_ff), dtype),
        "w_up": initializers.he_normal(k2, (d_model, d_ff), dtype),
        "w_down": initializers.he_normal(k3, (d_ff, d_model), dtype),
    }


def ffn_apply(p, x):
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


class MoE:
    @staticmethod
    def init(key, cfg: MoEConfig, dtype=jnp.float32):
        ks = jax.random.split(key, 3)
        e = cfg.n_experts

        def _expert_mat(k, shape):
            w = initializers.he_normal(k, shape, jnp.float32)
            if cfg.expert_weight_int8:
                scale = jnp.max(jnp.abs(w), axis=(1, 2), keepdims=True) / 127.0
                return {"q": jnp.round(w / scale).astype(jnp.int8),
                        "scale": scale.astype(jnp.float32)}
            return w.astype(dtype)

        params = {
            "router": initializers.normal(ks[0], (cfg.d_model, e), std=0.02, dtype=jnp.float32),
            "experts": {
                "w_gate": _expert_mat(ks[1], (e, cfg.d_model, cfg.d_ff)),
                "w_up": _expert_mat(jax.random.fold_in(ks[1], 1),
                                    (e, cfg.d_model, cfg.d_ff)),
                "w_down": _expert_mat(jax.random.fold_in(ks[1], 2),
                                      (e, cfg.d_ff, cfg.d_model)),
            },
        }
        if cfg.n_shared:
            params["shared"] = _ffn_init(ks[2], cfg.d_model,
                                         cfg.d_ff * cfg.n_shared, dtype)
        return params

    @staticmethod
    def apply(params, x, cfg: MoEConfig):
        """x: (B, S, d) -> (B, S, d), aux_loss (load-balance)."""
        b, s, d = x.shape
        t = b * s
        xt = x.reshape(t, d)
        e, k = cfg.n_experts, cfg.top_k
        cap = max(1, int(cfg.capacity_factor * k * t / e))

        logits = (xt.astype(jnp.float32) @ params["router"])          # (T, E)
        gates = jax.nn.softmax(logits, axis=-1)
        topw, topi = jax.lax.top_k(gates, k)                          # (T, k)
        topw = topw / jnp.maximum(jnp.sum(topw, -1, keepdims=True), 1e-9)

        # slot assignment: position of each (token, choice) within its expert
        onehot = jax.nn.one_hot(topi, e, dtype=jnp.int32)             # (T, k, E)
        flat_oh = onehot.reshape(t * k, e)
        pos = jnp.cumsum(flat_oh, axis=0) * flat_oh                   # 1-based
        pos_in_expert = jnp.max(pos, axis=-1) - 1                     # (T*k,)
        expert_id = topi.reshape(t * k)
        keep = pos_in_expert < cap                                    # drop overflow
        slot = expert_id * cap + jnp.clip(pos_in_expert, 0, cap - 1)  # (T*k,)

        token_of_choice = jnp.repeat(jnp.arange(t), k)
        # dispatch: slot -> token index (scatter; dropped choices never written)
        dispatch = jnp.zeros((e * cap,), jnp.int32)
        dispatch = dispatch.at[jnp.where(keep, slot, e * cap)].set(
            token_of_choice, mode="drop")
        slot_used = jnp.zeros((e * cap,), jnp.bool_).at[
            jnp.where(keep, slot, e * cap)].set(True, mode="drop")

        xe = jnp.take(xt, dispatch, axis=0).reshape(e, cap, d)        # (E, C, d)
        xe = xe * slot_used.reshape(e, cap, 1).astype(xe.dtype)
        if cfg.shard_dispatch:
            from jax.sharding import PartitionSpec as P
            from repro.dist.sharding import current_dp_axes, maybe_shard
            dp = current_dp_axes()
            if dp is not None:
                xe = maybe_shard(xe, P(None, dp, None))
        w = params["experts"]

        def _mat(m):  # dequantize int8 expert weights on use
            if isinstance(m, dict):
                return dequantize_symmetric(m["q"], m["scale"], xe.dtype)
            return m

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, _mat(w["w_gate"])))
        h = h * jnp.einsum("ecd,edf->ecf", xe, _mat(w["w_up"]))
        ye = jnp.einsum("ecf,efd->ecd", h, _mat(w["w_down"])).reshape(e * cap, d)

        # combine: scatter-add each kept choice back to its token, gate-weighted
        gathered = jnp.take(ye, jnp.clip(slot, 0, e * cap - 1), axis=0)  # (T*k, d)
        wts = (topw.reshape(t * k) * keep.astype(jnp.float32))[:, None]
        out = jax.ops.segment_sum(gathered * wts, token_of_choice, num_segments=t)

        if "shared" in params:
            out = out + ffn_apply(params["shared"], xt)

        # Switch-style load-balance auxiliary loss
        density = jnp.mean(jax.nn.one_hot(topi[:, 0], e, dtype=jnp.float32), axis=0)
        router_prob = jnp.mean(gates, axis=0)
        aux = e * jnp.sum(density * router_prob)
        return out.reshape(b, s, d).astype(x.dtype), aux
