"""Normalization layers: LayerNorm, RMSNorm, BatchNorm (with running stats).

BatchNorm is required by the paper's training recipe (§5.1.5, "Batch
Normalization is used to ensure stable training"). Running statistics live in
a separate ``state`` pytree (functional style), returned alongside outputs.
"""
from __future__ import annotations

import jax.numpy as jnp


class LayerNorm:
    @staticmethod
    def init(key, dim: int, dtype=jnp.float32):
        del key
        return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}

    @staticmethod
    def apply(params, x, eps: float = 1e-5):
        mean = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        y = (x - mean) / jnp.sqrt(var + eps)
        return y * params["scale"] + params["bias"]


class RMSNorm:
    @staticmethod
    def init(key, dim: int, dtype=jnp.float32):
        del key
        return {"scale": jnp.ones((dim,), dtype)}

    @staticmethod
    def apply(params, x, eps: float = 1e-6):
        # compute in fp32 for stability then cast back (LLaMA/Qwen convention)
        dtype = x.dtype
        x32 = x.astype(jnp.float32)
        ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * (1.0 / jnp.sqrt(ms + eps))
        return (y * params["scale"]).astype(dtype)


class BatchNorm:
    """Functional BatchNorm1d over the last axis.

    state = {"mean": (d,), "var": (d,), "count": ()}; apply returns
    (y, new_state) in training mode, y alone in eval mode.
    """
    MOMENTUM = 0.9

    @staticmethod
    def init(key, dim: int, dtype=jnp.float32):
        del key
        return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}

    @staticmethod
    def init_state(dim: int, dtype=jnp.float32):
        return {"mean": jnp.zeros((dim,), dtype), "var": jnp.ones((dim,), dtype)}

    @staticmethod
    def apply(params, state, x, *, train: bool, eps: float = 1e-5):
        if train:
            axes = tuple(range(x.ndim - 1))
            mean = jnp.mean(x, axis=axes)
            var = jnp.var(x, axis=axes)
            new_state = {
                "mean": BatchNorm.MOMENTUM * state["mean"] + (1 - BatchNorm.MOMENTUM) * mean,
                "var": BatchNorm.MOMENTUM * state["var"] + (1 - BatchNorm.MOMENTUM) * var,
            }
        else:
            mean, var = state["mean"], state["var"]
            new_state = state
        y = (x - mean) / jnp.sqrt(var + eps) * params["scale"] + params["bias"]
        return y, new_state
