"""Parameter initializers.

The paper initializes embeddings from N(0, 3e-3) (§5.1.5); dense layers use
glorot-uniform like the reference implementations of DNN/DCN/DeepFM/IPNN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EMBED_STD = 3e-3  # paper §5.1.5


def normal(key, shape, std=EMBED_STD, dtype=jnp.float32):
    return std * jax.random.normal(key, shape, dtype)


def glorot_uniform(key, shape, dtype=jnp.float32):
    fan_in, fan_out = _fans(shape)
    limit = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, dtype, -limit, limit)


def he_normal(key, shape, dtype=jnp.float32):
    fan_in, _ = _fans(shape)
    return jax.random.normal(key, shape, dtype) * jnp.sqrt(2.0 / fan_in)


def zeros(key, shape, dtype=jnp.float32):
    del key
    return jnp.zeros(shape, dtype)


def ones(key, shape, dtype=jnp.float32):
    del key
    return jnp.ones(shape, dtype)


def _fans(shape):
    if len(shape) < 1:
        return 1, 1
    if len(shape) == 1:
        return shape[0], shape[0]
    receptive = 1
    for s in shape[:-2]:
        receptive *= s
    return shape[-2] * receptive, shape[-1] * receptive
