"""Rotary position embeddings (RoPE) [arXiv:2104.09864].

Used by every assigned LM arch. Computed on the fly (no cached tables) so the
decode step can apply an arbitrary position offset.
"""
from __future__ import annotations

import jax.numpy as jnp


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim/2,)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                      # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., seq, 1, hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return rotated.astype(x.dtype)
