"""Pytree utilities for the dict-based parameter system."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def param_count(params) -> int:
    """Total number of scalars in a param pytree."""
    return sum(int(x.size) for x in jax.tree.leaves(params))


def param_bytes(params) -> int:
    """Total bytes of a param pytree at its current dtypes."""
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(params))


def tree_cast(params, dtype):
    """Cast every floating leaf to ``dtype`` (ints/bools untouched)."""
    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(_cast, params)


def flatten_with_names(params, prefix: str = ""):
    """Yield (dotted_name, leaf) pairs for a nested-dict pytree."""
    if isinstance(params, dict):
        for k in sorted(params):
            yield from flatten_with_names(params[k], f"{prefix}{k}." if prefix or True else k)
    else:
        yield prefix.rstrip("."), params


def tree_zeros_like(params):
    return jax.tree.map(jnp.zeros_like, params)


def global_norm(tree) -> jax.Array:
    """L2 norm over all leaves (for grad clipping / logging)."""
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))
