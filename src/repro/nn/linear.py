"""Dense layer."""
from __future__ import annotations

import jax.numpy as jnp
from repro.nn import init as initializers


class Dense:
    @staticmethod
    def init(key, d_in: int, d_out: int, *, use_bias: bool = True,
             kernel_init=initializers.glorot_uniform, dtype=jnp.float32):
        params = {"kernel": kernel_init(key, (d_in, d_out), dtype=dtype)}
        if use_bias:
            params["bias"] = jnp.zeros((d_out,), dtype)
        return params

    @staticmethod
    def apply(params, x):
        y = x @ params["kernel"]
        if "bias" in params:
            y = y + params["bias"]
        return y
