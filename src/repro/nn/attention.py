"""Multi-head attention with GQA, RoPE, optional qk-norm, and a KV cache.

One module serves every arch in the pool: the LM transformers use GQA + RoPE
(+ qk_norm for qwen3), BST/SASRec use small full/causal MHA with learned
positions (positions=None disables RoPE).

Decode: ``kv_cache`` is a dict {"k": (B, S_max, n_kv, hd), "v": ..., "len": ()}
holding past keys/values; apply() writes the new token(s) at position ``len``
and attends over the valid prefix. Shapes stay static — serving-friendly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.linear import Dense
from repro.nn.norms import RMSNorm
from repro.nn.rope import apply_rope

NEG_INF = -1e30


class MHA:
    @staticmethod
    def init(key, d_model: int, n_heads: int, n_kv_heads: int | None = None,
             head_dim: int | None = None, *, qk_norm: bool = False,
             dtype=jnp.float32):
        n_kv = n_kv_heads or n_heads
        hd = head_dim or d_model // n_heads
        ks = jax.random.split(key, 4)
        params = {
            "wq": Dense.init(ks[0], d_model, n_heads * hd, use_bias=False, dtype=dtype),
            "wk": Dense.init(ks[1], d_model, n_kv * hd, use_bias=False, dtype=dtype),
            "wv": Dense.init(ks[2], d_model, n_kv * hd, use_bias=False, dtype=dtype),
            "wo": Dense.init(ks[3], n_heads * hd, d_model, use_bias=False, dtype=dtype),
        }
        if qk_norm:
            params["q_norm"] = RMSNorm.init(None, hd, dtype)
            params["k_norm"] = RMSNorm.init(None, hd, dtype)
        return params

    @staticmethod
    def apply(params, x, *, n_heads: int, n_kv_heads: int, head_dim: int,
              causal: bool = True, rope_theta: float | None = 10000.0,
              positions=None, kv_cache=None, attn_mask=None):
        """x: (B, S, d). Returns (out (B, S, d), new_kv_cache | None)."""
        b, s, _ = x.shape
        hd, n_kv = head_dim, n_kv_heads
        q = Dense.apply(params["wq"], x).reshape(b, s, n_heads, hd)
        k = Dense.apply(params["wk"], x).reshape(b, s, n_kv, hd)
        v = Dense.apply(params["wv"], x).reshape(b, s, n_kv, hd)

        if "q_norm" in params:  # qwen3-style per-head RMS qk-norm
            q = RMSNorm.apply(params["q_norm"], q)
            k = RMSNorm.apply(params["k_norm"], k)

        if kv_cache is not None:
            offset = kv_cache["len"]
        else:
            offset = 0
        if positions is None:
            positions = offset + jnp.arange(s)[None, :]  # (1, S)
        if rope_theta is not None:
            q = apply_rope(q, positions, rope_theta)
            k = apply_rope(k, positions, rope_theta)

        new_cache = None
        if kv_cache is not None:
            ck = jax.lax.dynamic_update_slice_in_dim(kv_cache["k"], k.astype(kv_cache["k"].dtype), offset, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(kv_cache["v"], v.astype(kv_cache["v"].dtype), offset, axis=1)
            new_cache = {"k": ck, "v": cv, "len": offset + s}
            k, v = ck, cv  # attend over the whole (masked) cache

        out = gqa_attention(q, k, v, n_heads=n_heads, n_kv_heads=n_kv,
                            causal=causal, q_offset=offset,
                            kv_valid_len=(None if kv_cache is None else offset + s),
                            attn_mask=attn_mask)
        out = out.reshape(b, s, n_heads * hd)
        return Dense.apply(params["wo"], out), new_cache


def gqa_attention(q, k, v, *, n_heads: int, n_kv_heads: int, causal: bool,
                  q_offset=0, kv_valid_len=None, attn_mask=None):
    """q: (B,S,Hq,hd); k,v: (B,T,Hkv,hd) -> (B,S,Hq,hd).

    Grouped-query: each of the Hq/Hkv query groups attends to one kv head.
    Softmax in fp32 regardless of input dtype.

    ``q_offset`` / ``kv_valid_len`` may be scalars (one shared cache length,
    the classic decode batch) or per-row ``(B,)`` vectors (continuous
    batching: every cache slot holds a sequence at its own length).
    """
    b, s, hq, hd = q.shape
    t = k.shape[1]
    group = hq // n_kv_heads
    qg = q.reshape(b, s, n_kv_heads, group, hd)
    scale = hd ** -0.5
    # read K/V at their stored dtype (bf16 caches stay bf16 in HBM — halves
    # decode cache traffic); accumulate in fp32 via preferred_element_type
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale

    # masks normalize to (B|1, S, T): scalar offsets/lengths reshape to the
    # broadcasting (1, 1, 1), per-row (B,) vectors to (B, 1, 1)
    mask = None
    if causal:
        q_pos = (jnp.reshape(jnp.asarray(q_offset), (-1, 1, 1))
                 + jnp.arange(s)[None, :, None])          # (B|1, S, 1)
        k_pos = jnp.arange(t)[None, None, :]
        mask = k_pos <= q_pos                             # (B|1, S, T)
    if kv_valid_len is not None:
        valid = (jnp.arange(t)[None, None, :]
                 < jnp.reshape(jnp.asarray(kv_valid_len), (-1, 1, 1)))
        mask = valid if mask is None else (mask & valid)
    if mask is not None:
        logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    if attn_mask is not None:  # (B, S, T) extra mask (padding etc.)
        logits = jnp.where(attn_mask[:, None, None], logits, NEG_INF)

    probs = jax.nn.softmax(logits, axis=-1)  # fp32 statistics
    out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, hq, hd).astype(q.dtype)


def make_kv_cache(batch: int, max_len: int, n_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16, prefill_len: int = 0):
    return {
        "k": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
        "len": jnp.asarray(prefill_len, jnp.int32),
    }
