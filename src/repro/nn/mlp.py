"""MLP tower used by every DLRM backbone (paper §5.1.5: 1024-512-256).

Supports optional BatchNorm between layers (paper's recipe) and a final
projection to ``d_out`` (logit head) when requested.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.linear import Dense
from repro.nn.norms import BatchNorm


class MLP:
    @staticmethod
    def init(key, d_in: int, hidden: tuple, *, d_out: int | None = None,
             use_batchnorm: bool = True, dtype=jnp.float32):
        dims = [d_in, *hidden]
        keys = jax.random.split(key, len(hidden) + 1)
        layers = [Dense.init(keys[i], dims[i], dims[i + 1], dtype=dtype)
                  for i in range(len(hidden))]
        params = {"layers": layers}
        if use_batchnorm:
            params["bn"] = [BatchNorm.init(None, h, dtype) for h in hidden]
        if d_out is not None:
            params["head"] = Dense.init(keys[-1], dims[-1], d_out, dtype=dtype)
        return params

    @staticmethod
    def init_state(hidden: tuple, *, use_batchnorm: bool = True, dtype=jnp.float32):
        if not use_batchnorm:
            return {}
        return {"bn": [BatchNorm.init_state(h, dtype) for h in hidden]}

    @staticmethod
    def apply(params, state, x, *, train: bool = False, act=jax.nn.relu):
        new_bn = []
        for i, layer in enumerate(params["layers"]):
            x = Dense.apply(layer, x)
            if "bn" in params:
                x, s = BatchNorm.apply(params["bn"][i], state["bn"][i], x, train=train)
                new_bn.append(s)
            x = act(x)
        if "head" in params:
            x = Dense.apply(params["head"], x)
        new_state = {"bn": new_bn} if "bn" in params else {}
        return x, new_state
