"""The bench gate (scripts/bench_compare.py --gate) — ISSUE-9 acceptance:
a synthetic deterministic-metric regression must exit non-zero; matching
artifacts must pass; a stale allowlist or a wall-clock-reaching pattern is
itself a failure (the gate may only ever check deterministic metrics).
"""
import importlib.util
import json
import pathlib

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "bench_compare",
    pathlib.Path(__file__).resolve().parents[1] / "scripts"
    / "bench_compare.py")
bench_compare = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(bench_compare)


ARTIFACT = {
    "config": {"mode": "smoke"},
    "tiers": [{"hot_fraction": 0.1, "hit_rate": 0.74, "bytes_moved": 47112,
               "hot_bytes": 100, "cold_bytes": 900,
               "score_p50_ms_synchronous": 86.0}],
    "drift": {
        "requests": 48, "shift_at": 12,
        "points": [
            {"policy": "static", "hit_rate": 0.228, "steady_hit_rate": 0.03,
             "bytes_moved": 264252, "compiles_during_run": 0,
             "e2e_p99_ms": 678.9},
            {"policy": "decay", "hit_rate": 0.597, "steady_hit_rate": 0.558,
             "bytes_moved": 130632, "compiles_during_run": 0,
             "e2e_p99_ms": 695.2},
        ],
    },
    "unix_time": 1,
}

GATE = {
    "files": {
        "BENCH_prefetch.json": {
            "rules": [
                {"pattern": r"^tiers\.\d+\.(hit_rate|bytes_moved)$"},
                {"pattern": r"^drift\.points\.\d+\."
                            r"(hit_rate|steady_hit_rate|bytes_moved|"
                            r"compiles_during_run)$"},
            ]
        }
    }
}


@pytest.fixture
def dirs(tmp_path):
    fresh = tmp_path / "fresh"
    base = tmp_path / "base"
    fresh.mkdir(), base.mkdir()
    for d in (fresh, base):
        (d / "BENCH_prefetch.json").write_text(json.dumps(ARTIFACT))
    gate = tmp_path / "gate_metrics.json"
    gate.write_text(json.dumps(GATE))
    return fresh, base, gate


def _main(fresh, base, gate):
    return bench_compare.main(["--fresh", str(fresh), "--baseline",
                               str(base), "--gate", str(gate)])


def test_gate_passes_on_matching_artifacts(dirs, capsys):
    fresh, base, gate = dirs
    assert _main(fresh, base, gate) == 0
    assert "PASS" in capsys.readouterr().out


def test_gate_fails_on_synthetic_regression(dirs, capsys):
    fresh, base, gate = dirs
    bad = json.loads(json.dumps(ARTIFACT))
    bad["drift"]["points"][1]["steady_hit_rate"] -= 0.1    # regression
    bad["drift"]["points"][1]["compiles_during_run"] = 2   # recompile
    (fresh / "BENCH_prefetch.json").write_text(json.dumps(bad))
    assert _main(fresh, base, gate) == 1
    out = capsys.readouterr().out
    assert "steady_hit_rate" in out and "compiles_during_run" in out


def test_gate_checks_compile_counts_despite_advisory_skip(dirs):
    """The advisory mode's SKIP regex drops ``compiles``; the gate must not
    — a recompile during the deterministic replay is exactly what it
    exists to block."""
    fresh, base, gate = dirs
    failures, checked = bench_compare.gate_check(str(fresh), str(base),
                                                 str(gate))
    assert not failures
    # both drift points' compile counters were among the checked metrics
    bad = json.loads(json.dumps(ARTIFACT))
    bad["drift"]["points"][0]["compiles_during_run"] = 1
    (fresh / "BENCH_prefetch.json").write_text(json.dumps(bad))
    failures, _ = bench_compare.gate_check(str(fresh), str(base), str(gate))
    assert any("compiles_during_run" in f for f in failures)


def test_gate_fails_on_missing_fresh_artifact(dirs):
    fresh, base, gate = dirs
    (fresh / "BENCH_prefetch.json").unlink()
    assert _main(fresh, base, gate) == 1


def test_gate_fails_on_stale_pattern(dirs):
    """An allowlist pattern matching nothing means the bench schema moved
    out from under the gate — that must fail loudly, not silently gate
    zero metrics."""
    fresh, base, gate = dirs
    cfg = json.loads(json.dumps(GATE))
    cfg["files"]["BENCH_prefetch.json"]["rules"].append(
        {"pattern": r"^drift\.points\.\d+\.renamed_metric$"})
    gate.write_text(json.dumps(cfg))
    assert _main(fresh, base, gate) == 1


def test_gate_rejects_wall_clock_patterns(dirs):
    """Deterministic metrics only: a pattern reaching a ``*_ms`` key is a
    config bug and fails the gate even when the values happen to match."""
    fresh, base, gate = dirs
    cfg = json.loads(json.dumps(GATE))
    cfg["files"]["BENCH_prefetch.json"]["rules"].append(
        {"pattern": r"^drift\.points\.\d+\.e2e_p99_ms$"})
    gate.write_text(json.dumps(cfg))
    failures, _ = bench_compare.gate_check(str(fresh), str(base), str(gate))
    assert any("wall-clock" in f for f in failures)


def test_gate_tolerance_band(dirs):
    fresh, base, gate = dirs
    cfg = {"files": {"BENCH_prefetch.json": {"rules": [
        {"pattern": r"^tiers\.\d+\.hit_rate$", "tol_pct": 5.0}]}}}
    gate.write_text(json.dumps(cfg))
    near = json.loads(json.dumps(ARTIFACT))
    near["tiers"][0]["hit_rate"] *= 1.04        # inside the 5% band
    (fresh / "BENCH_prefetch.json").write_text(json.dumps(near))
    assert _main(fresh, base, gate) == 0
    near["tiers"][0]["hit_rate"] = ARTIFACT["tiers"][0]["hit_rate"] * 1.08
    (fresh / "BENCH_prefetch.json").write_text(json.dumps(near))
    assert _main(fresh, base, gate) == 1


def test_repo_gate_config_matches_checked_in_baseline():
    """The real allowlist applied to the real baseline is self-consistent:
    every pattern matches, nothing wall-clock sneaks in (the exact check CI
    runs against a fresh artifact)."""
    root = pathlib.Path(__file__).resolve().parents[1]
    failures, checked = bench_compare.gate_check(
        str(root / "benchmarks" / "baselines"),
        str(root / "benchmarks" / "baselines"),
        str(root / "benchmarks" / "gate_metrics.json"))
    assert failures == []
    assert checked > 20


def test_advisory_mode_still_exits_zero(dirs, capsys):
    fresh, base, _ = dirs
    bad = json.loads(json.dumps(ARTIFACT))
    bad["tiers"][0]["hit_rate"] = 0.1           # would fail the gate
    (fresh / "BENCH_prefetch.json").write_text(json.dumps(bad))
    assert bench_compare.main(["--fresh", str(fresh), "--baseline",
                               str(base)]) == 0
    assert "Bench compare" in capsys.readouterr().out
