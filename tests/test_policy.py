"""Traffic-adaptive tier policy (ISSUE-9 acceptance criteria).

Covers: the exponential-decay score math against a hand trace, plan
feasibility/bounds (``max_moves``, per-width slot accounting, hysteresis),
lookups bit-exact through arbitrary promotion/demotion rounds, the
writeback ordering contract (mirror first — a demotion can never lose an
update), last-write-wins dedupe, the seeded popularity-shift scenario
(adaptive recovers, static doesn't), zero ``CellCache`` recompiles across
moves + writebacks in a live engine, the ``TickClock`` determinism the CI
bench gate stands on, and the ``PressureAdapter`` miss-share → repack
control loop.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import (DecayAdmissionPolicy, StaticTierPolicy,
                         TieredTableStore)
from repro.core.inference import build_packed_table, packed_lookup
from repro.core.mpe import MPEConfig
from repro.core.quantizer import dequantize_codes, quantize_codes
from repro.embeddings.frequency import zipf_frequencies


def _random_packed_table(n=160, d=12, seed=0):
    rng = np.random.default_rng(seed)
    cfg = MPEConfig()
    emb = rng.normal(size=(n, d)).astype(np.float32)
    fbits = rng.integers(0, len(cfg.bits), size=n).astype(np.int32)
    alpha = (np.abs(rng.normal(size=len(cfg.bits))) * 0.1
             + 0.01).astype(np.float32)
    beta = (rng.normal(size=d) * 0.01).astype(np.float32)
    table, meta = build_packed_table(emb, fbits, alpha, beta, cfg)
    return table, meta


# -- score math ---------------------------------------------------------------

def test_decay_scores_match_hand_trace():
    p = DecayAdmissionPolicy(4, halflife=1.0)       # decay = 0.5 per tick
    p.observe([0, 0, 1])                            # t=1: s0=2, s1=1
    p.observe([1])                                  # t=2: s1=1*0.5+1=1.5
    s = p.scores()                                  # decayed to t=2
    assert s[0] == pytest.approx(1.0)               # 2 * 0.5
    assert s[1] == pytest.approx(1.5)
    assert s[2] == 0.0 and s[3] == 0.0
    p.observe([])                                   # empty chunk still ticks
    assert p.scores()[0] == pytest.approx(0.5)
    assert p.observations == 3


def test_policy_validates_knobs():
    with pytest.raises(ValueError):
        DecayAdmissionPolicy(8, halflife=0.0)
    with pytest.raises(ValueError):
        DecayAdmissionPolicy(8, margin=0.9)


def test_static_policy_never_moves():
    table, meta = _random_packed_table()
    store = TieredTableStore(table, meta, zipf_frequencies(meta["n"]), 0.3)
    pol = store.attach_policy(StaticTierPolicy())
    store.lookup(np.arange(meta["n"], dtype=np.int32).reshape(-1, 4))
    plan = pol.plan(store)
    assert plan.n_moves == 0


# -- plan feasibility + incremental moves ------------------------------------

def test_plan_bounded_and_feasible():
    table, meta = _random_packed_table()
    store = TieredTableStore(table, meta, zipf_frequencies(meta["n"], seed=1),
                             0.25)
    pol = store.attach_policy(
        DecayAdmissionPolicy(meta["n"], halflife=4.0, max_moves=10))
    rng = np.random.default_rng(5)
    cold_ids = np.nonzero(~store._is_hot_np)[0]
    for _ in range(6):                              # hammer the cold tier
        store.lookup(rng.choice(cold_ids, size=(32, 4)).astype(np.int32))
    plan = pol.plan(store)
    assert 0 < plan.n_moves <= 10
    assert not store._is_hot_np[plan.promote].any()
    assert store._is_hot_np[plan.demote].all()
    bits = meta["bits"]
    widx = store._width_idx_np
    free = store.free_slot_counts()
    for i, b in enumerate(bits):                    # per-width slot budget
        n_pro = int((widx[plan.promote] == i).sum())
        n_dem = int((widx[plan.demote] == i).sum())
        assert b != 0 or (n_pro == 0 and n_dem == 0)
        if b != 0:
            assert n_pro <= free.get(f"b{b}", 0) + n_dem
    # hysteresis: every swap's riser beats its victim by the margin
    for k in range(plan.demote.size):
        assert plan.promote_score[-(k + 1)] > 0
    s = store.apply_moves(plan.promote, plan.demote)
    assert s["promotions"] == plan.promote.size
    assert s["demotions"] == plan.demote.size
    assert store._is_hot_np[plan.promote].all()
    assert not store._is_hot_np[plan.demote].any()
    # infeasible plans are rejected loudly, not applied
    with pytest.raises(ValueError):
        store.apply_moves(plan.promote, np.zeros(0, np.int64))  # already hot


def test_lookups_bit_exact_through_move_rounds():
    table, meta = _random_packed_table(seed=2)
    n = meta["n"]
    store = TieredTableStore(table, meta, zipf_frequencies(n, seed=1), 0.3)
    store.attach_policy(
        DecayAdmissionPolicy(n, halflife=4.0, max_moves=64))
    probe = np.arange(n, dtype=np.int32).reshape(-1, 4)
    ref = np.asarray(packed_lookup(table, meta, jnp.asarray(probe)))
    rng = np.random.default_rng(6)
    for round_ in range(8):
        ids = ((rng.integers(0, n, size=(48, 3)) + round_ * 20) % n)
        store.lookup(ids.astype(np.int32))
        plan = store.policy.plan(store)
        store.apply_moves(plan.promote, plan.demote)
        got = np.asarray(store.lookup(probe))
        assert np.array_equal(got, ref), f"values drifted at round {round_}"


# -- writeback ----------------------------------------------------------------

def test_writeback_round_trip_bit_exact_per_width():
    table, meta = _random_packed_table(seed=3)
    n, d, bits = meta["n"], meta["d"], meta["bits"]
    store = TieredTableStore(table, meta, zipf_frequencies(n, seed=1), 0.4)
    rng = np.random.default_rng(7)
    widx = store._width_idx_np
    # one hot + one cold feature per non-zero width bucket (when present)
    picks = []
    for i, b in enumerate(bits):
        if b == 0:
            continue
        feats = np.nonzero(widx == i)[0]
        for hot in (True, False):
            sub = feats[store._is_hot_np[feats] == hot]
            if sub.size:
                picks.append(int(sub[0]))
    ids = np.asarray(picks, np.int64)
    vecs = rng.normal(size=(ids.size, d)).astype(np.float32)
    s = store.writeback(ids, vecs)
    assert s["written"] == ids.size and s["bytes"] > 0
    got = np.asarray(store.lookup(ids.astype(np.int32)[:, None]))[:, 0]
    for k, f in enumerate(ids):
        i = int(widx[f])
        b = int(bits[i])
        codes = quantize_codes(jnp.asarray(vecs[k][None]),
                               store._alpha_np[i], store._beta_np, b)
        want = np.asarray(dequantize_codes(codes, store._alpha_np[i],
                                           store._beta_np))[0]
        assert np.array_equal(got[k], want), f"feature {f} (b={b})"
    assert store.counters()["writebacks"] == ids.size


def test_writeback_survives_demotion_and_dedupes():
    """The ordering contract: mirror written first, so demoting a feature
    right after a writeback re-exposes the *updated* row — no lost update.
    Duplicate ids in one writeback resolve last-write-wins."""
    table, meta = _random_packed_table(seed=4)
    n, d = meta["n"], meta["d"]
    store = TieredTableStore(table, meta, zipf_frequencies(n, seed=1), 0.4)
    widx, bits = store._width_idx_np, meta["bits"]
    hot_nz = np.nonzero(store._is_hot_np
                        & (np.asarray(bits)[widx] != 0))[0]
    f = int(hot_nz[0])
    rng = np.random.default_rng(8)
    v1, v2 = rng.normal(size=(2, d)).astype(np.float32)
    store.writeback(np.array([f, f]), np.stack([v1, v2]))   # last wins
    hot_read = np.asarray(store.lookup(np.array([[f]], np.int32)))[0, 0]
    store.apply_moves(np.zeros(0, np.int64), np.array([f]))  # demote
    cold_read = np.asarray(store.lookup(np.array([[f]], np.int32)))[0, 0]
    assert np.array_equal(hot_read, cold_read)               # nothing lost
    i = int(widx[f])
    codes = quantize_codes(jnp.asarray(v2[None]), store._alpha_np[i],
                           store._beta_np, int(bits[i]))
    want = np.asarray(dequantize_codes(codes, store._alpha_np[i],
                                       store._beta_np))[0]
    assert np.array_equal(cold_read, want)                   # v2, not v1


# -- popularity shift: adaptive recovers, static doesn't ---------------------

def _shift_run(policy, n_chunks=60, shift_chunk=20, steady_chunk=40, seed=9):
    """Seeded zipf traffic whose identity rotates by n/2 at ``shift_chunk``;
    returns (pre-shift hit rate, steady-state hit rate after the shift)."""
    table, meta = _random_packed_table(seed=1)
    n = meta["n"]
    freqs = zipf_frequencies(n)                    # rank == id: 0 hottest
    store = TieredTableStore(table, meta, freqs, 0.2)
    store.attach_policy(policy)
    rng = np.random.default_rng(seed)
    snaps = {}
    for chunk in range(n_chunks):
        ids = rng.choice(n, size=(64, 4), p=freqs)
        if chunk >= shift_chunk:
            ids = (ids + n // 2) % n
        store.lookup(ids.astype(np.int32))
        plan = store.policy.plan(store)
        store.apply_moves(plan.promote, plan.demote)
        if chunk + 1 in (shift_chunk, steady_chunk):
            snaps[chunk + 1] = store.counters()
    c = store.counters()
    pre = snaps[shift_chunk]["hit_rate"]
    hot_d = c["hot_lookups"] - snaps[steady_chunk]["hot_lookups"]
    cold_d = c["cold_lookups"] - snaps[steady_chunk]["cold_lookups"]
    return pre, hot_d / (hot_d + cold_d)


def test_popularity_shift_adaptive_recovers_static_does_not():
    pre_s, steady_static = _shift_run(StaticTierPolicy())
    pre_a, steady_adaptive = _shift_run(
        DecayAdmissionPolicy(160, halflife=8.0, max_moves=64))
    assert pre_s > 0.5 and pre_a > 0.5          # both fine before the shift
    assert steady_adaptive > steady_static + 0.25
    assert steady_adaptive > 0.5                # recovered
    assert steady_static < 0.3                  # stale split stays broken


# -- engine integration: zero recompiles + deterministic replay ---------------

@pytest.fixture(scope="module")
def pipeline():
    from repro.launch.serve import train_packed_dlrm
    return train_packed_dlrm(field_vocabs=(150, 100, 120), train_steps=10,
                             train_batch=128, d_embed=8, mlp_hidden=(16,),
                             seed=4)


def _drift_engine_run(pipeline, policy_name):
    """A small TickClock open-loop drift replay with writebacks; returns
    (deterministic counters dict, engine)."""
    from repro.data.synthetic import DriftingCTR, SyntheticCTR
    from repro.launch.serve import run_open_loop
    from repro.models.dlrm import DLRM
    from repro.serve import Engine, TickClock

    cfg, params, state, buffers, spec, res = pipeline
    freqs = SyntheticCTR(spec).expected_frequencies()
    master = np.asarray(res["final_params"]["embedding"]["emb"])
    offs = np.asarray(buffers["offsets"], np.int64)
    store = TieredTableStore(res["packed_table"], res["packed_meta"],
                             freqs, 0.2)
    engine = Engine(clock=TickClock())
    engine.register_tiered_model("dlrm", DLRM, cfg, params, state, buffers,
                                 store, shapes={"tiered": 64})
    if policy_name == "decay":
        policy = DecayAdmissionPolicy(store.meta["n"], halflife=8.0,
                                      max_moves=128)
    else:
        policy = StaticTierPolicy()
    engine.attach_tier_policy(policy, every=1)
    ds = DriftingCTR(spec._replace(batch_size=48), shift_at=8,
                     shift_frac=0.4, step0=10_000)

    def on_submit(i, ids):
        if i and i % 6 == 0:
            gids = np.unique(np.asarray(ids, np.int64) + offs[None, :])
            engine.writeback_embeddings(gids, master[gids])

    compiles0 = engine.compile_count
    ol = run_open_loop(engine, lambda i: ds.batch(10_000 + i)["ids"], 24,
                       500.0, kind="tiered", on_submit=on_submit)
    c = store.counters()
    det = {k: c[k] for k in ("hot_lookups", "cold_lookups", "bytes_moved",
                             "promotions", "demotions", "writebacks",
                             "writeback_bytes")}
    det["completed"], det["shed"] = ol["completed"], ol["shed"]
    det["recompiles"] = engine.compile_count - compiles0
    return det, engine


def test_engine_moves_and_writebacks_zero_recompiles(pipeline):
    det, engine = _drift_engine_run(pipeline, "decay")
    assert det["recompiles"] == 0               # the acceptance criterion
    assert det["promotions"] > 0                # the policy actually moved
    assert det["writebacks"] > 0                # updates actually flowed
    assert engine.tier_moves["plans"] > 0
    assert engine.tier_moves["promotions"] == det["promotions"]


def test_engine_drift_replay_deterministic(pipeline):
    """Two identical TickClock replays produce identical counters — the
    property the blocking CI bench gate (scripts/bench_compare.py --gate)
    relies on."""
    a, _ = _drift_engine_run(pipeline, "decay")
    b, _ = _drift_engine_run(pipeline, "decay")
    assert a == b


def test_engine_adaptive_beats_static_hit_rate(pipeline):
    det_s, _ = _drift_engine_run(pipeline, "static")
    det_a, _ = _drift_engine_run(pipeline, "decay")
    hr = lambda d: d["hot_lookups"] / (d["hot_lookups"] + d["cold_lookups"])  # noqa: E731
    assert hr(det_a) > hr(det_s)
    assert det_s["promotions"] == 0


# -- pressure adapter: live counters -> precision repack ----------------------

def test_pressure_adapter_narrows_under_misses(pipeline):
    from repro.data.synthetic import SyntheticCTR
    from repro.launch.serve import build_engine, repack_tools
    from repro.serve import PressureAdapter

    cfg, params, state, buffers, spec, res = pipeline
    freqs = SyntheticCTR(spec).expected_frequencies()
    store = TieredTableStore(res["packed_table"], res["packed_meta"],
                             freqs, 0.1)
    engine = build_engine(cfg, params, state, buffers, p99_rows=64,
                          bulk_rows=128, store=store)
    planner, swapper = repack_tools(engine, res, freqs)
    adapter = engine.attach_adapter(
        PressureAdapter(planner, swapper, res["group_bits"], every=1,
                        promote_below=0.02, min_moved=1))
    # cold-heavy traffic: a tiny hot tier makes the miss share dominate
    ids = SyntheticCTR(spec._replace(batch_size=128)).batch(77_777)["ids"]
    engine.score_tiered(ids)
    compiles0 = engine.compile_count
    engine.sched_step()                 # adapter plans from the live window
    assert adapter.repacks == 1
    narrowed = planner.bytes_packed(adapter.assignment)
    assert narrowed < adapter.base_bytes
    engine.sched_step()                 # queued swap lands atomically
    assert engine.swaps_applied >= 1
    assert engine.compile_count == compiles0
