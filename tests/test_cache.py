"""Tiered hot/cold store + async prefetch (repro.cache).

Covers the ISSUE-3 acceptance criteria: tiered lookups bit-exact against the
monolithic packed table across hot fractions {0, 0.1, 1.0}, the prefetch
train loop step-identical to the synchronous loop, hit counters matching a
hand-computed trace, and the engine's tiered score path agreeing with the
monolithic score cells.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import PrefetchPipeline, TieredTableStore
from repro.core.inference import build_packed_table, packed_lookup
from repro.core.mpe import MPEConfig
from repro.core.packing import row_bytes
from repro.data.synthetic import CTRSpec, SyntheticCTR
from repro.embeddings.frequency import hot_feature_mask, zipf_frequencies
from repro.embeddings.table import FieldSpec
from repro.models.dlrm import DLRMConfig
from repro.train.loop import Trainer
from repro.train.optimizer import adam
from repro.zoo import dlrm_builder


def _random_packed_table(n=160, d=12, seed=0):
    rng = np.random.default_rng(seed)
    cfg = MPEConfig()
    emb = rng.normal(size=(n, d)).astype(np.float32)
    fbits = rng.integers(0, len(cfg.bits), size=n).astype(np.int32)
    alpha = (np.abs(rng.normal(size=len(cfg.bits))) * 0.1 + 0.01).astype(np.float32)
    beta = (rng.normal(size=d) * 0.01).astype(np.float32)
    table, meta = build_packed_table(emb, fbits, alpha, beta, cfg)
    return table, meta


@pytest.mark.parametrize("hot_fraction", [0.0, 0.1, 1.0])
def test_tiered_lookup_bit_exact(hot_fraction):
    table, meta = _random_packed_table()
    freqs = zipf_frequencies(meta["n"], seed=1)
    store = TieredTableStore(table, meta, freqs, hot_fraction)
    rng = np.random.default_rng(2)
    ids = rng.integers(0, meta["n"], size=(41, 3)).astype(np.int32)
    ref = np.asarray(packed_lookup(table, meta, jnp.asarray(ids)))
    got = np.asarray(store.lookup(ids))
    assert got.shape == ref.shape
    assert np.array_equal(got, ref)
    # prefetch-handle path is the same bytes, staged earlier
    fill = store.prefetch_cold(ids)
    assert np.array_equal(np.asarray(store.lookup(ids, fill)), ref)


def test_hot_feature_mask_deterministic_topk():
    freqs = np.array([5.0, 1.0, 9.0, 9.0, 2.0])
    mask = hot_feature_mask(freqs, 0.4)  # ceil(0.4*5) = 2 hottest
    assert mask.tolist() == [False, False, True, True, False]
    assert hot_feature_mask(freqs, 0.0).sum() == 0
    assert hot_feature_mask(freqs, 1.0).all()


def test_hit_counters_match_hand_trace():
    # 4 features, all at one non-zero width; freqs make features {0, 1} hot
    cfg = MPEConfig(bits=(0, 8))
    n, d = 4, 4
    rng = np.random.default_rng(3)
    emb = rng.normal(size=(n, d)).astype(np.float32)
    fbits = np.full((n,), 1, np.int32)      # every feature at 8 bits
    alpha = np.array([0.0, 0.05], np.float32)
    beta = np.zeros((d,), np.float32)
    table, meta = build_packed_table(emb, fbits, alpha, beta, cfg)
    store = TieredTableStore(table, meta, [40, 30, 2, 1], 0.5)

    ids = np.array([[0, 2], [1, 3], [0, 0]], np.int32)   # 3 hot+hot/cold mix rows
    store.lookup(ids)
    c = store.counters()
    # hand trace: flat ids = 0,2,1,3,0,0 -> hot: 0,1,0,0 (4), cold: 2,3 (2)
    assert c["hot_lookups"] == 4
    assert c["cold_lookups"] == 2
    assert c["bytes_moved"] == 2 * row_bytes(d, 8)
    assert c["hit_rate"] == pytest.approx(4 / 6)
    assert c["hot_bytes"] == 2 * row_bytes(d, 8)
    assert c["cold_bytes"] == 2 * row_bytes(d, 8)

    store.reset_counters()
    store.lookup(np.array([2, 3], np.int32))             # all cold
    assert store.counters()["hot_lookups"] == 0
    assert store.counters()["bytes_moved"] == 2 * row_bytes(d, 8)

    # batcher padding (valid mask) fetches nothing and skips the counters
    store.reset_counters()
    padded = np.array([[2, 3], [0, 0], [0, 0]], np.int32)
    fill = store.prefetch_cold(padded, valid=np.array([True, False, False]))
    assert fill.bytes_moved == 2 * row_bytes(d, 8)       # row 0 only
    c = store.counters()
    assert c["hot_lookups"] == 0 and c["cold_lookups"] == 2


def _tiny_setup(seed=0):
    spec = CTRSpec(field_vocabs=(300, 200), batch_size=128, seed=seed)
    ds = SyntheticCTR(spec)
    fields = tuple(FieldSpec(f"f{i}", v) for i, v in enumerate(spec.field_vocabs))
    base = DLRMConfig(fields=fields, d_embed=8, mlp_hidden=(16,), backbone="dnn")
    return ds, dlrm_builder(base, ds.expected_frequencies())


def test_prefetch_loop_step_identical():
    """The prefetch pipeline changes when bytes move, never the training
    trajectory: per-step losses and final params match the synchronous loop
    bit for bit."""
    runs = {}
    for prefetch in (False, True):
        ds, build = _tiny_setup()
        b = build(jax.random.PRNGKey(0), "plain", {})
        tr = Trainer(b["loss_fn"], b["params"], b["buffers"], b["state"],
                     adam(1e-3))
        losses = []
        tr.run(lambda s: ds.batch(s), 10, log_every=1,
               log_fn=lambda m: losses.append(m.split(" gnorm")[0]),
               prefetch=prefetch)
        runs[prefetch] = (losses, jax.tree.map(np.asarray, tr.params))
    assert runs[False][0] == runs[True][0]          # per-step loss lines
    for a, b in zip(jax.tree.leaves(runs[False][1]),
                    jax.tree.leaves(runs[True][1])):
        assert np.array_equal(a, b)


def test_prefetch_pipeline_stages_ahead_and_restarts():
    seen = []

    def data_fn(step):
        seen.append(step)
        return {"x": np.full((2,), step, np.int32)}

    pipe = PrefetchPipeline(data_fn, depth=2)
    b0 = pipe(0)
    assert np.asarray(b0["x"])[0] == 0
    assert seen == [0, 1, 2]                        # staged two ahead
    b1 = pipe(1)
    assert np.asarray(b1["x"])[0] == 1
    assert seen == [0, 1, 2, 3]                     # reused the staged batch
    # checkpoint-restore style jump: stale read-ahead is dropped, not served
    b7 = pipe(7)
    assert np.asarray(b7["x"])[0] == 7
    assert pipe(8) is not None and 8 in seen


def test_prefetch_pipeline_cold_fills_bounded():
    """Staged cold fills must not accumulate across steps (device-memory
    leak): unconsumed fills for past steps are evicted on the next call."""
    class FakeStore:
        def prefetch_cold(self, ids, valid=None):
            return ("fill", int(np.asarray(ids)[0, 0]))

    pipe = PrefetchPipeline(lambda s: {"ids": np.full((2, 2), s, np.int32)},
                            store=FakeStore())
    for step in range(25):
        pipe(step)                         # never calls take_cold
        assert len(pipe._cold) <= pipe.depth + 1
    assert pipe.take_cold(25) == ("fill", 25)   # current read-ahead usable
    assert pipe.take_cold(0) is None            # long gone


@pytest.mark.multidevice
def test_tiered_hot_tier_row_shards_on_mesh():
    """Placing the hot tier with ``tiered_hot_pspecs`` on a real 2×2 mesh
    moves bytes, not values: the device-put row shards are genuine (distinct
    blocks along "model") and a lookup through the sharded tree stays
    bit-exact vs the monolithic packed table. Runs in-process in the CI
    ``multidevice`` job (the shard_map serving path is covered end-to-end in
    tests/test_shard.py)."""
    from repro.cache.tiers import tiered_hot_lookup
    from repro.dist import (make_device_mesh, tiered_hot_pspecs,
                            tree_named_shardings, use_mesh)

    table, meta = _random_packed_table()
    freqs = zipf_frequencies(meta["n"], seed=1)
    store = TieredTableStore(table, meta, freqs, 0.4)
    mesh = make_device_mesh((2, 2), ("data", "model"))
    ns = tree_named_shardings(mesh, tiered_hot_pspecs(store.hot))
    hot_sharded = jax.device_put(store.hot, ns)
    for sub in jax.tree.leaves(hot_sharded["subtables"]):
        assert len({str(s.index) for s in sub.addressable_shards}) == 2, \
            sub.sharding

    rng = np.random.default_rng(7)
    ids = jnp.asarray(rng.integers(0, meta["n"], size=(53,)), jnp.int32)
    with use_mesh(mesh):
        got = jax.jit(lambda h, i: tiered_hot_lookup(
            h, store.meta["bits"], store.meta["d"], i))(hot_sharded, ids)
    ref = np.asarray(packed_lookup(table, meta, ids))
    is_hot = np.asarray(store.hot["is_hot"])[np.asarray(ids)]
    np.testing.assert_allclose(np.asarray(got)[is_hot], ref[is_hot],
                               rtol=1e-6, atol=1e-7)
    assert np.array_equal(np.asarray(got)[~is_hot],
                          np.zeros_like(ref[~is_hot]))


@pytest.fixture(scope="module")
def served():
    from repro.launch.serve import build_engine, train_packed_dlrm
    cfg, params, state, buffers, spec, res = train_packed_dlrm(
        field_vocabs=(150, 100, 120), train_steps=10, train_batch=128,
        d_embed=8, mlp_hidden=(16,), seed=4)
    freqs = SyntheticCTR(spec).expected_frequencies()
    store = TieredTableStore(res["packed_table"], res["packed_meta"],
                             freqs, 0.3)
    engine = build_engine(cfg, params, state, buffers, p99_rows=64,
                          bulk_rows=256, store=store)
    ids = SyntheticCTR(spec._replace(batch_size=300)).batch(50_000)["ids"]
    return engine, store, ids


def test_engine_tiered_matches_monolithic(served):
    engine, store, ids = served
    mono = engine.score(ids)
    tiered = engine.score_tiered(ids)
    assert np.allclose(mono, tiered, atol=1e-6)


def test_engine_tiered_overlap_invariant_and_warm(served):
    engine, store, ids = served
    a = engine.score_tiered(ids, overlap=True)
    b = engine.score_tiered(ids, overlap=False)
    assert np.array_equal(a, b)                     # overlap only moves bytes
    n_compiles = engine.compile_count
    engine.score_tiered(ids)
    assert engine.compile_count == n_compiles       # zero recompiles when warm
    c = engine.tier_counters()
    assert c and all(v["hot_lookups"] + v["cold_lookups"] > 0
                     for v in c.values())
