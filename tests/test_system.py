"""End-to-end behaviour tests for the paper's system (integration level)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mpe import MPEConfig
from repro.core.pipeline import run_mpe_pipeline
from repro.data.synthetic import CTRSpec, SyntheticCTR
from repro.embeddings.table import FieldSpec
from repro.models.dlrm import DLRMConfig
from repro.train.optimizer import adam
from repro.zoo import dlrm_builder


@pytest.fixture(scope="module")
def pipeline_result():
    spec = CTRSpec(field_vocabs=(1500, 800, 2000, 600), batch_size=1024,
                   seed=0)
    ds = SyntheticCTR(spec)
    fields = tuple(FieldSpec(f"f{i}", v) for i, v in enumerate(spec.field_vocabs))
    base = DLRMConfig(fields=fields, d_embed=16, mlp_hidden=(32, 16),
                      backbone="dnn")
    eval_batches = ds.eval_set(2)
    build = dlrm_builder(base, ds.expected_frequencies(), lam=3e-5,
                         eval_batches=eval_batches)
    res = run_mpe_pipeline(
        build, lambda s: ds.batch(s), key=jax.random.PRNGKey(1),
        mpe_cfg=MPEConfig(lam=3e-5), optimizer=adam(1e-3),
        search_steps=80, retrain_steps=80, retrain_mode="mpe",
        eval_fn=build(jax.random.PRNGKey(1), "plain", {})["eval_fn"],
        log_fn=lambda *a: None)
    res["_ds"], res["_build"] = ds, build
    return res


def test_pipeline_compresses(pipeline_result):
    """MPE must land well below the uniform-6-bit LSQ+ floor (paper Table 3)."""
    assert pipeline_result["storage_ratio"] < 6 / 32
    assert pipeline_result["avg_bits"] < 6.0


def test_pipeline_accuracy_sane(pipeline_result):
    assert pipeline_result["eval"]["auc"] > 0.70  # strong signal retained


def test_bits_correlate_with_frequency(pipeline_result):
    """Figure 6: precision should correlate positively with group frequency
    (group 0 = most frequent)."""
    gb = pipeline_result["group_bits"].astype(np.float64)
    g = len(gb)
    if g < 4:
        pytest.skip("too few groups")
    # Spearman-style: frequent half should average >= rare half
    head = gb[: g // 2].mean()
    tail = gb[g // 2:].mean()
    assert head >= tail


def test_packed_export_matches_model(pipeline_result):
    """Serving from the packed table reproduces retrain-layer embeddings."""
    from repro.core.inference import packed_lookup
    from repro.core.sampling import MPERetrainEmbedding
    res = pipeline_result
    fp = res["final_params"]["embedding"]
    ids = jnp.arange(100)
    cfg = MPEConfig(lam=3e-5)
    deq = packed_lookup(res["packed_table"], res["packed_meta"], ids)
    rp, rb = MPERetrainEmbedding.init(fp["emb"], fp["alpha"], fp["beta"],
                                      jnp.asarray(res["feature_bits_idx"]))
    ref = MPERetrainEmbedding.lookup(rp, rb, ids, cfg)
    np.testing.assert_allclose(np.asarray(deq), np.asarray(ref), atol=1e-6)


def test_packed_bytes_match_ratio(pipeline_result):
    res = pipeline_result
    n, d = res["packed_meta"]["n"], res["packed_meta"]["d"]
    dense_bytes = n * d * 4
    # packed bytes ≈ ratio · dense (word-alignment padding bounded by 31 bits/row)
    assert res["packed_bytes"] <= res["storage_ratio"] * dense_bytes * 1.6 + 4096


def test_retraining_modes_differ(pipeline_result):
    """w/o retraining must be evaluable and (typically) worse — Table 4 is
    exercised fully in benchmarks/table4.py; here we check the plumbing."""
    ds, build = pipeline_result["_ds"], pipeline_result["_build"]
    res0 = run_mpe_pipeline(
        build, lambda s: ds.batch(s), key=jax.random.PRNGKey(1),
        mpe_cfg=MPEConfig(lam=3e-5), optimizer=adam(1e-3),
        search_steps=30, retrain_steps=0, retrain_mode="none",
        eval_fn=build(jax.random.PRNGKey(1), "plain", {})["eval_fn"],
        log_fn=lambda *a: None)
    assert "auc" in res0["eval"]
