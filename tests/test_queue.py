"""The request-lifecycle stack (ISSUE 5): admission queue, coalescing
scheduler, continuous-batching decode.

Covers the acceptance criteria end-to-end:

  - coalescing: N interleaved requests are bit-identical to the per-request
    path with fewer cell invocations, strictly higher occupancy and zero
    recompiles (CellCache counters);
  - the coalescing packer: seeded-numpy randomized sweeps over request-size
    mixes (no hypothesis in this env) asserting round-trip integrity — every
    request gets exactly its own rows back, none dropped or duplicated, also
    under shedding;
  - continuous batching: sequences of different lengths join/leave the
    running decode batch, token-identical to per-request decode, KV-cache
    slots recycled with no new compiles after warmup;
  - admission policy: bounded-queue shedding, deadline shedding, and the
    three-way queue-wait / batch-assembly / compute breakdown;
  - multi-tenant SLO scheduling (ISSUE 8): priority lanes + EDF order,
    per-tenant quotas (queue share sheds, in-flight rows defer), watermark
    load shedding, the max-wait coalescing window (exact virtual times via
    ``ManualClock``), per-kind/per-tenant counter traces, fault injection
    (a raising dispatch fails only its chunk's tickets; decode KV slots
    recycle), and seeded sweeps of the lifecycle_props invariants shared
    with the hypothesis suite in test_scheduler_props.py.
"""
import jax
import numpy as np
import pytest

import lifecycle_props as props
from repro.data.synthetic import SyntheticCTR
from repro.launch.serve import (build_engine, run_open_loop,
                                run_open_loop_mix, train_packed_dlrm)
from repro.serve import (AdmissionQueue, Engine, ManualClock, RequestBatcher,
                         RequestFailedError, TenantQuota, lm_decode_cell,
                         lm_decode_slotted_cell)


# ---------------------------------------------------------------------------
# admission queue
# ---------------------------------------------------------------------------

def test_queue_fifo_and_kind_routing():
    q = AdmissionQueue(capacity=8)
    a = q.submit("score", "A", 3, now=0.0)
    b = q.submit("tiered", "B", 2, now=0.1)
    c = q.submit("score", "C", 5, now=0.2)
    ready, expired = q.take("score", now=1.0)
    assert [r.payload for r in ready] == ["A", "C"] and not expired
    assert a.ticket < c.ticket
    # the tiered request stayed queued, in order
    ready, _ = q.take("tiered", now=1.0)
    assert [r.payload for r in ready] == ["B"] and b is ready[0]
    assert len(q) == 0


def test_queue_sheds_on_full_and_counts():
    q = AdmissionQueue(capacity=2)
    assert q.submit("score", 0, 1, now=0.0) is not None
    assert q.submit("score", 1, 1, now=0.0) is not None
    assert q.submit("score", 2, 1, now=0.0) is None     # reject-on-full
    assert q.counters()["shed_full"] == 1
    assert q.counters()["admitted"] == 2
    with pytest.raises(ValueError):
        AdmissionQueue(capacity=0)


def test_queue_deadline_shed_at_take():
    q = AdmissionQueue(capacity=8)
    q.submit("score", "late", 1, now=0.0, deadline_ms=100.0)
    q.submit("score", "ok", 1, now=0.0, deadline_ms=10_000.0)
    ready, expired = q.take("score", now=1.0)   # 1s > 100ms deadline
    assert [r.payload for r in ready] == ["ok"]
    assert [r.payload for r in expired] == ["late"]
    assert q.counters()["shed_deadline"] == 1


# ---------------------------------------------------------------------------
# multi-tenant admission: priority lanes, EDF, quotas, watermark
# ---------------------------------------------------------------------------

def test_take_priority_lanes_then_edf_then_ticket():
    q = AdmissionQueue(capacity=16)
    q.submit("score", "p1-late", 1, now=0.0, priority=1, deadline_ms=100.0)
    q.submit("score", "p0-no-deadline", 1, now=0.0)
    q.submit("score", "p0-tight", 1, now=0.0, deadline_ms=900.0)
    q.submit("score", "p0-loose", 1, now=0.0, deadline_ms=5_000.0)
    q.submit("score", "p1-none", 1, now=0.0, priority=1)
    ready, _ = q.take("score", now=0.05)
    # lane 0 first (EDF inside: tight < loose < no-deadline), then lane 1
    assert [r.payload for r in ready] == \
        ["p0-tight", "p0-loose", "p0-no-deadline", "p1-late", "p1-none"]


def test_tenant_queue_share_quota_sheds_at_submit():
    q = AdmissionQueue(capacity=16,
                       quotas={"a": TenantQuota(max_queued=2)})
    assert q.submit("score", 0, 1, now=0.0, tenant="a") is not None
    assert q.submit("score", 1, 1, now=0.0, tenant="a") is not None
    assert q.submit("score", 2, 1, now=0.0, tenant="a") is None  # share full
    assert q.submit("score", 3, 1, now=0.0, tenant="b") is not None
    assert q.counters()["per_tenant"]["a"]["shed_quota"] == 1
    # draining frees the share
    ready, _ = q.take("score", now=1.0)
    assert len(ready) == 3
    assert q.submit("score", 4, 1, now=2.0, tenant="a") is not None


def test_tenant_inflight_quota_defers_not_sheds():
    q = AdmissionQueue(capacity=16,
                       quotas={"a": TenantQuota(max_inflight_rows=10)})
    r1 = q.submit("score", 0, 8, now=0.0, tenant="a")
    r2 = q.submit("score", 1, 8, now=0.0, tenant="a")
    ready, _ = q.take("score", now=1.0)
    assert ready == [r1]                    # r2 deferred: 16 rows > 10
    assert len(q) == 1 and r2.status == "queued"
    ready, _ = q.take("score", now=2.0)     # still over quota: stays queued
    assert ready == []
    q.release(r1)                           # r1 completes
    ready, _ = q.take("score", now=3.0)
    assert ready == [r2]
    assert q.counters()["shed_quota"] == 0  # deferral is not shedding
    # a request that could never dispatch is rejected outright
    with pytest.raises(ValueError, match="max_inflight_rows"):
        q.submit("score", 2, 11, now=4.0, tenant="a")


def test_watermark_sheds_background_lane_first():
    q = AdmissionQueue(capacity=4, shed_watermark=0.5)
    assert q.submit("score", 0, 1, now=0.0) is not None
    assert q.submit("score", 1, 1, now=0.0) is not None
    # depth 2 = 0.5 * 4: background (priority > 0) sheds, urgent admits
    assert q.submit("score", 2, 1, now=0.0, priority=1) is None
    assert q.submit("score", 3, 1, now=0.0) is not None
    assert q.counters()["shed_load"] == 1
    with pytest.raises(ValueError):
        AdmissionQueue(capacity=4, shed_watermark=0.0)
    with pytest.raises(ValueError):
        q.submit("score", 4, 1, now=0.0, priority=-1)


def test_per_kind_counter_trace_hand_computed():
    """Every admission counter, per kind and per tenant, traced by hand
    through a fixed sequence (the ``test_cache.py`` counter-trace style)."""
    q = AdmissionQueue(capacity=4, shed_watermark=0.75,
                       quotas={"b": TenantQuota(max_queued=1)})
    zero = {"admitted": 0, "shed_full": 0, "shed_deadline": 0,
            "shed_quota": 0, "shed_load": 0}

    q.submit("score", 0, 1, now=0.0, tenant="a")              # admitted
    q.submit("tiered", 1, 1, now=0.0, tenant="b")             # admitted
    q.submit("tiered", 2, 1, now=0.0, tenant="b")             # b share full
    q.submit("score", 3, 1, now=0.0, tenant="a", priority=2)  # depth 2 < 3
    # depth now 3 = 0.75 * 4: the next background arrival sheds on load
    q.submit("score", 4, 1, now=0.0, tenant="a", priority=2)  # shed_load
    q.submit("score", 5, 1, now=0.0, tenant="a")              # admitted (4)
    q.submit("score", 6, 1, now=0.0, tenant="a")              # shed_full
    c = q.counters()
    assert c["depth"] == 4 and c["capacity"] == 4
    assert c["per_kind"] == {
        "score": dict(zero, admitted=3, shed_full=1, shed_load=1),
        "tiered": dict(zero, admitted=1, shed_quota=1)}
    assert c["per_tenant"] == {
        "a": dict(zero, admitted=3, shed_full=1, shed_load=1),
        "b": dict(zero, admitted=1, shed_quota=1)}
    # totals are the per-kind sums
    assert (c["admitted"], c["shed_full"], c["shed_quota"], c["shed_load"],
            c["shed_deadline"]) == (4, 1, 1, 1, 0)

    # deadline shed at take lands in the expiring request's kind/tenant
    q2 = AdmissionQueue(capacity=4)
    q2.submit("score", 0, 1, now=0.0, deadline_ms=10.0, tenant="late")
    ready, expired = q2.take("score", now=1.0)
    assert not ready and len(expired) == 1
    assert q2.counters()["per_kind"]["score"]["shed_deadline"] == 1
    assert q2.counters()["per_tenant"]["late"]["shed_deadline"] == 1


def test_max_wait_window_hold_and_release():
    """``take(min_rows=, max_wait_s=)``: a light load holds (everything
    stays queued) until the bucket fills or the oldest request ages out —
    exact times, virtual clock."""
    q = AdmissionQueue(capacity=16)
    r1 = q.submit("score", 0, 5, now=0.0)
    # 5 rows < 64 and age 10ms < 100ms window: hold
    ready, _ = q.take("score", now=0.01, min_rows=64, max_wait_s=0.1)
    assert ready == [] and len(q) == 1 and r1.status == "queued"
    # bucket fills: dispatch immediately, well inside the window
    r2 = q.submit("score", 1, 60, now=0.02)
    ready, _ = q.take("score", now=0.03, min_rows=64, max_wait_s=0.1)
    assert ready == [r1, r2]
    for r in ready:
        q.release(r)
    # window expiry: a lone request dispatches once it's 100ms old
    r3 = q.submit("score", 2, 5, now=1.0)
    ready, _ = q.take("score", now=1.05, min_rows=64, max_wait_s=0.1)
    assert ready == []
    ready, _ = q.take("score", now=1.11, min_rows=64, max_wait_s=0.1)
    assert ready == [r3]
    # expired requests shed even while the lane holds
    q.submit("score", 3, 5, now=2.0, deadline_ms=10.0)
    q.submit("score", 4, 5, now=2.0)
    ready, expired = q.take("score", now=2.05, min_rows=64, max_wait_s=0.1)
    assert ready == [] and len(expired) == 1


# ---------------------------------------------------------------------------
# seeded property sweeps over the new knobs (shared with the hypothesis
# suite in test_scheduler_props.py via lifecycle_props)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(10))
def test_multilane_stream_invariants_randomized(seed):
    """Randomized tenant/priority/deadline/quota streams: no dropped or
    duplicated tickets, EDF order within a lane, quota ceilings never
    exceeded, counters consistent."""
    rng = np.random.default_rng(seed)
    specs = props.random_stream(rng, int(rng.integers(10, 80)))
    cfg = props.random_config(rng)
    result = props.drive_queue(specs, cfg)
    props.check_no_drop_no_dup(result)
    props.check_edf_order(result)
    props.check_quota_ceilings(result, cfg.get("quotas"))
    props.check_counters_consistent(result)


@pytest.mark.parametrize("seed", range(4))
def test_fifo_identity_degenerate_stream_randomized(seed):
    rng = np.random.default_rng(seed)
    props.check_fifo_identity(
        [int(n) for n in rng.integers(1, 100, size=rng.integers(1, 30))])


# ---------------------------------------------------------------------------
# coalescing packer: seeded randomized sweeps (no hypothesis in this env)
# ---------------------------------------------------------------------------

def _packer():
    return RequestBatcher({"p99": 64, "bulk": 256})


@pytest.mark.parametrize("seed", range(8))
def test_pack_round_trip_integrity_randomized(seed):
    """Every request gets exactly its own rows back — none dropped, none
    duplicated — across random request-size mixes."""
    rng = np.random.default_rng(seed)
    n_req = int(rng.integers(1, 12))
    sizes = [int(rng.integers(1, 700)) for _ in range(n_req)]
    reqs = [rng.integers(0, 1000, size=(n, 3)).astype(np.int32)
            for n in sizes]
    batcher = _packer()
    chunks = batcher.pack(sizes)

    # spans tile each request exactly, in order
    per_req_rows = {i: [] for i in range(n_req)}
    for chunk in chunks:
        assert chunk.n_valid <= chunk.rows
        covered = 0
        for span in chunk.spans:
            assert span.dst_start == covered       # spans tile the chunk
            covered += span.n
            per_req_rows[span.req].append((span.src_start, span.n))
        assert covered == chunk.n_valid
    for i, n in enumerate(sizes):
        spans = sorted(per_req_rows[i])
        assert spans[0][0] == 0
        assert sum(s[1] for s in spans) == n       # no drop, no dup
        pos = 0
        for start, ln in spans:
            assert start == pos                    # contiguous, in order
            pos += ln

    # gather/scatter round-trip through padded chunks
    sinks = [np.full((n, 3), -1, np.int32) for n in sizes]
    for chunk in chunks:
        rows = RequestBatcher.gather(reqs, chunk)
        padded, mask = RequestBatcher.pad(rows, chunk.rows)
        assert mask.sum() == chunk.n_valid
        RequestBatcher.scatter(padded[:chunk.n_valid], chunk, sinks)
    for got, want in zip(sinks, reqs):
        np.testing.assert_array_equal(got, want)


def test_pack_single_request_equals_plan():
    batcher = _packer()
    for n in (1, 64, 65, 300, 700):
        packed = batcher.pack([n])
        planned = batcher.plan(n)
        assert [(c.bucket, c.rows, c.start, c.n_valid) for c in packed] == \
            [(c.bucket, c.rows, c.start, c.n_valid) for c in planned]
        assert all(len(c.spans) == 1 and c.spans[0].req == 0
                   for c in packed)


def test_pack_rejects_empty_requests():
    with pytest.raises(ValueError):
        _packer().pack([5, 0, 3])


# ---------------------------------------------------------------------------
# engine-level coalescing (bit-identical, fewer dispatches, higher
# occupancy, zero recompiles)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served():
    cfg, params, state, buffers, spec, res = train_packed_dlrm(
        field_vocabs=(600, 400, 500), train_steps=25, train_batch=256, seed=3)
    engine = build_engine(cfg, params, state, buffers,
                          p99_rows=64, bulk_rows=256)
    return {"engine": engine, "cfg": cfg, "params": params, "state": state,
            "buffers": buffers, "spec": spec}


def _twin(served, queue_capacity=1024):
    """A fresh engine sharing the warm CellCache (registration is pure
    hits — no compiles), so per-engine stats/occupancy start clean."""
    from repro.models.dlrm import DLRM
    base = served["engine"]
    twin = Engine(mesh=base.mesh, cache=base.cache,
                  queue_capacity=queue_capacity)
    twin.register_packed_model(
        "dlrm", DLRM, served["cfg"], served["params"], served["state"],
        served["buffers"], shapes={"serve_p99": 64, "serve_bulk": 256})
    return twin


def _dispatches(engine):
    return sum(s["count"] for s in engine.summary().values())


def test_coalesced_bit_identical_fewer_cells_higher_occupancy(served):
    ds = SyntheticCTR(served["spec"]._replace(batch_size=20))
    reqs = [ds.batch(500 + i)["ids"] for i in range(8)]

    solo = _twin(served)
    per_request = [solo.score(r, return_logits=True) for r in reqs]
    solo_occ = solo.counters()["occupancy"]

    co = _twin(served)
    compiles_before = co.compile_count
    tickets = [co.submit(r) for r in reqs]     # N interleaved submissions
    co.drain()
    coalesced = [co.poll(t) for t in tickets]
    co_occ = co.counters()["occupancy"]

    # bit-identical results to the per-request path
    for a, b in zip(per_request, coalesced):
        np.testing.assert_array_equal(a, b)
    # fewer cell invocations (8 per-request dispatches vs packed chunks)
    assert _dispatches(co) < _dispatches(solo)
    # strictly higher occupancy on every cell the coalesced path used
    solo_total = (sum(v["valid_rows"] for v in solo_occ.values()),
                  sum(v["padded_rows"] for v in solo_occ.values()))
    co_total = (sum(v["valid_rows"] for v in co_occ.values()),
                sum(v["padded_rows"] for v in co_occ.values()))
    assert co_total[0] == solo_total[0] == 8 * 20   # same real rows
    assert co_total[1] < solo_total[1]              # fewer padded rows
    assert (co_total[0] / co_total[1]) > (solo_total[0] / solo_total[1])
    # zero recompiles: both twins re-keyed the warm executables
    assert co.compile_count == compiles_before == served["engine"].compile_count


def test_shedding_no_drop_no_dup(served):
    """Admitted requests complete with exactly their own rows even when the
    bounded queue sheds the overflow."""
    ds = SyntheticCTR(served["spec"]._replace(batch_size=10))
    reqs = [ds.batch(900 + i)["ids"] for i in range(6)]
    engine = _twin(served, queue_capacity=4)
    tickets = [engine.submit(r) for r in reqs]
    assert tickets[4] is None and tickets[5] is None   # shed at capacity 4
    assert engine.queue.counters()["shed_full"] == 2
    engine.drain()
    for r, t in zip(reqs[:4], tickets[:4]):
        np.testing.assert_array_equal(
            engine.poll(t), _twin(served).score(r, return_logits=True))
    assert engine.rstats.shed == 2


def test_deadline_shed_poll_raises(served):
    ds = SyntheticCTR(served["spec"]._replace(batch_size=5))
    engine = _twin(served)
    # virtual clock: request arrives at t=0 with a 50ms deadline; the first
    # scheduling round happens at t=1s, so it must shed, not dispatch
    t = engine.submit(ds.batch(1)["ids"], now=0.0, deadline_ms=50.0)
    engine.sched_step(now=1.0)
    with pytest.raises(RuntimeError, match="shed"):
        engine.poll(t)
    assert engine.queue.counters()["shed_deadline"] == 1


def test_request_summary_three_way_breakdown(served):
    ds = SyntheticCTR(served["spec"]._replace(batch_size=30))
    engine = _twin(served)
    for i in range(3):
        engine.score(ds.batch(50 + i)["ids"])
    rs = engine.request_summary()["score"]
    assert rs["count"] == 3
    for part in ("latency", "queue", "assembly", "compute"):
        assert rs[part]["p50_ms"] >= 0.0
        assert rs[part]["p50_ms"] <= rs[part]["p99_ms"] + 1e-9
    # per-cell summaries carry occupancy for every scored cell
    for cell in engine.summary().values():
        assert 0.0 < cell["occupancy"] <= 1.0


def test_open_loop_replay_queue_wait_under_overload(served):
    """Open-loop arrivals above capacity accumulate *virtual* queue wait —
    the wait is separable from compute in the breakdown."""
    ds = SyntheticCTR(served["spec"]._replace(batch_size=20))
    engine = _twin(served)
    engine.score(ds.batch(1)["ids"])       # warm the dispatch path
    res = run_open_loop(engine, lambda i: ds.batch(100 + i)["ids"],
                        12, 100_000.0, seed=0)   # absurd offered rate
    assert res["completed"] == 12 and res["shed"] == 0
    assert res["goodput_qps"] > 0
    rs = engine.request_summary()["score"]
    # all 12 arrive before the first dispatch completes: later requests wait
    assert rs["queue"]["p99_ms"] > 0.0


# ---------------------------------------------------------------------------
# multi-tenant scheduling at the engine level: bit-identity, manual clock,
# max-wait window, fault injection, two-tenant open loop
# ---------------------------------------------------------------------------

def _mt_twin(served, **engine_kw):
    """A fresh engine on the warm CellCache with multi-tenant knobs."""
    from repro.models.dlrm import DLRM
    base = served["engine"]
    twin = Engine(mesh=base.mesh, cache=base.cache, **engine_kw)
    twin.register_packed_model(
        "dlrm", DLRM, served["cfg"], served["params"], served["state"],
        served["buffers"], shapes={"serve_p99": 64, "serve_bulk": 256})
    return twin


def test_single_tenant_no_contention_bit_identical_zero_recompiles(served):
    """Acceptance: single-tenant/no-contention traffic through the priority
    scheduler (quotas + watermark + lanes all configured) is bit-identical
    to the plain FIFO path, with zero recompiles (CellCache-asserted)."""
    ds = SyntheticCTR(served["spec"]._replace(batch_size=20))
    reqs = [ds.batch(700 + i)["ids"] for i in range(6)]

    fifo = _twin(served)
    f_tickets = [fifo.submit(r) for r in reqs]
    fifo.drain()
    f_out = [fifo.poll(t) for t in f_tickets]

    compiles_before = served["engine"].compile_count
    mt = _mt_twin(served,
                  quotas={"default": TenantQuota(max_queued=1000,
                                                 max_inflight_rows=100_000)},
                  shed_watermark=0.9)
    m_tickets = [mt.submit(r) for r in reqs]      # all default tenant, p0
    mt.drain()
    m_out = [mt.poll(t) for t in m_tickets]

    for a, b in zip(f_out, m_out):
        np.testing.assert_array_equal(a, b)       # bit-identical
    assert mt.compile_count == compiles_before    # zero recompiles
    assert mt.queue.counters()["shed_quota"] == 0
    assert mt.queue.counters()["shed_load"] == 0


def test_manual_clock_exact_queue_wait(served):
    """With ``ManualClock`` injected, every lifecycle timestamp is virtual:
    queue-wait is exactly the time the test advanced, no wall-clock."""
    ds = SyntheticCTR(served["spec"]._replace(batch_size=5))
    clock = ManualClock()
    engine = _mt_twin(served, clock=clock)
    t = engine.submit(ds.batch(1)["ids"])        # arrives at clock()=0.0
    clock.advance(0.25)
    engine.sched_step()                          # dispatches at clock()=0.25
    req = engine._requests[t]
    assert req.queue_ms == pytest.approx(250.0)
    out = engine.poll(t)
    assert out is not None
    # compute was measured on the same (frozen) clock: exactly zero
    rs = engine.request_summary()["score"]
    assert rs["queue"]["p50_ms"] == pytest.approx(250.0)


def test_coalesce_window_holds_then_dispatches_engine(served):
    """The max-wait window at the engine level, exact virtual times: a
    light request holds; a second arrival filling the bucket releases it;
    a lone request dispatches at exactly arrival + window."""
    ds = SyntheticCTR(served["spec"]._replace(batch_size=5))
    engine = _mt_twin(served, coalesce_window_ms=100.0, clock=ManualClock())
    t1 = engine.submit(ds.batch(1)["ids"], now=0.0)       # 5 rows < 64
    engine.sched_step(now=0.01)
    assert engine._requests[t1].status == "queued"        # held
    big = SyntheticCTR(served["spec"]._replace(batch_size=60))
    t2 = engine.submit(big.batch(2)["ids"], now=0.02)     # 65 rows ≥ 64
    engine.sched_step(now=0.03)
    assert engine._requests[t1].dispatch_t == 0.03        # released together
    assert engine._requests[t2].dispatch_t == 0.03
    engine.drain(now=0.03)
    assert engine.poll(t1) is not None and engine.poll(t2) is not None

    # a lone light request: virtual drain jumps the cursor to the window
    # expiry instead of spinning, and dispatches exactly there
    t3 = engine.submit(ds.batch(3)["ids"], now=1.0)
    cursor = engine.drain(now=1.0)
    assert engine._requests[t3].dispatch_t == pytest.approx(1.1)
    assert cursor >= 1.1
    assert engine.poll(t3) is not None


def test_fault_injection_fails_only_affected_chunk(served):
    """A dispatch that raises mid-``sched_step`` fails exactly the requests
    riding that chunk: their poll raises ``RequestFailedError``, every
    other request completes bit-identically, and the engine stays
    drainable with zero stuck requests."""
    ds_big = SyntheticCTR(served["spec"]._replace(batch_size=256))
    ds_small = SyntheticCTR(served["spec"]._replace(batch_size=64))
    a, b = ds_big.batch(11)["ids"], ds_small.batch(12)["ids"]
    want_b = _twin(served).score(b, return_logits=True)

    engine = _twin(served)
    orig = engine._timed_call
    calls = {"n": 0}

    def flaky(reg, *request):
        calls["n"] += 1
        if calls["n"] == 1:           # the first chunk's compute dispatch
            raise RuntimeError("injected fault")
        return orig(reg, *request)

    engine._timed_call = flaky
    ta = engine.submit(a)             # 256 rows -> fills the bulk chunk
    tb = engine.submit(b)             # 64 rows -> its own p99 chunk
    engine.drain()
    engine._timed_call = orig

    with pytest.raises(RequestFailedError, match="injected fault"):
        engine.poll(ta)
    np.testing.assert_array_equal(engine.poll(tb), want_b)
    assert engine.rstats.failed == 1
    assert len(engine.queue) == 0 and not engine.scheduler.busy
    assert engine.queue.counters()["inflight_rows"] == {}   # quota released
    # the engine keeps serving after the fault
    np.testing.assert_array_equal(engine.score(b, return_logits=True), want_b)


def test_two_tenant_skewed_priority_open_loop(served):
    """``run_open_loop_mix``: a latency tenant (priority 0) and a bulk
    tenant (priority 1, quota-bounded) share the engine; both make
    progress and the per-tenant/per-lane split is reported."""
    ds = SyntheticCTR(served["spec"]._replace(batch_size=20))
    engine = _mt_twin(
        served, quotas={"bulk": TenantQuota(max_inflight_rows=512)})
    engine.score(ds.batch(1)["ids"])            # warm the dispatch path
    streams = [
        {"tenant": "latency", "qps": 500.0, "n_requests": 10, "priority": 0},
        {"tenant": "bulk", "qps": 500.0, "n_requests": 10, "priority": 1},
    ]
    res = run_open_loop_mix(engine, lambda i, _b: ds.batch(300 + i)["ids"],
                            streams, seed=0)
    per = res["per_stream"]
    assert per["latency"]["completed"] == 10
    assert per["bulk"]["completed"] == 10
    assert per["latency"]["goodput_qps"] > 0
    lanes = engine.request_summary(by="lane")
    assert lanes["score:p0"]["count"] == 11     # + the warm request
    assert lanes["score:p1"]["count"] == 10
    tenants = engine.request_summary(by="tenant")
    assert set(tenants) >= {"latency", "bulk"}
    assert engine.counters()["goodput"]["by_tenant"]["bulk"] == 10


# ---------------------------------------------------------------------------
# continuous-batching decode
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lm_setup():
    from repro.models.lm import LM, LMConfig
    cfg = LMConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                   head_dim=16, d_ff=64, vocab=50, remat=False)
    params, buffers = LM.init(jax.random.PRNGKey(0), cfg)
    return cfg, params, buffers


def _reference_generate(engine, prompt, max_new):
    """Per-request decode through the classic cell: one sequence alone,
    fed token-by-token (prompt replay then greedy feedback)."""
    caches, out = None, []
    toks = list(np.asarray(prompt).reshape(-1))
    for i in range(len(toks) + max_new - 1):
        tok = toks[i] if i < len(toks) else out[-1]
        logits, caches = engine.decode(np.array([[tok]], np.int32), caches)
        if i >= len(toks) - 1:
            out.append(int(np.argmax(logits[0])))
    return out


def test_continuous_batching_token_identical_and_slot_reuse(lm_setup):
    """Sequences of different lengths join/leave the running batch:
    token-identical to per-request decode, slots recycled (5 sequences
    through a 2-slot cache), zero new compiles after warmup."""
    cfg, params, buffers = lm_setup
    engine = Engine()
    engine.register(lm_decode_slotted_cell(cfg, params, buffers, batch=2,
                                           max_len=16, arch="lm"))
    session = engine.scheduler.sessions["lm"]
    warm = engine.submit_decode([1, 2], 2)
    engine.drain()
    engine.poll(warm)
    compiles = engine.compile_count

    prompts = [[3, 7, 11], [5], [9, 2], [4, 4, 4, 4], [1]]
    tickets = [engine.submit_decode(p, 4) for p in prompts]
    engine.drain()
    outs = [engine.poll(t).tolist() for t in tickets]

    # joined/left the 2-slot pool: never more than 2 active, all 5 served
    assert session.cap == 2 and len(session.active) == 0
    assert sorted(session.free) == [0, 1]
    assert engine.compile_count == compiles        # no new compiles

    ref_engine = Engine()
    ref_engine.register(lm_decode_cell(cfg, params, buffers, batch=2,
                                       max_len=16, arch="lm"))
    for p, got in zip(prompts, outs):
        assert got == _reference_generate(ref_engine, p, 4)


def test_decode_deadline_holds_while_waiting_for_a_slot(lm_setup):
    """A decode request's deadline is enforced while it waits for a free
    slot, not only while it sits in the admission queue."""
    cfg, params, buffers = lm_setup
    engine = Engine()
    engine.register(lm_decode_slotted_cell(cfg, params, buffers, batch=1,
                                           max_len=16, arch="lm"))
    # t1 takes the only slot first (it joins before t2 even arrives — under
    # EDF a deadline-carrying request in the same round would go first);
    # t2 then waits for the slot with a 50ms deadline
    t1 = engine.submit_decode([1, 2], 8, now=0.0)
    cursor = engine.sched_step(now=0.0)
    t2 = engine.submit_decode([3], 2, now=cursor, deadline_ms=50.0)
    # t2 starts waiting behind t1; by the next round (1s later) t2's
    # deadline passed long ago — it must never take the slot t1 frees
    while engine.scheduler.busy:
        cursor = engine.sched_step(now=max(cursor, 1.0))
    assert engine.poll(t1) is not None
    with pytest.raises(RuntimeError, match="shed"):
        engine.poll(t2)
    assert engine.queue.counters()["shed_deadline"] == 1


def test_decode_fault_recycles_slots_and_stays_drainable(lm_setup):
    """A decode-cell dispatch that raises fails the active jobs (poll
    raises), recycles their KV slots back to the free list, and the session
    keeps serving new sequences — no restart, no recompile."""
    cfg, params, buffers = lm_setup
    engine = Engine()
    engine.register(lm_decode_slotted_cell(cfg, params, buffers, batch=2,
                                           max_len=16, arch="lm"))
    warm = engine.submit_decode([1, 2], 2)
    engine.drain()
    engine.poll(warm)
    session = engine.scheduler.sessions["lm"]
    compiles = engine.compile_count

    t1 = engine.submit_decode([3, 7], 4)
    t2 = engine.submit_decode([5], 4)
    orig = engine._timed_call
    calls = {"n": 0}

    def flaky(reg, *request):
        calls["n"] += 1
        if calls["n"] == 2:       # fail the second decode step, mid-stream
            raise RuntimeError("decode fault")
        return orig(reg, *request)

    engine._timed_call = flaky
    engine.drain()                # must terminate: failed jobs leave slots
    engine._timed_call = orig

    for t in (t1, t2):
        with pytest.raises(RequestFailedError, match="decode fault"):
            engine.poll(t)
    assert not session.active and sorted(session.free) == [0, 1]  # recycled
    assert engine.rstats.failed == 2
    # the recycled slots serve new sequences, still zero new compiles
    t3 = engine.submit_decode([9], 3)
    engine.drain()
    assert engine.poll(t3) is not None
    assert engine.compile_count == compiles


def test_submit_rejects_unroutable_kind(served):
    with pytest.raises(ValueError, match="unroutable"):
        _twin(served).submit(np.zeros((2, 3), np.int32), kind="retrieve")


def test_poll_consumes_ticket(served):
    ds = SyntheticCTR(served["spec"]._replace(batch_size=5))
    engine = _twin(served)
    t = engine.submit(ds.batch(7)["ids"])
    assert engine.poll(t) is None          # pending: not consumed
    engine.drain()
    assert engine.poll(t) is not None
    with pytest.raises(KeyError):          # consumed by the first poll
        engine.poll(t)


def test_decode_deadline_and_capacity_guard(lm_setup):
    cfg, params, buffers = lm_setup
    engine = Engine()
    engine.register(lm_decode_slotted_cell(cfg, params, buffers, batch=2,
                                           max_len=8, arch="lm"))
    # a sequence that can't fit the compiled cache length is rejected at
    # submission (it could never join the slot pool)
    with pytest.raises(ValueError, match="max_len"):
        engine.submit_decode([1, 2, 3, 4, 5], 6)
    # occupancy of the decode cell reflects active slots per step
    t2 = engine.submit_decode([1, 2], 3)
    engine.drain()
    assert engine.poll(t2) is not None
    occ = engine.counters()["occupancy"]["lm/decode_cb"]
    assert 0.0 < occ["occupancy"] <= 1.0
