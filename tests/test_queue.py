"""The request-lifecycle stack (ISSUE 5): admission queue, coalescing
scheduler, continuous-batching decode.

Covers the acceptance criteria end-to-end:

  - coalescing: N interleaved requests are bit-identical to the per-request
    path with fewer cell invocations, strictly higher occupancy and zero
    recompiles (CellCache counters);
  - the coalescing packer: seeded-numpy randomized sweeps over request-size
    mixes (no hypothesis in this env) asserting round-trip integrity — every
    request gets exactly its own rows back, none dropped or duplicated, also
    under shedding;
  - continuous batching: sequences of different lengths join/leave the
    running decode batch, token-identical to per-request decode, KV-cache
    slots recycled with no new compiles after warmup;
  - admission policy: bounded-queue shedding, deadline shedding, and the
    three-way queue-wait / batch-assembly / compute breakdown.
"""
import jax
import numpy as np
import pytest

from repro.data.synthetic import SyntheticCTR
from repro.launch.serve import build_engine, run_open_loop, train_packed_dlrm
from repro.serve import (AdmissionQueue, Engine, RequestBatcher,
                         lm_decode_cell, lm_decode_slotted_cell)


# ---------------------------------------------------------------------------
# admission queue
# ---------------------------------------------------------------------------

def test_queue_fifo_and_kind_routing():
    q = AdmissionQueue(capacity=8)
    a = q.submit("score", "A", 3, now=0.0)
    b = q.submit("tiered", "B", 2, now=0.1)
    c = q.submit("score", "C", 5, now=0.2)
    ready, expired = q.take("score", now=1.0)
    assert [r.payload for r in ready] == ["A", "C"] and not expired
    assert a.ticket < c.ticket
    # the tiered request stayed queued, in order
    ready, _ = q.take("tiered", now=1.0)
    assert [r.payload for r in ready] == ["B"] and b is ready[0]
    assert len(q) == 0


def test_queue_sheds_on_full_and_counts():
    q = AdmissionQueue(capacity=2)
    assert q.submit("score", 0, 1, now=0.0) is not None
    assert q.submit("score", 1, 1, now=0.0) is not None
    assert q.submit("score", 2, 1, now=0.0) is None     # reject-on-full
    assert q.counters()["shed_full"] == 1
    assert q.counters()["admitted"] == 2
    with pytest.raises(ValueError):
        AdmissionQueue(capacity=0)


def test_queue_deadline_shed_at_take():
    q = AdmissionQueue(capacity=8)
    q.submit("score", "late", 1, now=0.0, deadline_ms=100.0)
    q.submit("score", "ok", 1, now=0.0, deadline_ms=10_000.0)
    ready, expired = q.take("score", now=1.0)   # 1s > 100ms deadline
    assert [r.payload for r in ready] == ["ok"]
    assert [r.payload for r in expired] == ["late"]
    assert q.counters()["shed_deadline"] == 1


# ---------------------------------------------------------------------------
# coalescing packer: seeded randomized sweeps (no hypothesis in this env)
# ---------------------------------------------------------------------------

def _packer():
    return RequestBatcher({"p99": 64, "bulk": 256})


@pytest.mark.parametrize("seed", range(8))
def test_pack_round_trip_integrity_randomized(seed):
    """Every request gets exactly its own rows back — none dropped, none
    duplicated — across random request-size mixes."""
    rng = np.random.default_rng(seed)
    n_req = int(rng.integers(1, 12))
    sizes = [int(rng.integers(1, 700)) for _ in range(n_req)]
    reqs = [rng.integers(0, 1000, size=(n, 3)).astype(np.int32)
            for n in sizes]
    batcher = _packer()
    chunks = batcher.pack(sizes)

    # spans tile each request exactly, in order
    per_req_rows = {i: [] for i in range(n_req)}
    for chunk in chunks:
        assert chunk.n_valid <= chunk.rows
        covered = 0
        for span in chunk.spans:
            assert span.dst_start == covered       # spans tile the chunk
            covered += span.n
            per_req_rows[span.req].append((span.src_start, span.n))
        assert covered == chunk.n_valid
    for i, n in enumerate(sizes):
        spans = sorted(per_req_rows[i])
        assert spans[0][0] == 0
        assert sum(s[1] for s in spans) == n       # no drop, no dup
        pos = 0
        for start, ln in spans:
            assert start == pos                    # contiguous, in order
            pos += ln

    # gather/scatter round-trip through padded chunks
    sinks = [np.full((n, 3), -1, np.int32) for n in sizes]
    for chunk in chunks:
        rows = RequestBatcher.gather(reqs, chunk)
        padded, mask = RequestBatcher.pad(rows, chunk.rows)
        assert mask.sum() == chunk.n_valid
        RequestBatcher.scatter(padded[:chunk.n_valid], chunk, sinks)
    for got, want in zip(sinks, reqs):
        np.testing.assert_array_equal(got, want)


def test_pack_single_request_equals_plan():
    batcher = _packer()
    for n in (1, 64, 65, 300, 700):
        packed = batcher.pack([n])
        planned = batcher.plan(n)
        assert [(c.bucket, c.rows, c.start, c.n_valid) for c in packed] == \
            [(c.bucket, c.rows, c.start, c.n_valid) for c in planned]
        assert all(len(c.spans) == 1 and c.spans[0].req == 0
                   for c in packed)


def test_pack_rejects_empty_requests():
    with pytest.raises(ValueError):
        _packer().pack([5, 0, 3])


# ---------------------------------------------------------------------------
# engine-level coalescing (bit-identical, fewer dispatches, higher
# occupancy, zero recompiles)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served():
    cfg, params, state, buffers, spec, res = train_packed_dlrm(
        field_vocabs=(600, 400, 500), train_steps=25, train_batch=256, seed=3)
    engine = build_engine(cfg, params, state, buffers,
                          p99_rows=64, bulk_rows=256)
    return {"engine": engine, "cfg": cfg, "params": params, "state": state,
            "buffers": buffers, "spec": spec}


def _twin(served, queue_capacity=1024):
    """A fresh engine sharing the warm CellCache (registration is pure
    hits — no compiles), so per-engine stats/occupancy start clean."""
    from repro.models.dlrm import DLRM
    base = served["engine"]
    twin = Engine(mesh=base.mesh, cache=base.cache,
                  queue_capacity=queue_capacity)
    twin.register_packed_model(
        "dlrm", DLRM, served["cfg"], served["params"], served["state"],
        served["buffers"], shapes={"serve_p99": 64, "serve_bulk": 256})
    return twin


def _dispatches(engine):
    return sum(s["count"] for s in engine.summary().values())


def test_coalesced_bit_identical_fewer_cells_higher_occupancy(served):
    ds = SyntheticCTR(served["spec"]._replace(batch_size=20))
    reqs = [ds.batch(500 + i)["ids"] for i in range(8)]

    solo = _twin(served)
    per_request = [solo.score(r, return_logits=True) for r in reqs]
    solo_occ = solo.counters()["occupancy"]

    co = _twin(served)
    compiles_before = co.compile_count
    tickets = [co.submit(r) for r in reqs]     # N interleaved submissions
    co.drain()
    coalesced = [co.poll(t) for t in tickets]
    co_occ = co.counters()["occupancy"]

    # bit-identical results to the per-request path
    for a, b in zip(per_request, coalesced):
        np.testing.assert_array_equal(a, b)
    # fewer cell invocations (8 per-request dispatches vs packed chunks)
    assert _dispatches(co) < _dispatches(solo)
    # strictly higher occupancy on every cell the coalesced path used
    solo_total = (sum(v["valid_rows"] for v in solo_occ.values()),
                  sum(v["padded_rows"] for v in solo_occ.values()))
    co_total = (sum(v["valid_rows"] for v in co_occ.values()),
                sum(v["padded_rows"] for v in co_occ.values()))
    assert co_total[0] == solo_total[0] == 8 * 20   # same real rows
    assert co_total[1] < solo_total[1]              # fewer padded rows
    assert (co_total[0] / co_total[1]) > (solo_total[0] / solo_total[1])
    # zero recompiles: both twins re-keyed the warm executables
    assert co.compile_count == compiles_before == served["engine"].compile_count


def test_shedding_no_drop_no_dup(served):
    """Admitted requests complete with exactly their own rows even when the
    bounded queue sheds the overflow."""
    ds = SyntheticCTR(served["spec"]._replace(batch_size=10))
    reqs = [ds.batch(900 + i)["ids"] for i in range(6)]
    engine = _twin(served, queue_capacity=4)
    tickets = [engine.submit(r) for r in reqs]
    assert tickets[4] is None and tickets[5] is None   # shed at capacity 4
    assert engine.queue.counters()["shed_full"] == 2
    engine.drain()
    for r, t in zip(reqs[:4], tickets[:4]):
        np.testing.assert_array_equal(
            engine.poll(t), _twin(served).score(r, return_logits=True))
    assert engine.rstats.shed == 2


def test_deadline_shed_poll_raises(served):
    ds = SyntheticCTR(served["spec"]._replace(batch_size=5))
    engine = _twin(served)
    # virtual clock: request arrives at t=0 with a 50ms deadline; the first
    # scheduling round happens at t=1s, so it must shed, not dispatch
    t = engine.submit(ds.batch(1)["ids"], now=0.0, deadline_ms=50.0)
    engine.sched_step(now=1.0)
    with pytest.raises(RuntimeError, match="shed"):
        engine.poll(t)
    assert engine.queue.counters()["shed_deadline"] == 1


def test_request_summary_three_way_breakdown(served):
    ds = SyntheticCTR(served["spec"]._replace(batch_size=30))
    engine = _twin(served)
    for i in range(3):
        engine.score(ds.batch(50 + i)["ids"])
    rs = engine.request_summary()["score"]
    assert rs["count"] == 3
    for part in ("latency", "queue", "assembly", "compute"):
        assert rs[part]["p50_ms"] >= 0.0
        assert rs[part]["p50_ms"] <= rs[part]["p99_ms"] + 1e-9
    # per-cell summaries carry occupancy for every scored cell
    for cell in engine.summary().values():
        assert 0.0 < cell["occupancy"] <= 1.0


def test_open_loop_replay_queue_wait_under_overload(served):
    """Open-loop arrivals above capacity accumulate *virtual* queue wait —
    the wait is separable from compute in the breakdown."""
    ds = SyntheticCTR(served["spec"]._replace(batch_size=20))
    engine = _twin(served)
    engine.score(ds.batch(1)["ids"])       # warm the dispatch path
    res = run_open_loop(engine, lambda i: ds.batch(100 + i)["ids"],
                        12, 100_000.0, seed=0)   # absurd offered rate
    assert res["completed"] == 12 and res["shed"] == 0
    assert res["goodput_qps"] > 0
    rs = engine.request_summary()["score"]
    # all 12 arrive before the first dispatch completes: later requests wait
    assert rs["queue"]["p99_ms"] > 0.0


# ---------------------------------------------------------------------------
# continuous-batching decode
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lm_setup():
    from repro.models.lm import LM, LMConfig
    cfg = LMConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                   head_dim=16, d_ff=64, vocab=50, remat=False)
    params, buffers = LM.init(jax.random.PRNGKey(0), cfg)
    return cfg, params, buffers


def _reference_generate(engine, prompt, max_new):
    """Per-request decode through the classic cell: one sequence alone,
    fed token-by-token (prompt replay then greedy feedback)."""
    caches, out = None, []
    toks = list(np.asarray(prompt).reshape(-1))
    for i in range(len(toks) + max_new - 1):
        tok = toks[i] if i < len(toks) else out[-1]
        logits, caches = engine.decode(np.array([[tok]], np.int32), caches)
        if i >= len(toks) - 1:
            out.append(int(np.argmax(logits[0])))
    return out


def test_continuous_batching_token_identical_and_slot_reuse(lm_setup):
    """Sequences of different lengths join/leave the running batch:
    token-identical to per-request decode, slots recycled (5 sequences
    through a 2-slot cache), zero new compiles after warmup."""
    cfg, params, buffers = lm_setup
    engine = Engine()
    engine.register(lm_decode_slotted_cell(cfg, params, buffers, batch=2,
                                           max_len=16, arch="lm"))
    session = engine.scheduler.sessions["lm"]
    warm = engine.submit_decode([1, 2], 2)
    engine.drain()
    engine.poll(warm)
    compiles = engine.compile_count

    prompts = [[3, 7, 11], [5], [9, 2], [4, 4, 4, 4], [1]]
    tickets = [engine.submit_decode(p, 4) for p in prompts]
    engine.drain()
    outs = [engine.poll(t).tolist() for t in tickets]

    # joined/left the 2-slot pool: never more than 2 active, all 5 served
    assert session.cap == 2 and len(session.active) == 0
    assert sorted(session.free) == [0, 1]
    assert engine.compile_count == compiles        # no new compiles

    ref_engine = Engine()
    ref_engine.register(lm_decode_cell(cfg, params, buffers, batch=2,
                                       max_len=16, arch="lm"))
    for p, got in zip(prompts, outs):
        assert got == _reference_generate(ref_engine, p, 4)


def test_decode_deadline_holds_while_waiting_for_a_slot(lm_setup):
    """A decode request's deadline is enforced while it waits for a free
    slot, not only while it sits in the admission queue."""
    cfg, params, buffers = lm_setup
    engine = Engine()
    engine.register(lm_decode_slotted_cell(cfg, params, buffers, batch=1,
                                           max_len=16, arch="lm"))
    # t1 takes the only slot; t2 waits with a 50ms deadline
    t1 = engine.submit_decode([1, 2], 8, now=0.0)
    t2 = engine.submit_decode([3], 2, now=0.0, deadline_ms=50.0)
    # the first round admits both, joins t1, and t2 starts waiting; by the
    # next round (1s later) t2's deadline passed long ago — it must never
    # take the slot t1 frees
    cursor = engine.sched_step(now=0.0)
    while engine.scheduler.busy:
        cursor = engine.sched_step(now=max(cursor, 1.0))
    assert engine.poll(t1) is not None
    with pytest.raises(RuntimeError, match="shed"):
        engine.poll(t2)
    assert engine.queue.counters()["shed_deadline"] == 1


def test_submit_rejects_unroutable_kind(served):
    with pytest.raises(ValueError, match="unroutable"):
        _twin(served).submit(np.zeros((2, 3), np.int32), kind="retrieve")


def test_poll_consumes_ticket(served):
    ds = SyntheticCTR(served["spec"]._replace(batch_size=5))
    engine = _twin(served)
    t = engine.submit(ds.batch(7)["ids"])
    assert engine.poll(t) is None          # pending: not consumed
    engine.drain()
    assert engine.poll(t) is not None
    with pytest.raises(KeyError):          # consumed by the first poll
        engine.poll(t)


def test_decode_deadline_and_capacity_guard(lm_setup):
    cfg, params, buffers = lm_setup
    engine = Engine()
    engine.register(lm_decode_slotted_cell(cfg, params, buffers, batch=2,
                                           max_len=8, arch="lm"))
    # a sequence that can't fit the compiled cache length is rejected at
    # submission (it could never join the slot pool)
    with pytest.raises(ValueError, match="max_len"):
        engine.submit_decode([1, 2, 3, 4, 5], 6)
    # occupancy of the decode cell reflects active slots per step
    t2 = engine.submit_decode([1, 2], 3)
    engine.drain()
    assert engine.poll(t2) is not None
    occ = engine.counters()["occupancy"]["lm/decode_cb"]
    assert 0.0 < occ["occupancy"] <= 1.0
