"""Capacity-bucketed all-to-all lookup suite (ISSUE 10).

Covers the ``lookup_comms="a2a"`` path of ``repro.dist.shard`` and the new
sharded backward paths:

  - bucket-planner properties in the ``tests/lifecycle_props.py`` style
    (seeded-numpy sweeps, plain asserts): no id dropped or duplicated under
    overflow, slots unique and bucket-local, spill bounded by
    ``spill_capacity``;
  - bit-exact parity a2a vs psum vs the single-device reference on 1x1,
    1x4, 2x2 and 1x2x2 meshes — at full capacity, under a forced-overflow
    capacity, and through the Pallas kernel path;
  - engine-level: ``lookup_comms`` forks the cell fingerprint, repeat
    shapes recompile nothing (CellCache counters);
  - grad parity for the sharded ``embedding_bag`` / ``flash_attention``
    backward paths vs ``jax.value_and_grad`` on the unsharded kernels,
    plus the explicit ~1e-6 psum reassociation tolerance pin for
    ``sharded_embedding_bag``;
  - HLO attribution: the compiled a2a cell really moves its bytes through
    ``all-to-all`` (and the psum cell through ``all-reduce``), as
    ``hlo_analysis`` reports them to roofline/BC501.

Marked ``multidevice`` like tests/test_shard.py; on single-device sessions
the subprocess fallback there re-runs this file under 4 virtual devices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.inference import packed_lookup
from repro.dist import shard
from repro.dist.mesh import host_mesh, make_device_mesh, use_mesh

from test_shard import _mesh, _random_packed_table

multidevice = pytest.mark.multidevice

CAPACITIES = (None, 8, 1)  # full slice / partial / forced overflow


@pytest.fixture(scope="module")
def served_model():
    from repro.launch.serve import train_packed_dlrm
    return train_packed_dlrm(field_vocabs=(150, 100, 120), train_steps=10,
                             train_batch=128, d_embed=8, mlp_hidden=(16,),
                             seed=4)


# ---------------------------------------------------------------------------
# bucket planner properties (lifecycle_props style: seeded sweeps, no drops)
# ---------------------------------------------------------------------------

def check_plan(owner, valid, n_shards, capacity):
    """Assert the BucketPlan contract over one (owner, valid) instance."""
    plan = shard.plan_buckets(jnp.asarray(owner), jnp.asarray(valid),
                              n_shards=n_shards, capacity=capacity)
    slot = np.asarray(plan.slot)
    inb = np.asarray(plan.in_bucket)
    spl = np.asarray(plan.spilled)
    counts = np.asarray(plan.counts)
    owner = np.asarray(owner)
    valid = np.asarray(valid)

    # no drop, no dup: every valid id is bucketed XOR spilled
    assert not (inb & spl).any()
    np.testing.assert_array_equal(inb | spl, valid)
    assert not (inb & ~valid).any() and not (spl & ~valid).any()

    o2 = owner.reshape(-1, owner.shape[-1])
    v2 = valid.reshape(-1, owner.shape[-1])
    i2 = inb.reshape(-1, owner.shape[-1])
    s2 = slot.reshape(-1, owner.shape[-1])
    c2 = counts.reshape(-1, n_shards)
    for sl in range(o2.shape[0]):
        # slots of bucketed ids are unique and land in the owner's bucket
        used = s2[sl][i2[sl]]
        assert len(set(used.tolist())) == len(used)
        np.testing.assert_array_equal(used // capacity, o2[sl][i2[sl]])
        # counts = raw per-bucket demand; occupancy = min(demand, capacity)
        for dest in range(n_shards):
            demand = int((v2[sl] & (o2[sl] == dest)).sum())
            assert c2[sl, dest] == demand
            got = int((i2[sl] & (o2[sl] == dest)).sum())
            assert got == min(demand, capacity)
    # total spill bounded by the static spill buffer
    per_slice_spill = spl.reshape(-1, owner.shape[-1]).sum(axis=-1)
    cap_bound = shard.spill_capacity(owner.shape[-1], capacity, n_shards)
    assert (per_slice_spill <= cap_bound).all()


def test_plan_buckets_properties_sweep():
    rng = np.random.default_rng(11)
    for _ in range(40):
        n_shards = int(rng.integers(2, 5))
        slice_len = int(rng.integers(1, 24))
        n_slices = int(rng.integers(1, 4))
        capacity = int(rng.integers(1, slice_len + 1))
        shape = (n_slices, slice_len) if n_slices > 1 else (slice_len,)
        owner = rng.integers(0, n_shards, size=shape).astype(np.int32)
        valid = rng.random(shape) < rng.choice([0.3, 0.8, 1.0])
        check_plan(owner, valid, n_shards, capacity)


def test_plan_buckets_all_one_owner_overflow():
    """Worst case: every id of a slice targets one shard at capacity 1 —
    all but the first spill, none drop."""
    owner = np.zeros((2, 9), np.int32)
    valid = np.ones((2, 9), bool)
    check_plan(owner, valid, 4, 1)
    plan = shard.plan_buckets(jnp.asarray(owner), jnp.asarray(valid),
                              n_shards=4, capacity=1)
    assert int(np.asarray(plan.in_bucket).sum()) == 2   # one per slice
    assert int(np.asarray(plan.spilled).sum()) == 16
    assert shard.spill_capacity(9, 1, 4) >= 8  # per-slice bound holds


def test_spill_capacity_bound():
    # per slice at most slice_len - capacity ids can overflow (the first
    # `capacity` of any bucket fit by construction)
    assert shard.spill_capacity(16, 16, 4) == 0
    assert shard.spill_capacity(16, 4, 4) == 4 * 12
    assert shard.spill_capacity(3, 8, 2) == 0  # capacity clamps at slice


# ---------------------------------------------------------------------------
# lookup parity: a2a vs psum vs single-device reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mesh_shape", [(1, 1), (1, 4), (2, 2)])
@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("capacity", CAPACITIES)
@multidevice
def test_a2a_lookup_parity(mesh_shape, use_kernel, capacity, rng):
    table, meta = _random_packed_table()
    ids = jnp.asarray(rng.integers(0, meta["n"], size=(24, 3)), jnp.int32)
    ref = np.asarray(jax.jit(
        lambda t, i: packed_lookup(t, meta, i))(table, ids))
    with use_mesh(_mesh(mesh_shape)):
        a2a = jax.jit(lambda t, i: shard.sharded_packed_lookup(
            t, meta, i, use_kernel=use_kernel, lookup_comms="a2a",
            bucket_capacity=capacity))(table, ids)
        psum = jax.jit(lambda t, i: shard.sharded_packed_lookup(
            t, meta, i, use_kernel=use_kernel))(table, ids)
    np.testing.assert_array_equal(np.asarray(a2a), ref)
    np.testing.assert_array_equal(np.asarray(psum), ref)


@pytest.mark.parametrize("capacity", CAPACITIES)
@multidevice
def test_a2a_lookup_parity_pod_mesh(capacity, rng):
    """1x2x2 ("pod", "data", "model") mesh: default rows over "model", and
    rows over the ("pod", "model") tuple via host_packed_table_pspecs —
    the multi-host layout, exercised with pod laid over local devices."""
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    table, meta = _random_packed_table(n=150, row_pad_multiple=1)
    ids = jnp.asarray(rng.integers(0, meta["n"], size=(41,)), jnp.int32)
    ref = np.asarray(jax.jit(
        lambda t, i: packed_lookup(t, meta, i))(table, ids))
    for mesh_shape, rows_axes in [((1, 2, 2), ("model",)),
                                  ((2, 1, 2), ("pod", "model"))]:
        mesh = make_device_mesh(mesh_shape, ("pod", "data", "model"))
        with use_mesh(mesh):
            got = jax.jit(lambda t, i, _ra=rows_axes: shard.sharded_packed_lookup(
                t, meta, i, rows_axes=_ra, lookup_comms="a2a",
                bucket_capacity=capacity))(table, ids)
        np.testing.assert_array_equal(np.asarray(got), ref, err_msg=str(
            (mesh_shape, rows_axes)))


@pytest.mark.parametrize("mesh_shape", [(1, 4), (2, 2)])
@pytest.mark.parametrize("capacity", (None, 4, 1))
@multidevice
def test_tiered_a2a_parity(mesh_shape, capacity, rng):
    from repro.cache import TieredTableStore
    from repro.cache.tiers import tiered_hot_lookup
    from repro.embeddings.frequency import zipf_frequencies
    table, meta = _random_packed_table()
    store = TieredTableStore(table, meta, zipf_frequencies(meta["n"], seed=1),
                             0.4)
    ids = jnp.asarray(rng.integers(0, meta["n"], size=(37,)), jnp.int32)
    ref = np.asarray(jax.jit(lambda h, i: tiered_hot_lookup(
        h, meta["bits"], meta["d"], i))(store.hot, ids))
    with use_mesh(_mesh(mesh_shape)):
        got = jax.jit(lambda h, i: shard.sharded_tiered_hot_lookup(
            h, meta["bits"], meta["d"], i, lookup_comms="a2a",
            bucket_capacity=capacity))(store.hot, ids)
    np.testing.assert_array_equal(np.asarray(got), ref)


def test_lookup_comms_validation(rng):
    table, meta = _random_packed_table()
    ids = jnp.asarray(rng.integers(0, meta["n"], size=(8,)), jnp.int32)
    with pytest.raises(ValueError, match="lookup_comms"):
        shard.sharded_packed_lookup(table, meta, ids, lookup_comms="ring")
    with pytest.raises(ValueError, match="lookup_comms"):
        shard.sharded_tiered_hot_lookup({}, meta["bits"], meta["d"], ids,
                                        lookup_comms="ring")


def test_route_stats_deterministic(rng):
    table, meta = _random_packed_table()
    ids = jnp.asarray(rng.integers(0, meta["n"], size=(64,)), jnp.int32)
    a = shard.lookup_route_stats(table, meta, ids, n_shards=4,
                                 bucket_capacity=4)
    b = shard.lookup_route_stats(table, meta, ids, n_shards=4,
                                 bucket_capacity=4)
    assert a == b
    assert a["routed"] == a["bucketed"] + a["spilled"]
    assert a["capacity"] == 4 and a["slice_len"] == 16
    full = shard.lookup_route_stats(table, meta, ids, n_shards=4)
    assert full["spilled"] == 0 and full["capacity"] == 16


# ---------------------------------------------------------------------------
# engine: fingerprint fork + zero recompiles on repeat shapes
# ---------------------------------------------------------------------------

@multidevice
def test_engine_a2a_parity_and_zero_recompile(served_model):
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    from repro.data.synthetic import SyntheticCTR
    from repro.launch.serve import build_engine
    cfg, params, state, buffers, spec, res = served_model
    ids = SyntheticCTR(spec._replace(batch_size=300)).batch(50_000)["ids"]

    ref_engine = build_engine(cfg, params, state, buffers, p99_rows=64,
                              bulk_rows=256, mesh=host_mesh(1, 1),
                              shard_lookup=False)
    ref = ref_engine.score(ids)

    engine = build_engine(cfg, params, state, buffers, p99_rows=64,
                          bulk_rows=256, mesh=_mesh((2, 2)),
                          lookup_comms="a2a", bucket_capacity=16)
    got = engine.score(ids)
    np.testing.assert_array_equal(got, ref)

    # repeat shape on a warm engine ⇒ zero recompiles
    n_compiles = engine.compile_count
    engine.score(ids)
    assert engine.compile_count == n_compiles
    assert engine.counters()["hits"] == 0


@multidevice
def test_lookup_comms_forks_cell_fingerprint(served_model):
    """psum and a2a cells of the same shape must not share a cache entry —
    ``lookup_comms``/``bucket_capacity`` are part of the cell meta."""
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    from repro.models.dlrm import DLRM
    from repro.serve.cells import packed_score_cell
    cfg, params, state, buffers, spec, res = served_model
    mk = lambda comms, cap: packed_score_cell(  # noqa: E731
        DLRM, cfg, params, state, buffers, batch=64, arch="dlrm",
        shape="p99", shard_lookup=True, lookup_comms=comms,
        bucket_capacity=cap)
    fps = {mk("psum", None).fingerprint, mk("a2a", None).fingerprint,
           mk("a2a", 8).fingerprint}
    assert len(fps) == 3


# ---------------------------------------------------------------------------
# sharded backward paths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mesh_shape", [(1, 4), (2, 2), (4, 1)])
@multidevice
def test_embedding_bag_grad_parity(mesh_shape, rng):
    from repro.kernels.embedding_bag.ops import embedding_bag_kernel
    rows, d, B, L = 64, 8, 16, 6
    tab = jnp.asarray(rng.normal(size=(rows, d)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, rows, size=(B, L)).astype(np.int32))
    mask = jnp.asarray(rng.random((B, L)) < 0.8)

    def loss_ref(t):
        return jnp.sum(embedding_bag_kernel(t, ids, mask, True) ** 2)

    lr, gr = jax.jit(jax.value_and_grad(loss_ref))(tab)
    mesh = _mesh(mesh_shape)

    def loss_sh(t):
        return jnp.sum(
            shard.sharded_embedding_bag(t, ids, mask, mesh=mesh) ** 2)

    ls, gs = jax.jit(jax.value_and_grad(loss_sh))(tab)
    np.testing.assert_allclose(float(ls), float(lr), rtol=2e-6)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(gr),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("mesh_shape", [(1, 4), (2, 2)])
@multidevice
def test_embedding_bag_psum_tolerance(mesh_shape, rng):
    """The documented ~1e-6 psum reassociation tolerance, pinned: when the
    row axis really splits, the sharded forward may differ from the
    single-device kernel only by reassociation of the bag sum — bounded at
    1e-6 absolute for O(1)-magnitude rows. A reduction-order change that
    drifts past this fails here instead of silently."""
    from repro.kernels.embedding_bag.ops import embedding_bag_kernel
    rows, d, B, L = 96, 16, 32, 8
    tab = jnp.asarray(rng.normal(size=(rows, d)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, rows, size=(B, L)).astype(np.int32))
    mask = jnp.asarray(rng.random((B, L)) < 0.9)
    ref = np.asarray(embedding_bag_kernel(tab, ids, mask, True))
    with use_mesh(_mesh(mesh_shape)):
        got = np.asarray(jax.jit(lambda t, i, m: shard.sharded_embedding_bag(
            t, i, m))(tab, ids, mask))
    np.testing.assert_allclose(got, ref, rtol=0, atol=1e-6)


@pytest.mark.parametrize("mesh_shape", [(1, 4), (2, 2)])
@multidevice
def test_flash_attention_grad_parity(mesh_shape, rng):
    """Sharded flash grads are bit-exact vs the unsharded kernel (the bwd
    kernel runs per-device on whole heads — no cross-shard reduction
    touches dq/dk/dv)."""
    from repro.kernels.flash_attention.ops import flash_attention_kernel
    B, S, H, hd = 4, 32, 4, 8
    q, k, v = (jnp.asarray(rng.normal(size=(B, S, H, hd)).astype(np.float32))
               for _ in range(3))

    def loss_ref(q, k, v):
        return jnp.sum(flash_attention_kernel(q, k, v, bq=16, bk=16) ** 2)

    vr, gr = jax.jit(jax.value_and_grad(loss_ref, argnums=(0, 1, 2)))(q, k, v)
    mesh = _mesh(mesh_shape)

    def loss_sh(q, k, v):
        return jnp.sum(shard.sharded_flash_attention(
            q, k, v, bq=16, bk=16, mesh=mesh) ** 2)

    vs, gs = jax.jit(jax.value_and_grad(loss_sh, argnums=(0, 1, 2)))(q, k, v)
    np.testing.assert_allclose(float(vs), float(vr), rtol=1e-5)
    for a, b in zip(gs, gr):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# HLO attribution: the a2a cell moves bytes through all-to-all
# ---------------------------------------------------------------------------

@multidevice
def test_hlo_attributes_all_to_all(rng):
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    from repro.launch.hlo_analysis import analyze
    table, meta = _random_packed_table()
    ids = jnp.asarray(rng.integers(0, meta["n"], size=(64,)), jnp.int32)
    mesh = _mesh((1, 4))

    def coll(comms, cap=None):
        jitted = jax.jit(lambda t, i: shard.sharded_packed_lookup(
            t, meta, i, mesh=mesh, lookup_comms=comms, bucket_capacity=cap))
        txt = jitted.lower(table, ids).compile().as_text()
        return analyze(txt)["collectives_per_device"]

    a2a = coll("a2a")
    assert "all-to-all" in a2a and a2a["all-to-all"]["bytes"] > 0
    assert a2a["all-to-all"]["count"] == 2  # ids out, packed words back
    psum = coll("psum")
    assert "all-to-all" not in psum
    # the headline claim: fewer collective bytes than the dense psum merge
    # at model-axis width 4 (d=12 f32 partials vs <=3-word packed rows)
    assert a2a["total_bytes"] < psum["total_bytes"]
    # forced overflow adds the integer spill psum but stays attributed
    spill = coll("a2a", cap=1)
    assert "all-reduce" in spill and spill["all-reduce"]["bytes"] > 0
