"""Interface + semantic tests for the Table-3 baseline compressors."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import get_compressor

CASES = [
    ("plain", {}, 1.0),
    ("lsq", {"bits": 6}, 6 / 32),
    ("lsq", {"bits": 4}, 4 / 32),
    ("alpt", {"bits": 8}, 8 / 32),
    ("qr", {"k": 2}, None),
    ("pep", {}, None),
    ("optfs", {"total_steps": 100}, None),
    ("mpe_search", None, None),
]


@pytest.mark.parametrize("name,cfg,ratio", CASES)
def test_interface(name, cfg, ratio, rng):
    C = get_compressor(name)
    key = jax.random.PRNGKey(0)
    freqs = rng.zipf(1.3, 512).astype(np.float64)
    p, b = C.init(key, 512, 16, freqs, cfg)
    ids = jnp.asarray(rng.integers(0, 512, (64, 4)))
    out = C.lookup(p, b, ids, cfg, train=True, step=jnp.asarray(5))
    assert out.shape == (64, 4, 16)
    assert np.isfinite(np.asarray(out)).all()
    r = C.storage_ratio(p, b, cfg)
    if ratio is not None:
        assert abs(r - ratio) < 1e-6
    assert 0.0 <= r <= 1.01
    # grads exist and are finite
    g = jax.grad(lambda pp: jnp.sum(
        C.lookup(pp, b, ids, cfg, train=True, step=jnp.asarray(5)) ** 2))(p)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()


def test_alpt_stays_on_grid(rng):
    """ALPT invariant: after post_update the table is exactly b-bit valued."""
    C = get_compressor("alpt")
    cfg = {"bits": 8}
    p, b = C.init(jax.random.PRNGKey(0), 256, 8, None, cfg)
    # simulate an optimizer perturbation off-grid
    p = dict(p, emb=p["emb"] + 1e-4 * jax.random.normal(jax.random.PRNGKey(1),
                                                        p["emb"].shape))
    p = C.post_update(p, b, cfg, jax.random.PRNGKey(2))
    v = np.asarray(p["emb"]) / float(p["alpha"])
    np.testing.assert_allclose(v, np.round(v), atol=1e-4)
    assert v.min() >= -128 and v.max() <= 127


def test_qr_compression_is_half(rng):
    C = get_compressor("qr")
    p, b = C.init(jax.random.PRNGKey(0), 10_000, 16, None, {"k": 2})
    assert abs(C.storage_ratio(p, b, {"k": 2}) - 0.5) < 1e-3
    # quotient sharing: ids 2k and 2k+1 share the quotient row
    e0 = C.lookup(p, b, jnp.asarray([[0]]), {"k": 2})
    e1 = C.lookup(p, b, jnp.asarray([[1]]), {"k": 2})
    q = np.asarray(p["quot"][0])
    r0, r1 = np.asarray(p["rem"][0]), np.asarray(p["rem"][1])
    np.testing.assert_allclose(np.asarray(e0)[0, 0], q * r0, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(e1)[0, 0], q * r1, rtol=1e-6)


def test_optfs_gates_harden_at_eval(rng):
    C = get_compressor("optfs")
    cfg = {"total_steps": 100}
    p, b = C.init(jax.random.PRNGKey(0), 64, 8, None, cfg)
    p = dict(p, gate_logit=jnp.asarray(rng.normal(0, 2, (64,)), jnp.float32))
    ids = jnp.arange(64).reshape(1, -1)
    out = C.lookup(p, b, ids, cfg, train=False)
    closed = np.asarray(p["gate_logit"]) <= 0
    np.testing.assert_array_equal(np.asarray(out)[0, closed], 0.0)


def test_pep_prunes_below_threshold(rng):
    C = get_compressor("pep")
    p, b = C.init(jax.random.PRNGKey(0), 64, 8, None, {})
    p = dict(p, thresh_logit=jnp.full((8,), 0.0))  # sigmoid = 0.5 threshold
    ids = jnp.arange(64).reshape(1, -1)
    out = np.asarray(C.lookup(p, b, ids, {}))
    emb = np.asarray(p["emb"])
    np.testing.assert_array_equal(out[0][np.abs(emb) <= 0.5], 0.0)


def test_packed_compressor_lookup(rng):
    C = get_compressor("packed")
    cfg = {"bits": (0, 1, 2, 3, 4, 5, 6), "d": 8, "n": 256}
    p, b = C.init(jax.random.PRNGKey(0), 256, 8, rng.zipf(1.3, 256), cfg)
    ids = jnp.asarray(rng.integers(0, 256, (32,)))
    out = C.lookup(p, b, ids, cfg)
    assert out.shape == (32, 8)
    assert np.isfinite(np.asarray(out)).all()
    assert C.storage_ratio(p, b, cfg) < 0.5
