"""Structural validation of every dry-run cell: specs and shardings must have
matching pytree structure, and pspec ranks must match array ranks. The real
lower+compile runs in launch/dryrun.py (512 fake devices); this guards the
cell definitions cheaply on 1 device."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ALL_ARCHS, get_arch
from repro.launch.cells import build_cell

CELLS = [(a, s) for a in ALL_ARCHS() for s in get_arch(a).shapes]


@pytest.mark.parametrize("arch_id,shape", CELLS,
                         ids=[f"{a}-{s}" for a, s in CELLS])
def test_cell_structure(arch_id, shape):
    cell = build_cell(arch_id, shape, multi_pod=False)
    assert len(cell.input_specs) == len(cell.in_pspecs)
    for spec_tree, ps_tree in zip(cell.input_specs, cell.in_pspecs):
        specs = jax.tree.leaves(spec_tree)
        pspecs = jax.tree.leaves(ps_tree,
                                 is_leaf=lambda x: isinstance(x, P))
        assert len(specs) == len(pspecs), (
            f"{cell.name}: {len(specs)} arrays vs {len(pspecs)} pspecs")
        for sd, ps in zip(specs, pspecs):
            assert isinstance(ps, P), (cell.name, ps)
            assert len(ps) <= max(sd.ndim, 1), (cell.name, sd.shape, ps)
            # divisibility: named axes must divide the dim (16 per axis)
            for dim, axes in zip(sd.shape, ps):
                if axes is None:
                    continue
                axes = (axes,) if isinstance(axes, str) else axes
                size = 1
                for ax in axes:
                    size *= {"pod": 2, "data": 16, "model": 16}[ax]
                assert dim % size == 0 or dim >= size, (
                    f"{cell.name}: dim {dim} not shardable by {axes}")
