"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp refs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st
from conftest import hyp_examples

from repro.core import packing, quantizer
from repro.core.mpe import MPEConfig
from repro.kernels.mpe_lookup.kernel import packed_lookup_pallas
from repro.kernels.mpe_lookup.ref import packed_lookup_ref
from repro.kernels.mpe_qat.ops import mixed_expectation_kernel
from repro.kernels.mpe_qat.ref import mixed_expectation_ref
from repro.kernels.embedding_bag.ops import embedding_bag_kernel
from repro.kernels.embedding_bag.ref import embedding_bag_ref

BITS = MPEConfig().bits


@pytest.mark.parametrize("b", [1, 2, 3, 4, 5, 6, 7, 8])
@pytest.mark.parametrize("d", [8, 16, 50, 64])
def test_lookup_kernel_matches_ref(b, d, rng):
    n_b, p_b = quantizer.int_bounds(b)
    codes = rng.integers(n_b, p_b + 1, (64, d)).astype(np.int32)
    words = packing.pack_codes(jnp.asarray(codes), b)
    ids = jnp.asarray(rng.integers(0, 64, (33,)), jnp.int32)
    alpha = jnp.float32(0.01)
    beta = jnp.asarray(rng.normal(0, 1e-3, d), jnp.float32)
    k = packed_lookup_pallas(ids, words, alpha, beta, b=b, d=d)
    r = packed_lookup_ref(ids, words, alpha, beta, b=b, d=d)
    np.testing.assert_allclose(np.asarray(k), np.asarray(r), rtol=1e-6)


@settings(max_examples=hyp_examples(8), deadline=None)
@given(n_rows=st.integers(8, 600), d=st.sampled_from([16, 32]),
       seed=st.integers(0, 999))
def test_qat_kernel_sweep(n_rows, d, seed):
    rng = np.random.default_rng(seed)
    m = len(BITS)
    rows = jnp.asarray(rng.normal(0, 3e-3, (n_rows, d)), jnp.float32)
    probs = jax.nn.softmax(jnp.asarray(rng.normal(0, 1, (n_rows, m)),
                                       jnp.float32), -1)
    alpha = jnp.asarray([quantizer.init_alpha(3e-3, b) for b in BITS])
    beta = jnp.asarray(rng.normal(0, 1e-4, (d,)), jnp.float32)
    out_k = mixed_expectation_kernel(rows, probs, alpha, beta, BITS)
    out_r = mixed_expectation_ref(rows, probs, alpha, beta, bits=BITS)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-7)


def test_qat_kernel_grads_match_ref(rng):
    m = len(BITS)
    rows = jnp.asarray(rng.normal(0, 3e-3, (300, 16)), jnp.float32)
    probs = jax.nn.softmax(jnp.asarray(rng.normal(0, 1, (300, m)), jnp.float32), -1)
    alpha = jnp.asarray([quantizer.init_alpha(3e-3, b) for b in BITS])
    beta = jnp.asarray(rng.normal(0, 1e-4, (16,)), jnp.float32)

    def lk(r, p, a, be):
        return jnp.sum(jnp.sin(mixed_expectation_kernel(r, p, a, be, BITS)))

    def lr(r, p, a, be):
        return jnp.sum(jnp.sin(mixed_expectation_ref(r, p, a, be, bits=BITS)))

    gk = jax.grad(lk, argnums=(0, 1, 2, 3))(rows, probs, alpha, beta)
    gr = jax.grad(lr, argnums=(0, 1, 2, 3))(rows, probs, alpha, beta)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("shape", [(4, 3, 16), (16, 7, 32), (8, 1, 8)])
def test_embedding_bag_kernel(shape, dtype, rng):
    b, l, d = shape
    tab = jnp.asarray(rng.normal(0, 1, (200, d)), dtype)
    ids = jnp.asarray(rng.integers(0, 200, (b, l)))
    mask = jnp.asarray(rng.random((b, l)) < 0.8)
    k = embedding_bag_kernel(tab, ids, mask)
    r = embedding_bag_ref(tab, ids, mask)
    np.testing.assert_allclose(np.asarray(k), np.asarray(r), rtol=1e-5,
                               atol=1e-6)


def test_embedding_bag_grad(rng):
    tab = jnp.asarray(rng.normal(0, 1, (100, 16)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 100, (8, 5)))
    mask = jnp.ones((8, 5), bool)
    gk = jax.grad(lambda t: jnp.sum(embedding_bag_kernel(t, ids, mask) ** 2))(tab)
    gr = jax.grad(lambda t: jnp.sum(embedding_bag_ref(t, ids, mask) ** 2))(tab)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr), rtol=1e-5,
                               atol=1e-6)
