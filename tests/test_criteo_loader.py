"""Real-Criteo-format TSV loader against a generated mini fixture."""
import os
import tempfile

import numpy as np

from repro.data.criteo import (CriteoTSV, N_FIELDS, build_criteo_vocab,
                               frequencies_from_counts, vocab_sizes)


def _write_fixture(path, rows=60, seed=0):
    rng = np.random.default_rng(seed)
    with open(path, "w") as f:
        for _ in range(rows):
            label = rng.integers(0, 2)
            ints = [("" if rng.random() < 0.2 else str(rng.integers(0, 5000)))
                    for _ in range(13)]
            cats = [("" if rng.random() < 0.1 else
                     f"{rng.integers(0, 8):08x}") for _ in range(26)]
            f.write("\t".join([str(label), *ints, *cats]) + "\n")


def test_criteo_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "mini.txt")
        _write_fixture(path)
        vocabs, counts = build_criteo_vocab(path, min_count=2)
        sizes = vocab_sizes(vocabs)
        assert len(sizes) == N_FIELDS
        assert all(s >= 1 for s in sizes)

        ds = CriteoTSV(path, vocabs, batch_size=16)
        batches = list(ds)
        assert all(b["ids"].shape == (16, N_FIELDS) for b in batches)
        assert all(b["label"].shape == (16,) for b in batches)
        # ids within each field's vocab
        for b in batches:
            for fi in range(N_FIELDS):
                assert b["ids"][:, fi].max() < sizes[fi]

        freqs = frequencies_from_counts(vocabs, counts)
        assert freqs.shape == (sum(sizes),)
        assert (freqs > 0).all()


def test_criteo_rare_tokens_hit_oov():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "mini.txt")
        # one row with unique hex tokens -> all rare -> OOV on reload
        with open(path, "w") as f:
            f.write("\t".join(["1"] + ["7"] * 13 + [f"{i:08x}" for i in
                                                    range(100, 126)]) + "\n")
            f.write("\t".join(["0"] + ["7"] * 13 + [f"{i:08x}" for i in
                                                    range(200, 226)]) + "\n")
        vocabs, _ = build_criteo_vocab(path, min_count=2)
        ds = CriteoTSV(path, vocabs, batch_size=2)
        b = next(iter(ds))
        # categorical fields (appearing once each) -> OOV id 0
        assert (b["ids"][:, 13:] == 0).all()
        # the shared integer token survives the filter
        assert (b["ids"][:, :13] > 0).all()
