"""Flash attention Pallas kernel vs exact-softmax oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st
from conftest import hyp_examples

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ops import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.nn.attention import gqa_attention

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("s,bq,bk", [(32, 8, 8), (64, 16, 32), (64, 64, 64)])
def test_flash_matches_oracle(s, bq, bk, causal):
    q = jax.random.normal(KEY, (3, s, 16))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (3, s, 16))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (3, s, 16))
    out = flash_attention_pallas(q, k, v, causal=causal, bq=bq, bk=bk)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_flash_gqa_wrapper_matches_module():
    q = jax.random.normal(KEY, (2, 32, 8, 16))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 32, 4, 16))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 32, 4, 16))
    ref = gqa_attention(q, k, v, n_heads=8, n_kv_heads=4, causal=True)
    out = flash_attention_kernel(q, k, v, bq=8, bk=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_flash_backward_matches_autodiff(causal):
    from repro.kernels.flash_attention.kernel import (flash_attention_bwd,
                                                      flash_attention_fwd_stats)
    q = jax.random.normal(KEY, (3, 64, 16))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (3, 64, 16))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (3, 64, 16))
    do = jax.random.normal(jax.random.fold_in(KEY, 3), q.shape)
    o, lse = flash_attention_fwd_stats(q, k, v, causal=causal, bq=16, bk=16)
    grads = flash_attention_bwd(q, k, v, o, lse, do, causal=causal,
                                bq=16, bk=16)
    ref = jax.grad(lambda *a: jnp.sum(flash_attention_ref(*a, causal=causal)
                                      * do), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(grads, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_flash_custom_vjp_end_to_end():
    q = jax.random.normal(KEY, (2, 32, 8, 16))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 32, 4, 16))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 32, 4, 16))
    gk = jax.grad(lambda *a: jnp.sum(
        flash_attention_kernel(*a, bq=8, bk=8) ** 2), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lambda *a: jnp.sum(
        gqa_attention(*a, n_heads=8, n_kv_heads=4, causal=True) ** 2),
        argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4)


@settings(max_examples=hyp_examples(6), deadline=None)
@given(seed=st.integers(0, 999), hd=st.sampled_from([8, 16, 32]))
def test_flash_property_sweep(seed, hd):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (2, 32, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 32, hd))
    out = flash_attention_pallas(q, k, v, causal=True, bq=16, bk=16)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-5, atol=5e-5)
