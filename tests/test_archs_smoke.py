"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
asserting output shapes and no NaNs (deliverable (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, ALL_ARCHS
from repro.models.bst import BST
from repro.models.dlrm import DLRM
from repro.models.gnn import GIN
from repro.models.lm import LM
from repro.models.sasrec import SASRec
from repro.models.two_tower import TwoTower
from repro.models.wide_deep import WideDeep

KEY = jax.random.PRNGKey(0)
LM_ARCHS = ["starcoder2-7b", "qwen3-32b", "internlm2-1.8b",
            "deepseek-moe-16b", "grok-1-314b"]


def _finite(x):
    assert np.isfinite(np.asarray(x)).all()


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_arch_smoke(arch_id, rng):
    cfg = get_arch(arch_id).make_config(reduced=True)
    params, bufs = LM.init(KEY, cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)))
    # train step
    loss, _ = LM.loss_fn(params, bufs, {"tokens": toks, "labels": toks}, cfg)
    _finite(loss)
    g = jax.grad(lambda p: LM.loss_fn(p, bufs, {"tokens": toks,
                                                "labels": toks}, cfg)[0])(params)
    _finite(jax.tree.leaves(g)[0])
    # prefill + decode
    last, caches = LM.prefill(params, bufs, toks, cfg, max_len=32,
                              cache_dtype=jnp.float32)
    assert last.shape == (2, cfg.vocab)
    nxt = jnp.argmax(last, -1)[:, None]
    logits, caches = LM.decode_step(params, bufs, nxt, caches, cfg)
    assert logits.shape == (2, cfg.vocab)
    _finite(logits)
    assert int(caches["len"]) == 17


@pytest.mark.parametrize("shape", ["full_graph_sm", "molecule", "minibatch_lg"])
def test_gin_smoke(shape, rng):
    from repro.data.graphs import (make_sbm_graph, make_molecule_batch,
                                   csr_from_edges, NeighborSampler)
    cfg = get_arch("gin-tu").make_config(reduced=True, shape=shape)
    params, bufs = GIN.init(KEY, cfg)
    if shape == "molecule":
        mol = make_molecule_batch(8, 10, 20, atom_vocab=cfg.atom_vocab)
        graph = {k: jnp.asarray(v) if not isinstance(v, int) else v
                 for k, v in mol.items()}
    elif shape == "minibatch_lg":
        g = make_sbm_graph(500, 4000, cfg.d_in, cfg.n_classes, seed=1)
        csr = csr_from_edges(g["edge_src"].astype(np.int64),
                             g["edge_dst"].astype(np.int64), 500)
        sub = NeighborSampler(csr, (5, 3)).sample(np.arange(8))
        nn_ = sub["node_ids"].shape[0]
        graph = {"x": jnp.asarray(g["x"][sub["node_ids"]]),
                 "edge_src": jnp.asarray(sub["edge_src"]),
                 "edge_dst": jnp.asarray(sub["edge_dst"]),
                 "edge_mask": jnp.asarray(sub["edge_mask"]),
                 "labels": jnp.asarray(g["labels"][sub["node_ids"]]),
                 "label_mask": jnp.asarray((np.arange(nn_) < 8).astype(np.float32))}
    else:
        g = make_sbm_graph(200, 1000, cfg.d_in, cfg.n_classes, seed=0)
        graph = {k: jnp.asarray(v) if not isinstance(v, int) else v
                 for k, v in g.items()}
    loss, _ = GIN.loss_fn(params, bufs, graph, cfg, lam=1e-5)
    _finite(loss)
    g2 = jax.grad(lambda p: GIN.loss_fn(p, bufs, graph, cfg, lam=1e-5)[0])(params)
    _finite(jax.tree.leaves(g2)[0])


def test_wide_deep_smoke(rng):
    cfg = get_arch("wide-deep").make_config(reduced=True)
    params, bufs, state = WideDeep.init(KEY, cfg)
    b = {"ids": jnp.asarray(rng.integers(0, 1000, (8, len(cfg.fields)))),
         "label": jnp.asarray(rng.integers(0, 2, (8,)))}
    loss, _ = WideDeep.loss_fn(params, bufs, state, b, cfg, lam=1e-5)
    _finite(loss)


def test_two_tower_smoke(rng):
    cfg = get_arch("two-tower-retrieval").make_config(reduced=True)
    params, bufs, state = TwoTower.init(KEY, cfg)
    b = {"user_ids": jnp.asarray(rng.integers(0, 1000, (8, 2))),
         "item_ids": jnp.asarray(rng.integers(0, 500, (8, 2))),
         "item_logq": jnp.zeros((8,))}
    loss, _ = TwoTower.loss_fn(params, bufs, state, b, cfg, lam=1e-5)
    _finite(loss)
    scores, idx = TwoTower.retrieval_score(params, bufs, state,
                                           b["user_ids"][:1], b["item_ids"],
                                           cfg, top_k=4)
    assert scores.shape == (4,)


def test_bst_smoke(rng):
    cfg = get_arch("bst").make_config(reduced=True)
    params, bufs, state = BST.init(KEY, cfg)
    b = {"seq_ids": jnp.asarray(rng.integers(0, cfg.item_vocab, (8, cfg.seq_len))),
         "target_id": jnp.asarray(rng.integers(0, cfg.item_vocab, (8,))),
         "ctx_ids": jnp.asarray(rng.integers(0, 100, (8, 1))),
         "label": jnp.asarray(rng.integers(0, 2, (8,)))}
    loss, _ = BST.loss_fn(params, bufs, state, b, cfg, lam=1e-5)
    _finite(loss)


def test_sasrec_smoke(rng):
    cfg = get_arch("sasrec").make_config(reduced=True)
    params, bufs, state = SASRec.init(KEY, cfg)
    b = {"seq_ids": jnp.asarray(rng.integers(0, cfg.item_vocab, (8, cfg.seq_len))),
         "pos_ids": jnp.asarray(rng.integers(0, cfg.item_vocab, (8, cfg.seq_len))),
         "neg_ids": jnp.asarray(rng.integers(0, cfg.item_vocab, (8, cfg.seq_len))),
         "mask": jnp.ones((8, cfg.seq_len))}
    loss, _ = SASRec.loss_fn(params, bufs, state, b, cfg, lam=1e-5)
    _finite(loss)
    s, i = SASRec.score_candidates(params, bufs, b["seq_ids"][:2],
                                   jnp.arange(64), cfg, top_k=5)
    assert s.shape == (2, 5)


@pytest.mark.parametrize("backbone", ["dnn", "dcn", "deepfm", "ipnn"])
def test_dlrm_backbones_smoke(backbone, rng):
    cfg = get_arch("dlrm-criteo").make_config(reduced=True, backbone=backbone)
    params, bufs, state = DLRM.init(KEY, cfg)
    b = {"ids": jnp.asarray(rng.integers(0, 1000, (8, len(cfg.fields)))),
         "label": jnp.asarray(rng.integers(0, 2, (8,)))}
    loss, _ = DLRM.loss_fn(params, bufs, state, b, cfg, lam=1e-5,
                           step=jnp.asarray(0))
    _finite(loss)


def test_all_archs_registered():
    assert len(ALL_ARCHS()) == 11  # 10 assigned + dlrm-criteo (paper's own)
    total_cells = sum(len(get_arch(a).shapes) for a in ALL_ARCHS())
    assert total_cells == 44  # 40 assigned + 4 paper cells
