"""Shared fixtures + the ``multidevice`` marker.

Collection must never hard-fail on missing dev-only deps: modules using
hypothesis (see requirements-dev.txt) begin with
``pytest.importorskip("hypothesis")`` so they collect as skipped when the
dep is absent. ``scripts/verify.sh`` runs a collect-only smoke to enforce a
clean import graph.

``multidevice`` marks tests that need a real multi-device mesh (≥ 4 jax
devices). The blocking CI ``multidevice`` job runs them in-process under
``XLA_FLAGS=--xla_force_host_platform_device_count=4``; in a single-device
session they auto-skip (the subprocess fallbacks in ``test_dist.py`` /
``test_shard.py`` keep the coverage). The device count is read lazily so
collection itself never initializes the jax backend.
"""
import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multidevice: needs >= 4 jax devices (run under "
        "XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def pytest_runtest_setup(item):
    if item.get_closest_marker("multidevice") is not None:
        import jax
        n = jax.device_count()
        if n < 4:
            pytest.skip(f"needs >= 4 jax devices, have {n} (set XLA_FLAGS="
                        "--xla_force_host_platform_device_count=4)")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
