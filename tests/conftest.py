"""Shared fixtures.

Collection must never hard-fail on missing dev-only deps: modules using
hypothesis (see requirements-dev.txt) begin with
``pytest.importorskip("hypothesis")`` so they collect as skipped when the
dep is absent. ``scripts/verify.sh`` runs a collect-only smoke to enforce a
clean import graph.
"""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
