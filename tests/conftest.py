"""Shared fixtures + the custom markers, registered in one place.

Collection must never hard-fail on missing dev-only deps: modules using
hypothesis (see requirements-dev.txt) begin with
``pytest.importorskip("hypothesis")`` so they collect as skipped when the
dep is absent. ``scripts/verify.sh`` runs a collect-only smoke to enforce a
clean import graph.

Markers (all registered here so ``pytest --strict-markers`` passes):

``multidevice`` marks tests that need a real multi-device mesh (≥ 4 jax
devices). The blocking CI ``multidevice`` job runs them in-process under
``XLA_FLAGS=--xla_force_host_platform_device_count=4``; in a single-device
session they auto-skip (the subprocess fallbacks in ``test_dist.py`` /
``test_shard.py`` keep the coverage). The device count is read lazily so
collection itself never initializes the jax backend.

``integration`` marks black-box server tests that spawn the
``repro.launch.server`` subprocess (train + compile + socket traffic —
minutes, not seconds). They are excluded from tier-1: the blocking CI
``integration`` job opts in with ``REPRO_INTEGRATION=1``; a plain local
``pytest`` run skips them.

``hyp_examples`` scales every hypothesis ``max_examples`` by
``REPRO_HYPOTHESIS_SCALE`` (default 1): per-PR CI keeps the counts tuned
for latency, the scheduled nightly workflow (.github/workflows/nightly.yml)
sets the scale to 10 for a deep property sweep. A helper function (not a
profile) because per-test ``@settings(max_examples=...)`` would override
any profile default.
"""
import os

import numpy as np
import pytest


def hyp_examples(n: int) -> int:
    """``n`` hypothesis examples, scaled by ``REPRO_HYPOTHESIS_SCALE``."""
    return n * max(int(os.environ.get("REPRO_HYPOTHESIS_SCALE", "1")), 1)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multidevice: needs >= 4 jax devices (run under "
        "XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    config.addinivalue_line(
        "markers",
        "integration: spawns the serving subprocess (run with "
        "REPRO_INTEGRATION=1)")


def pytest_runtest_setup(item):
    if item.get_closest_marker("multidevice") is not None:
        import jax
        n = jax.device_count()
        if n < 4:
            pytest.skip(f"needs >= 4 jax devices, have {n} (set XLA_FLAGS="
                        "--xla_force_host_platform_device_count=4)")
    if item.get_closest_marker("integration") is not None \
            and not os.environ.get("REPRO_INTEGRATION"):
        pytest.skip("integration test (set REPRO_INTEGRATION=1 to run)")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
