"""MoE block semantics: routing conservation, capacity drops, int8 experts,
shared experts."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.moe import MoE, MoEConfig

KEY = jax.random.PRNGKey(0)


def _run(cfg, x=None):
    params = MoE.init(KEY, cfg)
    if x is None:
        x = 0.1 * jax.random.normal(jax.random.fold_in(KEY, 9),
                                    (2, 8, cfg.d_model))
    out, aux = MoE.apply(params, x, cfg)
    return params, x, out, aux


def test_moe_shapes_and_finite():
    cfg = MoEConfig(n_experts=4, top_k=2, d_model=16, d_ff=32)
    _, x, out, aux = _run(cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) > 0


def test_moe_generous_capacity_is_dropless():
    """With capacity >= T·k/E every token's experts contribute."""
    cfg = MoEConfig(n_experts=4, top_k=1, d_model=8, d_ff=16,
                    capacity_factor=4.0)
    params, x, out, _ = _run(cfg)
    # tokens with identical inputs map to identical outputs (routing is
    # deterministic in x); no dropped rows -> no zero outputs for nonzero x
    norms = np.linalg.norm(np.asarray(out).reshape(-1, 8), axis=1)
    assert (norms > 0).all()


def test_moe_int8_experts_close_to_fp():
    cfg32 = MoEConfig(n_experts=4, top_k=2, d_model=16, d_ff=32,
                      capacity_factor=4.0)
    cfg8 = cfg32._replace(expert_weight_int8=True)
    p32 = MoE.init(KEY, cfg32)
    p8 = MoE.init(KEY, cfg8)
    # int8 init quantizes the same he-normal draw: dequantized weights close
    w32 = np.asarray(p32["experts"]["w_gate"])
    w8 = np.asarray(p8["experts"]["w_gate"]["q"], np.float32) * \
        np.asarray(p8["experts"]["w_gate"]["scale"])
    assert np.abs(w32 - w8).max() <= np.abs(w32).max() / 127 + 1e-6
    x = 0.1 * jax.random.normal(jax.random.fold_in(KEY, 9), (2, 8, 16))
    out32, _ = MoE.apply(p32, x, cfg32)
    out8, _ = MoE.apply(p8, x, cfg8)
    np.testing.assert_allclose(np.asarray(out8), np.asarray(out32),
                               rtol=0.15, atol=0.02)


def test_moe_shared_expert_always_on():
    cfg = MoEConfig(n_experts=4, top_k=1, d_model=8, d_ff=16, n_shared=2)
    params, x, out, _ = _run(cfg)
    # zeroing the routed experts must leave the shared-expert contribution
    zeroed = jax.tree.map(jnp.zeros_like, params["experts"])
    out_shared, _ = MoE.apply(dict(params, experts=zeroed), x, cfg)
    assert float(jnp.max(jnp.abs(out_shared))) > 0
