"""Memory-bounded attention/CE paths vs their exact references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.attention import gqa_attention
from repro.nn.chunked import chunked_gqa_attention, chunked_softmax_xent

KEY = jax.random.PRNGKey(0)


def _qkv(b, s, hq, hkv, hd, dtype=jnp.float32):
    q = jax.random.normal(KEY, (b, s, hq, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, hkv, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, hkv, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("expand_kv", [False, True])
@pytest.mark.parametrize("qc,kc", [(8, 8), (16, 32), (64, 64)])
def test_chunked_matches_full(expand_kv, qc, kc):
    q, k, v = _qkv(2, 64, 8, 4, 16)
    ref = gqa_attention(q, k, v, n_heads=8, n_kv_heads=4, causal=True)
    out = chunked_gqa_attention(q, k, v, n_kv_heads=4, causal=True,
                                q_chunk=qc, kv_chunk=kc, expand_kv=expand_kv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_chunked_bf16_blocks_close():
    q, k, v = _qkv(2, 64, 8, 4, 16)
    ref = gqa_attention(q, k, v, n_heads=8, n_kv_heads=4, causal=True)
    out = chunked_gqa_attention(q, k, v, n_kv_heads=4, causal=True,
                                q_chunk=16, kv_chunk=16,
                                block_dtype=jnp.bfloat16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-2, atol=5e-2)


def test_chunked_decode_window():
    """q_offset + kv_valid_len (decode-style partial cache)."""
    q, k, v = _qkv(2, 64, 8, 4, 16)
    ref = gqa_attention(q[:, :8], k, v, n_heads=8, n_kv_heads=4, causal=True,
                        q_offset=30, kv_valid_len=38)
    out = chunked_gqa_attention(q[:, :8], k, v, n_kv_heads=4, causal=True,
                                q_offset=30, kv_valid_len=38,
                                q_chunk=8, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_chunked_attention_grads():
    q, k, v = _qkv(1, 32, 4, 2, 8)

    def loss_ref(q, k, v):
        return jnp.sum(gqa_attention(q, k, v, n_heads=4, n_kv_heads=2,
                                     causal=True) ** 2)

    def loss_chk(q, k, v):
        return jnp.sum(chunked_gqa_attention(q, k, v, n_kv_heads=2,
                                             causal=True, q_chunk=8,
                                             kv_chunk=8) ** 2)

    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    gc = jax.grad(loss_chk, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gr, gc):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_chunked_ce_matches_full():
    b, s, d, v = 2, 16, 8, 32
    x = jax.random.normal(KEY, (b, s, d))
    head = jax.random.normal(jax.random.fold_in(KEY, 1), (d, v))
    labels = jax.random.randint(jax.random.fold_in(KEY, 2), (b, s), 0, v)
    logp = jax.nn.log_softmax(x @ head, axis=-1)
    full = -jnp.mean(jnp.take_along_axis(logp, labels[..., None], axis=-1))
    for chunk in (4, 8, 16):
        chk = chunked_softmax_xent(x, head, labels, chunk=chunk)
        np.testing.assert_allclose(float(chk), float(full), rtol=1e-6)
    gf = jax.grad(lambda h: -jnp.mean(jnp.take_along_axis(
        jax.nn.log_softmax(x @ h, -1), labels[..., None], -1)))(head)
    gc = jax.grad(lambda h: chunked_softmax_xent(x, h, labels, chunk=4))(head)
    np.testing.assert_allclose(np.asarray(gf), np.asarray(gc), rtol=1e-5,
                               atol=1e-7)
