"""Train-loop behaviour: resume bit-exactness, NaN guard, grad compression."""
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import CTRSpec, SyntheticCTR
from repro.embeddings.table import FieldSpec
from repro.models.dlrm import DLRMConfig
from repro.train.compression import (int8_compress, int8_decompress,
                                     rowsparse_compress, rowsparse_decompress)
from repro.train.loop import Trainer
from repro.train.optimizer import adam, warmup_cosine
from repro.zoo import dlrm_builder


def _tiny_setup():
    spec = CTRSpec(field_vocabs=(300, 200), batch_size=256, seed=0)
    ds = SyntheticCTR(spec)
    fields = tuple(FieldSpec(f"f{i}", v) for i, v in enumerate(spec.field_vocabs))
    base = DLRMConfig(fields=fields, d_embed=8, mlp_hidden=(16,), backbone="dnn")
    return ds, dlrm_builder(base, ds.expected_frequencies())


def test_checkpoint_resume_bit_exact():
    ds, build = _tiny_setup()
    d = tempfile.mkdtemp()
    try:
        b = build(jax.random.PRNGKey(0), "plain", {})
        tr = Trainer(b["loss_fn"], b["params"], b["buffers"], b["state"],
                     adam(1e-3), ckpt_dir=d, ckpt_every=10)
        tr.run(lambda s: ds.batch(s), 20, log_every=0)

        b2 = build(jax.random.PRNGKey(0), "plain", {})
        tr2 = Trainer(b2["loss_fn"], b2["params"], b2["buffers"], b2["state"],
                      adam(1e-3), ckpt_dir=d, ckpt_every=10)
        assert tr2.restore() and tr2.step == 20
        tr2.run(lambda s: ds.batch(s), 30, log_every=0)

        b3 = build(jax.random.PRNGKey(0), "plain", {})
        tr3 = Trainer(b3["loss_fn"], b3["params"], b3["buffers"], b3["state"],
                      adam(1e-3))
        tr3.run(lambda s: ds.batch(s), 30, log_every=0)
        for a, c in zip(jax.tree.leaves(tr2.params), jax.tree.leaves(tr3.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_nan_guard_skips_update():
    ds, build = _tiny_setup()
    b = build(jax.random.PRNGKey(0), "plain", {})

    def loss_fn(params, buffers, state, batch, *, step=None):
        loss, aux = b["loss_fn"](params, buffers, state, batch, step=step)
        # poison the loss via the batch's nan flag
        return loss + batch["nan"], aux

    tr = Trainer(loss_fn, b["params"], b["buffers"], b["state"], adam(1e-3))
    before = np.asarray(jax.tree.leaves(tr.params)[0]).copy()

    def data_fn(step):
        d = ds.batch(step)
        d["nan"] = np.float32("nan") if step == 0 else np.float32(0.0)
        return d

    tr.run(data_fn, 1, log_every=0)
    after = np.asarray(jax.tree.leaves(tr.params)[0])
    np.testing.assert_array_equal(before, after)  # step skipped

    tr.run(data_fn, 2, log_every=0)  # clean step applies
    after2 = np.asarray(jax.tree.leaves(tr.params)[0])
    assert np.abs(after2 - before).max() > 0


def test_int8_error_feedback_telescopes(rng):
    """Σ decompressed_t -> Σ g_t (bias cancels through the residual)."""
    g_true = jnp.asarray(rng.normal(0, 1, (50, 64)), jnp.float32)
    err = jnp.zeros((64,))
    total = jnp.zeros((64,))
    for t in range(50):
        q, s, err = int8_compress(g_true[t], err)
        total = total + int8_decompress(q, s)
    np.testing.assert_allclose(np.asarray(total),
                               np.asarray(jnp.sum(g_true, 0)),
                               rtol=0, atol=np.abs(np.asarray(g_true)).max() / 60)


def test_rowsparse_roundtrip(rng):
    g = jnp.zeros((100, 8)).at[jnp.asarray([3, 50, 99])].set(1.5)
    idx, vals = rowsparse_compress(g, jnp.asarray([3, 50, 99]))
    back = rowsparse_decompress(100, idx, vals)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(g))


def test_lr_schedule():
    fn = warmup_cosine(1e-3, warmup=10, total=100)
    assert float(fn(jnp.asarray(0))) == 0.0
    assert abs(float(fn(jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(fn(jnp.asarray(100))) < 1e-5
