"""The packed-table serving subsystem (repro.serve).

Covers the three layers: batcher pad/unpad round-trips at off-shape request
sizes, cell-cache hit/miss behaviour via compile counts (the zero-recompile
acceptance criterion), and end-to-end ``score``/``retrieve``/``decode``
against unbatched references.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.inference import build_packed_table
from repro.core.mpe import MPEConfig
from repro.data.synthetic import SyntheticCTR
from repro.launch.serve import build_engine, train_packed_dlrm
from repro.models.dlrm import DLRM
from repro.serve import Engine, RequestBatcher
from repro.serve.cache import CellCache
from repro.serve.cells import lm_decode_cell, two_tower_retrieval_cell


# ---------------------------------------------------------------------------
# batcher
# ---------------------------------------------------------------------------

def _registry():
    return RequestBatcher({"serve_p99": 512, "serve_bulk": 2048})


@pytest.mark.parametrize("n", [1, 300, 513, 5000])
def test_batcher_plan_covers_request(n):
    chunks = _registry().plan(n)
    # chunks tile the request exactly, in order, without overlap
    assert chunks[0].start == 0
    for prev, cur in zip(chunks, chunks[1:]):
        assert cur.start == prev.start + prev.n_valid
    assert sum(c.n_valid for c in chunks) == n
    for c in chunks:
        assert 0 < c.n_valid <= c.rows


def test_batcher_bucket_selection():
    b = _registry()
    assert [c.bucket for c in b.plan(1)] == ["serve_p99"]
    assert [c.bucket for c in b.plan(300)] == ["serve_p99"]
    # 513 no longer fits the p99 cell: rides the bulk cell in one chunk
    assert [c.bucket for c in b.plan(513)] == ["serve_bulk"]
    # 5000 = 2×2048 bulk chunks + 904 remainder (too big for p99 ⇒ bulk)
    assert [c.bucket for c in b.plan(5000)] == ["serve_bulk"] * 3


@pytest.mark.parametrize("n", [1, 300, 513, 5000])
def test_batcher_pad_unpad_roundtrip(n, rng):
    b = _registry()
    ids = rng.integers(0, 1000, size=(n, 4)).astype(np.int32)
    got = np.empty_like(ids)
    for chunk, padded, mask in b.split(ids):
        assert padded.shape[0] == chunk.rows
        assert mask.sum() == chunk.n_valid and mask[:chunk.n_valid].all()
        assert (padded[chunk.n_valid:] == 0).all()  # id-0 padding stays valid
        got[chunk.start:chunk.start + chunk.n_valid] = \
            RequestBatcher.unpad(padded, chunk.n_valid)
    np.testing.assert_array_equal(got, ids)


def test_batcher_errors():
    b = _registry()
    with pytest.raises(ValueError):
        b.plan(0)
    with pytest.raises(ValueError):
        RequestBatcher.pad(np.zeros((10, 2)), 4)
    with pytest.raises(ValueError):
        RequestBatcher().plan(5)  # no shapes registered


# ---------------------------------------------------------------------------
# cell cache
# ---------------------------------------------------------------------------

def test_cell_cache_hit_miss_compile_counts():
    from repro.dist.mesh import host_mesh
    from jax.sharding import PartitionSpec as P

    cache = CellCache(host_mesh())
    builds = {"n": 0}

    def build():
        builds["n"] += 1
        step = lambda w, x: x @ w
        specs = (jnp.ones((4, 2)), jax.ShapeDtypeStruct((8, 4), jnp.float32))
        return step, specs, (P(None, None), P(None, None)), P(None, None), {}

    key = cache.key("toy", "mm@8")
    c1 = cache.get_or_compile(key, build)
    assert (cache.compiles, cache.hits, builds["n"]) == (1, 0, 1)
    c2 = cache.get_or_compile(key, build)
    assert c2 is c1                      # warm executable returned as-is
    assert (cache.compiles, cache.hits, builds["n"]) == (1, 1, 1)
    # a different shape is a different executable
    cache.get_or_compile(cache.key("toy", "mm@16"), build)
    assert cache.compiles == 2 and builds["n"] == 2


# ---------------------------------------------------------------------------
# engine end-to-end (score)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served():
    """Tiny trained packed DLRM behind an engine with 64/256-row cells."""
    cfg, params, state, buffers, spec, res = train_packed_dlrm(
        field_vocabs=(600, 400, 500), train_steps=25, train_batch=256, seed=3)
    engine = build_engine(cfg, params, state, buffers,
                          p99_rows=64, bulk_rows=256)
    return {"engine": engine, "cfg": cfg, "params": params, "state": state,
            "buffers": buffers, "spec": spec}


def _reference_logits(served, ids):
    """Unbatched (no padding, no jit) packed-table scoring."""
    logits, _, _ = DLRM.apply(served["params"], served["buffers"],
                              served["state"], {"ids": jnp.asarray(ids)},
                              served["cfg"], train=False)
    return np.asarray(logits)


@pytest.mark.parametrize("n", [1, 50, 300])
def test_score_matches_unbatched_reference(served, n):
    ds = SyntheticCTR(served["spec"]._replace(batch_size=n))
    ids = ds.batch(777)["ids"]
    got = served["engine"].score(ids, return_logits=True)
    np.testing.assert_allclose(got, _reference_logits(served, ids),
                               rtol=1e-4, atol=1e-4)


def test_score_probabilities(served):
    ds = SyntheticCTR(served["spec"]._replace(batch_size=10))
    probs = served["engine"].score(ds.batch(778)["ids"])
    assert probs.shape == (10,)
    assert (probs > 0).all() and (probs < 1).all()


def test_second_run_zero_recompiles(served):
    """Acceptance criterion: repeat requests of the same shape never
    recompile — they hit the warm executables from the cell cache."""
    engine = served["engine"]
    ds = SyntheticCTR(served["spec"]._replace(batch_size=300))
    engine.score(ds.batch(1)["ids"])
    compiles_before = engine.compile_count
    engine.score(ds.batch(2)["ids"])     # same shape again
    engine.score(ds.batch(3)["ids"])
    assert engine.compile_count == compiles_before

    # re-registering the same model on a shared cache is pure hits
    twin = Engine(mesh=engine.mesh, cache=engine.cache)
    twin.register_packed_model(
        "dlrm", DLRM, served["cfg"], served["params"], served["state"],
        served["buffers"], shapes={"serve_p99": 64, "serve_bulk": 256})
    assert engine.cache.compiles == compiles_before
    assert engine.cache.hits >= 4        # 2 score + 2 lookup cells re-keyed


def test_stats_record_lookup_split(served):
    engine = served["engine"]
    ds = SyntheticCTR(served["spec"]._replace(batch_size=20))
    engine.score(ds.batch(5)["ids"])
    summary = engine.summary()
    cell = summary["dlrm/serve_p99"]
    assert cell["count"] >= 1
    for k in ("p50_ms", "p99_ms", "lookup_p50_ms", "compute_p50_ms"):
        assert cell[k] >= 0.0
    assert cell["p50_ms"] <= cell["p99_ms"] + 1e-9


# ---------------------------------------------------------------------------
# retrieval cell
# ---------------------------------------------------------------------------

def test_retrieve_matches_reference(rng):
    from repro.embeddings.table import FieldSpec
    from repro.models.two_tower import TwoTower, TwoTowerConfig

    cfg = TwoTowerConfig(user_fields=(FieldSpec("u0", 50), FieldSpec("u1", 40)),
                         item_fields=(FieldSpec("i0", 80),),
                         d_embed=8, tower_hidden=(16, 8))
    params, buffers, state = TwoTower.init(jax.random.PRNGKey(0), cfg)

    # pack the (untrained) dense table directly — no pipeline needed
    emb = np.asarray(params["embedding"]["emb"])
    n, d = emb.shape
    mpe = MPEConfig()
    fbits = rng.integers(0, len(mpe.bits), size=(n,)).astype(np.int32)
    alpha = np.full((len(mpe.bits),), 0.02, np.float32)
    beta = np.zeros((d,), np.float32)
    table, meta = build_packed_table(emb, fbits, alpha, beta, mpe)

    scfg = cfg._replace(compressor="packed", comp_cfg=meta)
    sparams = dict(params, embedding=table)
    sbuffers = dict(buffers, embedding={})

    engine = Engine()
    engine.register(two_tower_retrieval_cell(
        TwoTower, scfg, sparams, state, sbuffers, n_cands=128, top_k=10,
        arch="tt"))

    user = rng.integers(0, 40, size=(1, 2)).astype(np.int32)
    cands = rng.integers(0, 80, size=(100, 1)).astype(np.int32)
    scores, idx = engine.retrieve(user, cands)

    ref_scores, ref_idx = TwoTower.retrieval_score(
        sparams, sbuffers, state, jnp.asarray(user), jnp.asarray(cands),
        scfg, top_k=10)
    np.testing.assert_allclose(scores, np.asarray(ref_scores),
                               rtol=1e-4, atol=1e-5)
    assert (idx < 100).all()             # padded candidates never surface


def test_retrieve_chunks_oversized_corpus(rng):
    from repro.embeddings.table import FieldSpec
    from repro.models.two_tower import TwoTower, TwoTowerConfig

    cfg = TwoTowerConfig(user_fields=(FieldSpec("u0", 30),),
                         item_fields=(FieldSpec("i0", 60),),
                         d_embed=4, tower_hidden=(8, 4))
    params, buffers, state = TwoTower.init(jax.random.PRNGKey(1), cfg)
    emb = np.asarray(params["embedding"]["emb"])
    mpe = MPEConfig()
    fbits = np.full((emb.shape[0],), 6, np.int32)  # all rows widest bucket
    table, meta = build_packed_table(
        emb, fbits, np.full((len(mpe.bits),), 0.02, np.float32),
        np.zeros((emb.shape[1],), np.float32), mpe)
    scfg = cfg._replace(compressor="packed", comp_cfg=meta)
    sparams = dict(params, embedding=table)
    sbuffers = dict(buffers, embedding={})

    engine = Engine()
    engine.register(two_tower_retrieval_cell(
        TwoTower, scfg, sparams, state, sbuffers, n_cands=64, top_k=5,
        arch="tt"))
    user = np.zeros((1, 1), np.int32)
    cands = rng.integers(0, 60, size=(150, 1)).astype(np.int32)  # 3 chunks
    scores, idx = engine.retrieve(user, cands)
    assert scores.shape == (5,) and idx.shape == (5,)
    assert (np.diff(scores) <= 1e-9).all()          # sorted descending
    ref_scores, _ = TwoTower.retrieval_score(
        sparams, sbuffers, state, jnp.asarray(user), jnp.asarray(cands),
        scfg, top_k=5)
    np.testing.assert_allclose(scores, np.asarray(ref_scores),
                               rtol=1e-4, atol=1e-5)

    # same arch/shape/avals but different static config (temperature) must
    # NOT warm-hit the first executable — the fingerprint keys it apart
    compiles = engine.compile_count
    hot_cfg = scfg._replace(temperature=1.0)
    engine.register(two_tower_retrieval_cell(
        TwoTower, hot_cfg, sparams, state, sbuffers, n_cands=64, top_k=5,
        arch="tt"))
    assert engine.compile_count == compiles + 1
    hot_scores, _ = engine.retrieve(user, cands[:64])
    ref_hot, _ = TwoTower.retrieval_score(
        sparams, sbuffers, state, jnp.asarray(user), jnp.asarray(cands[:64]),
        hot_cfg, top_k=5)
    np.testing.assert_allclose(hot_scores, np.asarray(ref_hot),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# decode cell (int8 KV cache on by default)
# ---------------------------------------------------------------------------

def test_decode_cell_int8_cache_default():
    from repro.models.lm import LM, LMConfig

    cfg = LMConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                   head_dim=16, d_ff=64, vocab=50, remat=False)
    params, buffers = LM.init(jax.random.PRNGKey(0), cfg)

    engine = Engine()
    engine.register(lm_decode_cell(cfg, params, buffers, batch=4, max_len=8,
                                   arch="lm-tiny"))
    assert engine.compile_count == 1

    tokens = np.array([[3], [7], [11]], np.int32)      # b=3 rides the 4-cell
    logits, caches = engine.decode(tokens)
    assert logits.shape == (3, 50)
    assert caches["k"].dtype == jnp.int8               # int8 default
    assert "k_scale" in caches and int(caches["len"]) == 1
    # scales calibrated from the first write, not the init constant
    assert float(jnp.max(caches["k_scale"])) != pytest.approx(0.05)

    logits2, caches = engine.decode(tokens[:, :1], caches)
    assert int(caches["len"]) == 2
    assert engine.compile_count == 1                   # still one executable
    assert np.isfinite(logits2).all()
