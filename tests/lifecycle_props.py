"""Shared property checks for the multi-lane admission queue.

Plain functions, no hypothesis import: the exact same driver + invariants
run twice —

  - under **hypothesis** in ``tests/test_scheduler_props.py`` (dev envs and
    CI, where requirements-dev.txt installs it), with minimized
    counterexamples;
  - under the **seeded-numpy sweeps** in ``tests/test_queue.py`` (always-on
    tier-1), so the invariant logic itself is exercised even where
    hypothesis is absent.

``drive_queue`` replays a randomized submit/take/complete stream against an
``AdmissionQueue`` on a virtual timeline and returns a trace; the ``check_*``
functions assert the scheduler contract over it: no dropped or duplicated
tickets, EDF dispatch order within a lane, per-tenant quota ceilings never
exceeded, counters consistent with the trace — and ``check_fifo_identity``:
with one tenant, priority 0 and no deadlines the multi-lane queue dispatches
in exactly the single-lane FIFO order.
"""
from __future__ import annotations

import math

from repro.serve.queue import SHED, AdmissionQueue, TenantQuota

KINDS = ("score", "tiered")


def random_stream(rng, n_events: int) -> list[dict]:
    """A random submit-stream spec from a ``numpy.random.Generator`` —
    mirrors the hypothesis strategy in ``test_scheduler_props.py``."""
    specs = []
    for _ in range(n_events):
        specs.append({
            "kind": str(rng.choice(KINDS)),
            "n_rows": int(rng.integers(1, 41)),
            "tenant": str(rng.choice(["a", "b", "c"])),
            "priority": int(rng.integers(0, 3)),
            "deadline_ms": (None if rng.random() < 0.5
                            else float(rng.integers(1, 500))),
            "dt": float(rng.random() * 0.05),
        })
    return specs


def random_config(rng) -> dict:
    """A random queue configuration. ``max_inflight_rows`` stays ≥ the
    largest request ``random_stream`` emits (40) so no submit is rejected
    outright for exceeding its tenant's whole budget."""
    quotas = {}
    if rng.random() < 0.7:
        quotas["a"] = TenantQuota(max_queued=int(rng.integers(1, 6)),
                                  max_inflight_rows=int(rng.integers(40, 200)))
    if rng.random() < 0.4:
        quotas["b"] = TenantQuota(max_queued=None,
                                  max_inflight_rows=int(rng.integers(40, 120)))
    return {"capacity": int(rng.integers(4, 33)),
            "quotas": quotas or None,
            "shed_watermark": float(rng.choice([1.0, 0.75, 0.5])),
            "take_every": int(rng.integers(1, 5)),
            "complete_frac": float(rng.random())}


# ---------------------------------------------------------------------------
# the driver
# ---------------------------------------------------------------------------

def drive_queue(specs: list[dict], cfg: dict) -> dict:
    """Replay ``specs`` against a fresh queue: submit each event, drain both
    kinds every ``take_every`` submits, complete (release) a
    ``complete_frac`` share of the taken requests between drains, then
    drain to empty. Returns the trace the ``check_*`` functions consume."""
    q = AdmissionQueue(cfg["capacity"], quotas=cfg.get("quotas"),
                      shed_watermark=cfg.get("shed_watermark", 1.0))
    admitted: list = []
    shed_at_submit = 0
    batches: list[tuple[str, list]] = []
    inflight: list = []
    peak_inflight: dict[str, int] = {}
    now = 0.0
    for i, s in enumerate(specs):
        now += s["dt"]
        req = q.submit(s["kind"], i, s["n_rows"], now=now,
                       deadline_ms=s["deadline_ms"], tenant=s["tenant"],
                       priority=s["priority"])
        if req is None:
            shed_at_submit += 1
        else:
            admitted.append(req)
        if (i + 1) % cfg["take_every"] == 0:
            now += 0.01
            _drain_once(q, now, batches, inflight, peak_inflight)
            _complete(q, inflight, cfg["complete_frac"])
    # drain to empty: release everything between rounds so quota-deferred
    # requests make progress
    rounds = 0
    while len(q):
        now += 0.05
        _drain_once(q, now, batches, inflight, peak_inflight)
        _complete(q, inflight, 1.0)
        rounds += 1
        assert rounds < 10_000, "queue failed to drain (stuck requests)"
    _complete(q, inflight, 1.0)
    return {"queue": q, "admitted": admitted, "batches": batches,
            "shed_at_submit": shed_at_submit,
            "peak_inflight": peak_inflight}


def _drain_once(q, now, batches, inflight, peak_inflight):
    for kind in KINDS:
        ready, _expired = q.take(kind, now=now)
        if ready:
            batches.append((kind, ready))
            inflight.extend(ready)
        rows: dict[str, int] = {}
        for r in inflight:
            rows[r.tenant] = rows.get(r.tenant, 0) + r.n_rows
        for tenant, n in rows.items():
            peak_inflight[tenant] = max(peak_inflight.get(tenant, 0), n)


def _complete(q, inflight, frac: float):
    k = math.ceil(len(inflight) * frac)
    for req in inflight[:k]:
        q.release(req)
    del inflight[:k]


# ---------------------------------------------------------------------------
# the invariants
# ---------------------------------------------------------------------------

def check_no_drop_no_dup(result: dict):
    """Every admitted ticket is dispatched exactly once or deadline-shed
    exactly once — none lost, none duplicated, none left queued."""
    dispatched = [r for _, batch in result["batches"] for r in batch]
    tickets = [r.ticket for r in dispatched]
    assert len(tickets) == len(set(tickets)), "ticket dispatched twice"
    expired = {r.ticket for r in result["admitted"] if r.status == SHED}
    assert not (set(tickets) & expired), "ticket both dispatched and shed"
    assert set(tickets) | expired == {r.ticket for r in result["admitted"]}
    assert len(result["queue"]) == 0


def check_edf_order(result: dict):
    """Within every drained batch: priority lanes in order, EDF inside a
    lane, ticket (arrival) order on ties."""
    for _kind, batch in result["batches"]:
        keys = [(r.priority,
                 math.inf if r.deadline_t is None else r.deadline_t,
                 r.ticket) for r in batch]
        assert keys == sorted(keys), f"EDF order violated: {keys}"


def check_quota_ceilings(result: dict, quotas):
    """A tenant's taken-but-unreleased rows never exceed its
    ``max_inflight_rows`` at any point in the trace."""
    for tenant, quota in (quotas or {}).items():
        if quota.max_inflight_rows is not None:
            peak = result["peak_inflight"].get(tenant, 0)
            assert peak <= quota.max_inflight_rows, \
                f"tenant {tenant}: {peak} in-flight rows > quota " \
                f"{quota.max_inflight_rows}"


def check_counters_consistent(result: dict):
    """The queue's counters reconcile with the trace, and every total
    equals the sum of its per-kind split and of its per-tenant split."""
    c = result["queue"].counters()
    assert c["admitted"] == len(result["admitted"])
    assert c["shed_deadline"] == \
        sum(1 for r in result["admitted"] if r.status == SHED)
    assert (c["shed_full"] + c["shed_quota"] + c["shed_load"]
            == result["shed_at_submit"])
    for key in ("admitted", "shed_full", "shed_deadline", "shed_quota",
                "shed_load"):
        assert c[key] == sum(rec[key] for rec in c["per_kind"].values())
        assert c[key] == sum(rec[key] for rec in c["per_tenant"].values())


def check_fifo_identity(sizes: list[int]):
    """One tenant, priority 0, no deadlines, no quotas: the multi-lane
    queue drains in exactly the single-lane FIFO (ticket) order."""
    q = AdmissionQueue(capacity=len(sizes) + 1)
    reqs = [q.submit("score", i, n, now=float(i))
            for i, n in enumerate(sizes)]
    ready, expired = q.take("score", now=float(len(sizes)) + 1.0)
    assert not expired
    assert [r.ticket for r in ready] == [r.ticket for r in reqs]
    for r in ready:
        q.release(r)
