"""Loop-aware HLO analyzer: trip-count weighting against known programs."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import (analyze, normalize_cost,
                                       split_computations)


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_scan_flops_weighted_by_trip_count():
    d, trips = 64, 7
    w_spec = jax.ShapeDtypeStruct((d, d), jnp.float32)
    x_spec = jax.ShapeDtypeStruct((d, d), jnp.float32)

    def one(w, x):
        return x @ w

    def scanned(w, x):
        def body(h, _):
            return h @ w, None
        h, _ = jax.lax.scan(body, x, None, length=trips)
        return h

    f1 = analyze(_compile(one, w_spec, x_spec))["flops_per_device"]
    fs = analyze(_compile(scanned, w_spec, x_spec))["flops_per_device"]
    expected = 2 * d * d * d
    assert abs(f1 - expected) / expected < 0.01
    assert abs(fs - trips * expected) / (trips * expected) < 0.05


def test_split_computations_finds_entry():
    hlo = _compile(lambda x: (x * 2).sum(), jax.ShapeDtypeStruct((8,), jnp.float32))
    entry, comps = split_computations(hlo)
    assert entry is not None and entry in comps
    assert len(comps) >= 1


def test_nested_scan_multiplies():
    d, inner, outer = 16, 3, 4

    def nested(w, x):
        def obody(h, _):
            def ibody(g, _):
                return g @ w, None
            g, _ = jax.lax.scan(ibody, h, None, length=inner)
            return g, None
        h, _ = jax.lax.scan(obody, x, None, length=outer)
        return h

    spec = jax.ShapeDtypeStruct((d, d), jnp.float32)
    f = analyze(_compile(nested, spec, spec))["flops_per_device"]
    expected = inner * outer * 2 * d ** 3
    assert abs(f - expected) / expected < 0.10


def test_cond_branch_modes_order_and_flops():
    """lax.cond branch accounting: "sum" charges both branches (conservative
    static bound), "max" only the heavy one, "min" only the light one — the
    common write-one-slot decode branch of the kv_int8 cells."""
    d = 128

    def heavy(x):
        return x @ x  # a dot only the heavy branch runs

    def light(x):
        return x

    def f(pred, x):
        return jax.lax.cond(pred, heavy, light, x).sum()

    hlo = _compile(f, jax.ShapeDtypeStruct((), jnp.bool_),
                   jax.ShapeDtypeStruct((d, d), jnp.float32))
    res = {m: analyze(hlo, cond_mode=m) for m in ("sum", "max", "min")}
    b = {m: r["hbm_bytes_per_device"] for m, r in res.items()}
    fl = {m: r["flops_per_device"] for m, r in res.items()}
    # the heavy branch's dot is charged under sum and max, never under min
    dot_flops = 2 * d ** 3
    assert fl["sum"] >= dot_flops and fl["max"] >= dot_flops
    assert fl["min"] < dot_flops
    # bytes ordering follows the branch selection
    assert b["sum"] >= b["max"] > b["min"]
    for m, r in res.items():
        assert r["cond_mode"] == m


def test_cond_mode_rejects_unknown():
    import pytest
    hlo = _compile(lambda x: x * 2, jax.ShapeDtypeStruct((4,), jnp.float32))
    with pytest.raises(ValueError):
        analyze(hlo, cond_mode="median")


def test_normalize_cost_handles_every_cost_analysis_shape():
    """jax 0.4.x cost_analysis() returns [dict] (or [] on sharded shard_map
    modules XLA declines to cost); newer jax returns the dict. The dryrun and
    shard_bench consumers must see one dict or None either way."""
    assert normalize_cost({"flops": 1.0}) == {"flops": 1.0}
    assert normalize_cost([{"flops": 2.0}]) == {"flops": 2.0}
    assert normalize_cost([]) is None
    assert normalize_cost(()) is None
    assert normalize_cost(None) is None


# ---------------------------------------------------------------------------
# cond_mode accounting against hand-written HLO (exact arithmetic: compiled
# HLO adds fusion noise, so the branch bytes are authored by hand here).
#
# heavy: dot(p, p) on f32[8,8]   -> bytes 3*8*8*4 = 768, flops 2*64*8 = 1024
# light: negate(p) on f32[8,8]   -> bytes 2*8*8*4 = 512, flops 0
# entry: parameters + the conditional itself are skipped -> 0 bytes

_COND_HLO = """\
HloModule cond_by_hand

%heavy (hp: f32[8,8]) -> f32[8,8] {
  %hp = f32[8,8] parameter(0)
  ROOT %hdot = f32[8,8] dot(%hp, %hp), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%light (lp: f32[8,8]) -> f32[8,8] {
  %lp = f32[8,8] parameter(0)
  ROOT %lneg = f32[8,8] negate(%lp)
}

ENTRY %main (pr: pred[], x: f32[8,8]) -> f32[8,8] {
  %pr = pred[] parameter(0)
  %x = f32[8,8] parameter(1)
  ROOT %c = f32[8,8] conditional(%pr, %x, %x), true_computation=%heavy, false_computation=%light
}
"""


def test_cond_two_branch_hand_computed_bytes():
    res = {m: analyze(_COND_HLO, cond_mode=m)
           for m in ("sum", "max", "min")}
    heavy_b, light_b, dot_fl = 768, 512, 1024
    assert res["sum"]["hbm_bytes_per_device"] == heavy_b + light_b
    assert res["max"]["hbm_bytes_per_device"] == heavy_b
    assert res["min"]["hbm_bytes_per_device"] == light_b
    assert res["sum"]["flops_per_device"] == dot_fl
    assert res["max"]["flops_per_device"] == dot_fl
    assert res["min"]["flops_per_device"] == 0


# lax.switch lowers to the branch_computations={...} syntax; branch costs
# are authored to be pairwise distinct AND to put the dot in the *middle*
# branch, so "max" (picked by bytes) must not inherit its flops:
#   b0: negate            -> 512 bytes, 0 flops
#   b1: dot               -> 768 bytes, 1024 flops
#   b2: multiply + add    -> 1536 bytes, 0 flops

_SWITCH_HLO = """\
HloModule switch_by_hand

%b0 (p0: f32[8,8]) -> f32[8,8] {
  %p0 = f32[8,8] parameter(0)
  ROOT %o0 = f32[8,8] negate(%p0)
}

%b1 (p1: f32[8,8]) -> f32[8,8] {
  %p1 = f32[8,8] parameter(0)
  ROOT %o1 = f32[8,8] dot(%p1, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

%b2 (p2: f32[8,8]) -> f32[8,8] {
  %p2 = f32[8,8] parameter(0)
  %t2 = f32[8,8] multiply(%p2, %p2)
  ROOT %o2 = f32[8,8] add(%t2, %p2)
}

ENTRY %main (idx: s32[], x: f32[8,8]) -> f32[8,8] {
  %idx = s32[] parameter(0)
  %x = f32[8,8] parameter(1)
  ROOT %c = f32[8,8] conditional(%idx, %x, %x, %x), branch_computations={%b0, %b1, %b2}
}
"""


def test_switch_three_branch_hand_computed_bytes():
    res = {m: analyze(_SWITCH_HLO, cond_mode=m)
           for m in ("sum", "max", "min")}
    assert res["sum"]["hbm_bytes_per_device"] == 512 + 768 + 1536
    assert res["max"]["hbm_bytes_per_device"] == 1536   # b2: heaviest bytes
    assert res["min"]["hbm_bytes_per_device"] == 512    # b0: lightest
    # the dot lives in the un-picked middle branch: only "sum" charges it
    assert res["sum"]["flops_per_device"] == 1024
    assert res["max"]["flops_per_device"] == 0
    assert res["min"]["flops_per_device"] == 0
