"""Loop-aware HLO analyzer: trip-count weighting against known programs."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import (analyze, normalize_cost,
                                       split_computations)


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_scan_flops_weighted_by_trip_count():
    d, trips = 64, 7
    w_spec = jax.ShapeDtypeStruct((d, d), jnp.float32)
    x_spec = jax.ShapeDtypeStruct((d, d), jnp.float32)

    def one(w, x):
        return x @ w

    def scanned(w, x):
        def body(h, _):
            return h @ w, None
        h, _ = jax.lax.scan(body, x, None, length=trips)
        return h

    f1 = analyze(_compile(one, w_spec, x_spec))["flops_per_device"]
    fs = analyze(_compile(scanned, w_spec, x_spec))["flops_per_device"]
    expected = 2 * d * d * d
    assert abs(f1 - expected) / expected < 0.01
    assert abs(fs - trips * expected) / (trips * expected) < 0.05


def test_split_computations_finds_entry():
    hlo = _compile(lambda x: (x * 2).sum(), jax.ShapeDtypeStruct((8,), jnp.float32))
    entry, comps = split_computations(hlo)
    assert entry is not None and entry in comps
    assert len(comps) >= 1


def test_nested_scan_multiplies():
    d, inner, outer = 16, 3, 4

    def nested(w, x):
        def obody(h, _):
            def ibody(g, _):
                return g @ w, None
            g, _ = jax.lax.scan(ibody, h, None, length=inner)
            return g, None
        h, _ = jax.lax.scan(obody, x, None, length=outer)
        return h

    spec = jax.ShapeDtypeStruct((d, d), jnp.float32)
    f = analyze(_compile(nested, spec, spec))["flops_per_device"]
    expected = inner * outer * 2 * d ** 3
    assert abs(f - expected) / expected < 0.10


def test_normalize_cost_handles_every_cost_analysis_shape():
    """jax 0.4.x cost_analysis() returns [dict] (or [] on sharded shard_map
    modules XLA declines to cost); newer jax returns the dict. The dryrun and
    shard_bench consumers must see one dict or None either way."""
    assert normalize_cost({"flops": 1.0}) == {"flops": 1.0}
    assert normalize_cost([{"flops": 2.0}]) == {"flops": 2.0}
    assert normalize_cost([]) is None
    assert normalize_cost(()) is None
    assert normalize_cost(None) is None
