"""Optimizer semantics: Adam trajectory, bf16 moments, clipping, schedules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optimizer import (adam, apply_updates, clip_by_global_norm,
                                   sgd)


def _run(opt, steps=60, dim=8):
    """Minimize ||x - t||² from a fixed start; returns final distance."""
    t = jnp.arange(1.0, dim + 1)
    params = {"x": jnp.zeros((dim,))}
    state = opt.init(params)
    for _ in range(steps):
        grads = {"x": 2 * (params["x"] - t)}
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    return float(jnp.max(jnp.abs(params["x"] - t)))


def test_adam_converges():
    assert _run(adam(0.3), steps=200) < 0.05


def test_adam_bf16_moments_converges():
    """Quantized moments track fp32 closely on a quadratic."""
    d32 = _run(adam(0.3), steps=120)
    d16 = _run(adam(0.3, moment_dtype=jnp.bfloat16), steps=120)
    assert abs(d32 - d16) < 0.3


def test_adam_bf16_moment_state_dtype():
    opt = adam(1e-3, moment_dtype=jnp.bfloat16)
    state = opt.init({"w": jnp.zeros((4, 4))})
    assert state["mu"]["w"].dtype == jnp.bfloat16
    assert state["nu"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((4, 4))}
    _, state = opt.update(g, state, {"w": jnp.zeros((4, 4))})
    assert state["mu"]["w"].dtype == jnp.bfloat16


def test_sgd_momentum_converges():
    assert _run(sgd(0.05, momentum=0.9), steps=200) < 0.05


def test_clip_by_global_norm():
    grads = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    np.testing.assert_allclose(float(norm), 20.0)
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5)
    # small grads untouched
    grads = {"a": jnp.full((4,), 0.01)}
    clipped, _ = clip_by_global_norm(grads, 1.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]), 0.01, rtol=1e-6)


def test_adamw_decay_skips_vectors():
    opt = adam(1e-2, weight_decay=0.1)
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    state = opt.init(params)
    grads = jax.tree.map(jnp.zeros_like, params)
    updates, _ = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(updates["w"]))) > 0  # decayed
    assert float(jnp.max(jnp.abs(updates["b"]))) == 0  # bias skipped
