"""Property-based tests (hypothesis) for the multi-lane scheduler contract.

Thin strategy wrappers over ``tests/lifecycle_props.py`` — the invariant
logic lives there, shared with the always-on seeded sweeps in
``tests/test_queue.py``, so an env without hypothesis (this module skips at
import, like the other hypothesis suites) still exercises every check.
Randomized request streams across tenants / priorities / deadlines must
show: no dropped or duplicated tickets, EDF dispatch order within a lane,
per-tenant quota ceilings never exceeded, counters consistent — and the
degenerate stream (one tenant, priority 0, no deadlines) drains in exactly
the single-lane FIFO order.
"""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402
from conftest import hyp_examples  # noqa: E402

import lifecycle_props as props  # noqa: E402
from repro.serve.queue import TenantQuota  # noqa: E402

spec_st = st.fixed_dictionaries({
    "kind": st.sampled_from(list(props.KINDS)),
    "n_rows": st.integers(1, 40),
    "tenant": st.sampled_from(["a", "b", "c"]),
    "priority": st.integers(0, 3),
    "deadline_ms": st.one_of(st.none(), st.floats(1.0, 500.0)),
    "dt": st.floats(0.0, 0.05),
})

# max_inflight_rows ≥ 40 (the largest request) so no submit is rejected for
# exceeding a tenant's whole budget — mirrors lifecycle_props.random_config
quota_st = st.builds(
    TenantQuota,
    max_queued=st.one_of(st.none(), st.integers(1, 6)),
    max_inflight_rows=st.one_of(st.none(), st.integers(40, 200)))

cfg_st = st.fixed_dictionaries({
    "capacity": st.integers(4, 32),
    "quotas": st.one_of(
        st.none(),
        st.dictionaries(st.sampled_from(["a", "b"]), quota_st, max_size=2)),
    "shed_watermark": st.sampled_from([1.0, 0.75, 0.5]),
    "take_every": st.integers(1, 5),
    "complete_frac": st.floats(0.0, 1.0),
})


@settings(max_examples=hyp_examples(60), deadline=None)
@given(specs=st.lists(spec_st, min_size=1, max_size=60), cfg=cfg_st)
def test_stream_invariants(specs, cfg):
    result = props.drive_queue(specs, cfg)
    props.check_no_drop_no_dup(result)
    props.check_edf_order(result)
    props.check_quota_ceilings(result, cfg.get("quotas"))
    props.check_counters_consistent(result)


@settings(max_examples=hyp_examples(40), deadline=None)
@given(sizes=st.lists(st.integers(1, 100), min_size=1, max_size=30))
def test_fifo_identity_degenerate_stream(sizes):
    props.check_fifo_identity(sizes)
