"""MPE core invariants: grouping, distribution, sampling, packed export."""
import collections

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st
from conftest import hyp_examples

from repro.core import (MPEConfig, MPESearchEmbedding, MPERetrainEmbedding,
                        build_packed_table, feature_bits, make_groups,
                        packed_lookup, sample_group_bits)


def test_groups_are_frequency_sorted(rng):
    freqs = rng.zipf(1.2, 1000).astype(np.float64)
    gof, fsum = make_groups(freqs, 128)
    gof = np.asarray(gof)
    # every feature in group k must be at least as frequent as any in group k+1
    g = gof.max() + 1
    mins = [freqs[gof == k].min() for k in range(g)]
    maxs = [freqs[gof == k].max() for k in range(g)]
    for k in range(g - 1):
        assert mins[k] >= maxs[k + 1]


def test_group_sizes(rng):
    freqs = rng.random(1000)
    gof, fsum = make_groups(freqs, 128)
    counts = collections.Counter(np.asarray(gof).tolist())
    sizes = sorted(counts.values(), reverse=True)
    assert sizes[0] == 128 and sizes[-1] == 1000 - 7 * 128
    assert fsum.shape == (8,)


def test_initial_distribution_uniform(rng):
    cfg = MPEConfig()
    params, bufs = MPESearchEmbedding.init(jax.random.PRNGKey(0), 300, 8,
                                           rng.random(300), cfg)
    p = MPESearchEmbedding.probabilities(params, cfg)
    np.testing.assert_allclose(np.asarray(p), 1.0 / len(cfg.bits), rtol=1e-5)
    # expected bits at uniform init = mean of candidates = 3.0
    eb = MPESearchEmbedding.expected_bits(params, bufs, cfg)
    np.testing.assert_allclose(float(eb), 3.0, rtol=1e-5)


def test_eq11_sampling_picks_highest_eligible():
    """b* = max{b_i | p_i > 1/(2m)} — not the argmax."""
    cfg = MPEConfig()
    m = len(cfg.bits)
    gamma = np.zeros((2, m), np.float32)
    # group 0: argmax at b=1, but b=5 has p>1/2m  -> must sample 5
    probs0 = np.array([.05, .5, .05, .05, .05, .25, .05])
    probs1 = np.array([.9, .02, .02, .02, .02, .01, .01])  # -> 0
    gamma[0] = np.log(probs0) * cfg.tau
    gamma[1] = np.log(probs1) * cfg.tau
    params = {"gamma": jnp.asarray(gamma)}
    out = np.asarray(sample_group_bits(params, cfg))
    assert cfg.bits[out[0]] == 5
    assert cfg.bits[out[1]] == 0


def test_sampling_always_nonempty(rng):
    """max p >= 1/m > 1/(2m), so some width is always eligible."""
    cfg = MPEConfig()
    gamma = jnp.asarray(rng.normal(0, 5 * cfg.tau, (50, len(cfg.bits))),
                        jnp.float32)
    out = np.asarray(sample_group_bits({"gamma": gamma}, cfg))
    assert (out >= 0).all()


def test_packed_table_matches_fakequant(rng):
    """Packed inference (§4) must equal the retrain layer's fake quant."""
    cfg = MPEConfig()
    key = jax.random.PRNGKey(1)
    params, bufs = MPESearchEmbedding.init(key, 700, 16, rng.zipf(1.3, 700),
                                           cfg)
    params = dict(params, gamma=jnp.asarray(
        rng.normal(0, 0.01, params["gamma"].shape), jnp.float32))
    gb = sample_group_bits(params, cfg)
    fb = feature_bits(gb, bufs["group_of_feature"])
    table, meta = build_packed_table(params["emb"], fb, params["alpha"],
                                     params["beta"], cfg)
    rp, rb = MPERetrainEmbedding.init(params["emb"], params["alpha"],
                                      params["beta"], fb)
    ids = jnp.asarray(rng.integers(0, 700, (256,)))
    np.testing.assert_allclose(
        np.asarray(packed_lookup(table, meta, ids)),
        np.asarray(MPERetrainEmbedding.lookup(rp, rb, ids, cfg)),
        rtol=0, atol=1e-6)


def test_regularizer_weights_infrequent_groups_harder(rng):
    """Eq. 10: 1/s_j weighting — a rare group's bit-probability shift moves
    the regularizer more than the same shift on a frequent group."""
    cfg = MPEConfig()
    n = 256
    freqs = np.concatenate([np.full(128, 1000.0), np.full(128, 1.0)])
    params, bufs = MPESearchEmbedding.init(jax.random.PRNGKey(0), n, 8,
                                           freqs, cfg)

    def reg_with_boost(group):
        gamma = np.zeros((2, len(cfg.bits)), np.float32)
        gamma[group, -1] = 10 * cfg.tau  # push highest bit-width
        p = dict(params, gamma=jnp.asarray(gamma))
        return float(MPESearchEmbedding.reg_loss(p, bufs, cfg))

    assert reg_with_boost(1) > reg_with_boost(0)


@settings(max_examples=hyp_examples(10), deadline=None)
@given(seed=st.integers(0, 1000), lam=st.sampled_from([0.0, 1e-5, 1e-4]))
def test_lookup_differentiable(seed, lam):
    cfg = MPEConfig(lam=lam)
    rng = np.random.default_rng(seed)
    params, bufs = MPESearchEmbedding.init(jax.random.PRNGKey(seed), 300, 8,
                                           rng.zipf(1.3, 300), cfg)
    ids = jnp.asarray(rng.integers(0, 300, (64,)))

    def loss(p):
        e = MPESearchEmbedding.lookup(p, bufs, ids, cfg)
        return jnp.sum(e ** 2) + lam * MPESearchEmbedding.reg_loss(p, bufs, cfg)

    g = jax.grad(loss)(params)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()
