"""repro.dist contract tests: pspec families, no-op degradation on one
device, and a real NamedSharding round-trip on a simulated 4-device CPU mesh.

The multi-device case runs **in-process** when the session already has ≥ 4
devices (the blocking CI ``multidevice`` job sets
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` — see
tests/conftest.py) and falls back to a subprocess otherwise:
``--xla_force_host_platform_device_count`` must be set before jax
initializes its backend, and a single-device pytest session has already
pinned it.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.inference import packed_specs
from repro.core.mpe import MPEConfig
from repro.dist import (current_dp_axes, dp_axes, host_mesh, lm_batch_pspecs,
                        lm_cache_pspecs, lm_param_pspecs, maybe_shard,
                        packed_table_pspecs, recsys_table_pspecs,
                        replicate_like, shard_batch_dim,
                        tree_named_shardings, use_mesh)

SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# pspec families
# ---------------------------------------------------------------------------

def test_dp_axes():
    assert dp_axes(False) == ("data",)
    assert dp_axes(True) == ("pod", "data")


def test_lm_param_pspecs_fsdp_rule():
    params = {
        "layers": {
            "attn": {"wq": {"kernel": SDS((64, 5120, 8192), jnp.float32)}},
            "ln_attn": {"scale": SDS((64, 5120), jnp.float32)},
        },
        "lm_head": SDS((5120, 151936), jnp.float32),
        "ln_f": {"scale": SDS((5120,), jnp.float32)},
        "embedding": {"emb": SDS((151936, 5120), jnp.float32)},
    }
    ps = lm_param_pspecs(params, None)
    # 2-D+: last dim over "model", second-to-last over "data" when divisible
    assert ps["layers"]["attn"]["wq"]["kernel"] == P(None, "data", "model")
    assert ps["lm_head"] == P("data", "model")
    assert ps["embedding"]["emb"] == P("data", "model")
    # stacked norm scale: 64 % 16 == 0 so the layer axis FSDP-shards too
    assert ps["layers"]["ln_attn"]["scale"] == P("data", "model")
    # 1-D leaves replicate
    assert ps["ln_f"]["scale"] == P(None)


def test_lm_param_pspecs_indivisible_dims_replicate():
    ps = lm_param_pspecs({"w": SDS((24, 100), jnp.float32)}, None)
    assert ps["w"] == P(None, None)


def test_lm_batch_and_cache_pspecs():
    assert lm_batch_pspecs(False) == {"tokens": P(("data",), None),
                                      "labels": P(("data",), None)}
    cache = lm_cache_pspecs(long_context=False, multi_pod=False)
    assert cache["k"] == P(None, ("data",), "model", None, None)
    assert cache["v"] == cache["k"]
    assert cache["len"] == P()
    assert cache["k"][1] == ("data",)  # cells.py derives scale pspecs from it
    long = lm_cache_pspecs(long_context=True, multi_pod=True)
    assert long["k"] == P(None, None, "model", None, None)  # B=1: no batch axis


def test_recsys_table_pspecs():
    rows = ("data", "model")
    ps = recsys_table_pspecs(rows)
    assert ps["emb"] == P(rows, None)
    assert ps["gamma"] == P(None, None)
    assert ps["alpha"] == P(None) and ps["beta"] == P(None)
    # structure-matching mode: unknown leaves get rank-matched replication
    sds = {"emb": SDS((4096, 16), jnp.float32), "extra": SDS((3, 3, 3), jnp.float32)}
    ps2 = recsys_table_pspecs(rows, sds)
    assert set(ps2) == {"emb", "extra"}
    assert ps2["extra"] == P(None, None, None)


def test_packed_table_pspecs_group_alignment():
    hist = (0.0, 0.30, 0.20, 0.20, 0.10, 0.10, 0.10)
    sds = packed_specs(100_000, 16, MPEConfig(), hist)
    ps = packed_table_pspecs(sds, rows_axes=("data", "model"))
    for name, sub in sds["subtables"].items():
        assert ps["subtables"][name] == P(("data", "model"), None)
        # row shards stay aligned to the 512-row padding groups, so a packed
        # row (whose codes straddle uint32 word boundaries) never splits
        assert sub.shape[0] % 512 == 0
    for k in ("local_idx", "width_idx", "alpha", "beta"):
        assert ps[k] == P(None)


def test_replicate_like_preserves_structure():
    tree = {"a": {"b": jnp.zeros((2, 3)), "c": jnp.zeros(())},
            "d": [jnp.zeros((4,)), jnp.zeros((1, 2, 3))]}
    ps = replicate_like(tree)
    assert jax.tree.structure(ps, is_leaf=lambda x: isinstance(x, P)) \
        == jax.tree.structure(tree)
    assert ps["a"]["b"] == P(None, None)
    assert ps["a"]["c"] == P()
    assert ps["d"][1] == P(None, None, None)


# ---------------------------------------------------------------------------
# single-device degradation
# ---------------------------------------------------------------------------

def test_noop_without_mesh():
    x = jnp.ones((8, 4))
    assert current_dp_axes() is None
    assert shard_batch_dim(x) is x
    assert maybe_shard(x, P("data", None)) is x


def test_noop_on_single_device_mesh():
    mesh = host_mesh(n_data=1, n_model=1)
    with use_mesh(mesh):
        x = jnp.ones((8, 4))
        assert current_dp_axes() is None
        assert shard_batch_dim(x) is x


def test_tree_named_shardings_on_host_mesh():
    mesh = host_mesh()
    tree = {"emb": P("data", None), "alpha": P(None), "opt": {"step": P()}}
    ns = tree_named_shardings(mesh, tree)
    assert ns["emb"].mesh == mesh and ns["emb"].spec == P("data", None)
    assert ns["opt"]["step"].spec == P()
    # a pspec-typed tree maps leaf-for-leaf (P must be treated as a leaf)
    assert jax.tree.structure(
        ns, is_leaf=lambda x: hasattr(x, "spec")).num_leaves == 3


# ---------------------------------------------------------------------------
# simulated 4-device mesh (in-process under the multidevice marker; a
# subprocess fallback keeps single-device sessions covered)
# ---------------------------------------------------------------------------

def _four_device_round_trip_checks():
    """The 4-device NamedSharding round-trip — shared by the in-process
    ``multidevice`` test and the single-device subprocess fallback."""
    import numpy as np
    from repro.dist import (current_dp_axes, make_device_mesh, maybe_shard,
                            shard_batch_dim, tree_named_shardings, use_mesh)

    assert jax.device_count() >= 4, jax.devices()
    mesh = make_device_mesh((2, 2), ("data", "model"))

    # round-trip: place a pytree with tree_named_shardings, read it back
    tree = {"emb": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            "alpha": jnp.arange(7, dtype=jnp.float32),
            "opt": {"step": jnp.zeros((), jnp.int32)}}
    pspecs = {"emb": P(("data", "model"), None), "alpha": P(None),
              "opt": {"step": P()}}
    shardings = tree_named_shardings(mesh, pspecs)
    placed = jax.tree.map(jax.device_put, tree, shardings)
    assert placed["emb"].sharding.spec == P(("data", "model"), None)
    assert len({s.data.tobytes() for s in placed["emb"].addressable_shards}) == 4
    for k in tree:
        np.testing.assert_array_equal(np.asarray(jax.tree.leaves(placed[k])[0]),
                                      np.asarray(jax.tree.leaves(tree[k])[0]))

    # maybe_shard applies a real constraint under the mesh...
    with use_mesh(mesh):
        assert current_dp_axes() == ("data",)
        out = jax.jit(lambda x: shard_batch_dim(x) * 2)(jnp.ones((8, 4)))
        assert out.sharding.spec[0] in (("data",), "data"), out.sharding
        # ...but skips axes the array can't divide (batch 3 on 2-way data)
        odd = jax.jit(lambda x: shard_batch_dim(x) * 2)(jnp.ones((3, 4)))
        np.testing.assert_array_equal(np.asarray(odd), 2.0)
    # ...and degrades to identity outside it
    x = jnp.ones((8, 4))
    assert maybe_shard(x, P("data", None)) is x


@pytest.mark.multidevice
def test_four_device_round_trip_in_process():
    _four_device_round_trip_checks()


_FALLBACK_SCRIPT = """
import test_dist
test_dist._four_device_round_trip_checks()
print("4-device dist round-trip OK")
"""


def subprocess_env_4dev():
    """Env for a 4-virtual-device child: src + tests on the path, XLA flag
    set before the child's jax initializes its backend."""
    env = dict(os.environ)
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.abspath(os.path.join(here, os.pardir, "src"))
    env["PYTHONPATH"] = os.pathsep.join(
        [src, here] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4").strip()
    env["JAX_PLATFORMS"] = "cpu"
    return env


def test_four_device_round_trip_subprocess():
    if jax.device_count() >= 4:
        pytest.skip("in-process multidevice test covers this session")
    proc = subprocess.run([sys.executable, "-c", _FALLBACK_SCRIPT],
                          env=subprocess_env_4dev(), capture_output=True,
                          text=True, timeout=300)
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "4-device dist round-trip OK" in proc.stdout


# ---------------------------------------------------------------------------
# three-axis ("pod", "data", "model") mesh — the multi-pod CLI layout.
# parse_mesh_flag accepts 'pod,dp,mp'; the shard wrappers are axis-generic,
# so the sharded lookup must stay bit-exact on the 1x2x2 mesh too.
# ---------------------------------------------------------------------------

def test_parse_mesh_flag_rejects_garbage():
    from repro.dist.mesh import parse_mesh_flag
    assert parse_mesh_flag(None) is None
    assert parse_mesh_flag("") is None
    for bad in ("2", "2,2,2,2", "a,b", "2;2"):
        with pytest.raises(SystemExit):
            parse_mesh_flag(bad)


def _pod_mesh_checks():
    """1x2x2 ("pod", "data", "model") mesh drive — shared by the in-process
    ``multidevice`` test and the single-device subprocess fallback."""
    import numpy as np
    from repro.core.inference import build_packed_table, packed_lookup
    from repro.core.mpe import MPEConfig
    from repro.dist import shard
    from repro.dist.mesh import parse_mesh_flag

    assert jax.device_count() >= 4, jax.devices()
    mesh = parse_mesh_flag("1,2,2")
    assert mesh.axis_names == ("pod", "data", "model")
    assert mesh.devices.shape == (1, 2, 2)

    rng = __import__("numpy").random.default_rng(0)
    cfg = MPEConfig()
    emb = rng.normal(size=(160, 12)).astype(np.float32)
    fbits = rng.integers(0, len(cfg.bits), size=160).astype(np.int32)
    alpha = (np.abs(rng.normal(size=len(cfg.bits))) * 0.1 + 0.01).astype(
        np.float32)
    beta = (rng.normal(size=12) * 0.01).astype(np.float32)
    table, meta = build_packed_table(emb, fbits, alpha, beta, cfg)
    ids = jnp.asarray(rng.integers(0, meta["n"], size=(24, 3)), jnp.int32)
    ref = np.asarray(jax.jit(lambda t, i: packed_lookup(t, meta, i))(table,
                                                                     ids))
    with use_mesh(mesh):
        # batch axes of the pod mesh are every non-"model" axis
        assert current_dp_axes() == ("pod", "data")
        got = jax.jit(lambda t, i: shard.sharded_packed_lookup(t, meta, i))(
            table, ids)
    np.testing.assert_array_equal(np.asarray(got), ref)


@pytest.mark.multidevice
def test_pod_mesh_in_process():
    _pod_mesh_checks()


_POD_FALLBACK_SCRIPT = """
import test_dist
test_dist._pod_mesh_checks()
print("1x2x2 pod-mesh drive OK")
"""


def test_pod_mesh_subprocess():
    if jax.device_count() >= 4:
        pytest.skip("in-process multidevice test covers this session")
    proc = subprocess.run([sys.executable, "-c", _POD_FALLBACK_SCRIPT],
                          env=subprocess_env_4dev(), capture_output=True,
                          text=True, timeout=300)
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "1x2x2 pod-mesh drive OK" in proc.stdout
