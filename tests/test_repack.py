"""Serving-time precision adaptation (repro.serve.repack).

Covers the ISSUE-7 acceptance criteria: a live repack to a new assignment
completes with **zero CellCache recompiles** (compile counters are flat
across the swap), a repack to the identical assignment is **bit-exact**, a
swap queued mid-stream never changes an already-dispatched chunk's result,
the tiered store refreshes in place (hot-tier shapes pinned, counters
cumulative), and a swapped table on a 2×2 mesh matches the single-device
reference (multidevice-marked).
"""
import numpy as np
import pytest

from repro.cache import TieredTableStore
from repro.core.inference import build_packed_table
from repro.core.mpe import MPEConfig, make_groups
from repro.core.packing import row_bytes
from repro.data.synthetic import SyntheticCTR
from repro.serve.repack import (RepackPlanner, TableSwapper,
                                headroom_capacities, subtable_capacities)

LAM = 3e-5


# -- planner policy (no engine, no jax) --------------------------------------


def _toy_planner(caps=None, freqs=None):
    """4 groups of 3 features over the default width ladder."""
    cfg = MPEConfig()
    gof = np.repeat(np.arange(4, dtype=np.int32), 3)
    meta = {"bits": cfg.bits, "d": 8, "n": 12}
    if caps is None:
        caps = {f"b{b}": 12 for b in cfg.bits if b != 0}
    return RepackPlanner(meta, gof, caps, frequencies=freqs), cfg


def test_planner_byte_math_and_identity():
    planner, cfg = _toy_planner()
    assign = np.array([5, 3, 1, 0], np.int32)
    per_group = [row_bytes(8, cfg.bits[i]) if cfg.bits[i] else 0
                 for i in assign]
    assert planner.bytes_packed(assign) == 3 * sum(per_group)
    assert planner.bucket_counts(assign).sum() == 12
    # a budget at (or above) the current payload plans the identity
    plan = planner.plan_budget(assign, planner.bytes_packed(assign))
    assert plan.n_features_moved == 0
    assert np.array_equal(plan.group_bits_idx, assign)
    assert plan.bytes_packed == plan.bytes_before


def test_planner_budget_demotes_coldest_first_within_capacity():
    freqs = np.array([9.0] * 3 + [5.0] * 3 + [2.0] * 3 + [1.0] * 3)
    planner, cfg = _toy_planner(freqs=freqs)
    assign = np.full((4,), len(cfg.bits) - 1, np.int32)   # everyone widest
    before = planner.bytes_packed(assign)
    plan = planner.plan_budget(assign, before - 1)        # force a reduction
    assert plan.bytes_packed <= before - 1
    assert planner.capacity_ok(plan.group_bits_idx)
    # packed widths quantize to whole uint32 words, so a notch may be free —
    # the ordering property is what matters: the coldest group bears the
    # deepest demotion, the hottest keeps the widest width
    assert plan.group_bits_idx[3] == plan.group_bits_idx.min()
    assert plan.group_bits_idx[0] == plan.group_bits_idx.max()


def test_planner_respects_capacity_skips_full_buckets():
    # intermediate buckets can hold nothing: demotions must bottom out at
    # width 0 instead of overflowing a pinned subtable
    cfg = MPEConfig()
    caps = {f"b{b}": 12 for b in cfg.bits if b != 0}
    for b in cfg.bits[1:-1]:
        if b != 0:
            caps[f"b{b}"] = 0
    planner, _ = _toy_planner(caps=caps)
    assign = np.full((4,), len(cfg.bits) - 1, np.int32)
    plan = planner.plan_budget(assign, 0)
    assert planner.capacity_ok(plan.group_bits_idx)
    assert set(plan.group_bits_idx.tolist()) == {0}       # all-zero floor


def test_planner_pressure_maps_hit_rate_to_budget():
    planner, cfg = _toy_planner()
    assign = np.full((4,), len(cfg.bits) - 1, np.int32)
    # 100% hit rate -> identity plan
    plan = planner.plan_pressure(assign, {"hot_lookups": 10, "cold_lookups": 0})
    assert plan.n_features_moved == 0
    # heavy misses -> shrunk payload
    plan = planner.plan_pressure(assign, {"hot_lookups": 1, "cold_lookups": 9})
    assert plan.bytes_packed < plan.bytes_before


def test_planner_promote_spends_budget_hottest_first():
    freqs = np.array([1.0] * 3 + [9.0] * 3 + [2.0] * 3 + [1.0] * 3)
    planner, cfg = _toy_planner(freqs=freqs)
    assign = np.zeros((4,), np.int32)
    widest = len(cfg.bits) - 1
    budget = 3 * row_bytes(8, cfg.bits[widest])           # room for one group
    plan = planner.plan_promote(assign, bytes_budget=budget)
    assert plan.bytes_packed <= budget
    assert plan.group_bits_idx[1] > 0                     # the hottest group
    assert planner.capacity_ok(plan.group_bits_idx)


def test_headroom_capacities_round_and_cover_all_widths():
    cfg = MPEConfig()
    caps = headroom_capacities({"bits": cfg.bits, "d": 8, "n": 100},
                               fraction=0.5, multiple=8)
    assert set(caps) == {f"b{b}" for b in cfg.bits if b != 0}
    assert all(v == 56 for v in caps.values())            # ceil(50 / 8) * 8


def test_build_packed_table_rejects_overflowing_capacity():
    cfg = MPEConfig()
    rng = np.random.default_rng(0)
    emb = rng.normal(size=(16, 4)).astype(np.float32)
    fbits = np.full((16,), len(cfg.bits) - 1, np.int32)
    alpha = np.full((len(cfg.bits),), 0.05, np.float32)
    beta = np.zeros((4,), np.float32)
    caps = {f"b{b}": 8 for b in cfg.bits if b != 0}       # 16 rows won't fit
    with pytest.raises(ValueError, match="pinned capacity"):
        build_packed_table(emb, fbits, alpha, beta, cfg, row_capacities=caps)


# -- live engine swaps --------------------------------------------------------


@pytest.fixture(scope="module")
def served():
    """A trained packed DLRM served two ways (monolithic + tiered) with
    repack tooling bound: (engine, store, res, planner, swapper, ids)."""
    from repro.launch.serve import (build_engine, repack_tools,
                                    train_packed_dlrm)
    cfg, params, state, buffers, spec, res = train_packed_dlrm(
        field_vocabs=(150, 100, 120), train_steps=10, train_batch=128,
        d_embed=8, mlp_hidden=(16,), seed=4)
    freqs = SyntheticCTR(spec).expected_frequencies()
    store = TieredTableStore(res["packed_table"], res["packed_meta"],
                             freqs, 0.3)
    engine = build_engine(cfg, params, state, buffers, p99_rows=64,
                          bulk_rows=256, store=store)
    planner, swapper = repack_tools(engine, res, freqs, lam=LAM)
    ids = SyntheticCTR(spec._replace(batch_size=40)).batch(50_000)["ids"]
    return engine, store, res, planner, swapper, ids


def _restore(served):
    """Swap the original assignment back in so tests stay order-independent."""
    engine, _, res, _, swapper, _ = served
    swapper.repack(np.asarray(res["feature_bits_idx"], np.int32))
    engine.sched_step()


def test_identical_assignment_repack_is_bit_exact(served):
    engine, _, res, _, swapper, ids = served
    base = engine.score(ids, return_logits=True)
    base_t = engine.score_tiered(ids, return_logits=True)
    c0 = engine.compile_count
    swapper.repack(np.asarray(res["feature_bits_idx"], np.int32))
    engine.sched_step()
    assert engine.swaps_applied >= 1
    assert engine.compile_count == c0
    assert np.array_equal(engine.score(ids, return_logits=True), base)
    assert np.array_equal(engine.score_tiered(ids, return_logits=True),
                          base_t)


def test_new_assignment_repack_zero_recompiles(served):
    engine, _, res, planner, swapper, ids = served
    base = engine.score(ids, return_logits=True)
    c0 = engine.compile_count
    gbits = np.asarray(res["group_bits"])
    plan = planner.plan_budget(gbits,
                               int(planner.bytes_packed(gbits) * 0.6))
    assert plan.n_features_moved > 0
    assert planner.capacity_ok(plan.group_bits_idx)
    summary = swapper.repack(plan)
    engine.sched_step()
    out = engine.score(ids, return_logits=True)
    assert engine.compile_count == c0                  # the tentpole invariant
    assert not np.array_equal(out, base)               # precision really moved
    assert summary["bytes_packed"] < summary["bytes_before"]
    # monolithic and tiered lanes agree on the *new* table too
    out_t = engine.score_tiered(ids, return_logits=True)
    assert np.allclose(out, out_t, atol=1e-6)
    _restore(served)


def test_swap_applies_at_step_boundary_not_mid_round(served):
    """A swap queued while requests are in flight lands between rounds: the
    already-dispatched chunk keeps its old-table result, the next request
    sees the new table, and no chunk ever mixes the two."""
    engine, _, res, planner, swapper, ids = served
    old_ref = engine.score(ids, return_logits=True)
    gbits = np.asarray(res["group_bits"])
    plan = planner.plan_budget(gbits,
                               int(planner.bytes_packed(gbits) * 0.6))

    t_a = engine.submit(ids)
    engine.sched_step()                          # dispatches A (old table)
    a_first = engine.poll(t_a)
    swapper.repack(plan)                         # queued, not applied
    t_b = engine.submit(ids)
    engine.drain()                               # applies swap, dispatches B
    b_out = engine.poll(t_b)
    a_out = a_first if a_first is not None else engine.poll(t_a)
    assert np.array_equal(a_out, old_ref)        # dispatched chunk untouched
    new_ref = engine.score(ids, return_logits=True)
    assert np.array_equal(b_out, new_ref)        # post-swap request: new table
    assert not np.array_equal(a_out, b_out)
    _restore(served)


def test_tiered_refresh_pins_hot_shapes_and_keeps_counters(served):
    engine, store, res, planner, swapper, ids = served
    engine.score_tiered(ids)                     # populate counters
    before = store.counters()
    hot_shapes = {k: v.shape for k, v in store.hot["subtables"].items()}
    gbits = np.asarray(res["group_bits"])
    plan = planner.plan_budget(gbits,
                               int(planner.bytes_packed(gbits) * 0.6))
    swapper.repack(plan)
    engine.sched_step()
    after = store.counters()
    assert {k: v.shape for k, v in store.hot["subtables"].items()} \
        == hot_shapes                            # compiled hot layout survives
    assert after["hot_lookups"] >= before["hot_lookups"]   # cumulative
    assert after["prefetches"] >= before["prefetches"]
    _restore(served)


def test_refresh_rejects_changed_static_metadata(served):
    _, store, res, _, _, _ = served
    bad_meta = dict(res["packed_meta"], n=res["packed_meta"]["n"] + 1)
    with pytest.raises(ValueError, match="static metadata"):
        store.refresh(res["packed_table"], bad_meta)


def test_swap_rejects_layout_change(served):
    """A table packed to different capacities must be refused, not silently
    recompiled."""
    engine, _, res, _, swapper, _ = served
    emb = res["final_params"]["embedding"]
    fat = headroom_capacities(res["packed_meta"], fraction=0.9)
    table, meta = build_packed_table(
        np.asarray(emb["emb"]), np.asarray(res["feature_bits_idx"]),
        np.asarray(emb["alpha"]), np.asarray(emb["beta"]),
        MPEConfig(lam=LAM), row_capacities=fat)
    engine.request_swap(table, meta)
    with pytest.raises(ValueError, match="compiled .* layout"):
        engine.sched_step()


def test_swap_without_target_cell_raises():
    from repro.serve import Engine
    engine = Engine()
    engine.request_swap({"subtables": {}}, {"bits": (0, 8), "d": 4, "n": 4})
    with pytest.raises(ValueError, match="no registered cell"):
        engine.sched_step()


@pytest.mark.multidevice
def test_swapped_table_matches_single_device_on_mesh():
    """After a live repack on a 2×2 (data, model) mesh, the swapped subtables
    re-shard through the compiled ``in_shardings`` (same
    ``packed_table_pspecs``) and scores match the single-device engine that
    applied the identical plan."""
    from repro.dist import make_device_mesh
    from repro.launch.serve import (build_engine, repack_tools,
                                    train_packed_dlrm)
    cfg, params, state, buffers, spec, res = train_packed_dlrm(
        field_vocabs=(150, 100, 120), train_steps=10, train_batch=128,
        d_embed=8, mlp_hidden=(16,), seed=4)
    freqs = SyntheticCTR(spec).expected_frequencies()
    mesh = make_device_mesh((2, 2), ("data", "model"))
    engines = [build_engine(cfg, dict(params), state, buffers, p99_rows=64,
                            bulk_rows=256),
               build_engine(cfg, dict(params), state, buffers, p99_rows=64,
                            bulk_rows=256, mesh=mesh)]
    gbits = np.asarray(res["group_bits"])
    plan = None
    for eng in engines:
        planner, swapper = repack_tools(eng, res, freqs, lam=LAM)
        if plan is None:
            plan = planner.plan_budget(gbits,
                                       int(planner.bytes_packed(gbits) * 0.6))
        swapper.repack(plan)
        eng.sched_step()
    ids = SyntheticCTR(spec._replace(batch_size=40)).batch(50_000)["ids"]
    c_mesh = engines[1].compile_count
    ref = engines[0].score(ids, return_logits=True)
    got = engines[1].score(ids, return_logits=True)
    assert engines[1].compile_count == c_mesh
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)
