"""shard_map parity suite (ISSUE 4): all four Pallas kernels, the packed and
tiered serve cells, and the shard_map train step on real multi-device meshes.

Everything here is marked ``multidevice`` and runs in-process in the
blocking CI job of the same name (``XLA_FLAGS`` virtualizes 4 CPU devices —
see tests/conftest.py). On a single-device session the marked tests skip and
``test_shard_suite_subprocess_fallback`` re-runs the whole suite in a
4-virtual-device child pytest, so tier-1 keeps the coverage.

Parity contract (docs/ARCHITECTURE.md §shard_map layer):
  - packed lookup / tiered hot lookup / flash attention / QAT expectation:
    bit-identical to the jitted single-device path on 1x1, 1x4 and 2x2
    meshes (the masked-gather+psum adds one non-zero term to zeros).
  - embedding bag: documented tolerance — the psum over row shards
    reassociates the bag sum (exact when the row axes don't really split).
  - train step: documented tolerance — mean-of-shard-means reassociates the
    batch reduction.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantizer
from repro.core.inference import build_packed_table, packed_lookup
from repro.core.mpe import MPEConfig
from repro.dist import shard
from repro.dist.mesh import make_device_mesh, use_mesh

multidevice = pytest.mark.multidevice

MESH_SHAPES = [(1, 1), (1, 4), (2, 2)]
BITS = MPEConfig().bits


def _mesh(shape):
    return make_device_mesh(shape, ("data", "model"))


def _random_packed_table(n=160, d=12, seed=0, row_pad_multiple=None):
    rng = np.random.default_rng(seed)
    cfg = MPEConfig()
    emb = rng.normal(size=(n, d)).astype(np.float32)
    fbits = rng.integers(0, len(cfg.bits), size=n).astype(np.int32)
    alpha = (np.abs(rng.normal(size=len(cfg.bits))) * 0.1 + 0.01).astype(np.float32)
    beta = (rng.normal(size=d) * 0.01).astype(np.float32)
    return build_packed_table(emb, fbits, alpha, beta, cfg,
                              row_pad_multiple=row_pad_multiple)


# ---------------------------------------------------------------------------
# kernel parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mesh_shape", MESH_SHAPES)
@pytest.mark.parametrize("use_kernel", [False, True])
@multidevice
def test_packed_lookup_parity(mesh_shape, use_kernel, rng):
    table, meta = _random_packed_table()
    ids = jnp.asarray(rng.integers(0, meta["n"], size=(24, 3)), jnp.int32)
    ref = np.asarray(jax.jit(lambda t, i: packed_lookup(t, meta, i))(table, ids))
    with use_mesh(_mesh(mesh_shape)):
        got = jax.jit(lambda t, i: shard.sharded_packed_lookup(
            t, meta, i, use_kernel=use_kernel))(table, ids)
    np.testing.assert_array_equal(np.asarray(got), ref)


@pytest.mark.parametrize("mesh_shape", [(1, 4), (2, 2)])
@multidevice
def test_packed_lookup_pad_to_shard_edge(mesh_shape, rng):
    """Non-divisible edge: row_pad_multiple=1 leaves odd subtable row counts
    (23, 31, ... rows on a 2/4-way model axis) — the wrapper's
    pad_rows_to_shard must keep the result bit-exact."""
    table, meta = _random_packed_table(n=150, row_pad_multiple=1)
    mp = mesh_shape[1]
    assert any(v.shape[0] % mp for v in table["subtables"].values()), \
        "edge case degenerated: all subtables divide the model axis"
    ids = jnp.asarray(rng.integers(0, meta["n"], size=(37,)), jnp.int32)
    ref = np.asarray(jax.jit(lambda t, i: packed_lookup(t, meta, i))(table, ids))
    with use_mesh(_mesh(mesh_shape)):
        got = jax.jit(lambda t, i: shard.sharded_packed_lookup(
            t, meta, i))(table, ids)
    np.testing.assert_array_equal(np.asarray(got), ref)


@pytest.mark.parametrize("mesh_shape", [(1, 4), (2, 2)])
@multidevice
def test_embedding_bag_parity(mesh_shape, rng):
    from repro.kernels.embedding_bag.ops import embedding_bag_kernel_sharded
    from repro.kernels.embedding_bag.ref import embedding_bag_ref
    tab = jnp.asarray(rng.normal(0, 1, (101, 16)), jnp.float32)  # odd rows
    ids = jnp.asarray(rng.integers(0, 101, (8, 5)))
    mask = jnp.asarray(rng.random((8, 5)) < 0.8)
    ref = np.asarray(jax.jit(embedding_bag_ref)(tab, ids, mask))
    with use_mesh(_mesh(mesh_shape)):
        got = jax.jit(lambda t, i, m: embedding_bag_kernel_sharded(
            t, i, m))(tab, ids, mask)
    # documented tolerance: the psum reassociates each bag's sum
    np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("mesh_shape", MESH_SHAPES)
@multidevice
def test_flash_attention_parity(mesh_shape, rng):
    from repro.kernels.flash_attention.ops import (
        flash_attention_kernel, flash_attention_kernel_sharded)
    q = jnp.asarray(rng.normal(0, 1, (4, 32, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (4, 32, 2, 16)), jnp.float32)  # GQA
    v = jnp.asarray(rng.normal(0, 1, (4, 32, 2, 16)), jnp.float32)
    ref = np.asarray(jax.jit(lambda a, b, c: flash_attention_kernel(
        a, b, c, causal=True))(q, k, v))
    with use_mesh(_mesh(mesh_shape)):
        got = jax.jit(lambda a, b, c: flash_attention_kernel_sharded(
            a, b, c, causal=True))(q, k, v)
    np.testing.assert_array_equal(np.asarray(got), ref)


@pytest.mark.parametrize("mesh_shape", MESH_SHAPES)
@multidevice
def test_mixed_expectation_parity(mesh_shape, rng):
    from repro.kernels.mpe_qat.ops import (mixed_expectation_kernel,
                                           mixed_expectation_kernel_sharded)
    m = len(BITS)
    rows = jnp.asarray(rng.normal(0, 3e-3, (101, 16)), jnp.float32)  # odd rows
    probs = jax.nn.softmax(jnp.asarray(rng.normal(0, 1, (101, m)),
                                       jnp.float32), -1)
    alpha = jnp.asarray([quantizer.init_alpha(3e-3, b) for b in BITS])
    beta = jnp.asarray(rng.normal(0, 1e-4, (16,)), jnp.float32)
    ref = np.asarray(jax.jit(lambda r, p, a, b: mixed_expectation_kernel(
        r, p, a, b, BITS))(rows, probs, alpha, beta))
    with use_mesh(_mesh(mesh_shape)):
        got = jax.jit(lambda r, p, a, b: mixed_expectation_kernel_sharded(
            r, p, a, b, BITS))(rows, probs, alpha, beta)
    np.testing.assert_array_equal(np.asarray(got), ref)


@pytest.mark.parametrize("mesh_shape", [(1, 4), (2, 2)])
@multidevice
def test_tiered_hot_lookup_parity(mesh_shape, rng):
    from repro.cache import TieredTableStore
    from repro.cache.tiers import tiered_hot_lookup
    from repro.embeddings.frequency import zipf_frequencies
    table, meta = _random_packed_table()
    store = TieredTableStore(table, meta, zipf_frequencies(meta["n"], seed=1),
                             0.4)
    ids = jnp.asarray(rng.integers(0, meta["n"], size=(37,)), jnp.int32)
    ref = np.asarray(jax.jit(lambda h, i: tiered_hot_lookup(
        h, meta["bits"], meta["d"], i))(store.hot, ids))
    with use_mesh(_mesh(mesh_shape)):
        got = jax.jit(lambda h, i: shard.sharded_tiered_hot_lookup(
            h, meta["bits"], meta["d"], i))(store.hot, ids)
    np.testing.assert_array_equal(np.asarray(got), ref)
    # hot shards really live on the model axis when it has > 1 device
    if mesh_shape[1] > 1:
        from repro.dist.sharding import tiered_hot_pspecs, tree_named_shardings
        mesh = _mesh(mesh_shape)
        ns = tree_named_shardings(mesh, tiered_hot_pspecs(store.hot))
        placed = jax.device_put(store.hot["subtables"], ns["subtables"])
        for sub in jax.tree.leaves(placed):
            # distinct row blocks along "model"; replicated over "data"
            n_shards = len({str(s.index) for s in sub.addressable_shards})
            assert n_shards == mesh.shape["model"], sub.sharding


# ---------------------------------------------------------------------------
# serve cells: engine-level parity + zero recompiles
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served_model():
    from repro.launch.serve import train_packed_dlrm
    return train_packed_dlrm(field_vocabs=(150, 100, 120), train_steps=10,
                             train_batch=128, d_embed=8, mlp_hidden=(16,),
                             seed=4)


def _single_device_mesh():
    from repro.dist.mesh import host_mesh
    return host_mesh(n_data=1, n_model=1)


@pytest.mark.parametrize("mesh_shape", [(1, 4), (2, 2)])
@multidevice
def test_serve_cells_sharded_parity_and_zero_recompile(mesh_shape,
                                                       served_model):
    from repro.data.synthetic import SyntheticCTR
    from repro.launch.serve import build_engine
    cfg, params, state, buffers, spec, res = served_model
    ids = SyntheticCTR(spec._replace(batch_size=300)).batch(50_000)["ids"]

    ref_engine = build_engine(cfg, params, state, buffers, p99_rows=64,
                              bulk_rows=256, mesh=_single_device_mesh(),
                              shard_lookup=False)
    ref = ref_engine.score(ids)

    engine = build_engine(cfg, params, state, buffers, p99_rows=64,
                          bulk_rows=256, mesh=_mesh(mesh_shape))
    got = engine.score(ids)
    np.testing.assert_array_equal(got, ref)

    # warm process ⇒ zero recompiles, asserted via the CellCache counters
    n_compiles = engine.compile_count
    engine.score(ids)
    assert engine.compile_count == n_compiles
    assert engine.counters()["hits"] == 0  # distinct shapes, no double compile


@pytest.mark.parametrize("mesh_shape", [(2, 2)])
@multidevice
def test_tiered_serve_cells_sharded_parity(mesh_shape, served_model):
    from repro.cache import TieredTableStore
    from repro.data.synthetic import SyntheticCTR
    from repro.launch.serve import build_engine
    cfg, params, state, buffers, spec, res = served_model
    freqs = SyntheticCTR(spec).expected_frequencies()
    ids = SyntheticCTR(spec._replace(batch_size=300)).batch(60_000)["ids"]

    def tiered_engine(mesh, shard_lookup):
        store = TieredTableStore(res["packed_table"], res["packed_meta"],
                                 freqs, 0.3)
        return build_engine(cfg, params, state, buffers, p99_rows=64,
                            bulk_rows=256, store=store, mesh=mesh,
                            shard_lookup=shard_lookup)

    ref = tiered_engine(_single_device_mesh(), False).score_tiered(ids)
    engine = tiered_engine(_mesh(mesh_shape), True)
    got = engine.score_tiered(ids)
    np.testing.assert_array_equal(got, ref)  # hot psum + cold fill: exact
    n_compiles = engine.compile_count
    engine.score_tiered(ids)
    assert engine.compile_count == n_compiles


# ---------------------------------------------------------------------------
# train step under shard_map
# ---------------------------------------------------------------------------

def _tiny_builder(seed=0):
    from repro.data.synthetic import CTRSpec, SyntheticCTR
    from repro.embeddings.table import FieldSpec
    from repro.models.dlrm import DLRMConfig
    from repro.zoo import dlrm_builder
    spec = CTRSpec(field_vocabs=(300, 200), batch_size=64, seed=seed)
    ds = SyntheticCTR(spec)
    fields = tuple(FieldSpec(f"f{i}", v) for i, v in enumerate(spec.field_vocabs))
    # batchnorm off: DP batch statistics are per-shard (standard non-sync-BN
    # semantics), which is a semantic — not numerical — difference
    base = DLRMConfig(fields=fields, d_embed=8, mlp_hidden=(16,),
                      backbone="dnn", use_batchnorm=False)
    return ds, dlrm_builder(base, ds.expected_frequencies())


@pytest.mark.parametrize("mesh_shape", [(1, 4), (2, 2)])
@multidevice
def test_sharded_value_and_grad_parity(mesh_shape):
    ds, build = _tiny_builder()
    b = build(jax.random.PRNGKey(0), "plain", {})
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    (l_ref, (st_ref, m_ref)), g_ref = jax.jit(
        lambda p, bu, st, ba: jax.value_and_grad(b["loss_fn"], has_aux=True)(
            p, bu, st, ba, step=0))(b["params"], b["buffers"], b["state"], batch)

    mesh = _mesh(mesh_shape)
    vag = shard.sharded_value_and_grad(b["loss_fn"], mesh)
    (l_sh, (st_sh, m_sh)), g_sh = jax.jit(
        lambda p, bu, st, ba: vag(p, bu, st, ba, step=0))(
        b["params"], b["buffers"], b["state"], batch)

    np.testing.assert_allclose(float(l_sh), float(l_ref), rtol=1e-6)
    for a, r in zip(jax.tree.leaves(g_sh), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                   rtol=1e-4, atol=1e-7)
    # the table's grads arrive row-shard-local when the rows divide the axis
    if mesh_shape[1] > 1:
        assert g_sh["embedding"]["emb"].sharding.spec[0] == "model"


@pytest.mark.parametrize("mesh_shape", [(2, 2)])
@multidevice
def test_trainer_mesh_loss_trajectory(mesh_shape):
    """Trainer(mesh=...) trains to the same losses as the single-device loop
    (documented fp32 tolerance: mean-of-shard-means + psum-scattered table
    grads reassociate reductions)."""
    from repro.train.loop import Trainer
    from repro.train.optimizer import adam
    runs = {}
    for mesh in (None, _mesh(mesh_shape)):
        ds, build = _tiny_builder()
        b = build(jax.random.PRNGKey(0), "plain", {})
        tr = Trainer(b["loss_fn"], b["params"], b["buffers"], b["state"],
                     adam(1e-3), mesh=mesh)
        losses = []
        tr.run(lambda s: ds.batch(s), 8, log_every=1,
               log_fn=lambda m: losses.append(float(m.split("loss ")[1]
                                                    .split(" ")[0])))
        runs[mesh is None] = (losses, jax.tree.map(np.asarray, tr.params))
    np.testing.assert_allclose(runs[False][0], runs[True][0], rtol=1e-4)
    for a, r in zip(jax.tree.leaves(runs[False][1]),
                    jax.tree.leaves(runs[True][1])):
        np.testing.assert_allclose(a, r, rtol=2e-3, atol=1e-5)


# ---------------------------------------------------------------------------
# single-device degradation (runs everywhere — no marker)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_kernel", [False, True])
def test_sharded_lookup_degrades_without_mesh(use_kernel, rng):
    table, meta = _random_packed_table()
    ids = jnp.asarray(rng.integers(0, meta["n"], size=(9, 3)), jnp.int32)
    got = shard.sharded_packed_lookup(table, meta, ids, use_kernel=use_kernel)
    ref = packed_lookup(table, meta, ids)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-6)


# ---------------------------------------------------------------------------
# subprocess fallback: single-device sessions re-run the suite on 4 virtual
# devices (the CI `test` job path; the `multidevice` job runs in-process)
# ---------------------------------------------------------------------------

def test_shard_suite_subprocess_fallback():
    if jax.device_count() >= 4:
        pytest.skip("in-process multidevice tests cover this session")
    from test_dist import subprocess_env_4dev
    here = os.path.dirname(os.path.abspath(__file__))
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-m", "multidevice",
         "-p", "no:cacheprovider", os.path.join(here, "test_shard.py"),
         os.path.join(here, "test_shard_a2a.py"),
         os.path.join(here, "test_dist.py")],
        env=subprocess_env_4dev(), capture_output=True, text=True,
        timeout=1800, cwd=os.path.join(here, os.pardir))
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout[-4000:]}\nstderr:\n{proc.stderr[-2000:]}"
    assert " passed" in proc.stdout and "failed" not in proc.stdout