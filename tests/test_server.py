"""Black-box integration tests: the engine behind the socket server.

Everything here goes over the wire — length-prefixed JSON frames into a
spawned ``repro.launch.server`` subprocess — so serialization, framing,
concurrent connections and the multi-tenant admission policy are exercised
end-to-end, TGI-integration-harness style. Marked ``integration``: excluded
from tier-1, run by the blocking CI ``integration`` job under
``REPRO_INTEGRATION=1``.
"""
import concurrent.futures

import numpy as np
import pytest

from server_fixture import ServerProcess

pytestmark = pytest.mark.integration

N_FIELDS = 3          # the server CLI trains field_vocabs=(600, 400, 500)
MAX_ID = 400          # < every field vocab


def _ids(seed: int, n: int) -> np.ndarray:
    return np.random.default_rng(seed).integers(
        0, MAX_ID, size=(n, N_FIELDS)).astype(np.int32)


@pytest.fixture(scope="module")
def server():
    s = ServerProcess(train_steps=5, log_name="test_server",
                      args=["--quota", "bulk=4:64"])
    yield s
    s.stop()


def test_ping_and_unknown_op(server):
    with server.client() as c:
        assert c.ping()
        assert "error" in c.call("frobnicate")


def test_score_round_trip(server):
    """submit → poll-until-done over the wire returns one probability-ish
    score per row, deterministically (same ids, same result)."""
    ids = _ids(0, 10)
    with server.client() as c:
        a = c.score(ids)
        b = c.score(ids)
    assert a.shape == (10,)
    np.testing.assert_array_equal(a, b)


def test_concurrent_clients_coalesce_end_to_end(server):
    """≥ 2 concurrent clients, each on its own connection, all in flight at
    once; every client gets exactly its own rows back (cross-checked against
    a solo run of the same ids)."""
    batches = {i: _ids(100 + i, 5 + 3 * i) for i in range(4)}

    def worker(i):
        with server.client() as c:
            return c.score(batches[i], tenant=f"t{i % 2}")

    with concurrent.futures.ThreadPoolExecutor(max_workers=4) as ex:
        got = list(ex.map(worker, batches))
    with server.client() as c:
        for i, out in enumerate(got):
            assert out.shape == (batches[i].shape[0],)
            np.testing.assert_array_equal(out, c.score(batches[i]))


def test_tenant_quota_and_counters_over_the_wire(server):
    """The admission policy is visible through the protocol: the 'bulk'
    tenant's in-flight quota (max_inflight_rows=64) rejects an oversized
    request deterministically, and the counters/request-summary ops report
    the per-tenant/per-lane split of what did run."""
    with server.client() as c:
        with pytest.raises(RuntimeError, match="max_inflight_rows"):
            c.submit(_ids(200, 100), tenant="bulk")   # 100 rows > 64
        out = c.score(_ids(201, 8), tenant="bulk", priority=1)
        assert out.shape == (8,)
        counters = c.counters()
        assert counters["queue"]["per_tenant"]["bulk"]["admitted"] >= 1
        assert "score:p1" in counters["goodput"]["by_lane"]
        assert counters["goodput"]["by_tenant"].get("bulk", 0) >= 1
        summary = c.request_summary(by="tenant")
        assert "bulk" in summary


def test_poll_unknown_and_consumed_tickets(server):
    with server.client() as c:
        assert c.poll(10_000_000)["status"] == "unknown"
        t = c.submit(_ids(5, 3))
        out = c.poll(t)
        while out["status"] == "pending":
            out = c.poll(t)
        assert out["status"] == "done"
        assert c.poll(t)["status"] == "unknown"   # consumed by the poll
