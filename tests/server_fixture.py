"""Launcher fixture for the black-box serving harness.

``ServerProcess`` spawns ``python -m repro.launch.server`` as a real
subprocess (the TGI integration-test service pattern: spawn, readiness
probe, teardown), captures its stdout/stderr into ``server-logs/`` (the CI
``integration`` job uploads that directory when the job fails), waits for
the ``READY host:port`` line, then confirms liveness with a ``ping`` over
the wire before handing the address to the test.

Teardown prefers a protocol ``shutdown`` (exercises the op) and escalates
to terminate/kill so a wedged server can't hang the suite.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
LOG_DIR = REPO / "server-logs"
_READY = re.compile(r"^READY (\S+):(\d+)$", re.M)


class ServerProcess:
    """One serving subprocess: spawn → READY → ping → (tests) → stop."""

    def __init__(self, *, train_steps: int = 5, args=(),
                 startup_timeout_s: float = 420.0,
                 log_name: str = "server"):
        LOG_DIR.mkdir(exist_ok=True)
        self.log_path = LOG_DIR / f"{log_name}.log"
        env = dict(os.environ)
        env["PYTHONPATH"] = (str(REPO / "src") + os.pathsep
                             + env.get("PYTHONPATH", ""))
        cmd = [sys.executable, "-m", "repro.launch.server", "--port", "0",
               "--train-steps", str(train_steps), *map(str, args)]
        self._log = open(self.log_path, "w")
        self.proc = subprocess.Popen(cmd, stdout=self._log,
                                     stderr=subprocess.STDOUT, env=env,
                                     cwd=REPO)
        try:
            self.host, self.port = self._wait_ready(startup_timeout_s)
        except BaseException:
            self.stop()
            raise

    # -- readiness ----------------------------------------------------------

    def _wait_ready(self, timeout_s: float) -> tuple[str, int]:
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            if self.proc.poll() is not None:
                raise RuntimeError(
                    f"server exited rc={self.proc.returncode} before READY "
                    f"— tail of {self.log_path}:\n{self._log_tail()}")
            m = _READY.search(self.log_path.read_text())
            if m:
                host, port = m.group(1), int(m.group(2))
                self._probe(host, port, deadline)
                return host, port
            time.sleep(0.5)
        raise TimeoutError(
            f"server not READY after {timeout_s}s — tail of "
            f"{self.log_path}:\n{self._log_tail()}")

    def _probe(self, host: str, port: int, deadline: float):
        from repro.launch.server import EngineClient
        while True:
            try:
                with EngineClient(host, port, timeout=5.0) as c:
                    assert c.ping()
                return
            except OSError:
                if time.monotonic() > deadline:
                    raise
                time.sleep(0.2)

    def _log_tail(self, n: int = 2000) -> str:
        try:
            return self.log_path.read_text()[-n:]
        except OSError:
            return "<log unreadable>"

    # -- use ----------------------------------------------------------------

    def client(self, **kw):
        from repro.launch.server import EngineClient
        return EngineClient(self.host, self.port, **kw)

    def stop(self):
        if self.proc.poll() is None:
            try:
                with self.client(timeout=5.0) as c:
                    c.shutdown()
            except OSError:
                pass
            try:
                self.proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                self.proc.terminate()
                try:
                    self.proc.wait(timeout=5)
                except subprocess.TimeoutExpired:
                    self.proc.kill()
                    self.proc.wait()
        self._log.close()
