"""int8 KV-cache decode: correctness vs the fp32-cache reference."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LM, LMConfig

CFG = LMConfig(n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
               d_ff=128, vocab=256)
KEY = jax.random.PRNGKey(0)


def test_int8_kv_decode_top1_matches():
    params, bufs = LM.init(KEY, CFG)
    toks = jax.random.randint(KEY, (2, 8), 0, 256)
    last, c32 = LM.prefill(params, bufs, toks, CFG, max_len=16,
                           cache_dtype=jnp.float32)
    nt = jnp.argmax(last, -1)[:, None]
    l32, _ = LM.decode_step(params, bufs, nt, c32, CFG)

    c8 = LM.make_kv_caches(CFG, 2, 16, dtype=jnp.int8, kv_scale_init=0.02)
    _, _, c8 = LM.apply(params, bufs, toks, CFG, kv_caches=c8)
    l8, c8 = LM.decode_step(params, bufs, nt, c8, CFG)

    assert c8["k"].dtype == jnp.int8
    assert int(c8["len"]) == 9
    # quantization noise must not flip the argmax on a well-separated head
    np.testing.assert_array_equal(np.asarray(jnp.argmax(l32, -1)),
                                  np.asarray(jnp.argmax(l8, -1)))
    assert float(jnp.max(jnp.abs(l32 - l8))) < 0.5


def test_int8_kv_codes_in_range():
    c8 = LM.make_kv_caches(CFG, 2, 16, dtype=jnp.int8)
    params, bufs = LM.init(KEY, CFG)
    toks = jax.random.randint(KEY, (2, 8), 0, 256)
    _, _, c8 = LM.apply(params, bufs, toks, CFG, kv_caches=c8)
    k = np.asarray(c8["k"], np.int32)
    assert k.min() >= -127 and k.max() <= 127
