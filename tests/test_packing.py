"""Property tests for bit-level packing (paper §4, TPU uint32 layout)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st
from conftest import hyp_examples

from repro.core import packing
from repro.core.quantizer import int_bounds


@settings(max_examples=hyp_examples(60), deadline=None)
@given(b=st.integers(1, 8), d=st.integers(1, 96), n=st.integers(1, 40),
       seed=st.integers(0, 2**16))
def test_pack_unpack_roundtrip(b, d, n, seed):
    rng = np.random.default_rng(seed)
    n_b, p_b = int_bounds(b)
    codes = rng.integers(n_b, p_b + 1, (n, d)).astype(np.int32)
    words = packing.pack_codes(jnp.asarray(codes), b)
    assert words.shape == (n, packing.words_per_row(d, b))
    back = packing.unpack_codes(words, b, d)
    np.testing.assert_array_equal(np.asarray(back), codes)


@given(b=st.integers(1, 8), d=st.integers(1, 128))
@settings(max_examples=hyp_examples(40), deadline=None)
def test_words_per_row_is_tight(b, d):
    w = packing.words_per_row(d, b)
    assert w * 32 >= d * b
    assert (w - 1) * 32 < d * b


def test_packed_density():
    """Packed size ≈ d·b bits (no byte-alignment waste beyond the last word)."""
    d, b, n = 64, 3, 1000
    w = packing.words_per_row(d, b)
    assert w == 6  # 192 bits / 32
    assert w * 32 - d * b <= 31
