"""Unit + property tests for the LSQ+ quantizer (paper Eqs. 2, 4-6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st
from conftest import hyp_examples

from repro.core import quantizer


@pytest.mark.parametrize("b", [1, 2, 3, 4, 5, 6, 7, 8])
def test_codes_within_bounds(b, rng):
    theta = jnp.asarray(rng.normal(0, 0.01, (128, 16)), jnp.float32)
    codes = quantizer.quantize_codes(theta, 0.002, jnp.zeros((16,)), b)
    n_b, p_b = quantizer.int_bounds(b)
    assert codes.min() >= n_b and codes.max() <= p_b


@pytest.mark.parametrize("b", [2, 4, 6])
def test_idempotent(b, rng):
    """Quantizing an already-quantized tensor is the identity."""
    theta = jnp.asarray(rng.normal(0, 0.01, (64, 8)), jnp.float32)
    alpha, beta = jnp.float32(0.003), jnp.asarray(rng.normal(0, 1e-3, (8,)), jnp.float32)
    q1 = quantizer.lsq_quantize(theta, alpha, beta, b)
    q2 = quantizer.lsq_quantize(q1, alpha, beta, b)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=0, atol=1e-6)


def test_ste_theta_gradient_mask(rng):
    """Eq. 4: dQ/dθ is 1 strictly inside the clamp range, 0 outside."""
    b = 3
    alpha = jnp.float32(0.01)
    beta = jnp.zeros((4,))
    theta = jnp.asarray([[0.001, 0.02, -0.05, 0.035]], jnp.float32)
    g = jax.grad(lambda t: jnp.sum(quantizer.lsq_quantize(t, alpha, beta, b)))(theta)
    n_b, p_b = quantizer.int_bounds(b)   # [-4, 3]
    v = np.asarray(theta) / 0.01         # [0.1, 2.0, -5.0, 3.5]
    expected = ((v > n_b) & (v < p_b)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(g), expected)


def test_alpha_gradient_matches_eq5(rng):
    b = 2
    n_b, p_b = quantizer.int_bounds(b)
    alpha = jnp.float32(0.01)
    beta = jnp.zeros(())
    theta = jnp.asarray([0.001, -0.5, 0.5, 0.013], jnp.float32)
    g = jax.grad(lambda a: jnp.sum(quantizer.lsq_quantize(theta, a, beta, b)),
                 argnums=0)(alpha)
    v = np.asarray(theta) / 0.01
    per = np.where(v <= n_b, n_b, np.where(v >= p_b, p_b, np.round(v) - v))
    np.testing.assert_allclose(float(g), per.sum(), rtol=1e-5)


def test_beta_gradient_matches_eq6(rng):
    b = 2
    alpha = jnp.float32(0.01)
    beta = jnp.zeros((2,))
    theta = jnp.asarray([[0.001, -0.5]], jnp.float32)  # inside, below
    g = jax.grad(lambda bt: jnp.sum(quantizer.lsq_quantize(theta, alpha, bt, b)),
                 argnums=0)(beta)
    np.testing.assert_allclose(np.asarray(g), [0.0, 1.0])


@settings(max_examples=hyp_examples(25), deadline=None)
@given(b=st.integers(1, 8), scale=st.floats(1e-4, 1e-1),
       seed=st.integers(0, 2**16))
def test_quantization_error_bounded(b, scale, seed):
    """Inside the clamp range, |Q(θ)-θ| <= α/2 (uniform quantizer property)."""
    rng = np.random.default_rng(seed)
    theta = jnp.asarray(rng.normal(0, scale, (64,)), jnp.float32)
    alpha = jnp.float32(2 * scale / max(1, 2 ** (b - 1)))
    q = quantizer.lsq_quantize(theta, alpha, jnp.zeros(()), b)
    n_b, p_b = quantizer.int_bounds(b)
    v = np.asarray(theta) / float(alpha)
    inside = (v > n_b) & (v < p_b)
    err = np.abs(np.asarray(q) - np.asarray(theta))
    assert (err[inside] <= float(alpha) / 2 + 1e-6).all()


def test_mixed_expectation_prob_weighting(rng):
    """Eq. 9: with a one-hot p the mixture equals the single quantizer."""
    bits = (0, 1, 2, 3, 4, 5, 6)
    rows = jnp.asarray(rng.normal(0, 3e-3, (32, 8)), jnp.float32)
    alpha = jnp.asarray([quantizer.init_alpha(3e-3, b) for b in bits])
    beta = jnp.zeros((8,))
    for i, b in enumerate(bits):
        probs = jax.nn.one_hot(jnp.full((32,), i), len(bits))
        out = quantizer.mixed_expectation(rows, probs, alpha, beta, bits)
        if b == 0:
            np.testing.assert_array_equal(np.asarray(out), 0.0)
        else:
            ref = quantizer.lsq_quantize(rows, alpha[i], beta, b)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=1e-6)
