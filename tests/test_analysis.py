"""Static contract checker: every rule catches its seeded violation, and
the clean repo produces zero findings.

The seeded violations mirror the acceptance list: an injected fp32 upcast
in a packed cell (PF102), a hand-rolled out-of-contract pspec (SC202), a
cell arg that forks the compile cache (RC301/RC303), and an over-budget
collective measured from real HLO accounting (BC501).
"""
import importlib.util
import os
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis.budgets import (HEADROOM, budget_entry, check_budget,
                                    load_budgets)
from repro.analysis.findings import (Finding, filter_suppressed,
                                     parse_pragmas)
from repro.analysis.lint import lint_source, lint_tree
from repro.analysis.precision import check_precision
from repro.analysis.recompile import (check_fingerprint,
                                      check_key_collisions,
                                      check_trace_determinism)
from repro.analysis.shardspec import (check_celldef_specs,
                                      check_shard_map_reductions,
                                      check_spec_tree)
from repro.dist.mesh import host_mesh, use_mesh
from repro.serve.cells import ServeCellDef

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _codes(findings):
    return [f.code for f in findings]


def _celldef(**kw):
    d = dict(arch="t", shape="s", kind="score", batch=4,
             step_fn=lambda x: x * 2.0,
             bound=(), bound_pspecs=(),
             request_specs=(jax.ShapeDtypeStruct((4, 3), jnp.float32),),
             request_pspecs=(P(None, None),),
             out_pspecs=P(None, None), meta={"kind": "score"}, static=None)
    d.update(kw)
    return ServeCellDef(**d)


# -- precision flow (PF1xx) -------------------------------------------------

def test_pf101_float64_output():
    from jax.experimental import enable_x64
    with enable_x64():
        jaxpr = jax.make_jaxpr(lambda x: x.astype(jnp.float64) * 2.0)(
            jnp.ones((4,), jnp.float32))
    assert "PF101" in _codes(check_precision(jaxpr, "seeded"))


def test_pf102_injected_upcast_in_packed_cell(tmp_path):
    """The acceptance seed: an inline int8->f32 dequant written in a module
    under a ``repro/`` path (so the user frame is attributable) but outside
    the sanctioned quantizer/packing call sites."""
    pkg = tmp_path / "repro_seeded" / "repro"
    pkg.mkdir(parents=True)
    bad = pkg / "bad_cell.py"
    bad.write_text(textwrap.dedent("""\
        import jax.numpy as jnp

        def bad_lookup(table, alpha, ids):
            codes = jnp.take(table, ids, axis=0)
            return codes.astype(jnp.float32) * alpha   # inline dequant
    """))
    spec = importlib.util.spec_from_file_location("repro_bad_cell", bad)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)

    table = jnp.zeros((16, 8), jnp.int8)
    alpha = jnp.float32(0.1)
    ids = jnp.zeros((4,), jnp.int32)
    jaxpr = jax.make_jaxpr(mod.bad_lookup)(table, alpha, ids)
    found = check_precision(jaxpr, "seeded-packed", packed=True)
    pf102 = [f for f in found if f.code == "PF102"]
    assert pf102 and pf102[0].file.endswith("repro/bad_cell.py")
    assert pf102[0].line == 5


def test_pf102_sanctioned_dequant_is_clean():
    """The same computation routed through core.quantizer attributes its
    convert to the sanctioned module and passes."""
    from repro.core.quantizer import dequantize_codes
    codes = jnp.zeros((4, 8), jnp.int8)
    alpha = jnp.float32(0.1)
    jaxpr = jax.make_jaxpr(
        lambda c, a: dequantize_codes(c, a, jnp.float32(0.0)))(codes, alpha)
    assert _codes(check_precision(jaxpr, "clean", packed=True)) == []


def test_pf102_int32_only_narrow_for_packed_cells():
    """int32 index math converts are legal in unpacked cells and flagged in
    packed ones — but only when the frame is inside repro/ (this test file
    is outside, so both pass; frame attribution is what PF102 keys on)."""
    jaxpr = jax.make_jaxpr(lambda i: i.astype(jnp.float32))(
        jnp.zeros((4,), jnp.int32))
    assert _codes(check_precision(jaxpr, "x", packed=False)) == []
    assert _codes(check_precision(jaxpr, "x", packed=True)) == []


def test_pf103_packed_words_into_float():
    jaxpr = jax.make_jaxpr(lambda w: w.astype(jnp.float32))(
        jnp.zeros((4,), jnp.uint32))
    assert "PF103" in _codes(check_precision(jaxpr, "seeded"))


def test_pf104_int8_arithmetic():
    jaxpr = jax.make_jaxpr(lambda a, b: a * b)(
        jnp.zeros((4,), jnp.int8), jnp.zeros((4,), jnp.int8))
    assert "PF104" in _codes(check_precision(jaxpr, "seeded"))


# -- sharding contract (SC2xx) ----------------------------------------------

def test_sc201_unknown_axis():
    found = check_spec_tree(P("rows"), "seeded", role="out")
    assert _codes(found) == ["SC201"]


def test_sc202_out_of_contract_pspec():
    """The acceptance seed: a hand-rolled pspec whose axis pair is not a
    registered AXIS_GROUPS entry (wrong order changes the row-major shard
    index)."""
    celldef = _celldef(out_pspecs=P(("model", "data"), None))
    found = check_celldef_specs(celldef)
    assert "SC202" in _codes(found)
    # the registered order is fine
    assert check_celldef_specs(
        _celldef(out_pspecs=P(("data", "model"), None))) == []


def test_sc202_nested_spec_trees():
    found = check_spec_tree({"k": P(None), "v": P(("model", "pod"))},
                            "seeded", role="bound[0]")
    assert _codes(found) == ["SC202"]


def test_sc204_shard_map_partial_without_psum():
    from jax.experimental.shard_map import shard_map
    mesh = host_mesh()

    def partial_body(x):
        return jnp.sum(x, axis=0)          # device-local partial, no merge

    def merged_body(x):
        return jax.lax.psum(jnp.sum(x, axis=0), "model")

    x = jnp.ones((4, 8), jnp.float32)
    with use_mesh(mesh):
        bad = jax.make_jaxpr(shard_map(
            partial_body, mesh=mesh, in_specs=P("model", None),
            out_specs=P(None), check_rep=False))(x)
        good = jax.make_jaxpr(shard_map(
            merged_body, mesh=mesh, in_specs=P("model", None),
            out_specs=P(None), check_rep=False))(x)
    assert _codes(check_shard_map_reductions(bad, "seeded")) == ["SC204"]
    assert check_shard_map_reductions(good, "clean") == []


# -- recompile hazards (RC3xx) ----------------------------------------------

def test_rc301_weak_typed_bound_forks_cache():
    """The acceptance seed: a Python scalar closed into ``bound`` traces
    weak-typed — the first strongly-typed request re-traces the cell."""
    celldef = _celldef(step_fn=lambda s, x: x * s, bound=(3.0,),
                       bound_pspecs=(P(),))
    assert "RC301" in _codes(check_fingerprint(celldef))
    fixed = _celldef(step_fn=lambda s, x: x * s,
                     bound=(jnp.asarray(3.0, jnp.float32),),
                     bound_pspecs=(P(),))
    assert check_fingerprint(fixed) == []


def test_rc302_address_in_fingerprint():
    class Opaque:                               # default __repr__: 0x...
        pass
    celldef = _celldef(static=Opaque())
    assert "RC302" in _codes(check_fingerprint(celldef))


def test_rc303_key_collision_different_signatures():
    a = _celldef()
    b = _celldef(request_specs=(jax.ShapeDtypeStruct((4, 3), jnp.bfloat16),))
    assert a.fingerprint == b.fingerprint       # identical identity fields
    assert _codes(check_key_collisions([a, b])) == ["RC303"]
    assert check_key_collisions([a, a]) == []


def test_rc304_nondeterministic_trace():
    calls = []

    def step(x):
        calls.append(1)
        return x * float(len(calls))            # constant changes per trace

    celldef = _celldef(step_fn=step)
    x = jnp.ones((4,), jnp.float32)
    # the fresh lambda per call defeats make_jaxpr's identity-keyed trace
    # cache, exactly as corpus.trace_cell does
    found = check_trace_determinism(
        celldef, lambda: jax.make_jaxpr(lambda y: step(y))(x))
    assert _codes(found) == ["RC304"]
    assert check_trace_determinism(
        celldef, lambda: jax.make_jaxpr(lambda y: y * 2.0)(x)) == []


# -- collective budgets (BC5xx) ---------------------------------------------

_AR_HLO = """\
HloModule m

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (p0: f32[64]) -> f32[64] {
  %p0 = f32[64] parameter(0)
  ROOT %ar = f32[64] all-reduce(%p0), to_apply=%add
}
"""


def test_bc501_over_budget_collective():
    """The acceptance seed: a real all-reduce measured by the HLO
    accounting (64 f32 = 256 bytes) against a 128-byte budget."""
    from repro.launch.hlo_analysis import analyze
    measured = analyze(_AR_HLO)["collectives_per_device"]
    assert measured["total_bytes"] == 256
    assert measured["all-reduce"]["count"] == 1
    found = check_budget("cell", measured, {"cell": {"total_bytes": 128}})
    assert _codes(found) == ["BC501"]
    assert check_budget("cell", measured,
                        {"cell": {"total_bytes": 256}}) == []


def test_bc502_missing_budget_entry():
    found = check_budget("newcell", {"total_bytes": 0.0}, {})
    assert _codes(found) == ["BC502"]


def test_budget_entry_headroom():
    assert budget_entry({"total_bytes": 1000})["total_bytes"] == \
        int(1000 * HEADROOM)


# -- source lint (RL4xx) ----------------------------------------------------

def test_rl401_hand_rolled_pspec():
    src = ("from jax.sharding import PartitionSpec as P\n"
           "x = P('data', None)\n"
           "y = maybe_shard(z, P('model', None))\n"
           "w = P(dp, None)\n")
    found = lint_source(src, "src/repro/serve/foo.py")
    assert _codes(found) == ["RL401"] and found[0].line == 2
    assert lint_source(src, "src/repro/dist/sharding.py") == []


def test_rl402_shard_map_outside_dist():
    src = ("from jax.experimental.shard_map import shard_map\n"
           "f = shard_map(g, mesh=m)\n")
    assert _codes(lint_source(src, "src/repro/serve/foo.py")) == \
        ["RL402", "RL402"]
    assert lint_source(src, "src/repro/dist/shard.py") == []


def test_rl403_host_sync_in_serve():
    src = "import jax\njax.block_until_ready(x)\n"
    assert _codes(lint_source(src, "src/repro/serve/foo.py")) == ["RL403"]
    assert lint_source(src, "src/repro/launch/foo.py") == []


def test_rl404_device_float64_literal():
    src = ("import jax.numpy as jnp\nimport numpy as np\n"
           "a = jnp.zeros((3,), jnp.float64)\n"
           "b = np.zeros((3,), np.float64)\n")   # host-side: legal
    found = lint_source(src, "src/repro/core/foo.py")
    assert _codes(found) == ["RL404"] and found[0].line == 3


def test_rl405_nondeterminism_in_cell_modules():
    src = "import time\nt = time.time()\n"
    assert _codes(lint_source(src, "src/repro/serve/cells.py")) == ["RL405"]
    assert lint_source(src, "src/repro/serve/engine.py") == []


# -- pragma suppression ------------------------------------------------------

def test_parse_pragmas():
    src = ("x = 1  # staticcheck: ignore[PF102, SC202]\n"
           "y = 2  # staticcheck: ignore\n"
           "z = 3\n")
    assert parse_pragmas(src) == {1: {"PF102", "SC202"}, 2: None}


def test_lint_pragma_suppresses_named_rule():
    src = ("import jax\n"
           "jax.block_until_ready(x)  # staticcheck: ignore[RL403]\n"
           "jax.device_get(y)  # staticcheck: ignore[RL401]\n")
    assert _codes(lint_source(src, "src/repro/serve/foo.py")) == ["RL403"]


def test_trace_finding_pragma(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text("deq = codes.astype(f32)  # staticcheck: ignore[PF102]\n")
    hit = Finding("PF102", "m", "cell", file=str(f), line=1)
    miss = Finding("PF104", "m", "cell", file=str(f), line=1)
    assert filter_suppressed([hit, miss]) == [miss]


# -- the clean repo ----------------------------------------------------------

def test_lint_clean_on_repo():
    assert [f.render() for f in lint_tree(REPO_ROOT)] == []


@pytest.fixture(scope="module")
def corpus_engine():
    from repro.analysis.corpus import build_corpus
    return build_corpus()


def test_registered_cells_introspection(corpus_engine):
    cells = corpus_engine.registered_cells()
    names = {reg.celldef.name for reg in cells.values()}
    # every cell kind is represented, lookup companions included
    expected = {"dlrm/serve_p99", "dlrm/serve_p99.lookup", "dlrm/serve_bulk",
                "dlrm/serve_bulk.lookup", "dlrm/tiered_p99",
                "dlrm/tiered_bulk", "lm-tiny/decode", "lm-cb/decode_cb"}
    if jax.device_count() >= 4:  # the a2a comms variants need a real mesh
        expected |= {"dlrm/serve_p99_a2a", "dlrm/tiered_p99_a2a"}
    assert expected == names


def test_clean_corpus_no_findings(corpus_engine):
    """The gate's exit-0 property: the full trace-level pass over the
    standard fleet, against the checked-in budgets, finds nothing."""
    from repro.analysis.runner import check_engine
    rep = check_engine(corpus_engine, budgets=load_budgets())
    assert rep.n_cells == (10 if jax.device_count() >= 4 else 8)
    assert [f.render() for f in rep.findings] == []
    # every corpus cell has a budget line checked in; the a2a cells only
    # compile on a >1-device model axis, so on a 1x1 session their budget
    # lines are present but unexercised
    budgets = load_budgets()
    assert set(rep.measured) <= set(budgets)
    unmeasured = set(budgets) - set(rep.measured)
    if jax.device_count() >= 4:
        assert not unmeasured
    else:
        assert unmeasured <= {"dlrm/serve_p99_a2a@64", "dlrm/tiered_p99_a2a@64"}
