"""Data generators + metrics."""
import jax.numpy as jnp
import numpy as np

from repro.data.graphs import NeighborSampler, csr_from_edges, make_sbm_graph
from repro.data.synthetic import CTRSpec, SyntheticCTR
from repro.data.tokens import TokenStream
from repro.embeddings.frequency import zipf_frequencies
from repro.train.metrics import auc, logloss


def test_ctr_determinism_and_elasticity(rng):
    spec = CTRSpec(field_vocabs=(500, 300), batch_size=128, seed=3)
    ds = SyntheticCTR(spec)
    a, b = ds.batch(7), ds.batch(7)
    np.testing.assert_array_equal(a["ids"], b["ids"])
    # different host shards differ (elastic resharding key)
    c = ds.batch(7, host_id=1, n_hosts=2)
    assert (a["ids"] != c["ids"]).any()


def test_ctr_positive_ratio():
    spec = CTRSpec(field_vocabs=(2000, 1000, 500), batch_size=8192)
    ds = SyntheticCTR(spec)
    ratio = np.mean([ds.batch(i)["label"].mean() for i in range(4)])
    assert 0.15 < ratio < 0.40  # Criteo-like (25.6%)


def test_ctr_signal_learnable():
    spec = CTRSpec(field_vocabs=(2000, 1000), batch_size=8192)
    ds = SyntheticCTR(spec)
    b = ds.batch(0)
    z = ds.true_logit(b["ids"].astype(np.int64))
    p = 1 / (1 + np.exp(-z))
    # the planted ground truth must have real AUC against its own labels
    a = float(auc(jnp.asarray(b["label"], jnp.float32), jnp.asarray(p)))
    assert a > 0.70


def test_zipf_frequencies_normalized():
    f = zipf_frequencies(1000, 1.1)
    assert abs(f.sum() - 1.0) < 1e-9
    assert f[0] > f[-1]


def test_sampler_edges_point_child_to_parent(rng):
    g = make_sbm_graph(300, 2000, 4, 3, seed=0)
    csr = csr_from_edges(g["edge_src"].astype(np.int64),
                         g["edge_dst"].astype(np.int64), 300)
    ns = NeighborSampler(csr, (4, 2), seed=0)
    seeds = np.arange(10)
    sub = ns.sample(seeds)
    n_exp, e_exp = NeighborSampler.output_sizes(10, (4, 2))
    assert sub["node_ids"].shape == (n_exp,)
    assert sub["edge_src"].shape == (e_exp,)
    # hop-1 edges: children (positions 10..50) -> parents (0..10)
    assert (sub["edge_dst"][:40] < 10).all()
    assert (sub["edge_src"][:40] >= 10).all() and (sub["edge_src"][:40] < 50).all()
    # sampled neighbor ids must be real neighbors where mask is set
    for e in range(40):
        if sub["edge_mask"][e]:
            child_pos = sub["edge_src"][e]
            parent_pos = sub["edge_dst"][e]
            child_gid = sub["node_ids"][child_pos]
            parent_gid = sub["node_ids"][parent_pos]
            nbrs = csr.indices[csr.indptr[parent_gid]:csr.indptr[parent_gid + 1]]
            assert child_gid in nbrs


def test_token_stream_shapes_and_zipf():
    ts = TokenStream(1000, 4, 32, seed=0)
    b = ts.batch_at(0)
    assert b["tokens"].shape == (4, 32) and b["labels"].shape == (4, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_auc_against_quadratic_reference(rng):
    labels = rng.integers(0, 2, 500).astype(np.float32)
    scores = rng.normal(0, 1, 500) + labels  # informative
    a = float(auc(jnp.asarray(labels), jnp.asarray(scores)))
    # O(n^2) reference (ties broken by 0.5)
    pos = scores[labels == 1][:, None]
    neg = scores[labels == 0][None, :]
    ref = (np.sum(pos > neg) + 0.5 * np.sum(pos == neg)) / (pos.size * neg.size / 1)
    ref = (np.sum(pos > neg) + 0.5 * np.sum(pos == neg)) / (
        (labels == 1).sum() * (labels == 0).sum())
    np.testing.assert_allclose(a, ref, atol=1e-6)


def test_auc_with_ties(rng):
    labels = jnp.asarray([0, 1, 0, 1], jnp.float32)
    scores = jnp.asarray([0.5, 0.5, 0.5, 0.5])
    assert abs(float(auc(labels, scores)) - 0.5) < 1e-6


def test_logloss():
    labels = jnp.asarray([1.0, 0.0])
    probs = jnp.asarray([0.9, 0.1])
    expected = -np.mean([np.log(0.9), np.log(0.9)])
    np.testing.assert_allclose(float(logloss(labels, probs)), expected,
                               rtol=1e-5)
