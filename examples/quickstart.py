"""Quickstart: compress a DLRM embedding table with MPE in ~2 minutes on CPU.

    PYTHONPATH=src python examples/quickstart.py

Runs the full paper pipeline — precision search (Eq. 8-10), sampling (Eq. 11),
retraining (§3.4), packed export (§4) — on a synthetic Zipf CTR dataset, then
serves a few batches from the bit-packed table.
"""
import jax
import jax.numpy as jnp

from repro.core.mpe import MPEConfig
from repro.core.pipeline import run_mpe_pipeline
from repro.data.synthetic import CTRSpec, SyntheticCTR
from repro.embeddings.table import FieldSpec
from repro.models.dlrm import DLRM, DLRMConfig
from repro.train.optimizer import adam
from repro.zoo import dlrm_builder


def main():
    spec = CTRSpec(field_vocabs=(3000, 2000, 1000, 800), batch_size=2048)
    ds = SyntheticCTR(spec)
    fields = tuple(FieldSpec(f"f{i}", v) for i, v in enumerate(spec.field_vocabs))
    cfg = DLRMConfig(fields=fields, d_embed=16, mlp_hidden=(64, 32),
                     backbone="dnn")
    build = dlrm_builder(cfg, ds.expected_frequencies(), lam=3e-5,
                         eval_batches=ds.eval_set(4))

    res = run_mpe_pipeline(
        build, lambda step: ds.batch(step), key=jax.random.PRNGKey(0),
        mpe_cfg=MPEConfig(lam=3e-5), optimizer=adam(1e-3),
        search_steps=150, retrain_steps=150,
        eval_fn=build(jax.random.PRNGKey(0), "plain", {})["eval_fn"])

    print(f"\ncompression ratio : {res['storage_ratio']:.4f} "
          f"({1/res['storage_ratio']:.0f}x)")
    print(f"average bit-width : {res['avg_bits']:.2f}")
    print(f"test AUC          : {res['eval']['auc']:.4f}")
    print(f"packed bytes      : {res['packed_bytes']:,} "
          f"(fp32 table would be {sum(spec.field_vocabs)*16*4:,})")

    # serve from the packed table
    serve_cfg = cfg._replace(compressor="packed",
                             comp_cfg={"bits": res["packed_meta"]["bits"],
                                       "d": 16, "n": res["packed_meta"]["n"]})
    params = {k: v for k, v in res["final_params"].items() if k != "embedding"}
    params["embedding"] = res["packed_table"]
    buffers = dict(res["buffers"], embedding={})
    logits, _, _ = DLRM.apply(params, buffers, res["state"],
                              {"ids": jnp.asarray(ds.batch(999)["ids"])},
                              serve_cfg, train=False)
    print(f"served batch from packed table: {logits.shape} logits, "
          f"mean p={float(jax.nn.sigmoid(logits).mean()):.3f}")


if __name__ == "__main__":
    main()
