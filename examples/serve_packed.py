"""Serving scenario: batched request scoring from a bit-packed table.

Drives the persistent serving engine (``repro.serve.Engine``) through the
``repro.launch.serve`` CLI: trains a quick MPE pipeline, registers the
serve_p99/serve_bulk cell shapes, then streams off-shape request batches
through the batcher and reports per-cell p50/p99 latency in the Figure-5
lookup-vs-compute split.

    PYTHONPATH=src python examples/serve_packed.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    # 300-row requests deliberately ride the 512-row serve_p99 cell (pad-to-
    # shape), and the bulk job chunks onto serve_bulk — the full engine path.
    main(["--steps", "20", "--batch", "300", "--bulk", "10000",
          "--train-steps", "80"])
