"""Serving scenario: batched request scoring from a bit-packed table.

Thin wrapper over repro.launch.serve (trains a quick pipeline, then measures
p50/p99 batch-scoring latency split like paper Figure 5).

    PYTHONPATH=src python examples/serve_packed.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
