"""MPE on GIN's categorical atom-type embedding (the molecule cell).

    PYTHONPATH=src python examples/gnn_molecule_mpe.py [--steps 150]
"""
import argparse

import jax
import numpy as np

from repro.core.mpe import MPEConfig
from repro.core.sampling import average_bits, feature_bits, sample_group_bits
from repro.data.graphs import make_molecule_batch
from repro.models.gnn import GIN, GINConfig
from repro.train.loop import Trainer
from repro.train.optimizer import adam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()

    mpe_cfg = MPEConfig(lam=3e-5, group_size=16)  # small vocab -> small groups
    cfg = GINConfig(n_layers=3, d_hidden=32, input_mode="categorical",
                    atom_vocab=119, readout="graph", n_classes=2,
                    compressor="mpe_search", comp_cfg=mpe_cfg._asdict())
    # atom frequencies are Zipf-ish in real molecule corpora
    freqs = (np.arange(1, 120) ** -1.1)
    params, buffers = GIN.init(jax.random.PRNGKey(0), cfg, freqs=freqs)

    n_graphs = 64

    def data_fn(step):
        b = make_molecule_batch(n_graphs, 12, 24, atom_vocab=119, seed=step)
        b.pop("n_graphs")  # static — injected below, not traced
        return b

    def loss_fn(p, bu, st, batch, *, step=None):
        graph = dict(batch, n_graphs=n_graphs)
        loss, ce = GIN.loss_fn(p, bu, graph, cfg, lam=mpe_cfg.lam, train=True,
                               step=step)
        return loss, (st, ce)

    tr = Trainer(loss_fn, params, buffers, {}, adam(3e-3))
    tr.run(data_fn, args.steps, log_every=50)

    gb = sample_group_bits(tr.params["embedding"], mpe_cfg)
    fb = feature_bits(gb, buffers["embedding"]["group_of_feature"])
    print(f"\natom-table avg bits: {average_bits(fb, mpe_cfg):.2f} "
          f"(ratio {average_bits(fb, mpe_cfg)/32:.4f})")


if __name__ == "__main__":
    main()
