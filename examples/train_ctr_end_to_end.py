"""End-to-end driver: train a ~100M-parameter CTR model for a few hundred
steps with the full MPE pipeline, checkpointing, and packed export.

    PYTHONPATH=src python examples/train_ctr_end_to_end.py [--steps 250]

Model: DNN backbone, 8 fields / 6.3M features × d=16 ≈ 101M embedding params
+ 1024-512-256 MLP (the paper's interaction net). ~15 min on this CPU; on a
v5e pod slice the same code runs under the production mesh.
"""
import argparse
import tempfile

import jax

from repro.core.mpe import MPEConfig
from repro.core.pipeline import run_mpe_pipeline
from repro.data.synthetic import CTRSpec, SyntheticCTR
from repro.embeddings.table import FieldSpec
from repro.models.dlrm import DLRMConfig
from repro.nn.module import param_count
from repro.train.optimizer import adam
from repro.zoo import dlrm_builder

VOCABS = (2_097_152, 1_048_576, 1_048_576, 786_432, 524_288, 524_288,
          262_144, 16_384)  # 6.3M features


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=250)
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="mpe_ckpt_")

    ds = SyntheticCTR(CTRSpec(field_vocabs=VOCABS, batch_size=args.batch))
    fields = tuple(FieldSpec(f"f{i}", v) for i, v in enumerate(VOCABS))
    cfg = DLRMConfig(fields=fields, d_embed=16,
                     mlp_hidden=(1024, 512, 256), backbone="dnn")
    build = dlrm_builder(cfg, ds.expected_frequencies(), lam=1e-5,
                         eval_batches=ds.eval_set(2))

    probe = build(jax.random.PRNGKey(0), "plain", {})
    print(f"model size: {param_count(probe['params'])/1e6:.1f}M params "
          f"({sum(VOCABS)*16/1e6:.0f}M embedding)")
    del probe

    res = run_mpe_pipeline(
        build, lambda step: ds.batch(step), key=jax.random.PRNGKey(0),
        mpe_cfg=MPEConfig(lam=1e-5), optimizer=adam(1e-3),
        search_steps=args.steps, retrain_steps=args.steps,
        eval_fn=build(jax.random.PRNGKey(0), "plain", {})["eval_fn"],
        ckpt_dir=ckpt)
    print(f"\nMPE on 101M-param table: ratio={res['storage_ratio']:.4f} "
          f"({1/max(res['storage_ratio'],1e-9):.0f}x), "
          f"avg_bits={res['avg_bits']:.2f}, eval={res['eval']}")
    print(f"checkpoints in {ckpt} (resume by re-running with --ckpt-dir)")


if __name__ == "__main__":
    main()
