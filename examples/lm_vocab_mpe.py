"""Beyond-paper: MPE on an LM's token-embedding table.

Token frequencies are Zipfian like CTR features, so MPE's frequency-grouped
precision search transfers directly (DESIGN.md §4): frequent tokens keep high
precision, the long tail compresses to 1-2 bits or drops to zero.

    PYTHONPATH=src python examples/lm_vocab_mpe.py [--steps 200]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mpe import MPEConfig
from repro.core.sampling import average_bits, feature_bits, sample_group_bits
from repro.data.tokens import TokenStream
from repro.models.lm import LM, LMConfig
from repro.train.loop import Trainer
from repro.train.optimizer import adam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    vocab = 4096
    ts = TokenStream(vocab, batch=16, seq_len=64)
    mpe_cfg = MPEConfig(lam=1e-5, embed_std=0.02)
    cfg = LMConfig(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                   head_dim=32, d_ff=256, vocab=vocab,
                   compressor="mpe_search", comp_cfg=mpe_cfg._asdict(),
                   embed_std=0.02)
    params, buffers = LM.init(jax.random.PRNGKey(0), cfg,
                              freqs=ts.expected_frequencies())

    def loss_fn(p, bu, st, batch, *, step=None):
        from repro.core import MPESearchEmbedding
        loss, ce = LM.loss_fn(p, bu, batch, cfg, train=True, step=step)
        reg = MPESearchEmbedding.reg_loss(p["embedding"], bu["embedding"],
                                          mpe_cfg)
        return loss + mpe_cfg.lam * reg, (st, jnp.mean(ce))

    tr = Trainer(loss_fn, params, buffers, {}, adam(1e-3))
    tr.run(lambda s: ts.batch_at(s), args.steps, log_every=50)

    gb = sample_group_bits(tr.params["embedding"], mpe_cfg)
    fb = feature_bits(gb, buffers["embedding"]["group_of_feature"])
    bits = np.asarray([0, 1, 2, 3, 4, 5, 6])[np.asarray(gb)]
    print(f"\nvocab-table avg bits: {average_bits(fb, mpe_cfg):.2f} "
          f"(ratio {average_bits(fb, mpe_cfg)/32:.4f})")
    print(f"frequent-quartile groups avg: {bits[:len(bits)//4].mean():.2f} bits")
    print(f"rare-quartile groups avg    : {bits[-len(bits)//4:].mean():.2f} bits")


if __name__ == "__main__":
    main()
