"""Compression benchmark matrix → BENCH_compression.json.

The apples-to-apples accuracy-vs-bytes-vs-latency matrix the storage-
compression surveys (arxiv 2311.15578, 2408.02304) call for: one row per
method — MPE served at several **live-repack byte budgets** (the
``repro.serve.repack`` path: each budget is planned, re-packed and swapped
into the running engine with zero recompiles) against every baseline in
``src/repro/core/baselines/`` (plain backbone, qr_trick, pep, optfs, alpt,
lsq_uniform) — each with an accuracy proxy (AUC/logloss on the shared
synthetic CTR eval set), embedding payload bytes, and serve p50/p99 measured
through the same ``Engine.score`` request path (baselines serve through
``repro.serve.baseline_score_cell``; MPE through the packed cells).

CI runs the ``--smoke`` variant every PR and diffs the artifact against the
checked-in baseline via ``scripts/bench_compare.py``.

    PYTHONPATH=src python benchmarks/compression_bench.py --smoke
    PYTHONPATH=src python benchmarks/compression_bench.py --out benchmarks/artifacts/BENCH_compression.json
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

try:        # script invocation: python benchmarks/compression_bench.py
    from common import (FIELD_VOCABS, LAM, METHOD_CFGS, dataset, fields,
                        run_baseline, run_mpe)
except ImportError:   # module invocation: python -m benchmarks.compression_bench
    from benchmarks.common import (FIELD_VOCABS, LAM, METHOD_CFGS, dataset,
                                   fields, run_baseline, run_mpe)
from repro.core.inference import build_packed_table
from repro.core.mpe import MPEConfig, make_groups
from repro.models.dlrm import DLRM, DLRMConfig
from repro.serve import Engine, baseline_score_cell
from repro.serve.repack import RepackPlanner, TableSwapper, headroom_capacities
from repro.train.metrics import auc as auc_metric
from repro.train.metrics import logloss as logloss_metric

FULL = dict(steps=150, serve_steps=30, serve_batch=256, p99_rows=512,
            budgets=(1.0, 0.75, 0.5, 0.25), headroom=0.6)
SMOKE = dict(steps=25, serve_steps=8, serve_batch=100, p99_rows=128,
             budgets=(1.0, 0.5), headroom=0.6)

BASELINES = ("backbone", "qr", "pep", "optfs", "alpt", "lsq")


def _dense_bytes() -> int:
    return sum(FIELD_VOCABS) * 16 * 4          # fp32 backbone table


def _time_scores(engine, serve_batch: int, n_steps: int) -> dict:
    """p50/p99 of end-to-end ``Engine.score`` wall-clock over a fresh
    request stream (one warmup request dropped)."""
    req_ds = dataset()
    ids0 = req_ds.batch(20_000)["ids"][:serve_batch]
    engine.score(ids0)                         # warm
    lat = []
    for step in range(n_steps):
        ids = req_ds.batch(21_000 + step)["ids"][:serve_batch]
        t0 = time.perf_counter()
        engine.score(ids)
        lat.append((time.perf_counter() - t0) * 1e3)
    return {"p50_ms": round(float(np.percentile(lat, 50)), 3),
            "p99_ms": round(float(np.percentile(lat, 99)), 3)}


def _packed_eval(serve_cfg, params, state, buffers, eval_batches) -> dict:
    """AUC/logloss of a packed table through the eval-mode forward — the
    accuracy proxy for each repack budget (mirrors ``repro.zoo._ctr_eval``)."""
    scores, labels = [], []
    for b in eval_batches:
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        logits, _, _ = DLRM.apply(params, buffers, state, batch, serve_cfg,
                                  train=False)
        scores.append(np.asarray(jax.nn.sigmoid(logits)))
        labels.append(np.asarray(batch["label"]))
    s, l = np.concatenate(scores), np.concatenate(labels)
    return {"auc": float(auc_metric(jnp.asarray(l), jnp.asarray(s))),
            "logloss": float(logloss_metric(jnp.asarray(l, jnp.float32),
                                            jnp.asarray(s)))}


def run_mpe_rows(cfg: dict) -> dict:
    """MPE at each byte budget via the live serving-time repack path."""
    out, res = run_mpe("dnn", steps=cfg["steps"], return_result=True)
    emb = res["final_params"]["embedding"]
    caps = headroom_capacities(res["packed_meta"], fraction=cfg["headroom"])
    mpe_cfg = MPEConfig(lam=LAM)
    table, meta = build_packed_table(
        np.asarray(emb["emb"]), np.asarray(res["feature_bits_idx"]),
        np.asarray(emb["alpha"]), np.asarray(emb["beta"]), mpe_cfg,
        row_capacities=caps)

    base = DLRMConfig(fields=fields(), d_embed=16, mlp_hidden=(64, 32),
                      backbone="dnn")
    serve_cfg = base._replace(compressor="packed",
                              comp_cfg={"bits": meta["bits"], "d": meta["d"],
                                        "n": meta["n"]})
    params = {k: v for k, v in res["final_params"].items() if k != "embedding"}
    params["embedding"] = table
    buffers = dict(res["buffers"], embedding={})
    state = res["state"]

    engine = Engine()
    engine.register_packed_model("mpe", DLRM, serve_cfg, params, state,
                                 buffers, shapes={"serve_p99": cfg["p99_rows"]},
                                 lookup_split=False)
    freqs = dataset().expected_frequencies()
    gof, _ = make_groups(freqs, mpe_cfg.group_size)
    planner = RepackPlanner(meta, gof, caps, frequencies=freqs)
    swapper = TableSwapper(engine, emb["emb"], emb["alpha"], emb["beta"],
                           mpe_cfg, capacities=caps)

    gbits = np.asarray(res["group_bits"])
    bytes_full = planner.bytes_packed(gbits)
    eval_batches = dataset().eval_set(4)
    dense = _dense_bytes()
    rows = {}
    for frac in cfg["budgets"]:
        c0 = engine.compile_count
        plan = planner.plan_budget(gbits, int(frac * bytes_full))
        swapper.repack(plan)
        engine.sched_step()                    # the atomic swap point
        if engine.compile_count != c0:
            raise RuntimeError("live repack recompiled a cell — the "
                               "zero-recompile invariant is broken")
        lat = _time_scores(engine, cfg["serve_batch"], cfg["serve_steps"])
        table_b, _ = swapper.build(plan.feature_bits_idx)
        ev = _packed_eval(serve_cfg, dict(params, embedding=table_b), state,
                          buffers, eval_batches)
        rows[f"mpe@{frac:.2f}"] = {
            **ev, **lat,
            "bytes": int(plan.bytes_packed),
            "ratio": round(plan.bytes_packed / dense, 6),
            "n_features_moved": int(plan.n_features_moved),
            "recompiles": engine.compile_count - c0,
        }
        print(f"[compression] mpe@{frac:.2f}: auc={ev['auc']:.4f} "
              f"bytes={plan.bytes_packed} p50={lat['p50_ms']}ms "
              f"(recompiles=0, moved={plan.n_features_moved})")
    rows["mpe@1.00" if 1.0 in cfg["budgets"] else next(iter(rows))][
        "search_auc"] = out["auc"]
    return rows


def run_baseline_row(method: str, cfg: dict) -> dict:
    """One baseline: train, eval, then serve through the generic cell."""
    r, trained = run_baseline("dnn", method, steps=cfg["steps"],
                              return_trained=True)
    engine = Engine()
    engine.register(baseline_score_cell(
        DLRM, trained["cfg"], trained["params"], trained["state"],
        trained["buffers"], batch=cfg["p99_rows"], arch=method,
        shape="serve_p99"))
    lat = _time_scores(engine, cfg["serve_batch"], cfg["serve_steps"])
    row = {"auc": r["auc"], "logloss": r["logloss"],
           "bytes": int(r["ratio"] * _dense_bytes()),
           "ratio": round(r["ratio"], 6), "seconds": round(r["seconds"], 2),
           **lat}
    print(f"[compression] {method}: auc={r['auc']:.4f} bytes={row['bytes']} "
          f"p50={lat['p50_ms']}ms")
    return row


def run(cfg: dict) -> dict:
    t0 = time.time()
    methods = run_mpe_rows(cfg)
    for m in BASELINES:
        assert m in METHOD_CFGS, m
        methods[m] = run_baseline_row(m, cfg)
    return {
        "config": {k: (list(v) if isinstance(v, tuple) else v)
                   for k, v in cfg.items()},
        "env": {"jax": jax.__version__,
                "backend": jax.default_backend(),
                "device_count": jax.device_count(),
                "platform": platform.platform()},
        "dense_bytes": _dense_bytes(),
        "methods": methods,
        "train_s": round(time.time() - t0, 2),
        "unix_time": int(time.time()),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short trainings + two budgets (the CI data point)")
    ap.add_argument("--out", default=None,
                    help="output path (default benchmarks/artifacts/"
                         "BENCH_compression.json)")
    args = ap.parse_args(argv)

    out_path = args.out or os.path.join("benchmarks", "artifacts",
                                        "BENCH_compression.json")
    result = run(dict(SMOKE if args.smoke else FULL,
                      mode="smoke" if args.smoke else "full"))
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)

    print(f"{'method':<12} {'auc':>7} {'bytes':>10} {'p50_ms':>8} {'p99_ms':>8}")
    for name, row in result["methods"].items():
        print(f"{name:<12} {row['auc']:>7.4f} {row['bytes']:>10} "
              f"{row['p50_ms']:>8} {row['p99_ms']:>8}")
    print(f"[compression] wrote {out_path}")


if __name__ == "__main__":
    main()
